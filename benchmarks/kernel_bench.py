"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle, us/call.

On CPU the timings only sanity-check plumbing (interpret mode executes the
kernel body in Python); the numbers that matter for the TPU target come from
the roofline analysis. Reported anyway for completeness."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core import problems
from repro.core.cola import build_env
from repro.core.partition import make_partition
from repro.core.subproblem import SubproblemSpec, block_gram, cd_solve_all
from repro.data import synthetic
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import cd_solve_pallas
from repro.models.attention import chunked_attention


def _time(fn, iters=3):
    fn()  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.time() - t0) / iters * 1e6


def run(fast: bool = True):
    csv_row("fig", "kernel", "case", "us_per_call")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, h, kvh, hd = 1, 256, 8, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kvh, hd))
    v = jax.random.normal(ks[2], (b, s, kvh, hd))
    pos = jnp.tile(jnp.arange(s), (b, 1)).astype(jnp.int32)
    csv_row("kernels", "flash_attention(pallas-interp)", f"{s}x{s}",
            f"{_time(lambda: flash_attention(q, k, v, pos, pos, mode='causal', block_q=64, block_kv=64)):.0f}")
    csv_row("kernels", "chunked_attention(jnp)", f"{s}x{s}",
            f"{_time(lambda: chunked_attention(q, k, v, pos, pos, mode='causal', kv_chunk=64)):.0f}")

    x, y, _ = synthetic.regression(256, 128, seed=0)
    prob = problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)
    kk = 8
    part = make_partition(prob.n, kk)
    env = build_env(prob, part)
    grads = jax.vmap(prob.grad_f)(jnp.zeros((kk, prob.d)))
    xp = jnp.zeros((kk, part.block))
    spec = SubproblemSpec(sigma_over_tau=kk / prob.tau, inv_k=1.0 / kk)
    csv_row("kernels", "cd_glm(pallas-interp)", f"K={kk},pass=1",
            f"{_time(lambda: cd_solve_pallas(prob, spec, env.a_parts, xp, grads, env.gp_parts, env.masks, part.block)):.0f}")
    csv_row("kernels", "cd_glm(jnp-oracle)", f"K={kk},pass=1",
            f"{_time(lambda: cd_solve_all(prob, spec, env.a_parts, xp, grads, env.gp_parts, env.masks, part.block)):.0f}")

    # Gram-cached CD: O(n_k) per coordinate step vs the residual path's O(d)
    gram = env.gram_parts if env.gram_parts is not None else block_gram(
        env.a_parts)
    csv_row("kernels", "cd_glm_gram(pallas-interp)", f"K={kk},pass=1",
            f"{_time(lambda: cd_solve_pallas(prob, spec, env.a_parts, xp, grads, env.gp_parts, env.masks, part.block, cd_mode='gram', gram_parts=gram)):.0f}")
    csv_row("kernels", "cd_glm_gram(jnp-oracle)", f"K={kk},pass=1",
            f"{_time(lambda: cd_solve_all(prob, spec, env.a_parts, xp, grads, env.gp_parts, env.masks, part.block, gram_parts=gram)):.0f}")


if __name__ == "__main__":
    run()
