"""Rounds/sec of the CoLA drivers: per-round loop vs round-block scan vs the
shard_map distributed runtime.

This is the framework-overhead benchmark behind the round-block engine
(``repro.core.executor``): for the paper's regime — cheap local computation
between communication rounds — the seed driver's per-round dispatch and its
blocking metric sync dominate wall-clock. The block executor amortizes one
dispatch over ``block_size`` rounds and records metrics on device; the
``repro.dist`` runtime rides the same engine, so its row documents the
shard_map wrapper's overhead on a 1-device mesh (the collectives are
identities there).

Writes ``BENCH_cola.json`` at the repo root — the committed trajectory CI
compares against. The full run also records a ``smoke_baseline`` section
with the reduced config CI actually executes; ``--check`` (the CI gate)
compares the current measurement against the committed numbers and FAILS on
a >20% rounds/sec regression (override with ``--tolerance`` or
``BENCH_TOLERANCE``). The loop driver serves as the machine-speed control:
committed bars scale with the measured loop drift, so a uniformly slower
runner passes while an engine that lost its dispatch amortization fails.

Usage:
  PYTHONPATH=src:. python benchmarks/round_bench.py            # full + write
  PYTHONPATH=src:. python benchmarks/round_bench.py --smoke --check  # CI gate
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timeit_rounds
from repro.core import executor as exec_engine
from repro.core import metrics as metrics_lib, problems, topology as topo
from repro.core.cola import ColaConfig, build_env, run_cola
from repro.core.partition import make_partition
from repro.data import synthetic
from repro.dist.runtime import run_dist_cola

ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_PATH = ROOT / "BENCH_cola.json"

# rounds/sec keys the --check gate enforces. The loop driver is the
# machine-speed CONTROL (pure per-round dispatch, no engine to regress): its
# measured/committed ratio estimates how much slower this machine is than
# the recording one, and the engine keys' committed bars scale down by that
# drift — so a globally-loaded runner passes while a block engine that
# degenerated toward per-round dispatch still fails.
_CONTROL = "loop_rounds_per_sec"
# recording-overhead rows: rounds/sec of the block engine under each
# recorder (gather-gap vs local-certificate) at three record cadences, on
# the simulator and the 1-device dist runtime
_REC_MODES = ("sim", "dist")
_REC_KINDS = ("gap", "cert")
_REC_EVERY = ("1", "10", "inf")
_REC_KEYS = tuple(f"rec_{m}_{r}_e{e}_rounds_per_sec"
                  for m in _REC_MODES for r in _REC_KINDS for e in _REC_EVERY)
# plan-executed gossip vs the dense all-gather on a REAL 8-device node mesh
# (subprocess: plan execution places one node per device) — the rows that
# gate the topology-program compiler's dispatch overhead
_PLAN_KEYS = ("plan_gossip_rounds_per_sec", "dense_gossip_rounds_per_sec")
_GATED = ("block_rounds_per_sec", "dist_block_rounds_per_sec") \
    + _REC_KEYS + _PLAN_KEYS
# robust (trimmed-mean) plan gossip is gated on its SAME-RUN ratio against
# plain plan gossip (< _ROBUST_MAX_OVERHEAD x), not on an absolute
# rounds/sec bar — the ratio is machine-drift free, so the key stays out
# of _GATED and out of the committed-baseline bookkeeping
_ROBUST_KEY = "robust_gossip_rounds_per_sec"
_ROBUST_MAX_OVERHEAD = 1.3
# smoke's ~30ms runs are dispatch-bound and the same-run overhead ratios
# jitter +-25% even interleaved (single-core CI boxes), so --smoke holds
# looser SANITY bars; the tight claims above are enforced on the full run
_ROBUST_SMOKE_MAX = 2.0
_QUANT_SMOKE_MAX = 4.0
# quantized (int8 + EF) plan gossip: same-run ratio against plain plan
# gossip. The codec trades FLOPs for bytes, so on a CPU mesh — where bytes
# are free and the encode is real work — it IS slower; the bar caps how
# much. The pipelined variant double-buffers the payload so the wire work
# can overlap the solve; CPU has no async collectives to overlap, so its
# gate is no-regression against the unpipelined quantized run (the
# overlap itself is asserted structurally: pipeline_order_ok).
_QUANT_KEY = "quant_gossip_rounds_per_sec"
_QUANT_MAX_OVERHEAD = 3.0
_PIPE_KEY = "pipelined_gossip_rounds_per_sec"
# streamed client sampling (ColaConfig(participation=SampleConfig(...))) is
# gated on its SAME-RUN ratio against the static full-K run on the same
# complete graph: deriving each round's mask + reweighted W inside the scan
# must cost at most _SAMPLED_MAX_OVERHEAD x the static-schedule round
_SAMPLED_KEY = "sampled_rounds_per_sec"
_SAMPLED_STATIC_KEY = "staticK_rounds_per_sec"
_SAMPLED_MAX_OVERHEAD = 2.0


def bench_config(smoke: bool = False) -> dict:
    rounds = 50 if smoke else 200
    k = 16
    n_samples, n_features = (128, 64) if smoke else (256, 128)
    record_every = 1  # the run_cola default: the loop driver syncs per round
    x, y, _ = synthetic.regression(n_samples, n_features, seed=0)
    prob = problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)
    graph = topo.ring(k)
    cfg = ColaConfig(kappa=1.0)
    mesh = jax.make_mesh((1,), ("data",))
    tag = f"K={k},T={rounds}"

    csv_row("fig", "executor", "case", "rounds_per_sec")
    loop_rps, loop_res = timeit_rounds(
        lambda: run_cola(prob, graph, cfg, rounds, record_every=record_every,
                         executor="loop"), rounds, label="loop")
    csv_row("round_bench", "loop", tag, f"{loop_rps:.1f}")
    block_rps, block_res = timeit_rounds(
        lambda: run_cola(prob, graph, cfg, rounds, record_every=record_every,
                         executor="block", block_size=64), rounds,
        label="block")
    csv_row("round_bench", "block", tag, f"{block_rps:.1f}")
    dist_rps, dist_res = timeit_rounds(
        lambda: run_dist_cola(prob, graph, cfg, mesh, rounds,
                              record_every=record_every, comm="dense",
                              block_size=64), rounds, label="dist")
    csv_row("round_bench", "dist_block", tag, f"{dist_rps:.1f}")
    speedup = block_rps / loop_rps
    csv_row("round_bench", "speedup", tag, f"{speedup:.2f}x")

    # the three drivers must agree (bitwise on state; tests assert it too)
    assert np.array_equal(np.asarray(loop_res.state.x_parts),
                          np.asarray(block_res.state.x_parts)), \
        "block executor diverged from the loop driver"
    assert np.array_equal(np.asarray(block_res.state.x_parts),
                          np.asarray(dist_res.state.x_parts)), \
        "dist runtime diverged from the block executor"

    result = {
        "config": {"K": k, "rounds": rounds, "n_samples": n_samples,
                   "n_features": n_features, "record_every": record_every,
                   "kappa": cfg.kappa, "topology": "ring",
                   "backend": jax.default_backend()},
        "loop_rounds_per_sec": round(loop_rps, 2),
        "block_rounds_per_sec": round(block_rps, 2),
        "dist_block_rounds_per_sec": round(dist_rps, 2),
        "speedup": round(speedup, 2),
        "final_primal": {"loop": loop_res.history["primal"][-1],
                         "block": block_res.history["primal"][-1],
                         "dist": dist_res.history["primal"][-1]},
    }
    result.update(bench_recording(smoke))
    result.update(bench_sampled(smoke))
    result.update(bench_plan_gossip(smoke))
    return result


def bench_sampled(smoke: bool = False) -> dict:
    """Streamed client sampling vs the static full-K schedule, interleaved.

    Both runs execute the block engine on the same complete graph; the
    sampled run derives each round's active mask and reweighted W on device
    inside the scan (``ScheduleProgram``) instead of slicing a
    pre-materialized stack. The gate holds the SAME-RUN slowdown ratio
    under ``_SAMPLED_MAX_OVERHEAD`` — machine-drift free, like the robust
    and quant ratio gates."""
    from repro.core.schedule import SampleConfig

    rounds = 50 if smoke else 200
    k = 16
    n_samples, n_features = (128, 64) if smoke else (256, 128)
    x, y, _ = synthetic.regression(n_samples, n_features, seed=3)
    prob = problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)
    graph = topo.complete(k)
    cfg_static = ColaConfig(kappa=1.0)
    cfg_sampled = ColaConfig(
        kappa=1.0, participation=SampleConfig(k_active=4, mode="dense"))

    def run(c):
        return run_cola(prob, graph, c, rounds, record_every=rounds - 1,
                        executor="block", block_size=64)

    bests, _ = timeit_rounds(
        [lambda: run(cfg_static), lambda: run(cfg_sampled)], rounds,
        repeats=8 if smoke else 4, label="sampled_pair")
    static_rps, sampled_rps = bests
    csv_row("round_bench", "sampled", f"K={k},K'=4,T={rounds}",
            f"static {static_rps:.1f} / sampled {sampled_rps:.1f}")
    return {_SAMPLED_STATIC_KEY: round(static_rps, 2),
            _SAMPLED_KEY: round(sampled_rps, 2)}


_PLAN_BENCH_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from benchmarks.common import timeit_rounds
    from repro.core import problems, topology as topo
    from repro.core.cola import ColaConfig
    from repro.data import synthetic
    from repro.dist.runtime import run_dist_cola

    rounds, n_s, n_f = (int(a) for a in sys.argv[1:4])
    x, y, _ = synthetic.regression(n_s, n_f, seed=0)
    prob = problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)
    graph = topo.torus_2d(2, 4)  # non-circulant: the plan path's home turf
    cfg = ColaConfig(kappa=1.0)
    mesh = jax.make_mesh((8,), ("data",))

    def bench(comm, run_cfg=cfg):
        return timeit_rounds(
            lambda: run_dist_cola(prob, graph, run_cfg, mesh, rounds,
                                  comm=comm, record_every=rounds - 1),
            rounds, label="plan_" + comm)

    # the robust/plan and pipe/quant gates are RATIOS of two same-run
    # measurements, so time each pair INTERLEAVED (timeit_rounds with two
    # runners: a load spike hits both runs, not whichever happened to go
    # second) and with more repeats than the absolute rows — at smoke's 50
    # rounds a single rep is ~30ms and best-of-3 back-to-back still carries
    # +-20% jitter
    def bench_pair(cfg_a, cfg_b, reps=8):
        run = lambda c: run_dist_cola(prob, graph, c, mesh, rounds,
                                      comm="plan", record_every=rounds - 1)
        bests, results = timeit_rounds(
            [lambda: run(cfg_a), lambda: run(cfg_b)], rounds, repeats=reps,
            label="plan_pair")
        return bests[0], results[0], bests[1], results[1]

    plan_rps, plan_res, robust_rps, robust_res = bench_pair(
        cfg, ColaConfig(kappa=1.0, robust="trim"))
    dense_rps, dense_res = bench("dense")
    assert np.allclose(plan_res.history["primal"][-1],
                       dense_res.history["primal"][-1], rtol=1e-5), \\
        "plan gossip diverged from the dense oracle"
    assert np.allclose(robust_res.history["primal"][-1],
                       plan_res.history["primal"][-1], rtol=1e-5), \\
        "robust trim on a clean run diverged from plain plan gossip"

    quant_rps, quant_res, pipe_rps, pipe_res = bench_pair(
        ColaConfig(kappa=1.0, wire="int8"),
        ColaConfig(kappa=1.0, wire="int8", pipeline=True))
    assert np.array_equal(np.asarray(quant_res.state.x_parts),
                          np.asarray(pipe_res.state.x_parts)), \\
        "pipelined int8 run diverged from the unpipelined one"

    # HLO structure: the pipelined body's first ppermute must consume the
    # CARRIED double buffer (operand chain free of compute) — and the
    # unpipelined body must fail the same check, or the checker is vacuous
    from repro.analysis import drivers as an_drivers
    hlo_p, _ = an_drivers.quant_round_hlo(prob, graph, 8, 4, "int8",
                                          pipeline=True)
    hlo_u, _ = an_drivers.quant_round_hlo(prob, graph, 8, 4, "int8")
    order_ok = (not an_drivers.pipeline_order_findings(hlo_p, "bench")
                and bool(an_drivers.pipeline_order_findings(hlo_u, "bench")))

    print("PLANBENCH " + json.dumps(
        {"plan_gossip_rounds_per_sec": round(plan_rps, 2),
         "dense_gossip_rounds_per_sec": round(dense_rps, 2),
         "robust_gossip_rounds_per_sec": round(robust_rps, 2),
         "quant_gossip_rounds_per_sec": round(quant_rps, 2),
         "pipelined_gossip_rounds_per_sec": round(pipe_rps, 2),
         "pipeline_order_ok": order_ok}))
""")


def bench_plan_gossip(smoke: bool = False) -> dict:
    """Plan-executed gossip vs dense all-gather on an 8-virtual-device node
    mesh (torus 2x4 — non-circulant, so only the plan path keeps
    neighbor-only comm). Runs in a subprocess so the main process keeps the
    single real CPU device for the other rows."""
    rounds = 50 if smoke else 200
    n_s, n_f = (128, 64) if smoke else (256, 128)
    env = dict(os.environ, PYTHONPATH="src:.")
    out = subprocess.run(
        [sys.executable, "-c", _PLAN_BENCH_SCRIPT, str(rounds), str(n_s),
         str(n_f)], env=env, capture_output=True, text=True, timeout=900,
        cwd=str(ROOT))
    for line in out.stdout.splitlines():
        if line.startswith("PLANBENCH "):
            vals = json.loads(line[len("PLANBENCH "):])
            for key, rps in vals.items():
                csv_row("round_bench", key, f"K=8,T={rounds}",
                        str(rps) if isinstance(rps, bool) else f"{rps:.1f}")
            return vals
    raise RuntimeError("plan gossip bench subprocess failed:\n"
                       + out.stdout + "\n" + out.stderr)


def bench_recording(smoke: bool = False) -> dict:
    """Recording-overhead rows: block-engine rounds/sec under the
    gather-``GapRecorder`` vs the local-``CertificateRecorder`` at
    ``record_every`` in {1, 10, inf}, simulator + dist runtime.

    The certificate recorder is built with stopping disabled so every case
    executes the full round budget (rounds/sec stays comparable); the
    L-bounded problem is a lasso (Prop.-1 requirement).
    """
    rounds = 50 if smoke else 200
    k = 16
    n_samples, n_features = (128, 64) if smoke else (256, 128)
    x, y, _ = synthetic.regression(n_samples, n_features, seed=1,
                                   sparsity_solution=0.2)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), 1e-2)
    graph = topo.ring(k)
    cfg = ColaConfig(kappa=1.0)
    mesh = jax.make_mesh((1,), ("data",))
    part = make_partition(prob.n, k)
    env = build_env(prob, part)
    recorders = {
        "gap": metrics_lib.GapRecorder(prob, part),
        "cert": metrics_lib.certificate_recorder(
            prob, part, env, graph, eps=1e-3, stop_on_certified=False),
    }
    out = {}
    for rec_name, rec in recorders.items():
        for every_name in _REC_EVERY:
            every = rounds if every_name == "inf" else int(every_name)
            sim_rps, _ = timeit_rounds(
                lambda: run_cola(prob, graph, cfg, rounds,
                                 record_every=every, recorder=rec,
                                 block_size=64), rounds, repeats=2,
                label=f"rec_sim_{rec_name}_e{every_name}")
            out[f"rec_sim_{rec_name}_e{every_name}_rounds_per_sec"] = \
                round(sim_rps, 2)
            dist_rps, _ = timeit_rounds(
                lambda: run_dist_cola(prob, graph, cfg, mesh, rounds,
                                      record_every=every, recorder=rec,
                                      comm="dense", block_size=64),
                rounds, repeats=2,
                label=f"rec_dist_{rec_name}_e{every_name}")
            out[f"rec_dist_{rec_name}_e{every_name}_rounds_per_sec"] = \
                round(dist_rps, 2)
            csv_row("round_bench", f"rec_{rec_name}_e{every_name}",
                    f"K={k},T={rounds}",
                    f"sim {sim_rps:.1f} / dist {dist_rps:.1f}")
    return out


def delta_table(result: dict, smoke: bool) -> dict | None:
    """Per-row percent delta of every measured rounds/sec key against the
    committed BENCH_cola.json (positive = faster than committed). Returns
    ``{key: {"committed", "measured", "delta_pct"}}`` — the human-readable
    companion to the pass/fail gate, so a CI log shows HOW FAR each row
    moved, not just whether it crossed the bar. None when no committed
    baseline (or section) exists; the gate itself reports that failure."""
    if not BENCH_PATH.exists():
        return None
    committed = json.loads(BENCH_PATH.read_text())
    baseline = committed.get("smoke_baseline") if smoke else committed
    if not baseline:
        return None
    table = {}
    for key in (_CONTROL,) + _GATED + (_ROBUST_KEY, _QUANT_KEY, _PIPE_KEY,
                                       _SAMPLED_STATIC_KEY, _SAMPLED_KEY):
        base, got = baseline.get(key), result.get(key)
        if not base or got is None:
            continue
        table[key] = {"committed": base, "measured": got,
                      "delta_pct": round(100.0 * (got - base) / base, 1)}
    return table


def print_delta_table(table: dict) -> None:
    width = max(len(k) for k in table)
    print(f"{'key':<{width}}  {'committed':>10}  {'measured':>10}  "
          f"{'delta':>8}", flush=True)
    for key, row in table.items():
        print(f"{key:<{width}}  {row['committed']:>10.1f}  "
              f"{row['measured']:>10.1f}  {row['delta_pct']:>+7.1f}%",
              flush=True)


def check_regression(result: dict, smoke: bool, tolerance: float) -> list[str]:
    """Compare measured rounds/sec against the committed BENCH_cola.json.

    Each engine key must stay above ``(1 - tolerance) * committed * drift``
    where ``drift = min(1, measured_loop / committed_loop)`` is the
    machine-speed correction from the loop control (a faster machine keeps
    the full committed bar; a loaded/slower one lowers it proportionally
    instead of failing spuriously). Missing baseline file/section is a
    failure too — the gate must never pass vacuously.
    """
    if not BENCH_PATH.exists():
        return [f"no committed baseline at {BENCH_PATH}"]
    committed = json.loads(BENCH_PATH.read_text())
    baseline = committed.get("smoke_baseline") if smoke else committed
    if not baseline:
        return ["committed BENCH_cola.json has no smoke_baseline section"]
    if not baseline.get(_CONTROL):
        return [f"baseline missing the {_CONTROL} control"]
    drift = min(1.0, result[_CONTROL] / baseline[_CONTROL])
    csv_row("round_bench", "gate", "machine_drift", f"{drift:.2f}")
    failures = []
    for key in _GATED:
        base = baseline.get(key)
        if base is None:
            failures.append(f"baseline missing {key}")
            continue
        got, bar = result[key], (1.0 - tolerance) * baseline[key] * drift
        if got < bar:
            failures.append(
                f"{key}: {got:.1f} rounds/s is below the drift-adjusted bar "
                f"{bar:.1f} (committed {base:.1f}, machine drift "
                f"{drift:.2f}, tolerance {tolerance:.0%})")
        csv_row("round_bench", "gate", key,
                f"{got:.1f} vs bar {bar:.1f} (committed {base:.1f})")
    # robust-mixing overhead: same-run ratio against plain plan gossip, so
    # no committed baseline and no drift correction is involved (smoke
    # holds the sanity bars — see _ROBUST_SMOKE_MAX)
    robust_bar = _ROBUST_SMOKE_MAX if smoke else _ROBUST_MAX_OVERHEAD
    robust = result.get(_ROBUST_KEY)
    if not robust:
        failures.append(f"missing {_ROBUST_KEY} measurement")
    else:
        overhead = result["plan_gossip_rounds_per_sec"] / robust
        csv_row("round_bench", "gate", _ROBUST_KEY,
                f"{overhead:.2f}x overhead vs plain plan gossip "
                f"(bar {robust_bar:.2f}x)")
        if overhead > robust_bar:
            failures.append(
                f"{_ROBUST_KEY}: robust trim costs {overhead:.2f}x over "
                f"plain plan gossip (bar {robust_bar:.2f}x)")
    # quantized-wire overhead and pipelining: same-run ratios too
    quant_bar = _QUANT_SMOKE_MAX if smoke else _QUANT_MAX_OVERHEAD
    quant = result.get(_QUANT_KEY)
    if not quant:
        failures.append(f"missing {_QUANT_KEY} measurement")
    else:
        overhead = result["plan_gossip_rounds_per_sec"] / quant
        csv_row("round_bench", "gate", _QUANT_KEY,
                f"{overhead:.2f}x overhead vs fp32 plan gossip "
                f"(bar {quant_bar:.2f}x)")
        if overhead > quant_bar:
            failures.append(
                f"{_QUANT_KEY}: the int8 codec costs {overhead:.2f}x over "
                f"fp32 plan gossip (bar {quant_bar:.2f}x)")
    # streamed-sampling overhead: same-run ratio against the static full-K
    # run (the 2x bar from the streaming schedule's acceptance criterion)
    sampled, static = result.get(_SAMPLED_KEY), \
        result.get(_SAMPLED_STATIC_KEY)
    if not sampled or not static:
        failures.append(f"missing {_SAMPLED_KEY}/{_SAMPLED_STATIC_KEY} "
                        "measurement")
    else:
        overhead = static / sampled
        csv_row("round_bench", "gate", _SAMPLED_KEY,
                f"{overhead:.2f}x overhead vs static full-K "
                f"(bar {_SAMPLED_MAX_OVERHEAD:.2f}x)")
        if overhead > _SAMPLED_MAX_OVERHEAD:
            failures.append(
                f"{_SAMPLED_KEY}: streamed participation costs "
                f"{overhead:.2f}x over the static full-K schedule "
                f"(bar {_SAMPLED_MAX_OVERHEAD:.2f}x)")
    pipe = result.get(_PIPE_KEY)
    if not pipe or not quant:
        failures.append(f"missing {_PIPE_KEY} measurement")
    else:
        ratio = pipe / quant
        # smoke geometry is dispatch-bound (the ~30ms runs measure the
        # extra buffer carry, not the round), so only the full-size run
        # holds the >= 1.0x no-regression bar; smoke gets double slack
        bar = 1.0 - (2 * tolerance if smoke else tolerance)
        csv_row("round_bench", "gate", _PIPE_KEY,
                f"{ratio:.2f}x vs unpipelined quantized "
                f"(bar {bar:.2f}x)")
        if ratio < bar:
            failures.append(
                f"{_PIPE_KEY}: pipelining costs {ratio:.2f}x of the "
                f"unpipelined quantized run (bar {bar:.2f}x) — "
                "the double buffer is adding work, not hiding it")
    if not result.get("pipeline_order_ok"):
        failures.append(
            "pipeline_order_ok is false: the pipelined body's first "
            "ppermute no longer consumes the carried double buffer (or the "
            "order checker stopped discriminating against the unpipelined "
            "body)")
    return failures


def run(smoke: bool = False, check: bool = False,
        tolerance: float = 0.2) -> dict:
    result = {"bench": "cola_round_executor"}
    exec_engine.driver_cache_stats(reset=True)
    result.update(bench_config(smoke))
    if check:
        # retrace accounting (the analysis.RetraceMonitor counters): every
        # timed repeat must HIT the driver cache — a miss per repeat means
        # the content key churns and each "measurement" re-traces, so the
        # rounds/sec rows time compilation instead of the engine
        stats = exec_engine.driver_cache_stats()
        result["driver_cache"] = dict(stats)
        csv_row("round_bench", "retrace", "driver_cache",
                f"hits={stats['hits']} misses={stats['misses']} "
                f"bypass={stats['bypass']}")
        if stats["hits"] < stats["misses"]:
            print("REGRESSION: driver cache misses outnumber hits "
                  f"({stats['misses']} misses vs {stats['hits']} hits) — "
                  "the bench is retracing per repeat", file=sys.stderr)
            sys.exit(1)
        # gate against the COMMITTED numbers before any rewrite below —
        # checking after the write would compare the measurement to itself
        table = delta_table(result, smoke)
        if table:
            result["delta_vs_committed"] = table
            print_delta_table(table)
        failures = check_regression(result, smoke, tolerance)
        if failures:
            for msg in failures:
                print(f"REGRESSION: {msg}", file=sys.stderr)
            sys.exit(1)
        csv_row("round_bench", "gate", "result", "ok")
    if not smoke:
        # the committed trajectory carries BOTH configs: the full numbers
        # and the reduced config CI re-measures under --smoke --check. The
        # smoke floor is the per-key min of two runs (control included) so
        # run-to-run noise on the recording machine doesn't inflate the
        # committed bar; speedup is recomputed from the floored values.
        smoke_a, smoke_b = bench_config(True), bench_config(True)
        for key in (_CONTROL,) + _GATED:
            smoke_a[key] = min(smoke_a[key], smoke_b[key])
        smoke_a["speedup"] = round(
            smoke_a["block_rounds_per_sec"] / smoke_a[_CONTROL], 2)
        result["smoke_baseline"] = smoke_a
        BENCH_PATH.write_text(json.dumps(result, indent=2) + "\n")
        csv_row("round_bench", "json", str(BENCH_PATH), "written")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, no BENCH_cola.json write")
    ap.add_argument("--check", action="store_true",
                    help="fail on >tolerance rounds/sec slowdown vs the "
                         "committed BENCH_cola.json")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_TOLERANCE", "0.2")))
    args = ap.parse_args()
    run(smoke=args.smoke, check=args.check, tolerance=args.tolerance)
