"""Rounds/sec of the CoLA drivers: per-round Python loop vs round-block scan.

This is the framework-overhead benchmark behind the round-block engine
(``repro.core.executor``): for the paper's regime — cheap local computation
between communication rounds — the seed driver's per-round dispatch and its
blocking metric sync dominate wall-clock. The block executor amortizes one
dispatch over ``block_size`` rounds and records metrics on device.

Writes ``BENCH_cola.json`` at the repo root (the committed trajectory the
CI smoke run and future PRs compare against). ``--smoke`` runs a reduced
config and skips the JSON write.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, run_cola
from repro.data import synthetic

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _bench_case(prob, graph, cfg, rounds, record_every, **kwargs):
    """Wall-clock one full run (after a warmup run that owns compilation)."""
    run_cola(prob, graph, cfg, rounds, record_every=record_every, **kwargs)
    t0 = time.perf_counter()
    res = run_cola(prob, graph, cfg, rounds, record_every=record_every,
                   **kwargs)
    jax.block_until_ready(res.state.x_parts)
    return rounds / (time.perf_counter() - t0), res


def run(smoke: bool = False) -> dict:
    rounds = 50 if smoke else 200
    k = 16
    n_samples, n_features = (128, 64) if smoke else (256, 128)
    record_every = 1  # the run_cola default: the loop driver syncs per round
    x, y, _ = synthetic.regression(n_samples, n_features, seed=0)
    prob = problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)
    graph = topo.ring(k)
    cfg = ColaConfig(kappa=1.0)

    csv_row("fig", "executor", "case", "rounds_per_sec")
    loop_rps, loop_res = _bench_case(prob, graph, cfg, rounds, record_every,
                                     executor="loop")
    csv_row("round_bench", "loop", f"K={k},T={rounds}", f"{loop_rps:.1f}")
    block_rps, block_res = _bench_case(prob, graph, cfg, rounds, record_every,
                                       executor="block", block_size=64)
    csv_row("round_bench", "block", f"K={k},T={rounds}", f"{block_rps:.1f}")
    speedup = block_rps / loop_rps
    csv_row("round_bench", "speedup", f"K={k},T={rounds}", f"{speedup:.2f}x")

    # the two drivers must agree (bitwise on state; tests assert it too)
    import numpy as np
    assert np.array_equal(np.asarray(loop_res.state.x_parts),
                          np.asarray(block_res.state.x_parts)), \
        "block executor diverged from the loop driver"

    result = {
        "bench": "cola_round_executor",
        "config": {"K": k, "rounds": rounds, "n_samples": n_samples,
                   "n_features": n_features, "record_every": record_every,
                   "kappa": cfg.kappa, "topology": "ring",
                   "backend": jax.default_backend()},
        "loop_rounds_per_sec": round(loop_rps, 2),
        "block_rounds_per_sec": round(block_rps, 2),
        "speedup": round(speedup, 2),
        "final_primal": {"loop": loop_res.history["primal"][-1],
                         "block": block_res.history["primal"][-1]},
    }
    if not smoke:
        out = ROOT / "BENCH_cola.json"
        out.write_text(json.dumps(result, indent=2) + "\n")
        csv_row("round_bench", "json", str(out), "written")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, no BENCH_cola.json write")
    args = ap.parse_args()
    run(smoke=args.smoke)
