"""Attack-harness smoke: the end-to-end robustness story in under a minute.

Three checks, CI-sized (K=16 torus, seeded 2-node sign-flip Byzantine):

1. undefended: the attacked run visibly breaks AND the honest-cohort
   certificate detects it (``violated_round`` is set) — lying participants
   cannot silently poison a run that claims a duality-gap guarantee;
2. ``robust="trim"`` neutralizes the same attack: the run converges within
   2x the clean round count and the certificate stays sound;
3. the distributed plan executor (``run_dist_cola(comm="plan")``) agrees
   with the simulator on the defended run — trim is bitwise on any mesh
   the host exposes (set XLA_FLAGS=--xla_force_host_platform_device_count=4
   to exercise a real multi-device mesh, as the dist-4dev CI job does).

Prints ``ATTACK_SMOKE_OK`` on success; any failure raises.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import attack
from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, run_cola
from repro.data import synthetic
from repro.dist.runtime import run_dist_cola


def run() -> None:
    x, y, _ = synthetic.regression(48, 24, seed=0)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)
    graph = topo.torus_2d(4, 4)
    byz = attack.Byzantine(nodes=(0, 10), mode="sign_flip", scale=10.0,
                           start=5, seed=1)

    def sim(robust, atk):
        cfg = ColaConfig(kappa=2.0, robust=robust)
        return run_cola(prob, graph, cfg, rounds=2000, record_every=20,
                        recorder="gap+certificate", eps=1.0,
                        attacks=([atk] if atk else None))

    clean = sim(None, None)
    assert clean.history["stop_round"] is not None, \
        "clean run never certified the eps=1.0 gap"
    assert clean.history["violated_round"] is None

    undefended = sim(None, byz)
    assert undefended.history["violated_round"] is not None, \
        "undefended sign-flip attack went undetected by the certificate"
    print(f"attack_smoke,undefended,violated_round="
          f"{undefended.history['violated_round']}")

    trim = sim("trim", byz)
    assert trim.history["violated_round"] is None, \
        "trim-defended run tripped the honest-cohort certificate"
    assert trim.history["stop_round"] is not None and \
        trim.history["stop_round"] <= 2 * clean.history["stop_round"], \
        "trim defense did not converge within 2x the clean round count"
    print(f"attack_smoke,trim,stop_round={trim.history['stop_round']} "
          f"(clean {clean.history['stop_round']})")

    # defended run through the compiled topology-plan executor: bitwise
    # against the simulator on whatever mesh the host exposes
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("nodes",))
    cfg = ColaConfig(kappa=2.0, robust="trim")
    dist = run_dist_cola(prob, graph, cfg, mesh, rounds=2000, comm="plan",
                         record_every=20, recorder="gap+certificate",
                         eps=1.0, attacks=[byz])
    np.testing.assert_array_equal(
        np.asarray(trim.state.x_parts), np.asarray(dist.state.x_parts),
        err_msg="defended plan executor diverged bitwise from simulator")
    assert dist.history["violated_round"] is None
    assert dist.history["stop_round"] == trim.history["stop_round"]
    print(f"attack_smoke,dist_plan,devices={n_dev},bitwise=ok")
    print("ATTACK_SMOKE_OK")


if __name__ == "__main__":
    run()
