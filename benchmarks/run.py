"""Benchmark runner: one section per paper figure + roofline summary.

  PYTHONPATH=src python -m benchmarks.run [--full]

Output is CSV-ish lines prefixed by the figure tag. ``--full`` uses the
paper-scale problem sizes (slow on CPU); the default is a reduced but
faithful sweep.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig2,fig3,fig4,kernels,roofline")
    args = ap.parse_args()
    fast = not args.full
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (fig1_theta, fig2_baselines, fig3_topology,
                            fig4_fault, kernel_bench, roofline)

    sections = [
        ("fig1", lambda: fig1_theta.run(fast)),
        ("fig2", lambda: fig2_baselines.run(fast)),
        ("fig3", lambda: fig3_topology.run(fast)),
        ("fig4", lambda: fig4_fault.run(fast)),
        ("kernels", lambda: kernel_bench.run(fast)),
    ]
    for name, fn in sections:
        if only and name not in only:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # keep the suite going; report the failure
            print(f"{name},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
            raise
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)

    if not only or "roofline" in only:
        print("\n===== roofline (single-pod baselines) =====", flush=True)
        rows = roofline.run()
        if rows:
            print("fig,arch,shape,compute_s,memory_s,collective_s,dominant,"
                  "useful_ratio,temp_gb_dev")
            for r in rows:
                print(f"roofline,{r['arch']},{r['shape']},"
                      f"{r['compute_s']:.4e},{r['memory_s']:.4e},"
                      f"{r['collective_s']:.4e},{r['dominant']},"
                      f"{r['useful_ratio']:.4f},{r['hbm_gb']:.2f}")
        else:
            print("# no dry-run artifacts found — run "
                  "`python -m repro.launch.dryrun --all` first")


if __name__ == "__main__":
    main()
