"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import problems
from repro.data import synthetic


def timed(fn, *args, **kwargs):
    t0 = time.time()
    out = fn(*args, **kwargs)
    return out, time.time() - t0


def make_ridge(n_samples=2000, n_features=400, lam=1e-4, seed=0):
    """Fig. 1 stand-in: dense synthetic normal regression (paper: 10000x1000).

    Reduced by default so the CPU container sweeps in minutes; pass the
    paper's sizes for the full reproduction."""
    x, y, _ = synthetic.regression(n_samples, n_features, seed=seed)
    return problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), lam), (x, y)


def make_lasso(n_samples=2000, n_features=400, lam=1e-5, seed=1):
    """Webspam stand-in (paper: 350k x 16M sparse)."""
    x, y, _ = synthetic.regression(n_samples, n_features, seed=seed,
                                   sparsity_solution=0.1)
    return problems.lasso(jnp.asarray(x), jnp.asarray(y), lam), (x, y)


def csv_row(*cols):
    print(",".join(str(c) for c in cols), flush=True)
