"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import problems
from repro.data import synthetic


def timed(fn, *args, **kwargs):
    t0 = time.time()
    out = fn(*args, **kwargs)
    return out, time.time() - t0


def timeit_rounds(runners, rounds, *, repeats=3, ready=None, label="bench"):
    """Best-of-``repeats`` rounds/sec of one runner — or of several, timed
    INTERLEAVED.

    One warmup call per runner owns compilation before any clock starts;
    scheduler noise slows individual runs, never speeds them, so max
    rounds/sec is the stable statistic for a regression gate. A sequence of
    runners is timed round-robin (repeat 1 of each, then repeat 2, ...) so
    a load spike hits every runner of a same-run ratio, not whichever
    happened to go second. Every timed repeat records a ``repro.obs.trace``
    span (``<label>-repeat``), so a scoped tracer around a bench collects
    the per-repeat wall-clock timeline alongside the returned best.

    Returns ``(best, last_result)`` for a single callable,
    ``(bests, last_results)`` lists for a sequence. ``ready`` blocks on the
    result (default: ``jax.block_until_ready(res.state.x_parts)``).
    """
    import jax

    from repro.obs import trace as obs_trace

    if ready is None:
        ready = lambda res: jax.block_until_ready(res.state.x_parts)
    single = callable(runners)
    runs = [runners] if single else list(runners)
    with obs_trace.span(f"{label}-warmup", runners=len(runs)):
        results = [r() for r in runs]
    bests = [0.0] * len(runs)
    for rep in range(repeats):
        for i, r in enumerate(runs):
            with obs_trace.span(f"{label}-repeat", runner=i, rep=rep):
                t0 = time.perf_counter()
                res = r()
                ready(res)
                dt = time.perf_counter() - t0
            bests[i] = max(bests[i], rounds / dt)
            results[i] = res
    if single:
        return bests[0], results[0]
    return bests, results


def make_ridge(n_samples=2000, n_features=400, lam=1e-4, seed=0):
    """Fig. 1 stand-in: dense synthetic normal regression (paper: 10000x1000).

    Reduced by default so the CPU container sweeps in minutes; pass the
    paper's sizes for the full reproduction."""
    x, y, _ = synthetic.regression(n_samples, n_features, seed=seed)
    return problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), lam), (x, y)


def make_lasso(n_samples=2000, n_features=400, lam=1e-5, seed=1):
    """Webspam stand-in (paper: 350k x 16M sparse)."""
    x, y, _ = synthetic.regression(n_samples, n_features, seed=seed,
                                   sparsity_solution=0.1)
    return problems.lasso(jnp.asarray(x), jnp.asarray(y), lam), (x, y)


def csv_row(*cols):
    print(",".join(str(c) for c in cols), flush=True)
