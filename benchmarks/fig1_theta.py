"""Fig. 1 — effect of the local approximation quality Theta (via kappa).

Ridge regression on a dense synthetic dataset, ring of K=16 nodes.
Reports suboptimality after a fixed round budget AND the wall-clock
communication/computation trade-off (Fig. 1b)."""
from __future__ import annotations

import time

from repro.core import topology as topo
from repro.core.cola import ColaConfig, run_cola, solve_reference
from benchmarks.common import csv_row, make_ridge


def run(fast: bool = True):
    prob, _ = make_ridge(*(2000, 400) if fast else (10000, 1000))
    opt = solve_reference(prob, rounds=600 if fast else 2000, kappa=10)
    rounds = 40 if fast else 200
    csv_row("fig", "kappa", "rounds", "suboptimality", "time_s")
    for kappa in (0.25, 0.5, 1.0, 2.0, 4.0):
        t0 = time.time()
        res = run_cola(prob, topo.ring(16), ColaConfig(kappa=kappa),
                       rounds=rounds, record_every=rounds - 1)
        csv_row("fig1", kappa, rounds,
                f"{res.history['primal'][-1] - opt:.6f}",
                f"{time.time() - t0:.2f}")
    return {"optimum": opt}


if __name__ == "__main__":
    run()
