"""Fig. 2 — CoLA vs DIGing vs D-ADMM, ridge (strongly cvx) + lasso (general).

LIBSVM URL/webspam are not shippable offline; dense synthetic stand-ins with
the paper's regularization are used (DESIGN.md §8). DIGing's step is grid
searched (paper methodology); D-ADMM uses the Shi et al. rho with a CD budget
matched to CoLA's. Also logs the consensus-violation trajectory (Fig. 5)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import baselines as bl, problems, topology as topo
from repro.core.cola import ColaConfig, run_cola, solve_reference
from benchmarks.common import csv_row, make_lasso, make_ridge


def run(fast: bool = True):
    graph = topo.ring(16)
    rounds = 60 if fast else 400
    out = {}

    # --- Ridge (strongly convex): CoLA primal & dual mappings --------------
    prob, (x, y) = make_ridge(lam=1e-4)
    opt = solve_reference(prob, rounds=800, kappa=10)
    csv_row("fig", "method", "rounds", "final_suboptimality",
            "final_consensus_violation")
    for name, kwargs in [("cola_primal", {}),]:
        res = run_cola(prob, graph, ColaConfig(kappa=2.0), rounds=rounds,
                       record_every=max(rounds // 8, 1), **kwargs)
        csv_row("fig2_ridge", name, rounds,
                f"{res.history['primal'][-1] - opt:.6f}",
                f"{res.history['consensus_violation'][-1]:.3e}")
        out[name] = res.history
    dual = problems.ridge_dual(jnp.asarray(x), jnp.asarray(y), 1e-4)
    dopt = solve_reference(dual, rounds=800, kappa=10)
    res = run_cola(dual, graph, ColaConfig(kappa=2.0), rounds=rounds,
                   record_every=max(rounds // 8, 1))
    csv_row("fig2_ridge", "cola_dual", rounds,
            f"{res.history['primal'][-1] - dopt:.6f}",
            f"{res.history['consensus_violation'][-1]:.3e}")

    cons = bl.make_consensus_problem(x, y, 16, loss="square", reg="l2",
                                     lam=1e-4)
    w_opt = np.linalg.solve(x.T @ x + 1e-4 * np.eye(x.shape[1]), x.T @ y)
    f_opt = float(cons.objective(jnp.asarray(w_opt)))
    best, best_step = np.inf, None
    for step in (0.003, 0.01, 0.03, 0.1, 0.3):
        r = bl.run_diging(cons, graph, step=step, rounds=rounds // 2,
                          record_every=rounds // 2 - 1)
        v = r.history["objective"][-1] - f_opt
        if np.isfinite(v) and v < best:
            best, best_step = v, step
    csv_row("fig2_ridge", f"diging(step={best_step})", rounds // 2,
            f"{best:.6f}", "-")
    r = bl.run_dadmm(cons, graph, rho=1.0, rounds=rounds // 2,
                     inner_steps=10, record_every=rounds // 2 - 1)
    csv_row("fig2_ridge", "dadmm(rho=1)", rounds // 2,
            f"{r.history['objective'][-1] - f_opt:.6f}", "-")

    # --- Lasso (general convex) --------------------------------------------
    lprob, (lx, ly) = make_lasso(lam=1e-5)
    lopt = solve_reference(lprob, rounds=800, kappa=10)
    res = run_cola(lprob, graph, ColaConfig(kappa=2.0), rounds=rounds,
                   record_every=max(rounds // 8, 1))
    csv_row("fig2_lasso", "cola", rounds,
            f"{res.history['primal'][-1] - lopt:.6f}",
            f"{res.history['consensus_violation'][-1]:.3e}")
    lcons = bl.make_consensus_problem(lx, ly, 16, loss="square", reg="l1",
                                      lam=1e-5)
    # consensus-form lasso has the same optimal value as the CoLA mapping
    lbest = np.inf
    for step in (0.003, 0.01, 0.03, 0.1):
        r = bl.run_dgd(lcons, graph, step=step, rounds=rounds // 2,
                       record_every=rounds // 2 - 1, diminishing=True)
        v = r.history["objective"][-1] - lopt
        if np.isfinite(v):
            lbest = min(lbest, v)
    csv_row("fig2_lasso", "dgd(best)", rounds // 2, f"{lbest:.6f}", "-")

    # --- Fig. 5: consensus-violation trajectory -----------------------------
    traj = out["cola_primal"]["consensus_violation"]
    csv_row("fig5", "cola_primal_cv_trajectory",
            *[f"{v:.3e}" for v in traj])
    return out


if __name__ == "__main__":
    run()
