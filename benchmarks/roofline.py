"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun), computes
the three per-chip roofline terms on the TPU v5e target

    compute    = HLO_FLOPs_per_device / 197e12
    memory     = HLO_bytes_per_device / 819e9
    collective = collective_bytes_per_device / 50e9

identifies the dominant term, and reports MODEL_FLOPS / HLO_FLOPs (useful-
compute ratio; catches remat/redundancy waste). MODEL_FLOPS uses 6*N*D for
training (2*N*D forward-only for prefill/decode), with N the ACTIVE
parameter count for MoE.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import jax

from repro.configs.base import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def active_params(arch: str) -> tuple[int, int]:
    """(total, active) parameter counts; active discounts unrouted experts."""
    from repro.launch.specs import params_specs
    cfg = get_config(arch)
    shapes = params_specs(cfg)
    total = active = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    for path, leaf in flat:
        keys = [str(e.key) for e in path
                if isinstance(e, jax.tree_util.DictKey)]
        n = leaf.size
        total += n
        if cfg.num_experts and "moe" in keys and keys[-1] in (
                "w_gate", "w_up", "w_down") and "shared" not in keys:
            n = n * cfg.experts_per_token // cfg.num_experts
        active += n
    return total, active


def model_flops(arch: str, shape_name: str) -> float:
    """Global MODEL_FLOPS for one step of this (arch, shape)."""
    shape = SHAPES[shape_name]
    _, n_active = active_params(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per request
    return 2.0 * n_active * shape.global_batch


def analyze(report: dict) -> dict:
    arch, shape = report["arch"], report["shape"]
    chips = report["chips"]
    hlo = report.get("hlo")
    if hlo:  # trip-count-aware analyzer (repro.launch.hlo_analysis)
        flops_dev = hlo["flops"]
        bytes_dev = hlo["bytes"]
        coll_dev = hlo["collectives"]["total"]
    else:    # legacy: XLA cost_analysis (counts while bodies once)
        flops_dev = report["flops_per_device"]
        bytes_dev = report["bytes_per_device"]
        coll_dev = report["collectives"]["total"]
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape) / chips
    return {
        "arch": arch, "shape": shape, "mesh": report["mesh"],
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_ratio": mf / flops_dev if flops_dev else 0.0,
        "roofline_bound_s": max(terms.values()),
        "hbm_gb": report.get("memory", {}).get("temp_bytes", 0) / 1e9,
    }


def run(dir_: str = "experiments/dryrun", mesh: str = "single"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        rep = json.load(open(path))
        if rep.get("status") != "compiled":
            continue
        rows.append(analyze(rep))
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful FLOP ratio | temp GB/dev |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.3f} "
            f"| {r['hbm_gb']:.1f} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = run(args.dir, args.mesh)
    if args.markdown:
        print(markdown_table(rows))
        return
    print("fig,arch,shape,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,temp_gb_dev")
    for r in rows:
        print(f"roofline,{r['arch']},{r['shape']},{r['compute_s']:.4e},"
              f"{r['memory_s']:.4e},{r['collective_s']:.4e},{r['dominant']},"
              f"{r['useful_ratio']:.4f},{r['hbm_gb']:.2f}")


if __name__ == "__main__":
    main()
