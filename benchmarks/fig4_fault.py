"""Fig. 4 / Fig. 6 — fault tolerance: each node stays with probability p per
round; leavers freeze x_[k] (Fig. 4) or reset it (Fig. 6)."""
from __future__ import annotations

from repro.core import topology as topo
from repro.core.cola import ColaConfig, run_cola, solve_reference
from benchmarks.common import csv_row, make_ridge


def run(fast: bool = True):
    prob, _ = make_ridge(lam=1e-4, seed=3)
    opt = solve_reference(prob, rounds=800, kappa=10)
    rounds = 80 if fast else 400
    k = 16
    graph = topo.connected_cycle(k, 2)

    def schedule(p_stay):
        def s(t, rng):
            return rng.random(k) < p_stay
        return s

    csv_row("fig", "p_stay", "mode", "rounds", "suboptimality")
    results = {}
    for p in (0.5, 0.8, 0.9, 1.0):
        res = run_cola(prob, graph, ColaConfig(kappa=2.0), rounds=rounds,
                       record_every=rounds - 1,
                       active_schedule=None if p == 1.0 else schedule(p))
        sub = res.history["primal"][-1] - opt
        csv_row("fig4", p, "freeze", rounds, f"{sub:.6f}")
        results[("freeze", p)] = sub
    res = run_cola(prob, graph, ColaConfig(kappa=2.0), rounds=rounds,
                   record_every=rounds - 1, active_schedule=schedule(0.8),
                   leave_mode="reset")
    csv_row("fig6", 0.8, "reset", rounds,
            f"{res.history['primal'][-1] - opt:.6f}")

    # §2 / Definition 5: heterogeneous Theta_k — half the nodes straggle at
    # a quarter of the CD budget every round
    import numpy as np
    full = int(2.0 * (prob.n // k + 1))

    def budgets(t, rng):
        b = np.full(k, full)
        b[rng.random(k) < 0.5] = max(full // 4, 1)
        return b

    res = run_cola(prob, graph, ColaConfig(kappa=2.0), rounds=rounds,
                   record_every=rounds - 1, budget_schedule=budgets)
    csv_row("def5", "half-nodes-1/4-budget", "straggle", rounds,
            f"{res.history['primal'][-1] - opt:.6f}")
    return results


if __name__ == "__main__":
    run()
