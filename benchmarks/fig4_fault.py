"""Fig. 4 / Fig. 6 — fault tolerance: each node stays with probability p per
round; leavers freeze x_[k] (Fig. 4) or reset it (Fig. 6). Plus the attack
columns: Byzantine fraction x robust mixing mode -> suboptimality, the
fault model where participants LIE instead of leaving (repro.attack).

Schedules are pre-materialized host-side into (T, K) arrays and handed to
``run_cola`` directly (the same stacked-schedule path the attack transforms
ride), drawn from the same rng stream the old per-round closures consumed —
the fig4/fig6/def5 rows are bitwise what the closure path produced.
"""
from __future__ import annotations

import numpy as np

from repro import attack
from repro.core import topology as topo
from repro.core.cola import ColaConfig, run_cola, solve_reference
from benchmarks.common import csv_row, make_ridge


def _stay_masks(rounds: int, k: int, p_stay: float, seed: int = 0
                ) -> np.ndarray:
    """(T, K) bool: node k participates in round t with probability p_stay.
    One rng.random(k) draw per round — the exact stream the closure form
    ``lambda t, rng: rng.random(k) < p_stay`` consumed."""
    rng = np.random.default_rng(seed)
    return np.stack([rng.random(k) < p_stay for _ in range(rounds)])


def _straggler_budgets(rounds: int, k: int, full: int, seed: int = 0
                       ) -> np.ndarray:
    """(T, K) int32: each round, each node straggles (quarter CD budget)
    with probability 1/2 — same draw order as the old budgets closure."""
    rng = np.random.default_rng(seed)
    out = np.full((rounds, k), full, np.int32)
    for t in range(rounds):
        out[t, rng.random(k) < 0.5] = max(full // 4, 1)
    return out


def run(fast: bool = True):
    prob, _ = make_ridge(lam=1e-4, seed=3)
    opt = solve_reference(prob, rounds=800, kappa=10)
    rounds = 80 if fast else 400
    k = 16
    graph = topo.connected_cycle(k, 2)

    csv_row("fig", "p_stay", "mode", "rounds", "suboptimality")
    results = {}
    for p in (0.5, 0.8, 0.9, 1.0):
        res = run_cola(prob, graph, ColaConfig(kappa=2.0), rounds=rounds,
                       record_every=rounds - 1,
                       active_schedule=(None if p == 1.0
                                        else _stay_masks(rounds, k, p)))
        sub = res.history["primal"][-1] - opt
        csv_row("fig4", p, "freeze", rounds, f"{sub:.6f}")
        results[("freeze", p)] = sub
    res = run_cola(prob, graph, ColaConfig(kappa=2.0), rounds=rounds,
                   record_every=rounds - 1,
                   active_schedule=_stay_masks(rounds, k, 0.8),
                   leave_mode="reset")
    csv_row("fig6", 0.8, "reset", rounds,
            f"{res.history['primal'][-1] - opt:.6f}")

    # §2 / Definition 5: heterogeneous Theta_k — half the nodes straggle at
    # a quarter of the CD budget every round
    full = int(2.0 * (prob.n // k + 1))
    res = run_cola(prob, graph, ColaConfig(kappa=2.0), rounds=rounds,
                   record_every=rounds - 1,
                   budget_schedule=_straggler_budgets(rounds, k, full))
    csv_row("def5", "half-nodes-1/4-budget", "straggle", rounds,
            f"{res.history['primal'][-1] - opt:.6f}")

    # Byzantine columns: a fraction of nodes sign-flip their wire payloads
    # (x10, warm onset at round 5 — see the repro.attack threat model) and
    # the mixing layer either trusts them (robust None) or aggregates
    # robustly. Suboptimality is the attack analogue of the churn columns.
    csv_row("fig", "byz_frac", "robust", "rounds", "suboptimality")
    for frac in (1 / k, 2 / k):
        byz = attack.Byzantine(fraction=frac, mode="sign_flip", scale=10.0,
                               start=5, seed=1)
        for robust in (None, "trim", "median"):
            res = run_cola(prob, graph, ColaConfig(kappa=2.0, robust=robust),
                           rounds=rounds, record_every=rounds - 1,
                           attacks=[byz])
            sub = res.history["primal"][-1] - opt
            csv_row("fig4atk", f"{frac:.4f}", robust or "none", rounds,
                    f"{sub:.6f}")
            results[("attack", frac, robust)] = sub
    return results


if __name__ == "__main__":
    run()
