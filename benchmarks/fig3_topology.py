"""Fig. 3 — CoLA across 5 topologies (ring / 2-cycle / 3-cycle / grid /
complete), ridge on the epsilon stand-in; reports beta and suboptimality."""
from __future__ import annotations

from repro.core import topology as topo
from repro.core.cola import ColaConfig, run_cola, solve_reference
from benchmarks.common import csv_row, make_ridge


def run(fast: bool = True):
    prob, _ = make_ridge(lam=1e-5, seed=2)
    opt = solve_reference(prob, rounds=800, kappa=10)
    rounds = 50 if fast else 300
    k = 16
    graphs = {
        "ring": topo.ring(k),
        "2-connected-cycle": topo.connected_cycle(k, 2),
        "3-connected-cycle": topo.connected_cycle(k, 3),
        "2d-grid": topo.grid_2d(4, 4),
        "complete": topo.complete(k),
    }
    csv_row("fig", "topology", "beta", "rounds", "suboptimality")
    results = {}
    for name, g in graphs.items():
        beta = topo.beta(topo.metropolis_weights(g))
        res = run_cola(prob, g, ColaConfig(kappa=1.0), rounds=rounds,
                       record_every=rounds - 1)
        sub = res.history["primal"][-1] - opt
        csv_row("fig3", name, f"{beta:.4f}", rounds, f"{sub:.6f}")
        results[name] = (beta, sub)
    return results


if __name__ == "__main__":
    run()
