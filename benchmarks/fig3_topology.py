"""Fig. 3 — CoLA across topologies, executed through the topology-program
compiler.

The sweep now runs on the ``repro.topo`` registry (ring / cycles / grid /
torus / expander / complete), static AND under a churn schedule, and for
each graph reports:

* ``beta`` — the mixing contraction governing Theorems 1/2;
* the compiled comm plan's cost model: edge-color count (= ppermutes per
  gossip step) and per-device bytes/round vs the dense all-gather;
* the BLOCK-mode cost model for the same K=16 graph quotiented onto 4
  devices (the CI mesh run_dist_cola actually executes on): block-level
  color count and per-device block-payload bytes — the column showing how
  the quotient collapses dense node-level colorings (complete: 15 -> 3);
* suboptimality after the round budget (static and churn runs);
* a plan-vs-dense oracle check: one compiled-plan gossip step must equal
  ``dense_mix`` on the same W (the property the dist runtime's plan path
  relies on) and one block-plan step must equal it BITWISE, asserted here
  for both the static W and a churn-reweighted round;
* convergence-vs-bytes (``fig3_wire`` rows): for each wire codec (fp32 /
  fp8 / int8, error feedback on and off) the rounds to reach the fp32
  run's final suboptimality and the total wire bytes per device spent
  getting there — the trade the quantized wire buys: EF runs land near the
  fp32 round count at a quarter of the bytes, while the no-EF runs hit
  their quantization noise floor and may never certify ("-").
"""
from __future__ import annotations

import numpy as np

from repro import topo as topo_programs
from repro.core import mixing, topology as topo
from repro.core.cola import ColaConfig, run_cola, solve_reference
from benchmarks.common import csv_row, make_ridge

SWEEP = ("ring", "cycle2", "cycle3", "grid", "torus2d", "expander",
         "complete")

#: (wire, error_feedback) columns of the convergence-vs-bytes table;
#: fp32 has no codec so EF is moot there
WIRE_SWEEP = (("fp32", True), ("fp8", True), ("fp8", False),
              ("int8", True), ("int8", False))


def _check_plan_oracle(graph: topo.Topology, w: np.ndarray, seed: int = 0,
                       atol: float = 1e-5, devices: int = 4) -> None:
    """Compiled-plan mixing == dense_mix on this graph (static + churn);
    the block-quotiented plan must match BITWISE."""
    import jax.numpy as jnp

    plan = topo_programs.compile_plan(graph)
    bplan = topo_programs.compile_block_plan(graph, devices)
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((graph.num_nodes, 8)).astype(np.float32)
    for w_t in (w, topo.reweight_for_active(
            graph, rng.random(graph.num_nodes) < 0.75)):
        got = np.asarray(topo_programs.mix_with_plan(plan, w_t, v))
        want = np.asarray(mixing.dense_mix(jnp.asarray(w_t, jnp.float32),
                                           jnp.asarray(v)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=atol)
        got_b = np.asarray(topo_programs.mix_with_block_plan(bplan, w_t, v))
        np.testing.assert_array_equal(got_b, want)


def run(fast: bool = True):
    prob, _ = make_ridge(lam=1e-5, seed=2)
    opt = solve_reference(prob, rounds=800, kappa=10)
    rounds = 50 if fast else 300
    k, m_dev, d, itemsize = 16, 4, prob.d, 4

    def churn(t, rng):
        return rng.random(k) < 0.8

    csv_row("fig", "topology", "beta", "colors", "bytes_per_dev",
            "blk4_colors", "blk4_bytes_per_dev", "dense_bytes", "rounds",
            "subopt_static", "subopt_churn")
    results = {}
    for name in SWEEP:
        g = topo_programs.build(name, k)
        w = topo.metropolis_weights(g)
        beta = topo.beta(w)
        plan = topo_programs.compile_plan(g)
        bplan = topo_programs.compile_block_plan(g, m_dev)
        _check_plan_oracle(g, w, devices=m_dev)
        static = run_cola(prob, g, ColaConfig(kappa=1.0), rounds=rounds,
                          record_every=rounds - 1)
        churned = run_cola(prob, g, ColaConfig(kappa=1.0), rounds=rounds,
                           record_every=rounds - 1, active_schedule=churn,
                           seed=7)
        sub_s = static.history["primal"][-1] - opt
        sub_c = churned.history["primal"][-1] - opt
        bytes_dev = plan.bytes_per_device_per_step(d, itemsize)
        blk_bytes_dev = bplan.bytes_per_device_per_step(d, itemsize)
        dense_dev = k * d * itemsize
        csv_row("fig3", name, f"{beta:.4f}", plan.num_colors, bytes_dev,
                bplan.num_colors, blk_bytes_dev, dense_dev, rounds,
                f"{sub_s:.6f}", f"{sub_c:.6f}")
        results[name] = {"beta": beta, "colors": plan.num_colors,
                         "bytes_per_device": bytes_dev,
                         "block4_colors": bplan.num_colors,
                         "block4_bytes_per_device": blk_bytes_dev,
                         "subopt_static": sub_s, "subopt_churn": sub_c}

    # -- convergence vs bytes: what the quantized wire actually buys ------
    csv_row("fig", "topology", "wire", "eps", "rounds_to_eps",
            "wire_bytes_per_dev_per_round", "wire_bytes_to_eps")
    for name in SWEEP:
        g = topo_programs.build(name, k)
        plan = topo_programs.compile_plan(g)
        subs = {}
        for wire, ef in WIRE_SWEEP:
            res = run_cola(prob, g,
                           ColaConfig(kappa=1.0, wire=wire,
                                      error_feedback=ef),
                           rounds=rounds, record_every=1)
            subs[(wire, ef)] = np.asarray(res.history["primal"]) - opt
        # target: the fp32 run's final suboptimality (+5% slack) — the
        # quality bar every codec column is racing to at its own byte rate
        eps = 1.05 * max(float(subs[("fp32", True)][-1]), 1e-7)
        wires = results[name]["wire"] = {}
        for (wire, ef), sub in subs.items():
            hit = np.nonzero(sub <= eps)[0]
            r2e = int(hit[0]) + 1 if hit.size else None
            per_round = plan.bytes_per_device_per_step(d, itemsize,
                                                       wire=wire)
            label = wire + ("" if wire == "fp32" else
                            ("+ef" if ef else "-ef"))
            csv_row("fig3_wire", name, label, f"{eps:.2e}",
                    "-" if r2e is None else r2e, per_round,
                    "-" if r2e is None else r2e * per_round)
            wires[label] = {"rounds_to_eps": r2e,
                            "bytes_per_round": per_round,
                            "bytes_to_eps":
                                None if r2e is None else r2e * per_round}
    return results


if __name__ == "__main__":
    run()
