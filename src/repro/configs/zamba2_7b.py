"""Zamba2-7B [arXiv:2411.15242]: Mamba2 backbone with SHARED attention+MLP
blocks interleaved (81 blocks = 27 groups x [2 mamba + 1 shared attn]).
Shared attention runs sliding-window so long-context decode state is bounded."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, blocks_per_attn=2,
    attention="sliding", window=4096,
    source="arXiv:2411.15242 (Mamba2 + shared attn blocks)",
)
