"""InternVL2-26B [arXiv:2404.16821]: InternViT (STUB: patch embeddings in)
+ InternLM2-20B-style language decoder."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553,
    frontend_dim=3200, num_prefix_tokens=256,
    source="arXiv:2404.16821 (InternViT stubbed; InternLM2 backbone)",
)
