"""Qwen3-4B [hf:Qwen/Qwen3-8B family]: dense GQA decoder with qk-norm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936,
    qk_norm=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B (qk_norm, GQA kv=8)",
)
