"""Llama-4-Maverick-400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family]:
MoE 128 experts top-1 + shared expert, chunked local attention (iRoPE-style)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    num_experts=128, experts_per_token=1, moe_shared_expert=True,
    moe_every=2,  # alternating dense/MoE layers (~400B total, ~17B active)
    attention="chunked_local", window=8192,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (MoE 128e top-1, early fusion)",
)
