"""xLSTM-125M [arXiv:2405.04517]: alternating mLSTM + sLSTM blocks."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="xlstm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4, head_dim=192,
    d_ff=0, vocab_size=50304,
    slstm_ratio=2,  # one sLSTM per mLSTM (paired blocks)
    source="arXiv:2405.04517 (sLSTM + mLSTM blocks)",
)
