from repro.configs.base import (  # noqa: F401
    ARCHS,
    InputShape,
    ModelConfig,
    SHAPES,
    get_config,
    smoke_variant,
)
