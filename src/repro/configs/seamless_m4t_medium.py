"""SeamlessM4T-medium [arXiv:2308.11596]: encoder-decoder; the speech frontend
(mel + conv codec) is a STUB — input_specs feeds frame embeddings directly."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, encoder_layers=12,
    d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206,
    frontend_dim=1024,
    source="arXiv:2308.11596 (enc-dec, multimodal; conv frontend stubbed)",
)
