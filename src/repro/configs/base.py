"""Architecture & input-shape configuration system.

Every assigned architecture registers a ``ModelConfig`` via its module in
``repro.configs.<id>``; ``get_config(arch_id)`` resolves it, and
``smoke_variant`` produces the reduced same-family config used in CPU smoke
tests (<= 2 layers, d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | xlstm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # attention variants
    attention: str = "full"        # full | sliding | chunked_local
    window: int = 4096
    qk_norm: bool = False
    rope_theta: float = 1e4
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_shared_expert: bool = False
    moe_every: int = 1             # 2 = alternate dense/MoE layers (llama4)
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    blocks_per_attn: int = 0       # hybrid: mamba blocks per shared-attn block
    slstm_ratio: int = 0           # xlstm: 1 sLSTM per this many blocks (0=none)
    # encoder-decoder
    encoder_layers: int = 0
    # modality frontend stubs (audio frames / vision patches)
    frontend_dim: int = 0          # embedding dim produced by the stub frontend
    num_prefix_tokens: int = 0     # patches per image / frames per utterance
    # numerics
    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    remat_policy: str = "full"     # full | dots (checkpoint_dots) | none
    attn_compute_dtype: str = "float32"  # scores/PV einsum operand dtype
    attn_backend: str = "jnp"      # jnp (chunked scan) | pallas (VMEM tiles)
    scan_chunk: int = 256          # chunk for SSM scans / flash attention
    source: str = ""               # citation for the config

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def supports_decode(self) -> bool:
        return True  # no encoder-only archs in the assigned pool

    @property
    def sub_quadratic(self) -> bool:
        """Whether long_500k decode is feasible (bounded state)."""
        return (self.family in ("xlstm", "hybrid")
                or self.attention in ("sliding", "chunked_local"))


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ARCHS = [
    "qwen3_4b",
    "stablelm_12b",
    "xlstm_125m",
    "h2o_danube3_4b",
    "llama4_maverick_400b",
    "dbrx_132b",
    "mistral_large_123b",
    "seamless_m4t_medium",
    "internvl2_26b",
    "zamba2_7b",
]


# Assignment ids -> config module names (hyphens normalize to underscores).
ALIASES = {
    "llama4_maverick_400b_a17b": "llama4_maverick_400b",
    "h2o_danube_3_4b": "h2o_danube3_4b",
}


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_")
    arch = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: <=2 layers, d_model<=512, <=4 experts."""
    d_model = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4)
    kv = min(cfg.num_kv_heads, heads)
    updates = dict(
        num_layers=2,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        window=min(cfg.window, 16),
        dtype="float32",
        param_dtype="float32",
        remat=False,
        scan_chunk=16,
    )
    if cfg.num_experts:
        updates["num_experts"] = min(cfg.num_experts, 4)
        updates["experts_per_token"] = min(cfg.experts_per_token, 2)
    if cfg.encoder_layers:
        updates["encoder_layers"] = 2
    if cfg.blocks_per_attn:
        updates["blocks_per_attn"] = 2
        updates["num_layers"] = 3   # one hybrid group: 2 mamba + 1 shared attn
    if cfg.slstm_ratio:
        updates["num_layers"] = 2   # one mLSTM + one sLSTM
    if cfg.frontend_dim:
        updates["frontend_dim"] = min(cfg.frontend_dim, 128)
        updates["num_prefix_tokens"] = min(cfg.num_prefix_tokens, 8)
    if cfg.ssm_state:
        updates["ssm_state"] = min(cfg.ssm_state, 16)
        updates["ssm_head_dim"] = 32
    return dataclasses.replace(cfg, **updates)
