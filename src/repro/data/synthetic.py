"""Synthetic dataset generators.

The paper's Fig. 1 uses a dense synthetic regression set (10000 x 1000, normal
entries); Figs. 2-4 use LIBSVM datasets (URL, webspam, epsilon) that cannot be
shipped offline — benchmarks use these generators as documented stand-ins with
matched regularization (see DESIGN.md §8).
"""
from __future__ import annotations

import numpy as np


def regression(n_samples: int, n_features: int, *, noise: float = 0.1,
               density: float = 1.0, sparsity_solution: float = 0.1,
               seed: int = 0, dtype=np.float32):
    """Dense/sparse linear-regression data: X (n_samples, n_features), y.

    Ground-truth weights are `sparsity_solution`-sparse so lasso recovers
    structure; columns are roughly unit-norm (normal / sqrt(n_samples)).
    """
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n_samples, n_features)).astype(dtype)
    if density < 1.0:
        mask = rng.random((n_samples, n_features)) < density
        x = np.where(mask, x, 0.0).astype(dtype)
    x /= np.sqrt(n_samples)
    w = np.zeros(n_features, dtype=dtype)
    nnz = max(1, int(sparsity_solution * n_features))
    idx = rng.choice(n_features, size=nnz, replace=False)
    w[idx] = rng.normal(size=nnz).astype(dtype)
    y = x @ w + noise * rng.normal(size=n_samples).astype(dtype)
    return x.astype(dtype), y.astype(dtype), w


def classification(n_samples: int, n_features: int, *, seed: int = 0,
                   density: float = 1.0, dtype=np.float32):
    """Binary classification with labels in {-1, +1} from a logistic model."""
    x, _, w = regression(n_samples, n_features, noise=0.0, density=density,
                         seed=seed, dtype=dtype)
    rng = np.random.default_rng(seed + 1)
    logits = 5.0 * (x @ w)
    p = 1.0 / (1.0 + np.exp(-logits))
    y = np.where(rng.random(n_samples) < p, 1.0, -1.0).astype(dtype)
    return x, y, w


def token_stream(num_tokens: int, vocab_size: int, *, seed: int = 0):
    """Synthetic LM token stream with Zipfian unigram statistics plus a
    short-range bigram structure so models have something learnable."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    base = rng.choice(vocab_size, size=num_tokens, p=probs)
    # bigram: with prob 0.25 repeat previous token + 1 (mod V) -> learnable
    rep = rng.random(num_tokens) < 0.25
    shifted = np.roll(base, 1) + 1
    out = np.where(rep, shifted % vocab_size, base)
    return out.astype(np.int32)
