"""``run_dist_cola``: the multi-host shard_map CoLA runtime.

The single-host simulator (``repro.core.cola.run_cola``) keeps all K nodes
stacked in one device's arrays; this driver lays the node axis over a mesh
axis instead, so K paper-nodes execute as K/M node blocks on M devices with
no coordinator. Three design rules make it bit-compatible with the simulator
and as cheap to dispatch:

* **same round body** — the per-round function is ``cola._round_body`` with
  only the two mixing hooks swapped for collective implementations, so every
  node-local op (CD solve, local updates, churn masking) is literally the
  simulator's code;
* **same executor** — rounds run through the round-block scan engine
  (``repro.core.executor.run_round_blocks``): ``block_size`` rounds per
  dispatch, schedules pre-materialized by the simulator's own
  ``_materialize_schedule`` (identical rng consumption), metrics recorded on
  device, state donated across blocks;
* **neighbor exchange, not all-reduce** — ``comm="ring"`` mixes v via the
  banded ``lax.ppermute`` ring from ``repro.core.mixing`` (deg(k)·|v| bytes
  per link per gossip step, the paper's communication model); ``comm="dense"``
  is the arbitrary-graph fallback (all-gather + W matmul) and the mode that
  is bitwise identical to the simulator on a 1-device mesh.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import executor as exec_engine, mixing, topology as topo
from repro.core.cola import (ColaConfig, RunResult, _METRICS,
                             _materialize_schedule, _reset_leavers,
                             _round_body, build_env, init_state)
from repro.core.duality import gap_report
from repro.core.partition import make_partition
from repro.core.problems import Problem
from repro.dist.sharding import cola_env_pspecs, cola_state_pspecs


def _dist_mixers(axis: str, local_nodes: int, conn: int, comm: str,
                 gossip_steps: int) -> tuple[Callable, Callable]:
    """(mix_fn, grad_mix_fn) for the shard_map round body.

    ``dense``: all-gather the (K, d) stack, fold W^B once (redundantly per
    device, O(B K^3) — cheap next to the solve), mix, slice back this
    device's node block. On a 1-device mesh every collective degenerates to
    the identity, which is what makes the dense path bitwise equal to the
    simulator there.

    ``ring``: banded circulant mixing via ``ppermute`` neighbor pushes —
    requires one node per device and a circulant W (ring / c-connected
    cycle with Metropolis weights; churn reweighting breaks this).
    """
    if comm == "dense":
        def steps_mix(w, stack, steps):
            if steps <= 0:
                return stack
            full = lax.all_gather(stack, axis, tiled=True)      # (K, d)
            mixed = mixing.mix_power(w, full, steps)
            i = lax.axis_index(axis)
            return lax.dynamic_slice_in_dim(mixed, i * local_nodes,
                                            local_nodes)
    elif comm == "ring":
        if local_nodes != 1:
            raise ValueError(
                f"comm='ring' places one node per device; got {local_nodes} "
                "nodes per device — use comm='dense' or a bigger mesh axis")

        def steps_mix(w, stack, steps):
            band = mixing.banded_weights(w, conn)
            out = stack[0]
            for _ in range(steps):
                out = mixing.ring_mix_ppermute(out, axis, band, conn)
            return out[None]
    else:
        raise ValueError(f"unknown comm {comm!r} (want 'dense' or 'ring')")

    mix_fn = lambda w, v: steps_mix(w, v, gossip_steps)
    grad_mix_fn = lambda w, g: steps_mix(w, g, 1)
    return mix_fn, grad_mix_fn


def run_dist_cola(problem: Problem, graph: topo.Topology, cfg: ColaConfig,
                  mesh, rounds: int, *, comm: str = "ring",
                  axis: str | None = None, conn: int = 1,
                  record_every: int = 1,
                  active_schedule=None, budget_schedule=None,
                  leave_mode: str = "freeze", seed: int = 0,
                  w_override: np.ndarray | None = None,
                  block_size: int = 64) -> RunResult:
    """Run Algorithm 1 with the node axis sharded over ``mesh``.

    Args mirror ``run_cola`` (same schedules, same rng consumption, same
    history layout) plus:

      mesh: a jax Mesh; the node axis K shards over ``axis`` (default: the
        mesh's first axis), K % axis_size == 0, K/axis_size nodes per device.
      comm: "ring" (ppermute neighbor exchange; circulant W, one node per
        device) or "dense" (all-gather + W matmul; any W, any node count —
        and bitwise identical to ``run_cola`` on a 1-device mesh).
      conn: connectivity of the circulant band for ``comm="ring"``.

    Returns ``RunResult(state, history)`` with the fully-stacked (K, ...)
    state, like the simulator.
    """
    axis = axis or mesh.axis_names[0]
    m = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    k = graph.num_nodes
    if k % m != 0:
        raise ValueError(f"K={k} nodes must divide over {m} devices on "
                         f"mesh axis {axis!r}")
    local_nodes = k // m
    if comm == "ring" and active_schedule is not None:
        raise ValueError("comm='ring' needs a circulant W; churn reweighting "
                         "breaks that — use comm='dense' under churn")

    base_w = (w_override if w_override is not None
              else topo.metropolis_weights(graph))
    if comm == "ring":
        # W is round-constant on this path (no churn), so validate the
        # banded ppermute mixing loses no weight mass before tracing
        mixing.check_circulant_band(base_w, conn)

    part = make_partition(problem.n, k)
    env = build_env(problem, part,
                    with_gram=cfg.use_gram(problem.d, part.block,
                                           problem.a.dtype.itemsize))
    state = init_state(problem, part)
    dtype = problem.a.dtype
    sched = _materialize_schedule(graph, rounds, active_schedule,
                                  budget_schedule, leave_mode, seed, base_w,
                                  dtype)
    has_budget = "budgets" in sched
    has_reset = "leavers" in sched

    # lay the node axis of state + env over the mesh axis up front so the
    # donated buffers never migrate between blocks
    state_spec, env_spec = cola_state_pspecs(axis), cola_env_pspecs(axis)
    state = jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, state_spec)), state)
    env = jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, env_spec)), env)

    mix_fn, grad_mix_fn = _dist_mixers(axis, local_nodes, conn, comm,
                                       cfg.gossip_steps)
    body = _round_body(problem, part, cfg, mix_fn=mix_fn,
                       grad_mix_fn=grad_mix_fn)

    def shard_round(st, env_l, w_t, active_l, budgets_l, leavers_l,
                    reset_any):
        if has_reset:
            # the simulator's reset, with the node-sum completed across
            # devices — shares the Lemma-1 invariant implementation
            st = lax.cond(
                reset_any,
                lambda ss: _reset_leavers(
                    ss, env_l, part, leavers_l,
                    total_fn=lambda c: lax.psum(jnp.sum(c, axis=0), axis)),
                lambda ss: ss, st)
        return body(st, env_l, w_t, active_l,
                    budgets_l if has_budget else None)

    # node-axis operands shard over `axis`; W and the per-round scalars are
    # replicated. ColaEnv.gram_parts may be None — a P(axis) prefix covers
    # whichever leaves exist.
    node, repl = P(axis), P()
    shard_step = mixing.shard_map(
        shard_round, mesh,
        in_specs=(state_spec, env_spec, repl, node,
                  node if has_budget else repl,
                  node if has_reset else repl, repl),
        out_specs=state_spec)

    zeros_k = np.zeros((rounds,), dtype)

    def step_fn(st, env_ctx, s_t):
        st = shard_step(st, env_ctx, s_t["w"], s_t["active"],
                        s_t["budgets"] if has_budget else s_t["_pad"],
                        s_t["leavers"] if has_reset else s_t["_pad"],
                        s_t["reset_any"] if has_reset else s_t["_pad"])
        return st, None

    sched = dict(sched)
    sched["_pad"] = zeros_k  # scalar per-round filler for unused operands

    def record_fn(st):
        # the state arrays are ordinary (sharded) jit values here, outside
        # the shard_map — this is gap_report exactly as the simulator runs
        # it, GSPMD inserting the gathers
        rep = gap_report(problem, part, st.x_parts, st.v_stack)
        return jnp.stack([getattr(rep, name) for name in _METRICS])

    rec = exec_engine.record_flags(rounds, record_every)
    res = exec_engine.run_round_blocks(
        step_fn, state, sched, context=env, record_fn=record_fn,
        record_mask=rec, block_size=block_size,
        cache_key=("cola-dist", exec_engine.fingerprint(problem), part, cfg,
                   mesh, axis, comm, conn, has_budget, has_reset))

    history: dict = {"round": [int(t) for t in np.nonzero(rec)[0]]}
    for j, name in enumerate(_METRICS):
        history[name] = [float(v) for v in res.metrics[:, j]]
    return RunResult(state=res.state, history=history)
