"""``run_dist_cola``: the multi-host shard_map CoLA runtime.

The single-host simulator (``repro.core.cola.run_cola``) keeps all K nodes
stacked in one device's arrays; this driver lays the node axis over a mesh
axis instead, so K paper-nodes execute as K/M node blocks on M devices with
no coordinator. Three design rules make it bit-compatible with the simulator
and as cheap to dispatch:

* **same round body** — the per-round function is ``cola._round_body`` with
  only the two mixing hooks swapped for collective implementations, so every
  node-local op (CD solve, local updates, churn masking) is literally the
  simulator's code;
* **same executor** — rounds run through the round-block scan engine
  (``repro.core.executor.run_round_blocks``): ``block_size`` rounds per
  dispatch, schedules pre-materialized by the simulator's own
  ``_materialize_schedule`` (identical rng consumption), metrics recorded on
  device, state donated across blocks;
* **neighbor exchange, not all-reduce** — ``comm="ring"`` mixes v via the
  banded ``lax.ppermute`` ring from ``repro.core.mixing`` (deg(k)·|v| bytes
  per link per gossip step, the paper's communication model); ``comm="dense"``
  is the arbitrary-graph fallback (all-gather + W matmul) and the mode that
  is bitwise identical to the simulator on a 1-device mesh.

Metric recording follows the same split (``repro.core.metrics`` recorders):
the gap recorder evaluates ``gap_report`` on the globally-sharded state and
lets GSPMD insert the (K, d)/(K, n_k) stack gathers — fine at paper scale,
O(K) bytes per device per record round. The Prop.-1 certificate recorder
instead records UNDER shard_map from local quantities: gradients of the
local node block, the Eq.-10 neighborhood mean via ``lax.ppermute`` of the
(d,)-sized local gradient (ring) and scalar ``psum``/``pmax`` reductions
for the row — O(d) per device per record round, no stack gathers (asserted
against the lowered HLO in tests via ``launch.hlo_analysis``). Certificate
stop conditions short-circuit remaining rounds exactly as in the simulator.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import executor as exec_engine, metrics as metrics_lib, \
    mixing, topology as topo
from repro.core.cola import (ColaConfig, RunResult,
                             _materialize_schedule, _reset_leavers,
                             _round_body, build_env, init_state)
from repro.core.duality import neighborhood_mean
from repro.core.partition import make_partition
from repro.core.problems import Problem
from repro.dist.sharding import (cola_env_pspecs, cola_recorder_pspecs,
                                 cola_state_pspecs)


def _dist_mixers(axis: str, local_nodes: int, conn: int, comm: str,
                 gossip_steps: int) -> tuple[Callable, Callable]:
    """(mix_fn, grad_mix_fn) for the shard_map round body.

    ``dense``: all-gather the (K, d) stack, fold W^B once (redundantly per
    device, O(B K^3) — cheap next to the solve), mix, slice back this
    device's node block. On a 1-device mesh every collective degenerates to
    the identity, which is what makes the dense path bitwise equal to the
    simulator there.

    ``ring``: banded circulant mixing via ``ppermute`` neighbor pushes —
    requires one node per device and a circulant W (ring / c-connected
    cycle with Metropolis weights; churn reweighting breaks this).
    """
    if comm == "dense":
        def steps_mix(w, stack, steps):
            if steps <= 0:
                return stack
            full = lax.all_gather(stack, axis, tiled=True)      # (K, d)
            mixed = mixing.mix_power(w, full, steps)
            i = lax.axis_index(axis)
            return lax.dynamic_slice_in_dim(mixed, i * local_nodes,
                                            local_nodes)
    elif comm == "ring":
        if local_nodes != 1:
            raise ValueError(
                f"comm='ring' places one node per device; got {local_nodes} "
                "nodes per device — use comm='dense' or a bigger mesh axis")

        def steps_mix(w, stack, steps):
            band = mixing.banded_weights(w, conn)
            out = stack[0]
            for _ in range(steps):
                out = mixing.ring_mix_ppermute(out, axis, band, conn)
            return out[None]
    else:
        raise ValueError(f"unknown comm {comm!r} (want 'dense' or 'ring')")

    mix_fn = lambda w, v: steps_mix(w, v, gossip_steps)
    grad_mix_fn = lambda w, g: steps_mix(w, g, 1)
    return mix_fn, grad_mix_fn


# ---------------------------------------------------------------------------
# distributed recorders
# ---------------------------------------------------------------------------

def _place_recorder(recorder, mesh, axis):
    """Lay the recorder's per-run arrays (its ``init_spec`` state plus the
    per-node problem blocks it closes over) out over the node mesh axis, so
    the record program's captured constants start sharded like the state."""
    if isinstance(recorder, metrics_lib.ComposedRecorder):
        return dataclasses.replace(recorder, parts=tuple(
            _place_recorder(p, mesh, axis) for p in recorder.parts))
    if not isinstance(recorder, metrics_lib.CertificateRecorder):
        return recorder
    arrays = {"a_parts": recorder.a_parts, "gp_parts": recorder.gp_parts,
              "masks": recorder.masks, **recorder.init_spec()}
    specs = cola_recorder_pspecs(axis, arrays)
    placed = {name: jax.device_put(arr, NamedSharding(mesh, specs[name]))
              for name, arr in arrays.items()}
    return dataclasses.replace(recorder, **placed)


def _certificate_dist_record(rec, mesh, axis: str, local_nodes: int,
                             comm: str, conn: int) -> Callable:
    """Shard_map record_fn for ``CertificateRecorder``: O(d) collectives.

    Condition (9) is node-local. Condition (10)'s neighborhood mean comes
    from the gossip exchange pattern itself: on the ring, ``2*conn``
    ``ppermute`` pushes of this device's (d,) gradient (the certificate's
    only vector communication); on the dense fallback, the same all-gather
    the round body already performs. Row entries reduce with scalar
    ``psum``/``pmax`` — on a 1-device mesh every collective degenerates to
    the identity and the program is bitwise the simulator's record_fn.
    """
    k = rec.part.num_nodes
    if comm == "ring":
        # the ppermute neighborhood is the circulant band; the recorder's
        # mask must agree with it or the mean would silently differ from
        # the stacked oracle
        band = np.zeros((k, k))
        idx = np.arange(k)
        for off in range(-conn, conn + 1):
            band[idx, (idx + off) % k] = 1.0
        if not np.array_equal(np.asarray(rec.neigh_mask) != 0, band != 0):
            raise ValueError(
                "certificate recording with comm='ring' needs the graph's "
                f"neighborhoods to be the circulant band of conn={conn}")

    def body(x_l, v_l, a_l, gp_l, m_l, nm_l, thr):
        grads = jax.vmap(rec.problem.grad_f)(v_l)            # (ln, d)
        if comm == "ring":
            g = grads[0]
            nsum = g
            for off in range(1, conn + 1):
                fwd = lax.ppermute(g, axis,
                                   [(i, (i + off) % k) for i in range(k)])
                bwd = lax.ppermute(g, axis,
                                   [((i + off) % k, i) for i in range(k)])
                nsum = nsum + fwd + bwd
            neigh_mean = (nsum / (2 * conn + 1))[None]       # (1, d)
        else:
            full = lax.all_gather(grads, axis, tiled=True)   # (K, d)
            neigh_mean = neighborhood_mean(full, nm_l)       # (ln, d)
        # condition (9) uses only this device's blocks — swap the local
        # slices in so the vmapped node math runs on (ln, ...) operands
        local = dataclasses.replace(rec, a_parts=a_l, gp_parts=gp_l,
                                    masks=m_l)
        local_gap, disagree = local.local_row_inputs(x_l, v_l, grads,
                                                     neigh_mean)
        return rec.summarize(local_gap, disagree, grad_thresh=thr,
                             psum=lambda s: lax.psum(s, axis),
                             pmax=lambda s: lax.pmax(s, axis))

    node, repl = P(axis), P()
    shard = mixing.shard_map(
        body, mesh,
        in_specs=(node, node, node, node, node, node, repl), out_specs=P())

    def record(state, sched=None):
        if rec.dynamic:
            # churn: the reweighted round's neighbor mask + threshold come
            # in through the schedule (see metrics.certificate_schedule)
            nm, thr = sched["cert_mask"], sched["cert_grad_thresh"]
        else:
            nm, thr = rec.neigh_mask, jnp.asarray(rec.grad_thresh)
        return shard(state.x_parts, state.v_stack, rec.a_parts,
                     rec.gp_parts, rec.masks, nm, thr)

    return record


def _dist_record_fn(recorder, mesh, axis, local_nodes, comm, conn
                    ) -> Callable:
    """The distributed record program for any recorder: certificates record
    under shard_map (O(d) collectives), everything else records on the
    globally-sharded state as-is (GSPMD inserts the gathers)."""
    if isinstance(recorder, metrics_lib.ComposedRecorder):
        pairs = [(p, _dist_record_fn(p, mesh, axis, local_nodes, comm, conn))
                 for p in recorder.parts]
        return lambda st, sched=None: jnp.concatenate([
            f(st, sched) if getattr(p, "uses_schedule", False) else f(st)
            for p, f in pairs])
    if isinstance(recorder, metrics_lib.CertificateRecorder):
        return _certificate_dist_record(recorder, mesh, axis, local_nodes,
                                        comm, conn)
    return recorder.record_fn


class _DistRecorder:
    """Duck-typed Recorder view with the record program specialized for the
    mesh; labels / stop condition / cache identity delegate to the inner
    recorder (plus the comm layout, which changes the compiled program)."""

    def __init__(self, inner, record_fn, comm: str, conn: int):
        self._inner = inner
        self._record_fn = record_fn
        self._comm, self._conn = comm, conn

    @property
    def labels(self):
        return self._inner.labels

    @property
    def uses_schedule(self):
        return bool(getattr(self._inner, "uses_schedule", False))

    def record_fn(self, state, sched=None):
        if self.uses_schedule:
            return self._record_fn(state, sched)
        return self._record_fn(state)

    @property
    def stop_fn(self):
        return self._inner.stop_fn

    def init_spec(self):
        return self._inner.init_spec()

    def cache_token(self):
        return ("dist", self._comm, self._conn, self._inner.cache_token())


def run_dist_cola(problem: Problem, graph: topo.Topology, cfg: ColaConfig,
                  mesh, rounds: int, *, comm: str = "ring",
                  axis: str | None = None, conn: int = 1,
                  record_every: int = 1,
                  recorder="gap", eps: float | None = None,
                  active_schedule=None, budget_schedule=None,
                  leave_mode: str = "freeze", seed: int = 0,
                  w_override: np.ndarray | None = None,
                  block_size: int = 64) -> RunResult:
    """Run Algorithm 1 with the node axis sharded over ``mesh``.

    Args mirror ``run_cola`` (same schedules, same rng consumption, same
    history layout, same ``recorder``/``eps`` certificate-driven stopping)
    plus:

      mesh: a jax Mesh; the node axis K shards over ``axis`` (default: the
        mesh's first axis), K % axis_size == 0, K/axis_size nodes per device.
      comm: "ring" (ppermute neighbor exchange; circulant W, one node per
        device) or "dense" (all-gather + W matmul; any W, any node count —
        and bitwise identical to ``run_cola`` on a 1-device mesh).
      conn: connectivity of the circulant band for ``comm="ring"``.

    The certificate recorder records under shard_map from local gradients
    (``ppermute``/``psum``, O(d) per device per record round); the gap
    recorder keeps the gather-everything ``gap_report`` semantics.

    Returns ``RunResult(state, history)`` with the fully-stacked (K, ...)
    state, like the simulator.
    """
    axis = axis or mesh.axis_names[0]
    m = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    k = graph.num_nodes
    if k % m != 0:
        raise ValueError(f"K={k} nodes must divide over {m} devices on "
                         f"mesh axis {axis!r}")
    local_nodes = k // m
    if comm == "ring" and active_schedule is not None:
        raise ValueError("comm='ring' needs a circulant W; churn reweighting "
                         "breaks that — use comm='dense' under churn")

    base_w = (w_override if w_override is not None
              else topo.metropolis_weights(graph))
    if comm == "ring":
        # W is round-constant on this path (no churn), so validate the
        # banded ppermute mixing loses no weight mass before tracing
        mixing.check_circulant_band(base_w, conn)

    part = make_partition(problem.n, k)
    env = build_env(problem, part,
                    with_gram=cfg.use_gram(problem.d, part.block,
                                           problem.a.dtype.itemsize))
    state = init_state(problem, part)
    dtype = problem.a.dtype
    sched = _materialize_schedule(graph, rounds, active_schedule,
                                  budget_schedule, leave_mode, seed, base_w,
                                  dtype)
    has_budget = "budgets" in sched
    has_reset = "leavers" in sched

    rec = metrics_lib.make_recorder(recorder, problem, part, env, graph,
                                    base_w, eps)
    if active_schedule is not None:
        rec = metrics_lib.dynamize(rec)  # churn-aware certificate inputs

    # lay the node axis of state + env over the mesh axis up front so the
    # donated buffers never migrate between blocks
    state_spec, env_spec = cola_state_pspecs(axis), cola_env_pspecs(axis)
    state = jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, state_spec)), state)
    env = jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, env_spec)), env)
    rec = _place_recorder(rec, mesh, axis)
    dist_rec = _DistRecorder(
        rec, _dist_record_fn(rec, mesh, axis, local_nodes, comm, conn),
        comm, conn)

    mix_fn, grad_mix_fn = _dist_mixers(axis, local_nodes, conn, comm,
                                       cfg.gossip_steps)
    body = _round_body(problem, part, cfg, mix_fn=mix_fn,
                       grad_mix_fn=grad_mix_fn)

    def shard_round(st, env_l, w_t, active_l, budgets_l, leavers_l,
                    reset_any):
        if has_reset:
            # the simulator's reset, with the node-sum completed across
            # devices — shares the Lemma-1 invariant implementation
            st = lax.cond(
                reset_any,
                lambda ss: _reset_leavers(
                    ss, env_l, part, leavers_l,
                    total_fn=lambda c: lax.psum(jnp.sum(c, axis=0), axis)),
                lambda ss: ss, st)
        return body(st, env_l, w_t, active_l,
                    budgets_l if has_budget else None)

    # node-axis operands shard over `axis`; W and the per-round scalars are
    # replicated. ColaEnv.gram_parts may be None — a P(axis) prefix covers
    # whichever leaves exist.
    node, repl = P(axis), P()
    shard_step = mixing.shard_map(
        shard_round, mesh,
        in_specs=(state_spec, env_spec, repl, node,
                  node if has_budget else repl,
                  node if has_reset else repl, repl),
        out_specs=state_spec)

    zeros_k = np.zeros((rounds,), dtype)

    def step_fn(st, env_ctx, s_t):
        st = shard_step(st, env_ctx, s_t["w"], s_t["active"],
                        s_t["budgets"] if has_budget else s_t["_pad"],
                        s_t["leavers"] if has_reset else s_t["_pad"],
                        s_t["reset_any"] if has_reset else s_t["_pad"])
        return st, None

    sched = dict(sched)
    sched["_pad"] = zeros_k  # scalar per-round filler for unused operands

    rec_mask = exec_engine.record_flags(rounds, record_every)
    if dist_rec.uses_schedule:
        sched.update(metrics_lib.certificate_schedule(
            rec, sched["w"], sched["active"], rec_mask))
    res = exec_engine.run_round_blocks(
        step_fn, state, sched, context=env, recorder=dist_rec,
        record_mask=rec_mask, block_size=block_size,
        cache_key=("cola-dist", exec_engine.fingerprint(problem), part, cfg,
                   mesh, axis, comm, conn, has_budget, has_reset,
                   dist_rec.cache_token()))
    return RunResult(state=res.state,
                     history=metrics_lib.history_from(dist_rec, res))
