"""``run_dist_cola``: the multi-host shard_map CoLA runtime.

The single-host simulator (``repro.core.cola.run_cola``) keeps all K nodes
stacked in one device's arrays; this driver lays the node axis over a mesh
axis instead, so K paper-nodes execute as K/M node blocks on M devices with
no coordinator. Three design rules make it bit-compatible with the simulator
and as cheap to dispatch:

* **same round body** — the per-round function is ``cola._round_body`` with
  only the two mixing hooks swapped for collective implementations, so every
  node-local op (CD solve, local updates, churn masking) is literally the
  simulator's code;
* **same executor** — rounds run through the round-block scan engine
  (``repro.core.executor.run_round_blocks``): ``block_size`` rounds per
  dispatch, schedules pre-materialized by the simulator's own
  ``_materialize_schedule`` (identical rng consumption), metrics recorded on
  device, state donated across blocks;
* **neighbor exchange, not all-reduce** — ``comm="ring"`` mixes v via the
  banded ``lax.ppermute`` ring from ``repro.core.mixing`` (deg(k)·|v| bytes
  per link per gossip step, the paper's communication model);
  ``comm="plan"`` generalizes it to ARBITRARY sparse graphs AND to meshes
  smaller than the graph through the topology-program compiler
  (``repro.topo``): with one node per device the support is edge-colored
  into matchings, each color one ``lax.ppermute``, per-round weights —
  including churn-reweighted ones — riding the schedule as ``PlanSchedule``
  coefficient arrays; with K/M > 1 nodes per device the node graph
  quotients onto the mesh (``BlockPlan``): intra-block edges become local
  mixing terms (zero communication), inter-block edges collapse onto a
  device-level graph whose Delta+1 colors each move one (K/M, d) block
  payload per ppermute, and each device contracts its assembled
  neighborhood buffer against its (K/M, K) W rows in one dot — bitwise the
  simulator's dense mix, at O(colors·(K/M)·|v|) bytes per device. So one
  compiled program executes any paper topology (K=8/16/32) on any mesh
  whose size divides K; ``comm="dense"`` is the all-gather + W matmul
  oracle. A ``ring`` request whose W turns out non-circulant, that runs
  under churn, or that lands on a mesh smaller than K, dispatches to the
  plan path instead of failing (the historical "churn forces comm='dense'"
  and "plan places one node per device" restrictions are both retired).

Metric recording follows the same split (``repro.core.metrics`` recorders):
the gap recorder evaluates ``gap_report`` on the globally-sharded state and
lets GSPMD insert the (K, d)/(K, n_k) stack gathers — fine at paper scale,
O(K) bytes per device per record round. The Prop.-1 certificate recorder
instead records UNDER shard_map from local quantities: gradients of the
local node block, the Eq.-10 neighborhood mean via ``lax.ppermute`` of the
(d,)-sized local gradient (ring / per-node plan) or of the (K/M, d) local
gradient block over the block-level colors (block plan), plus scalar
``psum``/``pmax`` reductions for the row — O(colors·(K/M)·d) per device per
record round, no stack gathers (asserted against the lowered HLO in tests
via ``launch.hlo_analysis``). Certificate stop conditions short-circuit
remaining rounds exactly as in the simulator.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import executor as exec_engine, metrics as metrics_lib, \
    mixing, quant, topology as topo
from repro.topo import lowering as topo_lowering, plan as topo_plan
from repro.core.cola import (ColaConfig, RunResult,
                             _arm_wire_state, _as_schedule_fn,
                             _check_wire_config,
                             _materialize_schedule, _reset_leavers,
                             _round_body, build_env, init_state)
from repro.core.duality import consensus_residual, neighborhood_mean
from repro.core.partition import make_partition
from repro.core.problems import Problem
from repro.dist.sharding import (block_payload_pspec, cola_counters_pspecs,
                                 cola_env_pspecs, cola_recorder_pspecs,
                                 cola_state_pspecs, plan_payload_pspecs)


def _dist_mixers(axis: str, local_nodes: int, conn: int, comm: str,
                 gossip_steps: int,
                 plan: topo_plan.CommPlan | topo_plan.BlockPlan | None = None,
                 robust: str | None = None, robust_trim: int = 1,
                 robust_clip: float | None = None
                 ) -> tuple[Callable, Callable]:
    """(mix_fn, grad_mix_fn) for the shard_map round body.

    The first mixer argument is the round's *comm payload* — the schedule
    slice the driver routes in: the replicated (K, K) W for ``dense`` /
    ``ring``, or the node-sharded ``(plan_diag, plan_coefs)`` pair for
    ``plan``. ``mix_fn(payload, v_send, v_self)`` follows the simulator's
    wire-only attack contract: ``v_send`` is what goes over the wire,
    ``v_self`` the honest local stack (None on unattacked rounds — the fast
    path, bitwise identical to the pre-attack program).

    ``dense``: all-gather the (K, d) stack, fold W^B once (redundantly per
    device, O(B K^3) — cheap next to the solve), mix, slice back this
    device's node block. On a 1-device mesh every collective degenerates to
    the identity, which is what makes the dense path bitwise equal to the
    simulator there.

    ``ring``: banded circulant mixing via ``ppermute`` neighbor pushes —
    one node per device, round-constant circulant W (the historical
    TPU-native special case, kept for bitwise compatibility).

    ``plan``: the compiled topology program. One node per device
    (``CommPlan``): one ``ppermute`` per node-level edge color, per-node
    coefficients from the ``PlanSchedule`` slice. K/M nodes per device
    (``BlockPlan``): one ``ppermute`` of the (K/M, d) block payload per
    BLOCK-level color, this device's (K/M, K) W rows (the
    ``BlockPlanSchedule`` slice) contracted against the assembled
    neighborhood buffer in one dot — bitwise the simulator's dense mix.
    Either way any sparse graph (and any churn reweighting of it) runs at
    neighbor-only cost with a single compiled program.

    ``robust`` swaps the v-aggregation for the Byzantine-resilient
    neighborhood statistic (``mixing.robust_neighborhood_mix``): on
    ``dense`` every device robust-mixes the all-gathered full stack and
    slices its block back (bitwise the simulator's ``robust_mix_steps``);
    on ``plan`` the plan MUST be a BlockPlan — the assembled neighborhood
    buffer feeds ``block_robust_mix_steps`` (``run_dist_cola`` compiles a
    BlockPlan whenever robust is set, even at one node per device). The
    gradient mixer stays LINEAR regardless — the simulator's
    ``grad_mode='mixed'`` default is the plain ``dense_mix``, and robust
    statistics defend the consensus state, not the gradient average.
    """
    if comm == "dense":
        def steps_mix(w, stack, steps):
            if steps <= 0:
                return stack
            full = lax.all_gather(stack, axis, tiled=True)      # (K, d)
            mixed = mixing.mix_power(w, full, steps)
            i = lax.axis_index(axis)
            return lax.dynamic_slice_in_dim(mixed, i * local_nodes,
                                            local_nodes)
    elif comm == "ring":
        if local_nodes != 1:
            raise ValueError(
                f"comm='ring' places one node per device; got {local_nodes} "
                "nodes per device — use comm='dense' or a bigger mesh axis")

        def steps_mix(w, stack, steps):
            band = mixing.banded_weights(w, conn)
            out = stack[0]
            for _ in range(steps):
                out = mixing.ring_mix_ppermute(out, axis, band, conn)
            return out[None]
    elif comm == "plan":
        if isinstance(plan, topo_plan.BlockPlan):
            if local_nodes != plan.local_nodes:
                raise ValueError(
                    f"block plan carries {plan.local_nodes} nodes/device but "
                    f"the mesh layout implies {local_nodes}")

            def steps_mix(payload, stack, steps):
                # payload: this device's (K/M, K) rows of the round's W
                return topo_lowering.block_mix_steps(stack, axis, plan,
                                                     payload, steps)
        else:
            if local_nodes != 1:
                raise ValueError(
                    f"a per-node CommPlan places one node per device; got "
                    f"{local_nodes} nodes per device — compile a BlockPlan "
                    "(run_dist_cola does this automatically)")

            def steps_mix(payload, stack, steps):
                diag, coefs = payload  # node-sharded slices: (1,), (C, 1)
                out = topo_lowering.plan_mix_steps(
                    stack[0], axis, plan, diag[0], coefs[:, 0], steps)
                return out[None]
    else:
        raise ValueError(
            f"unknown comm {comm!r} (want 'dense', 'ring' or 'plan')")

    if robust is None:
        if comm == "dense":
            # bitwise the simulator's mix_power_wire: gather both the wire
            # payload and (when attacked) the honest stack, run the full-K
            # computation redundantly per device, slice this block back
            def mix_fn(w, v_send, v_self):
                if v_self is None:
                    return steps_mix(w, v_send, gossip_steps)
                full = lax.all_gather(v_send, axis, tiled=True)
                full_self = lax.all_gather(v_self, axis, tiled=True)
                mixed = mixing.mix_power_wire(w, full, full_self,
                                              gossip_steps)
                i = lax.axis_index(axis)
                return lax.dynamic_slice_in_dim(mixed, i * local_nodes,
                                                local_nodes)
        elif comm == "ring":
            def mix_fn(w, v_send, v_self):
                if v_self is None or gossip_steps <= 0:
                    return steps_mix(w, v_send, gossip_steps)
                band = mixing.banded_weights(w, conn)
                out = mixing.ring_mix_ppermute(v_send[0], axis, band, conn)
                out = out + band[conn] * (v_self[0] - v_send[0])
                for _ in range(gossip_steps - 1):
                    out = mixing.ring_mix_ppermute(out, axis, band, conn)
                return out[None]
        elif isinstance(plan, topo_plan.BlockPlan):
            def mix_fn(payload, v_send, v_self):
                return topo_lowering.block_mix_steps_wire(
                    v_send, v_self, axis, plan, payload, gossip_steps)
        else:
            def mix_fn(payload, v_send, v_self):
                diag, coefs = payload
                out = topo_lowering.plan_mix_steps_wire(
                    v_send[0], None if v_self is None else v_self[0],
                    axis, plan, diag[0], coefs[:, 0], gossip_steps)
                return out[None]
    elif comm == "dense":
        def mix_fn(w, v_send, v_self):
            if gossip_steps <= 0:
                return v_send
            full = lax.all_gather(v_send, axis, tiled=True)   # (K, d)
            full_self = (None if v_self is None
                         else lax.all_gather(v_self, axis, tiled=True))
            mixed = mixing.robust_mix_steps(w, full, robust,
                                            trim=robust_trim,
                                            clip=robust_clip,
                                            steps=gossip_steps,
                                            self_stack=full_self)
            i = lax.axis_index(axis)
            return lax.dynamic_slice_in_dim(mixed, i * local_nodes,
                                            local_nodes)
    elif comm == "plan" and isinstance(plan, topo_plan.BlockPlan):
        def mix_fn(payload, v_send, v_self):
            return topo_lowering.block_robust_mix_steps(
                v_send, axis, plan, payload, robust, trim=robust_trim,
                clip=robust_clip, steps=gossip_steps, v_self=v_self)
    else:
        raise ValueError(
            f"robust={robust!r} needs comm='dense' or a block-level plan; "
            f"got comm={comm!r} (run_dist_cola compiles the BlockPlan and "
            "re-dispatches 'ring' automatically)")
    # one LINEAR step for grad_mode='mixed', matching the simulator's
    # dense_mix default even when the v aggregation is robust
    grad_mix_fn = lambda w, g: steps_mix(w, g, 1)
    return mix_fn, grad_mix_fn


def _dist_qmixers(axis: str, local_nodes: int, comm: str, cfg: ColaConfig,
                  plan) -> tuple[Callable, Callable]:
    """(qmix_fn, qencode_fn) — the quantized-wire counterparts of
    ``_dist_mixers`` for the shard_map round body.

    ``qmix_fn(payload, v, ef, qkey, buf)`` runs the B EF-compensated gossip
    steps on the codec wire view; ``buf`` is the pre-encoded (payload,
    scale) double buffer when ``cfg.pipeline`` (consumed by step 0's
    ppermutes at the TOP of the round body). ``qencode_fn(v, ef, nkey)``
    encodes the NEXT round's step-0 payload at the end of the body.
    Stochastic-rounding keys always derive from GLOBAL node ids
    (``axis_index * K/M + row``), so the draws — and hence the wire bits —
    are bitwise the simulator's regardless of the mesh layout.

    ``plan`` (CommPlan): per-node lowering — the int8/fp8 payload AND its
    fp32 scale sidecar each ppermute per edge color, receivers dequantize
    before the coefficient contraction. ``plan`` (BlockPlan): the (K/M, d)
    quantized block + (K/M, 1) scales ppermute per block color into the
    dequantized neighborhood buffer, one dot against the W rows. ``dense``:
    quantize locally, all-gather the NARROW payload + scales (the oracle
    keeps the byte reduction), dequantize, dense mix, slice back.

    ``cfg.robust`` composes on both paths: the outlier gate judges the
    DEQUANTIZED neighborhood rows — the same values an honest receiver
    would consume — via ``lowering.block_robust_qmix_step`` (block plan;
    ``run_dist_cola`` always compiles a BlockPlan when robust is set) or
    ``mixing.robust_mix_steps`` on the gathered dequantized stack
    (``dense``), bitwise the simulator's composed branch for trim/median
    (clip: allclose, see ``lowering.block_robust_mix_step``).
    """
    wire, steps = cfg.wire, cfg.gossip_steps

    def _row_ids():
        return lax.axis_index(axis) * local_nodes + jnp.arange(local_nodes)

    if comm == "plan" and not isinstance(plan, topo_plan.BlockPlan):
        def qmix_fn(payload, v, ef, qkey, buf):
            diag, coefs = payload
            pb = None if buf is None else (buf[0][0], buf[1][0])
            out, ef_new = topo_lowering.plan_qmix_steps(
                v[0], None if ef is None else ef[0], axis, plan,
                diag[0], coefs[:, 0], steps, wire, qkey, payload=pb)
            return out[None], (None if ef_new is None else ef_new[None])

        def qencode_fn(v, ef, nkey):
            key = jax.random.fold_in(quant.step_key(nkey, 0),
                                     lax.axis_index(axis))
            p = v[0] if ef is None else v[0] + ef[0]
            q, s = quant.quantize(p, wire, key)
            deq = quant.dequantize(q, s)
            ef_new = None if ef is None else (p - deq)[None]
            return q[None], s[None], deq[None], ef_new
    elif comm == "plan":
        if cfg.robust is not None:
            # composed robust x quantized wire: single-step by the
            # _check_wire_config scoping (and buf is always None — pipeline
            # is rejected when composed)
            def qmix_fn(payload, v, ef, qkey, buf):
                return topo_lowering.block_robust_qmix_step(
                    v, ef, axis, plan, payload, wire, qkey, cfg.robust,
                    trim=cfg.robust_trim, clip=cfg.robust_clip)
        else:
            def qmix_fn(payload, v, ef, qkey, buf):
                return topo_lowering.block_qmix_steps(
                    v, ef, axis, plan, payload, steps, wire, qkey,
                    payload=buf)

        def qencode_fn(v, ef, nkey):
            p = v if ef is None else v + ef
            q, s = quant.quantize_rows(p.reshape(local_nodes, -1), wire,
                                       quant.step_key(nkey, 0),
                                       node_ids=_row_ids())
            deq = quant.dequantize(q, s)
            ef_new = (None if ef is None
                      else (p.reshape(local_nodes, -1) - deq).reshape(p.shape))
            return q, s, deq.reshape(v.shape), ef_new
    elif comm == "dense":
        def qmix_fn(w, v, ef, qkey, buf):
            out, ef_l = v.reshape(local_nodes, -1), ef
            for s in range(steps):
                if s == 0 and buf is not None:
                    q, sc = buf
                else:
                    k = None if qkey is None else quant.step_key(qkey, s)
                    p = out if ef_l is None else out + ef_l
                    q, sc = quant.quantize_rows(p, wire, k,
                                                node_ids=_row_ids())
                    if ef_l is not None:
                        ef_l = p - quant.dequantize(q, sc)
                # the oracle's all-gather moves the NARROW payload + the
                # fp32 sidecar — quantize-then-gather, never the reverse
                # (gathered as raw bytes so no backend upcasts float8,
                # see topo_lowering.ppermute_wire)
                if q.dtype.itemsize == 1 and \
                        jnp.issubdtype(q.dtype, jnp.floating):
                    qf = lax.bitcast_convert_type(
                        lax.all_gather(
                            lax.bitcast_convert_type(q, jnp.uint8),
                            axis, tiled=True), q.dtype)
                else:
                    qf = lax.all_gather(q, axis, tiled=True)
                sf = lax.all_gather(sc, axis, tiled=True)
                deq_full = quant.dequantize(qf, sf)
                if cfg.robust is not None:
                    # composed oracle: the gate judges the dequantized
                    # stack, exactly the simulator's composed branch
                    mixed = mixing.robust_mix_steps(
                        w, deq_full, cfg.robust, trim=cfg.robust_trim,
                        clip=cfg.robust_clip, steps=1)
                else:
                    mixed = mixing.dense_mix(w, deq_full)
                out = lax.dynamic_slice_in_dim(
                    mixed, lax.axis_index(axis) * local_nodes, local_nodes)
            return out.reshape(v.shape), ef_l

        def qencode_fn(v, ef, nkey):
            p = (v if ef is None else v + ef).reshape(local_nodes, -1)
            q, s = quant.quantize_rows(p, wire, quant.step_key(nkey, 0),
                                       node_ids=_row_ids())
            deq = quant.dequantize(q, s)
            ef_new = None if ef is None else (p - deq).reshape(v.shape)
            return q, s, deq.reshape(v.shape), ef_new
    else:
        raise ValueError(
            f"quantized wire has no comm={comm!r} lowering (a 'ring' "
            "request re-dispatches to 'plan' in run_dist_cola)")
    return qmix_fn, qencode_fn


# ---------------------------------------------------------------------------
# distributed recorders
# ---------------------------------------------------------------------------

def _place_recorder(recorder, mesh, axis):
    """Lay the recorder's per-run arrays (its ``init_spec`` state plus the
    per-node problem blocks it closes over) out over the node mesh axis, so
    the record program's captured constants start sharded like the state."""
    if isinstance(recorder, metrics_lib.ComposedRecorder):
        return dataclasses.replace(recorder, parts=tuple(
            _place_recorder(p, mesh, axis) for p in recorder.parts))
    if not isinstance(recorder, metrics_lib.CertificateRecorder):
        return recorder
    arrays = {"a_parts": recorder.a_parts, "gp_parts": recorder.gp_parts,
              "masks": recorder.masks, **recorder.init_spec()}
    specs = cola_recorder_pspecs(axis, arrays)
    placed = {name: jax.device_put(arr, NamedSharding(mesh, specs[name]))
              for name, arr in arrays.items()}
    return dataclasses.replace(recorder, **placed)


def _certificate_dist_record(rec, mesh, axis: str, local_nodes: int,
                             comm: str, conn: int,
                             plan=None) -> Callable:
    """Shard_map record_fn for ``CertificateRecorder``: O(d) collectives.

    Condition (9) is node-local. Condition (10)'s neighborhood mean comes
    from the gossip exchange pattern itself: on the ring, ``2*conn``
    ``ppermute`` pushes of this device's (d,) gradient (the certificate's
    only vector communication); on the per-node plan path, one ``ppermute``
    per edge color with the round's neighbor-mask row selecting what
    arrives (so the neighborhood follows the ACTIVE plan — under churn, the
    reweighted support from the certificate schedule — instead of a static
    band); on the block plan path, one ``ppermute`` of the (K/M, d) local
    gradient block per BLOCK-level color, mask-rows selecting per node; on
    the dense fallback, the same all-gather the round body already
    performs. Row entries reduce with scalar ``psum``/``pmax`` — on a
    1-device mesh every collective degenerates to the identity and the
    program is bitwise the simulator's record_fn.
    """
    k = rec.part.num_nodes

    def compile_support(support):
        return (topo_plan.compile_plan(support) if local_nodes == 1
                else topo_plan.compile_block_plan(support,
                                                  k // local_nodes))

    if comm == "ring":
        # the ppermute neighborhood must match the recorder's mask; a mask
        # that is NOT the circulant band (historically a ValueError)
        # dispatches into the plan path — compile the mask's own support.
        # Attack-aware mode also needs per-round mask rows (dishonest
        # columns drop out of the Eq.-10 mean), which the band path has no
        # slot for.
        band = np.zeros((k, k))
        idx = np.arange(k)
        for off in range(-conn, conn + 1):
            band[idx, (idx + off) % k] = 1.0
        if (rec.attack_aware or not np.array_equal(
                np.asarray(rec.neigh_mask) != 0, band != 0)):
            comm, plan = "plan", compile_support(np.asarray(rec.neigh_mask))
    if comm == "plan" and plan is None:
        plan = compile_support(np.asarray(rec.neigh_mask))

    def body(x_l, v_l, a_l, gp_l, m_l, nm_l, thr, hon):
        hon_l = None
        if rec.attack_aware:
            # hon is the replicated (K,) honesty mask from the attack
            # schedule: columns mask the neighborhood mean (a liar's
            # gradient never enters it), the own-node slice masks the
            # cohort sums and conditions
            nm_l = nm_l * hon[None, :].astype(nm_l.dtype)
            hon_l = lax.dynamic_slice_in_dim(
                hon, lax.axis_index(axis) * local_nodes, local_nodes)
        grads = jax.vmap(rec.problem.grad_f)(v_l)            # (ln, d)
        if comm == "plan" and isinstance(plan, topo_plan.BlockPlan):
            # block exchange of the whole (ln, d) gradient block; the
            # mask rows zero exactly what the stacked oracle excludes, so
            # the mean matches duality.neighborhood_mean bitwise
            nsum, count = topo_lowering.block_neighborhood_stats(
                grads, axis, plan, nm_l)
            neigh_mean = nsum / count[:, None]               # (ln, d)
        elif comm == "plan":
            # mask-selected plan exchange: nm_l is this node's row of the
            # self-inclusive neighborhood mask (static graph or the churn
            # round's reweighted support via the certificate schedule)
            nsum, count = topo_lowering.plan_neighborhood_stats(
                grads[0], axis, plan, nm_l[0])
            neigh_mean = (nsum / count)[None]                # (1, d)
        elif comm == "ring":
            g = grads[0]
            nsum = g
            for off in range(1, conn + 1):
                fwd = lax.ppermute(g, axis,
                                   [(i, (i + off) % k) for i in range(k)])
                bwd = lax.ppermute(g, axis,
                                   [((i + off) % k, i) for i in range(k)])
                nsum = nsum + fwd + bwd
            neigh_mean = (nsum / (2 * conn + 1))[None]       # (1, d)
        else:
            full = lax.all_gather(grads, axis, tiled=True)   # (K, d)
            neigh_mean = neighborhood_mean(full, nm_l)       # (ln, d)
        # condition (9) uses only this device's blocks — swap the local
        # slices in so the vmapped node math runs on (ln, ...) operands
        local = dataclasses.replace(rec, a_parts=a_l, gp_parts=gp_l,
                                    masks=m_l)
        local_gap, disagree = local.local_row_inputs(x_l, v_l, grads,
                                                     neigh_mean)
        # Lemma-1 tamper detection: local [sum_l v_l, sum_l A_l x_l]
        # partials completed with ONE stacked (2, d) psum — O(d), no stack
        # gathers; identity on a 1-device mesh (bitwise the simulator)
        sums = lax.psum(rec.invariant_sums(x_l, v_l, a_l, honest=hon_l),
                        axis)
        resid = consensus_residual(sums[0], sums[1], k)
        return rec.summarize(local_gap, disagree, resid=resid,
                             grad_thresh=thr, honest=hon_l,
                             psum=lambda s: lax.psum(s, axis),
                             pmax=lambda s: lax.pmax(s, axis))

    node, repl = P(axis), P()
    shard = mixing.shard_map(
        body, mesh,
        in_specs=(node, node, node, node, node, node, repl, repl),
        out_specs=P())

    def record(state, sched=None):
        if rec.dynamic:
            # churn: the reweighted round's neighbor mask + threshold come
            # in through the schedule (see metrics.certificate_schedule)
            nm, thr = sched["cert_mask"], sched["cert_grad_thresh"]
        else:
            nm, thr = rec.neigh_mask, jnp.asarray(rec.grad_thresh)
        if rec.attack_aware:
            hon = (jnp.asarray(sched["atk_dishonest"])
                   <= 0).astype(state.v_stack.dtype)
        else:
            hon = jnp.ones((k,), state.v_stack.dtype)  # unused, DCE'd
        return shard(state.x_parts, state.v_stack, rec.a_parts,
                     rec.gp_parts, rec.masks, nm, thr, hon)

    return record


def _dist_record_fn(recorder, mesh, axis, local_nodes, comm, conn,
                    plan=None) -> Callable:
    """The distributed record program for any recorder: certificates record
    under shard_map (O(d) collectives), everything else records on the
    globally-sharded state as-is (GSPMD inserts the gathers)."""
    if isinstance(recorder, metrics_lib.ComposedRecorder):
        pairs = [(p, _dist_record_fn(p, mesh, axis, local_nodes, comm, conn,
                                     plan))
                 for p in recorder.parts]
        return lambda st, sched=None: jnp.concatenate([
            f(st, sched) if getattr(p, "uses_schedule", False) else f(st)
            for p, f in pairs])
    if isinstance(recorder, metrics_lib.CertificateRecorder):
        return _certificate_dist_record(recorder, mesh, axis, local_nodes,
                                        comm, conn, plan)
    return recorder.record_fn


class _DistRecorder:
    """Duck-typed Recorder view with the record program specialized for the
    mesh; labels / stop condition / cache identity delegate to the inner
    recorder (plus the comm layout, which changes the compiled program)."""

    def __init__(self, inner, record_fn, comm: str, conn: int, plan=None):
        self._inner = inner
        self._record_fn = record_fn
        self._comm, self._conn = comm, conn
        self._plan = plan

    @property
    def labels(self):
        return self._inner.labels

    @property
    def uses_schedule(self):
        return bool(getattr(self._inner, "uses_schedule", False))

    def record_fn(self, state, sched=None):
        if self.uses_schedule:
            return self._record_fn(state, sched)
        return self._record_fn(state)

    @property
    def stop_fn(self):
        return self._inner.stop_fn

    def init_spec(self):
        return self._inner.init_spec()

    def cadence_ratio(self, row):
        return self._inner.cadence_ratio(row)

    def cache_token(self):
        plan_tok = self._plan.cache_token() if self._plan else None
        return ("dist", self._comm, self._conn, plan_tok,
                self._inner.cache_token())


def run_dist_cola(problem: Problem, graph: topo.Topology, cfg: ColaConfig,
                  mesh, rounds: int, *, comm: str = "ring",
                  axis: str | None = None, conn: int = 1,
                  record_every: int = 1,
                  recorder="gap", eps: float | None = None,
                  active_schedule=None, budget_schedule=None,
                  leave_mode: str = "freeze", seed: int = 0,
                  w_override: np.ndarray | None = None,
                  attacks=None, wire: str | None = None,
                  block_size: int = 64) -> RunResult:
    """Run Algorithm 1 with the node axis sharded over ``mesh``.

    Args mirror ``run_cola`` (same schedules, same rng consumption, same
    history layout, same ``recorder``/``eps`` certificate-driven stopping)
    plus:

      mesh: a jax Mesh; the node axis K shards over ``axis`` (default: the
        mesh's first axis), K % axis_size == 0, K/axis_size nodes per device.
      comm: "ring" (banded ppermute; round-constant circulant W, one node
        per device), "plan" (compiled topology program from ``repro.topo``:
        ANY sparse graph, including time-varying churn-reweighted ones; one
        ``ppermute`` per edge color with per-round schedule coefficients
        when K equals the mesh axis, or — on a smaller mesh — one
        ``ppermute`` of the (K/M, d) node-block payload per BLOCK-level
        color, bitwise-equal to the simulator), or "dense" (all-gather + W
        matmul; any W, any node count — and bitwise identical to
        ``run_cola`` on a 1-device mesh). A "ring" request dispatches to
        "plan" automatically when churn is scheduled, W is not
        circulant-banded, or the mesh is smaller than K.
      conn: connectivity of the circulant band for ``comm="ring"``.
      attacks: the same ``repro.attack`` scenarios ``run_cola`` accepts —
        they transform the identical pre-materialized schedule, so a seeded
        attack corrupts the distributed run bitwise like the simulator.
        ``Eavesdropper`` taps are simulator-only (rejected here).
      wire: shorthand overriding ``cfg.wire`` — the gossip payload codec
        ("fp32" | "int8" | "fp8" | "fp8_e5m2", see ``repro.core.quant``).
        On a quantized wire every gossip collective moves the 1-byte
        payload plus the fp32 scale sidecar instead of the fp32 stack; a
        "ring" request re-dispatches to "plan" (the band path has no codec
        lowering), and the "dense" oracle quantizes BEFORE its all-gather
        so even the oracle honors the byte budget.

    ``cfg.robust`` swaps the v aggregation for the Byzantine-resilient
    neighborhood statistic on every comm path: ``dense`` robust-mixes the
    all-gathered stack; ``ring``/``plan`` compile a block-level plan (even
    at one node per device — the robust statistic needs the assembled
    neighborhood buffer) and run ``block_robust_mix_steps``, bitwise the
    simulator's ``robust_mix_steps``.

    The certificate recorder records under shard_map from local gradients
    (``ppermute``/``psum``, O(colors·(K/M)·d) per device per record round)
    — its neighborhood exchange follows the active comm plan (the churn
    round's reweighted support) rather than a static band; the gap recorder
    keeps the gather-everything ``gap_report`` semantics. ``record_every``
    accepts the same ``"adaptive"`` / ``AdaptiveCadence`` controller as
    ``run_cola``.

    Returns ``RunResult(state, history)`` with the fully-stacked (K, ...)
    state, like the simulator.
    """
    if wire is not None:
        cfg = dataclasses.replace(cfg, wire=wire)
    _check_wire_config(cfg, attacks=attacks, leave_mode=leave_mode,
                       dist=True)
    quantized = quant.is_quantized(cfg.wire)
    axis = axis or mesh.axis_names[0]
    m = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    k = graph.num_nodes
    if k % m != 0:
        raise ValueError(f"K={k} nodes must divide over {m} devices on "
                         f"mesh axis {axis!r}")
    local_nodes = k // m

    active_schedule = _as_schedule_fn(active_schedule, rounds, k,
                                      "active_schedule")
    budget_schedule = _as_schedule_fn(budget_schedule, rounds, k,
                                      "budget_schedule")
    base_w = (w_override if w_override is not None
              else topo.metropolis_weights(graph))
    plan = None
    if comm == "ring":
        # the circulant ppermute band only executes a round-constant
        # circulant W with one node per device; churn reweighting, a
        # non-circulant graph, or a mesh smaller than K now dispatches into
        # the compiled topology-program path instead of the historical
        # ValueErrors ("churn forces comm='dense'" / "one node per device");
        # robust aggregation is nonlinear — it also needs the plan path's
        # assembled neighborhood buffer
        if (active_schedule is not None or local_nodes != 1
                or cfg.robust is not None or quantized):
            comm = "plan"
        else:
            try:
                mixing.check_circulant_band(base_w, conn)
            except ValueError:
                comm = "plan"
    if comm == "plan":
        # under churn the per-round W is a reweighting of the graph (its
        # support only shrinks), so the graph's adjacency is the complete
        # compile-time support. A static w_override contributes its own
        # support too; the union also covers the certificate recorder's
        # adjacency-derived neighborhoods when they are denser than W's.
        support = graph.adjacency.copy()
        if active_schedule is None:
            off = np.asarray(base_w) != 0
            np.fill_diagonal(off, False)
            support = support | off
        # one node per device lowers per-node colors; K/M > 1 nodes per
        # device quotients the graph onto the mesh (block-level colors).
        # Robust aggregation always takes the block form — the trimmed-mean
        # / median / clip statistic runs over the ppermute-assembled
        # neighborhood buffer, which only the BlockPlan materializes (a
        # 1-node block is a valid BlockPlan). Quantized wires take it too:
        # the block contraction (W rows against the dequantized buffer) is
        # bitwise the simulator's dense mix, and bitwise matters here — a
        # 1-ulp reassociation difference in v would flip stochastic-
        # rounding draws next round and snowball through the codec, so the
        # per-node coefficient-sum form cannot hold multi-round parity
        plan = (topo_plan.compile_plan(support)
                if local_nodes == 1 and cfg.robust is None and not quantized
                else topo_plan.compile_block_plan(support, m))

    part = make_partition(problem.n, k)
    env = build_env(problem, part,
                    with_gram=cfg.use_gram(problem.d, part.block,
                                           problem.a.dtype.itemsize))
    state = init_state(problem, part)
    dtype = problem.a.dtype
    sched = _materialize_schedule(graph, rounds, active_schedule,
                                  budget_schedule, leave_mode, seed, base_w,
                                  dtype)
    if quantized:
        # the SAME per-round codec key stack both simulator drivers slice —
        # the stochastic-rounding draws are a function of (seed, round,
        # step, color, node), never of the mesh layout
        qkeys = np.asarray(quant.round_keys(seed, rounds + 1))
        sched["qkey"] = qkeys[:rounds]
        if cfg.pipeline:
            sched["qkey_next"] = qkeys[1:]
        state = _arm_wire_state(state, cfg, qkeys[0])
    atk_info = None
    if attacks is not None:
        from repro import attack as attack_lib
        # same transform order as the simulator: churn/budgets materialize,
        # attacks corrupt, then the certificate/plan schedules derive from
        # the corrupted exchange
        sched, atk_info = attack_lib.apply_attacks(
            sched, attacks,
            attack_lib.AttackContext(graph=graph, rounds=rounds, k=k,
                                     d=problem.d, dtype=dtype, seed=seed))
        if atk_info.tap_nodes:
            raise ValueError(
                "Eavesdropper taps are simulator-only (per-round payload "
                "trajectories are an analysis artifact) — record them with "
                "run_cola(attacks=...)")
    atk_names = atk_info.entry_names if atk_info else ()
    has_budget = "budgets" in sched
    has_reset = "leavers" in sched

    rec = metrics_lib.make_recorder(recorder, problem, part, env, graph,
                                    base_w, eps)
    if active_schedule is not None:
        rec = metrics_lib.dynamize(rec)  # churn-aware certificate inputs
    if "dishonest" in atk_names:
        # payload-corrupting attacks: certificates audit the honest cohort
        # against the schedule's ground-truth mask (metrics.attackify)
        rec = metrics_lib.attackify(rec)

    # lay the node axis of state + env over the mesh axis up front so the
    # donated buffers never migrate between blocks
    state_spec, env_spec = cola_state_pspecs(axis), cola_env_pspecs(axis)
    state = jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, state_spec)), state)
    env = jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, env_spec)), env)
    obs_upd = obs_inc = None
    if cfg.telemetry:
        # counters attach AFTER the state placement with their OWN specs
        # (scalars replicate, the per-sender gate row shards): the P(axis)
        # prefix spec above must never see them, and the shard_map round
        # program never does either — step_fn strips the counters off the
        # carry, runs the sharded round on the core state, then updates
        # them from the global (before, after, schedule) triple outside
        # shard_map, where GSPMD lays the recompute out over the mesh
        from repro.obs import counters as obs_counters
        obs_inc = obs_counters.dist_round_increments(
            cfg, problem.d, comm=comm, plan=plan, conn=conn, k=k,
            itemsize=dtype.itemsize)
        obs_upd = obs_counters.make_update(cfg, k, obs_inc)
        cts = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            obs_counters.init_counters(k), cola_counters_pspecs(axis))
        state = state._replace(counters=cts)
    rec = _place_recorder(rec, mesh, axis)
    dist_rec = _DistRecorder(
        rec, _dist_record_fn(rec, mesh, axis, local_nodes, comm, conn, plan),
        comm, conn, plan)

    mix_fn, grad_mix_fn = _dist_mixers(axis, local_nodes, conn, comm,
                                       cfg.gossip_steps, plan,
                                       robust=cfg.robust,
                                       robust_trim=cfg.robust_trim,
                                       robust_clip=cfg.robust_clip)
    qmix_fn = qencode_fn = None
    if quantized:
        qmix_fn, qencode_fn = _dist_qmixers(axis, local_nodes, comm, cfg,
                                            plan)
    body = _round_body(problem, part, cfg, mix_fn=mix_fn,
                       grad_mix_fn=grad_mix_fn, qmix_fn=qmix_fn,
                       qencode_fn=qencode_fn)

    def shard_round(st, env_l, w_t, active_l, budgets_l, leavers_l,
                    reset_any, atk_l, qkey_t, qkey_next_t):
        if has_reset:
            # the simulator's reset, with the node-sum completed across
            # devices — shares the Lemma-1 invariant implementation
            st = lax.cond(
                reset_any,
                lambda ss: _reset_leavers(
                    ss, env_l, part, leavers_l,
                    total_fn=lambda c: lax.psum(jnp.sum(c, axis=0), axis)),
                lambda ss: ss, st)
        return body(st, env_l, w_t, active_l,
                    budgets_l if has_budget else None,
                    atk_l if atk_names else None,
                    qkey_t if quantized else None,
                    qkey_next_t if quantized and cfg.pipeline else None)

    # node-axis operands shard over `axis`; the per-round scalars are
    # replicated. The comm payload is the replicated (K, K) W for
    # dense/ring, the node-sharded PlanSchedule slices (diag (K,),
    # coefs (C, K)) for the per-node plan path, or the row-sharded (K, K)
    # round W for the block plan path. ColaEnv.gram_parts may be None — a
    # P(axis) prefix covers whichever leaves exist.
    node, repl = P(axis), P()
    block_mode = isinstance(plan, topo_plan.BlockPlan)
    if plan is None:
        payload_spec = repl
    elif block_mode:
        payload_spec = block_payload_pspec(axis)
    else:
        payload_spec = plan_payload_pspecs(axis)
    # attack entries are per-node (K,)-rows (the (T, K, d) bias slices to
    # (K, d)) — they shard over the node axis like the state they corrupt
    shard_step = mixing.shard_map(
        shard_round, mesh,
        in_specs=(state_spec, env_spec, payload_spec, node,
                  node if has_budget else repl,
                  node if has_reset else repl, repl,
                  {n: node for n in atk_names}, repl, repl),
        out_specs=state_spec)

    zeros_k = np.zeros((rounds,), dtype)

    def step_fn(st, env_ctx, s_t):
        if plan is None:
            payload = s_t["w"]
        elif block_mode:
            payload = s_t["plan_w"]
        else:
            payload = (s_t["plan_diag"], s_t["plan_coefs"])
        atk = {n: s_t["atk_" + n] for n in atk_names}
        core = st if obs_upd is None else st._replace(counters=None)
        core = shard_step(core, env_ctx, payload, s_t["active"],
                          s_t["budgets"] if has_budget else s_t["_pad"],
                          s_t["leavers"] if has_reset else s_t["_pad"],
                          s_t["reset_any"] if has_reset else s_t["_pad"],
                          atk,
                          s_t["qkey"] if quantized else s_t["_pad"],
                          (s_t["qkey_next"] if quantized and cfg.pipeline
                           else s_t["_pad"]))
        if obs_upd is None:
            return core, None
        w = s_t.get("plan_w", s_t.get("w"))
        if w is None and plan is not None and not block_mode:
            # the per-node CommPlan path dropped the (T, K, K) W stack at
            # lowering time; rebuild this round's matrix from the executed
            # coefficients so the gate recompute judges the true W (and
            # make_update's robust-without-W guard never silently zeroes)
            w = topo_plan.w_from_coefficients_device(
                plan, s_t["plan_diag"], s_t["plan_coefs"])
        cts, obs_row = obs_upd(st, core, s_t, atk if atk_names else None, w)
        return core._replace(counters=cts), {"obs": obs_row}

    sched = dict(sched)
    sched["_pad"] = zeros_k  # scalar per-round filler for unused operands

    cad = metrics_lib.as_cadence(record_every)
    rec_mask = (None if cad
                else exec_engine.record_flags(rounds, record_every))
    cert = metrics_lib.first_certificate(rec)
    if cert is not None and cert.dynamic:
        # (attack-aware recorders also read the schedule, but their entry —
        # atk_dishonest — was materialized by apply_attacks already)
        sched.update(metrics_lib.certificate_schedule(
            rec, sched["w"], sched["active"],
            np.ones((rounds,), dtype=bool) if cad else rec_mask))
    if plan is not None:
        # materialize the per-round plan coefficients (validating that
        # every round's W stays inside the compiled support); the per-node
        # path drops the now-unconsumed (T, K, K) W stack from the device
        # schedule, the block path re-enters it row-sharded as ``plan_w``
        sched_cls = (topo_plan.BlockPlanSchedule if block_mode
                     else topo_plan.PlanSchedule)
        # a LinkCorruption-rewritten W stack varies per round even without
        # churn — the static broadcast fast path would bake round 0's links
        w_static = (active_schedule is None
                    and not (atk_info is not None and atk_info.w_modified))
        sched.update(sched_cls.from_w_stack(
            plan, sched["w"], static=w_static).entries())
        del sched["w"]
    with contextlib.ExitStack() as stack:
        run_tr = None
        if cfg.telemetry:
            from repro.obs import trace as obs_trace
            run_tr = stack.enter_context(obs_trace.use(obs_trace.Tracer()))
            stack.enter_context(run_tr.attach())
        res = exec_engine.run_round_blocks(
            step_fn, state, sched, context=env, recorder=dist_rec,
            record_mask=rec_mask, block_size=block_size, cadence=cad,
            num_rounds=rounds,
            cache_key=("cola-dist", exec_engine.fingerprint(problem), part,
                       cfg, mesh, axis, comm, conn, has_budget, has_reset,
                       dist_rec.cache_token(),
                       atk_info.token if atk_info else None))
    history = metrics_lib.history_from(dist_rec, res)
    if cfg.telemetry:
        from repro.obs import counters as obs_counters, report as obs_report
        obs_series = res.aux.get("obs") if isinstance(res.aux, dict) else None
        history["telemetry"] = obs_counters.summarize(
            res.state.counters, obs_inc, series=obs_series,
            stop_round=res.stop_round, dishonest=sched.get("atk_dishonest"))
        obs_report.auto_emit(obs_report.make_report(
            driver="run_dist_cola",
            problem_fp=exec_engine.fingerprint(problem),
            config=dataclasses.asdict(cfg),
            graph={"kind": getattr(graph, "name", type(graph).__name__),
                   "num_nodes": k},
            rounds=(rounds if res.stop_round is None
                    else res.stop_round + 1),
            history=history,
            contract=obs_inc["contract"],
            spans=run_tr.summary() if run_tr is not None else None))
    return RunResult(state=res.state, history=history)
