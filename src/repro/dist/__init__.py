"""``repro.dist`` — sharding rules + shard_map runtime for decentralized runs.

This package is the bridge between the paper's setting (K machines, an
arbitrary communication graph, no coordinator — He et al., NIPS 2018,
Algorithm 1) and a JAX mesh. The mapping from Algorithm-1 quantities to
sharding rules:

=====================  ==========================  =========================
Paper quantity          Buffer (shape)              PartitionSpec
=====================  ==========================  =========================
local iterate x_[k]     ``x_parts`` (K, n_k)        ``P(node_axis)``
local estimate v_k      ``v_stack`` (K, d)          ``P(node_axis)``
data columns A_[k]      ``a_parts`` (K, d, n_k)     ``P(node_axis)``
Gram blocks A^T A       ``gram_parts`` (K,n_k,n_k)  ``P(node_axis)``
mixing matrix W         ``w`` (K, K)                ``P()`` (replicated)
churn mask / Theta_k    ``active``/``budgets`` (K)  ``P(node_axis)``
metric rows (Lemma 2)   ``(m,)`` per record round   ``P()`` (replicated)
=====================  ==========================  =========================

Step 4's gossip exchange v_k <- sum_l W_kl v_l becomes ``lax.ppermute``
neighbor pushes for circulant graphs (``comm="ring"``: deg(k)·|v| bytes per
link, the paper's communication-efficiency argument on ICI hardware) or an
all-gather + W matmul for arbitrary graphs (``comm="dense"``). Everything
node-local — the Theta-approximate CD solve of Eq. 1-2, steps 6-8's updates,
churn freezing/reset — runs unchanged from the single-host simulator inside
the shard_map body, and the parity suites assert the two runtimes agree
bit-for-bit on a 1-device mesh.

``sharding`` also carries the FSDP+TP rules for the deep-net zoo (the
gossip-DP workload of ``repro.optim.gossip`` and the dry-run's production
meshes).
"""
from repro.dist.sharding import (MeshAxes, batch_pspecs, cache_pspecs,
                                 cola_env_pspecs, cola_state_pspecs,
                                 param_pspecs)
from repro.dist.runtime import run_dist_cola

__all__ = ["MeshAxes", "batch_pspecs", "cache_pspecs", "cola_env_pspecs",
           "cola_state_pspecs", "param_pspecs", "run_dist_cola"]
