"""Sharding rules: PartitionSpecs for every state buffer on the production mesh.

One rule set covers both workloads this repo runs:

* the **deep-net zoo** (dry-run / train / serve): FSDP+TP layout — every
  weight matrix puts its output (last) dimension on the ``model`` axis and
  its input dimension on the ``data`` axis, decode caches put batch on the
  data axes and head/feature dims on ``model``;
* the **CoLA state** (``repro.dist.runtime``): the node axis of every
  Algorithm-1 buffer (``x_parts`` (K, n_k), ``v_stack`` (K, d), schedules,
  metric rows) maps onto one mesh axis, so K nodes execute as K shards with
  no coordinator.

Every emitted spec is *divisibility-guarded*: an axis is assigned to a dim
only when the dim divides the mesh size for that axis (``sizes``), which is
what lets the dry-run's ``.lower()`` accept the in_shardings for all 10
architectures without per-arch special cases.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical axis names of the production mesh.

    ``data`` carries batch + FSDP shards, ``model`` carries tensor-parallel
    shards, ``pod`` (multi-pod meshes only) carries the CoLA gossip node
    axis — one paper "node" per pod, neighbor exchange over ICI/DCN via
    ``lax.ppermute`` instead of a cross-pod all-reduce.
    """

    data: str = "data"
    model: str = "model"
    pod: str | None = None

    @property
    def batch_axes(self):
        """Axes the batch dimension shards over (pod-major when present)."""
        return (self.pod, self.data) if self.pod else self.data


def _size(sizes: dict, axis) -> int:
    if isinstance(axis, tuple):
        total = 1
        for a in axis:
            total *= sizes[a]
        return total
    return sizes[axis]


def _path_keys(path) -> list[str]:
    keys = []
    for e in path:
        if hasattr(e, "key"):
            keys.append(str(e.key))
        elif hasattr(e, "idx"):
            keys.append(str(e.idx))
    return keys


def _matrix_spec(shape, data_size: int, model_size: int, *, fsdp: bool,
                 expert_data_dim: int | None = None) -> P:
    """FSDP+TP spec for one weight leaf.

    ``model`` goes on the last divisible dim (output/column parallel,
    falling back to the second-to-last), ``data`` (FSDP) on the best
    remaining divisible dim scanning from the second-to-last backwards —
    leading stacked-layer axes participate only when they divide. With
    ``expert_data_dim`` the FSDP shards land on the experts axis instead
    (token-grouped MoE dispatch).
    """
    ndim = len(shape)
    entries: list = [None] * ndim
    if ndim < 2:
        return P()  # norms/biases: replicate
    model_dim = None
    for dim in (ndim - 1, ndim - 2):
        if shape[dim] % model_size == 0:
            model_dim = dim
            entries[dim] = "model"
            break
    if fsdp:
        if expert_data_dim is not None and expert_data_dim != model_dim \
                and shape[expert_data_dim] % data_size == 0:
            entries[expert_data_dim] = "data"
        else:
            for dim in range(ndim - 2, -1, -1):
                if dim != model_dim and shape[dim] % data_size == 0:
                    entries[dim] = "data"
                    break
            else:
                if model_dim != ndim - 1 and shape[-1] % data_size == 0:
                    entries[-1] = "data"
    return P(*entries)


def _rename(spec: P, axes: MeshAxes) -> P:
    table = {"data": axes.data, "model": axes.model, None: None}
    return P(*(table[a] for a in tuple(spec)))


def param_pspecs(params: Any, axes: MeshAxes, sizes: dict, *,
                 fsdp: bool = True, moe_output_fsdp: bool = False) -> Any:
    """PartitionSpec tree matching ``params`` (arrays or ShapeDtypeStructs).

    Args:
      axes: logical axis names (``MeshAxes``).
      sizes: mesh axis name -> size (``dict(zip(mesh.axis_names,
        mesh.devices.shape))``); used for divisibility guards.
      fsdp: shard the non-TP dim of every weight over ``axes.data``. Off for
        resident-weights serving (model-sharded only, no per-step gather).
      moe_output_fsdp: put the FSDP shards of expert tensors on the experts
        axis (expert-parallel grouping for token-grouped dispatch) instead
        of the feature dim.
    """
    data_size = sizes[axes.data]
    model_size = sizes[axes.model]

    def leaf_spec(path, leaf):
        keys = _path_keys(path)
        expert_dim = None
        if (moe_output_fsdp and len(keys) >= 2 and keys[-2] == "moe"
                and keys[-1] in ("w_gate", "w_up", "w_down")
                and len(leaf.shape) >= 3):
            expert_dim = len(leaf.shape) - 3  # (..., E, d_in, d_out)
        spec = _matrix_spec(leaf.shape, data_size, model_size, fsdp=fsdp,
                            expert_data_dim=expert_dim)
        return _rename(spec, axes)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def cache_pspecs(cfg, cache: Any, global_batch: int, axes: MeshAxes,
                 sizes: dict) -> Any:
    """Decode-cache specs: batch on the data axes, trailing dim on ``model``.

    Cache leaves are stacked over scanned layer groups, so the layout is
    ``(L, B, ...)``: axis 0 replicates (scan carries it), axis 1 shards over
    ``axes.batch_axes`` when the batch divides (long-context B=1 decode
    replicates), and the last axis takes ``model`` when divisible (head_dim
    for KV caches, state/feature dims for SSM states). Only >=4-D leaves
    carry a feature axis — 3-D ones like the KV ``pos`` buffer end in the
    sequence axis, and TP-sharding positions would put a collective on
    every decode step's ring-buffer update.
    """
    batch_ax = axes.batch_axes
    b_size = _size(sizes, batch_ax)
    model_size = sizes[axes.model]

    def leaf_spec(leaf):
        shape = leaf.shape
        ndim = len(shape)
        if ndim < 2:
            return P()
        entries: list = [None] * ndim
        if ndim >= 2 and shape[1] == global_batch and global_batch % b_size == 0:
            entries[1] = batch_ax
        if ndim >= 4 and shape[-1] % model_size == 0:
            entries[-1] = axes.model
        return P(*entries)

    return jax.tree.map(leaf_spec, cache)


def batch_pspecs(cfg, shape, axes: MeshAxes) -> Any:
    """Input-batch specs: leading batch dim over ``axes.batch_axes``."""
    from repro.launch import specs as specs_lib

    sds = specs_lib.input_specs(cfg, shape)

    def leaf_spec(leaf):
        if len(leaf.shape) == 0:
            return P()
        return P(axes.batch_axes, *([None] * (len(leaf.shape) - 1)))

    return jax.tree.map(leaf_spec, sds)


# ---------------------------------------------------------------------------
# CoLA state (Algorithm 1) — the node axis onto a mesh axis
# ---------------------------------------------------------------------------

def cola_state_pspecs(axis: str) -> Any:
    """Specs for ``ColaState``: both buffers (``x_parts`` (K, n_k) and
    ``v_stack`` (K, d)) put the node axis K on mesh axis ``axis``; a
    1-device axis degenerates to the single-host simulator layout."""
    return P(axis)


def cola_env_pspecs(axis: str) -> Any:
    """Specs for ``ColaEnv``: every per-node array (``a_parts`` (K, d, n_k),
    ``gp_parts``/``masks`` (K, n_k), ``gram_parts`` (K, n_k, n_k)) shards
    its leading node axis; nothing is replicated but the Problem constants
    baked into the compiled round program."""
    return P(axis)


def plan_payload_pspecs(axis: str) -> tuple:
    """Specs for the comm-plan payload (``repro.topo.PlanSchedule`` round
    slices): ``plan_diag`` (K,) shards its node axis, ``plan_coefs``
    (C, K) shards the node axis and replicates the color axis — so inside
    the shard_map round body each device reads exactly its own scalar
    coefficients (no W matrix, no gathers) and the ppermute perms are the
    only cross-device traffic of a plan-executed gossip step."""
    return (P(axis), P(None, axis))


def block_payload_pspec(axis: str) -> P:
    """Spec for the block-mode comm-plan payload
    (``repro.topo.BlockPlanSchedule`` round slices): the (K, K) round W
    shards its ROW axis over the node mesh axis, so each device reads its
    own (K/M, K) coefficient rows — the per-node weights it applies to the
    ppermute-assembled (K/M, ...) block payloads — and no device ever
    materializes another block's rows."""
    return P(axis)


def cola_counters_pspecs(axis: str) -> Any:
    """Specs for the telemetry ``obs.counters.Counters`` carry
    (``ColaState.counters`` when ``ColaConfig.telemetry=True``): the scalar
    accumulators (round/byte/permute/saturation/EF totals) replicate — they
    are the same number on every device by construction — and the per-sender
    ``gate`` (K,) rejection counter shards its node axis over ``axis`` like
    every other per-node row. Returned as a ``Counters`` of specs so
    ``jax.tree.map`` pairs leaves one-to-one with ``init_counters``."""
    from repro.obs.counters import Counters

    rep = P()
    return Counters(rounds=rep, wire_bytes=rep, permutes=rep,
                    sat_sum=rep, ef_sq=rep, gate=P(axis))


def cola_recorder_pspecs(axis: str, rec_state: Any) -> Any:
    """Specs for a recorder's per-run state (``Recorder.init_spec``): every
    array with a leading node dimension — the ``sigma_k`` spectral-norm
    cache (K,), the self-inclusive neighbor mask (K, K), the per-node
    problem blocks the certificate's condition (9) consumes — shards its
    node axis over ``axis``; scalars (thresholds, bounds) replicate. This is
    what keeps certificate record rounds gather-free: every operand of the
    shard_map record program is already node-sharded."""
    import numpy as np

    return jax.tree.map(
        lambda x: P(axis) if np.ndim(x) >= 1 else P(), rec_state)
