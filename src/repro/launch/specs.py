"""ShapeDtypeStruct stand-ins for every model input (dry-run, no allocation).

``input_specs(cfg, shape)`` returns the abstract batch for an (architecture x
input shape) pair; ``state_specs`` / ``cache_specs`` build the abstract
train-state and decode-cache pytrees via ``jax.eval_shape``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer
from repro.train.steps import TrainHParams, init_train_state


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Abstract input batch for one step of this (arch, shape) pair."""
    b = shape.global_batch
    if shape.kind == "decode":
        batch = {"tokens": _sds((b, 1), jnp.int32)}
    else:
        s = shape.seq_len
        if cfg.family == "vlm":
            # patches occupy part of the context; text fills the rest
            s = max(1, s - cfg.num_prefix_tokens)
            batch = {"tokens": _sds((b, s), jnp.int32),
                     "patches": _sds((b, cfg.num_prefix_tokens,
                                      cfg.frontend_dim), jnp.bfloat16)}
        elif cfg.family == "encdec":
            # audio frames from the stubbed codec frontend, same length budget
            batch = {"tokens": _sds((b, s), jnp.int32),
                     "enc_embeds": _sds((b, s, cfg.frontend_dim),
                                        jnp.bfloat16)}
        else:
            batch = {"tokens": _sds((b, s), jnp.int32)}
        if shape.kind == "train":
            batch["labels"] = _sds(batch["tokens"].shape, jnp.int32)
    return batch


def state_specs(cfg: ModelConfig, hp: TrainHParams = TrainHParams()) -> Any:
    """Abstract TrainState (params + AdamW moments) — no allocation."""
    return jax.eval_shape(
        lambda key: init_train_state(cfg, key, hp), jax.random.key(0))


def params_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(lambda key: transformer.init_params(cfg, key),
                          jax.random.key(0))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    params = params_specs(cfg)
    return jax.eval_shape(
        lambda p: transformer.init_cache(cfg, p, batch, max_len), params)


def param_bytes(tree: Any) -> int:
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(tree))
