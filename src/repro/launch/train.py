"""End-to-end training driver.

Two modes:
  * canonical data-parallel (all-reduce) training,
  * ``--gossip K``: CoLA-style gossip data-parallelism — K node replicas,
    local AdamW steps, Metropolis parameter mixing over a ring instead of a
    global gradient all-reduce, with optional node dropout (--drop-p).

On this CPU container use ``--smoke`` (reduced config). Example:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --steps 100 --batch 8 --seq 64
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
      --gossip 4 --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, smoke_variant
from repro.optim import gossip as gsp
from repro.train import checkpoint
from repro.train.data import TokenBatches
from repro.train.steps import TrainHParams, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--gossip", type=int, default=0,
                    help="number of gossip-DP nodes (0 = all-reduce DP)")
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--drop-p", type=float, default=0.0,
                    help="per-round node dropout probability (gossip mode)")
    ap.add_argument("--mix-every", type=int, default=1,
                    help="local steps between gossip rounds (gossip mode)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    hp = TrainHParams(lr=args.lr)
    key = jax.random.PRNGKey(args.seed)
    pipe = TokenBatches(cfg.vocab_size, args.batch, args.seq, seed=args.seed)

    if args.gossip:
        run_gossip(cfg, hp, pipe, args)
        return

    state = init_train_state(cfg, key, hp)
    step_fn = jax.jit(make_train_step(cfg, hp))
    t0 = time.time()
    for i in range(args.steps):
        batch = jax.tree.map(jnp.asarray, pipe(i))
        state, metrics = step_fn(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            print(f"step {i:5d}  loss {m['loss']:.4f}  ce {m['ce']:.4f}  "
                  f"gnorm {m['grad_norm']:.3f}  "
                  f"{(time.time() - t0) / (i + 1):.2f}s/step", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, state.params)
        print(f"saved params -> {args.ckpt}")


def run_gossip(cfg, hp, pipe, args) -> None:
    k = args.gossip
    gcfg = gsp.GossipConfig(num_nodes=k, topology=args.topology,
                            mix_every=args.mix_every)
    key = jax.random.PRNGKey(args.seed)
    state0 = init_train_state(cfg, key, hp)
    states = gsp.replicate_state(state0, k)
    local = make_train_step(cfg, hp)
    step_fn = gsp.make_gossip_step(local, gcfg)
    rng = np.random.default_rng(args.seed)
    w_full = jnp.asarray(gcfg.weights(), jnp.float32)
    t0 = time.time()
    for i in range(args.steps):
        if args.drop_p > 0:
            active_np = rng.random(k) >= args.drop_p
            if not active_np.any():
                active_np[:] = True
            w = jnp.asarray(gcfg.weights(active_np), jnp.float32)
        else:
            active_np, w = np.ones(k, bool), w_full
        # node j draws its own shard of the stream (stateless addressing)
        batches = jax.tree.map(
            jnp.asarray,
            jax.tree.map(lambda *xs: np.stack(xs),
                         *[pipe(i, shard=j) for j in range(k)]))
        states, metrics = step_fn(states, batches,
                                  w, jnp.asarray(active_np, jnp.float32),
                                  do_mix=(i % gcfg.mix_every == 0))
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(jnp.mean(metrics["loss"]))
            cons = float(gsp.consensus_distance(states.params))
            print(f"round {i:5d}  mean-loss {loss:.4f}  "
                  f"consensus-dist {cons:.3e}  active {int(active_np.sum())}/{k}"
                  f"  {(time.time() - t0) / (i + 1):.2f}s/round", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, gsp.average_params(states.params))
        print(f"saved consensus-averaged params -> {args.ckpt}")


if __name__ == "__main__":
    main()
