"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) step.

This is how the distribution config is proven coherent without hardware: the
production mesh (16x16 single pod / 2x16x16 multi-pod) is built from 512
placeholder CPU devices, every step is lowered with ShapeDtypeStruct inputs
(no allocation), compiled, and its memory/cost analysis + collective schedule
recorded for the roofline analysis (EXPERIMENTS.md).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
# The first two lines MUST run before any other import (jax locks the device
# count on first init).
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import re
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCHS, SHAPES, InputShape, ModelConfig, \
    get_config
from repro.dist import sharding as shd
from repro.launch import mesh as mesh_lib
from repro.launch import specs as specs_lib
from repro.models import transformer
from repro.models.blocks import ModelCtx
from repro.launch import hlo_analysis
from repro.train.steps import (TrainHParams, make_decode_step,
                               make_prefill_step, make_train_step)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# dims like bf16[16,1024,8]{...}
_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
                "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
                "pred": 1}


def _shape_bytes(text: str) -> int:
    """Sum the sizes of all array shapes in an HLO result-type string."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dtype, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind bytes from the SPMD-partitioned HLO.

    Shapes in the partitioned module are per-device, so the sums are
    per-device bytes moved (all-reduce counted twice for the reduce+broadcast
    ring phases). ``-start`` variants cover the async forms.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        # result-typed op lines look like: %name = TYPE op-name(...)
        m = re.match(r"%?[\w.\-]+ = (.+?) ([\w\-]+)\(", ls)
        if not m:
            continue
        result_type, op = m.groups()
        base = op.removesuffix("-start")
        if base in _COLLECTIVES:
            factor = 2 if base == "all-reduce" else 1
            out[base] += factor * _shape_bytes(result_type)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def _named(tree: Any, mesh, spec_tree: Any):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


@dataclasses.dataclass(frozen=True)
class Opts:
    """Perf-hillclimb knobs (EXPERIMENTS.md §Perf). Defaults = baseline."""

    attn_bf16: bool = False        # bf16 score/PV operands (f32 accum)
    remat_policy: str = "full"     # full | dots | none
    microbatches: int = 1
    act_constraint: bool = False   # pin layer-boundary activation sharding
    param_dtype: str | None = None  # e.g. "bfloat16" master params
    state_dtype: str = "float32"   # optimizer moment dtype
    gossip_pod: bool = False       # CoLA gossip-DP across pods (train only)
    moe_grouped: bool = False      # token-grouped MoE dispatch
    serve_resident: bool = False   # serving: no FSDP — weights stay resident
    #   (model-sharded only); kills the per-token weight all-gather
    swa_window: int = 0            # >0: force sliding-window attention —
    #   gives quadratic-attention archs a sub-quadratic long_500k variant

    def apply_cfg(self, cfg: ModelConfig) -> ModelConfig:
        updates = {}
        if self.attn_bf16:
            updates["attn_compute_dtype"] = "bfloat16"
        if self.remat_policy != "full":
            updates["remat_policy"] = self.remat_policy
        if self.param_dtype:
            updates["param_dtype"] = self.param_dtype
        if self.swa_window and cfg.attention == "full":
            updates["attention"] = "sliding"
            updates["window"] = self.swa_window
        return dataclasses.replace(cfg, **updates) if updates else cfg

    def tag(self) -> str:
        bits = []
        if self.attn_bf16: bits.append("attnbf16")
        if self.remat_policy != "full": bits.append(f"remat-{self.remat_policy}")
        if self.microbatches > 1: bits.append(f"mb{self.microbatches}")
        if self.act_constraint: bits.append("actspec")
        if self.param_dtype: bits.append(f"p-{self.param_dtype}")
        if self.state_dtype != "float32": bits.append(f"s-{self.state_dtype}")
        if self.gossip_pod: bits.append("gossip")
        if self.moe_grouped: bits.append("moegrp")
        if self.serve_resident: bits.append("resident")
        if self.swa_window: bits.append(f"swa{self.swa_window}")
        return "+".join(bits) or "baseline"


BASELINE = Opts()


def model_ctx(mesh) -> ModelCtx:
    # MoE dispatch runs in global scatter mode and lets GSPMD partition the
    # per-expert einsums over the ``model`` axis (expert weights are sharded
    # by param_pspecs). A manual shard_map under remat+scan trips an XLA
    # SPMD bug ("Invalid binary instruction opcode copy"), so the manual
    # expert-parallel path is reserved for the executed (non-AOT) runtime.
    return ModelCtx(mesh=None, model_axis=None, moe_mode="scatter")


def model_ctx_opt(mesh, axes, opts: Opts) -> ModelCtx:
    groups = 1
    if opts.moe_grouped:
        sizes = _mesh_sizes(mesh)
        groups = sizes[axes.data] * (sizes.get("pod", 1)
                                     if axes.pod else 1)
    if not opts.act_constraint and groups <= 1:
        return model_ctx(mesh)
    return ModelCtx(mesh=mesh if opts.act_constraint else None,
                    model_axis=None, moe_mode="scatter",
                    act_spec=(P(axes.batch_axes, None, None)
                              if opts.act_constraint else None),
                    dispatch_groups=groups)


def _mesh_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _param_flags(kind: str, opts: Opts) -> dict:
    """param_pspecs kwargs per shape kind — the ONE mapping shared by the
    artifact builders and render_plan so the printed plan always matches
    the lowered in_shardings: training always runs FSDP (+ expert grouping
    with --moe-grouped); serving may keep weights resident (model-sharded
    only, --serve-resident) and never groups experts."""
    if kind == "train":
        return dict(fsdp=True, moe_output_fsdp=opts.moe_grouped)
    return dict(fsdp=not opts.serve_resident, moe_output_fsdp=False)


def _train_artifacts(cfg: ModelConfig, shape: InputShape, mesh, axes,
                     hp: TrainHParams, opts: Opts = BASELINE):
    state_sds = specs_lib.state_specs(cfg, hp)
    batch_sds = specs_lib.input_specs(cfg, shape)
    pspecs = shd.param_pspecs(
        state_sds.params, axes, _mesh_sizes(mesh),
        **_param_flags("train", opts))
    # opt_state is {"m": params-like, "v": params-like}
    state_specs_tree = state_sds._replace(
        params=pspecs, opt_state={"m": pspecs, "v": pspecs}, step=P())
    batch_specs_tree = shd.batch_pspecs(cfg, shape, axes)
    step_fn = make_train_step(cfg, hp, model_ctx_opt(mesh, axes, opts))
    in_shardings = (_named(state_sds, mesh, state_specs_tree),
                    _named(batch_sds, mesh, batch_specs_tree))
    out_shardings = (_named(state_sds, mesh, state_specs_tree),
                     None)
    fn = jax.jit(step_fn, in_shardings=in_shardings,
                 out_shardings=out_shardings)
    return fn, (state_sds, batch_sds)


def _prefill_artifacts(cfg: ModelConfig, shape: InputShape, mesh, axes,
                       opts: Opts = BASELINE):
    params_sds = specs_lib.params_specs(cfg)
    batch_sds = specs_lib.input_specs(cfg, shape)
    cache_sds = specs_lib.cache_specs(cfg, shape.global_batch, shape.seq_len)
    pspecs = shd.param_pspecs(
        params_sds, axes, _mesh_sizes(mesh), **_param_flags("prefill", opts))
    bspecs = shd.batch_pspecs(cfg, shape, axes)
    cspecs = shd.cache_pspecs(
        cfg, cache_sds, shape.global_batch, axes, _mesh_sizes(mesh))
    step_fn = make_prefill_step(cfg, model_ctx_opt(mesh, axes, opts))
    fn = jax.jit(step_fn, in_shardings=(
        _named(params_sds, mesh, pspecs), _named(batch_sds, mesh, bspecs),
        _named(cache_sds, mesh, cspecs)))
    return fn, (params_sds, batch_sds, cache_sds)


def _decode_artifacts(cfg: ModelConfig, shape: InputShape, mesh, axes,
                      opts: Opts = BASELINE):
    b = shape.global_batch
    params_sds = specs_lib.params_specs(cfg)
    cache_sds = specs_lib.cache_specs(cfg, b, shape.seq_len)
    pspecs = shd.param_pspecs(
        params_sds, axes, _mesh_sizes(mesh), **_param_flags("decode", opts))
    cspecs = shd.cache_pspecs(
        cfg, cache_sds, b, axes, _mesh_sizes(mesh))
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    t_sds = jax.ShapeDtypeStruct((), jnp.int32)
    batch_ax = axes.batch_axes if b >= 16 else ()
    tok_spec = P(batch_ax, None) if batch_ax else P()
    step_fn = make_decode_step(cfg, model_ctx_opt(mesh, axes, opts))
    args = [params_sds, tok_sds, t_sds, cache_sds]
    in_sh = [_named(params_sds, mesh, pspecs),
             NamedSharding(mesh, tok_spec), NamedSharding(mesh, P()),
             _named(cache_sds, mesh, cspecs)]
    kwargs = {}
    if cfg.family == "encdec":
        # cross-attention KV computed once at request admission
        enc_sds = jax.ShapeDtypeStruct(
            (b, shape.seq_len, cfg.frontend_dim), jnp.bfloat16)
        enc_kv_sds = jax.eval_shape(
            lambda p, e: transformer._enc_kv_all_layers(
                cfg, p, transformer.encode(cfg, p, e)[0]),
            params_sds, enc_sds)
        enc_pos_sds = jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)
        kv_spec = jax.tree.map(lambda _: NamedSharding(
            mesh, P(None, axes.data if b >= 16 else None, None, None, None)),
            enc_kv_sds)
        kwargs = {"enc_kv": enc_kv_sds, "enc_pos": enc_pos_sds}
        fn = jax.jit(lambda p, tok, t, c, enc_kv, enc_pos: step_fn(
            p, tok, t, c, enc_kv=enc_kv, enc_pos=enc_pos),
            in_shardings=tuple(in_sh) + (
                kv_spec, NamedSharding(
                    mesh, P(axes.batch_axes if b >= 16 else None, None))))
        return fn, tuple(args) + (enc_kv_sds, enc_pos_sds)
    fn = jax.jit(step_fn, in_shardings=tuple(in_sh))
    return fn, tuple(args)


def _gossip_train_artifacts(cfg: ModelConfig, shape: InputShape, mesh, axes,
                            hp: TrainHParams, opts: Opts):
    """CoLA gossip-DP across pods: each pod holds its own replica (sharded
    over data/model within the pod), takes a local step on its own batch
    shard, then parameter-mixes with its neighbor pod via collective-permute
    — the cross-pod gradient all-reduce disappears from the program."""
    from jax import lax

    n_pods = _mesh_sizes(mesh)["pod"]
    state_sds = specs_lib.state_specs(cfg, hp)
    stacked_sds = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_pods,) + l.shape, l.dtype),
        state_sds)
    b_local = shape.global_batch // n_pods
    batch_one = specs_lib.input_specs(cfg, shape)
    stacked_batch = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_pods, b_local) + l.shape[1:],
                                       l.dtype), batch_one)

    pod_axes = shd.MeshAxes()  # within-pod layout (data, model)
    pspecs = shd.param_pspecs(
        state_sds.params, pod_axes, _mesh_sizes(mesh))
    prepend = lambda spec: P("pod", *tuple(spec))
    pod_pspecs = jax.tree.map(prepend, pspecs,
                              is_leaf=lambda x: isinstance(x, P))
    state_specs_tree = state_sds._replace(
        params=pod_pspecs, opt_state={"m": pod_pspecs, "v": pod_pspecs},
        step=P())
    bspec_one = shd.batch_pspecs(cfg, shape, pod_axes)
    bspecs = jax.tree.map(prepend, bspec_one,
                          is_leaf=lambda x: isinstance(x, P))

    local_step = make_train_step(cfg, hp, model_ctx_opt(mesh, pod_axes, opts))

    def mix_params(params_stacked):
        def mix_leaf(p_local):
            # p_local: (1, ...) this pod's replica; pairwise Metropolis mix
            other = lax.ppermute(p_local, "pod",
                                 [(i, (i + 1) % n_pods) for i in range(n_pods)])
            return (0.5 * p_local.astype(jnp.float32)
                    + 0.5 * other.astype(jnp.float32)).astype(p_local.dtype)
        return jax.tree.map(mix_leaf, params_stacked)

    from repro.core import mixing
    shard_mix = mixing.shard_map(mix_params, mesh, in_specs=P("pod"),
                                 out_specs=P("pod"))

    def gossip_step(states, batches):
        new_states, metrics = jax.vmap(local_step)(states, batches)
        mixed = shard_mix(new_states.params)
        return new_states._replace(params=mixed), metrics

    fn = jax.jit(gossip_step,
                 in_shardings=(_named(stacked_sds, mesh, state_specs_tree),
                               _named(stacked_batch, mesh, bspecs)),
                 out_shardings=(_named(stacked_sds, mesh, state_specs_tree),
                                None))
    return fn, (stacked_sds, stacked_batch)


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               hp: TrainHParams | None = None, compile_: bool = True,
               opts: Opts = BASELINE) -> dict:
    """Lower + compile one (arch, shape, mesh) combination; return the report."""
    cfg = opts.apply_cfg(get_config(arch))
    shape = SHAPES[shape_name]
    if _shape_infeasible(cfg, shape_name):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi_pod" if multi_pod else "single_pod",
                "status": "skipped",
                "reason": "full quadratic attention; see DESIGN.md"}
    hp = hp or TrainHParams(microbatches=opts.microbatches,
                            state_dtype=opts.state_dtype)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    axes = mesh_lib.mesh_axes(multi_pod)
    t0 = time.time()
    with jax.default_device(jax.devices("cpu")[0]):
        if shape.kind == "train":
            if opts.gossip_pod:
                assert multi_pod, "--gossip-pod needs the multi-pod mesh"
                fn, args = _gossip_train_artifacts(cfg, shape, mesh, axes,
                                                   hp, opts)
            else:
                fn, args = _train_artifacts(cfg, shape, mesh, axes, hp, opts)
        elif shape.kind == "prefill":
            fn, args = _prefill_artifacts(cfg, shape, mesh, axes, opts)
        else:
            fn, args = _decode_artifacts(cfg, shape, mesh, axes, opts)
        lowered = fn.lower(*args)
    t_lower = time.time() - t0
    report = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi_pod" if multi_pod else "single_pod",
        "kind": shape.kind, "status": "lowered",
        "chips": int(mesh.devices.size),
        "opts": opts.tag(),
        "lower_s": round(t_lower, 2),
    }
    if not compile_:
        return report
    t0 = time.time()
    compiled = lowered.compile()
    report["compile_s"] = round(time.time() - t0, 2)
    report["status"] = "compiled"
    cost = compiled.cost_analysis() or {}
    report["flops_per_device"] = float(cost.get("flops", 0.0))
    report["bytes_per_device"] = float(cost.get("bytes accessed", 0.0))
    mem = compiled.memory_analysis()
    if mem is not None:
        report["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        }
    hlo_text = compiled.as_text()
    # XLA's cost_analysis counts while bodies once (ignores trip counts); the
    # trip-count-aware analyzer is the authoritative roofline source.
    report["hlo"] = hlo_analysis.analyze(
        hlo_text, pod_size=256 if multi_pod else None)
    report["collectives"] = collective_bytes(hlo_text)
    return report


def _shape_infeasible(cfg: ModelConfig, shape_name: str) -> bool:
    """long_500k decode needs bounded state (see DESIGN.md) — the one
    (arch, shape) combination the sweep and the plan both skip."""
    return shape_name == "long_500k" and not cfg.sub_quadratic


def render_plan(arch: str, shape_name: str, *, multi_pod: bool = False,
                opts: Opts = BASELINE) -> str:
    """Human-readable sharding plan: every state leaf with its shape and the
    PartitionSpec the shipped rules assign it (no lowering, no allocation)."""
    cfg = opts.apply_cfg(get_config(arch))
    shape = SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    axes = mesh_lib.mesh_axes(multi_pod)
    sizes = _mesh_sizes(mesh)

    lines = [f"# sharding plan: {arch} x {shape_name} on "
             f"{'x'.join(str(s) for s in mesh.devices.shape)} "
             f"({', '.join(mesh.axis_names)})"]

    def section(title, shapes_tree, specs_tree):
        lines.append(f"[{title}]")
        flat_s = jax.tree_util.tree_leaves_with_path(shapes_tree)
        flat_p = jax.tree.leaves(specs_tree,
                                 is_leaf=lambda x: isinstance(x, P))
        for (path, leaf), spec in zip(flat_s, flat_p):
            name = jax.tree_util.keystr(path)
            lines.append(f"  {name:<60} {str(leaf.shape):<24} {spec}")

    params_sds = specs_lib.params_specs(cfg)
    section("params", params_sds,
            shd.param_pspecs(params_sds, axes, sizes,
                             **_param_flags(shape.kind, opts)))
    section("batch", specs_lib.input_specs(cfg, shape),
            shd.batch_pspecs(cfg, shape, axes))
    # prefill steps shard a cache too (_prefill_artifacts) — render it for
    # every cache-carrying kind, not just decode
    if shape.kind in ("prefill", "decode") and not _shape_infeasible(
            cfg, shape_name):
        cache_sds = specs_lib.cache_specs(cfg, shape.global_batch,
                                          shape.seq_len)
        section("cache", cache_sds,
                shd.cache_pspecs(cfg, cache_sds, shape.global_batch, axes,
                                 sizes))
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--plan", action="store_true",
                    help="print the sharding plan (specs per leaf) instead "
                         "of lowering")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true",
                    help="re-run pairs whose report file already exists")
    ap.add_argument("--attn-bf16", action="store_true")
    ap.add_argument("--remat-policy", default="full",
                    choices=["full", "dots", "none"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--act-constraint", action="store_true")
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--state-dtype", default="float32")
    ap.add_argument("--gossip-pod", action="store_true")
    ap.add_argument("--moe-grouped", action="store_true")
    ap.add_argument("--serve-resident", action="store_true")
    ap.add_argument("--swa-window", type=int, default=0)
    ap.add_argument("--cola-d", type=int, default=1 << 20,
                    help="--plan: CoLA problem dimension d for the recorder "
                         "collective-footprint section")
    ap.add_argument("--cola-n", type=int, default=1 << 24,
                    help="--plan: CoLA coordinate count n (n_k = n / K)")
    ap.add_argument("--cola-k", type=int, default=16,
                    help="--plan: node count for the topology-program "
                         "section (the gossip graph compiled to ppermutes)")
    ap.add_argument("--cola-m", type=int, default=None,
                    help="--plan: ALSO render each topology's block plan "
                         "for K nodes quotiented onto M < K devices "
                         "(block-level colors, per-link block bytes, "
                         "intra- vs inter-block edge split)")
    ap.add_argument("--wire", default=None,
                    choices=["fp32", "fp8", "fp8_e5m2", "int8"],
                    help="--plan: gossip wire codec — renders each "
                         "topology's byte budget (and enforced contract "
                         "line) for the quantized payload + fp32 scale "
                         "sidecar instead of the fp32 wire")
    ap.add_argument("--active", type=int, default=None,
                    help="--plan: ALSO render the streamed participation "
                         "schedule footprint for K'=ACTIVE of --cola-k "
                         "sampled nodes per round "
                         "(ColaConfig(participation=SampleConfig(...)))")
    ap.add_argument("--rounds", type=int, default=1000,
                    help="--plan --active: round count T the streamed-vs-"
                         "stacked schedule byte comparison assumes")
    ap.add_argument("--topo", default="ring,torus2d,expander,complete",
                    help="--plan: comma-separated topology names "
                         "(repro.topo.GRAPHS) whose compiled comm plans to "
                         "render; 'none' skips the section")
    args = ap.parse_args()
    if args.cola_m is not None and (
            args.cola_m < 1 or args.cola_k % args.cola_m != 0):
        ap.error(f"--cola-m {args.cola_m} must divide --cola-k "
                 f"{args.cola_k} (contiguous node blocks per device)")
    opts = Opts(attn_bf16=args.attn_bf16, remat_policy=args.remat_policy,
                microbatches=args.microbatches,
                act_constraint=args.act_constraint,
                param_dtype=args.param_dtype, state_dtype=args.state_dtype,
                gossip_pod=args.gossip_pod, moe_grouped=args.moe_grouped,
                serve_resident=args.serve_resident,
                swa_window=args.swa_window)

    pairs = []
    archs = ARCHS if args.all or args.arch is None else [
        args.arch.replace("-", "_")]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    for a in archs:
        for s in shapes:
            pairs.append((a, s))

    if args.plan:
        for a, s in pairs:
            print(render_plan(a, s, multi_pod=args.multi_pod, opts=opts),
                  flush=True)
        # the CoLA control plane rides the same meshes: show what one metric
        # record round moves per device under each recorder (the gap
        # recorder gathers the stacks; the Prop.-1 certificate recorder is
        # O(d) on the ring) so the recording cadence can be budgeted like
        # any other collective
        from repro.core import metrics as cola_metrics
        k_nodes = 2 * 256 if args.multi_pod else 16
        print(cola_metrics.render_footprints(k=k_nodes, d=args.cola_d,
                                             n_k=args.cola_n // k_nodes),
              flush=True)
        # the telemetry counter carry (ColaConfig(telemetry=True)) rides the
        # same round scan: a handful of replicated scalars plus one
        # node-sharded gate vector — budget it next to the recorders
        from repro.obs import counters as obs_counters
        print(obs_counters.render_footprint(k_nodes), flush=True)
        # streamed participation schedules (client sampling): per-round
        # schedule bytes resident inside the scan vs the (T, ...) stacks
        # streaming replaces — the million-node population budget
        if args.active is not None:
            from repro.core import schedule as cola_schedule
            print(cola_schedule.render_stream_footprint(
                args.cola_k, args.active, args.rounds, args.cola_d),
                flush=True)
        # compiled comm plans for arbitrary gossip topologies: color count,
        # the ppermute matchings, and per-link / per-device bytes per round
        # — the neighbor-only communication budget the topology-program
        # compiler (repro.topo) buys over the dense all-gather, rendered
        # for ANY registered graph, not just the circulant band. With
        # --cola-m the K-node graph is additionally quotiented onto M
        # devices (the block plan run_dist_cola executes on a mesh smaller
        # than the graph): block-level colors, per-link BLOCK bytes and the
        # intra- vs inter-block edge split.
        if args.topo != "none":
            from repro.core import schedule as cola_schedule
            from repro.core import topology as cola_topo
            from repro import topo as topo_programs
            wire = None if args.wire in (None, "fp32") else args.wire
            if args.cola_k > cola_schedule.DENSE_MAX_NODES:
                # a dense (K, K) adjacency/plan at this K would not fit —
                # the streamed cohort path above is the whole story
                print(f"[topology program] skipped: K={args.cola_k:,} > "
                      f"{cola_schedule.DENSE_MAX_NODES:,} "
                      "(dense adjacency/coloring does not materialize at "
                      "this population; sampled runs use the implicit "
                      "complete graph + streamed cohort schedule)",
                      flush=True)
                return
            for name in args.topo.split(","):
                graph = topo_programs.build(name.strip(), args.cola_k)
                plan = topo_programs.compile_plan(graph)
                beta = cola_topo.beta(cola_topo.metropolis_weights(graph))
                print(f"[topology program] {name.strip()} "
                      f"(graph={graph.name}, beta={beta:.4f})", flush=True)
                # the same budget repro.analysis verifies against the
                # compiled HLO — the render above is the plan's promise,
                # this line is the enforced contract (--wire swaps both to
                # the quantized payload + scale-sidecar accounting)
                print("  " + plan.contract(args.cola_d,
                                           wire=wire).describe(),
                      flush=True)
                print(plan.render(d=args.cola_d, wire=wire), flush=True)
                if args.cola_m and args.cola_m < args.cola_k:
                    bplan = topo_programs.compile_block_plan(graph,
                                                             args.cola_m)
                    print("  " + bplan.contract(args.cola_d,
                                                wire=wire).describe(),
                          flush=True)
                    print(bplan.render(d=args.cola_d, wire=wire),
                          flush=True)
        return

    os.makedirs(args.out, exist_ok=True)
    for a, s in pairs:
        tag = "multi" if args.multi_pod else "single"
        suffix = "" if opts.tag() == "baseline" else f"__{opts.tag()}"
        path = os.path.join(args.out, f"{a}__{s}__{tag}{suffix}.json")
        if os.path.exists(path) and not args.force:
            print(f"=== {a} x {s} [{tag}-pod] cached ===", flush=True)
            continue
        print(f"=== {a} x {s} [{tag}-pod] ===", flush=True)
        try:
            rep = lower_pair(a, s, multi_pod=args.multi_pod,
                             compile_=not args.no_compile, opts=opts)
        except Exception as e:  # record the failure, keep sweeping
            rep = {"arch": a, "shape": s, "mesh": tag, "status": "failed",
                   "error": f"{type(e).__name__}: {e}"[:500]}
        print(json.dumps(rep, indent=1), flush=True)
        with open(path, "w") as f:
            json.dump(rep, f, indent=1)


if __name__ == "__main__":
    main()
