"""Batched serving driver: prefill a batch of prompts, stream decode steps.

  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-7b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, smoke_variant
from repro.models import transformer
from repro.models.model import build_model
from repro.train import checkpoint


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    api = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = api.init(key)
    if args.ckpt:
        params = checkpoint.restore(args.ckpt, params)

    b, s = args.batch, args.prompt_len
    prompt = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": prompt}
    kw = {}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.num_prefix_tokens, cfg.frontend_dim))
    if cfg.family == "encdec":
        enc = jax.random.normal(key, (b, s, cfg.frontend_dim))
        batch["enc_embeds"] = enc
        enc_out, enc_pos = api.encode(params, enc)
        kw = {"enc_kv": transformer._enc_kv_all_layers(cfg, params, enc_out),
              "enc_pos": enc_pos}

    max_len = s + args.gen + (cfg.num_prefix_tokens
                              if cfg.family == "vlm" else 0)
    cache = api.init_cache(params, b, max_len)
    decode = jax.jit(lambda p, tok, t, c: api.decode_step(p, tok, t, c, **kw))

    t0 = time.time()
    logits, cache = jax.block_until_ready(api.prefill(params, batch, cache))
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tok]
    pos0 = s + (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, tok, jnp.asarray(pos0 + i, jnp.int32),
                               cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={b} prompt={s} gen={args.gen}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({b * s / t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode / max(args.gen - 1, 1) * 1e3:.2f} ms/step "
          f"({b * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s)")
    print("sample tokens:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
