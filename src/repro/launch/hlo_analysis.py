"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts each ``while`` body ONCE,
ignoring the trip count — for scan-stacked models that under-reports FLOPs,
bytes and collective traffic by a factor of num_layers (and by seq/chunk for
the inner flash-attention/SSD scans). This module re-derives the three
roofline inputs directly from the SPMD-partitioned HLO text:

  * builds the computation graph (fusion ``calls=`` edges, ``while``
    condition/body regions),
  * extracts each while loop's trip count from its condition computation
    (the ``constant(N)`` compared against the induction variable — exact for
    lax.scan/fori_loop-generated loops, which is all this codebase emits),
  * walks from ENTRY with a running execution multiplicity,
  * FLOPs: exact for ``dot`` (2 x out_elems x contraction), approximate for
    fused elementwise (1 x out_elems),
  * bytes: post-fusion operand+output traffic per executed op,
  * collective bytes per kind (all-reduce counted twice: reduce+broadcast).

Shapes in the partitioned module are per-device, so every number is
per-chip. Validated in tests against hand-computed matmul chains.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
                "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
                "f8e4m3b11fnuz": 1}

#: structural HLO types that carry no payload — counted as zero-byte
#: entries (NOT silently dropped: an op whose only result is a token still
#: parses, and a tuple mixing tokens with arrays keeps its array bytes)
_ZERO_BYTE_TYPES = frozenset({"token", "opaque"})

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT )?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shapes(text: str) -> List[tuple]:
    """All (dtype, dims) shapes in a type string (tuples flattened).

    Zero-payload types (``token[]``, ``opaque[]``) are kept as zero-element
    entries rather than dropped, so callers still see the op parsed; truly
    unknown dtypes are skipped."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        if dt in _ZERO_BYTE_TYPES:
            out.append((dt, (0,)))
            continue
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, shape in _parse_shapes(text):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 0)
    return total


def _elems_of(text: str) -> int:
    total = 0
    for _, shape in _parse_shapes(text):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    rest: str  # operand list + attributes


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    types: Dict[str, str]  # symbol -> result type string


def parse_module(text: str) -> tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1), [], {})
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                # parameters appear in the header: %p: f32[...]
                for pm in re.finditer(r"%?([\w.\-]+):\s*([^,)]+)", line):
                    cur.types[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, rtype, opcode, rest = m.groups()
            cur.ops.append(Op(name, rtype, opcode, rest))
            cur.types[name] = rtype
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Trip count from the loop condition: the s32 constant in a LT compare.

    lax.scan / fori_loop emit `compare(%i, %constant(N)), direction=LT`
    (possibly wrapped in a fusion) with i starting at 0, step 1 -> N trips.
    """
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    consts = {}
    best = None
    for op in comp.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", f"{op.opcode}({op.rest}")
            if m:
                consts[op.name] = int(m.group(1))
        if op.opcode == "fusion":
            called = re.search(r"calls=%?([\w.\-]+)", op.rest)
            if called and called.group(1) in comps:
                inner = _trip_count(comps, called.group(1))
                if inner > 1:
                    best = inner
        if op.opcode == "compare" and "direction=LT" in op.rest:
            for operand in re.findall(r"%([\w.\-]+)", op.rest):
                if operand in consts:
                    best = consts[operand]
    if best is not None and best > 0:
        return best
    # fused compare: the constant lives in the outer region, the compare in
    # the wrapped computation — fall back to the largest s32 constant.
    if consts:
        c = max(consts.values())
        if c > 0:
            return c
    return 1


def _dot_flops(op: Op, types: Dict[str, str]) -> int:
    out_elems = _elems_of(op.result_type)
    operands = re.findall(r"%([\w.\-]+)", op.rest.split(")")[0])
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if m and operands:
        lhs_type = types.get(operands[0], "")
        shapes = _parse_shapes(lhs_type)
        if shapes:
            dims = shapes[0][1]
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(dims):
                    contract *= dims[idx]
    return 2 * out_elems * contract



def _operands(op: Op) -> List[str]:
    """Operand symbol names (everything before the first ')')."""
    return re.findall(r"%([\w.\-]+)", op.rest.split(")")[0])


def _sliced_param_reads(comps: Dict[str, Computation],
                        called: str) -> Dict[int, int]:
    """For a fused computation: parameter index -> effective read bytes,
    for parameters whose ONLY consumers are dynamic-slice ops (the scan
    per-iteration weight fetch pattern) — count the slice, not the stack."""
    comp = comps.get(called)
    if comp is None:
        return {}
    param_syms = {}
    for op in comp.ops:
        if op.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", f"parameter({op.rest}")
            if m:
                param_syms[op.name] = int(m.group(1))
    uses: Dict[str, List[str]] = {}
    slice_out: Dict[str, int] = {}
    for op in comp.ops:
        for operand in _operands(op):
            if operand in param_syms:
                uses.setdefault(operand, []).append(op.opcode)
                if op.opcode == "dynamic-slice":
                    slice_out[operand] = _bytes_of(op.result_type)
    out = {}
    for sym, idx in param_syms.items():
        ops_using = uses.get(sym, [])
        if ops_using and all(o == "dynamic-slice" for o in ops_using):
            out[idx] = slice_out.get(sym, 0) * len(ops_using)
    return out


def _fusion_root_opcode(comps: Dict[str, Computation], called: str) -> str:
    comp = comps.get(called)
    if comp is None or not comp.ops:
        return ""
    return comp.ops[-1].opcode


def _op_traffic(op: Op, comp: Computation, comps: Dict[str, Computation]
                ) -> int:
    """Approximate HBM traffic of one executed op (post-fusion view).

    Aliasing-aware special cases:
      * dynamic-slice reads only the slice, not the sliced array (the scan
        weight-fetch pattern would otherwise count the whole layer stack
        per trip);
      * dynamic-update-slice is in-place: traffic = 2 x update bytes;
      * fusions whose parameters are only dynamic-sliced count the slice,
        and a dynamic-update-slice root counts the update, not the buffer.
    """
    base = op.opcode.removesuffix("-start").removesuffix("-done")
    operand_syms = _operands(op)
    operand_bytes = [_bytes_of(comp.types.get(sym, ""))
                     for sym in operand_syms]
    out_bytes = _bytes_of(op.result_type)

    if base == "dynamic-slice" or base == "gather":
        return 2 * out_bytes
    if base == "dynamic-update-slice":
        upd = min((b for b in operand_bytes if b > 0), default=out_bytes)
        return 2 * upd
    if base == "fusion":
        called = re.search(r"calls=%?([\w.\-]+)", op.rest)
        if not called:
            return 0
        name = called.group(1)
        sliced = _sliced_param_reads(comps, name)
        root = _fusion_root_opcode(comps, name)
        if root == "dynamic-update-slice":
            # in-place cache/buffer write: count the update twice (r+w)
            upd = min((b for b in operand_bytes if b > 0), default=0)
            return 2 * upd + sum(sliced.values())
        if sliced:
            # scan weight-fetch fusions: the slice is real traffic
            return 2 * sum(sliced.values())
        return 0  # pure elementwise fusion: fused away on TPU
    if base in ("dot", "convolution", "reduce", "scatter", "sort") \
            or base in _COLLECTIVES:
        return sum(operand_bytes) + out_bytes
    # Perfect-fusion assumption for the TPU target: elementwise / layout ops
    # (convert, transpose, broadcast, select, copy, ...) fuse into their
    # matmul/reduce producers and consumers, contributing no extra HBM
    # traffic. The CPU HLO leaves them unfused, so counting them would
    # overstate the TPU memory term by orders of magnitude.
    return 0


_SKIP = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "partition-id", "replica-id", "iota"}


def _replica_groups(rest: str):
    """Materialize replica groups from either HLO format:
    explicit ``{{0,1},{2,3}}`` or iota ``[G,S]<=[d0,d1,..]T(p..)``."""
    import numpy as np
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
        rest)
    if m:
        g, s_, dims, perm = m.groups()
        dims = [int(d) for d in dims.split(",")]
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if perm:
            arr = arr.transpose([int(x) for x in perm.split(",")])
        return arr.reshape(int(g), int(s_))
    m = re.search(r"replica_groups=\{(\{[\d,\s]+\}(?:,\s*\{[\d,\s]+\})*)\}",
                  rest)
    if m:
        groups = re.findall(r"\{([\d,\s]+)\}", m.group(1))
        return [[int(x) for x in grp.replace(" ", "").split(",") if x]
                for grp in groups]
    return None


def _spans_pods(rest: str, pod_size: int) -> bool:
    """True if any replica group mixes devices from different pods."""
    groups = _replica_groups(rest)
    if groups is None:
        return True  # unknown grouping: conservatively cross-pod
    for grp in groups:
        pods = {int(dev) // pod_size for dev in grp}
        if len(pods) > 1:
            return True
    return False


def analyze(text: str, pod_size: int | None = None) -> dict:
    """Returns {"flops", "bytes", "collectives", "collective_counts"} —
    ``collectives`` is per-kind bytes, ``collective_counts`` per-kind
    executed-op counts (both trip-count-aware; an async start/done pair
    counts once). Counts are what the topology-plan tests budget: a plan-
    executed gossip step must issue at most ``num_colors``
    collective-permutes and zero all-gathers."""
    comps, entry = parse_module(text)
    flops = 0.0
    bytes_ = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll["cross_pod"] = 0.0
    counts = {k: 0.0 for k in _COLLECTIVES}
    visited_stack = []

    def walk(name: str, mult: float):
        nonlocal flops, bytes_
        comp = comps.get(name)
        if comp is None or name in visited_stack:
            return
        visited_stack.append(name)
        for op in comp.ops:
            base = op.opcode.removesuffix("-start").removesuffix("-done")
            if base in _SKIP:
                continue
            if base == "while":
                cond = re.search(r"condition=%?([\w.\-]+)", op.rest)
                body = re.search(r"body=%?([\w.\-]+)", op.rest)
                trips = _trip_count(comps, cond.group(1)) if cond else 1
                if body:
                    walk(body.group(1), mult * trips)
                continue
            if base in ("fusion", "call", "custom-call", "conditional",
                        "async-start"):
                for called in re.findall(r"calls=%?([\w.\-]+)", op.rest):
                    walk(called, mult)
                for called in re.findall(r"to_apply=%?([\w.\-]+)", op.rest):
                    pass  # reductions: negligible flops
            if base == "dot":
                flops += mult * _dot_flops(op, comp.types)
            elif base in ("fusion",):
                flops += mult * _elems_of(op.result_type)  # ~1 flop/elem
            elif base == "convolution":
                flops += mult * 2 * _elems_of(op.result_type)
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                # an async start/done pair is ONE collective: bytes and
                # counts both attribute to the -start (or the sync op)
                factor = 2 if base == "all-reduce" else 1
                nbytes = mult * factor * _bytes_of(op.result_type)
                coll[base] += nbytes
                counts[base] += mult
                if pod_size and _spans_pods(op.rest, pod_size):
                    coll["cross_pod"] += nbytes
            bytes_ += mult * _op_traffic(op, comp, comps)
        visited_stack.pop()

    if entry:
        walk(entry, 1.0)
    coll["total"] = sum(coll[k] for k in _COLLECTIVES)
    counts["total"] = sum(counts[k] for k in _COLLECTIVES)
    return {"flops": flops, "bytes": bytes_, "collectives": coll,
            "collective_counts": counts}
