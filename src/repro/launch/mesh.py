"""Production meshes for the TPU v5e target.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, while tests and benchmarks see the single real CPU device.
"""
from __future__ import annotations

import jax

from repro.dist.sharding import MeshAxes

# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per direction)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(multi_pod: bool = False) -> MeshAxes:
    return MeshAxes(pod="pod") if multi_pod else MeshAxes()


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the host devices (tests / CPU examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
