"""Edge coloring: decompose a graph's edge set into matchings.

A communication round on an arbitrary graph exchanges state across every
edge. ``lax.ppermute`` executes one *permutation* of the device ring per
call, so the compiler's job is to cover the edge set with as few
permutations as possible. For an undirected graph the natural unit is a
**matching**: a set of vertex-disjoint edges {i, j} lowers to the
involution i <-> j (plus implicit no-sends for unmatched nodes), which is a
valid ppermute permutation delivering both directions of every edge in one
collective.

A proper edge coloring is exactly a partition of the edges into matchings
(edges sharing a vertex get different colors). Vizing's theorem bounds the
optimum by Delta + 1, and ``misra_gries_edge_coloring`` achieves that bound
constructively on any simple graph — the compiler's default pass
(``edge_coloring``) never emits more than Delta + 1 ppermutes per gossip
step. The greedy first-fit pass is retained as the cheap oracle: it is
bounded by 2*Delta - 1 and lands on Delta or Delta + 1 for the regular
graphs the paper sweeps (ring: 2 for even K / 3 for odd, torus: 4), but
genuinely exceeds Delta + 1 on odd complete graphs (K_5 takes 7 colors,
K_9 takes 15) — real extra collectives per gossip step that the Vizing
bound eliminates. ``edge_coloring(method="auto")`` therefore runs greedy
first and falls back to Misra–Gries exactly when greedy lands above the
bound, keeping the historical (often Delta-optimal) matchings on the
paper's regular graphs while capping the dense/irregular ones at Delta + 1.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

Edge = Tuple[int, int]


def undirected_edges(support: np.ndarray) -> List[Edge]:
    """Canonical (i < j) edge list of a support matrix's off-diagonal part.

    ``support`` may be boolean adjacency or a weighted mixing matrix; the
    pattern is symmetrized (W from Metropolis weights is symmetric already,
    but a churn-reweighted or user-supplied matrix with a one-sided entry
    still means "these two nodes exchange").
    """
    s = np.asarray(support)
    nz = (s != 0) | (s != 0).T
    np.fill_diagonal(nz, False)
    ii, jj = np.nonzero(np.triu(nz))
    return [(int(i), int(j)) for i, j in zip(ii, jj)]


def greedy_edge_coloring(edges: Iterable[Edge], num_nodes: int
                         ) -> List[List[Edge]]:
    """First-fit proper edge coloring; returns the list of color classes.

    Edges are visited highest-degree-endpoint first (ties broken by the
    canonical (i, j) order), which keeps the greedy bound tight on the
    irregular graphs (stars, random-geometric) where pure lexicographic
    order can waste colors. Deterministic: same support -> same plan, which
    the compiled-driver cache and the bitwise stop-equivalence tests rely
    on.
    """
    edges = list(edges)
    for i, j in edges:
        if not (0 <= i < num_nodes and 0 <= j < num_nodes) or i == j:
            raise ValueError(f"bad edge ({i}, {j}) for K={num_nodes}")
    deg = np.zeros(num_nodes, dtype=np.int64)
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    order = sorted(edges,
                   key=lambda e: (-max(deg[e[0]], deg[e[1]]),
                                  -min(deg[e[0]], deg[e[1]]), e))
    # node_colors[v] = set of colors already incident to v
    node_colors: List[set] = [set() for _ in range(num_nodes)]
    classes: List[List[Edge]] = []
    for i, j in order:
        used = node_colors[i] | node_colors[j]
        c = 0
        while c in used:
            c += 1
        while c >= len(classes):
            classes.append([])
        classes[c].append((i, j))
        node_colors[i].add(c)
        node_colors[j].add(c)
    return [sorted(cls) for cls in classes]


def misra_gries_edge_coloring(edges: Iterable[Edge], num_nodes: int
                              ) -> List[List[Edge]]:
    """Vizing-optimal edge coloring: at most Delta + 1 color classes.

    The Misra–Gries (1992) constructive proof of Vizing's theorem: each
    uncolored edge (u, v) grows a maximal *fan* of u from v, inverts the
    alternating cd-path through u to make the fan tip's free color d free
    at u too, then rotates a fan prefix and colors its last edge d. Every
    step keeps the coloring proper, and no color index ever exceeds Delta
    (both endpoints of any edge have a free color within the first
    Delta + 1 palette slots). O(E * V) worst case — irrelevant next to the
    jit of the program the colors become.

    Deterministic: edges are processed in canonical sorted order, fans grow
    along sorted adjacency, free colors are always the smallest available —
    same support -> same plan, which the compiled-driver cache and the
    bitwise stop-equivalence tests rely on.
    """
    edges = [(min(i, j), max(i, j)) for i, j in edges]
    for i, j in edges:
        if not (0 <= i < num_nodes and 0 <= j < num_nodes) or i == j:
            raise ValueError(f"bad edge ({i}, {j}) for K={num_nodes}")
    if len(set(edges)) != len(edges):
        raise ValueError("duplicate edges (Misra–Gries needs a simple graph)")

    adj: List[List[int]] = [[] for _ in range(num_nodes)]
    for i, j in edges:
        adj[i].append(j)
        adj[j].append(i)
    for nbrs in adj:
        nbrs.sort()

    # used[v]: color -> the neighbor v reaches over that color (the
    # structure that makes cd-path walking O(path length))
    used: List[Dict[int, int]] = [dict() for _ in range(num_nodes)]
    color_of: Dict[Edge, int] = {}

    def set_color(a: int, b: int, c: int) -> None:
        color_of[(min(a, b), max(a, b))] = c
        used[a][c] = b
        used[b][c] = a

    def unset_color(a: int, b: int) -> int:
        c = color_of.pop((min(a, b), max(a, b)))
        del used[a][c]
        del used[b][c]
        return c

    def free_color(v: int) -> int:
        c = 0
        while c in used[v]:
            c += 1
        return c  # <= deg(v) <= Delta: the palette never exceeds Delta + 1

    for u, v in sorted(edges):
        # maximal fan of u from v: F[j+1] is a colored neighbor whose edge
        # color is free on F[j]
        fan = [v]
        in_fan = {v}
        grew = True
        while grew:
            grew = False
            last = fan[-1]
            for w in adj[u]:
                if w in in_fan:
                    continue
                cw = color_of.get((min(u, w), max(u, w)))
                if cw is not None and cw not in used[last]:
                    fan.append(w)
                    in_fan.add(w)
                    grew = True
                    break
        c = free_color(u)
        d = free_color(fan[-1])
        if c != d:
            # invert the cd-path from u (c is free at u, so it starts with
            # a d edge and alternates); afterwards d is free at u. The path
            # is simple — every vertex has at most one c and one d edge —
            # and cannot revisit u, so the walk terminates.
            path = []
            cur, col = u, d
            while col in used[cur]:
                nxt = used[cur][col]
                path.append((cur, nxt))
                cur, col = nxt, (c if col == d else d)
            # two-phase flip: interior path vertices carry BOTH colors, so
            # recoloring edge-by-edge would transiently alias used[] entries
            flipped = [(a, b, unset_color(a, b)) for a, b in path]
            for a, b, old in flipped:
                set_color(a, b, c if old == d else d)
        # shortest fan prefix that is still a fan post-inversion and whose
        # tip has d free (exists by the Misra–Gries invariant)
        w_idx = None
        for idx in range(len(fan)):
            if idx > 0:
                cj = color_of[(min(u, fan[idx]), max(u, fan[idx]))]
                if cj in used[fan[idx - 1]]:
                    break  # prefixes beyond a broken link are not fans
            if d not in used[fan[idx]]:
                w_idx = idx
                break
        if w_idx is None:  # pragma: no cover - violated algorithm invariant
            raise AssertionError("Misra–Gries: no rotatable fan prefix")
        # rotate: every fan edge takes its successor's color, the tip gets d
        # (unset first, then recolor — all rotated edges share the pivot u)
        shifted = [unset_color(u, fan[j + 1]) for j in range(w_idx)]
        for j in range(w_idx):
            set_color(u, fan[j], shifted[j])
        set_color(u, fan[w_idx], d)

    classes: List[List[Edge]] = [[] for _ in range(
        max(color_of.values(), default=-1) + 1)]
    for e, c in color_of.items():
        classes[c].append(e)
    return [sorted(cls) for cls in classes if cls]


def edge_coloring(edges: Iterable[Edge], num_nodes: int,
                  method: str = "auto") -> List[List[Edge]]:
    """The compiler's coloring pass. ``method``:

    * ``"auto"`` (default) — greedy first-fit, falling back to Misra–Gries
      exactly when greedy exceeds the Vizing bound, so the result NEVER has
      more than Delta + 1 classes while the paper's regular graphs keep
      their historical (often Delta-optimal) greedy matchings;
    * ``"mg"`` — always Misra–Gries (<= Delta + 1);
    * ``"greedy"`` — always first-fit (<= 2*Delta - 1; the oracle the
      property tests pit Misra–Gries against).
    """
    edges = list(edges)
    if method == "greedy":
        return greedy_edge_coloring(edges, num_nodes)
    if method == "mg":
        return misra_gries_edge_coloring(edges, num_nodes)
    if method != "auto":
        raise ValueError(f"unknown coloring method {method!r} "
                         "(want 'auto', 'mg' or 'greedy')")
    classes = greedy_edge_coloring(edges, num_nodes)
    deg = np.zeros(num_nodes, dtype=np.int64)
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    if len(classes) > int(deg.max(initial=0)) + 1:
        classes = misra_gries_edge_coloring(edges, num_nodes)
    return classes


def check_matching(edges: Sequence[Edge], num_nodes: int) -> None:
    """Raise unless ``edges`` are vertex-disjoint (a valid ppermute swap)."""
    seen: set = set()
    for i, j in edges:
        if i in seen or j in seen:
            raise ValueError(f"color class is not a matching at edge ({i},{j})")
        seen.add(i)
        seen.add(j)


def check_coloring(classes: Sequence[Sequence[Edge]], edges: Iterable[Edge],
                   num_nodes: int) -> None:
    """Raise unless ``classes`` is a proper edge coloring of ``edges``:
    every class a matching, and the classes an exact partition of the edge
    set. The validator both compile paths run on their chosen coloring and
    the property tests run on greedy AND Misra–Gries outputs."""
    for cls in classes:
        check_matching(cls, num_nodes)
    flat = sorted((min(i, j), max(i, j)) for cls in classes for i, j in cls)
    want = sorted((min(i, j), max(i, j)) for i, j in edges)
    if flat != want:
        raise ValueError(
            f"color classes do not partition the edge set: colored "
            f"{len(flat)} edge slots vs {len(want)} graph edges")
