"""Greedy edge-coloring: decompose a graph's edge set into matchings.

A communication round on an arbitrary graph exchanges state across every
edge. ``lax.ppermute`` executes one *permutation* of the device ring per
call, so the compiler's job is to cover the edge set with as few
permutations as possible. For an undirected graph the natural unit is a
**matching**: a set of vertex-disjoint edges {i, j} lowers to the
involution i <-> j (plus implicit no-sends for unmatched nodes), which is a
valid ppermute permutation delivering both directions of every edge in one
collective.

A proper edge coloring is exactly a partition of the edges into matchings
(edges sharing a vertex get different colors). Vizing's theorem bounds the
optimum by Delta + 1; the greedy first-fit pass below is guaranteed
<= 2*Delta - 1 colors and in practice lands on Delta or Delta + 1 for the
regular graphs the paper sweeps (ring: 2 for even K / 3 for odd, torus: 4,
complete: K or K - 1). Each color is one ppermute per gossip step, so the
color count IS the round's collective count — worth a deterministic
heuristic, not worth an exact solver.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

import numpy as np

Edge = Tuple[int, int]


def undirected_edges(support: np.ndarray) -> List[Edge]:
    """Canonical (i < j) edge list of a support matrix's off-diagonal part.

    ``support`` may be boolean adjacency or a weighted mixing matrix; the
    pattern is symmetrized (W from Metropolis weights is symmetric already,
    but a churn-reweighted or user-supplied matrix with a one-sided entry
    still means "these two nodes exchange").
    """
    s = np.asarray(support)
    nz = (s != 0) | (s != 0).T
    np.fill_diagonal(nz, False)
    ii, jj = np.nonzero(np.triu(nz))
    return [(int(i), int(j)) for i, j in zip(ii, jj)]


def greedy_edge_coloring(edges: Iterable[Edge], num_nodes: int
                         ) -> List[List[Edge]]:
    """First-fit proper edge coloring; returns the list of color classes.

    Edges are visited highest-degree-endpoint first (ties broken by the
    canonical (i, j) order), which keeps the greedy bound tight on the
    irregular graphs (stars, random-geometric) where pure lexicographic
    order can waste colors. Deterministic: same support -> same plan, which
    the compiled-driver cache and the bitwise stop-equivalence tests rely
    on.
    """
    edges = list(edges)
    for i, j in edges:
        if not (0 <= i < num_nodes and 0 <= j < num_nodes) or i == j:
            raise ValueError(f"bad edge ({i}, {j}) for K={num_nodes}")
    deg = np.zeros(num_nodes, dtype=np.int64)
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    order = sorted(edges,
                   key=lambda e: (-max(deg[e[0]], deg[e[1]]),
                                  -min(deg[e[0]], deg[e[1]]), e))
    # node_colors[v] = set of colors already incident to v
    node_colors: List[set] = [set() for _ in range(num_nodes)]
    classes: List[List[Edge]] = []
    for i, j in order:
        used = node_colors[i] | node_colors[j]
        c = 0
        while c in used:
            c += 1
        while c >= len(classes):
            classes.append([])
        classes[c].append((i, j))
        node_colors[i].add(c)
        node_colors[j].add(c)
    return [sorted(cls) for cls in classes]


def check_matching(edges: Sequence[Edge], num_nodes: int) -> None:
    """Raise unless ``edges`` are vertex-disjoint (a valid ppermute swap)."""
    seen: set = set()
    for i, j in edges:
        if i in seen or j in seen:
            raise ValueError(f"color class is not a matching at edge ({i},{j})")
        seen.add(i)
        seen.add(j)
