"""Lower a ``CommPlan`` / ``BlockPlan`` to shard_map collectives.

These are the bodies ``repro.dist.runtime`` traces inside its shard_map
round/record programs when ``comm="plan"``. Two layouts:

* **one node per device** (``CommPlan``, K == mesh axis size): one
  ``lax.ppermute`` per node-level color, per-node coefficients fed from the
  ``PlanSchedule`` entries (sharded over the node axis, so each device sees
  its own scalars);
* **node blocks** (``BlockPlan``, K/M contiguous nodes per device, M < K):
  one ``lax.ppermute`` of the whole (K/M, d) block payload per BLOCK-level
  color, assembled into a zero-filled (K, d) neighborhood buffer and
  contracted against this device's (K/M, K) W-row slice in one dot
  (``block_mix_step``). Intra-block edges ride the dot as local terms —
  zero communication.

Nothing here gathers a (K, ...) stack collectively — the whole point of
the compiler is that the lowered HLO contains collective-permutes of block-
sized payloads only, which the dist tests assert via ``launch.hlo_analysis``.

Semantics contracts (pinned by the property/parity tests):

* ``plan_mix_step(v_k, ...) == dense_mix(w, v_stack)[k]`` up to float
  summation order (self term first, then colors in order, matching
  ``plan.plan_mix_dense``);
* ``block_mix_step(v_block, ...) == dense_mix(w, v_stack)[block]``
  BITWISE — the buffer dot runs the same length-K contraction as the
  simulator's (K, K) @ (K, d) matmul, with exact zeros where no exchange
  happened (and where W is zero anyway). This is what makes
  ``run_dist_cola(comm="plan")`` on 1/2/4 devices bit-identical to
  ``run_cola``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import mixing, quant
from repro.topo.plan import BlockPlan, CommPlan


def plan_mix_step(v_local, axis_name: str, plan: CommPlan, diag, coefs):
    """One compiled gossip step for THIS device's node state.

    Args:
      v_local: this node's state, any shape (the node index is the position
        along ``axis_name``; one node per device).
      diag: scalar W_kk for this node (the node-sharded ``plan_diag`` slice).
      coefs: (C,) per-color coefficients W[k, partner_c(k)] for this node
        (the node-sharded ``plan_coefs`` slice; 0 where unmatched or where
        churn reweighting dropped the edge this round).
    """
    out = diag * v_local
    for c, perm in enumerate(plan.perms):
        # a matching's swap involution: unmatched devices receive zeros,
        # and their coefficient is 0 by construction — no conditional needed
        recv = lax.ppermute(v_local, axis_name, list(perm))
        out = out + coefs[c] * recv
    return out


def plan_mix_steps(v_local, axis_name: str, plan: CommPlan, diag, coefs,
                   steps: int):
    """B consecutive gossip steps (App. E.2): the sequential form W^B v.

    The dense path folds W first (cheap in K); on the wire the fold does
    not exist — each step exchanges neighbor-only traffic, so B steps cost
    B * num_colors ppermutes, exactly the paper's B-step communication
    model. ``steps`` is a static Python int (unrolled at trace time).
    """
    out = v_local
    for _ in range(steps):
        out = plan_mix_step(out, axis_name, plan, diag, coefs)
    return out


def plan_mix_steps_wire(v_send, v_self, axis_name: str, plan: CommPlan,
                        diag, coefs, steps: int):
    """``plan_mix_steps`` where the FIRST step's payload may be a wire lie
    (``repro.attack``): the node ppermutes ``v_send`` but its own W_kk term
    uses its honest ``v_self`` (pass None for the honest fast path). Later
    steps re-mix received values, which are honest."""
    if v_self is None or steps <= 0:
        return plan_mix_steps(v_send, axis_name, plan, diag, coefs, steps)
    first = plan_mix_step(v_send, axis_name, plan, diag, coefs)
    first = first + diag * (v_self - v_send)
    return plan_mix_steps(first, axis_name, plan, diag, coefs, steps - 1)


def block_gather_neighbors(x_block, axis_name: str, plan: BlockPlan):
    """Assemble the (K, width) node stack this device can SEE: its own
    (K/M, ...) block plus one ppermuted block per block-level color, written
    at the partner block's node rows; blocks of never-exchanged devices stay
    zero. One ppermute per color — the only collectives of the block path
    (no all-gather anywhere), shared by the mixing step and the
    certificate's Eq.-10 neighborhood exchange.
    """
    ln = plan.local_nodes
    flat = x_block.reshape(ln, -1)
    i = lax.axis_index(axis_name)
    partners = jnp.asarray(plan.block.partner_arrays())     # (C, M) static
    buf = jnp.zeros((plan.num_nodes, flat.shape[1]), flat.dtype)
    buf = lax.dynamic_update_slice_in_dim(buf, flat, i * ln, 0)
    for c, perm in enumerate(plan.block.perms):
        recv = lax.ppermute(flat, axis_name, list(perm))
        src = partners[c, i]
        # unmatched devices receive ppermute zero-fill and src == i: write
        # the own block back instead of clobbering it with zeros
        buf = lax.dynamic_update_slice_in_dim(
            buf, jnp.where(src != i, recv, flat), src * ln, 0)
    return buf


def block_mix_step(v_block, axis_name: str, plan: BlockPlan, w_rows):
    """One gossip step for THIS device's (K/M, ...) node block.

    Args:
      v_block: the device's node block, leading dim K/M.
      w_rows: (K/M, K) — this device's rows of the round's W (the
        node-sharded ``plan_w`` slice from ``BlockPlanSchedule``). Entries
        addressing nodes outside the assembled neighborhood are zero by the
        coverage contract, so the dot equals the dense (K, K) mix bitwise.
    """
    flat = v_block.reshape(v_block.shape[0], -1)
    buf = block_gather_neighbors(flat, axis_name, plan)
    out = w_rows.astype(flat.dtype) @ buf
    return out.reshape(v_block.shape)


def block_mix_steps(v_block, axis_name: str, plan: BlockPlan, w_rows,
                    steps: int):
    """B consecutive block-mode gossip steps (App. E.2), sequential on the
    wire like ``plan_mix_steps``: B * num_colors block ppermutes."""
    out = v_block
    for _ in range(steps):
        out = block_mix_step(out, axis_name, plan, w_rows)
    return out


def block_mix_steps_wire(v_send, v_self, axis_name: str, plan: BlockPlan,
                         w_rows, steps: int):
    """``block_mix_steps`` where the FIRST step's payload may be a wire lie
    (``repro.attack``): each node of the block sends ``v_send`` but its own
    W_kk term uses its honest ``v_self`` (pass None for the honest fast
    path). Later steps re-mix received values, which are honest."""
    if v_self is None or steps <= 0:
        return block_mix_steps(v_send, axis_name, plan, w_rows, steps)
    ln = plan.local_nodes
    first = block_mix_step(v_send, axis_name, plan, w_rows)
    row_ids = lax.axis_index(axis_name) * ln + jnp.arange(ln)
    diag = jnp.take_along_axis(w_rows, row_ids[:, None], axis=1)  # (ln, 1)
    delta = (v_self - v_send).reshape(ln, -1)
    first = first + (diag.astype(delta.dtype) * delta).reshape(v_send.shape)
    return block_mix_steps(first, axis_name, plan, w_rows, steps - 1)


# ---------------------------------------------------------------------------
# quantized wire: ppermute int8/fp8 payloads + fp32 scale sidecars
# ---------------------------------------------------------------------------

def ppermute_wire(q, axis_name: str, perm):
    """``lax.ppermute`` of a quantized payload as RAW BYTES.

    Some backends legalize float8 collectives by upcasting the operand to
    f16 — which would silently double the wire bytes the comm contracts
    cap. Bitcasting the payload to uint8 for the permute (and back after)
    keeps every quantized payload 1 byte/elem on every backend; the bit
    pattern — and hence the dequantized value — is untouched.
    """
    if q.dtype.itemsize == 1 and jnp.issubdtype(q.dtype, jnp.floating):
        raw = lax.ppermute(lax.bitcast_convert_type(q, jnp.uint8),
                           axis_name, perm)
        return lax.bitcast_convert_type(raw, q.dtype)
    return lax.ppermute(q, axis_name, perm)


def plan_qmix_steps(v_local, ef_local, axis_name: str, plan: CommPlan,
                    diag, coefs, steps: int, wire: str, round_key,
                    payload=None):
    """B quantized gossip steps for THIS device's node (one node/device).

    Each step the node encodes its value once (EF-compensated when
    ``ef_local`` is not None, stochastic rounding keyed per
    (round, step, node)), ppermutes the narrow payload PLUS its fp32
    absmax scale sidecar on every color, and dequantizes what arrives
    before the coefficient contraction.  The self term uses the node's own
    dequantized payload — the device-count-invariant wire view
    ``quant.wire_view`` defines, so this equals the simulator's
    ``dense_mix(w, deq)`` rows to float summation order (the same
    tolerance contract as the fp32 plan path).

    ``payload``: optional pre-encoded ``(q, scale)`` for the FIRST step —
    the pipelined executor's double buffer, encoded at the end of the
    previous round with this round's key (EF already folded then).
    Returns ``(mixed, ef_new)``.
    """
    i = lax.axis_index(axis_name)
    out, ef = v_local, ef_local
    for s in range(steps):
        flat = out.reshape(-1)
        if s == 0 and payload is not None:
            q, sc = payload
            deq = quant.dequantize(q, sc)
        else:
            k = None if round_key is None else \
                jax.random.fold_in(quant.step_key(round_key, s), i)
            p = flat if ef is None else flat + ef.reshape(-1)
            q, sc = quant.quantize(p, wire, k)
            deq = quant.dequantize(q, sc)
            if ef is not None:
                ef = (p - deq).reshape(ef.shape)
        acc = diag * deq
        for c, perm in enumerate(plan.perms):
            rq = ppermute_wire(q, axis_name, list(perm))
            rs = lax.ppermute(sc, axis_name, list(perm))
            acc = acc + coefs[c] * quant.dequantize(rq, rs)
        out = acc.reshape(out.shape)
    return out, ef


def block_gather_neighbors_q(q, scale, deq, axis_name: str, plan: BlockPlan):
    """Quantized-wire ``block_gather_neighbors``: ppermute the (K/M, d)
    narrow payload + (K/M, 1) scale sidecar per block color and dequantize
    into the zero-filled (K, d) neighborhood buffer.  The device's own
    rows hold its own DEQUANTIZED payload (``deq``) — every contribution,
    local or remote, goes through the same codec, which is what keeps the
    buffer dot bitwise-equal to ``dense_mix`` on the dequantized stack for
    any mesh size."""
    ln = plan.local_nodes
    i = lax.axis_index(axis_name)
    partners = jnp.asarray(plan.block.partner_arrays())     # (C, M) static
    buf = jnp.zeros((plan.num_nodes, deq.shape[1]), deq.dtype)
    buf = lax.dynamic_update_slice_in_dim(buf, deq, i * ln, 0)
    for c, perm in enumerate(plan.block.perms):
        rq = ppermute_wire(q, axis_name, list(perm))
        rs = lax.ppermute(scale, axis_name, list(perm))
        recv = quant.dequantize(rq, rs)
        src = partners[c, i]
        buf = lax.dynamic_update_slice_in_dim(
            buf, jnp.where(src != i, recv, deq), src * ln, 0)
    return buf


def block_qmix_steps(v_block, ef_block, axis_name: str, plan: BlockPlan,
                     w_rows, steps: int, wire: str, round_key,
                     payload=None):
    """B quantized block-mode gossip steps (see ``plan_qmix_steps``).

    Per step: encode this device's (K/M, d) block once (per-node-row
    absmax scales, per-node SR keys from the GLOBAL node ids, EF folded
    when ``ef_block`` is not None), ppermute payload + sidecar per block
    color, dequantize into the neighborhood buffer, contract against the
    W rows in one dot — bitwise the simulator's
    ``dense_mix(w, quant.wire_view(v))`` rows.  Returns
    ``(mixed, ef_new)``.
    """
    ln = plan.local_nodes
    row_ids = lax.axis_index(axis_name) * ln + jnp.arange(ln)
    out, ef = v_block, ef_block
    for s in range(steps):
        flat = out.reshape(ln, -1)
        if s == 0 and payload is not None:
            q, sc = payload
        else:
            k = None if round_key is None else quant.step_key(round_key, s)
            p = flat if ef is None else flat + ef.reshape(ln, -1)
            q, sc = quant.quantize_rows(p, wire, k, node_ids=row_ids)
            if ef is not None:
                ef = (p - quant.dequantize(q, sc)).reshape(ef.shape)
        deq = quant.dequantize(q, sc)
        buf = block_gather_neighbors_q(q, sc, deq, axis_name, plan)
        out = (w_rows.astype(deq.dtype) @ buf).reshape(out.shape)
    return out, ef


def block_robust_qmix_step(v_block, ef_block, axis_name: str,
                           plan: BlockPlan, w_rows, wire: str, round_key,
                           mode: str, *, trim: int = 1,
                           clip: float | None = None):
    """ONE robust gossip step on a QUANTIZED wire — the composed
    ``cfg.robust`` x ``cfg.wire`` lowering for the block plan path.

    Encodes this device's block exactly like ``block_qmix_steps`` (per-node
    absmax rows, SR keys from GLOBAL node ids, EF folded), ppermutes the
    narrow payload + sidecar per block color into the DEQUANTIZED
    neighborhood buffer, then aggregates each node row with
    ``mixing.robust_neighborhood_mix`` instead of the linear dot — so the
    outlier gate judges the same dequantized values the receivers would
    consume, bitwise the simulator's composed branch in
    ``cola._round_body`` (trim/median; clip is allclose, see
    ``block_robust_mix_step``). Single step by construction: the composed
    wire is scoped to ``gossip_steps == 1`` (re-encoding mixed values is
    unmodeled), which ``cola._check_wire_config`` enforces up front.
    Returns ``(mixed, ef_new)``.
    """
    ln = plan.local_nodes
    row_ids = lax.axis_index(axis_name) * ln + jnp.arange(ln)
    flat = v_block.reshape(ln, -1)
    key = None if round_key is None else quant.step_key(round_key, 0)
    p = flat if ef_block is None else flat + ef_block.reshape(ln, -1)
    q, sc = quant.quantize_rows(p, wire, key, node_ids=row_ids)
    deq = quant.dequantize(q, sc)
    ef_new = (None if ef_block is None
              else (p - deq).reshape(ef_block.shape))
    buf = block_gather_neighbors_q(q, sc, deq, axis_name, plan)   # (K, d)
    out = mixing.robust_neighborhood_mix(w_rows, buf, row_ids, mode,
                                         trim=trim, clip=clip,
                                         self_override=None)
    return out.reshape(v_block.shape).astype(v_block.dtype), ef_new


def block_robust_mix_step(v_block, axis_name: str, plan: BlockPlan, w_rows,
                          mode: str, *, trim: int = 1,
                          clip: float | None = None, v_self=None):
    """One ROBUST gossip step for THIS device's (K/M, ...) node block: the
    Byzantine-resilient replacement for ``block_mix_step``'s dot.

    Assembles the same ppermute neighborhood buffer, then aggregates each of
    this device's node rows with ``mixing.robust_neighborhood_mix`` (trimmed
    mean / median / norm clipping) instead of the linear W contraction. The
    robust rule depends only on buffer slots inside each node's W-row
    support — which the coverage contract guarantees were exchanged — so the
    result is BITWISE the simulator's ``mixing.robust_mix_dense`` on every
    mesh size, exactly like the linear block path.

    Bitwise caveat: the guarantee holds for ``mode="trim"`` / ``"median"``
    (selection + the shared weighted einsum). ``mode="clip"`` adds a
    sqrt/divide chain (deviation norms -> tau / norm scale) that XLA fuses
    differently inside the full scanned round program depending on the
    shard shape — a standalone call is bitwise on every mesh, but whole
    attacked runs drift by ~1 ulp (observed 6e-8) on multi-device meshes.
    End-to-end parity for clip is therefore allclose, not bitwise.

    ``v_self`` (same shape as ``v_block``) supplies each node's honest state
    when ``v_block`` is an attacked wire payload: the node's own buffer slot
    is overridden so a liar's lie travels to neighbors but never enters its
    own aggregate (wire-only attack semantics).
    """
    ln = plan.local_nodes
    flat = v_block.reshape(ln, -1)
    buf = block_gather_neighbors(flat, axis_name, plan)          # (K, d)
    row_ids = lax.axis_index(axis_name) * ln + jnp.arange(ln)
    ov = None if v_self is None else v_self.reshape(ln, -1)
    out = mixing.robust_neighborhood_mix(w_rows, buf, row_ids, mode,
                                         trim=trim, clip=clip,
                                         self_override=ov)
    return out.reshape(v_block.shape).astype(v_block.dtype)


def block_robust_mix_steps(v_block, axis_name: str, plan: BlockPlan, w_rows,
                           mode: str, *, trim: int = 1,
                           clip: float | None = None, steps: int = 1,
                           v_self=None):
    """B consecutive robust block-mode gossip steps — sequential on the wire
    (robust aggregation has no W^B fold), matching
    ``mixing.robust_mix_steps`` bitwise. ``v_self`` applies to the first
    step only: after one exchange the circulating values are honest."""
    out = v_block
    for i in range(steps):
        out = block_robust_mix_step(out, axis_name, plan, w_rows, mode,
                                    trim=trim, clip=clip,
                                    v_self=v_self if i == 0 else None)
    return out


def block_neighborhood_stats(g_block, axis_name: str, plan: BlockPlan,
                             mask_rows):
    """(masked neighbor sums, neighborhood sizes) for the Prop.-1
    certificate in block mode: exchange this device's (K/M, d) local
    gradients over the block-level colors and mask-select per node.

    ``mask_rows`` is the device's (K/M, K) slice of the self-inclusive 0/1
    neighborhood mask (static graph, or the churn round's reweighted-support
    rows from the certificate schedule). Masked-out buffer rows are exact
    zeros, so the result equals the stacked ``duality.neighborhood_mean``
    numerator/denominator bitwise. O(num_colors * (K/M) * d) bytes per
    device; no stack gathers.
    """
    mask_rows = jnp.asarray(mask_rows)
    buf = block_gather_neighbors(g_block, axis_name, plan)   # (K, d)
    sel = jnp.where(mask_rows[:, :, None] > 0, buf[None, :, :], 0.0)
    return jnp.sum(sel, axis=1), jnp.sum(mask_rows, axis=1)  # (ln, d), (ln,)


def plan_neighborhood_stats(g_local, axis_name: str, plan: CommPlan,
                            mask_row):
    """(masked neighbor sum, neighborhood size) for the Prop.-1 certificate.

    Exchanges THIS device's (d,)-vector ``g_local`` (the local gradient)
    over the plan's permutations and mask-selects what arrives:
    ``mask_row`` is this node's row of the self-inclusive 0/1 neighborhood
    mask — the static graph's row, or the churn round's reweighted-support
    row from the certificate schedule, in which case dropped neighbors
    contribute 0 exactly as the stacked ``duality.neighborhood_mean``
    oracle excludes them. O(num_colors * d) bytes per device; no stack
    gathers.
    """
    mask_row = jnp.asarray(mask_row)
    i = lax.axis_index(axis_name)
    partners = jnp.asarray(plan.partner_arrays())          # (C, K) static
    nsum = mask_row[i] * g_local                            # self (mask=1)
    for c, perm in enumerate(plan.perms):
        recv = lax.ppermute(g_local, axis_name, list(perm))
        nsum = nsum + mask_row[partners[c, i]] * recv
    return nsum, jnp.sum(mask_row)


def comm_budget(plan, d: int, itemsize: int = 4, *,
                gossip_steps: int = 1, wire: str | None = None) -> dict:
    """The collective budget this module's lowerings emit for ``plan``.

    ``plan_mix_steps`` / ``block_mix_steps`` (and their wire/robust
    variants) issue exactly ``num_colors`` ``lax.ppermute`` ops per gossip
    step — one per color class — each carrying a (d,) vector (per-node
    plan) or a (K/M, d) block payload. On a quantized wire
    (``plan_qmix_steps`` / ``block_qmix_steps``) each color ppermutes TWO
    tensors — the narrow payload and its fp32 scale sidecar — so the count
    doubles while the bytes drop ~4x. This is the single source of truth
    behind ``CommPlan.contract`` / ``BlockPlan.contract``: the budget is a
    property of HOW the plan lowers, so it lives next to the lowerings.
    """
    from repro.topo.plan import _permutes_per_step
    return {
        "collective_permutes":
            gossip_steps * _permutes_per_step(plan.num_colors, wire),
        "bytes_per_device":
            gossip_steps * plan.bytes_per_device_per_step(d, itemsize,
                                                          wire=wire),
    }
