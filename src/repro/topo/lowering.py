"""Lower a ``CommPlan`` to shard_map collectives.

These are the bodies ``repro.dist.runtime`` traces inside its shard_map
round/record programs when ``comm="plan"``: one ``lax.ppermute`` per color,
per-node coefficients fed from the ``PlanSchedule`` entries (sharded over
the node axis, so each device sees its own scalars). Nothing here gathers a
(K, ...) stack — the whole point of the compiler is that the lowered HLO
contains collective-permutes of |v|-sized payloads only, which the dist
tests assert via ``launch.hlo_analysis``.

Semantics contract (pinned by the property tests against
``plan.plan_mix_dense`` and ``mixing.dense_mix``): with ``diag``/``coefs``
from ``plan.plan_coefficients(plan, w)``,

    plan_mix_step(v_k, ...) == dense_mix(w, v_stack)[k]

up to float summation order (self term first, then colors in order — the
same order as the dense reference, so shard vs stacked agree bitwise on
matching backends).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.topo.plan import CommPlan


def plan_mix_step(v_local, axis_name: str, plan: CommPlan, diag, coefs):
    """One compiled gossip step for THIS device's node state.

    Args:
      v_local: this node's state, any shape (the node index is the position
        along ``axis_name``; one node per device).
      diag: scalar W_kk for this node (the node-sharded ``plan_diag`` slice).
      coefs: (C,) per-color coefficients W[k, partner_c(k)] for this node
        (the node-sharded ``plan_coefs`` slice; 0 where unmatched or where
        churn reweighting dropped the edge this round).
    """
    out = diag * v_local
    for c, perm in enumerate(plan.perms):
        # a matching's swap involution: unmatched devices receive zeros,
        # and their coefficient is 0 by construction — no conditional needed
        recv = lax.ppermute(v_local, axis_name, list(perm))
        out = out + coefs[c] * recv
    return out


def plan_mix_steps(v_local, axis_name: str, plan: CommPlan, diag, coefs,
                   steps: int):
    """B consecutive gossip steps (App. E.2): the sequential form W^B v.

    The dense path folds W first (cheap in K); on the wire the fold does
    not exist — each step exchanges neighbor-only traffic, so B steps cost
    B * num_colors ppermutes, exactly the paper's B-step communication
    model. ``steps`` is a static Python int (unrolled at trace time).
    """
    out = v_local
    for _ in range(steps):
        out = plan_mix_step(out, axis_name, plan, diag, coefs)
    return out


def plan_neighborhood_stats(g_local, axis_name: str, plan: CommPlan,
                            mask_row):
    """(masked neighbor sum, neighborhood size) for the Prop.-1 certificate.

    Exchanges THIS device's (d,)-vector ``g_local`` (the local gradient)
    over the plan's permutations and mask-selects what arrives:
    ``mask_row`` is this node's row of the self-inclusive 0/1 neighborhood
    mask — the static graph's row, or the churn round's reweighted-support
    row from the certificate schedule, in which case dropped neighbors
    contribute 0 exactly as the stacked ``duality.neighborhood_mean``
    oracle excludes them. O(num_colors * d) bytes per device; no stack
    gathers.
    """
    mask_row = jnp.asarray(mask_row)
    i = lax.axis_index(axis_name)
    partners = jnp.asarray(plan.partner_arrays())          # (C, K) static
    nsum = mask_row[i] * g_local                            # self (mask=1)
    for c, perm in enumerate(plan.perms):
        recv = lax.ppermute(g_local, axis_name, list(perm))
        nsum = nsum + mask_row[partners[c, i]] * recv
    return nsum, jnp.sum(mask_row)
