"""Graph builders beyond the paper's sweep — expanders, geometric graphs.

``core.topology`` ships the Fig.-3 family (ring / c-connected cycle / grid /
torus / complete / star); the plan compiler makes *any* sparse graph
executable at O(deg * d) communication, so this module adds the families
the decentralized-FL literature actually runs on (DeceFL, Bellet et al.):
random regular expanders (constant degree, near-optimal spectral gap) and
random geometric graphs (the classic P2P/sensor model with hubs and long
tails). ``GRAPHS`` is the unified name -> builder registry the fig-3
topology sweep and ``dryrun --plan`` resolve against.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from repro.core import topology as topo


def is_connected(adj: np.ndarray) -> bool:
    """BFS connectivity of a boolean adjacency matrix."""
    k = adj.shape[0]
    if k == 0:
        return True
    seen = np.zeros(k, dtype=bool)
    frontier = [0]
    seen[0] = True
    while frontier:
        nxt = adj[frontier].any(axis=0) & ~seen
        frontier = list(np.nonzero(nxt)[0])
        seen |= nxt
    return bool(seen.all())


def expander(k: int, degree: int = 4, seed: int = 0,
             max_tries: int = 200) -> topo.Topology:
    """Random regular-ish expander: superpose ``degree // 2`` random
    Hamiltonian cycles (+ a random perfect matching for odd degree, even K).

    Cycle superposition is the standard cheap construction whose spectral
    gap concentrates near the Ramanujan bound — the "good" end of the
    paper's beta sweep at constant degree. Deterministic in ``seed``;
    retries until the graph is connected AND every node reaches the full
    target degree (superposed cycles sharing an edge would silently
    collapse below it on small K).
    """
    if degree < 2 or degree >= k:
        raise ValueError(f"need 2 <= degree < k, got degree={degree}, k={k}")
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        adj = np.zeros((k, k), dtype=bool)
        for _ in range(degree // 2):
            order = rng.permutation(k)
            for a, b in zip(order, np.roll(order, -1)):
                adj[a, b] = adj[b, a] = True
        if degree % 2:
            if k % 2:
                raise ValueError("odd degree expander needs even k")
            order = rng.permutation(k)
            for a, b in order.reshape(-1, 2):
                adj[a, b] = adj[b, a] = True
        np.fill_diagonal(adj, False)
        if is_connected(adj) and adj.sum(axis=1).min() >= degree:
            return topo.Topology(f"expander-d{degree}", adj)
    raise RuntimeError(f"no connected expander found for k={k}, "
                       f"degree={degree} in {max_tries} tries")


def random_geometric(k: int, radius: float | None = None,
                     seed: int = 0) -> topo.Topology:
    """Random geometric graph: K points in the unit square, edges within
    ``radius``. ``radius=None`` starts at the connectivity threshold
    ``sqrt(2 ln k / k)`` and grows until connected — the irregular,
    hub-and-leaf end of the topology sweep (degrees vary, so the greedy
    coloring and the Metropolis weights both get exercised off the regular
    path). Deterministic in ``seed``."""
    if k < 2:
        raise ValueError(f"need k >= 2, got {k}")
    rng = np.random.default_rng(seed)
    pts = rng.random((k, 2))
    d2 = np.sum((pts[:, None, :] - pts[None, :, :]) ** 2, axis=-1)
    r = radius if radius is not None else float(
        np.sqrt(2.0 * np.log(max(k, 2)) / k))
    while True:
        adj = d2 <= r * r
        np.fill_diagonal(adj, False)
        if is_connected(adj):
            return topo.Topology(f"rgg-r{r:.2f}", adj)
        if radius is not None:
            raise ValueError(
                f"random_geometric(k={k}, radius={radius}, seed={seed}) is "
                "disconnected — grow the radius or pass radius=None")
        r *= 1.25


def hypercube(k: int) -> topo.Topology:
    """Boolean hypercube on K = 2^m nodes (degree log2 K, diameter log2 K)."""
    m = k.bit_length() - 1
    if k <= 0 or (1 << m) != k:
        raise ValueError(f"hypercube needs a power-of-two k, got {k}")
    adj = np.zeros((k, k), dtype=bool)
    for i in range(k):
        for b in range(m):
            j = i ^ (1 << b)
            adj[i, j] = adj[j, i] = True
    return topo.Topology(f"hypercube-{m}", adj)


# unified registry: the paper's Fig.-3 family plus the new builders, all
# resolvable by name (fig3_topology sweep, dryrun --plan --topo)
GRAPHS: Dict[str, Callable[[int], topo.Topology]] = dict(topo.TOPOLOGIES)
GRAPHS.update({
    "torus2d": lambda k: topo.torus_2d(*topo._square_factors(k)),
    "expander": lambda k: expander(k, degree=4, seed=0),
    "rgg": lambda k: random_geometric(k, seed=0),
    "hypercube": hypercube,
})


def build(name: str, k: int) -> topo.Topology:
    """Resolve a topology by registry name."""
    if name not in GRAPHS:
        raise ValueError(f"unknown topology {name!r} "
                         f"(want one of {sorted(GRAPHS)})")
    return GRAPHS[name](k)
