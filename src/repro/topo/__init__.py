"""``repro.topo`` — the topology-program compiler.

Treats a communication round on an arbitrary (possibly time-varying,
churn-reweighted) sparse doubly-stochastic W as a compiled program:

  support graph --edge-color--> matchings --lower--> ppermute perms
                                                  + per-round coefficients

``compile_plan`` builds the static ``CommPlan`` (permutation structure),
``PlanSchedule`` materializes per-round weights into executor schedule
arrays, ``lowering`` provides the shard_map bodies ``repro.dist.runtime``
executes under ``comm="plan"``, and ``graphs.GRAPHS`` registers the
topology families (paper sweep + expanders/geometric graphs) by name.
"""
from repro.topo.coloring import greedy_edge_coloring, undirected_edges
from repro.topo.graphs import GRAPHS, build, expander, hypercube, \
    random_geometric
from repro.topo.lowering import plan_mix_step, plan_mix_steps, \
    plan_neighborhood_stats
from repro.topo.plan import (CommPlan, PlanSchedule, check_plan_covers,
                             compile_plan, mix_with_plan, plan_coefficients,
                             plan_mix_dense)

__all__ = [
    "CommPlan", "PlanSchedule", "GRAPHS", "build", "check_plan_covers",
    "compile_plan", "expander", "greedy_edge_coloring", "hypercube",
    "mix_with_plan", "plan_coefficients", "plan_mix_dense", "plan_mix_step",
    "plan_mix_steps", "plan_neighborhood_stats", "random_geometric",
    "undirected_edges",
]
