"""``repro.topo`` — the topology-program compiler.

Treats a communication round on an arbitrary (possibly time-varying,
churn-reweighted) sparse doubly-stochastic W as a compiled program:

  support graph --edge-color--> matchings --lower--> ppermute perms
                                                  + per-round coefficients

``compile_plan`` builds the static ``CommPlan`` (permutation structure, one
node per device; Vizing-bounded Misra–Gries/greedy coloring via
``coloring.edge_coloring``), ``compile_block_plan`` lowers a K-node graph
onto M < K devices (``BlockPlan``: intra-block edges become local mixing,
inter-block edges quotient to a device-level graph colored into block-
payload matchings), ``PlanSchedule`` / ``BlockPlanSchedule`` materialize
per-round weights into executor schedule arrays, ``lowering`` provides the
shard_map bodies ``repro.dist.runtime`` executes under ``comm="plan"``, and
``graphs.GRAPHS`` registers the topology families (paper sweep +
expanders/geometric graphs) by name.
"""
from repro.topo.coloring import (check_coloring, edge_coloring,
                                 greedy_edge_coloring,
                                 misra_gries_edge_coloring, undirected_edges)
from repro.topo.graphs import GRAPHS, build, expander, hypercube, \
    random_geometric
from repro.topo.lowering import (block_gather_neighbors, block_mix_step,
                                 block_mix_steps, block_neighborhood_stats,
                                 plan_mix_step, plan_mix_steps,
                                 plan_neighborhood_stats)
from repro.topo.plan import (BlockPlan, BlockPlanSchedule, CommPlan,
                             PlanSchedule, block_mix_dense, check_plan_covers,
                             compile_block_plan, compile_plan,
                             mix_with_block_plan, mix_with_plan,
                             plan_coefficients, plan_mix_dense,
                             w_from_coefficients, w_from_coefficients_device)

__all__ = [
    "BlockPlan", "BlockPlanSchedule", "CommPlan", "PlanSchedule", "GRAPHS",
    "block_gather_neighbors", "block_mix_dense", "block_mix_step",
    "block_mix_steps", "block_neighborhood_stats", "build", "check_coloring",
    "check_plan_covers", "compile_block_plan", "compile_plan",
    "edge_coloring", "expander", "greedy_edge_coloring", "hypercube",
    "misra_gries_edge_coloring", "mix_with_block_plan", "mix_with_plan",
    "plan_coefficients", "plan_mix_dense", "plan_mix_step", "plan_mix_steps",
    "plan_neighborhood_stats", "random_geometric", "undirected_edges",
    "w_from_coefficients", "w_from_coefficients_device",
]
