"""Topology-program IR: compiled communication plans for arbitrary sparse W.

``compile_plan`` turns the support graph of any (possibly time-varying)
doubly-stochastic mixing matrix into a ``CommPlan``: a greedy edge coloring
of the support into matchings, each matching lowered to one ``lax.ppermute``
permutation (both directions of every edge in one collective). One gossip
step then executes as

    v'_k = W_kk * v_k + sum_c  W[k, partner_c(k)] * recv_c(k)

where ``recv_c`` is the color-c ppermute and the per-node coefficient is
read off the round's W — so a *static* plan (permutations fixed at compile
time) executes *any* reweighting of the support, including churn rounds
where dropped edges simply carry coefficient zero (the ppermute still runs;
the zero multiply discards the payload, and XLA's collective cost is
unchanged). That is what lets the round-block executor keep a single
compiled program across a time-varying graph: the permutations are program
structure, the weights are data.

``PlanSchedule`` materializes the per-round (diag, coefs) pairs into the
executor's stacked ``(T, ...)`` schedule arrays, exactly like the churn
masks; ``plan_mix_dense`` is the mesh-free reference executor used as the
oracle against ``mixing.dense_mix`` in the property tests; the byte
accounting below is what ``launch.dryrun --plan`` renders and what the HLO
assertions in the dist tests budget against.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core import topology as topo
from repro.topo import coloring

Edge = coloring.Edge


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """A compiled topology program: matchings lowered to ppermute perms.

    Everything here is static host data baked into the compiled round
    program — per-round weights live in ``PlanSchedule``, not here.

    Attributes:
      num_nodes: K.
      colors: per color class, the tuple of undirected edges (i < j).
      perms: per color, the ``lax.ppermute`` (src, dst) pairs — both
        directions of each edge (a matching's swap involution is a valid
        permutation; unmatched nodes send nothing and receive zeros).
      partners: per color, a K-tuple p with p[k] = k's exchange partner in
        that color, or k itself when unmatched (its received payload is
        the ppermute zero-fill and its coefficient is forced to 0).
    """

    num_nodes: int
    colors: Tuple[Tuple[Edge, ...], ...]
    perms: Tuple[Tuple[Tuple[int, int], ...], ...]
    partners: Tuple[Tuple[int, ...], ...]

    @property
    def num_colors(self) -> int:
        return len(self.colors)

    @property
    def num_edges(self) -> int:
        return sum(len(c) for c in self.colors)

    def support(self) -> np.ndarray:
        """(K, K) bool: the off-diagonal exchange pattern this plan covers."""
        s = np.zeros((self.num_nodes, self.num_nodes), dtype=bool)
        for cls in self.colors:
            for i, j in cls:
                s[i, j] = s[j, i] = True
        return s

    def partner_arrays(self) -> np.ndarray:
        """(C, K) int32 partner table (self-index where unmatched)."""
        return np.asarray(self.partners, dtype=np.int32).reshape(
            self.num_colors, self.num_nodes)

    def max_degree(self) -> int:
        return int(self.support().sum(axis=1).max(initial=0))

    def cache_token(self):
        """Hashable identity for compiled-driver cache keys: the program
        structure is exactly the permutations."""
        return ("CommPlan", self.num_nodes, self.colors)

    # -- byte accounting (dryrun --plan, HLO budget assertions) -------------

    def bytes_per_device_per_step(self, d: int, itemsize: int = 4) -> int:
        """Worst-case per-device ppermute payload of ONE gossip step: one
        (d,)-vector sent per color the node is matched in (<= num_colors)."""
        return self.num_colors * d * itemsize

    def bytes_per_link_per_step(self, d: int, itemsize: int = 4) -> int:
        """Bytes crossing one graph edge (both directions) per gossip step."""
        return 2 * d * itemsize

    def total_bytes_per_step(self, d: int, itemsize: int = 4) -> int:
        """Network-wide bytes of one gossip step: every edge, both ways."""
        return self.num_edges * self.bytes_per_link_per_step(d, itemsize)

    def render(self, d: int | None = None, itemsize: int = 4,
               max_edges: int = 8) -> str:
        """Human-readable plan (the ``dryrun --plan`` section)."""
        lines = [f"[comm plan] K={self.num_nodes} edges={self.num_edges} "
                 f"colors={self.num_colors} max_degree={self.max_degree()}"]
        for c, cls in enumerate(self.colors):
            shown = ", ".join(f"{i}<->{j}" for i, j in cls[:max_edges])
            more = f", ... +{len(cls) - max_edges}" if len(cls) > max_edges \
                else ""
            lines.append(f"  color {c}: {len(cls)} edge(s)  {shown}{more}")
        if d is not None:
            lines.append(
                f"  bytes/round (1 gossip step, d={d}, itemsize={itemsize}): "
                f"per-device<={self.bytes_per_device_per_step(d, itemsize):,} "
                f"per-link={self.bytes_per_link_per_step(d, itemsize):,} "
                f"total={self.total_bytes_per_step(d, itemsize):,}  "
                f"(dense all-gather per-device="
                f"{self.num_nodes * d * itemsize:,})")
        return "\n".join(lines)


def compile_plan(support) -> CommPlan:
    """Compile a support graph into a ``CommPlan``.

    Args:
      support: a ``core.topology.Topology``, or any (K, K) matrix whose
        off-diagonal nonzero pattern is the exchange graph (a mixing matrix
        works as-is; the diagonal is ignored — self-weights never move
        bytes).
    """
    if isinstance(support, topo.Topology):
        adj = support.adjacency
    else:
        adj = np.asarray(support)
    k = adj.shape[0]
    if adj.shape != (k, k):
        raise ValueError(f"support must be square, got {adj.shape}")
    edges = coloring.undirected_edges(adj)
    classes = coloring.greedy_edge_coloring(edges, k)
    perms, partners = [], []
    for cls in classes:
        coloring.check_matching(cls, k)
        perm = []
        partner = list(range(k))
        for i, j in cls:
            perm.append((i, j))
            perm.append((j, i))
            partner[i], partner[j] = j, i
        perms.append(tuple(sorted(perm)))
        partners.append(tuple(partner))
    return CommPlan(num_nodes=k,
                    colors=tuple(tuple(cls) for cls in classes),
                    perms=tuple(perms), partners=tuple(partners))


def check_plan_covers(plan: CommPlan, w: np.ndarray,
                      atol: float = 0.0) -> None:
    """Raise ValueError if ``w`` has off-diagonal mass outside the plan.

    The generalization of ``mixing.check_circulant_band``: plan execution
    reproduces ``dense_mix(w, .)`` exactly iff every nonzero off-diagonal
    W_ij rides some color's permutation. Churn-reweighted matrices over the
    compiled graph always pass (reweighting only *removes* edges); a
    w_override with extra edges must recompile.
    """
    w = np.asarray(w)
    if w.shape != (plan.num_nodes, plan.num_nodes):
        raise ValueError(f"W shape {w.shape} does not match the plan's "
                         f"K={plan.num_nodes}")
    off = np.abs(w.copy())
    np.fill_diagonal(off, 0.0)
    uncovered = off * ~plan.support()
    if uncovered.max(initial=0.0) > atol:
        i, j = np.unravel_index(np.argmax(uncovered), uncovered.shape)
        raise ValueError(
            f"W[{i},{j}]={w[i, j]:.3g} lies outside the compiled plan's "
            f"support — plan execution would drop that weight mass; "
            "recompile the plan from this W's support (or use the dense "
            "mixing path)")


def plan_coefficients(plan: CommPlan, w, *, check: bool = True
                      ) -> tuple[np.ndarray, np.ndarray]:
    """(diag (K,), coefs (C, K)) for one round's mixing matrix ``w``.

    ``diag[k] = W_kk``; ``coefs[c, k] = W[k, partner_c(k)]`` (0 where
    unmatched). Together with the plan's permutations these reproduce
    ``dense_mix(w, v)``: every off-diagonal entry appears in exactly one
    color, the diagonal in the local term.
    """
    w = np.asarray(w)
    if check:
        check_plan_covers(plan, w)
    k = plan.num_nodes
    diag = np.ascontiguousarray(np.diag(w))
    coefs = np.zeros((plan.num_colors, k), dtype=w.dtype)
    rows = np.arange(k)
    for c, partner in enumerate(plan.partner_arrays()):
        matched = partner != rows
        coefs[c, matched] = w[rows[matched], partner[matched]]
    return diag, coefs


@dataclasses.dataclass(frozen=True)
class PlanSchedule:
    """Per-round plan coefficients, materialized like the churn masks.

    ``diag`` (T, K) and ``coefs`` (T, C, K) are stacked schedule arrays the
    round-block executor slices per block; a static (round-invariant) W
    yields broadcast views, O(C*K) host memory regardless of T.
    """

    diag: np.ndarray   # (T, K)
    coefs: np.ndarray  # (T, C, K)

    @classmethod
    def from_w_stack(cls, plan: CommPlan, w_stack, *,
                     static: bool = False) -> "PlanSchedule":
        """Compile every round's coefficients (validating coverage per
        round). ``static=True`` asserts the stack is round-invariant and
        stores broadcast views instead of T copies."""
        w_stack = np.asarray(w_stack)
        t = w_stack.shape[0]
        if static or t == 0:
            w0 = w_stack[0] if t else np.eye(plan.num_nodes)
            if t and not (w_stack == w0).all():
                raise ValueError(
                    "PlanSchedule.from_w_stack(static=True) requires a "
                    "round-invariant W stack — this one varies; drop "
                    "static= to materialize per-round coefficients")
            diag0, coefs0 = plan_coefficients(plan, w0)
            return cls(
                diag=np.broadcast_to(diag0.astype(w_stack.dtype),
                                     (t,) + diag0.shape),
                coefs=np.broadcast_to(coefs0.astype(w_stack.dtype),
                                      (t,) + coefs0.shape))
        diag = np.empty((t, plan.num_nodes), dtype=w_stack.dtype)
        coefs = np.empty((t, plan.num_colors, plan.num_nodes),
                         dtype=w_stack.dtype)
        for t_i in range(t):
            diag[t_i], coefs[t_i] = plan_coefficients(plan, w_stack[t_i])
        return cls(diag=diag, coefs=coefs)

    def entries(self) -> dict:
        """The executor schedule entries the dist runtime splices in."""
        return {"plan_diag": self.diag, "plan_coefs": self.coefs}


def plan_mix_dense(plan: CommPlan, diag, coefs, v_stack):
    """Mesh-free reference executor: apply one plan-compiled gossip step to
    stacked (K, ...) state with jnp gathers standing in for the ppermutes.

    This is the oracle the property tests pin against ``mixing.dense_mix``
    (equal to float tolerance — the color-by-color summation order differs
    from the matmul's) and the program the shard_map lowering
    (``repro.topo.lowering.plan_mix_step``) must match shard-for-shard.
    """
    import jax.numpy as jnp

    v_stack = jnp.asarray(v_stack)
    flat = v_stack.reshape(v_stack.shape[0], -1)
    diag = jnp.asarray(diag, dtype=flat.dtype)
    coefs = jnp.asarray(coefs, dtype=flat.dtype)
    out = diag[:, None] * flat
    for c, partner in enumerate(plan.partner_arrays()):
        out = out + coefs[c][:, None] * flat[partner]
    return out.reshape(v_stack.shape)


def mix_with_plan(plan: CommPlan, w, v_stack):
    """Convenience: one gossip step of ``w`` through the compiled plan."""
    diag, coefs = plan_coefficients(plan, w)
    return plan_mix_dense(plan, diag, coefs, v_stack)
