"""Topology-program IR: compiled communication plans for arbitrary sparse W.

``compile_plan`` turns the support graph of any (possibly time-varying)
doubly-stochastic mixing matrix into a ``CommPlan``: a greedy edge coloring
of the support into matchings, each matching lowered to one ``lax.ppermute``
permutation (both directions of every edge in one collective). One gossip
step then executes as

    v'_k = W_kk * v_k + sum_c  W[k, partner_c(k)] * recv_c(k)

where ``recv_c`` is the color-c ppermute and the per-node coefficient is
read off the round's W — so a *static* plan (permutations fixed at compile
time) executes *any* reweighting of the support, including churn rounds
where dropped edges simply carry coefficient zero (the ppermute still runs;
the zero multiply discards the payload, and XLA's collective cost is
unchanged). That is what lets the round-block executor keep a single
compiled program across a time-varying graph: the permutations are program
structure, the weights are data.

``PlanSchedule`` materializes the per-round (diag, coefs) pairs into the
executor's stacked ``(T, ...)`` schedule arrays, exactly like the churn
masks; ``plan_mix_dense`` is the mesh-free reference executor used as the
oracle against ``mixing.dense_mix`` in the property tests; the byte
accounting below is what ``launch.dryrun --plan`` renders and what the HLO
assertions in the dist tests budget against.

**Block mode** (``compile_block_plan``): a graph over K paper-nodes also
lowers onto M < K devices (K/M contiguous nodes per device, the runtime's
node-block layout). The node graph is quotiented by the block assignment:
intra-block edges become local (zero-communication) mixing terms, and the
inter-block edges project to a *block-level* multigraph over the M devices
whose parallel edges collapse — one exchange of the whole (K/M, d) block
payload serves every node-pair between two devices. That collapsed device
graph is edge-colored (Misra–Gries, <= Delta_block + 1) so each color is a
matching between devices lowering to one ``lax.ppermute`` of the block
payload. Per-node coefficients are each device's (K/M, K) row slice of the
round's W (``BlockPlanSchedule``), applied as one masked-neighborhood dot
— which is what makes block execution bitwise-equal to the simulator's
dense (K, K) @ (K, d) mix (same contraction, zeros where no exchange
happened and W is zero anyway). The colors-per-step count drops from
O(Delta_node) to O(Delta_block) <= M, the scale lever that runs paper
K=32 sweeps on a 4-device CI mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core import topology as topo
from repro.topo import coloring

Edge = coloring.Edge


def _payload_bytes(d: int, itemsize: int, wire: str | None,
                   rows: int = 1) -> int:
    """Wire bytes of one ``rows x d`` ppermute payload.

    With ``wire=None`` this is the legacy fp32 accounting (``rows * d *
    itemsize``).  Naming a wire derives the REAL itemsize from the codec
    (1 byte for int8/fp8) and adds the fp32 absmax scale sidecar (one
    scale per node row) — the single source the rendered bytes,
    ``.contract()`` caps and ``comm_budget`` all share, so they cannot
    disagree with each other or with the quantized wire.
    """
    if wire is None:
        return rows * d * itemsize
    from repro.core import quant
    return quant.payload_bytes(d, wire, rows)


def _permutes_per_step(num_colors: int, wire: str | None) -> int:
    """Collective-permutes one gossip step issues: one per color on the
    fp32 wire, two per color on a quantized wire (the int8/fp8 payload and
    its fp32 scale sidecar ppermute as separate collectives)."""
    if wire is None:
        return num_colors
    from repro.core import quant
    return num_colors * (2 if quant.is_quantized(wire) else 1)


def _render_wire_line(plan, d: int, itemsize: int,
                      wire: str | None) -> list:
    """The quantized-wire bytes line ``render`` appends next to the fp32
    figure (empty on fp32/None wires)."""
    from repro.core import quant
    if wire is None or not quant.is_quantized(wire):
        return []
    dev = plan.bytes_per_device_per_step(d, wire=wire)
    dev32 = plan.bytes_per_device_per_step(d, itemsize)
    return [
        f"  wire={wire} (payload {quant.wire_itemsize(wire)} B/elem + "
        f"{quant.SCALE_BYTES} B scale/row): "
        f"per-device<={dev:,} "
        f"per-link={plan.bytes_per_link_per_step(d, wire=wire):,} "
        f"total={plan.total_bytes_per_step(d, wire=wire):,}  "
        f"({dev / dev32:.2f}x fp32)"]


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """A compiled topology program: matchings lowered to ppermute perms.

    Everything here is static host data baked into the compiled round
    program — per-round weights live in ``PlanSchedule``, not here.

    Attributes:
      num_nodes: K.
      colors: per color class, the tuple of undirected edges (i < j).
      perms: per color, the ``lax.ppermute`` (src, dst) pairs — both
        directions of each edge (a matching's swap involution is a valid
        permutation; unmatched nodes send nothing and receive zeros).
      partners: per color, a K-tuple p with p[k] = k's exchange partner in
        that color, or k itself when unmatched (its received payload is
        the ppermute zero-fill and its coefficient is forced to 0).
    """

    num_nodes: int
    colors: Tuple[Tuple[Edge, ...], ...]
    perms: Tuple[Tuple[Tuple[int, int], ...], ...]
    partners: Tuple[Tuple[int, ...], ...]

    @property
    def num_colors(self) -> int:
        return len(self.colors)

    @property
    def num_edges(self) -> int:
        return sum(len(c) for c in self.colors)

    def support(self) -> np.ndarray:
        """(K, K) bool: the off-diagonal exchange pattern this plan covers."""
        s = np.zeros((self.num_nodes, self.num_nodes), dtype=bool)
        for cls in self.colors:
            for i, j in cls:
                s[i, j] = s[j, i] = True
        return s

    def coverage(self) -> np.ndarray:
        """(K, K) bool: the off-diagonal W entries this plan can EXECUTE —
        for a per-node plan, exactly its support (every weight needs a
        permutation to ride)."""
        return self.support()

    def partner_arrays(self) -> np.ndarray:
        """(C, K) int32 partner table (self-index where unmatched)."""
        return np.asarray(self.partners, dtype=np.int32).reshape(
            self.num_colors, self.num_nodes)

    def max_degree(self) -> int:
        return int(self.support().sum(axis=1).max(initial=0))

    def cache_token(self):
        """Hashable identity for compiled-driver cache keys: the program
        structure is exactly the permutations."""
        return ("CommPlan", self.num_nodes, self.colors)

    # -- byte accounting (dryrun --plan, HLO budget assertions) -------------

    def bytes_per_device_per_step(self, d: int, itemsize: int = 4,
                                  wire: str | None = None) -> int:
        """Worst-case per-device ppermute payload of ONE gossip step: one
        (d,)-vector sent per color the node is matched in (<= num_colors).
        ``wire=`` switches to the real wire dtype's accounting (quantized
        elements + scale sidecar); ``itemsize`` is then ignored."""
        return self.num_colors * _payload_bytes(d, itemsize, wire)

    def bytes_per_link_per_step(self, d: int, itemsize: int = 4,
                                wire: str | None = None) -> int:
        """Bytes crossing one graph edge (both directions) per gossip step."""
        return 2 * _payload_bytes(d, itemsize, wire)

    def total_bytes_per_step(self, d: int, itemsize: int = 4,
                             wire: str | None = None) -> int:
        """Network-wide bytes of one gossip step: every edge, both ways."""
        return self.num_edges * self.bytes_per_link_per_step(d, itemsize,
                                                             wire)

    def contract(self, d: int, itemsize: int = 4, *, gossip_steps: int = 1,
                 wire: str | None = None):
        """The declared collective budget of this plan's lowered round
        program (``repro.analysis.contracts.CommContract``): at most
        ``gossip_steps * num_colors`` collective-permutes (twice that on a
        quantized wire — payload + scale sidecar) moving at most
        ``bytes_per_device_per_step`` each step, zero
        all-gathers/all-reduces — what ``analysis.check_comm`` holds the
        compiled HLO to. ``wire='int8'/'fp8'`` derives the cap from the
        quantized payload, so an fp32 payload leaking onto a claimed
        narrow wire overflows the byte clause."""
        from repro.analysis.contracts import CommContract
        from repro.topo.lowering import comm_budget
        budget = comm_budget(self, d, itemsize, gossip_steps=gossip_steps,
                             wire=wire)
        tag = f"-{wire}" if wire else ""
        return CommContract(
            name=f"plan-K{self.num_nodes}-c{self.num_colors}-d{d}{tag}",
            max_collective_permute_count=budget["collective_permutes"],
            max_collective_permute_bytes=budget["bytes_per_device"],
            require_collective_permute=True)

    def render(self, d: int | None = None, itemsize: int = 4,
               max_edges: int = 8, wire: str | None = None) -> str:
        """Human-readable plan (the ``dryrun --plan`` section). Naming a
        quantized ``wire`` adds its per-link/per-device bytes next to the
        fp32 figure."""
        lines = [f"[comm plan] K={self.num_nodes} edges={self.num_edges} "
                 f"colors={self.num_colors} max_degree={self.max_degree()}"]
        for c, cls in enumerate(self.colors):
            shown = ", ".join(f"{i}<->{j}" for i, j in cls[:max_edges])
            more = f", ... +{len(cls) - max_edges}" if len(cls) > max_edges \
                else ""
            lines.append(f"  color {c}: {len(cls)} edge(s)  {shown}{more}")
        if d is not None:
            lines.append(
                f"  bytes/round (1 gossip step, d={d}, itemsize={itemsize}): "
                f"per-device<={self.bytes_per_device_per_step(d, itemsize):,} "
                f"per-link={self.bytes_per_link_per_step(d, itemsize):,} "
                f"total={self.total_bytes_per_step(d, itemsize):,}  "
                f"(dense all-gather per-device="
                f"{self.num_nodes * d * itemsize:,})")
            lines.extend(_render_wire_line(self, d, itemsize, wire))
        return "\n".join(lines)


def _support_adjacency(support) -> np.ndarray:
    """(K, K) adjacency from a Topology or any square matrix's pattern."""
    if isinstance(support, topo.Topology):
        adj = support.adjacency
    else:
        adj = np.asarray(support)
    k = adj.shape[0]
    if adj.ndim != 2 or adj.shape != (k, k):
        raise ValueError(f"support must be square, got {adj.shape}")
    return adj


def _plan_from_classes(classes, k: int, edges) -> CommPlan:
    """Lower validated color classes to ppermute perms + partner tables."""
    coloring.check_coloring(classes, edges, k)
    perms, partners = [], []
    for cls in classes:
        perm = []
        partner = list(range(k))
        for i, j in cls:
            perm.append((i, j))
            perm.append((j, i))
            partner[i], partner[j] = j, i
        perms.append(tuple(sorted(perm)))
        partners.append(tuple(partner))
    return CommPlan(num_nodes=k,
                    colors=tuple(tuple(cls) for cls in classes),
                    perms=tuple(perms), partners=tuple(partners))


def compile_plan(support, *, method: str = "auto") -> CommPlan:
    """Compile a support graph into a ``CommPlan`` (one node per device).

    Args:
      support: a ``core.topology.Topology``, or any (K, K) matrix whose
        off-diagonal nonzero pattern is the exchange graph (a mixing matrix
        works as-is; the diagonal is ignored — self-weights never move
        bytes).
      method: coloring pass (``coloring.edge_coloring``): "auto" never
        exceeds the Vizing bound Delta + 1; "mg" / "greedy" force one pass.
    """
    adj = _support_adjacency(support)
    k = adj.shape[0]
    edges = coloring.undirected_edges(adj)
    classes = coloring.edge_coloring(edges, k, method=method)
    return _plan_from_classes(classes, k, edges)


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """A ``CommPlan`` over K paper-nodes lowered onto M < K devices.

    Nodes map to devices contiguously (node k lives on device k // (K/M),
    the runtime's node-block layout). The quotient of the node graph by
    that assignment splits the edges:

    * ``intra_edges`` — both endpoints on one device: local mixing terms,
      zero communication;
    * ``inter_edges`` — endpoints on different devices: projected onto the
      block-level multigraph over the M devices, whose parallel edges
      collapse (one (K/M, d) block exchange serves every node-pair between
      the two devices). The collapsed device graph's edge coloring lives in
      ``block`` — a ``CommPlan`` whose "nodes" are the M devices, each
      color one device-matching ppermute of the block payload.
    """

    num_nodes: int            # K paper-nodes
    num_devices: int          # M mesh devices
    block: CommPlan           # device-level plan (block.num_nodes == M)
    intra_edges: Tuple[Edge, ...]  # node-level, both ends on one device
    inter_edges: Tuple[Edge, ...]  # node-level, ends on distinct devices

    @property
    def local_nodes(self) -> int:
        return self.num_nodes // self.num_devices

    @property
    def num_colors(self) -> int:
        """Block-level colors = ppermutes per gossip step."""
        return self.block.num_colors

    @property
    def num_edges(self) -> int:
        """Node-level edge count (intra + inter)."""
        return len(self.intra_edges) + len(self.inter_edges)

    def support(self) -> np.ndarray:
        """(K, K) bool: the NODE-level exchange pattern this plan covers
        (same contract as ``CommPlan.support`` — ``check_plan_covers``
        consumes either)."""
        s = np.zeros((self.num_nodes, self.num_nodes), dtype=bool)
        for i, j in self.intra_edges + self.inter_edges:
            s[i, j] = s[j, i] = True
        return s

    def max_degree(self) -> int:
        return int(self.support().sum(axis=1).max(initial=0))

    def coverage(self) -> np.ndarray:
        """(K, K) bool: the off-diagonal W entries this plan can EXECUTE.

        Wider than ``support()``: EVERY same-device node pair is covered —
        the device's whole block sits in the assembled buffer, so an
        intra-block weight between nodes that were never graph-adjacent
        still computes exactly — plus every node pair whose blocks exchange
        under some color (one block ppermute delivers the full block, not
        just the compiled edges' rows)."""
        k, ln = self.num_nodes, self.local_nodes
        cov = np.zeros((k, k), dtype=bool)
        blocks = [np.arange(b * ln, (b + 1) * ln) for b in
                  range(self.num_devices)]
        for b in range(self.num_devices):
            cov[np.ix_(blocks[b], blocks[b])] = True
        for cls in self.block.colors:
            for u, v in cls:
                cov[np.ix_(blocks[u], blocks[v])] = True
                cov[np.ix_(blocks[v], blocks[u])] = True
        np.fill_diagonal(cov, False)
        return cov

    def device_of(self, node: int) -> int:
        return node // self.local_nodes

    def cache_token(self):
        return ("BlockPlan", self.num_nodes, self.num_devices,
                self.block.cache_token(), self.intra_edges, self.inter_edges)

    # -- byte accounting: per-LINK now means per block-level link -----------

    def bytes_per_device_per_step(self, d: int, itemsize: int = 4,
                                  wire: str | None = None) -> int:
        """Worst-case ppermute payload per device per gossip step: one
        (K/M, d) block per block-level color. ``wire=`` switches to the
        real wire dtype's accounting (quantized elements + one scale per
        node row); ``itemsize`` is then ignored."""
        return self.num_colors * _payload_bytes(d, itemsize, wire,
                                                rows=self.local_nodes)

    def bytes_per_link_per_step(self, d: int, itemsize: int = 4,
                                wire: str | None = None) -> int:
        """Bytes crossing one block-level (device-pair) link, both
        directions — covers ALL node-edges between the two blocks."""
        return 2 * _payload_bytes(d, itemsize, wire, rows=self.local_nodes)

    def total_bytes_per_step(self, d: int, itemsize: int = 4,
                             wire: str | None = None) -> int:
        return self.block.num_edges * self.bytes_per_link_per_step(
            d, itemsize, wire)

    def contract(self, d: int, itemsize: int = 4, *, gossip_steps: int = 1,
                 wire: str | None = None):
        """Block-mode collective budget (see ``CommPlan.contract``): at most
        ``gossip_steps * num_colors`` block-level collective-permutes of
        (K/M, d) payloads per step (twice that on a quantized wire —
        payload + scale sidecar) — ``num_colors <= Delta_block + 1`` by
        the Misra-Gries bound, so this is at least as strict as the Vizing
        budget the dist tests assert."""
        from repro.analysis.contracts import CommContract
        from repro.topo.lowering import comm_budget
        budget = comm_budget(self, d, itemsize, gossip_steps=gossip_steps,
                             wire=wire)
        tag = f"-{wire}" if wire else ""
        return CommContract(
            name=f"block-K{self.num_nodes}-M{self.num_devices}-"
                 f"c{self.num_colors}-d{d}{tag}",
            max_collective_permute_count=budget["collective_permutes"],
            max_collective_permute_bytes=budget["bytes_per_device"],
            require_collective_permute=True)

    def render(self, d: int | None = None, itemsize: int = 4,
               max_edges: int = 8, wire: str | None = None) -> str:
        """Human-readable block plan (the ``dryrun --plan --topo`` section
        when the mesh is smaller than the graph)."""
        ln = self.local_nodes
        lines = [f"[block plan] K={self.num_nodes} nodes on "
                 f"M={self.num_devices} devices ({ln} nodes/device)  "
                 f"edges: intra={len(self.intra_edges)} "
                 f"inter={len(self.inter_edges)} "
                 f"(collapsed to {self.block.num_edges} device link(s))  "
                 f"colors={self.num_colors}"]
        for c, cls in enumerate(self.block.colors):
            shown = ", ".join(f"dev{i}<->dev{j}" for i, j in cls[:max_edges])
            more = f", ... +{len(cls) - max_edges}" if len(cls) > max_edges \
                else ""
            lines.append(f"  color {c}: {len(cls)} link(s)  {shown}{more}")
        if d is not None:
            lines.append(
                f"  bytes/round (1 gossip step, d={d}, itemsize={itemsize}): "
                f"per-device<={self.bytes_per_device_per_step(d, itemsize):,} "
                f"per-link={self.bytes_per_link_per_step(d, itemsize):,} "
                f"total={self.total_bytes_per_step(d, itemsize):,}  "
                f"(dense all-gather per-device="
                f"{self.num_nodes * d * itemsize:,})")
            lines.extend(_render_wire_line(self, d, itemsize, wire))
        return "\n".join(lines)


def compile_block_plan(support, num_devices: int, *,
                       method: str = "auto") -> BlockPlan:
    """Quotient a K-node support graph onto M devices and color the result.

    Args:
      support: as ``compile_plan`` (Topology or (K, K) pattern).
      num_devices: M; K % M == 0, K/M contiguous nodes per device.
      method: coloring pass for the collapsed device graph (see
        ``coloring.edge_coloring``; "auto" <= Delta_block + 1).
    """
    adj = _support_adjacency(support)
    k = adj.shape[0]
    if num_devices < 1 or k % num_devices != 0:
        raise ValueError(f"K={k} nodes must divide over M={num_devices} "
                         "devices (contiguous node blocks)")
    ln = k // num_devices
    intra, inter = [], []
    block_adj = np.zeros((num_devices, num_devices), dtype=bool)
    for i, j in coloring.undirected_edges(adj):
        bi, bj = i // ln, j // ln
        if bi == bj:
            intra.append((i, j))
        else:
            inter.append((i, j))
            block_adj[bi, bj] = block_adj[bj, bi] = True
    block_edges = coloring.undirected_edges(block_adj)
    classes = coloring.edge_coloring(block_edges, num_devices, method=method)
    return BlockPlan(num_nodes=k, num_devices=num_devices,
                     block=_plan_from_classes(classes, num_devices,
                                              block_edges),
                     intra_edges=tuple(intra), inter_edges=tuple(inter))


def check_plan_covers(plan: CommPlan, w: np.ndarray,
                      atol: float = 0.0) -> None:
    """Raise ValueError if ``w`` has off-diagonal mass outside the plan.

    The generalization of ``mixing.check_circulant_band``: plan execution
    reproduces ``dense_mix(w, .)`` exactly iff every nonzero off-diagonal
    W_ij rides some color's permutation. Churn-reweighted matrices over the
    compiled graph always pass (reweighting only *removes* edges); a
    w_override with extra edges must recompile. Accepts a ``CommPlan`` or a
    ``BlockPlan`` — both expose ``coverage()``, the executable pattern this
    checks (for a block plan that is wider than the compiled graph edges:
    intra-block entries ride the local mixing term and any pair of
    exchanging blocks rides the full block payload).
    """
    w = np.asarray(w)
    if w.shape != (plan.num_nodes, plan.num_nodes):
        raise ValueError(f"W shape {w.shape} does not match the plan's "
                         f"K={plan.num_nodes}")
    off = np.abs(w.copy())
    np.fill_diagonal(off, 0.0)
    uncovered = off * ~plan.coverage()
    if uncovered.max(initial=0.0) > atol:
        i, j = np.unravel_index(np.argmax(uncovered), uncovered.shape)
        raise ValueError(
            f"W[{i},{j}]={w[i, j]:.3g} lies outside the compiled plan's "
            f"support — plan execution would drop that weight mass; "
            "recompile the plan from this W's support (or use the dense "
            "mixing path)")


def plan_coefficients(plan: CommPlan, w, *, check: bool = True
                      ) -> tuple[np.ndarray, np.ndarray]:
    """(diag (K,), coefs (C, K)) for one round's mixing matrix ``w``.

    ``diag[k] = W_kk``; ``coefs[c, k] = W[k, partner_c(k)]`` (0 where
    unmatched). Together with the plan's permutations these reproduce
    ``dense_mix(w, v)``: every off-diagonal entry appears in exactly one
    color, the diagonal in the local term.
    """
    w = np.asarray(w)
    if check:
        check_plan_covers(plan, w)
    k = plan.num_nodes
    diag = np.ascontiguousarray(np.diag(w))
    coefs = np.zeros((plan.num_colors, k), dtype=w.dtype)
    rows = np.arange(k)
    for c, partner in enumerate(plan.partner_arrays()):
        matched = partner != rows
        coefs[c, matched] = w[rows[matched], partner[matched]]
    return diag, coefs


def w_from_coefficients(plan: CommPlan, diag, coefs) -> np.ndarray:
    """Reassemble the (K, K) mixing matrix from one round's plan entries.

    Exact inverse of ``plan_coefficients`` over the plan's support: the
    diagonal comes back from ``diag``, and each color's coefficient row
    scatters to ``W[k, partner_c(k)]`` (unmatched slots carry 0 and stay
    off the matrix). Consumers that only see the lowered schedule —
    telemetry on the per-node CommPlan dist path reconstructs W from
    ``plan_diag``/``plan_coefs`` this way — get the same matrix the round
    actually mixed with, because every executable off-diagonal entry lives
    in exactly one color.
    """
    diag = np.asarray(diag)
    coefs = np.asarray(coefs)
    k = plan.num_nodes
    if diag.shape != (k,):
        raise ValueError(f"diag shape {diag.shape} != ({k},)")
    if coefs.shape != (plan.num_colors, k):
        raise ValueError(
            f"coefs shape {coefs.shape} != ({plan.num_colors}, {k})")
    w = np.zeros((k, k), dtype=np.result_type(diag.dtype, coefs.dtype))
    np.fill_diagonal(w, diag)
    rows = np.arange(k)
    for c, partner in enumerate(plan.partner_arrays()):
        matched = partner != rows
        w[rows[matched], partner[matched]] = coefs[c, matched]
    return w


def w_from_coefficients_device(plan: CommPlan, diag, coefs):
    """``w_from_coefficients`` for traced (on-device) schedule slices.

    Same scatter driven by the plan's static partner tables, built with
    ``jnp`` so it can run inside the dist runtime's jitted round step —
    this is how telemetry on the per-node CommPlan path recovers the round
    W the executed coefficients encode (the (T, K, K) stack was dropped
    from the device schedule at lowering time).
    """
    import jax.numpy as jnp

    k = plan.num_nodes
    diag = jnp.asarray(diag)
    rows = np.arange(k)
    w = jnp.zeros((k, k), dtype=diag.dtype)
    w = w.at[rows, rows].set(diag)
    for c, partner in enumerate(plan.partner_arrays()):
        matched = partner != rows
        w = w.at[rows[matched], partner[matched]].set(coefs[c][matched])
    return w


@dataclasses.dataclass(frozen=True)
class PlanSchedule:
    """Per-round plan coefficients, materialized like the churn masks.

    ``diag`` (T, K) and ``coefs`` (T, C, K) are stacked schedule arrays the
    round-block executor slices per block; a static (round-invariant) W
    yields broadcast views, O(C*K) host memory regardless of T.
    """

    diag: np.ndarray   # (T, K)
    coefs: np.ndarray  # (T, C, K)

    @classmethod
    def from_w_stack(cls, plan: CommPlan, w_stack, *,
                     static: bool = False) -> "PlanSchedule":
        """Compile every round's coefficients (validating coverage per
        round). ``static=True`` asserts the stack is round-invariant and
        stores broadcast views instead of T copies."""
        w_stack = np.asarray(w_stack)
        t = w_stack.shape[0]
        if static or t == 0:
            w0 = w_stack[0] if t else np.eye(plan.num_nodes)
            if t and not (w_stack == w0).all():
                raise ValueError(
                    "PlanSchedule.from_w_stack(static=True) requires a "
                    "round-invariant W stack — this one varies; drop "
                    "static= to materialize per-round coefficients")
            diag0, coefs0 = plan_coefficients(plan, w0)
            return cls(
                diag=np.broadcast_to(diag0.astype(w_stack.dtype),
                                     (t,) + diag0.shape),
                coefs=np.broadcast_to(coefs0.astype(w_stack.dtype),
                                      (t,) + coefs0.shape))
        diag = np.empty((t, plan.num_nodes), dtype=w_stack.dtype)
        coefs = np.empty((t, plan.num_colors, plan.num_nodes),
                         dtype=w_stack.dtype)
        for t_i in range(t):
            diag[t_i], coefs[t_i] = plan_coefficients(plan, w_stack[t_i])
        return cls(diag=diag, coefs=coefs)

    def entries(self) -> dict:
        """The executor schedule entries the dist runtime splices in."""
        return {"plan_diag": self.diag, "plan_coefs": self.coefs}


def plan_mix_dense(plan: CommPlan, diag, coefs, v_stack):
    """Mesh-free reference executor: apply one plan-compiled gossip step to
    stacked (K, ...) state with jnp gathers standing in for the ppermutes.

    This is the oracle the property tests pin against ``mixing.dense_mix``
    (equal to float tolerance — the color-by-color summation order differs
    from the matmul's) and the program the shard_map lowering
    (``repro.topo.lowering.plan_mix_step``) must match shard-for-shard.
    """
    import jax.numpy as jnp

    v_stack = jnp.asarray(v_stack)
    flat = v_stack.reshape(v_stack.shape[0], -1)
    diag = jnp.asarray(diag, dtype=flat.dtype)
    coefs = jnp.asarray(coefs, dtype=flat.dtype)
    out = diag[:, None] * flat
    for c, partner in enumerate(plan.partner_arrays()):
        out = out + coefs[c][:, None] * flat[partner]
    return out.reshape(v_stack.shape)


def mix_with_plan(plan: CommPlan, w, v_stack):
    """Convenience: one gossip step of ``w`` through the compiled plan."""
    diag, coefs = plan_coefficients(plan, w)
    return plan_mix_dense(plan, diag, coefs, v_stack)


# ---------------------------------------------------------------------------
# block mode: K nodes on M devices
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockPlanSchedule:
    """Per-round mixing matrices for block-mode plan execution.

    Block mode's per-node coefficients ARE each device's (K/M, K) row slice
    of the round's W — the coefficient mask that weights the device's
    assembled neighborhood buffer in one dot (``lowering.block_mix_step``).
    ``w`` is the (T, K, K) round stack the dist runtime shards row-wise
    over the node axis per round; coverage against the compiled plan is
    validated here (per round, or once for a ``static`` stack stored as
    broadcast views), so a round whose W grew edges outside the compiled
    support fails loudly instead of silently dropping weight mass.
    """

    w: np.ndarray  # (T, K, K)

    @classmethod
    def from_w_stack(cls, plan: BlockPlan, w_stack, *,
                     static: bool = False) -> "BlockPlanSchedule":
        w_stack = np.asarray(w_stack)
        t = w_stack.shape[0]
        if static or t == 0:
            w0 = w_stack[0] if t else np.eye(plan.num_nodes,
                                             dtype=w_stack.dtype)
            if t and not (w_stack == w0).all():
                raise ValueError(
                    "BlockPlanSchedule.from_w_stack(static=True) requires a "
                    "round-invariant W stack — this one varies; drop "
                    "static= to validate per-round coverage")
            check_plan_covers(plan, w0)
            return cls(w=np.broadcast_to(w0, (t,) + w0.shape))
        for t_i in range(t):
            check_plan_covers(plan, w_stack[t_i])
        return cls(w=w_stack)

    def entries(self) -> dict:
        """The executor schedule entry the dist runtime splices in (sharded
        ``P(axis)`` on the row dimension of each round's slice)."""
        return {"plan_w": self.w}


def block_mix_dense(plan: BlockPlan, w, v_stack, *, check: bool = True):
    """Mesh-free reference executor for block mode: per device, assemble
    the (K, d) neighborhood buffer (own block + one block per block-level
    color; never-exchanged blocks stay zero) and apply the device's (K/M, K)
    W rows in ONE dot.

    Because every nonzero W entry lands on an assembled row (coverage
    checked) and assembled-but-unweighted rows multiply exact zeros, each
    device's dot runs the same contraction as the dense (K, K) @ (K, d)
    matmul — ``mixing.dense_mix`` BITWISE, which is the parity contract the
    distributed block lowering (``lowering.block_mix_step``) is pinned to.
    """
    import jax.numpy as jnp

    w = np.asarray(w)
    if check:
        check_plan_covers(plan, w)
    k, m, ln = plan.num_nodes, plan.num_devices, plan.local_nodes
    v_stack = jnp.asarray(v_stack)
    flat = v_stack.reshape(k, -1)
    partners = plan.block.partner_arrays()  # (C, M)
    outs = []
    for dev in range(m):
        buf = jnp.zeros_like(flat)
        buf = buf.at[dev * ln:(dev + 1) * ln].set(
            flat[dev * ln:(dev + 1) * ln])
        for c in range(plan.num_colors):
            src = int(partners[c, dev])
            if src != dev:
                buf = buf.at[src * ln:(src + 1) * ln].set(
                    flat[src * ln:(src + 1) * ln])
        w_rows = jnp.asarray(w[dev * ln:(dev + 1) * ln], dtype=flat.dtype)
        outs.append(w_rows @ buf)
    return jnp.concatenate(outs, axis=0).reshape(v_stack.shape)


def mix_with_block_plan(plan: BlockPlan, w, v_stack):
    """Convenience: one gossip step of ``w`` through the block plan."""
    return block_mix_dense(plan, w, v_stack)


def block_robust_mix_dense(plan: BlockPlan, w, v_stack, mode: str, *,
                           trim: int = 1, clip: float | None = None,
                           check: bool = True, self_stack=None):
    """Mesh-free reference executor for ROBUST block mode: per device,
    assemble the zero-filled neighborhood buffer exactly as
    ``block_mix_dense`` does, then aggregate the device's node rows with
    ``repro.core.mixing.robust_neighborhood_mix`` instead of the dot.

    The robust rule reads only buffer slots inside each row's W support
    (coverage-checked), so this equals the full-stack
    ``mixing.robust_mix_dense`` BITWISE — the parity contract the shard_map
    robust lowering (``lowering.block_robust_mix_step``) is pinned to.

    ``self_stack`` (K, ...) supplies honest per-node states overriding each
    node's OWN buffer slot when ``v_stack`` is an attacked wire payload.
    """
    import jax.numpy as jnp

    from repro.core import mixing as core_mixing

    w = np.asarray(w)
    if check:
        check_plan_covers(plan, w)
    k, m, ln = plan.num_nodes, plan.num_devices, plan.local_nodes
    v_stack = jnp.asarray(v_stack)
    flat = v_stack.reshape(k, -1)
    partners = plan.block.partner_arrays()  # (C, M)
    outs = []
    for dev in range(m):
        buf = jnp.zeros_like(flat)
        buf = buf.at[dev * ln:(dev + 1) * ln].set(
            flat[dev * ln:(dev + 1) * ln])
        for c in range(plan.num_colors):
            src = int(partners[c, dev])
            if src != dev:
                buf = buf.at[src * ln:(src + 1) * ln].set(
                    flat[src * ln:(src + 1) * ln])
        w_rows = jnp.asarray(w[dev * ln:(dev + 1) * ln], dtype=flat.dtype)
        row_ids = jnp.arange(dev * ln, (dev + 1) * ln)
        ov = None
        if self_stack is not None:
            ov = jnp.asarray(self_stack).reshape(k, -1)[dev * ln:(dev + 1) * ln]
        outs.append(core_mixing.robust_neighborhood_mix(
            w_rows, buf, row_ids, mode, trim=trim, clip=clip,
            self_override=ov))
    out = jnp.concatenate(outs, axis=0)
    return out.reshape(v_stack.shape).astype(v_stack.dtype)
