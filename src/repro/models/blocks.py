"""Per-family transformer blocks: attention block with KV cache, dense/MoE
decoder layers, xLSTM pairs, Zamba2 hybrid groups, encoder/decoder layers.

All blocks share the signature
    apply(cfg, params, x, positions, cache, ctx) -> (y, new_cache, aux)
where ``cache=None`` selects the training path (no state materialized),
``positions`` are absolute token positions (B, S), and ``ctx`` carries the
optional mesh/axis info used by expert-parallel MoE.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import ssm
from repro.models.attention import chunked_attention


def _attention(cfg, q, k, v, q_pos, kv_pos, *, mode):
    """Backend dispatch: jnp chunked scan (oracle) or the Pallas kernel
    (VMEM-resident tiles; interpret mode on CPU, Mosaic on TPU)."""
    if cfg.attn_backend == "pallas":
        from repro.kernels.flash_attention import flash_attention
        import jax as _jax
        return flash_attention(
            q, k, v, q_pos, kv_pos, mode=mode, window=cfg.window,
            block_q=min(128, max(8, q.shape[1])),
            block_kv=min(128, max(8, k.shape[1])),
            interpret=_jax.default_backend() != "tpu")
    return chunked_attention(q, k, v, q_pos, kv_pos, mode=mode,
                             window=cfg.window, kv_chunk=cfg.scan_chunk,
                             compute_dtype=cfg.attn_compute_dtype)
from repro.models.common import (apply_rope, dense_init, head_rms_norm,
                                 rms_norm)
from repro.models.mlp import mlp_apply, mlp_init, moe_apply, moe_init


@dataclasses.dataclass(frozen=True)
class ModelCtx:
    """Runtime context: mesh/axes for expert parallelism, MoE mode, and the
    optional activation sharding constraint (a PartitionSpec for (B, S, D)
    hidden states applied at every scanned-layer boundary)."""

    mesh: Any = None
    model_axis: str | None = None
    moe_mode: str = "scatter"   # "scatter" | "dense"
    act_spec: Any = None        # PartitionSpec | None
    dispatch_groups: int = 0    # token-grouped MoE dispatch (see mlp.py)


DEFAULT_CTX = ModelCtx()


def _attn_mode(cfg: ModelConfig) -> str:
    return {"full": "causal", "sliding": "sliding",
            "chunked_local": "chunked_local"}[cfg.attention]


# ---------------------------------------------------------------------------
# GQA attention block with KV cache
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, d_model: int | None = None) -> dict:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.num_heads * hd), dtype),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.zeros((hd,), dtype=jnp.float32)
        p["k_scale"] = jnp.zeros((hd,), dtype=jnp.float32)
    return p


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype=dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype=dtype),
        "pos": jnp.full((batch, max_len), -1, dtype=jnp.int32),
    }


def attn_apply(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
               cache: dict | None = None, *, mode: str | None = None):
    """Self attention. x: (B, S, d); positions: (B, S) absolute positions.

    With a cache, new K/V are written at slot ``position % cache_len`` (a ring
    buffer — for full caches sized >= seq_len this is the identity layout; for
    sliding-window caches sized `window` it implements SWA decode in O(window)
    memory).
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    mode = mode or _attn_mode(cfg)
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_scale"])
        k = head_rms_norm(k, p["k_scale"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = _attention(cfg, q, k, v, positions, positions, mode=mode)
        new_cache = None
    else:
        cache_len = cache["k"].shape[1]
        # Attend over (old cache) ++ (fresh chunk): exact for one-token
        # decode, chunked prefill, and prompts longer than a ring buffer —
        # fresh keys are visible to the current chunk's queries even when
        # they won't all fit in the buffer afterwards. Prior positions can't
        # reappear in the fresh chunk, so there are no duplicate keys.
        k_att = jnp.concatenate([cache["k"].astype(q.dtype), k], axis=1)
        v_att = jnp.concatenate([cache["v"].astype(q.dtype), v], axis=1)
        pos_att = jnp.concatenate([cache["pos"], positions], axis=1)
        out = _attention(cfg, q, k_att, v_att, positions, pos_att, mode=mode)
        # ring-buffer write at slot = position % cache_len; a scatter handles
        # wrap-around, and prefills longer than the buffer keep only the last
        # cache_len tokens (older ones would be overwritten anyway).
        if s >= cache_len:
            k_w, v_w = k[:, -cache_len:], v[:, -cache_len:]
            pos_w = positions[:, -cache_len:]
        else:
            k_w, v_w, pos_w = k, v, positions
        slots = pos_w % cache_len                       # (B, S')
        bidx = jnp.arange(b)[:, None]
        ck = cache["k"].at[bidx, slots].set(k_w.astype(cache["k"].dtype))
        cv = cache["v"].at[bidx, slots].set(v_w.astype(cache["v"].dtype))
        cpos = cache["pos"].at[bidx, slots].set(pos_w)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    y = out.reshape(b, s, cfg.num_heads * hd) @ p["wo"]
    return y, new_cache


def cross_attn_init(key, cfg: ModelConfig) -> dict:
    return attn_init(key, cfg)


def cross_attn_apply(cfg: ModelConfig, p: dict, x: jax.Array,
                     enc_kv: tuple[jax.Array, jax.Array],
                     enc_pos: jax.Array):
    """Cross attention against precomputed encoder K/V."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k, v = enc_kv
    q_pos = jnp.zeros((b, s), dtype=jnp.int32)
    out = _attention(cfg, q, k, v, q_pos, enc_pos, mode="cross")
    return out.reshape(b, s, cfg.num_heads * hd) @ p["wo"]


def cross_kv(cfg: ModelConfig, p: dict, enc_out: jax.Array):
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    return k, v


# ---------------------------------------------------------------------------
# Decoder layers (dense & MoE)
# ---------------------------------------------------------------------------

def dense_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "attn": attn_init(k1, cfg),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def dense_layer_apply(cfg: ModelConfig, p: dict, x, positions, cache,
                      ctx: ModelCtx):
    h, new_cache = attn_apply(cfg, p["attn"], rms_norm(x, p["ln1"]), positions,
                              cache)
    x = x + h
    x = x + mlp_apply(p["mlp"], rms_norm(x, p["ln2"]))
    return x, new_cache, jnp.zeros((), dtype=jnp.float32)


def moe_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "attn": attn_init(k1, cfg),
        "moe": moe_init(k2, cfg.d_model, cfg.d_ff, cfg.num_experts, dtype,
                        shared_expert=cfg.moe_shared_expert),
    }


def moe_layer_apply(cfg: ModelConfig, p: dict, x, positions, cache,
                    ctx: ModelCtx):
    h, new_cache = attn_apply(cfg, p["attn"], rms_norm(x, p["ln1"]), positions,
                              cache)
    x = x + h
    y, aux = moe_apply(p["moe"], rms_norm(x, p["ln2"]),
                       experts_per_token=cfg.experts_per_token,
                       capacity_factor=cfg.moe_capacity_factor,
                       mode=ctx.moe_mode, mesh=ctx.mesh,
                       model_axis=ctx.model_axis,
                       dispatch_groups=ctx.dispatch_groups,
                       group_axes=(tuple(ctx.act_spec)[0]
                                   if ctx.act_spec is not None else None))
    return x + y, new_cache, aux


def moe_group_init(key, cfg: ModelConfig) -> dict:
    """Interleaved group (cfg.moe_every > 1): (moe_every - 1) dense layers
    followed by one MoE layer — llama4-style alternation."""
    ks = jax.random.split(key, cfg.moe_every)
    return {"dense": [dense_layer_init(k, cfg) for k in ks[:-1]],
            "moe": moe_layer_init(ks[-1], cfg)}


def moe_group_apply(cfg: ModelConfig, p: dict, x, positions, cache,
                    ctx: ModelCtx):
    n = cfg.moe_every - 1
    cache = cache or {"dense": [None] * n, "moe": None}
    new_dense = []
    for i in range(n):
        x, c, _ = dense_layer_apply(cfg, p["dense"][i], x, positions,
                                    cache["dense"][i], ctx)
        new_dense.append(c)
    x, cm, aux = moe_layer_apply(cfg, p["moe"], x, positions, cache["moe"],
                                 ctx)
    return x, {"dense": new_dense, "moe": cm}, aux


def moe_group_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                         dtype) -> dict:
    return {"dense": [init_kv_cache(cfg, batch, max_len, dtype)
                      for _ in range(cfg.moe_every - 1)],
            "moe": init_kv_cache(cfg, batch, max_len, dtype)}


# ---------------------------------------------------------------------------
# xLSTM pair (mLSTM block + sLSTM block), each pre-norm residual
# ---------------------------------------------------------------------------

def xlstm_pair_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "ln_m": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "ln_s": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "mlstm": ssm.mlstm_init(k1, cfg.d_model, cfg.num_heads, dtype),
        "slstm": ssm.slstm_init(k2, cfg.d_model, cfg.num_heads, dtype),
    }


def xlstm_pair_apply(cfg: ModelConfig, p: dict, x, positions, cache,
                     ctx: ModelCtx):
    cache = cache or {"mlstm": None, "slstm": None}
    h, m_state = ssm.mlstm_apply(p["mlstm"], rms_norm(x, p["ln_m"]),
                                 num_heads=cfg.num_heads,
                                 chunk=cfg.scan_chunk, state=cache["mlstm"])
    x = x + h
    h, s_state = ssm.slstm_apply(p["slstm"], rms_norm(x, p["ln_s"]),
                                 num_heads=cfg.num_heads,
                                 state=cache["slstm"])
    x = x + h
    return x, {"mlstm": m_state, "slstm": s_state}, jnp.zeros((), jnp.float32)


def xlstm_init_cache(cfg: ModelConfig, p: dict, batch: int) -> dict:
    return {
        "mlstm": ssm.mlstm_init_state(p["mlstm"], batch, cfg.num_heads),
        "slstm": ssm.slstm_init_state(p["slstm"], batch, cfg.num_heads),
    }


# ---------------------------------------------------------------------------
# Zamba2 hybrid group: N mamba2 blocks + one SHARED attention block
# ---------------------------------------------------------------------------

def mamba_block_init(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "ln": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "mamba": ssm.mamba2_init(key, cfg.d_model, cfg.ssm_state, dtype,
                                 expand=cfg.ssm_expand,
                                 head_dim=cfg.ssm_head_dim),
    }


def hybrid_group_init(key, cfg: ModelConfig) -> dict:
    """One scanned group: ``blocks_per_attn`` mamba blocks + the layer norms
    feeding the SHARED attention+MLP block (whose params live outside the
    scan — Zamba2's parameter-sharing trick)."""
    ks = jax.random.split(key, cfg.blocks_per_attn)
    return {"mamba_blocks": [mamba_block_init(k, cfg) for k in ks],
            "ln_attn": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
            "ln_mlp": jnp.zeros((cfg.d_model,), dtype=jnp.float32)}


def hybrid_shared_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {"attn": attn_init(k1, cfg),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff,
                            jnp.dtype(cfg.param_dtype))}


def hybrid_group_apply(cfg: ModelConfig, p: dict, shared: dict, x,
                       positions, cache, ctx: ModelCtx):
    n = cfg.blocks_per_attn
    cache = cache or {"mamba": [None] * n, "attn": None}
    new_mamba = []
    for i in range(n):
        blk = p["mamba_blocks"][i]
        h, st = ssm.mamba2_apply(blk["mamba"], rms_norm(x, blk["ln"]),
                                 ssm_state=cfg.ssm_state,
                                 chunk=cfg.scan_chunk,
                                 state=cache["mamba"][i])
        x = x + h
        new_mamba.append(st)
    h, attn_cache = attn_apply(cfg, shared["attn"], rms_norm(x, p["ln_attn"]),
                               positions, cache["attn"])
    x = x + h
    x = x + mlp_apply(shared["mlp"], rms_norm(x, p["ln_mlp"]))
    return x, {"mamba": new_mamba, "attn": attn_cache}, jnp.zeros((), jnp.float32)


def hybrid_init_cache(cfg: ModelConfig, p: dict, batch: int, max_len: int,
                      dtype) -> dict:
    return {
        "mamba": [ssm.mamba2_init_state(b["mamba"], batch, cfg.ssm_state)
                  for b in p["mamba_blocks"]],
        "attn": init_kv_cache(cfg, batch, max_len, dtype),
    }


# ---------------------------------------------------------------------------
# Encoder layer (bidirectional) and decoder layer with cross attention
# ---------------------------------------------------------------------------

def encoder_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "attn": attn_init(k1, cfg),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def encoder_layer_apply(cfg: ModelConfig, p: dict, x, positions):
    h, _ = attn_apply(cfg, p["attn"], rms_norm(x, p["ln1"]), positions,
                      None, mode="cross")  # bidirectional
    x = x + h
    x = x + mlp_apply(p["mlp"], rms_norm(x, p["ln2"]))
    return x


def decoder_xattn_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "ln_x": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "attn": attn_init(k1, cfg),
        "xattn": cross_attn_init(k2, cfg),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def decoder_xattn_layer_apply(cfg: ModelConfig, p: dict, x, positions, cache,
                              enc_kv, enc_pos, ctx: ModelCtx):
    h, new_cache = attn_apply(cfg, p["attn"], rms_norm(x, p["ln1"]), positions,
                              cache)
    x = x + h
    x = x + cross_attn_apply(cfg, p["xattn"], rms_norm(x, p["ln_x"]), enc_kv,
                             enc_pos)
    x = x + mlp_apply(p["mlp"], rms_norm(x, p["ln2"]))
    return x, new_cache, jnp.zeros((), jnp.float32)
