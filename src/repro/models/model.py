"""Public model API: build_model(cfg) -> ModelApi with init/forward/decode."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.models.blocks import DEFAULT_CTX, ModelCtx


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable
    forward: Callable          # (params, batch, ctx=) -> (logits, aux)
    init_cache: Callable       # (params, batch_size, max_len) -> cache
    prefill: Callable          # (params, batch, cache, ctx=) -> (logits, cache)
    decode_step: Callable      # (params, tokens, t, cache, ...) -> (logits, cache)
    encode: Callable | None    # encdec only
    param_count: Callable


def build_model(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        init=partial(transformer.init_params, cfg),
        forward=partial(transformer.forward, cfg),
        init_cache=partial(transformer.init_cache, cfg),
        prefill=partial(transformer.prefill, cfg),
        decode_step=partial(transformer.decode_step, cfg),
        encode=(partial(transformer.encode, cfg)
                if cfg.family == "encdec" else None),
        param_count=transformer.param_count,
    )
