"""Memory-efficient (flash-style) attention in pure JAX.

One chunked-KV implementation serves training, prefill and decode for all
attention variants in the zoo:

  * ``causal``         — standard autoregressive attention
  * ``sliding``        — sliding-window (h2o-danube3, zamba2 long mode)
  * ``chunked_local``  — non-overlapping local chunks (llama4 iRoPE-style)
  * ``cross``          — encoder-decoder cross attention (no causal mask)

The KV axis is processed in blocks under ``lax.scan`` with running
log-sum-exp, so the (Sq, Skv) score matrix is never materialized — this is
what keeps the 32k-prefill dry-runs within HBM, and it doubles as the
reference oracle for the Pallas flash kernel in ``repro.kernels``.

GQA is expressed by grouping query heads over KV heads. Positions are passed
explicitly so ring-buffer caches (SWA decode) and padded caches work without
special cases: a KV slot is attendable iff its position is valid (>= 0) and
the mode's positional predicate admits it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _mode_mask(mode: str, q_pos: jax.Array, kv_pos: jax.Array,
               window: int) -> jax.Array:
    """(..., Sq, Skv) boolean mask from positions."""
    q = q_pos[..., :, None]
    k = kv_pos[..., None, :]
    valid = k >= 0  # negative position = empty cache slot
    if mode == "causal":
        return valid & (k <= q)
    if mode == "sliding":
        return valid & (k <= q) & (k > q - window)
    if mode == "chunked_local":
        return valid & (k <= q) & ((k // window) == (q // window))
    if mode == "cross":
        return valid
    raise ValueError(f"unknown attention mode: {mode}")


@functools.partial(jax.jit, static_argnames=("mode", "window", "kv_chunk",
                                             "compute_dtype"))
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, kv_pos: jax.Array, *, mode: str,
                      window: int = 0, kv_chunk: int = 512,
                      compute_dtype: str = "float32") -> jax.Array:
    """Flash-style GQA attention.

    Args:
      q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with H = G * KV.
      q_pos: (B, Sq) int32 absolute positions of the queries.
      kv_pos: (B, Skv) int32 positions of KV slots; -1 marks empty slots.
      mode/window: attention variant (see module docstring).
      kv_chunk: KV block size for the scan.

    Returns:
      (B, Sq, H, hd) attention output in q.dtype.
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = hd ** -0.5

    # pad KV to a multiple of the chunk; padded slots get position -1 (masked)
    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)

    cdt = jnp.dtype(compute_dtype)
    qg = (q.reshape(b, sq, kvh, g, hd).astype(jnp.float32)
          * scale).astype(cdt)
    k_chunks = k.reshape(b, n_chunks, kv_chunk, kvh, hd).swapaxes(0, 1)
    v_chunks = v.reshape(b, n_chunks, kv_chunk, kvh, hd).swapaxes(0, 1)
    pos_chunks = kv_pos.reshape(b, n_chunks, kv_chunk).swapaxes(0, 1)

    def body(carry, chunk):
        m, l, acc = carry
        k_c, v_c, p_c = chunk
        # scores: (B, Sq, KV, G, chunk) — operands in ``compute_dtype``
        # (bf16 halves HBM traffic on TPU), accumulation forced to f32.
        s = jnp.einsum("bqkgh,bckh->bqkgc", qg, k_c.astype(cdt),
                       preferred_element_type=jnp.float32)
        mask = _mode_mask(mode, q_pos, p_c, window)          # (B, Sq, chunk)
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqkgc,bckh->bqkgh", p.astype(cdt), v_c.astype(cdt),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kvh, g), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, g), dtype=jnp.float32)
    acc0 = jnp.zeros((b, sq, kvh, g, hd), dtype=jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, acc0),
                              (k_chunks, v_chunks, pos_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def reference_attention(q, k, v, q_pos, kv_pos, *, mode: str,
                        window: int = 0) -> jax.Array:
    """Naive O(Sq*Skv) oracle used in tests against chunked_attention."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bckh->bqkgc", qg, k.astype(jnp.float32)) * hd ** -0.5
    mask = _mode_mask(mode, q_pos, kv_pos, window)
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgc,bckh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)
