"""Recurrent sequence-mixing blocks: Mamba2 (SSD), mLSTM and sLSTM.

All three follow the same execution pattern:

* training / prefill: **chunkwise parallel scan** — quadratic attention-like
  computation inside fixed-size chunks, a `lax.scan` carrying the recurrent
  state across chunks. Sub-quadratic in sequence length (O(S * chunk)).
* decode: O(1)-state single-step recurrence against a carried state — this is
  what makes the ``long_500k`` shape feasible for the SSM/hybrid archs.

Mamba2 follows the SSD formulation (scalar-per-head A, shared B/C group).
mLSTM/sLSTM follow the xLSTM paper (arXiv:2405.04517) with the stabilized
exponential gating (running log-scale max m).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import dense_init, rms_norm


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv. x: (B, S, C), w: (width, C).

    Returns (y, new_state) where state caches the last (width-1) inputs for
    decode. If ``state`` is given, x is treated as the continuation.
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), dtype=x.dtype)
    xx = jnp.concatenate([state, x], axis=1)
    new_state = xx[:, -(width - 1):, :]
    # windows: y_t = sum_{i} w_i * xx[t + i]
    y = jnp.zeros_like(x)
    for i in range(width):
        y = y + xx[:, i:i + x.shape[1], :] * w[i]
    return y, new_state


def _chunk(x: jax.Array, q: int) -> jax.Array:
    """(B, S, ...) -> (n_chunks, B, q, ...); S must be divisible by q."""
    b, s = x.shape[:2]
    return jnp.moveaxis(x.reshape(b, s // q, q, *x.shape[2:]), 1, 0)


def _pad_len(s: int, chunk: int) -> int:
    """Padding that makes s a positive multiple of chunk."""
    return (-s) % chunk if s >= chunk else chunk - s


def _pad_seq(x: jax.Array, pad: int, value: float = 0.0) -> jax.Array:
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _unchunk(x: jax.Array) -> jax.Array:
    n, b, q = x.shape[:3]
    return jnp.moveaxis(x, 0, 1).reshape(b, n * q, *x.shape[3:])


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------

def mamba2_init(key, d_model: int, ssm_state: int, dtype, *,
                expand: int = 2, head_dim: int = 64, conv_width: int = 4) -> dict:
    d_inner = expand * d_model
    heads = d_inner // head_dim
    ks = jax.random.split(key, 4)
    conv_ch = d_inner + 2 * ssm_state
    return {
        "in_proj": dense_init(ks[0], (d_model,
                                      2 * d_inner + 2 * ssm_state + heads),
                              dtype),
        "conv_w": dense_init(ks[1], (conv_width, conv_ch), dtype,
                             fan_in=conv_width),
        "a_log": jnp.zeros((heads,), dtype=jnp.float32),
        "dt_bias": jnp.full((heads,), -2.0, dtype=jnp.float32),
        "d_skip": jnp.ones((heads,), dtype=jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), dtype=jnp.float32),
        "out_proj": dense_init(ks[2], (d_inner, d_model), dtype),
    }


def _ssd_chunk_scan(xh, dt, a, bmat, cmat, h0, chunk: int):
    """Chunkwise SSD. xh: (B,S,H,P); dt: (B,S,H); a: (H,) negative;
    bmat/cmat: (B,S,N). h0: (B,H,P,N). Returns (y (B,S,H,P), hT)."""
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    la = dt * a[None, None, :]                     # (B,S,H) log-decay <= 0
    xs = (_chunk(xh, chunk), _chunk(dt, chunk), _chunk(la, chunk),
          _chunk(bmat, chunk), _chunk(cmat, chunk))

    def body(hprev, inp):
        xq, dtq, laq, bq, cq = inp                 # (B,q,H,P) etc.
        cum = jnp.cumsum(laq, axis=1)              # (B,q,H)
        # intra-chunk: y_i += sum_{j<=i} (c_i . b_j) exp(cum_i - cum_j) dt_j x_j
        seg = cum[:, :, None, :] - cum[:, None, :, :]        # (B,q_i,q_j,H)
        iq = jnp.arange(chunk)
        causal = (iq[:, None] >= iq[None, :])[None, :, :, None]
        decay = jnp.where(causal, jnp.exp(seg), 0.0)
        scores = jnp.einsum("bin,bjn->bij", cq, bq)          # (B,q,q)
        w = scores[:, :, :, None] * decay * dtq[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xq)
        # inter-chunk: y_i += (c_i . h_prev) * exp(cum_i)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cq, hprev,
                             jnp.exp(cum))
        # state update: h' = h * exp(cum_end) + sum_j exp(cum_end - cum_j) dt_j b_j x_j^T
        tail = jnp.exp(cum[:, -1:, :] - cum)                 # (B,q,H)
        hnew = hprev * jnp.exp(cum[:, -1])[:, :, None, None] + jnp.einsum(
            "bjh,bjn,bjhp->bhpn", tail * dtq, bq, xq)
        return hnew, y_intra + y_inter

    h_t, ys = lax.scan(body, h0, xs)
    return _unchunk(ys), h_t


def mamba2_apply(p: dict, x: jax.Array, *, ssm_state: int, chunk: int = 256,
                 state: dict | None = None):
    """x: (B, S, d). Returns (y, new_state) with state = {conv, ssm}."""
    b, s, d = x.shape
    proj = x @ p["in_proj"]
    d_inner = (proj.shape[-1] - 2 * ssm_state) * 0 + p["out_proj"].shape[0]
    heads = p["a_log"].shape[0]
    head_dim = d_inner // heads
    z, xbc, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner + 2 * ssm_state], axis=-1)
    conv_state = state["conv"] if state is not None else None
    xbc, conv_state = causal_conv1d(jax.nn.silu(xbc), p["conv_w"], conv_state)
    x_in, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + ssm_state], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])     # (B,S,H)
    a = -jnp.exp(p["a_log"])                                # (H,)
    xh = x_in.reshape(b, s, heads, head_dim)

    h0 = (state["ssm"] if state is not None else
          jnp.zeros((b, heads, head_dim, ssm_state), dtype=jnp.float32))
    if s == 1:
        # decode: single recurrent step
        la = (dt * a[None, None, :])[:, 0]                   # (B,H)
        hnew = h0 * jnp.exp(la)[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, 0], bmat[:, 0].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32),
                       hnew)[:, None]
        y = y.reshape(b, 1, heads, head_dim)
        h_t = hnew
    else:
        # pad S to a positive multiple of chunk; padded steps carry dt = 0,
        # so decay = exp(0) = 1 and zero contribution -> state is preserved.
        pad = _pad_len(s, chunk)
        y, h_t = _ssd_chunk_scan(
            _pad_seq(xh.astype(jnp.float32), pad),
            _pad_seq(dt, pad), a,
            _pad_seq(bmat.astype(jnp.float32), pad),
            _pad_seq(cmat.astype(jnp.float32), pad), h0,
            min(chunk, s + pad))
        y = y[:, :s]
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(y, p["norm_scale"])
    return y @ p["out_proj"], {"conv": conv_state, "ssm": h_t}


def mamba2_init_state(p: dict, batch: int, ssm_state: int) -> dict:
    heads = p["a_log"].shape[0]
    d_inner = p["out_proj"].shape[0]
    width = p["conv_w"].shape[0]
    conv_ch = p["conv_w"].shape[1]
    return {
        "conv": jnp.zeros((batch, width - 1, conv_ch), dtype=p["in_proj"].dtype),
        "ssm": jnp.zeros((batch, heads, d_inner // heads, ssm_state),
                         dtype=jnp.float32),
    }


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell, stabilized exponential gating)
# ---------------------------------------------------------------------------

def mlstm_init(key, d_model: int, num_heads: int, dtype) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d_model, d_model), dtype),
        "wk": dense_init(ks[1], (d_model, d_model), dtype),
        "wv": dense_init(ks[2], (d_model, d_model), dtype),
        "w_if": dense_init(ks[3], (d_model, 2 * num_heads), dtype),
        "w_o": dense_init(ks[4], (d_model, d_model), dtype),
        "out_proj": dense_init(ks[5], (d_model, d_model), dtype),
        "if_bias": jnp.concatenate([
            jnp.zeros((num_heads,)), 3.0 * jnp.ones((num_heads,))]
        ).astype(jnp.float32),
    }


def _mlstm_chunk_scan(q, k, v, log_i, log_f, state, chunk: int):
    """q,k,v: (B,S,H,P) f32; log_i/log_f: (B,S,H). state: (C (B,H,P,N=P),
    n (B,H,P), m (B,H)). Chunkwise stabilized mLSTM."""
    b, s, h, p = q.shape

    xs = tuple(_chunk(t, chunk) for t in (q, k, v, log_i, log_f))

    def body(carry, inp):
        cmat, nvec, m = carry
        qq, kq, vq, liq, lfq = inp                  # (B,q,H,*)
        bq = jnp.cumsum(lfq, axis=1)                # (B,q,H) cumulative log f
        # g_i = max_{j<=i} (log_i_j - b_j); stabilizer m_i = b_i + max(m_st, g_i)
        gi = lax.cummax(liq - bq, axis=1)
        m_st = m[:, None, :]                        # carry stabilizer
        m_new = bq + jnp.maximum(m_st, gi)          # (B,q,H)
        # intra-chunk weights: exp(b_i - b_j + log_i_j - m_i) for j <= i
        iq = jnp.arange(chunk)
        causal = (iq[:, None] >= iq[None, :])[None, :, :, None]
        logw = (bq[:, :, None, :] - bq[:, None, :, :]
                + liq[:, None, :, :] - m_new[:, :, None, :])
        w = jnp.where(causal, jnp.exp(logw), 0.0)   # (B,qi,qj,H)
        scores = jnp.einsum("bihp,bjhp->bijh", qq, kq) * (p ** -0.5)
        num_intra = jnp.einsum("bijh,bijh,bjhd->bihd", scores, w, vq)
        # denominator: n^T q with the same decay weights
        den_intra = jnp.einsum("bijh,bijh->bih", scores, w)
        # inter-chunk: decay exp(b_i + m_st - m_i)
        inter = jnp.exp(bq + m_st - m_new)          # (B,q,H)
        num_inter = jnp.einsum("bihp,bhdp,bih->bihd", qq, cmat, inter) * (p ** -0.5)
        den_inter = jnp.einsum("bihp,bhp,bih->bih", qq, nvec, inter) * (p ** -0.5)
        num = num_intra + num_inter
        den = den_intra + den_inter
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        # state update to end of chunk
        m_end = m_new[:, -1]                        # (B,H)
        tail = jnp.exp(bq[:, -1:, :] - bq + liq - m_end[:, None, :])
        c_new = (cmat * jnp.exp(bq[:, -1] + m - m_end)[:, :, None, None]
                 + jnp.einsum("bjh,bjhd,bjhp->bhdp", tail, vq, kq))
        n_new = (nvec * jnp.exp(bq[:, -1] + m - m_end)[:, :, None]
                 + jnp.einsum("bjh,bjhp->bhp", tail, kq))
        return (c_new, n_new, m_end), hout

    (cmat, nvec, m), ys = lax.scan(body, state, xs)
    return _unchunk(ys), (cmat, nvec, m)


def mlstm_apply(p: dict, x: jax.Array, *, num_heads: int, chunk: int = 256,
                state=None):
    """x: (B, S, d). Returns (y, state)."""
    b, s, d = x.shape
    hd = d // num_heads
    q = (x @ p["wq"]).reshape(b, s, num_heads, hd).astype(jnp.float32)
    k = (x @ p["wk"]).reshape(b, s, num_heads, hd).astype(jnp.float32)
    v = (x @ p["wv"]).reshape(b, s, num_heads, hd).astype(jnp.float32)
    gates = (x @ p["w_if"]).astype(jnp.float32) + p["if_bias"]
    log_i, f_raw = jnp.split(gates, 2, axis=-1)     # (B,S,H) each
    log_f = jax.nn.log_sigmoid(f_raw)
    o = jax.nn.sigmoid(x @ p["w_o"])

    if state is None:
        state = mlstm_init_state(p, b, num_heads)
    st = (state["c"], state["n"], state["m"])
    if s == 1:
        cmat, nvec, m = st
        li, lf = log_i[:, 0], log_f[:, 0]
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)
        ip = jnp.exp(li - m_new)
        c_new = cmat * fp[:, :, None, None] + jnp.einsum(
            "bhd,bhp->bhdp", v[:, 0], k[:, 0]) * ip[:, :, None, None]
        n_new = nvec * fp[:, :, None] + k[:, 0] * ip[:, :, None]
        num = jnp.einsum("bhp,bhdp->bhd", q[:, 0], c_new) * (hd ** -0.5)
        den = jnp.einsum("bhp,bhp->bh", q[:, 0], n_new) * (hd ** -0.5)
        hout = (num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
                )[:, None]
        st_new = (c_new, n_new, m_new)
    else:
        # pad with i-gate = 0 (log_i = -inf) and f-gate = 1 (log_f = 0) so the
        # padded tail neither adds to nor decays the carried state.
        pad = _pad_len(s, chunk)
        hout, st_new = _mlstm_chunk_scan(
            _pad_seq(q, pad), _pad_seq(k, pad), _pad_seq(v, pad),
            _pad_seq(log_i, pad, value=-1e30), _pad_seq(log_f, pad), st,
            min(chunk, s + pad))
        hout = hout[:, :s]
    y = hout.reshape(b, s, d).astype(x.dtype) * o
    return y @ p["out_proj"], {"c": st_new[0], "n": st_new[1], "m": st_new[2]}


def mlstm_init_state(p: dict, batch: int, num_heads: int) -> dict:
    d = p["wq"].shape[0]
    hd = d // num_heads
    return {
        "c": jnp.zeros((batch, num_heads, hd, hd), dtype=jnp.float32),
        "n": jnp.zeros((batch, num_heads, hd), dtype=jnp.float32),
        "m": jnp.full((batch, num_heads), -1e30, dtype=jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM (scalar cell with block-diagonal recurrence, exponential gating)
# ---------------------------------------------------------------------------

def slstm_init(key, d_model: int, num_heads: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    hd = d_model // num_heads
    return {
        "w_in": dense_init(ks[0], (d_model, 4 * d_model), dtype),
        # recurrent block-diagonal: (H, hd, 4*hd)
        "r": dense_init(ks[1], (num_heads, hd, 4 * hd), dtype, fan_in=hd),
        "bias": jnp.concatenate([
            jnp.zeros((2 * d_model,)), 3.0 * jnp.ones((d_model,)),
            jnp.zeros((d_model,))]).astype(jnp.float32),
        "out_proj": dense_init(ks[2], (d_model, d_model), dtype),
    }


def slstm_apply(p: dict, x: jax.Array, *, num_heads: int, state=None):
    """x: (B, S, d). Sequential scan over time (inherently recurrent)."""
    b, s, d = x.shape
    hd = d // num_heads
    pre = (x @ p["w_in"]).astype(jnp.float32)       # (B,S,4d)

    if state is None:
        state = slstm_init_state(p, b, num_heads)

    def step(carry, pre_t):
        c, n, h, m = carry                          # (B,H,hd) x3, (B,H,hd)
        rec = jnp.einsum("bhp,hpq->bhq", h, p["r"].astype(jnp.float32))
        tot = pre_t.reshape(b, num_heads, 4 * hd) + rec + \
            p["bias"].reshape(num_heads, 4 * hd)[None]
        z_r, i_r, f_r, o_r = jnp.split(tot, 4, axis=-1)  # (B,H,hd)
        log_f = jax.nn.log_sigmoid(f_r)
        m_new = jnp.maximum(log_f + m, i_r)
        fp = jnp.exp(log_f + m - m_new)
        ip = jnp.exp(i_r - m_new)
        z = jnp.tanh(z_r)
        c_new = fp * c + ip * z
        n_new = fp * n + ip
        h_new = jax.nn.sigmoid(o_r) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    st = (state["c"], state["n"], state["h"], state["m"])
    pre_t = jnp.moveaxis(pre, 1, 0)                 # (S,B,4d)
    st_new, hs = lax.scan(step, st, pre_t)
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    new_state = {"c": st_new[0], "n": st_new[1], "h": st_new[2],
                 "m": st_new[3]}
    return y @ p["out_proj"], new_state


def slstm_init_state(p: dict, batch: int, num_heads: int) -> dict:
    d = p["out_proj"].shape[0]
    hd = d // num_heads
    z = lambda: jnp.zeros((batch, num_heads, hd), dtype=jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, num_heads, hd), -1e30, dtype=jnp.float32)}
