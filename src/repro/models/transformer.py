"""Model assembly: stacked layers under ``lax.scan`` (O(1) HLO in depth),
per-family wiring, and the three entry points every architecture exposes:

  * ``forward``      — full-sequence logits (training)
  * ``init_cache``   — decode state (KV caches / SSM states / ring buffers)
  * ``decode_step``  — one token in, one token's logits out, state updated

``prefill`` is ``forward`` against a cache (fills it and returns last logits).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.blocks import DEFAULT_CTX, ModelCtx
from repro.models.common import dense_init, embed_init, rms_norm


def _stack_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _num_groups(cfg: ModelConfig) -> int:
    if cfg.family == "xlstm":
        return max(1, cfg.num_layers // 2)      # one group = mLSTM + sLSTM
    if cfg.family == "hybrid":
        return max(1, cfg.num_layers // (cfg.blocks_per_attn + 1))
    if cfg.family == "moe" and cfg.moe_every > 1:
        return max(1, cfg.num_layers // cfg.moe_every)
    return cfg.num_layers


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    n_groups = _num_groups(cfg)
    params: dict = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "unembed": dense_init(keys[1], (cfg.d_model, cfg.vocab_size), dtype),
    }
    if cfg.family == "dense":
        params["layers"] = _stack_init(
            lambda k: blocks.dense_layer_init(k, cfg), keys[2], n_groups)
    elif cfg.family == "moe":
        init_one = (blocks.moe_group_init if cfg.moe_every > 1
                    else blocks.moe_layer_init)
        params["layers"] = _stack_init(
            lambda k: init_one(k, cfg), keys[2], n_groups)
    elif cfg.family == "xlstm":
        params["layers"] = _stack_init(
            lambda k: blocks.xlstm_pair_init(k, cfg), keys[2], n_groups)
    elif cfg.family == "hybrid":
        params["layers"] = _stack_init(
            lambda k: blocks.hybrid_group_init(k, cfg), keys[2], n_groups)
        params["shared"] = blocks.hybrid_shared_init(keys[3], cfg)
    elif cfg.family == "encdec":
        params["layers"] = _stack_init(
            lambda k: blocks.decoder_xattn_layer_init(k, cfg), keys[2],
            cfg.num_layers)
        params["enc_layers"] = _stack_init(
            lambda k: blocks.encoder_layer_init(k, cfg), keys[3],
            cfg.encoder_layers)
        params["enc_in_proj"] = dense_init(keys[4],
                                           (cfg.frontend_dim, cfg.d_model),
                                           dtype)
        params["enc_ln_f"] = jnp.zeros((cfg.d_model,), dtype=jnp.float32)
    elif cfg.family == "vlm":
        params["layers"] = _stack_init(
            lambda k: blocks.dense_layer_init(k, cfg), keys[2], n_groups)
        params["patch_proj"] = dense_init(keys[4],
                                          (cfg.frontend_dim, cfg.d_model),
                                          dtype)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return params


# ---------------------------------------------------------------------------
# stacked-layer scan
# ---------------------------------------------------------------------------

def _group_apply(cfg: ModelConfig, params: dict, ctx: ModelCtx):
    """The per-group apply fn; closes over shared (non-scanned) params."""
    if cfg.family in ("dense", "vlm"):
        fn = lambda p, x, pos, c: blocks.dense_layer_apply(cfg, p, x, pos, c, ctx)
    elif cfg.family == "moe":
        apply_one = (blocks.moe_group_apply if cfg.moe_every > 1
                     else blocks.moe_layer_apply)
        fn = lambda p, x, pos, c: apply_one(cfg, p, x, pos, c, ctx)
    elif cfg.family == "xlstm":
        fn = lambda p, x, pos, c: blocks.xlstm_pair_apply(cfg, p, x, pos, c, ctx)
    elif cfg.family == "hybrid":
        shared = params["shared"]
        fn = lambda p, x, pos, c: blocks.hybrid_group_apply(
            cfg, p, shared, x, pos, c, ctx)
    else:
        raise ValueError(cfg.family)
    return fn


def _remat(cfg: ModelConfig, fn):
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        # save matmul outputs, recompute only elementwise — trades a little
        # memory for a big cut in backward recompute FLOPs
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _constrain(x: jax.Array, ctx: ModelCtx):
    """Optional activation sharding constraint (batch over data axes) —
    pins GSPMD's layer-boundary layout so it can't replicate the batch."""
    if ctx.act_spec is None or ctx.mesh is None:
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, ctx.act_spec))


def _run_stack(cfg: ModelConfig, params: dict, x: jax.Array,
               positions: jax.Array, caches, ctx: ModelCtx):
    """scan the stacked groups; caches may be None (training)."""
    inner = _group_apply(cfg, params, ctx)
    fn = _remat(cfg, lambda p, h, pos, c: inner(p, _constrain(h, ctx), pos, c))

    if caches is None:
        def body(carry, p_l):
            h, aux = carry
            h, _, aux_l = fn(p_l, h, positions, None)
            return (h, aux + aux_l), None

        (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
        return x, None, aux

    def body(carry, xs):
        h, aux = carry
        p_l, c_l = xs
        h, c_new, aux_l = fn(p_l, h, positions, c_l)
        return (h, aux + aux_l), c_new

    (x, aux), new_caches = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    (params["layers"], caches))
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# encoder (encdec family)
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params: dict, enc_embeds: jax.Array,
           ctx: ModelCtx = DEFAULT_CTX):
    """enc_embeds: (B, S_enc, frontend_dim) from the stubbed modality frontend."""
    params = compute_cast(cfg, params)
    x = (enc_embeds.astype(jnp.dtype(cfg.dtype)) @ params["enc_in_proj"])
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    fn = lambda p, h: blocks.encoder_layer_apply(cfg, p, _constrain(h, ctx),
                                                 positions)
    fn = _remat(cfg, fn)

    def body(h, p_l):
        return fn(p_l, h), None

    x, _ = lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_ln_f"]), positions


def _enc_kv_all_layers(cfg: ModelConfig, params: dict, enc_out: jax.Array):
    """Precompute per-decoder-layer cross K/V (stacked on the group axis)."""
    return jax.vmap(lambda p: blocks.cross_kv(cfg, p["xattn"], enc_out)
                    )(params["layers"])


def _run_decoder_xattn(cfg: ModelConfig, params: dict, x, positions, caches,
                       enc_kv, enc_pos, ctx: ModelCtx):
    fn = lambda p, h, c, kv: blocks.decoder_xattn_layer_apply(
        cfg, p, _constrain(h, ctx), positions, c, kv, enc_pos, ctx)
    fn = _remat(cfg, fn)

    if caches is None:
        def body(carry, xs):
            p_l, kv_l = xs
            h, _, _ = fn(p_l, carry, None, kv_l)
            return h, None
        x, _ = lax.scan(body, x, (params["layers"], enc_kv))
        return x, None

    def body(carry, xs):
        p_l, kv_l, c_l = xs
        h, c_new, _ = fn(p_l, carry, c_l, kv_l)
        return h, c_new

    x, new_caches = lax.scan(body, x, (params["layers"], enc_kv, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def compute_cast(cfg: ModelConfig, params: dict) -> dict:
    """Cast float params to the activation dtype (mixed-precision matmuls).

    Master params stay in ``param_dtype`` (f32) inside the optimizer; the
    forward pass consumes a ``cfg.dtype`` (bf16) copy so every matmul hits
    the MXU at low precision. Norm/gate math upcasts internally.
    """
    dtype = jnp.dtype(cfg.dtype)
    if dtype == jnp.dtype(cfg.param_dtype):
        return params
    return jax.tree.map(
        lambda p: p.astype(dtype)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params)


def embed_tokens(cfg: ModelConfig, params: dict, tokens: jax.Array):
    return params["embed"][tokens].astype(jnp.dtype(cfg.dtype))


def forward(cfg: ModelConfig, params: dict, batch: dict,
            ctx: ModelCtx = DEFAULT_CTX):
    """Full-sequence logits. batch keys per family:

      dense/moe/xlstm/hybrid: tokens (B, S)
      vlm:    tokens (B, S_text) + patches (B, P, frontend_dim)
      encdec: tokens (B, S_dec) + enc_embeds (B, S_enc, frontend_dim)

    Returns (logits (B, S*, V) float32, aux_loss scalar).
    """
    params = compute_cast(cfg, params)
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = embed_tokens(cfg, params, tokens)

    if cfg.family == "vlm":
        patches = (batch["patches"].astype(x.dtype) @ params["patch_proj"])
        x = jnp.concatenate([patches, x], axis=1)

    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    if cfg.family == "encdec":
        enc_out, enc_pos = encode(cfg, params, batch["enc_embeds"], ctx)
        enc_kv = _enc_kv_all_layers(cfg, params, enc_out)
        x, _ = _run_decoder_xattn(cfg, params, x, positions, None, enc_kv,
                                  enc_pos, ctx)
        aux = jnp.zeros((), jnp.float32)
    else:
        x, _, aux = _run_stack(cfg, params, x, positions, None, ctx)

    x = rms_norm(x, params["ln_f"])
    logits = (x @ params["unembed"]).astype(jnp.float32)
    return logits, aux


def init_cache(cfg: ModelConfig, params: dict, batch: int, max_len: int):
    """Decode state for the whole stack (leading axis = scanned groups)."""
    dtype = jnp.dtype(cfg.dtype)
    cache_len = max_len
    if cfg.attention in ("sliding", "chunked_local") and cfg.family in (
            "dense", "moe"):
        # ring buffer: both SWA and chunked-local attend only to keys within
        # the last `window` positions, so O(window) cache suffices for decode.
        cache_len = min(max_len, cfg.window)
    if cfg.family == "moe" and cfg.moe_every > 1:
        return jax.vmap(
            lambda _: blocks.moe_group_init_cache(cfg, batch, cache_len,
                                                  dtype)
        )(params["layers"]["moe"]["ln1"])
    if cfg.family in ("dense", "moe", "vlm"):
        return jax.vmap(
            lambda _: blocks.init_kv_cache(cfg, batch, cache_len, dtype)
        )(params["layers"]["ln1"])
    if cfg.family == "xlstm":
        return jax.vmap(lambda p: blocks.xlstm_init_cache(cfg, p, batch)
                        )(params["layers"])
    if cfg.family == "hybrid":
        attn_len = min(max_len, cfg.window) if cfg.attention == "sliding" \
            else max_len
        return jax.vmap(
            lambda p: blocks.hybrid_init_cache(cfg, p, batch, attn_len, dtype)
        )(params["layers"])
    if cfg.family == "encdec":
        return jax.vmap(
            lambda _: blocks.init_kv_cache(cfg, batch, cache_len, dtype)
        )(params["layers"]["ln1"])
    raise ValueError(cfg.family)


def decode_step(cfg: ModelConfig, params: dict, tokens: jax.Array,
                t: jax.Array, cache, *, enc_kv=None, enc_pos=None,
                ctx: ModelCtx = DEFAULT_CTX):
    """One decode step. tokens: (B, 1); t: scalar int32 position.

    For encdec pass enc_kv/enc_pos from ``encode`` + ``_enc_kv_all_layers``.
    Returns (logits (B, 1, V) f32, new_cache).
    """
    params = compute_cast(cfg, params)
    b = tokens.shape[0]
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.full((b, 1), t, dtype=jnp.int32)

    if cfg.family == "encdec":
        x, new_cache = _run_decoder_xattn(cfg, params, x, positions, cache,
                                          enc_kv, enc_pos, ctx)
    else:
        x, new_cache, _ = _run_stack(cfg, params, x, positions, cache, ctx)

    x = rms_norm(x, params["ln_f"])
    logits = (x @ params["unembed"]).astype(jnp.float32)
    return logits, new_cache


def prefill(cfg: ModelConfig, params: dict, batch: dict, cache,
            ctx: ModelCtx = DEFAULT_CTX):
    """Process a full prompt against a cache; returns (last_logits, cache)."""
    params = compute_cast(cfg, params)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm":
        patches = (batch["patches"].astype(x.dtype) @ params["patch_proj"])
        x = jnp.concatenate([patches, x], axis=1)
        s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    if cfg.family == "encdec":
        enc_out, enc_pos = encode(cfg, params, batch["enc_embeds"], ctx)
        enc_kv = _enc_kv_all_layers(cfg, params, enc_out)
        x, new_cache = _run_decoder_xattn(cfg, params, x, positions, cache,
                                          enc_kv, enc_pos, ctx)
    else:
        x, new_cache, _ = _run_stack(cfg, params, x, positions, cache, ctx)
    x = rms_norm(x[:, -1:], params["ln_f"])
    logits = (x @ params["unembed"]).astype(jnp.float32)
    return logits, new_cache


def param_count(params) -> int:
    return sum(p.size for p in jax.tree.leaves(params))
