"""Feed-forward layers: SwiGLU MLP and Mixture-of-Experts.

MoE uses capacity-based scatter dispatch. Two execution modes:

* ``dense`` — every expert processes every token, outputs combined by router
  weights. Exact (no dropping); used for small smoke configs and as the
  reference oracle in tests.
* ``scatter`` — tokens are scattered into per-expert capacity buffers,
  experts run batched matmuls, outputs gathered back. When a mesh axis is
  given the whole dispatch runs under a partial-manual ``shard_map`` over the
  ``model`` axis: each device owns E/num_shards experts, activations are
  replicated over ``model`` (as in tensor parallelism), and the only
  communication is the combining ``psum`` — no all-to-all and no global
  token shuffle. This is the expert-parallel layout used by the dry-runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import dense_init


# ---------------------------------------------------------------------------
# Dense SwiGLU
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def moe_init(key, d_model: int, d_ff: int, num_experts: int, dtype,
             shared_expert: bool = False) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": dense_init(k1, (d_model, num_experts), jnp.float32),
        "w_gate": dense_init(k2, (num_experts, d_model, d_ff), dtype,
                             fan_in=d_model),
        "w_up": dense_init(k3, (num_experts, d_model, d_ff), dtype,
                           fan_in=d_model),
        "w_down": dense_init(k4, (num_experts, d_ff, d_model), dtype,
                             fan_in=d_ff),
    }
    if shared_expert:
        p["shared"] = mlp_init(k5, d_model, d_ff, dtype)
    return p


def _router(p: dict, x_flat: jax.Array, experts_per_token: int):
    """Top-k routing. Returns (weights (T,k) f32, idx (T,k) i32, aux loss)."""
    logits = (x_flat.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, experts_per_token)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    # Switch-style load-balance auxiliary loss.
    e = probs.shape[-1]
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return w, idx, aux


def _expert_ffn(w_gate, w_up, w_down, buf):
    """buf: (E, C, d) -> (E, C, d) through per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", buf, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _scatter_moe_local(w_gate, w_up, w_down, x_flat, w_topk, idx, capacity,
                       e_offset, num_local_experts):
    """Capacity dispatch for the experts [e_offset, e_offset+E_loc).

    x_flat: (T, d); w_topk/idx: (T, k). Tokens routed to non-local experts are
    dropped here (they're handled by the other model shards).
    """
    t, k = idx.shape
    d = x_flat.shape[-1]
    flat_e = idx.reshape(-1) - e_offset                     # (T*k,)
    local = (flat_e >= 0) & (flat_e < num_local_experts)
    flat_e_c = jnp.where(local, flat_e, 0)
    # position of each (token, choice) within its expert's capacity buffer
    oh = jax.nn.one_hot(jnp.where(local, flat_e, num_local_experts),
                        num_local_experts + 1, dtype=jnp.int32)
    pos = (jnp.cumsum(oh, axis=0) - 1)                      # (T*k, E_loc+1)
    pos = jnp.sum(pos * oh, axis=-1)                        # (T*k,)
    keep = local & (pos < capacity)
    pos_c = jnp.clip(pos, 0, capacity - 1)

    tok = jnp.repeat(jnp.arange(t), k)
    contrib = x_flat[tok] * keep[:, None].astype(x_flat.dtype)
    buf = jnp.zeros((num_local_experts, capacity, d), dtype=x_flat.dtype)
    buf = buf.at[flat_e_c, pos_c].add(contrib)

    out_buf = _expert_ffn(w_gate, w_up, w_down, buf)        # (E_loc, C, d)

    gathered = out_buf[flat_e_c, pos_c]                     # (T*k, d)
    gathered = gathered * (keep[:, None] * w_topk.reshape(-1)[:, None]
                           ).astype(x_flat.dtype)
    return jnp.sum(gathered.reshape(t, k, d), axis=1)


def moe_apply(p: dict, x: jax.Array, *, experts_per_token: int,
              capacity_factor: float = 1.25, mode: str = "scatter",
              mesh=None, model_axis: str | None = None,
              dispatch_groups: int = 0, group_axes=None):
    """Apply the MoE layer. x: (B, S, d). Returns (y, aux_loss).

    ``dispatch_groups`` > 0 selects token-grouped dispatch: tokens are split
    into G groups (aligned with the data-parallel shards), each group runs
    its own capacity dispatch, and the expert einsums carry a leading group
    axis. With expert weights FSDP-sharded on a NON-contracting dim, GSPMD
    then all-gathers weights once per layer instead of all-reducing the
    (E, C, d_ff) partial sums over the data axis — the §Perf MoE fix.
    """
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    w_topk, idx, aux = _router(p, x_flat, experts_per_token)
    e = p["w_gate"].shape[0]

    if mode == "scatter" and dispatch_groups > 1 and model_axis is None:
        g = dispatch_groups
        t = b * s
        capacity = max(1, int(round(t // g * experts_per_token / e
                                    * capacity_factor)))
        xg = x_flat.reshape(g, t // g, d)
        wg = w_topk.reshape(g, t // g, -1)
        ig = idx.reshape(g, t // g, -1)
        if mesh is not None and group_axes is not None:
            from jax.sharding import NamedSharding
            cons = lambda a, spec: jax.lax.with_sharding_constraint(
                a, NamedSharding(mesh, spec))
            xg = cons(xg, P(group_axes, None, None))
            wg = cons(wg, P(group_axes, None, None))
            ig = cons(ig, P(group_axes, None, None))
        y = jax.vmap(
            lambda xf, wt, ix: _scatter_moe_local(
                p["w_gate"], p["w_up"], p["w_down"], xf, wt, ix, capacity,
                0, e))(xg, wg, ig)
        if mesh is not None and group_axes is not None:
            y = cons(y, P(group_axes, None, None))
        y = y.reshape(t, d)
        if "shared" in p:
            y = y + mlp_apply(p["shared"], x_flat)
        return y.reshape(b, s, d), aux

    if mode == "dense":
        # reference: all experts on all tokens
        h = jax.nn.silu(jnp.einsum("td,edf->etf", x_flat, p["w_gate"]))
        h = h * jnp.einsum("td,edf->etf", x_flat, p["w_up"])
        all_out = jnp.einsum("etf,efd->etd", h, p["w_down"])  # (E, T, d)
        comb = jnp.sum(
            jax.nn.one_hot(idx, e, dtype=jnp.float32)
            * w_topk[..., None], axis=1)                      # (T, E)
        y = jnp.einsum("te,etd->td", comb.astype(x.dtype), all_out)
    elif mesh is None or model_axis is None:
        capacity = max(1, int(round(b * s * experts_per_token / e
                                    * capacity_factor)))
        y = _scatter_moe_local(p["w_gate"], p["w_up"], p["w_down"], x_flat,
                               w_topk, idx, capacity, 0, e)
    else:
        n_shards = mesh.shape[model_axis]
        e_loc = e // n_shards
        capacity = max(1, int(round(b * s * experts_per_token / e
                                    * capacity_factor)))

        def shard_fn(wg, wu, wd, xf, wt, ix):
            shard = jax.lax.axis_index(model_axis)
            out = _scatter_moe_local(wg, wu, wd, xf, wt, ix, capacity,
                                     shard * e_loc, e_loc)
            return jax.lax.psum(out, model_axis)

        y = jax.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(model_axis), P(model_axis), P(model_axis),
                      P(), P(), P()),
            out_specs=P(), axis_names={model_axis})(
                p["w_gate"], p["w_up"], p["w_down"], x_flat, w_topk, idx)

    if "shared" in p:
        y = y + mlp_apply(p["shared"], x_flat)
    return y.reshape(b, s, d), aux
