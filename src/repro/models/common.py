"""Shared neural building blocks: norms, rotary embeddings, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def head_rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMSNorm over the head dim (Qwen3-style qk-norm).

    x: (..., heads, head_dim); scale: (head_dim,).
    """
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.

    x: (B, S, heads, head_dim); positions: (B, S) int32.
    """
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense_init(key: jax.Array, shape: tuple[int, ...], dtype,
               fan_in: int | None = None) -> jax.Array:
    """Truncated-normal with 1/sqrt(fan_in) scale (fan_in = shape[0] default)."""
    fi = fan_in if fan_in is not None else shape[0]
    std = fi ** -0.5
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                              jnp.float32)).astype(dtype)


def embed_init(key: jax.Array, shape: tuple[int, ...], dtype) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            ).astype(dtype)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None) -> jax.Array:
    """Mean token cross-entropy. logits (B,S,V) f32-cast, labels (B,S) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
