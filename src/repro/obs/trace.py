"""Host-side phase tracing: spans, driver-cache events, Chrome export.

A ``Tracer`` records named wall-clock spans (driver build, per-block
dispatch, bench repeats) plus ``executor.cached_driver`` hit/miss events,
and exports the whole timeline as Chrome-trace JSON (``chrome://tracing``
/ Perfetto). Every span also opens a ``jax.profiler.TraceAnnotation`` so
the same names show up inside a device profile when one is being taken.

A module-level default tracer is always installed — ``span()`` costs two
``perf_counter`` calls and a deque append, so instrumented code paths
(executor block dispatches, bench loops) call it unconditionally. Scoped
collection swaps in a fresh tracer::

    with obs.trace.use(obs.trace.Tracer()) as tr, tr.attach():
        run()
    tr.export("trace.json")
"""
from __future__ import annotations

import collections
import contextlib
import json
import time
from typing import Any

from repro.core import executor

#: default tracer keeps a bounded window so long sessions don't grow it
_DEFAULT_MAXLEN = 4096


class Tracer:
    """Collects spans + driver-cache events relative to its creation."""

    def __init__(self, name: str = "repro", maxlen: int | None = None):
        self.name = name
        self.spans: collections.deque = collections.deque(maxlen=maxlen)
        self.cache_events: collections.deque = collections.deque(
            maxlen=maxlen)
        self._t0 = time.perf_counter()

    @contextlib.contextmanager
    def span(self, name: str, **meta: Any):
        try:
            from jax.profiler import TraceAnnotation
            ann = TraceAnnotation(name)
        except Exception:  # profiler unavailable: host timing still works
            ann = contextlib.nullcontext()
        t0 = time.perf_counter()
        try:
            with ann:
                yield
        finally:
            self.spans.append({"name": name, "t0": t0 - self._t0,
                               "dur": time.perf_counter() - t0,
                               "meta": meta})

    def _on_cache(self, key, kind: str) -> None:
        self.cache_events.append({"t": time.perf_counter() - self._t0,
                                  "kind": kind, "key": repr(key)})

    @contextlib.contextmanager
    def attach(self):
        """Record driver-cache hit/miss/bypass events while active — a
        removable ``executor.cache_listener``, so nested tracers and
        ``RetraceMonitor``s each count their own events exactly once."""
        with executor.cache_listener(self._on_cache):
            yield self

    def cache_stats(self) -> dict:
        out = {"hits": 0, "misses": 0, "bypass": 0}
        for ev in self.cache_events:
            out[ev["kind"]] = out.get(ev["kind"], 0) + 1
        return out

    def summary(self) -> dict:
        """Span timings aggregated by name (count + total seconds) — the
        compact form a RunReport stores."""
        agg: dict = {}
        for s in self.spans:
            ent = agg.setdefault(s["name"], {"count": 0, "total_s": 0.0})
            ent["count"] += 1
            ent["total_s"] += s["dur"]
        for ent in agg.values():
            ent["total_s"] = round(ent["total_s"], 6)
        return {"spans": agg, "cache": self.cache_stats()}

    def chrome_trace(self) -> dict:
        """The timeline as Chrome trace-event JSON."""
        evs = []
        for s in self.spans:
            evs.append({"name": s["name"], "ph": "X", "pid": 1, "tid": 1,
                        "ts": s["t0"] * 1e6, "dur": s["dur"] * 1e6,
                        "args": {str(k): str(v)
                                 for k, v in s["meta"].items()}})
        for ev in self.cache_events:
            evs.append({"name": f"driver-cache {ev['kind']}", "ph": "i",
                        "pid": 1, "tid": 2, "ts": ev["t"] * 1e6, "s": "t",
                        "args": {"key": ev["key"]}})
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


_STACK: list = [Tracer(maxlen=_DEFAULT_MAXLEN)]


def current() -> Tracer:
    """The active tracer (innermost ``use()`` scope, else the default)."""
    return _STACK[-1]


@contextlib.contextmanager
def use(tracer: Tracer):
    """Install ``tracer`` as the active tracer within the scope."""
    _STACK.append(tracer)
    try:
        yield tracer
    finally:
        _STACK.remove(tracer)


def span(name: str, **meta: Any):
    """Record a span on the ACTIVE tracer: ``with obs.trace.span("x"): ...``"""
    return current().span(name, **meta)


@contextlib.contextmanager
def jax_profile(logdir: str):
    """Bridge to the full ``jax.profiler`` device trace: profiles the scope
    into ``logdir`` (TensorBoard/XProf format); span annotations recorded
    inside the scope appear as named host regions in that profile."""
    import jax

    jax.profiler.start_trace(logdir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()
