from repro.obs.cli import main

raise SystemExit(main())
