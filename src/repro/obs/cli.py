"""``python -m repro.obs`` — query the run registry.

Subcommands:
  list                 one line per recorded run
  show RUN             full report (RUN = run_id prefix or index, -1 = last)
  diff RUN_A RUN_B     config / counter / history deltas between two runs
  timeline RUN         per-round ASCII timeline (gap / eps / saturation)
  smoke [--dir D]      run two tiny telemetry runs (clean + attacked int8)
                       and exercise list/show/diff/timeline on them
"""
from __future__ import annotations

import argparse
import datetime
import json
import math
from typing import Any

from repro.obs import report as report_lib

_BLOCKS = " ▁▂▃▄▅▆▇█"


def sparkline(values, width: int = 64, log: bool = False) -> str:
    """Resample ``values`` to ``width`` buckets (max within bucket) and
    render one block character per bucket."""
    vals = [float(v) for v in values if v is not None]
    if not vals:
        return ""
    if log:
        floor = min((v for v in vals if v > 0), default=1e-12)
        vals = [math.log10(max(v, floor)) for v in vals]
    n = len(vals)
    width = min(width, n)
    buckets = [max(vals[i * n // width:(i + 1) * n // width] or [vals[-1]])
               for i in range(width)]
    lo, hi = min(buckets), max(buckets)
    span = hi - lo or 1.0
    return "".join(_BLOCKS[round((b - lo) / span * (len(_BLOCKS) - 1))]
                   for b in buckets)


def _fmt_ts(ts) -> str:
    try:
        return datetime.datetime.fromtimestamp(float(ts)).strftime(
            "%Y-%m-%d %H:%M:%S")
    except (TypeError, ValueError):
        return "?"


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def cmd_list(args) -> int:
    reports = report_lib.load_reports(args.dir)
    pruned = report_lib.pruned_total(args.dir)
    if not reports:
        print(f"no runs in {report_lib.runs_file(args.dir)}")
        if pruned:
            print(f"({pruned} older run(s) pruned by retention; "
                  f"cap={report_lib.retention_limit()}, "
                  f"override with {report_lib.ENV_KEEP})")
        return 0
    print(f"{'#':>3} {'run_id':<12} {'when':<19} {'driver':<13} "
          f"{'K':>3} {'rounds':>6} {'stop':>5} {'final':<22}")
    for i, r in enumerate(reports):
        hist = r.get("history") or {}
        final = hist.get("final") or {}
        lead = next(iter(
            f"{k}={_fmt(v)}" for k, v in final.items()), "")
        stop = hist.get("stop_round")
        print(f"{i:>3} {str(r.get('run_id', '?')):<12} "
              f"{_fmt_ts(r.get('timestamp')):<19} "
              f"{str(r.get('driver', '?')):<13} "
              f"{(r.get('graph') or {}).get('num_nodes', '?'):>3} "
              f"{r.get('rounds', '?'):>6} "
              f"{'-' if stop is None else stop:>5} {lead:<22}")
    if pruned:
        print(f"({pruned} older run(s) pruned by retention; "
              f"cap={report_lib.retention_limit()}, "
              f"override with {report_lib.ENV_KEEP})")
    return 0


def cmd_show(args) -> int:
    reports = report_lib.load_reports(args.dir)
    rec = report_lib.find_report(args.run, reports)
    rec = dict(rec)
    if not args.series:
        rec.pop("series", None)
        if isinstance(rec.get("counters"), dict):
            rec["counters"] = {k: v for k, v in rec["counters"].items()
                               if k != "series"}
    print(json.dumps(rec, indent=2, sort_keys=True, default=str))
    return 0


def cmd_diff(args) -> int:
    reports = report_lib.load_reports(args.dir)
    a = report_lib.find_report(args.run_a, reports)
    b = report_lib.find_report(args.run_b, reports)
    d = report_lib.diff_reports(a, b)
    print(f"diff {d['runs'][0]} -> {d['runs'][1]}")
    for section in ("config", "history", "counters"):
        delta = d[section]
        if not delta:
            print(f"  {section}: (no change)")
            continue
        print(f"  {section}:")
        for key, (va, vb) in delta.items():
            print(f"    {key}: {_fmt(va)} -> {_fmt(vb)}")
    print(f"  rounds: {d['rounds'][0]} -> {d['rounds'][1]}   "
          f"stop_round: {d['stop_round'][0]} -> {d['stop_round'][1]}")
    print(f"  only_telemetry: {d['only_telemetry']}")
    return 0


def cmd_timeline(args) -> int:
    reports = report_lib.load_reports(args.dir)
    rec = report_lib.find_report(args.run, reports)
    series = rec.get("series") or {}
    rounds = series.get("round")
    print(f"timeline {rec.get('run_id')} ({rec.get('driver')}, "
          f"{rec.get('rounds')} rounds)")
    if rounds:
        print(f"  recorded rounds {rounds[0]}..{rounds[-1]} "
              f"({len(rounds)} rows)")
    shown = False
    rows = (("gap", True), ("primal", False), ("dp_epsilon", False),
            ("saturation", False), ("ef_norm", True), ("gate", False))
    for key, log in rows:
        vals = series.get(key)
        if not vals:
            continue
        line = sparkline(vals, width=args.width, log=log)
        lo, hi = min(map(float, vals)), max(map(float, vals))
        tag = " (log)" if log else ""
        print(f"  {key:<11} |{line}| min={_fmt(lo)} max={_fmt(hi)}{tag}")
        shown = True
    if not shown:
        print("  (no per-round series in this report — run with "
              "ColaConfig(telemetry=True))")
    return 0


def cmd_smoke(args) -> int:
    """Two tiny telemetry runs + every subcommand over them (CI smoke)."""
    import os

    import jax.numpy as jnp

    from repro import attack, topo as topo_programs
    from repro.core import problems
    from repro.core.cola import ColaConfig, run_cola
    from repro.data import synthetic

    if args.dir:
        os.environ[report_lib.ENV_DIR] = args.dir
    x, y, _ = synthetic.regression(120, 48, seed=1)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), lam=1e-3)
    graph = topo_programs.build("torus2d", 16)
    rounds = 24
    atk = [attack.Byzantine(nodes=(1, 6), mode="sign_flip", scale=10.0,
                            start=4)]
    run_cola(prob, graph, ColaConfig(telemetry=True), rounds)
    run_cola(prob, graph,
             ColaConfig(telemetry=True, wire="int8", robust="trim"),
             rounds, attacks=atk)
    print(f"smoke: 2 telemetry runs appended to "
          f"{report_lib.runs_file(args.dir)}\n")
    ns = argparse.Namespace(dir=args.dir)
    cmd_list(ns)
    print()
    cmd_show(argparse.Namespace(dir=args.dir, run="-1", series=False))
    print()
    cmd_diff(argparse.Namespace(dir=args.dir, run_a="-2", run_b="-1"))
    print()
    cmd_timeline(argparse.Namespace(dir=args.dir, run="-1", width=48))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="query the .repro_runs run registry")
    ap.add_argument("--dir", default=None,
                    help="registry directory (default .repro_runs or "
                         "$REPRO_RUNS_DIR)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="one line per recorded run")
    p = sub.add_parser("show", help="full report record")
    p.add_argument("run", help="run_id prefix or index (-1 = latest)")
    p.add_argument("--series", action="store_true",
                   help="include the per-round series arrays")
    p = sub.add_parser("diff", help="delta between two runs")
    p.add_argument("run_a")
    p.add_argument("run_b")
    p = sub.add_parser("timeline", help="per-round ASCII timeline")
    p.add_argument("run")
    p.add_argument("--width", type=int, default=64)
    sub.add_parser("smoke", help="2 tiny telemetry runs + all subcommands")
    args = ap.parse_args(argv)
    return {"list": cmd_list, "show": cmd_show, "diff": cmd_diff,
            "timeline": cmd_timeline, "smoke": cmd_smoke}[args.cmd](args)
