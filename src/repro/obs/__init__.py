"""``repro.obs`` — run telemetry for COLA drivers.

Three layers, all opt-in (a telemetry-off run executes the exact pre-obs
program, bitwise):

* **On-device counters** (``obs.counters``): a ``Counters`` pytree carried
  through the round-block scan (``ColaConfig.telemetry=True``) accumulating
  per-round wire bytes and collective-permute counts (from the compiled
  plan's contract budget), quant saturation fraction and EF residual norm
  (``repro.core.quant``), and robust-gate edge rejections per sender
  (``repro.core.mixing.gate_flags`` — XLA CSEs the recomputed gate against
  the defended mix, so the counter is free). Totals land in every driver's
  ``history["telemetry"]``.
* **Host tracing** (``obs.trace``): ``span()`` phase timers (driver build,
  block dispatches, bench repeats) with a ``jax.profiler`` annotation
  bridge, driver-cache hit/miss events via ``executor.cache_listener``, and
  Chrome-trace JSON export.
* **Run registry** (``obs.report``): telemetry runs append a ``RunReport``
  JSONL line under ``.repro_runs/`` (env ``REPRO_RUNS_DIR`` overrides);
  ``python -m repro.obs list|show|diff|timeline`` queries it.
"""
from repro.obs.counters import (Counters, init_counters, make_update,
                                round_increments, summarize)
from repro.obs.report import (RunReport, append_report, diff_reports,
                              load_reports, runs_file)
from repro.obs.trace import Tracer, current, span, use

__all__ = [
    "Counters", "RunReport", "Tracer", "append_report", "current",
    "diff_reports", "init_counters", "load_reports", "make_update",
    "round_increments", "runs_file", "span", "summarize", "use",
]
