"""On-device telemetry counters carried through the round-block scan.

``Counters`` rides the executor state (``ColaState.counters``, an optional
field defaulting to ``None`` so telemetry-off pytrees — and programs — are
unchanged). The per-round update is a pure function of the global
(state-before, state-after, schedule-slice) triple, so one implementation
serves the single-host simulator and the shard_map distributed runtime:
every signal is either a static host-derived increment (wire bytes,
ppermute counts — exact, from the compiled plan's contract budget) or a
recomputation of an expression the round body already evaluates (the
step-0 payload encode, the robust-gate flags), which XLA CSEs against the
round's own computation inside the same jitted program.

Semantics to know when reading the numbers:

* ``wire_bytes`` / ``permutes`` model the wire the compiled topology plan
  executes for the run's graph — the simulator's dense matmuls stand in
  for that plan, so its counter equals the contract budget the dist
  lowering is held to (``plan.contract(d, wire=...)``).
* ``sat_sum`` accumulates the saturation fraction of each round's STEP-0
  encode (the honest payload); ``gate`` counts FIRST-step rejections (wire
  attacks only exist on step 0; with the default ``gossip_steps=1`` that
  is every rejection).
* the f32 byte/permute device counters stay exact up to 2^24 increments;
  ``summarize`` therefore reports the exact integer product
  ``rounds x per-round budget`` when the static increments are known.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mixing, quant
from repro.core.cola import _apply_payload_attack


class Counters(NamedTuple):
    """Per-run telemetry accumulators (leaves of the scan carry)."""

    rounds: jax.Array      # i32 () — rounds actually executed (pre-stop)
    wire_bytes: jax.Array  # f32 () — cumulative per-device gossip bytes
    permutes: jax.Array    # f32 () — cumulative collective-permute count
    sat_sum: jax.Array     # f32 () — sum of per-round step-0 saturation
    ef_sq: jax.Array       # f32 () — ||EF residual||^2 after last round
    gate: jax.Array        # (K,) i32 — robust-gate rejections per SENDER


def init_counters(k: int) -> Counters:
    return Counters(rounds=jnp.zeros((), jnp.int32),
                    wire_bytes=jnp.zeros((), jnp.float32),
                    permutes=jnp.zeros((), jnp.float32),
                    sat_sum=jnp.zeros((), jnp.float32),
                    ef_sq=jnp.zeros((), jnp.float32),
                    gate=jnp.zeros((k,), jnp.int32))


def round_increments(graph, d: int, cfg, itemsize: int = 4) -> dict:
    """Static per-round wire budget of the plan compiled for ``graph``.

    Returns ``{"bytes_per_round", "permutes_per_round", "contract",
    "contract_name"}`` — the same ``comm_budget`` numbers the plan's
    ``CommContract`` caps the lowered HLO to, so the telemetry byte counter
    and the checked contract agree by construction.
    """
    from repro.topo import compile_plan
    from repro.topo.lowering import comm_budget

    plan = compile_plan(graph)
    wire = cfg.wire if quant.is_quantized(cfg.wire) else None
    budget = comm_budget(plan, d, itemsize, gossip_steps=cfg.gossip_steps,
                         wire=wire)
    contract = plan.contract(d, itemsize, gossip_steps=cfg.gossip_steps,
                             wire=wire)
    return {"bytes_per_round": int(budget["bytes_per_device"]),
            "permutes_per_round": int(budget["collective_permutes"]),
            "contract": contract.describe(),
            "contract_name": contract.name}


def dist_round_increments(cfg, d: int, *, comm: str, plan=None,
                          conn: int = 1, k: int | None = None,
                          itemsize: int = 4) -> dict:
    """Per-round wire budget of the dist runtime's ACTUAL comm mode.

    ``comm="plan"`` uses the compiled (Block)Plan's contract budget
    (exact); ``"ring"`` counts the banded ppermutes; ``"dense"`` counts the
    all-gather payload per device (no ppermutes).
    """
    wire = cfg.wire if quant.is_quantized(cfg.wire) else None
    if comm == "plan" and plan is not None:
        from repro.topo.lowering import comm_budget
        budget = comm_budget(plan, d, itemsize,
                             gossip_steps=cfg.gossip_steps, wire=wire)
        contract = plan.contract(d, itemsize, gossip_steps=cfg.gossip_steps,
                                 wire=wire)
        return {"bytes_per_round": int(budget["bytes_per_device"]),
                "permutes_per_round": int(budget["collective_permutes"]),
                "contract": contract.describe(),
                "contract_name": contract.name}
    if comm == "ring":
        per = 2 * conn
        pb = quant.payload_bytes(d, cfg.wire)
        return {"bytes_per_round": cfg.gossip_steps * per * pb,
                "permutes_per_round": cfg.gossip_steps * per,
                "contract": f"ring conn={conn}: {per} ppermute(s)/step, "
                            f"{per * pb:,}B/device/step",
                "contract_name": f"ring-c{conn}-d{d}"}
    # dense all-gather fallback: each device receives the full K-row stack
    kk = int(k or 0)
    pb = quant.payload_bytes(d, cfg.wire, rows=max(kk, 1))
    return {"bytes_per_round": cfg.gossip_steps * pb,
            "permutes_per_round": 0,
            "contract": f"dense all-gather: {pb:,}B/device/step",
            "contract_name": f"dense-K{kk}-d{d}"}


def make_update(cfg, k: int, inc: dict):
    """Build the per-round counter update for one run.

    Returns ``update(before, after, s_t, atk, w) -> (Counters, obs_row)``
    where ``before``/``after`` are the (global-array) ColaStates around one
    executed round, ``s_t`` the round's schedule slice, ``atk`` the round's
    attack operand dict (or None) and ``w`` the round's (K, K) mixing
    matrix — None is only legal when ``cfg.robust`` is off (a comm path
    that lowered W away must reconstruct it, e.g. via
    ``topo.plan.w_from_coefficients_device``, before the gate recompute;
    silently skipping would report zero rejections for a defended run).
    ``obs_row`` is the f32 (3,) per-round series row
    ``[saturation, ef_norm, gate_total]``.
    """
    quantized = quant.is_quantized(cfg.wire)
    b_inc = jnp.float32(inc["bytes_per_round"])
    p_inc = jnp.float32(inc["permutes_per_round"])
    row_ids = jnp.arange(k)
    if cfg.robust is not None and not hasattr(cfg, "robust_trim"):
        raise ValueError("robust config without trim/clip knobs")

    def step0_key(s_t):
        return (quant.step_key(s_t["qkey"], 0) if "qkey" in s_t else None)

    def update(before, after, s_t, atk, w):
        c = before.counters
        # -- quant signals: saturation of the step-0 payload ---------------
        if quantized:
            if cfg.pipeline and before.buf is not None:
                q = before.buf[0]  # payload pre-encoded last round
            else:
                p = (before.v_stack if before.ef is None
                     else before.v_stack + before.ef)
                q, _ = quant.quantize_rows(p, cfg.wire, step0_key(s_t))
            sat_t = quant.saturation_frac(q, cfg.wire)
        else:
            sat_t = jnp.float32(0.0)
        ef_sq = (jnp.float32(0.0) if after.ef is None
                 else jnp.sum(jnp.square(after.ef)).astype(jnp.float32))
        # -- robust-gate rejections: recompute the exact gate the defended
        # mix applied this round (step 0) — same helpers, so XLA CSEs it
        gate_t = jnp.zeros((k,), jnp.int32)
        if cfg.robust is not None and w is None:
            raise ValueError(
                "telemetry gate recompute needs the round's (K, K) mixing "
                f"matrix but the comm path supplied none with robust="
                f"{cfg.robust!r} — reconstruct it from the lowered schedule "
                "(topo.plan.w_from_coefficients_device on plan_diag/"
                "plan_coefs) instead of dropping gate counts to zero")
        if cfg.robust is not None:
            v_send = _apply_payload_attack(before.v_stack, atk)
            if quantized:
                key0 = step0_key(s_t)
                _, _, deq_self, _ = quant.encode(before.v_stack, cfg.wire,
                                                 key0, None, before.ef)
                if v_send is before.v_stack:
                    stack, ov = deq_self, None
                else:
                    p_atk = (v_send if before.ef is None
                             else v_send + before.ef)
                    qa, sa = quant.quantize_rows(p_atk, cfg.wire, key0)
                    stack, ov = quant.dequantize(qa, sa), deq_self
            else:
                stack = v_send
                ov = None if v_send is before.v_stack else before.v_stack
            flat = stack.reshape(k, -1)
            flags = mixing.gate_flags(
                jnp.asarray(w, flat.dtype), flat, row_ids, cfg.robust,
                trim=cfg.robust_trim, clip=cfg.robust_clip,
                self_override=None if ov is None else ov.reshape(k, -1))
            gate_t = jnp.sum(flags, axis=0).astype(jnp.int32)  # per sender
        obs_row = jnp.stack([sat_t, jnp.sqrt(ef_sq),
                             jnp.sum(gate_t).astype(jnp.float32)])
        new = Counters(rounds=c.rounds + 1,
                       wire_bytes=c.wire_bytes + b_inc,
                       permutes=c.permutes + p_inc,
                       sat_sum=c.sat_sum + sat_t,
                       ef_sq=ef_sq,
                       gate=c.gate + gate_t)
        return new, obs_row

    return update


def summarize(counters: Counters, inc: dict | None = None, *,
              series=None, stop_round=None, dishonest=None) -> dict:
    """Host-side counter totals for ``history["telemetry"]`` / RunReport.

    ``inc`` (the static per-round increments) upgrades the f32 device byte
    and permute counters to exact integer products; ``dishonest`` (the
    materialized (T, K) ``atk_dishonest`` schedule entry) splits the gate
    counts into honest vs dishonest sender columns; ``series`` is the
    stacked (T, 3) per-round obs rows from the executor aux.
    """
    c = jax.device_get(counters)
    n = int(c.rounds)
    gate = np.asarray(c.gate).astype(int)
    out = {
        "rounds": n,
        "wire_bytes": int(round(float(c.wire_bytes))),
        "permutes": int(round(float(c.permutes))),
        "saturation_mean": float(c.sat_sum) / max(n, 1),
        "ef_norm": float(np.sqrt(float(c.ef_sq))),
        "gate_rejections": gate.tolist(),
        "gate_total": int(gate.sum()),
        "stop_round": stop_round,
    }
    if inc is not None:
        # exact integer totals — the f32 device counters lose exactness
        # past 2^24 increments, the host product never does
        out["wire_bytes"] = n * int(inc["bytes_per_round"])
        out["permutes"] = n * int(inc["permutes_per_round"])
        out["contract"] = inc["contract"]
    if dishonest is not None:
        bad = np.any(np.asarray(dishonest).astype(bool), axis=0)
        out["dishonest_nodes"] = np.nonzero(bad)[0].tolist()
        out["gate_dishonest"] = int(gate[bad].sum())
        out["gate_honest"] = int(gate[~bad].sum())
    if series is not None:
        s = np.asarray(jax.device_get(series))
        m = min(n, s.shape[0])
        out["series"] = {"saturation": s[:m, 0].astype(float).tolist(),
                         "ef_norm": s[:m, 1].astype(float).tolist(),
                         "gate": s[:m, 2].astype(int).tolist()}
    return out


def render_footprint(k: int, axis: str = "nodes") -> str:
    """Counter pspec footprint for ``dryrun --plan``: each leaf's shape,
    dtype, bytes and the ``dist.sharding.cola_counters_pspecs`` placement
    it gets on a device mesh."""
    from repro.dist import sharding as shard_specs

    cts = init_counters(k)
    specs = shard_specs.cola_counters_pspecs(axis)
    lines = [f"[obs counters] K={k} (ColaConfig.telemetry=True carry)"]
    total = 0
    for name, leaf, spec in zip(Counters._fields, cts, specs):
        nbytes = leaf.size * leaf.dtype.itemsize
        total += nbytes
        shape = "x".join(map(str, leaf.shape)) or "scalar"
        lines.append(f"  {name:<11} {shape:<8} {leaf.dtype.name:<8} "
                     f"{nbytes:>6,}B  pspec={spec}")
    lines.append(f"  total {total:,}B per run (donated with the state)")
    return "\n".join(lines)
