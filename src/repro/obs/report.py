"""Run registry: RunReport records appended as JSONL under ``.repro_runs/``.

Every telemetry run (``ColaConfig.telemetry=True``) emits one ``RunReport``
— config + content-addressed problem fingerprint, the plan contract line,
counter totals, span timings and a history summary plus compact per-round
series — appended to ``<runs dir>/runs.jsonl``. The directory defaults to
``.repro_runs`` under the CWD; the ``REPRO_RUNS_DIR`` env var overrides it
(tests point it at a tmpdir), and setting it to ``0``/``off`` disables
auto-emission entirely.

``diff_reports`` separates what changed into config / counters / history
deltas: two runs differing only in ``telemetry`` itself (the bitwise-twin
check) diff to an empty history delta and a config delta touching only
telemetry fields.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Any

DEFAULT_DIR = ".repro_runs"
ENV_DIR = "REPRO_RUNS_DIR"
#: retention cap on registry lines; ``REPRO_RUNS_KEEP`` overrides (0 or a
#: negative value disables pruning entirely)
ENV_KEEP = "REPRO_RUNS_KEEP"
DEFAULT_KEEP = 200

#: report fields that describe telemetry itself, not the computation — the
#: diff classifier (and the bitwise-twin acceptance check) keys off this
TELEMETRY_FIELDS = ("counters", "spans", "series", "run_id", "timestamp")
#: config knobs that only toggle observation, never the math
TELEMETRY_CONFIG_KEYS = ("telemetry",)


def runs_dir(path: str | None = None) -> str:
    return path or os.environ.get(ENV_DIR) or DEFAULT_DIR


def runs_file(path: str | None = None) -> str:
    return os.path.join(runs_dir(path), "runs.jsonl")


def pruned_file(path: str | None = None) -> str:
    """Sidecar holding the cumulative count of retention-pruned lines."""
    return os.path.join(runs_dir(path), "runs.pruned")


def pruned_total(dir: str | None = None) -> int:
    """How many registry lines retention has dropped over this registry's
    lifetime (what ``obs list`` surfaces so pruning is never silent)."""
    try:
        with open(pruned_file(dir)) as f:
            return int(f.read().strip() or 0)
    except (FileNotFoundError, ValueError):
        return 0


@dataclasses.dataclass
class RunReport:
    """One run's record (the JSONL line, 1:1 with ``to_dict``)."""

    run_id: str
    timestamp: float
    driver: str                 # run_cola | run_dist_cola | gossip | ...
    problem: str                # executor.fingerprint of the Problem
    config: dict                # dataclasses.asdict of the run config
    graph: dict                 # {"kind", "num_nodes"}
    rounds: int                 # rounds executed
    contract: str | None        # plan contract line (counter byte budget)
    history: dict               # summary: final row values, stop_round, ...
    counters: dict | None       # obs.counters.summarize totals
    spans: dict | None          # obs.trace Tracer.summary()
    series: dict | None         # compact per-round series for `timeline`

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "RunReport":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: d.get(k) for k in known})


def _history_summary(history: dict) -> dict:
    final: dict = {}
    for key, val in history.items():
        if key in ("telemetry", "round") or not isinstance(val, list):
            continue
        if val:
            final[key] = float(val[-1])
    return {"rounds_recorded": len(history.get("round", [])),
            "final": final,
            "stop_round": history.get("stop_round"),
            "certificate_violated": history.get("certificate_violated")}


def _series(history: dict, telemetry: dict | None) -> dict:
    out: dict = {}
    if history.get("round"):
        out["round"] = [int(t) for t in history["round"]]
        for key in ("gap", "primal", "consensus", "dp_epsilon"):
            if isinstance(history.get(key), list) and history[key]:
                out[key] = [float(v) for v in history[key]]
    if telemetry and isinstance(telemetry.get("series"), dict):
        out.update(telemetry["series"])
    return out


def make_report(*, driver: str, problem_fp: str, config: dict, graph: dict,
                rounds: int, history: dict, contract: str | None = None,
                counters: dict | None = None,
                spans: dict | None = None) -> RunReport:
    telemetry = history.get("telemetry")
    if counters is None and isinstance(telemetry, dict):
        counters = {k: v for k, v in telemetry.items() if k != "series"}
    body = {"driver": driver, "problem": problem_fp, "config": config,
            "graph": graph, "rounds": rounds}
    ts = time.time()
    run_id = hashlib.sha256(
        (json.dumps(body, sort_keys=True, default=str)
         + repr(ts)).encode()).hexdigest()[:12]
    return RunReport(run_id=run_id, timestamp=ts, contract=contract,
                     history=_history_summary(history),
                     counters=counters, spans=spans,
                     series=_series(history, telemetry), **body)


def retention_limit(keep: int | None = None) -> int:
    """Registry line cap (``REPRO_RUNS_KEEP``, default ``DEFAULT_KEEP``);
    ``<= 0`` means unbounded."""
    if keep is not None:
        return keep
    raw = os.environ.get(ENV_KEEP, "")
    try:
        return int(raw) if raw else DEFAULT_KEEP
    except ValueError:
        raise ValueError(
            f"{ENV_KEEP}={raw!r} is not an integer (want a line cap, "
            "or <= 0 to disable registry pruning)")


def prune_registry(dir: str | None = None, *,
                   keep: int | None = None) -> int:
    """Drop the OLDEST registry lines past the retention cap.

    Returns how many lines were pruned (0 when under the cap or pruning is
    disabled). Appending is the hot path, so the rewrite only happens on
    the appends that actually overflow; order is preserved, which keeps
    ``find_report`` index references stable for the surviving tail.
    """
    limit = retention_limit(keep)
    if limit <= 0:
        return 0
    path = runs_file(dir)
    if not os.path.exists(path):
        return 0
    with open(path) as f:
        lines = [ln for ln in f if ln.strip()]
    excess = len(lines) - limit
    if excess <= 0:
        return 0
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.writelines(lines[excess:])
    os.replace(tmp, path)
    total = pruned_total(dir) + excess
    with open(pruned_file(dir), "w") as f:
        f.write(str(total))
    return excess


def append_report(report: RunReport | dict, dir: str | None = None, *,
                  keep: int | None = None) -> str:
    """Append one report line to the registry; returns the JSONL path.

    Enforces the retention cap (``REPRO_RUNS_KEEP``, default
    ``DEFAULT_KEEP`` lines) by pruning oldest-first after the append, so
    an always-on telemetry fleet cannot grow the JSONL without bound."""
    d = runs_dir(dir)
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, "runs.jsonl")
    rec = report.to_dict() if isinstance(report, RunReport) else report
    with open(path, "a") as f:
        f.write(json.dumps(rec, sort_keys=True, default=str) + "\n")
    prune_registry(dir, keep=keep)
    return path


def auto_emit(report: RunReport, dir: str | None = None) -> str | None:
    """Registry append for telemetry runs; disabled when ``REPRO_RUNS_DIR``
    is set to ``0``/``off``/``none``."""
    env = os.environ.get(ENV_DIR, "")
    if dir is None and env.lower() in ("0", "off", "none") :
        return None
    return append_report(report, dir)


def load_reports(dir: str | None = None) -> list:
    path = runs_file(dir)
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def find_report(ref: str, reports: list) -> dict:
    """Resolve a CLI run reference: a run_id prefix, or a 0-based index
    (negative counts from the end: ``-1`` is the latest run)."""
    try:
        return reports[int(ref)]
    except (ValueError, IndexError):
        pass
    hits = [r for r in reports if str(r.get("run_id", "")).startswith(ref)]
    if len(hits) == 1:
        return hits[0]
    if not hits:
        raise KeyError(f"no run matching {ref!r} "
                       f"({len(reports)} runs in registry)")
    raise KeyError(f"ambiguous run reference {ref!r}: "
                   + ", ".join(r["run_id"] for r in hits))


def _delta(a: dict | None, b: dict | None, *, skip: tuple = ()) -> dict:
    a, b = a or {}, b or {}
    out = {}
    for key in sorted(set(a) | set(b)):
        if key in skip:
            continue
        va, vb = a.get(key), b.get(key)
        if va != vb:
            out[key] = (va, vb)
    return out


def diff_reports(a: dict, b: dict) -> dict:
    """Structured delta between two report records.

    ``only_telemetry`` is True when the runs computed the same thing — the
    history summary matches exactly and every differing config knob is a
    telemetry toggle — i.e. observation changed, the math did not.
    """
    cfg = _delta(a.get("config"), b.get("config"))
    hist = _delta((a.get("history") or {}).get("final"),
                  (b.get("history") or {}).get("final"))
    counters = _delta(a.get("counters"), b.get("counters"),
                      skip=("series",))
    stop = ((a.get("history") or {}).get("stop_round"),
            (b.get("history") or {}).get("stop_round"))
    return {
        "runs": (a.get("run_id"), b.get("run_id")),
        "config": cfg,
        "history": hist,
        "counters": counters,
        "rounds": (a.get("rounds"), b.get("rounds")),
        "stop_round": stop,
        "only_telemetry": (not hist and stop[0] == stop[1]
                           and a.get("rounds") == b.get("rounds")
                           and set(cfg) <= set(TELEMETRY_CONFIG_KEYS)),
    }
