"""Gossip data-parallelism: CoLA's decentralized communication pattern as a
first-class optimizer feature for the deep architectures in the zoo.

Instead of the canonical all-reduce of gradients, K nodes (mesh shards / pods)
each hold their OWN model replica, take local optimizer steps on local data,
and mix parameters with a doubly-stochastic Metropolis matrix over the node
graph — exactly Algorithm 1's communication step applied to the parameter
vector (the decentralized-SGD analogue the paper's Related Work situates CoLA
against, with CoLA's elasticity semantics carried over):

* per-round communication is O(deg(k) * |params|) neighbor exchanges
  (``lax.ppermute`` ring) instead of a global all-reduce — on a multi-pod
  deployment this removes the slow cross-pod collective from the critical
  path;
* nodes can drop (their replica freezes, W re-normalizes over the survivors)
  and re-join (re-initialized from a neighbor average) without any global
  coordination — the Fig. 4 fault-tolerance experiment for deep nets.

Two execution paths with identical semantics (validated in tests):
``vmap`` (single host, node axis stacked) and GSPMD/ppermute (node axis on a
mesh axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import executor as exec_engine, metrics as metrics_lib, \
    mixing, quant, topology as topo
from repro.optim import privacy


@dataclasses.dataclass(frozen=True)
class GossipConfig:
    """Node graph + mixing schedule for gossip-DP."""

    num_nodes: int
    topology: str = "ring"        # any key of topology.TOPOLOGIES
    gossip_steps: int = 1         # B mixing applications per round (App. E.2)
    mix_every: int = 1            # local steps between gossip rounds
    # Byzantine-resilient aggregation of the neighbor replicas — the same
    # robust mixing layer CoLA's v-aggregation uses (repro.core.mixing);
    # dense (vmap/GSPMD) path only: the ppermute ring folds W^B and has no
    # per-neighborhood buffer to aggregate over
    robust: str | None = None     # None | "trim" | "median" | "clip"
    robust_trim: int = 1
    robust_clip: float | None = None
    # parameter-payload codec on the gossip wire ("fp32" | "int8" | "fp8" |
    # "fp8_e5m2", see repro.core.quant): every emitted replica — the own
    # diagonal term included — goes through quantize-dequantize before the
    # mix, cutting the per-link parameter traffic ~4x. STATELESS: the
    # gossip-SGD mixer re-quantizes fresh values every mix round (no error-
    # feedback carry; the local optimizer steps between rounds already
    # decorrelate the rounding error). Dense path only, like robust/dp.
    wire: str = "fp32"

    def graph(self) -> topo.Topology:
        return topo.TOPOLOGIES[self.topology](self.num_nodes)

    def weights(self, active: np.ndarray | None = None) -> np.ndarray:
        g = self.graph()
        if active is None:
            return topo.metropolis_weights(g)
        return topo.reweight_for_active(g, active)


def mix_pytree(w: jax.Array, stacked: Any, steps: int = 1) -> Any:
    """Apply the gossip matrix to every leaf of a (K, ...)-stacked pytree."""
    def mix_leaf(p):
        out = p
        for _ in range(steps):
            out = mixing.dense_mix(w, out)
        return out.astype(p.dtype)
    return jax.tree.map(mix_leaf, stacked)


def ring_mix_pytree(stacked_local: Any, axis: str, band: jax.Array,
                    conn: int, steps: int = 1) -> Any:
    """ppermute ring mixing of per-node param shards (inside shard_map)."""
    def mix_leaf(p):
        out = p[0]
        for _ in range(steps):
            out = mixing.ring_mix_ppermute(out, axis, band, conn)
        return out[None].astype(p.dtype)
    return jax.tree.map(mix_leaf, stacked_local)


def robust_mix_pytree(w: jax.Array, stacked: Any, mode: str, *,
                      trim: int = 1, clip: float | None = None,
                      steps: int = 1) -> Any:
    """Byzantine-resilient gossip over a (K, ...)-stacked pytree: each leaf
    flattens to a (K, d) stack and goes through the same
    ``mixing.robust_mix_steps`` aggregation CoLA's v-mixing uses."""
    def mix_leaf(p):
        flat = p.reshape(p.shape[0], -1)
        out = mixing.robust_mix_steps(w, flat, mode, trim=trim, clip=clip,
                                      steps=steps)
        return out.reshape(p.shape).astype(p.dtype)
    return jax.tree.map(mix_leaf, stacked)


def _param_mixer(gcfg: GossipConfig, mesh, axis: str | None,
                 conn: int | None,
                 dp: privacy.DPConfig | None = None) -> Callable:
    """``mix(w, params, key=None) -> params`` applying the B gossip steps —
    the ONE mixing dispatch both gossip drivers (per-round
    ``make_gossip_step`` and the block runner) share: dense (K, K) pytree
    mix without a mesh (optionally robust and/or DP-noised), banded
    ``ppermute`` ring under shard_map with one (circulant W of connectivity
    ``conn``). ``key`` is consumed only by the DP wire mechanism."""
    if gcfg.robust is not None and mesh is not None:
        raise ValueError(
            "robust= gossip needs the dense path: the ppermute ring folds "
            "W^B and exposes no per-neighborhood buffer (drop mesh/axis)")
    wired = quant.is_quantized(gcfg.wire)
    if wired:
        if mesh is not None:
            raise ValueError(
                "wire= gossip quantization is implemented on the dense path "
                "— the ppermute ring folds W^B and has no codec lowering "
                "(drop mesh/axis)")
        if gcfg.robust is not None:
            raise ValueError(
                "wire= with robust= is unsupported: the robust aggregators "
                "consume raw neighbor stacks, not codec payloads")
    if dp is not None:
        if mesh is not None:
            raise ValueError("dp= gossip is implemented on the dense path "
                             "(drop mesh/axis)")
        if gcfg.robust is not None:
            raise ValueError(
                "dp= with robust= is unsupported: per-link noise gives "
                "every receiver a distinct wire view, which the shared "
                "neighborhood buffer of the robust aggregation cannot "
                "represent")

    def mix(w, params, key=None):
        if dp is not None:
            return privacy.noisy_dense_mix(w, params, dp, key,
                                           gcfg.gossip_steps,
                                           wire_codec=gcfg.wire)
        if mesh is None:
            if gcfg.robust is not None:
                return robust_mix_pytree(w, params, gcfg.robust,
                                         trim=gcfg.robust_trim,
                                         clip=gcfg.robust_clip,
                                         steps=gcfg.gossip_steps)
            if wired:
                # stateless wire view per gossip step: every emission —
                # including the node's own diagonal term — is quantize-
                # dequantized before the linear mix (round-to-nearest:
                # the non-DP drivers pass no key)
                out = params
                for s in range(gcfg.gossip_steps):
                    k_s = (None if key is None
                           else quant.wire_stream(jax.random.fold_in(key, s)))
                    out = mix_pytree(
                        w, quant.wire_view_pytree(out, gcfg.wire, k_s), 1)
                return out
            return mix_pytree(w, params, gcfg.gossip_steps)
        band = mixing.banded_weights(w, conn or 1)
        shard = mixing.shard_map(
            lambda p: ring_mix_pytree(p, axis, band, conn or 1,
                                      gcfg.gossip_steps),
            mesh, in_specs=P(axis), out_specs=P(axis))
        return shard(params)

    return mix


def make_gossip_step(local_step: Callable, gcfg: GossipConfig, *,
                     mesh=None, axis: str | None = None,
                     conn: int | None = None,
                     dp: privacy.DPConfig | None = None) -> Callable:
    """Wrap a local (state, batch) -> (state, metrics) step with gossip mixing.

    Returns step(states, batches, w, active) operating on (K, ...)-stacked
    state/batch pytrees:

      1. every ACTIVE node runs ``local_step`` on its local shard of data
         (frozen nodes keep their state — the paper's Theta_k = 1 model);
      2. parameters are gossip-mixed ``gossip_steps`` times with ``w``.

    With ``mesh``/``axis`` the mixing runs as a ppermute ring under a
    shard_map over that axis (requires circulant W of connectivity ``conn``);
    otherwise a dense (K,K) mix (vmap/GSPMD path, any W) — optionally
    Byzantine-robust (``gcfg.robust``) or DP-noised (``dp=``, see
    ``repro.optim.privacy``; pass the round index as ``dp_round`` so the
    key schedule stays reproducible, and account one
    ``dp.releases_per_mix_round(...)`` batch per mixed round).
    """
    mix_params = _param_mixer(gcfg, mesh, axis, conn, dp)
    base_key = None if dp is None else jax.random.PRNGKey(dp.seed)

    def step(states, batches, w, active, do_mix=True, dp_round=0):
        new_states, metrics = jax.vmap(local_step)(states, batches)
        keep = lambda new, old: jax.tree.map(
            lambda a, b: jnp.where(
                active.reshape((-1,) + (1,) * (a.ndim - 1)) > 0, a, b),
            new, old)
        new_states = keep(new_states, states)
        if not do_mix:
            # mix_every > 1: local steps between gossip rounds — divides the
            # communication volume by mix_every at a Theta-quantified
            # convergence cost (App. E.2 in reverse)
            return new_states, metrics
        key = (None if base_key is None
               else jax.random.fold_in(base_key, dp_round))
        return new_states._replace(
            params=mix_params(w, new_states.params, key)), metrics

    return jax.jit(step, static_argnames=("do_mix",))


def mix_schedule(rounds: int, mix_every: int) -> np.ndarray:
    """(T,) bool: gossip-mix on every ``mix_every``-th round (the last round
    of each local-step window), i.e. ``(t + 1) % mix_every == 0``."""
    return (np.arange(rounds) + 1) % mix_every == 0


def make_gossip_block_runner(local_step: Callable, gcfg: GossipConfig, *,
                             mesh=None, axis: str | None = None,
                             conn: int | None = None,
                             recorder=None,
                             dp: privacy.DPConfig | None = None,
                             telemetry: bool = False) -> Callable:
    """Round-block gossip-DP: many local-step+mix rounds per device dispatch.

    The per-round ``make_gossip_step`` path dispatches one jitted program per
    round from Python; this runner drives the identical round body through
    the shared scan executor (``repro.core.executor``) instead — batches,
    mixing matrices, active masks and mix flags are pre-staged as stacked
    (T, ...) schedule arrays, and per-round train metrics come back stacked
    in one end-of-run fetch.

    Both communication paths share the engine: the default dense (K, K) mix
    on vmap-stacked replicas, and — with ``mesh``/``axis`` — the
    shard_map/``lax.ppermute`` ring over that mesh axis (circulant W of
    connectivity ``conn``, exactly as in ``make_gossip_step``).

    A ``repro.core.metrics`` Recorder (e.g. ``ConsensusRecorder``) adds
    on-device eval rows over the replica stack — with a stop condition the
    engine short-circuits remaining rounds exactly as in the CoLA drivers
    (consensus-driven early exit).

    Returns ``run(states, batches, w, active, mix, *, block_size=32,
    record_mask=None)`` with
      batches: (T, K, ...) stacked batch pytree,
      w:       (T, K, K) per-round mixing matrices,
      active:  (T, K) participation masks,
      mix:     (T,) bool gossip-mix flags (see ``mix_schedule``),
    returning (states, metrics) — metrics leaves are (T, ...) stacks — or,
    when a recorder is set, (states, metrics, history).

    With ``dp=`` every mixed round applies the clipped Gaussian wire
    mechanism (``repro.optim.privacy``) with a per-round folded key, and
    the returned history (recorder path) gains ``dp_epsilon`` — the
    cumulative zCDP-accounted epsilon at each recorded round, counting
    ``gossip_steps * deg_max`` releases per mixed round under per-link
    noise — plus a ``dp`` summary dict (final epsilon/rho/releases).
    NOTE: ``states`` buffers are donated — do not reuse the argument.
    """
    mix_params = _param_mixer(gcfg, mesh, axis, conn, dp)
    base_key = None if dp is None else jax.random.PRNGKey(dp.seed)
    if telemetry and recorder is None:
        raise ValueError("telemetry=True needs a recorder (the history "
                         "carries the counters and the dp_epsilon series)")

    def step_fn(states, _ctx, sched_t):
        new_states, metrics = jax.vmap(local_step)(states, sched_t["batch"])
        active = sched_t["active"]
        keep = jax.tree.map(
            lambda a, b: jnp.where(
                active.reshape((-1,) + (1,) * (a.ndim - 1)) > 0, a, b),
            new_states, states)
        key = (None if base_key is None
               else jax.random.fold_in(base_key, sched_t["dp_round"]))
        mixed = lax.cond(
            sched_t["mix"],
            lambda p: mix_params(sched_t["w"], p, key),
            lambda p: p, keep.params)
        return keep._replace(params=mixed), metrics

    def run(states, batches, w, active, mix, *, block_size: int = 32,
            record_mask=None):
        sched = {"batch": batches, "w": w, "active": active, "mix": mix}
        if dp is not None:
            # per-round key index: noise draws are a function of the round,
            # not of block boundaries or early stopping
            sched["dp_round"] = np.arange(len(np.asarray(mix)))
        run_tr = None
        if telemetry:
            # per-replica parameter payload: the gossip wire moves whole
            # replicas, so the modeled budget is params x codec bytes per
            # emission — K emissions per mixed round on the dense path
            # (the all-gather oracle view), 2*conn ppermutes on the ring
            pcount = int(sum(np.prod(leaf.shape[1:])
                             for leaf in jax.tree.leaves(states.params)))
            pb = quant.payload_bytes(pcount, gcfg.wire)
            if mesh is None:
                per_mix = gcfg.gossip_steps * gcfg.num_nodes * pb
                permutes_mix = 0
                contract = (f"gossip dense x{gcfg.gossip_steps}: "
                            f"{per_mix:,}B/device/mixed-round "
                            f"({pcount:,} params, wire={gcfg.wire})")
            else:
                c = conn or 1
                per_mix = gcfg.gossip_steps * 2 * c * pb
                permutes_mix = gcfg.gossip_steps * 2 * c
                contract = (f"gossip ring conn={c}: {per_mix:,}B/device/"
                            f"mixed-round ({pcount:,} params)")
            from repro.obs import trace as obs_trace
            with obs_trace.use(obs_trace.Tracer()) as run_tr, \
                    run_tr.attach():
                res = exec_engine.run_round_blocks(step_fn, states, sched,
                                                   recorder=recorder,
                                                   record_mask=record_mask,
                                                   block_size=block_size)
        else:
            res = exec_engine.run_round_blocks(step_fn, states, sched,
                                               recorder=recorder,
                                               record_mask=record_mask,
                                               block_size=block_size)
        if recorder is None:
            return res.state, res.aux
        history = metrics_lib.history_from(recorder, res)
        if dp is not None:
            mix_host = np.asarray(mix, dtype=bool)
            rounds_rec = np.asarray(history["round"], dtype=np.int64)
            cum = np.cumsum(mix_host)
            history["dp_epsilon"] = privacy.epsilon_schedule(
                dp, gcfg.graph(), gcfg.gossip_steps,
                cum[np.clip(rounds_rec, 0, len(cum) - 1)]).tolist()
            final = privacy.GaussianAccountant(dp.sigma, dp.delta).add(
                int(cum[-1]) * dp.releases_per_mix_round(gcfg.graph(),
                                                         gcfg.gossip_steps))
            history["dp"] = {
                "clip": dp.clip, "sigma": dp.sigma, "delta": dp.delta,
                "per_link": dp.per_link, "releases": final.releases,
                "rho": final.rho, "epsilon": final.epsilon()}
        if telemetry:
            from repro.obs import report as obs_report
            mixed = int(np.asarray(mix, dtype=bool).sum())
            t_total = int(np.asarray(mix).shape[0])
            history["telemetry"] = {
                "rounds": t_total, "mixed_rounds": mixed,
                "wire_bytes": mixed * per_mix,
                "permutes": mixed * permutes_mix,
                "contract": contract, "stop_round": res.stop_round}
            if dp is not None and history.get("dp_epsilon"):
                history["telemetry"]["dp_epsilon"] = \
                    float(history["dp_epsilon"][-1])
            obs_report.auto_emit(obs_report.make_report(
                driver="gossip",
                problem_fp=exec_engine.fingerprint(gcfg),
                config=dataclasses.asdict(gcfg),
                graph={"kind": gcfg.topology,
                       "num_nodes": gcfg.num_nodes},
                rounds=t_total, history=history, contract=contract,
                spans=run_tr.summary()))
        return res.state, res.aux, history

    return run


@dataclasses.dataclass(frozen=True)
class ConsensusRecorder:
    """Recorder over the (K, ...)-stacked replica state: the deep-net
    consensus distance (Fig. 5 analogue), with optional early stop once the
    replicas agree to ``eps`` (e.g. after a final full-averaging round)."""

    eps: float | None = None

    labels = ("consensus_distance",)

    def record_fn(self, states) -> jax.Array:
        return jnp.stack([consensus_distance(states.params)])

    @property
    def stop_fn(self):
        if self.eps is None:
            return None
        eps = self.eps
        return lambda row: row[0] <= eps

    def init_spec(self) -> dict:
        return {}

    def cache_token(self):
        return ("ConsensusRecorder", self.eps)

    def collective_footprint(self, k, d, n_k, itemsize=4, comm="dense",
                             conn=1) -> dict:
        return {"all-gather": 0, "all-reduce": 2 * itemsize,
                "collective-permute": 0}


def replicate_state(state: Any, k: int) -> Any:
    """Stack K identical replicas on a new leading node axis."""
    return jax.tree.map(lambda p: jnp.broadcast_to(p[None], (k,) + p.shape),
                        state)


def consensus_distance(params_stack: Any) -> jax.Array:
    """sum_k ||p_k - p_bar||^2 over all leaves — the deep-net analogue of the
    paper's consensus violation (Fig. 5)."""
    def leaf(p):
        mean = jnp.mean(p, axis=0, keepdims=True)
        return jnp.sum((p.astype(jnp.float32) - mean.astype(jnp.float32))**2)
    return sum(jax.tree.leaves(jax.tree.map(leaf, params_stack)))


def average_params(params_stack: Any) -> Any:
    return jax.tree.map(lambda p: jnp.mean(p, axis=0), params_stack)
