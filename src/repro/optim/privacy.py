"""Differential privacy for the gossip parameter exchange.

Every gossip mix round each node EMITS its (clipped) parameter vector onto
the wire; an eavesdropper on the links (``repro.attack.Eavesdropper`` is the
in-repo threat model) sees one Gaussian-noised copy per release. This module
provides the two pieces the gossip drivers need:

* the mechanism: per-node L2 clipping to ``clip`` plus Gaussian wire noise
  with std ``sigma * 2 * clip`` — the L2 sensitivity of a clipped emission
  under replace-one-node adjacency is ``2 * clip``;
* the accounting: a zero-concentrated-DP (zCDP) Gaussian accountant
  [Bun & Steinke 2016]. Each release with noise multiplier ``sigma`` costs
  ``rho = 1 / (2 sigma^2)``; rho composes additively, and converts to
  ``(epsilon, delta)`` via ``epsilon = rho + 2 sqrt(rho ln(1/delta))``.

The release count is where per-LINK noise differs from per-round noise, and
is the contract the gossip drivers must get right:

* ``per_link=True`` (plan/ppermute-style gossip — each directed edge
  carries an independent draw): an adversary observing all links sees
  ``deg_max`` independent noisy copies per emission, so one mix round of
  B gossip steps costs ``B * deg_max`` releases per node.
* ``per_link=False`` (broadcast gossip — one draw shared by all of a
  node's neighbors): ``B`` releases per mix round.

Noise is injected on the WIRE only: the off-diagonal W terms. A node's own
``w_kk`` contribution never leaves the node and stays noiseless.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Gaussian-mechanism parameters for the gossip wire.

    ``clip``     — per-node L2 bound on the emitted parameter vector (the
                   whole pytree, flattened) enforced before every emission;
    ``sigma``    — noise multiplier: wire noise std is ``sigma * 2 * clip``;
    ``delta``    — target delta for the (epsilon, delta) conversion;
    ``per_link`` — independent draw per directed link (True, matches plan
                   gossip) vs one draw broadcast to all neighbors (False);
    ``seed``     — root of the jax.random key schedule (keys are folded
                   with the round index and gossip step, so the noise
                   stream is reproducible and schedule-independent).
    """

    clip: float
    sigma: float
    delta: float = 1e-5
    per_link: bool = True
    seed: int = 0

    def __post_init__(self):
        if self.clip <= 0 or self.sigma <= 0:
            raise ValueError("DPConfig needs clip > 0 and sigma > 0")
        if not (0 < self.delta < 1):
            raise ValueError(f"delta must be in (0, 1), got {self.delta}")

    @property
    def sensitivity(self) -> float:
        # replace-one-node adjacency: two clipped vectors differ by <= 2*clip
        return 2.0 * self.clip

    @property
    def noise_std(self) -> float:
        return self.sigma * self.sensitivity

    def releases_per_mix_round(self, graph, gossip_steps: int) -> int:
        """Gaussian releases per node per gossip-mix round: every gossip
        step re-emits, and per-link noise hands each of up to ``deg_max``
        neighbors an independent copy."""
        return gossip_steps * (max_degree(graph) if self.per_link else 1)


def max_degree(graph) -> int:
    """Largest neighbor count (excluding self) in the topology."""
    adj = np.asarray(graph.adjacency, dtype=bool)
    np.fill_diagonal(adj, False)
    return int(adj.sum(axis=1).max())


class GaussianAccountant:
    """Additive zCDP composition for repeated Gaussian releases.

    ``add(n)`` registers n releases at noise multiplier ``sigma``;
    ``epsilon()`` converts the accumulated rho to epsilon at ``delta``.
    """

    def __init__(self, sigma: float, delta: float = 1e-5):
        if sigma <= 0:
            raise ValueError("sigma must be > 0")
        self.sigma = float(sigma)
        self.delta = float(delta)
        self.releases = 0

    def add(self, n: int = 1) -> "GaussianAccountant":
        if n < 0:
            raise ValueError("cannot un-release")
        self.releases += int(n)
        return self

    @property
    def rho(self) -> float:
        return self.releases / (2.0 * self.sigma ** 2)

    def epsilon(self, delta: float | None = None) -> float:
        delta = self.delta if delta is None else delta
        rho = self.rho
        if rho == 0.0:
            return 0.0
        return rho + 2.0 * math.sqrt(rho * math.log(1.0 / delta))


def epsilon_schedule(dp: DPConfig, graph, gossip_steps: int,
                     mixes_so_far: np.ndarray) -> np.ndarray:
    """Cumulative epsilon after each entry of ``mixes_so_far`` (a running
    count of completed gossip-mix rounds) — the host-side curve the block
    runner attaches to run histories."""
    per_round = dp.releases_per_mix_round(graph, gossip_steps)
    out = np.empty(len(mixes_so_far), dtype=np.float64)
    for i, m in enumerate(np.asarray(mixes_so_far, dtype=np.int64)):
        acct = GaussianAccountant(dp.sigma, dp.delta).add(int(m) * per_round)
        out[i] = acct.epsilon()
    return out


# ---------------------------------------------------------------------------
# the mechanism: clip + wire noise, pytree-stacked over the node axis
# ---------------------------------------------------------------------------

def clip_params(params_stack, clip: float):
    """Scale each node's FULL parameter vector (all leaves, flattened) to
    L2 norm <= clip. One global factor per node, as in DP-SGD clipping."""
    leaves = jax.tree.leaves(params_stack)
    sq = sum(jnp.sum(p.astype(jnp.float32).reshape(p.shape[0], -1) ** 2,
                     axis=1) for p in leaves)                       # (K,)
    scale = jnp.minimum(1.0, clip / jnp.sqrt(sq + 1e-30))
    return jax.tree.map(
        lambda p: (p * scale.reshape((-1,) + (1,) * (p.ndim - 1))
                   .astype(p.dtype)),
        params_stack)


def noisy_dense_mix(w, params_stack, dp: DPConfig, key, steps: int = 1,
                    wire_codec: str | None = None):
    """B gossip steps of the dense (K, K) mix with the DP wire mechanism:
    each step re-clips the circulating values (every emission is clipped)
    and adds Gaussian noise on the off-diagonal W support — per directed
    link (independent (K, K, ...) draws) or per sender ((K, ...) draws
    shared by the row), matching ``dp.per_link``.

    ``wire_codec`` ("int8"/"fp8"/..., see ``repro.core.quant``) quantizes
    the emission in CLIP-THEN-QUANTIZE order, the order the sensitivity
    proof needs::

        clip -> quantize-dequantize -> re-clip guard -> Gaussian noise

    Quantizing AFTER the clip means what crosses the wire is the codec
    view of a norm-bounded vector; because rounding can inflate the norm
    by up to an ulp-scale factor, a second clip (a no-op unless the codec
    pushed ``||p||`` over) restores ``||p|| <= clip`` EXACTLY, so the
    released value keeps the ``2 * clip`` replace-one sensitivity and the
    zCDP accounting is unchanged by quantization. (Quantize-then-clip
    would instead release a post-clip value the codec never produced —
    an fp32 payload leaking onto a claimed-narrow wire.)
    """
    from repro.core import quant

    k = w.shape[0]
    wire = w * (1.0 - jnp.eye(k, dtype=w.dtype))   # off-diagonal: the links
    std = dp.noise_std
    out = params_stack
    for s in range(steps):
        out = clip_params(out, dp.clip)
        key_s = jax.random.fold_in(key, s)
        if quant.is_quantized(wire_codec):
            out = quant.wire_view_pytree(out, wire_codec,
                                         quant.wire_stream(key_s))
            out = clip_params(out, dp.clip)  # re-clip guard (see docstring)
        mixed = []
        flat, treedef = jax.tree.flatten(out)
        for i, p in enumerate(flat):
            key_i = jax.random.fold_in(key_s, i)
            if dp.per_link:
                xi = jax.random.normal(key_i, (k,) + p.shape, dtype=p.dtype)
                noise = jnp.einsum("kl,kl...->k...",
                                   wire.astype(p.dtype), xi) * std
            else:
                xi = jax.random.normal(key_i, p.shape, dtype=p.dtype)
                noise = jnp.einsum("kl,l...->k...",
                                   wire.astype(p.dtype), xi) * std
            dot = jnp.einsum("kl,l...->k...", w.astype(p.dtype), p)
            mixed.append((dot + noise).astype(p.dtype))
        out = jax.tree.unflatten(treedef, mixed)
    return out
