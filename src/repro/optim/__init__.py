from repro.optim.optimizers import adamw, sgd_momentum  # noqa: F401
from repro.optim.schedules import cosine_schedule, linear_warmup  # noqa: F401
