"""Pytree optimizers (no external deps): AdamW and SGD+momentum.

Each optimizer is a (init, update) pair operating on arbitrary parameter
pytrees. ``adamw`` supports low-precision first/second moments
(``state_dtype``) — used by the llama4-400B dry-run memory hillclimb.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, step, lr) -> (new_params, state)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.01, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, dtype=state_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step, lr):
        step_f = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** step_f
        c2 = 1.0 - b2 ** step_f

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m_new / c1
            vhat = v_new / c2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
                p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * delta
            return (p_new.astype(p.dtype), m_new.astype(state_dtype),
                    v_new.astype(state_dtype))

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def sgd_momentum(momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                   params)}

    def update(grads, state, params, step, lr):
        def upd(g, mu, p):
            mu_new = momentum * mu + g.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * mu_new
            return p_new.astype(p.dtype), mu_new

        out = jax.tree.map(upd, grads, state["mu"], params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"mu": new_mu}

    return Optimizer(init, update)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
