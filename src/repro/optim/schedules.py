"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup: int, peak: float):
    s = jnp.asarray(step, jnp.float32)
    return peak * jnp.minimum(1.0, (s + 1.0) / max(warmup, 1))


def cosine_schedule(step, warmup: int, total: int, peak: float,
                    floor: float = 0.0):
    s = jnp.asarray(step, jnp.float32)
    warm = linear_warmup(step, warmup, peak)
    frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(s < warmup, warm, cos)
