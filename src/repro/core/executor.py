"""Round-block execution engine: many rounds per device dispatch.

The per-round Python driver loop (seed ``run_cola`` / ``baselines._run``)
pays, every round, (a) a host->device dispatch of one jitted program and
(b) a blocking ``device_get`` sync whenever a metric is recorded. For the
paper's regime — cheap computation between communication rounds (Fig. 1) —
this framework overhead dominates wall-clock on fast hardware.

This module amortizes it: the round body runs inside a ``lax.scan`` over a
*block* of ``block_size`` rounds, so one dispatch executes the whole block.
Everything the host used to feed in per round (mixing matrices, active
masks, CD budgets, batches) is pre-materialized as stacked ``(T, ...)``
schedule arrays and sliced per block. The carried state is donated
(``donate_argnums``) so long runs reuse their ``(K, d)``/``(K, n_k)``
buffers instead of reallocating them every round.

Recording and run control are delegated to a pluggable ``Recorder``
(``repro.core.metrics``): its row is computed *on device* inside the scan
(a ``lax.cond`` on a per-round record flag, so skipped rounds cost
nothing) and fetched once at the end of the run. A recorder with a stop
condition (``stop_fn``, e.g. the Prop.-1 certificate's ``certified`` flag
or ``gap <= eps``) arms early exit: once a recorded row satisfies it, the
remaining rounds of the block turn into ``lax.cond`` no-ops (state passes
through bitwise-untouched) and the host skips all subsequent block
dispatches, at the price of one scalar stop-flag sync per block.

The engine is shared by all four drivers: the CoLA simulator
(``repro.core.cola.run_cola``), the decentralized baselines
(``repro.core.baselines``), the gossip-DP optimizer
(``repro.optim.gossip``) and the shard_map distributed runtime
(``repro.dist.runtime.run_dist_cola``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import hashlib
import types
import warnings
from collections import OrderedDict
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# Compiled-driver cache: jit only caches on the *function object*, and every
# run_cola/run_round_blocks call builds fresh closures, so without this each
# run re-traces and re-compiles its whole program — which dominates wall
# clock for short runs. Keys must be CONTENT-addressed (see ``fingerprint``):
# an id()-based key is wrong twice over — a rebuilt object at a recycled
# address silently reuses a driver whose closure baked in the OLD contents,
# and while an entry is live its closure pins the whole captured object.
# Bounded LRU.
_DRIVER_CACHE: OrderedDict = OrderedDict()
_DRIVER_CACHE_SIZE = 64


def _code_names(code: types.CodeType) -> set:
    """All global/attribute names a code object can reference, including
    from nested code (lambdas, comprehensions) — a global read inside a
    nested lambda bakes into the compiled driver just like a top-level one."""
    names = set(code.co_names)
    for c in code.co_consts:
        if isinstance(c, types.CodeType):
            names |= _code_names(c)
    return names


def _fp_update(h, obj, seen: set) -> None:
    """Feed ``obj``'s content (not its address) into the hash ``h``.

    Arrays hash by shape/dtype/bytes; functions hash by bytecode plus the
    contents of their closure cells and defaults — which is exactly the set
    of constants a jitted driver bakes into its executable (e.g. the label
    vector captured by ``Problem.grad_f``). ``seen`` guards cycles.
    """
    if isinstance(obj, (types.FunctionType, dict)) or (
            dataclasses.is_dataclass(obj) and not isinstance(obj, type)):
        if id(obj) in seen:
            h.update(b"<cycle>")
            return
        seen.add(id(obj))
    h.update(type(obj).__name__.encode())
    if obj is None or isinstance(obj, (bool, int, float, complex, str,
                                       bytes, np.generic)):
        h.update(repr(obj).encode())
    elif isinstance(obj, (np.ndarray, jax.Array)):
        arr = np.asarray(obj)
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    elif isinstance(obj, jax.ShapeDtypeStruct):
        h.update(str(obj.shape).encode())
        h.update(str(obj.dtype).encode())
    elif isinstance(obj, (tuple, list)):
        for x in obj:
            _fp_update(h, x, seen)
    elif isinstance(obj, dict):
        for k in sorted(obj, key=repr):
            _fp_update(h, k, seen)
            _fp_update(h, obj[k], seen)
    elif isinstance(obj, functools.partial):
        _fp_update(h, obj.func, seen)
        _fp_update(h, obj.args, seen)
        _fp_update(h, dict(obj.keywords), seen)
    elif isinstance(obj, types.FunctionType):
        _fp_update(h, obj.__code__, seen)
        if obj.__closure__:
            for cell in obj.__closure__:
                try:
                    _fp_update(h, cell.cell_contents, seen)
                except ValueError:  # empty cell
                    h.update(b"<empty-cell>")
        _fp_update(h, obj.__defaults__, seen)
        _fp_update(h, obj.__kwdefaults__, seen)
        # module-level references: a function body that reads SCALE or calls
        # other_fn bakes their current values into the compiled driver, so
        # they are part of the content. Scalars/arrays hash by value; heavier
        # globals (modules, functions, classes) by qualified name — deep
        # enough to tell jnp.exp from jnp.log without walking module graphs.
        for name in sorted(_code_names(obj.__code__)):
            if name not in obj.__globals__:
                continue
            g = obj.__globals__[name]
            h.update(name.encode())
            if isinstance(g, types.ModuleType):
                h.update(g.__name__.encode())
            elif isinstance(g, (types.FunctionType, types.BuiltinFunctionType,
                                type)):
                h.update(f"{getattr(g, '__module__', '')}."
                         f"{getattr(g, '__qualname__', '')}".encode())
            elif g is None or isinstance(g, (bool, int, float, complex, str,
                                             bytes, np.generic, np.ndarray,
                                             jax.Array, tuple)):
                _fp_update(h, g, seen)
            else:
                h.update(type(g).__qualname__.encode())
    elif isinstance(obj, types.MethodType):
        _fp_update(h, obj.__func__, seen)
        _fp_update(h, obj.__self__, seen)
    elif isinstance(obj, types.CodeType):
        h.update(obj.co_code)
        # co_names disambiguates same-bytecode bodies that differ only in
        # which attribute/global they reference (exp vs log); consts recurse
        # fully so nested lambdas/comprehensions hash their own literals too
        h.update(" ".join(obj.co_names).encode())
        for c in obj.co_consts:
            _fp_update(h, c, seen)
    elif dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        for f in dataclasses.fields(obj):
            h.update(f.name.encode())
            _fp_update(h, getattr(obj, f.name), seen)
    else:
        r = repr(obj)
        if " at 0x" in r:
            # a default repr is just class+address: hashing it would quietly
            # turn content-addressing back into address-keying (without even
            # the old scheme's liveness pin). Hash the instance dict when
            # there is one; otherwise refuse rather than alias.
            d = getattr(obj, "__dict__", None)
            if d:
                if id(obj) in seen:
                    h.update(b"<cycle>")
                    return
                seen.add(id(obj))
                h.update(type(obj).__qualname__.encode())
                _fp_update(h, dict(d), seen)
            else:
                raise TypeError(
                    f"fingerprint: cannot content-hash {type(obj)!r} "
                    "(address-based repr and no __dict__)")
        else:
            h.update(r.encode())


_FP_MEMO_ATTR = "_fingerprint_memo"


def fingerprint(*objs: Any) -> str:
    """Content-addressed digest of ``objs`` for driver-cache keys.

    Two separately-built objects with identical contents map to the SAME
    key (so rebuilding an identical Problem per call still hits the cache),
    and objects that differ anywhere a jitted closure could observe them —
    array data, closure constants, hyperparameters — map to different keys
    even if one is constructed at the other's recycled address.

    Hashing is O(bytes of captured arrays) — for a Problem that is a D2H
    copy + SHA256 of the (d, n) data matrix — so a single frozen-dataclass
    argument memoizes its digest on the instance: repeated runs over one
    large Problem hash it once. (Sound because frozen dataclasses over
    immutable jax arrays cannot change content; a dataclass with mutable
    np fields mutated in place would need the memo cleared.)
    """
    def memoizable(o):
        # only FROZEN dataclasses: a mutable one could change content after
        # the memo was written and silently return a stale digest
        return (dataclasses.is_dataclass(o) and not isinstance(o, type)
                and type(o).__dataclass_params__.frozen)

    if len(objs) == 1 and memoizable(objs[0]):
        memo = getattr(objs[0], _FP_MEMO_ATTR, None)
        if memo is not None:
            return memo
    h = hashlib.sha256()
    seen: set = set()
    for o in objs:
        _fp_update(h, o, seen)
    digest = h.hexdigest()
    if len(objs) == 1 and memoizable(objs[0]):
        try:
            object.__setattr__(objs[0], _FP_MEMO_ATTR, digest)
        except (AttributeError, TypeError):  # __slots__ etc. — just rehash
            pass
    return digest


def clear_driver_cache() -> None:
    """Drop all cached drivers (and the Problems/executables their closures
    pin). Call between large sweeps that build many distinct problems."""
    _DRIVER_CACHE.clear()


# Retrace accounting: every cached_driver resolution is counted (and
# broadcast to listeners) so the analysis retrace detector and the bench
# harness can tell "slow because the engine regressed" apart from "slow
# because an unstable cache key forced a re-trace+re-compile every run".
_CACHE_STATS = {"hits": 0, "misses": 0, "bypass": 0}
_CACHE_LISTENERS: list = []


def driver_cache_stats(reset: bool = False) -> dict:
    """Snapshot of {hits, misses, bypass} cached_driver resolutions since
    process start (or the last ``reset=True`` call)."""
    out = dict(_CACHE_STATS)
    if reset:
        for k in _CACHE_STATS:
            _CACHE_STATS[k] = 0
    return out


def _cache_event(key, kind: str) -> None:
    _CACHE_STATS[kind] += 1
    for listener in list(_CACHE_LISTENERS):
        listener(key, kind)


@contextlib.contextmanager
def cache_listener(fn: Callable[[Any, str], None]):
    """Register ``fn(key, kind)`` for cache events, removably.

    The one sanctioned way to observe ``cached_driver`` resolutions:
    the listener is appended on entry and removed on exit even if the body
    raises, so nested monitors (``analysis.RetraceMonitor``, ``obs.trace``
    tracers) never double-count or leak a stale callback across tests. The
    same function object may be registered by nested scopes — each exit
    removes exactly one registration (list.remove drops the first match,
    which is equivalent for identical callbacks).
    """
    _CACHE_LISTENERS.append(fn)
    try:
        yield fn
    finally:
        try:
            _CACHE_LISTENERS.remove(fn)
        except ValueError:  # already removed (e.g. test cleared the list)
            pass


def cached_driver(key, build: Callable[[], Callable]) -> Callable:
    """Return (building on miss) the jitted driver for ``key``.

    ``key`` must uniquely determine the semantics AND closure constants of
    the built function — use ``fingerprint()`` for captured objects (NEVER
    ``id()``: a rebuilt object at a recycled address would silently reuse
    the wrong compiled driver). ``key=None`` bypasses the cache.
    """
    if key is None:
        _cache_event(None, "bypass")
        return build()
    fn = _DRIVER_CACHE.get(key)
    if fn is None:
        _cache_event(key, "misses")
        fn = build()
        _DRIVER_CACHE[key] = fn
        if len(_DRIVER_CACHE) > _DRIVER_CACHE_SIZE:
            _DRIVER_CACHE.popitem(last=False)
    else:
        _cache_event(key, "hits")
        _DRIVER_CACHE.move_to_end(key)
    return fn


class BlockRunResult(NamedTuple):
    state: Any
    metrics: np.ndarray | None  # (R, m) rows for rounds where record_mask
    aux: Any                    # per-round step outputs stacked over T, or None
    # (R,) round indices of the metric rows — truncated at the stop round
    # when the recorder's stop condition fired
    rounds: np.ndarray | None = None
    stop_round: int | None = None  # round that certified/stopped, or None


def _num_rounds(schedule: Any, record_mask: np.ndarray | None,
                num_rounds: int | None) -> int:
    if num_rounds is not None:
        return int(num_rounds)
    if record_mask is not None:
        return int(np.shape(record_mask)[0])
    leaves = jax.tree.leaves(schedule)
    if not leaves:
        raise ValueError("cannot infer the round count: pass num_rounds, a "
                         "record_mask, or a schedule with (T, ...) leaves")
    return int(leaves[0].shape[0])


def run_round_blocks(step_fn: Callable[[Any, Any, Any], tuple[Any, Any]],
                     state: Any, schedule: Any, *,
                     context: Any = None,
                     recorder: Any = None,
                     record_mask: np.ndarray | None = None,
                     block_size: int = 64,
                     num_rounds: int | None = None,
                     cache_key: Any = None,
                     cadence: Any = None,
                     stream: Callable | None = None) -> BlockRunResult:
    """Run ``T`` rounds of ``step_fn`` in ceil(T / block_size) dispatches.

    Args:
      step_fn: ``(state, context, sched_t) -> (state, aux)`` — the pure round
        body. ``sched_t`` is the per-round slice of ``schedule``; ``aux`` is
        an optional per-round output pytree (or None).
      state: carried state pytree; its buffers are donated to the scan.
      schedule: pytree of ``(T, ...)`` arrays (host numpy is fine — each
        block's slice is shipped to the device at dispatch). May be empty
        (``{}``) when the round body needs no per-round inputs.
      context: run-constant pytree (e.g. the CoLA env) passed through to
        ``step_fn`` as a jit argument so large arrays are not baked into the
        executable as constants.
      recorder: a ``repro.core.metrics`` Recorder — its ``record_fn`` is
        evaluated on device for rounds where ``record_mask`` is set, and its
        ``stop_fn`` (when not None) arms early exit: the round whose row
        satisfies the stop condition is the LAST live round — the remaining
        rounds of its block are ``lax.cond`` no-ops and subsequent block
        dispatches are skipped host-side. Early exit costs one scalar device
        sync per block (the stop flag read); without a stop_fn the engine
        keeps the historical fully-async single-fetch behaviour and the
        identical compiled program.
      record_mask: ``(T,)`` bool — which rounds record a metric row.
      block_size: rounds per device dispatch. At most two program shapes are
        compiled (full block + remainder).
      num_rounds: explicit T when neither schedule nor record_mask carries it.
      cache_key: when set, the jitted block program is reused across calls
        (see ``cached_driver``) so repeated runs skip trace+compile. The key
        must pin down ``step_fn``/recorder semantics and captured constants —
        use ``fingerprint()`` for closed-over objects and the recorder's
        ``cache_token()``. A ``cadence`` is appended to the key
        automatically.
      stream: optional pure-jax generator ``t -> {entry: array}`` (see
        ``repro.core.schedule.ScheduleProgram.stream_fn``) evaluated INSIDE
        the scan body: its output merges over the round's ``schedule``
        slice (streamed entries win) before the step function and the
        recorder see it. This is what lets per-round inputs that are
        cheap to re-derive (participation masks, sampled mixing matrices,
        attack transform rows — anything keyed by ``fold_in(t)``) avoid
        (T, ...) host materialization entirely; ``schedule`` must then be
        a dict and may be empty. The generator is folded into the driver
        cache key automatically. ``stream=None`` programs are
        byte-identical to the historical executor.
      cadence: a ``repro.core.metrics.AdaptiveCadence`` — replaces the
        host-side ``record_mask`` with an ON-DEVICE record controller: the
        next record round and current cadence ride the scan carry, each
        recorded row's ``recorder.cadence_ratio`` geometrically backs the
        cadence off while far from the stop threshold and snaps it to
        ``base`` inside the near band. Stop short-circuiting (block no-ops
        + host-side skip) is unchanged; the last round always records.

    Returns:
      BlockRunResult(state, metrics, aux, rounds, stop_round): ``metrics``
      holds the recorded rows only (record_mask applied, truncated at the
      stop round), fetched in a single device sync at the end; ``rounds``
      are the corresponding round indices; ``aux`` stacks the per-round step
      outputs over all executed rounds (no-op rounds after a stop contribute
      zeros).
    """
    t_total = _num_rounds(schedule, record_mask, num_rounds)
    if stream is not None:
        if not isinstance(schedule, dict):
            raise TypeError(
                "stream= requires a dict schedule: streamed entries merge "
                f"into the per-round slice (got {type(schedule).__name__})")
        # the generator's bytecode + closure are part of the compiled
        # program's content, exactly like the step function's
        cache_key = (None if cache_key is None
                     else (cache_key, ("stream", fingerprint(stream))))
    record_fn = recorder.record_fn if recorder is not None else None
    stop_fn = recorder.stop_fn if recorder is not None else None
    # schedule-aware recorders (e.g. the dynamic churn certificate) receive
    # the round's schedule slice alongside the state
    uses_sched = bool(getattr(recorder, "uses_schedule", False))
    has_cadence = cadence is not None and record_fn is not None
    if has_cadence:
        ratio_fn = recorder.cadence_ratio  # required by the contract
        cache_key = (None if cache_key is None
                     else (cache_key, cadence.cache_token()))
    if record_fn is not None and record_mask is None and not has_cadence:
        record_mask = np.ones((t_total,), dtype=bool)
    rec_all = (np.asarray(record_mask, dtype=bool)
               if record_fn is not None and not has_cadence
               else np.zeros((t_total,), dtype=bool))
    has_stop = stop_fn is not None

    def build():
        def rec_call(s, sched_t):
            return record_fn(s, sched_t) if uses_sched else record_fn(s)

        def zero_row(s, sched_t):
            # shape-only evaluation, re-derived per trace so a cached driver
            # stays correct if it is reused at different state shapes
            sd = jax.eval_shape(rec_call, s, sched_t)
            return jnp.zeros(sd.shape, sd.dtype)

        def skip_step(s, ctx, sched_t):
            # post-certification rounds are no-ops: state passes through
            # untouched, which is what makes the stopped run's final state
            # bitwise equal to the full run's state at the stop round
            aux_sd = jax.eval_shape(lambda ss: step_fn(ss, ctx, sched_t)[1],
                                    s)
            return s, jax.tree.map(
                lambda sd: jnp.zeros(sd.shape, sd.dtype), aux_sd)

        if has_cadence:
            base = jnp.int32(cadence.base)
            grow = jnp.int32(cadence.grow)
            max_e = jnp.int32(cadence.max_every)
            near = jnp.float32(cadence.near)

            @partial(jax.jit, donate_argnums=(0,))
            def run_block_adaptive(carry0, ctx, sched, t_idx, force):
                def body(carry, xs):
                    s, stopped, nxt, every = carry
                    sched_t, t, force_t = xs
                    if stream is not None:
                        sched_t = {**sched_t, **stream(t)}
                    s, aux = lax.cond(
                        stopped, lambda ss: skip_step(ss, ctx, sched_t),
                        lambda ss: step_fn(ss, ctx, sched_t), s)
                    due = jnp.logical_or(t >= nxt, force_t)
                    do_rec = jnp.logical_and(due, jnp.logical_not(stopped))
                    row = lax.cond(do_rec,
                                   lambda ss: rec_call(ss, sched_t),
                                   lambda ss: zero_row(ss, sched_t), s)
                    # geometric back-off while far from the stop threshold,
                    # snap to base inside the near band; the zero row of a
                    # non-record round is discarded by the where() gates
                    far = ratio_fn(row).astype(jnp.float32) > near
                    new_every = jnp.where(
                        far, jnp.minimum(every * grow, max_e), base)
                    every = jnp.where(do_rec, new_every, every)
                    nxt = jnp.where(do_rec, t + new_every, nxt)
                    if stop_fn is not None:
                        stop_now = jnp.logical_and(do_rec, stop_fn(row))
                        stopped = jnp.logical_or(stopped, stop_now)
                    return (s, stopped, nxt, every), (aux, row, do_rec)
                return lax.scan(body, carry0, (sched, t_idx, force))

            return run_block_adaptive

        if not has_stop:
            if stream is None:
                # historical engine: no stop carry, no cond around the
                # step — byte-identical program to the pre-recorder
                # executor, which is what keeps GapRecorder histories
                # bitwise reproducible
                @partial(jax.jit, donate_argnums=(0,))
                def run_block(st, ctx, sched, rec):
                    def body(s, xs):
                        sched_t, rec_t = xs
                        s, aux = step_fn(s, ctx, sched_t)
                        if record_fn is None:
                            return s, (aux, None)
                        row = lax.cond(rec_t,
                                       lambda ss: rec_call(ss, sched_t),
                                       lambda ss: zero_row(ss, sched_t), s)
                        return s, (aux, row)
                    return lax.scan(body, st, (sched, rec))

                return run_block

            @partial(jax.jit, donate_argnums=(0,))
            def run_block_streamed(st, ctx, sched, rec, t_idx):
                def body(s, xs):
                    sched_t, rec_t, t = xs
                    sched_t = {**sched_t, **stream(t)}
                    s, aux = step_fn(s, ctx, sched_t)
                    if record_fn is None:
                        return s, (aux, None)
                    row = lax.cond(rec_t,
                                   lambda ss: rec_call(ss, sched_t),
                                   lambda ss: zero_row(ss, sched_t), s)
                    return s, (aux, row)
                return lax.scan(body, st, (sched, rec, t_idx))

            return run_block_streamed

        @partial(jax.jit, donate_argnums=(0,))
        def run_block_stop(carry0, ctx, sched, rec, t_idx=None):
            def body(carry, xs):
                s, stopped = carry
                if stream is None:
                    sched_t, rec_t = xs
                else:
                    sched_t, rec_t, t = xs
                    sched_t = {**sched_t, **stream(t)}

                s, aux = lax.cond(
                    stopped, lambda ss: skip_step(ss, ctx, sched_t),
                    lambda ss: step_fn(ss, ctx, sched_t), s)
                do_rec = jnp.logical_and(rec_t, jnp.logical_not(stopped))
                row = lax.cond(do_rec,
                               lambda ss: rec_call(ss, sched_t),
                               lambda ss: zero_row(ss, sched_t), s)
                stop_now = jnp.logical_and(do_rec, stop_fn(row))
                return (s, jnp.logical_or(stopped, stop_now)), \
                    (aux, row, do_rec)
            xs = (sched, rec) if stream is None else (sched, rec, t_idx)
            return lax.scan(body, carry0, xs)

        return run_block_stop

    # phase tracing (repro.obs.trace): the active tracer records the driver
    # build (trace time — runs only on a cache miss/bypass) and every block
    # dispatch. The first dispatch span absorbs the XLA compile; steady
    # blocks measure dispatch (+ the per-block stop-flag sync when early
    # exit is armed). Lazy import: obs.trace imports this module.
    from repro.obs import trace as obs_trace
    tracer = obs_trace.current()

    def timed_build():
        with tracer.span("driver-build", key=cache_key is not None):
            return build()

    run_block = cached_driver(cache_key, timed_build)

    rows, valids, auxes = [], [], []
    start = 0
    executed = 0
    n_dispatch = 0
    stopped_early = False
    with warnings.catch_warnings():
        if jax.default_backend() == "cpu":
            # donation is a no-op on CPU, so the warning is pure noise there;
            # on accelerators it signals real aliasing bugs — keep it
            warnings.filterwarnings("ignore", message=".*donated.*")
        stop_flag = jnp.asarray(False)
        # adaptive carry: (state, stopped, next-record round, cadence) — the
        # controller state persists across block dispatches like the state
        carry = (state, stop_flag, jnp.int32(0),
                 jnp.int32(cadence.base)) if has_cadence else None
        while start < t_total:
            stop = min(start + block_size, t_total)
            span_name = ("block-first-dispatch" if n_dispatch == 0
                         else "block-dispatch")
            n_dispatch += 1
            with tracer.span(span_name, start=start, rounds=stop - start):
                sched_b = jax.tree.map(lambda x: jnp.asarray(x[start:stop]),
                                       schedule)
                if has_cadence:
                    t_b = jnp.arange(start, stop, dtype=jnp.int32)
                    force_b = jnp.asarray(
                        np.arange(start, stop) == t_total - 1)
                    carry, (aux_b, rows_b, valid_b) = run_block(
                        carry, context, sched_b, t_b, force_b)
                    state, stop_flag = carry[0], carry[1]
                    valids.append(valid_b)
                elif has_stop:
                    args = ((state, stop_flag), context, sched_b,
                            jnp.asarray(rec_all[start:stop]))
                    if stream is not None:
                        args += (jnp.arange(start, stop, dtype=jnp.int32),)
                    (state, stop_flag), (aux_b, rows_b, valid_b) = \
                        run_block(*args)
                    valids.append(valid_b)
                else:
                    args = (state, context, sched_b,
                            jnp.asarray(rec_all[start:stop]))
                    if stream is not None:
                        args += (jnp.arange(start, stop, dtype=jnp.int32),)
                    state, (aux_b, rows_b) = run_block(*args)
                if rows_b is not None:
                    rows.append(rows_b)
                if aux_b is not None and jax.tree.leaves(aux_b):
                    auxes.append(aux_b)
                start = stop
                executed = stop
                # the host-side short-circuit: one scalar sync per block,
                # only when early exit is armed
                if has_stop and bool(stop_flag):
                    stopped_early = True
                    break

    metrics = rounds = None
    stop_round = None
    if record_fn is not None:
        if (has_stop or has_cadence) and valids:
            valid = np.concatenate([np.asarray(v) for v in valids], axis=0)
        else:
            valid = rec_all[:executed]
        if rows:
            metrics = np.concatenate([np.asarray(r) for r in rows],
                                     axis=0)[valid]
            rounds = np.nonzero(valid)[0]
        else:  # T == 0: empty history, same as the loop drivers
            if uses_sched:
                sched0 = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                    schedule)
                if stream is not None:
                    sched0 = {**sched0,
                              **jax.eval_shape(stream, jnp.int32(0))}
                row_sd = jax.eval_shape(record_fn, state, sched0)
            else:
                row_sd = jax.eval_shape(record_fn, state)
            metrics = np.zeros((0,) + row_sd.shape, row_sd.dtype)
            rounds = np.zeros((0,), dtype=np.int64)
        if stopped_early and rounds.size:
            stop_round = int(rounds[-1])
    aux = None
    if auxes:
        aux = jax.tree.map(lambda *xs: np.concatenate(
            [np.asarray(x) for x in xs], axis=0), *auxes)
    return BlockRunResult(state=state, metrics=metrics, aux=aux,
                          rounds=rounds, stop_round=stop_round)


def make_block_runner(step_fn: Callable, *, recorder: Any = None,
                      block_size: int = 64,
                      cache_key: Any = None) -> Callable:
    """Bind a round body and a Recorder into a reusable block runner.

    Returns ``run(state, schedule, *, context=None, record_mask=None,
    num_rounds=None) -> BlockRunResult`` — ``run_round_blocks`` with the
    recorder/engine knobs fixed, the shape all four drivers consume.
    """
    def run(state, schedule, *, context=None, record_mask=None,
            num_rounds=None):
        return run_round_blocks(
            step_fn, state, schedule, context=context, recorder=recorder,
            record_mask=record_mask, block_size=block_size,
            num_rounds=num_rounds, cache_key=cache_key)

    return run


def record_flags(rounds: int, record_every: int) -> np.ndarray:
    """The driver-loop recording pattern: every ``record_every``-th round and
    always the last one."""
    t = np.arange(rounds)
    return (t % record_every == 0) | (t == rounds - 1)
