"""Pluggable recording/control layer for the round-block executor.

A ``Recorder`` bundles the three things a driver previously wired by hand
(three divergent copies across ``cola.py``, ``baselines.py`` and
``dist/runtime.py``): what to measure each record round, what the columns are
called, and when the run may stop early. The round-block executor
(``repro.core.executor``) consumes a Recorder directly — the row is computed
on device inside the scan, and when the recorder's stop condition fires the
remaining rounds of the block become no-ops and subsequent block dispatches
are skipped host-side.

The protocol (duck-typed, no base class required):

  labels      tuple[str, ...] — column names; drives history dict keys.
  record_fn   state -> (len(labels),) row, pure jax (runs inside the scan).
  stop_fn     None (never stop) or row -> scalar bool; evaluated only on
              record rounds, so ``record_every`` is also the certification
              cadence.
  init_spec() pytree of per-run constant arrays the recorder derives at build
              time (e.g. the sigma_k spectral-norm cache); the distributed
              runtime shards these over the node mesh axis via
              ``repro.dist.sharding.cola_recorder_pspecs``.
  cache_token()  small hashable-by-``executor.fingerprint`` summary of the
              recorder's semantics for compiled-driver cache keys (the big
              arrays are determined by (problem, partition), which drivers
              fingerprint separately).
  collective_footprint(...)  bytes-per-record-round by collective kind on a
              K-device mesh — what ``launch.dryrun --plan`` renders.

Three implementations ship:

* ``GapRecorder`` — the Lemma-2 ``gap_report`` (primal/dual/gap/consensus),
  numerics unchanged from the historical drivers. On a mesh this gathers the
  full (K, d)/(K, n_k) stacks per record round (GSPMD inserts the
  collectives); with ``eps`` it stops when ``gap <= eps``.
* ``CertificateRecorder`` — the Prop.-1 local certificates: condition (9)
  from node-local quantities, condition (10) from the masked-neighbor
  gradient mean (one gossip exchange of (d,)-vectors), summarized to scalar
  reductions. The distributed runtime evaluates it with ``ppermute``/``psum``
  of the LOCAL gradient — O(d) per device per record round, no stack
  gathers. Stops at certification.
* ``ComposedRecorder`` — concatenates several recorders' rows; stops when
  any constituent's stop fires.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology as topo
from repro.core.duality import (block_spectral_norms, certificate_thresholds,
                                consensus_residual, gap_report, neighbor_mask,
                                neighborhood_mean, node_subproblem_gaps)
from repro.core.partition import Partition

GAP_METRICS = ("primal", "hamiltonian", "dual", "gap", "consensus_violation")
# append-only: downstream code indexes the first five by name, and new
# columns extend the row (consensus_residual = the Lemma-1 invariant
# residual, certificate_violated = the tamper-detection flag; see
# ``duality.consensus_residual``)
CERT_METRICS = ("local_gap_max", "grad_disagreement_max", "cond9_nodes",
                "cond10_nodes", "certified", "consensus_residual",
                "certificate_violated")


@dataclasses.dataclass(frozen=True)
class AdaptiveCadence:
    """On-device ``record_every`` controller: geometric back-off.

    Stopping is only checked on record rounds, so the recording cadence is
    also the certification latency — but far from ``eps`` every record
    round is wasted work. This controller doubles the cadence after each
    record round whose distance ratio (``recorder.cadence_ratio(row)``,
    ~"how many multiples of the stop threshold away we are") is still above
    ``near``, and snaps back to ``base`` the moment a row lands inside the
    ``near`` band — so certification is detected within ``base`` rounds of
    becoming true while the far-from-converged phase records only
    O(log T) rows.

    The decision runs on device inside the round-block scan (the next
    record round and current cadence ride the scan carry), so the
    executor's block short-circuiting and the single end-of-run metric
    fetch are unchanged. ``grow`` is an integer so the host loop driver
    reproduces the device arithmetic exactly.
    """

    base: int = 1        # cadence inside the near band (certification latency)
    max_every: int = 64  # back-off cap
    grow: int = 2        # geometric factor per far record round
    near: float = 2.0    # "near" band: ratio <= near tightens to base

    def __post_init__(self):
        if self.base < 1 or self.grow < 2 or self.max_every < self.base:
            raise ValueError(
                f"need base >= 1, grow >= 2, max_every >= base; got {self}")

    def cache_token(self):
        return ("AdaptiveCadence", self.base, self.max_every, self.grow,
                self.near)


def as_cadence(record_every) -> AdaptiveCadence | None:
    """Resolve a driver's ``record_every`` argument: an int keeps the fixed
    host-side mask, ``"adaptive"`` / an ``AdaptiveCadence`` instance arms
    the on-device controller."""
    if isinstance(record_every, AdaptiveCadence):
        return record_every
    if record_every == "adaptive":
        return AdaptiveCadence()
    return None


@dataclasses.dataclass(frozen=True)
class GapRecorder:
    """Lemma-2 global diagnostics (the historical ``gap_report`` row).

    ``record_fn`` is byte-for-byte the computation the drivers inlined before
    the recorder layer existed, so metric histories reproduce exactly.
    """

    problem: Any
    part: Partition
    eps: float | None = None

    labels = GAP_METRICS

    def record_fn(self, state) -> jax.Array:
        rep = gap_report(self.problem, self.part, state.x_parts,
                         state.v_stack)
        return jnp.stack([getattr(rep, name) for name in self.labels])

    @property
    def stop_fn(self) -> Callable | None:
        if self.eps is None:
            return None
        eps, idx = self.eps, self.labels.index("gap")
        return lambda row: row[idx] <= eps

    def cadence_ratio(self, row) -> jax.Array:
        """Distance-to-stop ratio for ``AdaptiveCadence``: gap / eps."""
        if self.eps is None:
            raise ValueError("adaptive record cadence needs eps= on the gap "
                             "recorder (the ratio is gap / eps)")
        return row[self.labels.index("gap")] / self.eps

    def init_spec(self) -> dict:
        return {}

    def cache_token(self):
        return ("GapRecorder", self.eps)

    def collective_footprint(self, k: int, d: int, n_k: int,
                             itemsize: int = 4, comm: str = "dense",
                             conn: int = 1) -> dict:
        # merge_vector + grad stack: every device materializes the full
        # (K, n_k) and (K, d) stacks, plus scalar reductions for the row
        return {"all-gather": k * (d + n_k) * itemsize,
                "all-reduce": 2 * len(self.labels) * itemsize,
                "collective-permute": 0}


@dataclasses.dataclass(frozen=True)
class CertificateRecorder:
    """Prop.-1 local certificates as an on-device metric row.

    All round-invariant inputs (sigma_k via ``block_spectral_norms``, the
    Eq.-9/10 thresholds, the self-inclusive neighbor mask) are resolved at
    construction — see ``certificate_recorder`` — so a record round costs one
    gradient evaluation, one neighborhood exchange of (d,)-vectors and scalar
    reductions. ``stop_fn`` fires at certification (``certified == 1``).
    """

    problem: Any
    part: Partition
    a_parts: jax.Array      # (K, d, n_k) — condition (9) needs A_[k]
    gp_parts: jax.Array     # (K, n_k)
    masks: jax.Array        # (K, n_k)
    # (K, K) 0/1 self-inclusive neighbor mask; None in cohort mode, where a
    # dense mask would be O(K^2) at million-node populations and the
    # neighborhood structure is the closed-form sampled-complete one
    neigh_mask: jax.Array | None
    sigma_k: jax.Array      # (K,) spectral-norm cache
    eps: float
    beta_ub: float
    l_bound: float
    gap_thresh: float
    grad_thresh: float
    stop_on_certified: bool = True
    # churn mode: read the Eq.-10 neighborhood mask and threshold from the
    # per-round schedule (support of the REWEIGHTED W_t, beta of the active
    # subnetwork) instead of the static init-time constants — the static
    # graph's denser mixing would otherwise yield a threshold looser than
    # the churn round's actual exchange justifies. See ``dynamize`` /
    # ``certificate_schedule``.
    dynamic: bool = False
    # Lemma-1 tamper detection (``duality.consensus_residual``): certifying
    # additionally requires the relative invariant residual <= cons_tol
    # (Prop. 1's proof rests on (1/K) sum v_k = A x — an attacked run that
    # satisfies Eqs. 9-10 at a SHIFTED fixed point must not certify), and
    # residual > viol_tol (or non-finite state) raises the
    # ``certificate_violated`` flag. Honest linear runs sit at float noise
    # (~1e-6); robust nonlinear aggregation drifts the invariant by the
    # neighborhood spread, which decays toward consensus — hence a band,
    # not an exact-zero check. An undefended Byzantine payload moves the
    # mean by O(||v||) per round, far above viol_tol.
    cons_tol: float = 1e-2
    viol_tol: float = 0.1
    stop_on_violation: bool = False
    # attack-harness mode (``attackify``): audit the HONEST COHORT. A node
    # that lies on the wire cannot have its data used by any sound
    # aggregator — the achievable target is the honest sub-network's
    # problem, so certifying the full-network invariant under a working
    # defense is impossible by construction. The recorder instead reads the
    # ground-truth per-round dishonesty mask the attack schedule recorded
    # (``sched["atk_dishonest"]`` — experimenter knowledge, never visible
    # to the defense) and restricts every certificate input to honest
    # nodes: the Lemma-1 sums, the Eq.-9/10 conditions and the Eq.-10
    # neighborhood mean. Under the trim defense (drop + weight-to-self)
    # with a symmetric W the restricted mixing is column-stochastic, so the
    # cohort invariant sum_H v_k = K * A_H x_H holds EXACTLY whenever the
    # gate rejects every lie — the defended run certifies at float noise,
    # while an undefended run absorbs the lies into honest states and
    # trips ``certificate_violated``.
    attack_aware: bool = False
    # client-sampling mode (million-node populations, see
    # ``core.schedule.SampleConfig``): the Eq.-10 neighborhood is the
    # sampled COMPLETE subnetwork — its mixing matrix is the exact uniform
    # average, so the neighborhood mean is one cohort-mean broadcast (no
    # (K, K) mask anywhere) and the dynamic threshold collapses to the
    # beta=0 run constant baked into ``grad_thresh`` at build time. The
    # schedule supplies ``cohort_idx`` (K',) and ``active`` (K,); frozen
    # nodes keep their own gradient as the neighborhood mean (disagreement
    # exactly 0), matching the churn oracle's isolated-node semantics.
    cohort: bool = False

    labels = CERT_METRICS

    @property
    def uses_schedule(self) -> bool:
        return self.dynamic or self.attack_aware or self.cohort

    def local_row_inputs(self, x_parts, v_stack, grads, neigh_mean):
        """(local_gap, disagreement) per node — shared by the stacked
        simulator path and the shard_map distributed path (which feeds the
        per-device slices plus a ppermute-built ``neigh_mean``)."""
        local_gap = node_subproblem_gaps(self.problem, x_parts, v_stack,
                                         self.a_parts, self.gp_parts,
                                         self.masks, grads)
        disagree = jnp.linalg.norm(grads - neigh_mean, axis=1)
        return local_gap, disagree

    def summarize(self, local_gap, disagree, *, resid, psum=None, pmax=None,
                  grad_thresh=None, honest=None, dtype=jnp.float32
                  ) -> jax.Array:
        """Assemble the scalar row from per-node quantities.

        ``psum``/``pmax`` default to identity (single-program stacked state);
        the distributed runtime passes ``lax.psum``/``lax.pmax`` partials so
        the cross-device reductions are scalar collectives. ``grad_thresh``
        overrides the static Eq.-10 threshold (the dynamic churn path feeds
        the per-round value). ``resid`` is the already-reduced Lemma-1
        consensus residual (``duality.consensus_residual``) — certification
        requires it <= cons_tol; > viol_tol (or non-finite, the divergence
        signature) raises ``certificate_violated``. ``honest`` (attack-aware
        mode) is this program's node slice of the 0/1 honesty mask: the
        Eq.-9/10 conditions and maxima restrict to honest nodes, and
        certification requires all HONEST nodes to satisfy both.
        """
        psum = psum if psum is not None else (lambda x: x)
        pmax = pmax if pmax is not None else (lambda x: x)
        if grad_thresh is None:
            grad_thresh = self.grad_thresh
        cond9 = local_gap <= self.gap_thresh
        cond10 = disagree <= grad_thresh
        if honest is None:
            n_target = jnp.asarray(self.part.num_nodes, dtype)
        else:
            ok = honest > 0
            cond9, cond10 = cond9 & ok, cond10 & ok
            local_gap = jnp.where(ok, local_gap, 0.0)
            disagree = jnp.where(ok, disagree, 0.0)
            n_target = psum(jnp.sum(honest.astype(dtype)))
        n9 = psum(jnp.sum(cond9.astype(dtype)))
        n10 = psum(jnp.sum(cond10.astype(dtype)))
        n_both = psum(jnp.sum((cond9 & cond10).astype(dtype)))
        resid = resid.astype(dtype)
        certified = ((n_both == n_target)
                     & (resid <= self.cons_tol)).astype(dtype)
        violated = ((resid > self.viol_tol)
                    | ~jnp.isfinite(resid)).astype(dtype)
        return jnp.stack([pmax(jnp.max(local_gap)).astype(dtype),
                          pmax(jnp.max(disagree)).astype(dtype),
                          n9, n10, certified, resid, violated])

    def invariant_sums(self, x_parts, v_stack, a_parts,
                       honest=None) -> jax.Array:
        """(2, d) stacked [sum_k v_k, sum_k A_[k] x_[k]] — the Lemma-1
        residual's inputs. Stacked so the distributed path completes BOTH
        partials with ONE vector psum (O(d), no stack gathers). ``honest``
        (attack-aware mode) restricts both sums to the honest cohort, whose
        invariant sum_H v = K * A_H x_H is what a working defense
        preserves (the full-network one is unpreservable: a rejected liar's
        data never reaches the cohort)."""
        if honest is None:
            v_sum = jnp.sum(v_stack, axis=0)
            ax_sum = jnp.einsum("kdn,kn->d", a_parts, x_parts)
        else:
            h = honest.astype(v_stack.dtype)
            v_sum = jnp.sum(h[:, None] * v_stack, axis=0)
            ax_sum = jnp.einsum("kdn,kn,k->d", a_parts, x_parts, h)
        return jnp.stack([v_sum, ax_sum])

    def record_fn(self, state, sched=None) -> jax.Array:
        if self.cohort:
            return self._cohort_record(state, sched)
        grads = jax.vmap(self.problem.grad_f)(state.v_stack)   # (K, d)
        if self.dynamic:
            mask = sched["cert_mask"]
            grad_thresh = sched["cert_grad_thresh"]
        else:
            mask, grad_thresh = self.neigh_mask, self.grad_thresh
        hon = None
        if self.attack_aware:
            hon = (jnp.asarray(sched["atk_dishonest"])
                   <= 0).astype(state.v_stack.dtype)
            # dishonest nodes leave every neighborhood mean: their emitted
            # gradient information is exactly what the defense discards
            mask = jnp.asarray(mask) * hon[None, :]
        neigh_mean = neighborhood_mean(grads, mask)
        local_gap, disagree = self.local_row_inputs(
            state.x_parts, state.v_stack, grads, neigh_mean)
        sums = self.invariant_sums(state.x_parts, state.v_stack,
                                   self.a_parts, honest=hon)
        resid = consensus_residual(sums[0], sums[1], self.part.num_nodes)
        return self.summarize(local_gap, disagree, resid=resid,
                              grad_thresh=grad_thresh, honest=hon)

    def _cohort_record(self, state, sched) -> jax.Array:
        """Cohort-mode row: everything O(K * d) or O(K' * d) — the Eq.-9
        gaps and Lemma-1 sums run over the full population (frozen nodes
        must still satisfy condition 9, exactly as under churn), while the
        Eq.-10 neighborhood mean is the one cohort-mean broadcast."""
        idx = sched["cohort_idx"]                               # (K',)
        act = jnp.asarray(sched["active"]) > 0                  # (K,)
        grads = jax.vmap(self.problem.grad_f)(state.v_stack)    # (K, d)
        cohort_mean = jnp.mean(grads[idx], axis=0)              # (d,)
        neigh_mean = jnp.where(act[:, None], cohort_mean[None, :], grads)
        local_gap, disagree = self.local_row_inputs(
            state.x_parts, state.v_stack, grads, neigh_mean)
        sums = self.invariant_sums(state.x_parts, state.v_stack,
                                   self.a_parts)
        resid = consensus_residual(sums[0], sums[1], self.part.num_nodes)
        return self.summarize(local_gap, disagree, resid=resid)

    @property
    def stop_fn(self) -> Callable | None:
        idx_c = self.labels.index("certified")
        idx_v = self.labels.index("certificate_violated")
        if self.stop_on_certified and self.stop_on_violation:
            return lambda row: (row[idx_c] > 0) | (row[idx_v] > 0)
        if self.stop_on_certified:
            return lambda row: row[idx_c] > 0
        if self.stop_on_violation:
            return lambda row: row[idx_v] > 0
        return None

    def cadence_ratio(self, row) -> jax.Array:
        """Distance-to-certification for ``AdaptiveCadence``: the worse of
        the two condition margins. Uses the static init-time thresholds even
        in dynamic (churn) mode — cadence is a scheduling heuristic, never a
        soundness input (certification itself always uses the round's true
        thresholds)."""
        gap_r = row[self.labels.index("local_gap_max")] / self.gap_thresh
        dis_r = (row[self.labels.index("grad_disagreement_max")]
                 / self.grad_thresh)
        return jnp.maximum(gap_r, dis_r)

    def init_spec(self) -> dict:
        if self.neigh_mask is None:
            return {"sigma_k": self.sigma_k}
        return {"sigma_k": self.sigma_k, "neigh_mask": self.neigh_mask}

    def cache_token(self):
        return ("CertificateRecorder", self.eps, self.beta_ub, self.l_bound,
                self.gap_thresh, self.grad_thresh, self.stop_on_certified,
                self.dynamic, self.cons_tol, self.viol_tol,
                self.stop_on_violation, self.attack_aware, self.cohort,
                None if self.neigh_mask is None
                else np.asarray(self.neigh_mask).tobytes())

    def collective_footprint(self, k: int, d: int, n_k: int,
                             itemsize: int = 4, comm: str = "dense",
                             conn: int = 1) -> dict:
        # scalar psums + the ONE stacked (2, d) Lemma-1 invariant psum
        # (``invariant_sums``) — still O(d) per device per record round
        scalars = (2 * len(self.labels) + 3) * itemsize + 2 * d * itemsize
        if comm == "ring":
            # 2*conn ppermute pushes of one (d,) gradient + scalar psums
            return {"all-gather": 0, "all-reduce": scalars,
                    "collective-permute": 2 * conn * d * itemsize}
        # dense fallback mirrors the round body's own gossip gather
        return {"all-gather": k * d * itemsize, "all-reduce": scalars,
                "collective-permute": 0}


@dataclasses.dataclass(frozen=True)
class ComposedRecorder:
    """Concatenate several recorders into one row; stop when ANY constituent
    recorder's stop condition fires. Labels must be pairwise disjoint."""

    parts: tuple

    def __post_init__(self):
        labels = self.labels
        if len(set(labels)) != len(labels):
            raise ValueError(f"composed recorder labels collide: {labels}")

    @property
    def labels(self):
        return tuple(lbl for p in self.parts for lbl in p.labels)

    @property
    def uses_schedule(self) -> bool:
        return any(getattr(p, "uses_schedule", False) for p in self.parts)

    def record_fn(self, state, sched=None) -> jax.Array:
        return jnp.concatenate([
            p.record_fn(state, sched)
            if getattr(p, "uses_schedule", False) else p.record_fn(state)
            for p in self.parts])

    @property
    def stop_fn(self) -> Callable | None:
        stops = []
        off = 0
        for p in self.parts:
            if p.stop_fn is not None:
                stops.append((off, off + len(p.labels), p.stop_fn))
            off += len(p.labels)
        if not stops:
            return None

        def stop(row):
            flags = [fn(row[a:b]) for a, b, fn in stops]
            out = flags[0]
            for f in flags[1:]:
                out = jnp.logical_or(out, f)
            return out

        return stop

    def cadence_ratio(self, row) -> jax.Array:
        """Min over constituent ratios: the recorder CLOSEST to stopping
        drives the cadence (any near part must tighten the whole row's
        cadence, since a single row serves every part)."""
        ratios = []
        off = 0
        for p in self.parts:
            if hasattr(p, "cadence_ratio"):
                try:
                    ratios.append(p.cadence_ratio(row[off:off + len(p.labels)]))
                except ValueError:  # e.g. gap part without eps: no opinion
                    pass
            off += len(p.labels)
        if not ratios:
            raise ValueError("adaptive cadence needs at least one part with "
                             "a cadence_ratio (gap-with-eps or certificate)")
        out = ratios[0]
        for r in ratios[1:]:
            out = jnp.minimum(out, r)
        return out

    def init_spec(self) -> dict:
        return {f"part{i}": p.init_spec() for i, p in enumerate(self.parts)}

    def cache_token(self):
        return ("ComposedRecorder",) + tuple(p.cache_token()
                                             for p in self.parts)

    def collective_footprint(self, k, d, n_k, itemsize=4, comm="dense",
                             conn=1) -> dict:
        out: dict = {}
        for p in self.parts:
            for kind, b in p.collective_footprint(
                    k, d, n_k, itemsize, comm, conn).items():
                out[kind] = out.get(kind, 0) + b
        return out


@dataclasses.dataclass(frozen=True)
class FnRecorder:
    """Ad-hoc recorder from a bare row function (the baselines' objective /
    consensus row, test probes). ``stop`` is an optional row -> bool."""

    labels: tuple
    fn: Callable
    stop: Callable | None = None

    def record_fn(self, state) -> jax.Array:
        return self.fn(state)

    @property
    def stop_fn(self) -> Callable | None:
        return self.stop

    def init_spec(self) -> dict:
        return {}

    def cache_token(self):
        # functions fingerprint by bytecode + closure via executor.fingerprint
        return ("FnRecorder", self.labels, self.fn, self.stop)

    def collective_footprint(self, k, d, n_k, itemsize=4, comm="dense",
                             conn=1) -> dict:
        return {"all-gather": 0, "all-reduce": 0, "collective-permute": 0}


def certificate_recorder(problem, part: Partition, env, neighbors,
                         eps: float, *, w=None,
                         sigma_k: jax.Array | None = None,
                         stop_on_certified: bool = True,
                         cons_tol: float = 1e-2, viol_tol: float = 0.1,
                         stop_on_violation: bool = False
                         ) -> CertificateRecorder:
    """Build a ``CertificateRecorder``, resolving every round-invariant input.

    Args:
      env: the ``ColaEnv`` (supplies a_parts / gp_parts / masks).
      neighbors: adjacency (or mixing matrix) whose support defines N_k.
      w: the mixing matrix used for the contraction bound beta; defaults to
        Metropolis weights over ``neighbors`` when it is a Topology.
      sigma_k: optional precomputed ``block_spectral_norms`` cache.
    """
    if isinstance(neighbors, topo.Topology):
        graph = neighbors
        neighbors = graph.adjacency
        if w is None:
            w = topo.metropolis_weights(graph)
    if w is None:
        w = np.asarray(neighbors, dtype=np.float64)
    l_bound = float(problem.l_bound)
    if not math.isfinite(l_bound):
        raise ValueError(
            f"problem {problem.name!r} has unbounded g_i support "
            "(l_bound=inf): Prop. 1 needs an L-bounded problem "
            "(lasso / box-constrained) — use the gap recorder instead")
    k = part.num_nodes
    sigma_k = block_spectral_norms(env.a_parts, cache=sigma_k)
    beta_ub = float(topo.beta(np.asarray(w)))
    mask = neighbor_mask(neighbors, k, dtype=env.a_parts.dtype)
    gap_thresh, grad_thresh = certificate_thresholds(
        env.masks, sigma_k, beta_ub, l_bound, eps, k)
    return CertificateRecorder(
        problem=problem, part=part, a_parts=env.a_parts,
        gp_parts=env.gp_parts, masks=env.masks, neigh_mask=mask,
        sigma_k=sigma_k, eps=float(eps), beta_ub=beta_ub, l_bound=l_bound,
        gap_thresh=float(gap_thresh), grad_thresh=float(grad_thresh),
        stop_on_certified=stop_on_certified, cons_tol=cons_tol,
        viol_tol=viol_tol, stop_on_violation=stop_on_violation)


def cohort_certificate_recorder(problem, part: Partition, env,
                                eps: float, *,
                                stop_on_certified: bool = True,
                                cons_tol: float = 1e-2,
                                viol_tol: float = 0.1,
                                stop_on_violation: bool = False
                                ) -> CertificateRecorder:
    """Build the client-sampling certificate (``cohort=True``): Prop.-1
    over the sampled subnetwork of a COMPLETE base graph. No (K, K) array
    is ever built — the neighbor mask is structural (the cohort) and the
    thresholds derive with the sampled-complete contraction factor
    beta = 0 (the induced mixing matrix is a rank-one projector)."""
    l_bound = float(problem.l_bound)
    if not math.isfinite(l_bound):
        raise ValueError(
            f"problem {problem.name!r} has unbounded g_i support "
            "(l_bound=inf): Prop. 1 needs an L-bounded problem "
            "(lasso / box-constrained) — use the gap recorder instead")
    k = part.num_nodes
    sigma_k = block_spectral_norms(env.a_parts)
    gap_thresh, grad_thresh = certificate_thresholds(
        env.masks, sigma_k, 0.0, l_bound, eps, k)
    return CertificateRecorder(
        problem=problem, part=part, a_parts=env.a_parts,
        gp_parts=env.gp_parts, masks=env.masks, neigh_mask=None,
        sigma_k=sigma_k, eps=float(eps), beta_ub=0.0, l_bound=l_bound,
        gap_thresh=float(gap_thresh), grad_thresh=float(grad_thresh),
        stop_on_certified=stop_on_certified, cons_tol=cons_tol,
        viol_tol=viol_tol, stop_on_violation=stop_on_violation,
        cohort=True)


def dynamize(recorder):
    """Churn-aware variant: every certificate part reads its Eq.-10
    neighborhood mask and threshold from the per-round schedule (see
    ``certificate_schedule``) instead of the static init-time graph — the
    static graph's denser mixing would make the threshold unsoundly loose
    during rounds where nodes have dropped."""
    if isinstance(recorder, ComposedRecorder):
        return dataclasses.replace(recorder, parts=tuple(
            dynamize(p) for p in recorder.parts))
    if isinstance(recorder, CertificateRecorder):
        return dataclasses.replace(recorder, dynamic=True)
    return recorder


def attackify(recorder, cons_tol: float = 0.25, viol_tol: float = 0.5):
    """Attack-harness variant: every certificate part audits the honest
    cohort, reading the attack schedule's ground-truth per-round dishonesty
    mask (``atk_dishonest``) — see ``CertificateRecorder.attack_aware``.
    Drivers apply this when ``apply_attacks`` reports payload-corrupting
    scenarios; a clean run's recorder is untouched.

    The default tolerances widen: when the attack begins at round S > 0,
    the cohort invariant inherits the boundary offset
    ``C = sum_L (K a_L x_L(S) - v_L(S))`` — the pre-onset entanglement of
    the liars' contributions with the honest states. A sound defense keeps
    C CONSTANT (the residual plateaus at ||C||-scale, ~0.1 for onsets in
    the first tenth of training, exactly 0 for round-0 onsets), while an
    undefended run absorbs new lie mass every round and the residual
    accumulates toward ~1. The (0.25, 0.5) band separates those regimes;
    the raw residual stays in the history for inspection."""
    if isinstance(recorder, ComposedRecorder):
        return dataclasses.replace(recorder, parts=tuple(
            attackify(p, cons_tol, viol_tol) for p in recorder.parts))
    if isinstance(recorder, CertificateRecorder):
        return dataclasses.replace(recorder, attack_aware=True,
                                   cons_tol=max(recorder.cons_tol, cons_tol),
                                   viol_tol=max(recorder.viol_tol, viol_tol))
    return recorder


def first_certificate(recorder) -> CertificateRecorder | None:
    if isinstance(recorder, CertificateRecorder):
        return recorder
    if isinstance(recorder, ComposedRecorder):
        for p in recorder.parts:
            found = first_certificate(p)
            if found is not None:
                return found
    inner = getattr(recorder, "_inner", None)
    return first_certificate(inner) if inner is not None else None


def certificate_round_inputs(cert: CertificateRecorder, w_t, active
                             ) -> tuple[np.ndarray, float]:
    """(neighbor mask, Eq.-10 threshold) for ONE churn round: the mask is
    the support of the reweighted W_t (self-inclusive — dropped neighbors
    have W_kj = 0 and leave the neighborhood, as the real exchange would),
    and the threshold re-derives with beta of the ACTIVE subnetwork's
    mixing submatrix (frozen nodes are fixed points of W_t, whose trivial
    eigenvalue-1 blocks say nothing about the survivors' contraction)."""
    w_t = np.asarray(w_t, np.float64)
    k = w_t.shape[0]
    mask = (w_t != 0) | np.eye(k, dtype=bool)
    act = np.asarray(active) > 0
    beta_t = topo.beta(w_t[np.ix_(act, act)]) if act.sum() > 1 else 0.0
    n_sizes = np.sum(np.asarray(cert.masks), axis=1)
    scale = float(np.sum(n_sizes ** 2 * np.asarray(cert.sigma_k)))
    thresh = (scale ** -0.5) * (1.0 - beta_t) / (
        2.0 * cert.l_bound * np.sqrt(float(k))) * cert.eps
    return mask, float(thresh)


def certificate_schedule(recorder, w_stack, actives,
                         record_mask: np.ndarray) -> dict:
    """Materialize the dynamic certificate's per-round schedule entries:
    ``cert_mask`` (T, K, K) and ``cert_grad_thresh`` (T,), evaluated only
    for record rounds (other rounds' slices are never read under the
    ``lax.cond`` record flag)."""
    cert = first_certificate(recorder)
    t, k = np.shape(w_stack)[0], np.shape(w_stack)[1]
    dtype = np.asarray(w_stack[:1]).dtype if t else np.float32
    masks = np.zeros((t, k, k), dtype=dtype)
    thresh = np.zeros((t,), dtype=dtype)
    for t_i in np.nonzero(np.asarray(record_mask, dtype=bool))[0]:
        m, th = certificate_round_inputs(cert, w_stack[t_i], actives[t_i])
        masks[t_i] = m
        thresh[t_i] = th
    return {"cert_mask": masks, "cert_grad_thresh": thresh}


def make_recorder(kind, problem, part: Partition, env, graph,
                  w, eps: float | None):
    """Resolve a driver's ``recorder=`` argument ("gap", "certificate",
    "gap+certificate", or an already-built Recorder instance).

    ``eps`` arms early stopping: the gap recorder stops at ``gap <= eps``,
    the certificate recorder at Prop.-1 certification of ``eps``. In the
    composed form only the certificate drives the stop (the gap columns are
    recorded for reference).
    """
    if not isinstance(kind, str):
        return kind
    if kind == "gap":
        return GapRecorder(problem, part, eps=eps)
    if kind in ("certificate", "gap+certificate"):
        if eps is None:
            raise ValueError(
                f"recorder={kind!r} needs eps=: the Prop.-1 conditions "
                "certify a specific accuracy")
        cert = certificate_recorder(problem, part, env, graph.adjacency,
                                    eps, w=w)
        if kind == "certificate":
            return cert
        return ComposedRecorder((GapRecorder(problem, part, eps=None), cert))
    raise ValueError(f"unknown recorder {kind!r} (want 'gap', 'certificate', "
                     "'gap+certificate' or a Recorder instance)")


def annotate_violation(history: dict) -> dict:
    """Surface tamper detection in the history: ``violated_round`` is the
    first RECORDED round whose ``certificate_violated`` flag fired (None when
    the flag never fired; absent when the recorder has no certificate part).
    """
    if "certificate_violated" in history:
        history["violated_round"] = next(
            (r for r, v in zip(history["round"],
                               history["certificate_violated"]) if v > 0),
            None)
    return history


def history_from(recorder, result) -> dict:
    """Build the driver history dict from a ``BlockRunResult``: one list per
    recorder label, the recorded round indices (truncated at early stop) and
    the stop round (None when the run used its full budget)."""
    history: dict = {"round": [int(t) for t in result.rounds]}
    for j, name in enumerate(recorder.labels):
        history[name] = [float(v) for v in result.metrics[:, j]]
    history["stop_round"] = result.stop_round
    return annotate_violation(history)


def render_footprints(k: int, d: int, n_k: int, itemsize: int = 4) -> str:
    """Human-readable per-record-round collective footprint of the stock
    recorders on a K-device node mesh (the ``dryrun --plan`` section)."""
    dummy_part = Partition(num_nodes=k, n=k * n_k, block=n_k)
    gap = GapRecorder(problem=None, part=dummy_part)
    # footprint needs no arrays — build the certificate entry structurally
    lines = [f"[cola recorder footprint] K={k} d={d} n_k={n_k} "
             f"itemsize={itemsize} (bytes per device per record round)"]
    rows = [("gap (gather)", "dense",
             gap.collective_footprint(k, d, n_k, itemsize)),
            ("certificate", "dense",
             CertificateRecorder.collective_footprint(
                 _FootprintOnly(), k, d, n_k, itemsize, "dense")),
            ("certificate", "ring",
             CertificateRecorder.collective_footprint(
                 _FootprintOnly(), k, d, n_k, itemsize, "ring"))]
    for name, comm, fp in rows:
        body = "  ".join(f"{kind}={fp[kind]:,}" for kind in
                         ("all-gather", "collective-permute", "all-reduce"))
        lines.append(f"  {name:<16} comm={comm:<6} {body}")
    return "\n".join(lines)


class _FootprintOnly:
    """Stand-in self for ``CertificateRecorder.collective_footprint`` so the
    plan can be rendered without materializing problem arrays."""

    labels = CERT_METRICS
