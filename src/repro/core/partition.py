"""Column partitioning of the data matrix A over K nodes (paper §1.1).

We use equal-size contiguous blocks (with zero-padding of A's columns when
``n % K != 0``) so the per-node state stacks into dense ``(K, d, n_k)`` /
``(K, n_k)`` arrays — the layout both the vmapped single-host simulator and the
shard_map distributed runtime operate on. Padded columns are all-zero, so their
coordinate updates are exact no-ops (guarded against 0/0 in the solver), and
``g`` contributions of padded coordinates are masked out.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Partition:
    """Equal block partition of n columns over K nodes."""

    num_nodes: int
    n: int            # true number of coordinates
    block: int        # n_k, coordinates per node (after padding)

    @property
    def n_padded(self) -> int:
        return self.num_nodes * self.block

    def pad_width(self) -> int:
        return self.n_padded - self.n

    def mask(self, dtype=jnp.float32) -> jax.Array:
        """(K, block) mask: 1 for real coordinates, 0 for padding."""
        flat = jnp.arange(self.n_padded) < self.n
        return flat.reshape(self.num_nodes, self.block).astype(dtype)

    def split_matrix(self, a: jax.Array) -> jax.Array:
        """(d, n) -> (K, d, block) column blocks."""
        d, n = a.shape
        assert n == self.n, (n, self.n)
        a_pad = jnp.pad(a, ((0, 0), (0, self.pad_width())))
        return jnp.moveaxis(a_pad.reshape(d, self.num_nodes, self.block), 1, 0)

    def split_vector(self, x: jax.Array) -> jax.Array:
        """(n,) -> (K, block)."""
        x_pad = jnp.pad(x, (0, self.pad_width()))
        return x_pad.reshape(self.num_nodes, self.block)

    def merge_vector(self, x_parts: jax.Array) -> jax.Array:
        """(K, block) -> (n,)."""
        return x_parts.reshape(-1)[: self.n]


def make_partition(n: int, num_nodes: int) -> Partition:
    block = -(-n // num_nodes)  # ceil division
    return Partition(num_nodes=num_nodes, n=n, block=block)


def shuffle_columns(a: np.ndarray, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Shuffle columns before partitioning (the paper shuffles and distributes).

    Returns the shuffled matrix and the permutation used.
    """
    rng = np.random.default_rng(seed)
    perm = rng.permutation(a.shape[1])
    return a[:, perm], perm
