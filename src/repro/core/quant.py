"""Wire codecs for quantized gossip: int8 / fp8 payloads + error feedback.

COLA's round traffic is the dual-estimate payload each node sends its
neighbors.  This module models that WIRE: a payload is quantized once per
(round, gossip step) on the sender, crosses every link as a narrow-dtype
tensor plus a per-node-row fp32 absmax scale sidecar, and every receiver
dequantizes the SAME values before the mixing contraction.  The semantics
are deliberately device-count-invariant: a neighbor contribution goes
through quantize-dequantize whether or not it physically crosses a device
boundary (including the node's own diagonal term), so the simulator, the
per-node plan lowering and the block lowering all compute one function and
the existing sim<->plan<->block parity suites extend to ``wire=int8/fp8``
unchanged.

Codecs
------
``int8``   symmetric absmax: ``scale = absmax/127`` per row, payload in
           ``[-127, 127]``.
``fp8``    absmax-rescaled cast to ``float8_e4m3fn`` (``fp8_e5m2`` selects
           the wide-exponent variant): ``scale = absmax/F8_MAX``.

Rounding is stochastic when a PRNG key is supplied (unbiased:
``E[dequantize(quantize(x))] = x``) and round-to-nearest otherwise.  Keys
derive from ``wire_key(key, round, step, color)`` — ``fold_in`` chained in
that order — then per node row via ``fold_in(key, node_id)``, so the draw
a node makes is a function of (seed, round, step, color, node) alone and
is bitwise identical no matter how rows are sharded across devices.

Error feedback
--------------
``wire_view(v, ef, ...)`` implements EF-compressed gossip: the node sends
``Q(v + ef)`` and keeps ``ef' = (v + ef) - dequantize(Q(v + ef))``.  The
residual rides the executor scan carry (``ColaState.ef``); the quantization
error then telescopes across rounds instead of accumulating as a noise
floor, which is what lets an int8 wire reach the fp32 fixed point.

Byte accounting
---------------
``wire_itemsize`` (1 for int8/fp8, 4 for fp32) and ``SCALE_BYTES`` (one
fp32 scale per node row) feed ``CommPlan``/``BlockPlan`` byte budgets so
rendered bytes, ``.contract()`` caps and ``comm_budget`` all describe the
quantized wire.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: wire names accepted by ``ColaConfig.wire`` / ``GossipConfig.wire``
WIRES = ("fp32", "fp8", "fp8_e5m2", "int8")

#: bytes of the per-node-row fp32 absmax scale that rides beside every
#: quantized payload (the "scale sidecar")
SCALE_BYTES = 4

_F8 = {"fp8": ("float8_e4m3fn", 448.0, 3),
       "fp8_e5m2": ("float8_e5m2", 57344.0, 2)}


def canonical_wire(wire: str | None) -> str:
    w = wire or "fp32"
    if w not in WIRES:
        raise ValueError(f"wire={wire!r}: expected one of {WIRES}")
    return w


def is_quantized(wire: str | None) -> bool:
    return canonical_wire(wire) != "fp32"


def wire_dtype(wire: str):
    w = canonical_wire(wire)
    if w == "fp32":
        return jnp.float32
    if w == "int8":
        return jnp.int8
    return getattr(jnp, _F8[w][0])


def wire_itemsize(wire: str | None) -> int:
    """Bytes per payload element on this wire (1 for int8/fp8)."""
    return 4 if canonical_wire(wire) == "fp32" else 1


def wire_qmax(wire: str) -> float:
    w = canonical_wire(wire)
    if w == "int8":
        return 127.0
    if w == "fp32":
        raise ValueError("fp32 wire has no quantization grid")
    return _F8[w][1]


#: fold slot decorrelating the codec PRNG stream from every other use of
#: the run seed (the schedule rng, attack draws, ...) — ASCII "wire"
_WIRE_STREAM = 0x77697265


def wire_stream(key):
    """Shift a key into the codec stream — decorrelates the stochastic-
    rounding uniforms from any other draws folded off the same key (e.g.
    the DP wire noise, which folds the same (round, step) indices)."""
    return jax.random.fold_in(key, _WIRE_STREAM)


def round_keys(seed: int, rounds: int):
    """(rounds, 2) uint32 — raw per-round codec keys ``fold_in(base, t)``.

    Both executors (and the shard_map runtime) slice the SAME stack, so the
    stochastic-rounding draws are a function of (seed, round, step, color,
    node) alone — bitwise identical across drivers and shardings.
    """
    base = wire_stream(jax.random.PRNGKey(seed))
    return jax.vmap(lambda t: jax.random.fold_in(base, t))(
        jnp.arange(rounds, dtype=jnp.int32))


def step_key(round_key, step: int = 0, color: int = 0):
    """Fold the (step, color) slots onto an already round-folded key."""
    return jax.random.fold_in(jax.random.fold_in(round_key, step), color)


def wire_key(key, round_: int, step: int = 0, color: int = 0):
    """The codec PRNG stream: ``fold_in(round, step, color)`` in order.

    The single-payload wire design quantizes once per (round, step) and
    ppermutes the same tensor on every color, so the color slot is 0 on
    the hot path; per-color callers fold their color index here.
    """
    return step_key(jax.random.fold_in(key, round_), step, color)


def _sr_int_grid(y, u):
    # stochastic rounding on the integer grid: floor(y + u), u ~ U[0, 1)
    return jnp.floor(y + u)


def _sr_f8_grid(y, u, mant_bits):
    # stochastic rounding on the local power-of-two-aligned fp8 grid:
    # floor |y| to the grid spanned by ulp = 2^(e - mant_bits), add the
    # uniform before flooring.  Values land exactly on representable fp8
    # points, so the final round-to-nearest cast is the identity.
    a = jnp.abs(y)
    e = jnp.floor(jnp.log2(jnp.maximum(a, jnp.float32(2.0) ** -24)))
    ulp = jnp.exp2(e - mant_bits)
    mag = jnp.floor(a / ulp + u) * ulp
    return jnp.sign(y) * mag


def quantize(x, wire: str, key=None):
    """Quantize ``x`` rows (absmax over the LAST axis) onto the wire grid.

    Returns ``(payload, scale)``: payload in the wire dtype with ``x``'s
    shape, scale fp32 with shape ``x.shape[:-1] + (1,)``.  Stochastic
    rounding when ``key`` is given (one uniform draw per element),
    round-to-nearest otherwise.
    """
    w = canonical_wire(wire)
    x = jnp.asarray(x, jnp.float32)
    if w == "fp32":
        return x, jnp.ones(x.shape[:-1] + (1,), jnp.float32)
    qmax = wire_qmax(w)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    # multiply by the constant reciprocal instead of dividing by qmax: XLA
    # strength-reduces constant divides to multiplies in SOME programs only,
    # which would make the wire scale differ by 1 ulp between the simulator
    # and the shard_map lowerings — spelling the multiply out keeps the
    # (payload, scale) bits identical across every jitted program
    scale = jnp.where(absmax > 0, absmax * jnp.float32(1.0 / qmax),
                      jnp.float32(1.0))
    y = x / scale
    if w == "int8":
        if key is not None:
            y = _sr_int_grid(y, jax.random.uniform(key, x.shape))
        else:
            y = jnp.round(y)
        q = jnp.clip(y, -qmax, qmax).astype(jnp.int8)
    else:
        if key is not None:
            y = _sr_f8_grid(y, jax.random.uniform(key, x.shape), _F8[w][2])
        q = jnp.clip(y, -qmax, qmax).astype(wire_dtype(w))
    return q, scale


def dequantize(q, scale):
    """Inverse of :func:`quantize`: fp32 values every receiver sees."""
    return q.astype(jnp.float32) * scale


def saturation_frac(q, wire: str):
    """Fraction of payload elements pinned at the wire grid's extreme
    (|q| == qmax) — the on-device saturation signal ``repro.obs`` counters
    accumulate. A persistently high fraction means the absmax scale is
    dominated by outlier coordinates and most of the grid is unused."""
    w = canonical_wire(wire)
    if w == "fp32":
        return jnp.float32(0.0)
    qmax = jnp.float32(wire_qmax(w))
    at_max = jnp.abs(q.astype(jnp.float32)) >= qmax
    return jnp.mean(at_max.astype(jnp.float32))


def node_keys(key, node_ids):
    """Per-node codec keys: ``fold_in(key, node_id)`` for each row.

    ``node_ids`` are GLOBAL node indices, so a (K, d) stack on one host,
    one (d,) row per device, and a (K/M, d) block shard all draw the same
    per-node randomness.
    """
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.asarray(node_ids, jnp.int32))


def quantize_rows(v, wire: str, key=None, node_ids=None):
    """Quantize a stack of per-node rows ``v[..., d]`` (leading axis =
    nodes) with per-node stochastic-rounding keys."""
    if key is None:
        return quantize(v, wire)
    if node_ids is None:
        node_ids = jnp.arange(v.shape[0])
    keys = node_keys(key, node_ids)
    return jax.vmap(lambda row, k: quantize(row, wire, k))(v, keys)


def encode(v, wire: str, key=None, node_ids=None, ef=None):
    """EF-compensated sender encode: payload/scale/receiver-view/residual.

    Sends ``Q(v + ef)``; the new residual is ``(v + ef) - deq`` (zero when
    error feedback is off, i.e. ``ef is None``).
    Returns ``(payload, scale, deq, ef_new)``.
    """
    p = v if ef is None else v + ef
    q, s = quantize_rows(p, wire, key, node_ids)
    deq = dequantize(q, s)
    ef_new = None if ef is None else p - deq
    return q, s, deq, ef_new


def wire_view(v, ef, wire: str, key=None, node_ids=None):
    """The dequantized values the network sees for ``v`` + EF bookkeeping.

    Returns ``(deq, ef_new)``.  ``wire='fp32'`` is the identity.
    """
    if not is_quantized(wire):
        return v, ef
    _, _, deq, ef_new = encode(v, wire, key, node_ids, ef)
    return deq, ef_new


def ef_init(v_stack, wire: str):
    """Zero EF residual matching the dual-estimate stack (None on fp32)."""
    if not is_quantized(wire):
        return None
    return jnp.zeros_like(v_stack)


# --- pytree wire (gossip-SGD path) -----------------------------------------

def wire_view_pytree(params, wire: str, key=None):
    """Quantize-dequantize every leaf of a (K, ...)-stacked pytree.

    Each leaf is flattened to (K, -1) rows (per-node absmax scales), keyed
    per leaf via ``fold_in(key, leaf_index)``.  Stateless (no EF): the
    gossip-SGD mixer re-quantizes fresh values every mix round.
    """
    if not is_quantized(wire):
        return params
    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for i, leaf in enumerate(leaves):
        k = None if key is None else jax.random.fold_in(key, i)
        rows = leaf.reshape((leaf.shape[0], -1))
        q, s = quantize_rows(rows, wire, k)
        out.append(dequantize(q, s).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def payload_bytes(d: int, wire: str, rows: int = 1) -> int:
    """Wire bytes of one ``rows x d`` payload: quantized elements + the
    fp32 scale sidecar (one scale per row; zero sidecar on fp32)."""
    sidecar = 0 if not is_quantized(wire) else rows * SCALE_BYTES
    return rows * d * wire_itemsize(wire) + sidecar
