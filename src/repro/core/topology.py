"""Network topologies and mixing matrices for decentralized learning (CoLA §1.1, App. B).

The communication graph of the K nodes is represented by a symmetric adjacency
matrix; the gossip mixing matrix ``W`` is built from Metropolis-Hastings weights
(App. B), which makes ``W`` symmetric and doubly stochastic for any connected
undirected graph. The spectral gap ``1 - beta`` (beta = second largest
eigenvalue magnitude) governs the convergence rates of Theorems 1 and 2.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """An undirected communication graph over K nodes."""

    name: str
    adjacency: np.ndarray  # (K, K) bool, no self loops

    @property
    def num_nodes(self) -> int:
        return self.adjacency.shape[0]

    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1)

    def neighbors(self, k: int) -> np.ndarray:
        return np.nonzero(self.adjacency[k])[0]


def _empty_adj(k: int) -> np.ndarray:
    return np.zeros((k, k), dtype=bool)


def ring(k: int) -> Topology:
    adj = _empty_adj(k)
    idx = np.arange(k)
    adj[idx, (idx + 1) % k] = True
    adj[(idx + 1) % k, idx] = True
    return Topology("ring", adj)


def connected_cycle(k: int, c: int) -> Topology:
    """c-connected cycle: each node linked to its c nearest neighbors per side."""
    if c < 1 or 2 * c >= k:
        raise ValueError(f"need 1 <= c < k/2, got c={c}, k={k}")
    adj = _empty_adj(k)
    idx = np.arange(k)
    for off in range(1, c + 1):
        adj[idx, (idx + off) % k] = True
        adj[(idx + off) % k, idx] = True
    return Topology(f"{c}-connected-cycle", adj)


def grid_2d(rows: int, cols: int) -> Topology:
    """2-D grid (non-wrapping)."""
    k = rows * cols
    adj = _empty_adj(k)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            if c + 1 < cols:
                adj[i, i + 1] = adj[i + 1, i] = True
            if r + 1 < rows:
                adj[i, i + cols] = adj[i + cols, i] = True
    return Topology(f"grid-{rows}x{cols}", adj)


def torus_2d(rows: int, cols: int) -> Topology:
    """2-D torus — matches the physical ICI mesh of a TPU pod slice."""
    k = rows * cols
    adj = _empty_adj(k)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            right = r * cols + (c + 1) % cols
            down = ((r + 1) % rows) * cols + c
            if right != i:  # degenerate 1-wide torus: no self loops
                adj[i, right] = adj[right, i] = True
            if down != i:
                adj[i, down] = adj[down, i] = True
    return Topology(f"torus-{rows}x{cols}", adj)


def complete(k: int) -> Topology:
    adj = ~np.eye(k, dtype=bool)
    return Topology("complete", adj)


@dataclasses.dataclass(frozen=True)
class ImplicitTopology:
    """A graph too large for a dense (K, K) adjacency matrix.

    Duck-types the ``Topology`` surface the drivers actually touch
    (``name``, ``num_nodes``); anything needing the dense adjacency or a
    materialized mixing matrix must special-case it (the cohort-sampling
    path in ``repro.core.cola`` does — its mixing is the closed-form
    uniform average over the sampled subnetwork, never a matrix).
    """

    name: str
    num_nodes: int


def implicit_complete(k: int) -> ImplicitTopology:
    """Complete graph over K nodes without the O(K^2) adjacency — the
    million-node population form ``ColaConfig(participation=...)``'s cohort
    mode consumes."""
    return ImplicitTopology("complete", k)


def star(k: int) -> Topology:
    adj = _empty_adj(k)
    adj[0, 1:] = True
    adj[1:, 0] = True
    return Topology("star", adj)


def disconnected(k: int) -> Topology:
    """No edges: W = I, spectral gap 0. Used in tests for the degenerate case."""
    return Topology("disconnected", _empty_adj(k))


TOPOLOGIES: Dict[str, Callable[[int], Topology]] = {
    "ring": ring,
    "cycle2": lambda k: connected_cycle(k, 2),
    "cycle3": lambda k: connected_cycle(k, 3),
    "grid": lambda k: grid_2d(*_square_factors(k)),
    "torus": lambda k: torus_2d(*_square_factors(k)),
    "complete": complete,
    "star": star,
}


def _square_factors(k: int) -> tuple[int, int]:
    r = int(np.sqrt(k))
    while k % r:
        r -= 1
    return r, k // r


def metropolis_weights(topology: Topology) -> np.ndarray:
    """Metropolis-Hastings mixing matrix (App. B): symmetric, doubly stochastic.

    W_ij = 1 / (1 + max(d_i, d_j)) for edges, diagonal absorbs the slack.
    """
    adj = topology.adjacency
    k = adj.shape[0]
    deg = adj.sum(axis=1).astype(np.float64)
    w = np.zeros((k, k), dtype=np.float64)
    ii, jj = np.nonzero(adj)
    w[ii, jj] = 1.0 / (1.0 + np.maximum(deg[ii], deg[jj]))
    w[np.arange(k), np.arange(k)] = 1.0 - w.sum(axis=1)
    return w


def beta(w: np.ndarray) -> float:
    """Second largest eigenvalue magnitude of a symmetric mixing matrix."""
    eig = np.linalg.eigvalsh(w)
    eig = np.sort(np.abs(eig))[::-1]
    return float(eig[1]) if eig.size > 1 else 0.0


def spectral_gap(w: np.ndarray) -> float:
    return 1.0 - beta(w)


def reweight_for_active(topology: Topology, active: np.ndarray) -> np.ndarray:
    """Mixing matrix when only ``active`` nodes participate (fault tolerance, §2).

    The remaining nodes "dynamically adjust their weights to maintain the doubly
    stochastic property" (paper §4): we apply Metropolis weights to the induced
    subgraph. Inactive nodes get W_kk = 1 (their state is frozen, no mixing).
    """
    adj = topology.adjacency & active[:, None] & active[None, :]
    k = adj.shape[0]
    deg = adj.sum(axis=1).astype(np.float64)
    w = np.zeros((k, k), dtype=np.float64)
    ii, jj = np.nonzero(adj)
    w[ii, jj] = 1.0 / (1.0 + np.maximum(deg[ii], deg[jj]))
    w[np.arange(k), np.arange(k)] = 1.0 - w.sum(axis=1)
    return w


def ring_weights(k: int, self_weight: float | None = None) -> np.ndarray:
    """Convenience: ring Metropolis weights (1/3 left, 1/3 self, 1/3 right for K>2)."""
    return metropolis_weights(ring(k))
