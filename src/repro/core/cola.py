"""CoLA — Algorithm 1, plus the CoCoA special case and the elastic runtime.

The single-host simulator keeps all K nodes' state stacked:
  x_parts (K, n_k), v_stack (K, d); one round is a single jitted program
(gossip mix -> vmapped local CD solve -> local updates). The shard_map
distributed runtime in ``repro.dist.runtime`` executes the same math with the
node axis laid out over mesh devices; tests assert bitwise-equivalent rounds.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mixing, topology as topo
from repro.core.duality import GapReport, gap_report
from repro.core.partition import Partition, make_partition
from repro.core.problems import Problem
from repro.core.subproblem import SubproblemSpec, cd_solve_all


@dataclasses.dataclass(frozen=True)
class ColaConfig:
    """Hyper-parameters of Algorithm 1. The paper's safe defaults need no tuning."""

    gamma: float = 1.0              # aggregation parameter (paper uses 1)
    sigma_prime: float | None = None  # subproblem relaxation; default gamma*K
    kappa: float = 1.0              # CD passes over the local block per round;
    #   kappa * n_k = the paper's "number of coordinates updated" (Fig. 1),
    #   the knob controlling the local accuracy Theta. May be fractional.
    gossip_steps: int = 1           # B gossip steps per round (App. E.2)
    grad_mode: str = "local"        # "local" (Eq. 2) | "mixed" (App. E.1)

    def resolved_sigma(self, k: int) -> float:
        return self.gamma * k if self.sigma_prime is None else self.sigma_prime

    def coord_steps(self, block: int) -> int:
        return max(1, int(round(self.kappa * block)))


class ColaState(NamedTuple):
    x_parts: jax.Array  # (K, n_k)
    v_stack: jax.Array  # (K, d)


class ColaEnv(NamedTuple):
    """Per-run arrays derived from the problem + partition."""

    a_parts: jax.Array   # (K, d, n_k)
    gp_parts: jax.Array  # (K, n_k)
    masks: jax.Array     # (K, n_k)


def build_env(problem: Problem, part: Partition) -> ColaEnv:
    return ColaEnv(
        a_parts=part.split_matrix(problem.a),
        gp_parts=part.split_vector(problem.g_params()),
        masks=part.mask(problem.a.dtype),
    )


def init_state(problem: Problem, part: Partition) -> ColaState:
    return ColaState(
        x_parts=jnp.zeros((part.num_nodes, part.block), dtype=problem.a.dtype),
        v_stack=jnp.zeros((part.num_nodes, problem.d), dtype=problem.a.dtype),
    )


def make_round(problem: Problem, part: Partition, cfg: ColaConfig
               ) -> Callable[[ColaState, ColaEnv, jax.Array, jax.Array], ColaState]:
    """Build the jitted one-round function of Algorithm 1.

    Returned signature: round(state, env, w, active) -> state. ``w`` and
    ``active`` are dynamic so fault-tolerance schedules don't retrigger
    compilation.
    """
    k = part.num_nodes
    sigma = cfg.resolved_sigma(k)
    spec = SubproblemSpec(sigma_over_tau=sigma / problem.tau, inv_k=1.0 / k)

    @jax.jit
    def one_round(state: ColaState, env: ColaEnv, w: jax.Array,
                  active: jax.Array,
                  budgets: jax.Array | None = None) -> ColaState:
        # Step 4: gossip mixing of the local estimates (B steps, App. E.2).
        v_half = mixing.mix_power(w, state.v_stack, cfg.gossip_steps)

        # Gradient each node uses for its subproblem.
        grads = jax.vmap(problem.grad_f)(v_half)
        if cfg.grad_mode == "mixed":
            # App. E.1: use the neighborhood-mixed gradient sum_l W_kl grad f(v_l).
            grads = mixing.dense_mix(w, grads)

        # Step 5: Theta-approximate local subproblem solve (kappa * n_k CD
        # steps; per-node budgets model heterogeneous Theta_k, Definition 5).
        dx = cd_solve_all(problem, spec, env.a_parts, state.x_parts, grads,
                          env.gp_parts, env.masks, cfg.coord_steps(part.block),
                          step_budgets=budgets)
        dx = dx * active[:, None].astype(dx.dtype)

        # Steps 6-8: local variable + local estimate updates.
        x_new = state.x_parts + cfg.gamma * dx
        dv = jnp.einsum("kdn,kn->kd", env.a_parts, dx)
        v_new = v_half + cfg.gamma * k * dv
        return ColaState(x_parts=x_new, v_stack=v_new)

    return one_round


def cocoa_mixing(k: int) -> np.ndarray:
    """W = (1/K) 11^T: one gossip step yields the exact consensus v_c = Ax,
    recovering centralized CoCoA as a special case of CoLA."""
    return np.full((k, k), 1.0 / k)


class RunResult(NamedTuple):
    state: ColaState
    history: dict  # lists keyed by metric name


def run_cola(problem: Problem, graph: topo.Topology, cfg: ColaConfig,
             rounds: int, *, record_every: int = 1,
             active_schedule: Callable[[int, np.random.Generator], np.ndarray] | None = None,
             budget_schedule: Callable[[int, np.random.Generator], np.ndarray] | None = None,
             leave_mode: str = "freeze", seed: int = 0,
             w_override: np.ndarray | None = None) -> RunResult:
    """Driver: runs Algorithm 1 and records Lemma-1/2 diagnostics.

    Args:
      active_schedule: optional (round, rng) -> (K,) bool mask simulating node
        churn (Fig. 4/6). W is re-normalized over the active subgraph each
        round via Metropolis weights.
      budget_schedule: optional (round, rng) -> (K,) int CD-step budgets —
        heterogeneous per-node solver quality Theta_k (Definition 5):
        stragglers do fewer coordinate updates this round.
      leave_mode: "freeze" (paper's main model: x_[k] frozen) or "reset"
        (App. D Fig. 6: x_[k] zeroed and all v_j adjusted to preserve the
        Lemma-1 mean invariant).
      w_override: use this mixing matrix instead of Metropolis weights
        (e.g. ``cocoa_mixing(K)`` for the centralized special case).
    """
    k = graph.num_nodes
    part = make_partition(problem.n, k)
    env = build_env(problem, part)
    state = init_state(problem, part)
    one_round = make_round(problem, part, cfg)
    base_w = w_override if w_override is not None else topo.metropolis_weights(graph)
    rng = np.random.default_rng(seed)

    dtype = problem.a.dtype
    w = jnp.asarray(base_w, dtype=dtype)
    all_active = np.ones((k,), dtype=bool)
    history: dict = {"round": [], "primal": [], "hamiltonian": [], "dual": [],
                     "gap": [], "consensus_violation": []}

    report = jax.jit(lambda s: gap_report(problem, part, s.x_parts, s.v_stack))

    prev_active = all_active
    for t in range(rounds):
        if active_schedule is not None:
            active = np.asarray(active_schedule(t, rng), dtype=bool)
            if not active.any():
                active = all_active.copy()  # never let the whole network die
            w_t = jnp.asarray(topo.reweight_for_active(graph, active), dtype=dtype)
            if leave_mode == "reset":
                leavers = prev_active & ~active
                if leavers.any():
                    state = _reset_leavers(state, env, part, leavers)
            prev_active = active
        else:
            active, w_t = all_active, w
        budgets = None
        if budget_schedule is not None:
            budgets = jnp.asarray(budget_schedule(t, rng), dtype=jnp.int32)
        state = one_round(state, env, w_t,
                          jnp.asarray(active, dtype=dtype), budgets)
        if t % record_every == 0 or t == rounds - 1:
            rep = report(state)
            history["round"].append(t)
            for name in ("primal", "hamiltonian", "dual", "gap",
                         "consensus_violation"):
                history[name].append(float(getattr(rep, name)))
    return RunResult(state=state, history=history)


def _reset_leavers(state: ColaState, env: ColaEnv, part: Partition,
                   leavers: np.ndarray) -> ColaState:
    """Fig.-6 model: zero x_[k] of leaving nodes; every node subtracts
    A_[k] x_[k] from its local estimate so (1/K) sum v_k = A x still holds."""
    leave = jnp.asarray(leavers)
    contrib = jnp.einsum("kdn,kn->kd", env.a_parts,
                         state.x_parts * leave[:, None])  # (K, d)
    total = jnp.sum(contrib, axis=0)                      # A_[k] x_[k] summed
    x_new = jnp.where(leave[:, None], 0.0, state.x_parts)
    v_new = state.v_stack - total[None, :]
    return ColaState(x_parts=x_new, v_stack=v_new)


def solve_reference(problem: Problem, rounds: int = 3000,
                    kappa: int = 10) -> float:
    """High-accuracy reference optimum via single-node CoCoA (used as F* when
    reporting suboptimality, mirroring the paper's methodology in App. D)."""
    graph = topo.complete(2)
    cfg = ColaConfig(kappa=kappa)
    res = run_cola(problem, graph, cfg, rounds, record_every=max(rounds // 4, 1),
                   w_override=cocoa_mixing(2))
    return min(res.history["primal"])
