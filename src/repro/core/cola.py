"""CoLA — Algorithm 1, plus the CoCoA special case and the elastic runtime.

The single-host simulator keeps all K nodes' state stacked:
  x_parts (K, n_k), v_stack (K, d); one round is a single jitted program
(gossip mix -> vmapped local CD solve -> local updates). The shard_map
distributed runtime in ``repro.dist.runtime`` executes the same math with the
node axis laid out over mesh devices; tests assert bitwise-equivalent rounds.

Two interchangeable drivers execute the rounds (tests assert they are
bitwise identical):

* ``executor="loop"`` — the retained reference path: one ``make_round``
  dispatch per round, metrics fetched synchronously every ``record_every``.
* ``executor="block"`` (default) — the round-block engine
  (``repro.core.executor``): schedules (per-round mixing matrices, active
  masks, CD budgets, reset flags) are pre-materialized as stacked (T, ...)
  arrays, ``block_size`` rounds run per device dispatch inside a
  ``lax.scan``, metric history is recorded on device and fetched once at
  the end, and the (K, n_k)/(K, d) state buffers are donated across blocks.

Recording and stopping go through the pluggable Recorder layer
(``repro.core.metrics``): ``recorder="gap"`` keeps the historical Lemma-2
history, ``recorder="certificate"`` records the Prop.-1 local certificates,
and ``eps=`` arms certificate-driven early termination (the round budget
becomes an upper bound).

The local CD solve picks between the residual and Gram-cached formulations
(``repro.core.subproblem.gram_pays``) via ``ColaConfig.cd_mode``.
"""
from __future__ import annotations

import contextlib
import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import executor as exec_engine, metrics as metrics_lib, \
    mixing, quant, schedule as schedule_lib, topology as topo
from repro.core.duality import GapReport, gap_report
from repro.core.partition import Partition, make_partition
from repro.core.problems import Problem
from repro.core.subproblem import (SubproblemSpec, block_gram, cd_solve_all,
                                   gram_pays)


@dataclasses.dataclass(frozen=True)
class ColaConfig:
    """Hyper-parameters of Algorithm 1. The paper's safe defaults need no tuning."""

    gamma: float = 1.0              # aggregation parameter (paper uses 1)
    sigma_prime: float | None = None  # subproblem relaxation; default gamma*K
    kappa: float = 1.0              # CD passes over the local block per round;
    #   kappa * n_k = the paper's "number of coordinates updated" (Fig. 1),
    #   the knob controlling the local accuracy Theta. May be fractional.
    gossip_steps: int = 1           # B gossip steps per round (App. E.2)
    grad_mode: str = "local"        # "local" (Eq. 2) | "mixed" (App. E.1)
    cd_mode: str = "auto"           # local solver formulation:
    #   "auto" — Gram-cached when subproblem.gram_pays says it's cheaper,
    #   "gram" / "residual" — force one path (see subproblem docstring).
    robust: str | None = None       # Byzantine-resilient v aggregation:
    #   None — the paper's linear W mix; "trim" / "median" / "clip" swap in
    #   repro.core.mixing.robust_neighborhood_mix (per-neighborhood trimmed
    #   mean / median / per-neighbor norm clipping). Nonlinear: B gossip
    #   steps apply sequentially (no W^B fold).
    robust_trim: int = 1            # extremes dropped per side ("trim" mode)
    robust_clip: float | None = None  # clip radius; None = median-adaptive
    wire: str = "fp32"              # gossip payload codec (repro.core.quant):
    #   "fp32" — the paper's full-precision wire; "int8" / "fp8" /
    #   "fp8_e5m2" — per-node-row absmax quantization with stochastic
    #   rounding keyed by fold_in(round, step, color): payloads cross every
    #   link at 1 byte/elem plus a 4-byte fp32 scale sidecar per row.
    error_feedback: bool = True     # EF-compressed gossip on quantized
    #   wires: send Q(v + e), keep e' = (v + e) - deq. The residual rides
    #   the scan carry (ColaState.ef) and telescopes across rounds, which
    #   is what lets the narrow wire reach the fp32 fixed point; without it
    #   the quantization error accumulates as a noise floor.
    pipeline: bool = False          # software-pipeline comm against compute
    #   (quantized wires only): round t+1's step-0 payload is encoded at
    #   the END of round t and double-buffered in the scan carry
    #   (ColaState.buf), so its ppermutes issue at the TOP of the next
    #   round body BEFORE the local CD solve — bitwise identical to the
    #   unpipelined schedule, structured so a Pallas async-remote-DMA
    #   backend can overlap the transfer with the solve.
    telemetry: bool = False         # carry repro.obs.Counters through the
    #   round scan (block executor only): per-round wire bytes/ppermutes,
    #   quant saturation + EF norm, robust-gate rejection counts. Totals
    #   land in history["telemetry"] and a RunReport is appended to the
    #   .repro_runs registry. Off: the program is bitwise the untelemetered
    #   one (the counters field stays None and traces away).
    participation: Any = None       # partial participation (client
    #   sampling): a repro.core.schedule.SampleConfig — each round K' of K
    #   nodes are sampled active via a fold_in(round) draw STREAMED inside
    #   the round scan (no (T, K)-shaped schedule is materialized). Dense
    #   mode (K <= schedule.DENSE_MAX_NODES) streams the reweighted mixing
    #   matrix through the standard round body; cohort mode (million-node
    #   populations) gathers/updates only the (K', ...) cohort slices and
    #   never builds a (K, K) array. Requires executor="block" and a
    #   complete base graph (see repro.core.schedule).

    def resolved_sigma(self, k: int) -> float:
        return self.gamma * k if self.sigma_prime is None else self.sigma_prime

    def coord_steps(self, block: int) -> int:
        return max(1, int(round(self.kappa * block)))

    def use_gram(self, d: int, n_k: int, itemsize: int = 4) -> bool:
        if self.cd_mode == "gram":
            return True
        if self.cd_mode == "residual":
            return False
        return gram_pays(d, n_k, itemsize)


class ColaState(NamedTuple):
    x_parts: jax.Array  # (K, n_k)
    v_stack: jax.Array  # (K, d)
    # (K, d) error-feedback residual on quantized wires (None on fp32: the
    # pytree — and every fp32 program — is unchanged by the new fields)
    ef: jax.Array | None = None
    # pre-encoded (payload, scale) for the NEXT round's step-0 gossip when
    # cfg.pipeline — the double buffer the round body's ppermutes consume
    buf: Any = None
    # repro.obs.Counters telemetry accumulators when cfg.telemetry (None
    # otherwise — the pytree, and every untelemetered program, unchanged)
    counters: Any = None


class ColaEnv(NamedTuple):
    """Per-run arrays derived from the problem + partition."""

    a_parts: jax.Array   # (K, d, n_k)
    gp_parts: jax.Array  # (K, n_k)
    masks: jax.Array     # (K, n_k)
    # (K, n_k, n_k) node-local Gram blocks A_[k]^T A_[k] for the Gram-cached
    # CD path, or None when the heuristic says the residual path is cheaper.
    gram_parts: jax.Array | None = None


def build_env(problem: Problem, part: Partition, *,
              with_gram: bool | None = None) -> ColaEnv:
    """Materialize the per-run arrays. ``with_gram=None`` precomputes the
    Gram blocks exactly when ``subproblem.gram_pays`` says the Gram-cached
    CD formulation is the cheaper one for this (d, n_k, dtype)."""
    a_parts = part.split_matrix(problem.a)
    if with_gram is None:
        with_gram = gram_pays(problem.d, part.block, a_parts.dtype.itemsize)
    return ColaEnv(
        a_parts=a_parts,
        gp_parts=part.split_vector(problem.g_params()),
        masks=part.mask(problem.a.dtype),
        gram_parts=block_gram(a_parts) if with_gram else None,
    )


def init_state(problem: Problem, part: Partition) -> ColaState:
    return ColaState(
        x_parts=jnp.zeros((part.num_nodes, part.block), dtype=problem.a.dtype),
        v_stack=jnp.zeros((part.num_nodes, problem.d), dtype=problem.a.dtype),
    )


def _apply_payload_attack(v: jax.Array, atk: dict | None) -> jax.Array:
    """The wire transform a Byzantine/free-rider schedule applies to the
    OUTGOING per-node payloads: ``coef * v + bias_coef * bias``. One shared
    implementation feeds both the round body's mix input and the
    eavesdropper taps, so what the tap records is exactly what crossed the
    wire. Elementwise per node: identical on stacked (K, d) and node-sharded
    (ln, d) operands."""
    if not atk:
        return v
    if "coef" in atk:
        v = atk["coef"][:, None].astype(v.dtype) * v
    if "bias_coef" in atk:
        v = v + (atk["bias_coef"][:, None].astype(v.dtype)
                 * atk["bias"].astype(v.dtype))
    return v


def _round_body(problem: Problem, part: Partition, cfg: ColaConfig, *,
                mix_fn: Callable | None = None,
                grad_mix_fn: Callable | None = None,
                qmix_fn: Callable | None = None,
                qencode_fn: Callable | None = None) -> Callable:
    """The pure one-round function of Algorithm 1, shared verbatim by the
    per-round loop (``make_round``), the round-block scan executor, and the
    shard_map distributed runtime (``repro.dist.runtime``) — which is what
    makes the drivers bitwise identical.

    ``mix_fn(w, v_send, v_self)`` applies the B gossip steps (default: the
    dense ``mixing.mix_power_wire`` on the full stacked state, or the
    robust dense aggregation when ``cfg.robust`` is set); ``v_self`` is
    None unless a wire attack corrupted the payloads. ``grad_mix_fn(w,
    grads)`` applies one mixing step for ``grad_mode='mixed'``. The
    distributed runtime swaps in collective (ppermute/all-gather)
    implementations while every node-local op stays this exact code.

    ``atk`` (an optional dict of per-node attack operands sliced from the
    schedule by the drivers — see ``repro.attack``) corrupts the round: the
    emitted payload becomes ``coef * v + bias_coef * bias`` on the wire
    BEFORE the gossip mix — receivers consume the lie while every node's
    own state (and own mixing term) evolves honestly — and ``work`` masks
    dx after the solve (free riders). All elementwise per node, so the
    simulator's (K,) entries and the distributed runtime's node-sharded
    slices produce bitwise-identical rounds.
    """
    k = part.num_nodes
    sigma = cfg.resolved_sigma(k)
    spec = SubproblemSpec(sigma_over_tau=sigma / problem.tau, inv_k=1.0 / k)
    quantized = quant.is_quantized(cfg.wire)
    # a caller-supplied qmix_fn is a LOWERED wire (the dist runtime's
    # collective codec path — robust-aware when cfg.robust is set): the
    # composed simulator-oracle branch below must not shadow it, or the
    # encode would draw LOCAL row keys under shard_map
    lowered_qmix = qmix_fn is not None
    if quantized and qmix_fn is None:
        # simulator oracle: quantize-dequantize every node's payload (own
        # diagonal term included — the device-count-invariant wire view),
        # then the dense W contraction on the dequantized stack
        qmix_fn = lambda w, v, ef, qkey, payload: mixing.qmix_steps(
            w, v, ef, cfg.gossip_steps, cfg.wire, qkey, payload=payload)
    if quantized and qencode_fn is None:
        qencode_fn = lambda v, ef, nkey: quant.encode(
            v, cfg.wire, quant.step_key(nkey, 0), None, ef)
    if mix_fn is None:
        if cfg.robust is not None:
            mix_fn = lambda w, v_send, v_self: mixing.robust_mix_steps(
                w, v_send, cfg.robust, trim=cfg.robust_trim,
                clip=cfg.robust_clip, steps=cfg.gossip_steps,
                self_stack=v_self)
        else:
            mix_fn = lambda w, v_send, v_self: mixing.mix_power_wire(
                w, v_send, v_self, cfg.gossip_steps)
    if grad_mix_fn is None:
        grad_mix_fn = mixing.dense_mix

    def one_round(state: ColaState, env: ColaEnv, w: jax.Array,
                  active: jax.Array,
                  budgets: jax.Array | None = None,
                  atk: dict | None = None,
                  qkey: jax.Array | None = None,
                  qkey_next: jax.Array | None = None) -> ColaState:
        # Step 4: gossip mixing of the local estimates (B steps, App. E.2).
        # A payload attack exists ONLY on the wire: receivers consume the
        # lie, but each node's own mixing term and its internal state stay
        # honest (a two-faced attacker — the stealthiest case for the
        # certificate layer to catch). v_self=None flags the honest fast
        # path, which is then bitwise the unattacked program.
        if quantized and not lowered_qmix and (cfg.robust is not None or atk):
            # quantized wire composed with attacks and/or a robust defense
            # (simulator only — _check_wire_config scopes it to the dense
            # path, gossip_steps=1, no pipeline): the lie transforms the
            # fp32 value and is then ENCODED, so only codec payloads ever
            # cross the narrow wire; each node's own slot (and its EF
            # residual) tracks the codec view of its HONEST value, making
            # honest nodes' draws — and a clean defended run — bitwise the
            # undefended quantized program's.
            key0 = None if qkey is None else quant.step_key(qkey, 0)
            _, _, deq_self, ef_new = quant.encode(state.v_stack, cfg.wire,
                                                  key0, None, state.ef)
            v_send = _apply_payload_attack(state.v_stack, atk)
            if v_send is state.v_stack:
                deq_send, self_stack = deq_self, None
            else:
                p_atk = v_send if state.ef is None else v_send + state.ef
                qa, sa = quant.quantize_rows(p_atk, cfg.wire, key0)
                deq_send, self_stack = quant.dequantize(qa, sa), deq_self
            if cfg.robust is not None:
                v_half = mixing.robust_mix_steps(
                    w, deq_send, cfg.robust, trim=cfg.robust_trim,
                    clip=cfg.robust_clip, steps=cfg.gossip_steps,
                    self_stack=self_stack)
            else:
                v_half = mixing.mix_power_wire(w, deq_send, self_stack,
                                               cfg.gossip_steps)
        elif quantized:
            # quantized wire: EF-compensated codec view of every payload;
            # when pipelining, state.buf holds the step-0 payload encoded
            # at the end of the previous round — the first ppermutes issue
            # here, BEFORE this round's CD solve below
            v_half, ef_new = qmix_fn(w, state.v_stack, state.ef, qkey,
                                     state.buf)
        else:
            v_send = _apply_payload_attack(state.v_stack, atk)
            v_self = None if v_send is state.v_stack else state.v_stack
            v_half = mix_fn(w, v_send, v_self)

        # Gradient each node uses for its subproblem.
        grads = jax.vmap(problem.grad_f)(v_half)
        if cfg.grad_mode == "mixed":
            # App. E.1: use the neighborhood-mixed gradient sum_l W_kl grad f(v_l).
            grads = grad_mix_fn(w, grads)

        # Step 5: Theta-approximate local subproblem solve (kappa * n_k CD
        # steps; per-node budgets model heterogeneous Theta_k, Definition 5).
        use_gram = (env.gram_parts is not None
                    and cfg.use_gram(problem.d, part.block,
                                     env.a_parts.dtype.itemsize))
        if cfg.cd_mode == "gram" and env.gram_parts is None:
            raise ValueError(
                "cd_mode='gram' but the env has no Gram blocks — build it "
                "with build_env(problem, part, with_gram=True)")
        dx = cd_solve_all(problem, spec, env.a_parts, state.x_parts, grads,
                          env.gp_parts, env.masks, cfg.coord_steps(part.block),
                          step_budgets=budgets,
                          gram_parts=env.gram_parts if use_gram else None)
        dx = dx * active[:, None].astype(dx.dtype)
        if atk is not None and "work" in atk:
            # free riders: no local progress this round
            dx = dx * atk["work"][:, None].astype(dx.dtype)

        # Steps 6-8: local variable + local estimate updates.
        x_new = state.x_parts + cfg.gamma * dx
        dv = jnp.einsum("kdn,kn->kd", env.a_parts, dx)
        v_new = v_half + cfg.gamma * k * dv
        if not quantized:
            return ColaState(x_parts=x_new, v_stack=v_new)
        buf_new = None
        if cfg.pipeline:
            # modulo schedule: encode the NEXT round's step-0 payload now,
            # with the next round's codec key — bitwise what the next round
            # would have encoded at its top, just issued one round early
            q, s, _, ef_new = qencode_fn(v_new, ef_new, qkey_next)
            buf_new = (q, s)
        return ColaState(x_parts=x_new, v_stack=v_new, ef=ef_new,
                         buf=buf_new)

    return one_round


def make_round(problem: Problem, part: Partition, cfg: ColaConfig
               ) -> Callable[[ColaState, ColaEnv, jax.Array, jax.Array], ColaState]:
    """Build the jitted one-round function of Algorithm 1.

    Returned signature: round(state, env, w, active) -> state. ``w`` and
    ``active`` are dynamic so fault-tolerance schedules don't retrigger
    compilation.
    """
    return jax.jit(_round_body(problem, part, cfg))


def cocoa_mixing(k: int) -> np.ndarray:
    """W = (1/K) 11^T: one gossip step yields the exact consensus v_c = Ax,
    recovering centralized CoCoA as a special case of CoLA."""
    return np.full((k, k), 1.0 / k)


class RunResult(NamedTuple):
    state: ColaState
    history: dict  # lists keyed by metric name
    # Eavesdropper tap trajectory (T, n_tap, d) when the attack list carries
    # a repro.attack.Eavesdropper (simulator only); None otherwise.
    taps: Any = None


_METRICS = metrics_lib.GAP_METRICS


def run_cola(problem: Problem, graph: topo.Topology, cfg: ColaConfig,
             rounds: int, *, record_every: int = 1,
             recorder: str | Any = "gap", eps: float | None = None,
             active_schedule: Callable[[int, np.random.Generator], np.ndarray] | None = None,
             budget_schedule: Callable[[int, np.random.Generator], np.ndarray] | None = None,
             leave_mode: str = "freeze", seed: int = 0,
             w_override: np.ndarray | None = None,
             attacks=None,
             executor: str = "block", block_size: int = 64) -> RunResult:
    """Driver: runs Algorithm 1 under a pluggable metric Recorder.

    Args:
      recorder: "gap" (Lemma-1/2 diagnostics, the historical history keys),
        "certificate" (Prop.-1 local certificates), "gap+certificate", or a
        ``repro.core.metrics`` Recorder instance. History keys follow the
        recorder's labels.
      eps: target duality gap; arms certificate-driven early stopping.
        ``rounds`` becomes a budget: the run terminates at the first record
        round whose row certifies (certificate recorder) or reaches
        ``gap <= eps`` (gap recorder), with final state bitwise identical
        to a non-stopping run truncated at that round. Stopping is only
        checked on record rounds — ``record_every`` is the certification
        cadence.
      record_every: fixed integer cadence, or ``"adaptive"`` / a
        ``metrics.AdaptiveCadence`` to let the recorder drive it on device:
        geometric back-off while the recorded row is far from the stop
        threshold, tightening to ``base`` near certification. Both drivers
        implement the identical controller (the loop driver on host, the
        block driver inside the scan carry), so histories still match.
      active_schedule: optional (round, rng) -> (K,) bool mask simulating node
        churn (Fig. 4/6), or a pre-materialized (T, K) bool array (the
        array form consumes no draws from the shared schedule rng). W is
        re-normalized over the active subgraph each round via Metropolis
        weights.
      budget_schedule: optional (round, rng) -> (K,) int CD-step budgets —
        heterogeneous per-node solver quality Theta_k (Definition 5):
        stragglers do fewer coordinate updates this round. Also accepts a
        pre-materialized (T, K) int array.
      leave_mode: "freeze" (paper's main model: x_[k] frozen) or "reset"
        (App. D Fig. 6: x_[k] zeroed and all v_j adjusted to preserve the
        Lemma-1 mean invariant).
      w_override: use this mixing matrix instead of Metropolis weights
        (e.g. ``cocoa_mixing(K)`` for the centralized special case).
      attacks: optional ``repro.attack`` scenario (or list of scenarios) —
        Byzantine payloads, free riders, link corruption, eavesdropper
        taps — applied as transforms over the pre-materialized schedule
        (block executor only). Composes with churn/budget schedules, which
        materialize first. Defenses are orthogonal: set ``cfg.robust``.
        An ``Eavesdropper`` fills ``RunResult.taps``.
      executor: "block" (default) runs ``block_size`` rounds per device
        dispatch via the round-block engine; "loop" is the retained
        one-dispatch-per-round reference path. Both consume the schedule
        rngs identically and produce bitwise-identical states.
      block_size: rounds per dispatch for the block executor.
    """
    k = graph.num_nodes
    _check_wire_config(cfg, attacks=attacks, leave_mode=leave_mode)
    part = make_partition(problem.n, k)
    sample = cfg.participation
    if sample is not None:
        if not isinstance(sample, schedule_lib.SampleConfig):
            raise TypeError(
                f"cfg.participation must be a repro.core.schedule."
                f"SampleConfig, got {type(sample).__name__}")
        if active_schedule is not None:
            raise ValueError(
                "participation= and active_schedule= both set: client "
                "sampling IS an active schedule — pass one or the other")
        if executor != "block":
            raise ValueError(
                "cfg.participation requires executor='block' — the sampled "
                "schedule streams through the round-block scan")
        schedule_lib.require_complete(graph)
        if sample.resolve_mode(k) == "cohort":
            return _run_cola_cohort(
                problem, graph, cfg, rounds, part=part,
                record_every=record_every, recorder=recorder, eps=eps,
                budget_schedule=budget_schedule, leave_mode=leave_mode,
                seed=seed, w_override=w_override, attacks=attacks,
                block_size=block_size)
    # honor cfg.cd_mode: forced "gram" must materialize the blocks even when
    # the heuristic declines, forced "residual" must not pay for them
    env = build_env(problem, part,
                    with_gram=cfg.use_gram(problem.d, part.block,
                                           problem.a.dtype.itemsize))
    state = init_state(problem, part)
    base_w = w_override if w_override is not None else topo.metropolis_weights(graph)
    rec = metrics_lib.make_recorder(recorder, problem, part, env, graph,
                                    base_w, eps)
    active_schedule = _as_schedule_fn(active_schedule, rounds, k,
                                      "active_schedule")
    budget_schedule = _as_schedule_fn(budget_schedule, rounds, k,
                                      "budget_schedule")
    if active_schedule is not None or sample is not None:
        # churn (and client sampling, which is streamed churn): certificates
        # must judge each record round against the REWEIGHTED exchange
        # (mask + beta of the active subnetwork), not the static graph
        # baked at init
        rec = metrics_lib.dynamize(rec)
    args = (problem, part, env, state, graph, cfg, rounds, record_every,
            rec, active_schedule, budget_schedule, leave_mode, seed, base_w)
    if executor == "block":
        return _run_cola_block(*args, attacks=attacks, block_size=block_size)
    if executor == "loop":
        if attacks is not None:
            raise ValueError(
                "attacks= requires executor='block' — attack scenarios are "
                "schedule transforms over the pre-materialized (T, ...) "
                "schedules the loop driver does not build")
        if cfg.telemetry:
            raise ValueError(
                "cfg.telemetry requires executor='block' — the obs "
                "counters ride the round-block scan carry")
        return _run_cola_loop(*args)
    raise ValueError(f"unknown executor {executor!r} (want 'block' or 'loop')")


def _check_wire_config(cfg: ColaConfig, *, attacks=None,
                       leave_mode: str = "freeze", dist: bool = False) -> None:
    """Reject config corners the quantized wire deliberately does not
    support yet (scope control: each would silently change what crosses
    the wire, so failing loudly beats a wrong byte budget)."""
    if not quant.is_quantized(cfg.wire):
        if cfg.pipeline:
            raise ValueError(
                "cfg.pipeline requires a quantized wire — the fp32 payload "
                "has no encode step to hoist (set wire='int8'/'fp8')")
        return
    composed = attacks is not None or cfg.robust is not None
    if dist and attacks is not None:
        raise NotImplementedError(
            "attacks= with a quantized wire on the distributed runtime: "
            "the shard_map qmix lowerings have no attacked-encode path yet "
            "(the simulator supports this composition)")
    if composed and cfg.pipeline:
        raise NotImplementedError(
            "cfg.pipeline with attacks=/cfg.robust on a quantized wire: "
            "the double-buffered payload is encoded a round early, before "
            "the attack transform / gate decision for its round exists")
    if composed and cfg.gossip_steps != 1:
        raise NotImplementedError(
            "attacks=/cfg.robust on a quantized wire require "
            "gossip_steps=1: steps 2..B would have to re-encode mixed "
            "values, which the composed path does not model yet")
    if cfg.grad_mode == "mixed":
        raise NotImplementedError(
            "grad_mode='mixed' with a quantized wire: the gradient exchange "
            "would cross in fp32 and break the declared byte budget")
    if cfg.pipeline and leave_mode == "reset":
        raise NotImplementedError(
            "cfg.pipeline with leave_mode='reset': the pre-encoded payload "
            "in flight would be stale after the leaver reset")


def _arm_wire_state(state: ColaState, cfg: ColaConfig, key0) -> ColaState:
    """Attach the quantized-wire carry to a fresh state: the EF residual
    (zeros) and, when pipelining, round 0's pre-encoded payload."""
    if not quant.is_quantized(cfg.wire):
        return state
    ef = quant.ef_init(state.v_stack, cfg.wire) if cfg.error_feedback else None
    buf = None
    if cfg.pipeline:
        q, s, _, ef = quant.encode(state.v_stack, cfg.wire,
                                   quant.step_key(jnp.asarray(key0), 0),
                                   None, ef)
        buf = (q, s)
    return state._replace(ef=ef, buf=buf)


def _run_cola_loop(problem, part, env, state, graph, cfg, rounds, record_every,
                   recorder, active_schedule, budget_schedule, leave_mode,
                   seed, base_w) -> RunResult:
    """Reference driver: one jitted dispatch per round, blocking metric sync
    every ``record_every`` rounds (the seed behaviour, kept for equivalence
    tests and as the benchmark baseline). Consumes the same Recorder as the
    block engine: one jitted row per record round, host-side stop check."""
    k = part.num_nodes
    # content-addressed: a rebuilt identical Problem reuses the driver, a
    # same-address different-content Problem misses (see executor.fingerprint)
    prob_fp = exec_engine.fingerprint(problem)
    one_round = exec_engine.cached_driver(
        ("cola-round", prob_fp, part, cfg),
        lambda: make_round(problem, part, cfg))
    rng = np.random.default_rng(seed)
    qkeys = None
    if quant.is_quantized(cfg.wire):
        # one extra row: the pipelined body encodes round t+1's payload
        qkeys = jnp.asarray(quant.round_keys(seed, rounds + 1))
        state = _arm_wire_state(state, cfg, qkeys[0])

    dtype = problem.a.dtype
    w = jnp.asarray(base_w, dtype=dtype)
    all_active = np.ones((k,), dtype=bool)
    history: dict = {"round": []}
    history.update({name: [] for name in recorder.labels})
    history["stop_round"] = None

    uses_sched = bool(getattr(recorder, "uses_schedule", False))
    cert = metrics_lib.first_certificate(recorder) if uses_sched else None
    report = exec_engine.cached_driver(
        ("cola-report", prob_fp, part, recorder.cache_token()),
        lambda: jax.jit(recorder.record_fn))
    stop_fn = recorder.stop_fn

    # host twin of the executor's on-device AdaptiveCadence controller:
    # identical integer cadence arithmetic and f32 ratio compare, so loop
    # and block drivers record the same rounds
    cad = metrics_lib.as_cadence(record_every)
    next_rec, every = 0, (cad.base if cad else None)

    prev_active = all_active
    for t in range(rounds):
        if active_schedule is not None:
            active = np.asarray(active_schedule(t, rng), dtype=bool)
            if not active.any():
                active = all_active.copy()  # never let the whole network die
            w_t = jnp.asarray(topo.reweight_for_active(graph, active), dtype=dtype)
            if leave_mode == "reset":
                leavers = prev_active & ~active
                if leavers.any():
                    state = _reset_leavers(state, env, part, leavers)
            prev_active = active
        else:
            active, w_t = all_active, w
        budgets = None
        if budget_schedule is not None:
            budgets = jnp.asarray(budget_schedule(t, rng), dtype=jnp.int32)
        if qkeys is None:
            state = one_round(state, env, w_t,
                              jnp.asarray(active, dtype=dtype), budgets)
        else:
            state = one_round(state, env, w_t,
                              jnp.asarray(active, dtype=dtype), budgets,
                              None, qkeys[t], qkeys[t + 1])
        due = (t >= next_rec) if cad else (t % record_every == 0)
        if due or t == rounds - 1:
            if uses_sched:
                mask_t, thr_t = metrics_lib.certificate_round_inputs(
                    cert, w_t, active)
                row = report(state, {
                    "cert_mask": jnp.asarray(mask_t, dtype),
                    "cert_grad_thresh": jnp.asarray(thr_t, dtype)})
            else:
                row = report(state)
            history["round"].append(t)
            for j, name in enumerate(recorder.labels):
                history[name].append(float(row[j]))
            if cad:
                far = (np.float32(recorder.cadence_ratio(row))
                       > np.float32(cad.near))
                every = (min(every * cad.grow, cad.max_every) if far
                         else cad.base)
                next_rec = t + every
            if stop_fn is not None and bool(stop_fn(row)):
                history["stop_round"] = t
                break
    return RunResult(state=state,
                     history=metrics_lib.annotate_violation(history))


def _as_schedule_fn(s, rounds: int, k: int, name: str):
    """Normalize a schedule argument: pass callables (and None) through,
    wrap a pre-materialized (T, K) array as a per-round lookup. The wrapper
    ignores the shared schedule rng — callers mixing array and callable
    schedules must account for the draws the array form no longer takes."""
    if s is None or callable(s):
        return s
    arr = np.asarray(s)
    if arr.shape != (rounds, k):
        raise ValueError(f"pre-materialized {name} must be ({rounds}, {k}),"
                         f" got {arr.shape}")
    return lambda t, rng: arr[t]


def _materialize_schedule(graph, rounds, active_schedule, budget_schedule,
                          leave_mode, seed, base_w, dtype) -> dict:
    """Evaluate the host-side schedule callables for all T rounds up front,
    into stacked (T, ...) arrays the scan executor can slice per block.

    The rng is consumed in the same per-round order as the loop driver
    (active draw, then budget draw), so both drivers see identical schedules
    for the same seed.
    """
    k = graph.num_nodes
    has_churn = active_schedule is not None
    has_budget = budget_schedule is not None
    has_reset = has_churn and leave_mode == "reset"
    rng = np.random.default_rng(seed)

    if has_churn:
        w_stack = np.empty((rounds, k, k), dtype=dtype)
        actives = np.empty((rounds, k), dtype=dtype)
    else:
        # no churn: every round shares base_w; broadcast views keep the
        # schedule O(K^2) in host memory, copied blockwise at dispatch
        w_stack = np.broadcast_to(np.asarray(base_w, dtype=dtype),
                                  (rounds, k, k))
        actives = np.broadcast_to(np.ones((k,), dtype=dtype), (rounds, k))
    budgets = np.empty((rounds, k), np.int32) if has_budget else None
    leavers = np.zeros((rounds, k), bool) if has_reset else None
    reset_any = np.zeros((rounds,), bool) if has_reset else None

    prev_active = np.ones((k,), dtype=bool)
    if has_churn or has_budget:
        for t in range(rounds):
            if has_churn:
                active = np.asarray(active_schedule(t, rng), dtype=bool)
                if not active.any():
                    active = np.ones((k,), dtype=bool)
                w_stack[t] = topo.reweight_for_active(graph, active)
                actives[t] = active.astype(dtype)
                if has_reset:
                    left = prev_active & ~active
                    leavers[t] = left
                    reset_any[t] = left.any()
                prev_active = active
            if has_budget:
                budgets[t] = np.asarray(budget_schedule(t, rng),
                                        dtype=np.int32)

    sched = {"w": w_stack, "active": actives}
    if has_budget:
        sched["budgets"] = budgets
    if has_reset:
        sched["leavers"] = leavers
        sched["reset_any"] = reset_any
    return sched


def _run_cola_block(problem, part, env, state, graph, cfg, rounds,
                    record_every, recorder, active_schedule, budget_schedule,
                    leave_mode, seed, base_w, *, attacks=None,
                    block_size) -> RunResult:
    """Round-block driver: ``block_size`` rounds per dispatch (see
    ``repro.core.executor``), the Recorder's row computed on device inside
    the scan, certificate-driven early exit handled by the engine."""
    dtype = problem.a.dtype
    sample = cfg.participation
    sched = _materialize_schedule(graph, rounds, active_schedule,
                                  budget_schedule, leave_mode, seed, base_w,
                                  dtype)
    atk_info = None
    atk_part = None
    if attacks is not None:
        from repro import attack as attack_lib
        ctx = attack_lib.AttackContext(graph=graph, rounds=rounds,
                                       k=part.num_nodes, d=problem.d,
                                       dtype=dtype, seed=seed)
        if sample is not None:
            # a participation run streams its schedule, so the attacks must
            # be generative too: one composed jax part rides the same
            # stream (W-rewriting / recording scenarios raise here)
            atk_part, atk_info = attack_lib.streamed_attacks(attacks, ctx)
        else:
            # attacks transform the schedule AFTER churn/budgets materialize
            # and BEFORE the certificate schedule derives from it —
            # certificates judge the corrupted exchange, exactly what ran
            sched, atk_info = attack_lib.apply_attacks(sched, attacks, ctx)
        if "dishonest" in atk_info.entry_names:
            # payload-corrupting attacks: the certificate audits the honest
            # cohort against the ground-truth dishonesty mask the schedule
            # transform recorded (see metrics.attackify)
            recorder = metrics_lib.attackify(recorder)
    atk_names = atk_info.entry_names if atk_info else ()
    tap_nodes = atk_info.tap_nodes if atk_info else ()
    tap_idx = jnp.asarray(tap_nodes, jnp.int32) if tap_nodes else None
    stream = None
    if sample is not None:
        s_cert = metrics_lib.first_certificate(recorder)
        parts = schedule_lib.participation_parts(
            part.num_nodes, sample, dtype=dtype, run_seed=seed,
            cert=s_cert if (s_cert is not None and s_cert.dynamic) else None,
            leave_reset=(leave_mode == "reset"))
        if atk_part is not None:
            parts = parts + (atk_part,)
        prog = schedule_lib.ScheduleProgram(parts=parts)
        if sample.stream:
            # the no-churn broadcast w/active legs give way to the streamed
            # generator entries, merged inside the scan body each round
            del sched["w"], sched["active"]
            stream = prog.stream_fn()
        else:
            # escape hatch for the bitwise pins: the SAME jax generator,
            # evaluated host-side into classical stacked schedules
            sched.update(prog.materialize(rounds))
    has_budget = "budgets" in sched
    has_reset = ("leavers" in sched
                 or (stream is not None and leave_mode == "reset"))
    quantized = quant.is_quantized(cfg.wire)
    if quantized:
        # per-round codec keys ride the schedule like every other input;
        # the extra row feeds the pipelined body's encode of round t+1
        keys = np.asarray(quant.round_keys(seed, rounds + 1))
        sched["qkey"] = keys[:rounds]
        if cfg.pipeline:
            sched["qkey_next"] = keys[1:]
        state = _arm_wire_state(state, cfg, keys[0])
    obs_upd = obs_inc = None
    if cfg.telemetry:
        from repro.obs import counters as obs_counters
        obs_inc = obs_counters.round_increments(graph, problem.d, cfg,
                                                dtype.itemsize)
        obs_upd = obs_counters.make_update(cfg, part.num_nodes, obs_inc)
        state = state._replace(
            counters=obs_counters.init_counters(part.num_nodes))
    body = _round_body(problem, part, cfg)

    def step_fn(st, env_ctx, s_t):
        if has_reset:
            # cond matches the loop driver's host-side `leavers.any()` gate,
            # so rounds without leavers execute the identical program
            st = lax.cond(
                s_t["reset_any"],
                lambda ss: _reset_leavers(ss, env_ctx, part, s_t["leavers"]),
                lambda ss: ss, st)
        atk = {n: s_t["atk_" + n] for n in atk_names} or None
        tap = None
        if tap_idx is not None:
            # what the tapped nodes emit THIS round (post-reset state, same
            # wire transform the mix consumes — XLA shares the computation)
            tap = _apply_payload_attack(st.v_stack, atk)[tap_idx]
        st_pre = st
        st = body(st, env_ctx, s_t["w"], s_t["active"],
                  s_t["budgets"] if has_budget else None, atk,
                  s_t["qkey"] if quantized else None,
                  s_t["qkey_next"] if quantized and cfg.pipeline else None)
        if obs_upd is None:
            return st, tap
        # the round body rebuilds the state pytree, so reattach the
        # updated counters — they stay leaves of the scan carry
        cts, obs_row = obs_upd(st_pre, st, s_t, atk, s_t["w"])
        st = st._replace(counters=cts)
        aux = {"obs": obs_row}
        if tap is not None:
            aux["taps"] = tap
        return st, aux

    cad = metrics_lib.as_cadence(record_every)
    rec = (None if cad
           else exec_engine.record_flags(rounds, record_every))
    cert = metrics_lib.first_certificate(recorder)
    if cert is not None and cert.dynamic and sample is None:
        # dynamic certificate: the per-round neighbor mask + threshold ride
        # the schedule like every other per-round input. Under an adaptive
        # cadence any round may record, so materialize every round's entry.
        # (attack-aware recorders also use the schedule, but their entry —
        # atk_dishonest — was materialized by apply_attacks already; a
        # participation run's entries come from its own streamed generator.)
        sched.update(metrics_lib.certificate_schedule(
            recorder, sched["w"], sched["active"],
            np.ones((rounds,), dtype=bool) if cad else rec))
    with contextlib.ExitStack() as stack:
        run_tr = None
        if cfg.telemetry:
            # scope a fresh tracer (+ its cache listener) to this run so the
            # report's span timings cover exactly these block dispatches
            from repro.obs import trace as obs_trace
            run_tr = stack.enter_context(obs_trace.use(obs_trace.Tracer()))
            stack.enter_context(run_tr.attach())
        res = exec_engine.run_round_blocks(
            step_fn, state, sched, context=env, recorder=recorder,
            record_mask=rec, block_size=block_size, cadence=cad,
            num_rounds=rounds, stream=stream,
            cache_key=("cola-block", exec_engine.fingerprint(problem), part,
                       cfg, has_budget, has_reset, recorder.cache_token(),
                       atk_info.token if atk_info else None))
    history = metrics_lib.history_from(recorder, res)
    taps = res.aux if tap_nodes else None
    if cfg.telemetry:
        from repro.obs import counters as obs_counters, report as obs_report
        obs_series = res.aux.get("obs") if isinstance(res.aux, dict) else None
        taps = res.aux.get("taps") if isinstance(res.aux, dict) else None
        history["telemetry"] = obs_counters.summarize(
            res.state.counters, obs_inc, series=obs_series,
            stop_round=res.stop_round,
            dishonest=sched.get("atk_dishonest"))
        obs_report.auto_emit(obs_report.make_report(
            driver="run_cola",
            problem_fp=exec_engine.fingerprint(problem),
            config=dataclasses.asdict(cfg),
            graph={"kind": getattr(graph, "name", type(graph).__name__),
                   "num_nodes": part.num_nodes},
            rounds=(rounds if res.stop_round is None
                    else res.stop_round + 1),
            history=history,
            contract=obs_inc["contract"],
            spans=run_tr.summary() if run_tr is not None else None))
    return RunResult(state=res.state, history=history, taps=taps)


def _run_cola_cohort(problem, graph, cfg, rounds, *, part, record_every,
                     recorder, eps, budget_schedule, leave_mode, seed,
                     w_override, attacks, block_size) -> RunResult:
    """Million-node client-sampling driver: each round only the sampled
    K'-node cohort computes.

    Nothing (K, K)- or (T, K)-shaped exists anywhere. The streamed schedule
    carries the sorted cohort indices (K',) and the active mask (K,); the
    round body gathers the cohort's (K', ...) state/env slices, applies the
    sampled-complete gossip mix in closed form (the induced Metropolis
    matrix over active nodes of a complete graph is the exact uniform
    average — see ``schedule.sampled_complete_weights``), runs the vmapped
    local CD solve on the slices, and scatters the updates back. Frozen
    nodes are untouched, exactly the dense participation semantics, so the
    two modes agree to reduction order at small K.

    The certificate stays sound on the sampled subnetwork via the cohort
    mode of ``metrics.CertificateRecorder`` (beta = 0 closed form over the
    complete induced subgraph; cond9 judged over ALL K nodes — frozen nodes
    must hold their thresholds too, matching the materialized-churn oracle).
    """
    sample = cfg.participation
    k = part.num_nodes
    for flag, what in (
            (attacks is not None, "attacks="),
            (budget_schedule is not None, "budget_schedule="),
            (leave_mode != "freeze", f"leave_mode={leave_mode!r}"),
            (w_override is not None, "w_override="),
            (cfg.telemetry, "cfg.telemetry"),
            (cfg.robust is not None, "cfg.robust"),
            (quant.is_quantized(cfg.wire), f"wire={cfg.wire!r}"),
            (cfg.grad_mode != "local", f"grad_mode={cfg.grad_mode!r}"),
            (cfg.gossip_steps != 1, "gossip_steps != 1"),
    ):
        if flag:
            raise NotImplementedError(
                f"{what} is not supported in cohort participation mode — "
                "the gather/scatter round body implements the bare "
                "Algorithm-1 round over the sampled cohort (dense "
                f"participation mode, K <= {schedule_lib.DENSE_MAX_NODES}, "
                "supports these compositions)")
    env = build_env(problem, part,
                    with_gram=cfg.use_gram(problem.d, part.block,
                                           problem.a.dtype.itemsize))
    state = init_state(problem, part)
    if isinstance(recorder, str):
        # make_recorder wants a dense graph/W for the certificate — the
        # cohort form derives its thresholds without either
        if recorder not in ("gap", "certificate", "gap+certificate"):
            raise ValueError(f"unknown recorder {recorder!r} (want 'gap', "
                             "'certificate', 'gap+certificate' or a "
                             "Recorder instance)")
        recs = []
        if recorder in ("gap", "gap+certificate"):
            recs.append(metrics_lib.GapRecorder(
                problem, part, eps=eps if recorder == "gap" else None))
        if recorder in ("certificate", "gap+certificate"):
            if eps is None:
                raise ValueError(
                    f"recorder={recorder!r} needs eps=: the Prop.-1 "
                    "conditions certify a specific accuracy")
            recs.append(metrics_lib.cohort_certificate_recorder(
                problem, part, env, eps))
        rec = (recs[0] if len(recs) == 1
               else metrics_lib.ComposedRecorder(tuple(recs)))
    else:
        rec = recorder

    dtype = problem.a.dtype
    sigma = cfg.resolved_sigma(k)
    spec = SubproblemSpec(sigma_over_tau=sigma / problem.tau, inv_k=1.0 / k)
    gamma = cfg.gamma
    steps = cfg.coord_steps(part.block)
    use_gram = (env.gram_parts is not None
                and cfg.use_gram(problem.d, part.block,
                                 env.a_parts.dtype.itemsize))
    if cfg.cd_mode == "gram" and env.gram_parts is None:
        raise ValueError(
            "cd_mode='gram' but the env has no Gram blocks — build it "
            "with build_env(problem, part, with_gram=True)")

    def step_fn(st, env_ctx, s_t):
        idx = s_t["cohort_idx"]                      # (K',) sorted
        v_sub = st.v_stack[idx]                      # (K', d)
        a_sub = env_ctx.a_parts[idx]                 # (K', d, n_k)
        # Step 4 over the sampled complete subnetwork: the mix is the exact
        # uniform cohort average (rank-one W), inactive nodes untouched
        v_half = jnp.broadcast_to(jnp.mean(v_sub, axis=0, keepdims=True),
                                  v_sub.shape)
        grads = jax.vmap(problem.grad_f)(v_half)
        dx = cd_solve_all(problem, spec, a_sub, st.x_parts[idx], grads,
                          env_ctx.gp_parts[idx], env_ctx.masks[idx], steps,
                          step_budgets=None,
                          gram_parts=env_ctx.gram_parts[idx] if use_gram
                          else None)
        # Steps 6-8 scattered back: frozen nodes keep x and v verbatim
        dv = jnp.einsum("kdn,kn->kd", a_sub, dx)
        x_new = st.x_parts.at[idx].add(gamma * dx)
        v_new = st.v_stack.at[idx].set(v_half + gamma * k * dv)
        return ColaState(x_parts=x_new, v_stack=v_new), None

    prog = schedule_lib.ScheduleProgram(
        parts=schedule_lib.cohort_parts(k, sample, dtype=dtype,
                                        run_seed=seed))
    if sample.stream:
        sched, stream = {}, prog.stream_fn()
    else:
        sched, stream = prog.materialize(rounds), None
    cad = metrics_lib.as_cadence(record_every)
    rec_mask = (None if cad
                else exec_engine.record_flags(rounds, record_every))
    res = exec_engine.run_round_blocks(
        step_fn, state, sched, context=env, recorder=rec,
        record_mask=rec_mask, block_size=block_size, cadence=cad,
        num_rounds=rounds, stream=stream,
        cache_key=("cola-cohort", exec_engine.fingerprint(problem), part,
                   cfg, rec.cache_token()))
    return RunResult(state=res.state,
                     history=metrics_lib.history_from(rec, res))


def _reset_leavers(state: ColaState, env: ColaEnv, part: Partition,
                   leavers: np.ndarray,
                   total_fn: Callable | None = None) -> ColaState:
    """Fig.-6 model: zero x_[k] of leaving nodes; every node subtracts
    A_[k] x_[k] from its local estimate so (1/K) sum v_k = A x still holds.

    ``total_fn(contrib) -> (d,)`` reduces the per-node contributions over
    ALL K nodes; the default sums the stacked axis, the shard_map runtime
    passes a psum-augmented reduction so the one invariant implementation
    serves both drivers.
    """
    leave = jnp.asarray(leavers)
    contrib = jnp.einsum("kdn,kn->kd", env.a_parts,
                         state.x_parts * leave[:, None])  # (K, d)
    if total_fn is None:
        total_fn = lambda c: jnp.sum(c, axis=0)           # A_[k] x_[k] summed
    total = total_fn(contrib)
    x_new = jnp.where(leave[:, None], 0.0, state.x_parts)
    v_new = state.v_stack - total[None, :]
    # a leaver's codec residual describes payload history that no longer
    # exists — zero it with the rest of its local state (pipeline + reset
    # is rejected up front, so state.buf is always None here)
    ef_new = (None if state.ef is None
              else jnp.where(leave[:, None], 0.0, state.ef))
    return ColaState(x_parts=x_new, v_stack=v_new, ef=ef_new, buf=state.buf,
                     counters=state.counters)


def solve_reference(problem: Problem, rounds: int = 3000,
                    kappa: int = 10) -> float:
    """High-accuracy reference optimum via single-node CoCoA (used as F* when
    reporting suboptimality, mirroring the paper's methodology in App. D)."""
    graph = topo.complete(2)
    cfg = ColaConfig(kappa=kappa)
    res = run_cola(problem, graph, cfg, rounds, record_every=max(rounds // 4, 1),
                   w_override=cocoa_mixing(2))
    return min(res.history["primal"])
