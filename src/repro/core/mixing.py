"""Gossip mixing operators: v_k <- sum_l W_kl v_l  (Algorithm 1, step 4).

Three executable paths with identical semantics (validated against each
other in tests):

* ``dense_mix`` — a (K, K) x (K, d) matmul on stacked node state. Used by the
  single-host simulator (vmapped over nodes) and as the oracle for arbitrary
  graphs.
* ``ring_mix_ppermute`` — a shard_map body using ``lax.ppermute`` neighbor
  exchanges for banded (c-connected-cycle / ring) mixing matrices. This is the
  TPU-native adaptation: each gossip round costs only deg(k) * |v| bytes per
  ICI link instead of a full all-reduce, which is exactly the paper's
  communication-efficiency argument transcribed to pod hardware. Retained as
  the circulant special case (and for bitwise compatibility of historical
  ring runs).
* the **topology-program path** (``repro.topo``) — the general form:
  ``compile_plan`` edge-colors ANY sparse W's support into matchings, each
  lowered to one ``ppermute`` (``repro.topo.lowering.plan_mix_step``), with
  per-round weight coefficients riding the executor schedule. This is what
  ``repro.dist.runtime`` executes for non-circulant and churn-reweighted
  (time-varying) graphs; ``check_circulant_band`` below is the ring path's
  validity gate, ``repro.topo.check_plan_covers`` its generalization.

``mix_power`` applies B gossip steps (time-varying-graph extension, App. E.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with a fallback to the pre-0.5 experimental API.

    The installed jax (0.4.x) only ships ``jax.experimental.shard_map``;
    newer releases promote it to ``jax.shard_map``. Every shard_map user in
    this repo goes through this shim so the mesh paths work on both.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def dense_mix(w: jax.Array, v_stack: jax.Array) -> jax.Array:
    """v'_k = sum_l W_kl v_l for stacked node state.

    Args:
      w: (K, K) mixing matrix.
      v_stack: (K, ...) per-node state stacked on axis 0.
    """
    flat = v_stack.reshape(v_stack.shape[0], -1)
    out = w.astype(flat.dtype) @ flat
    return out.reshape(v_stack.shape)


def mix_power(w: jax.Array, v_stack: jax.Array, steps: int) -> jax.Array:
    """Apply B consecutive gossip steps (App. E.2 time-varying extension).

    For B >= 2 the B-step mix (W^B) v is computed by folding W first:
    B-1 (K, K) matmuls + one (K, d) mix — O(B K^3 + K^2 d) instead of the
    sequential O(B K^2 d), a win whenever the node state is larger than the
    node count (d > K, the only regime the paper cares about). B is a static
    Python int, so the fold unrolls at trace time.
    """
    if steps <= 0:
        return v_stack
    if steps == 1:
        return dense_mix(w, v_stack)
    w_pow = w
    for _ in range(steps - 1):
        w_pow = w @ w_pow
    return dense_mix(w_pow, v_stack)


def banded_weights(w: jax.Array, conn: int) -> jax.Array:
    """Extract (2*conn+1,) banded weights [w_-c..w_0..w_+c] from a circulant W.

    ASSUMES W is circulant-banded (ring or c-connected cycle with uniform
    Metropolis weights); ``w`` is usually traced here, so no mass check is
    possible — callers with a concrete W validate via
    ``check_circulant_band`` before entering jit.
    """
    k = w.shape[0]
    offs = jnp.arange(-conn, conn + 1)
    rows = jnp.arange(k)
    cols = (rows[None, :] + offs[:, None]) % k
    band = w[rows[None, :], cols]  # (2c+1, K)
    return band[:, 0]


def check_circulant_band(w, conn: int, atol: float = 1e-6) -> None:
    """Raise ValueError unless the CONCRETE matrix ``w`` is circulant with
    bandwidth <= ``conn`` — i.e. the banded ppermute mixing reproduces the
    full W matmul exactly (no weight mass outside the band, no row
    variation the band extraction would silently drop)."""
    import numpy as np

    w = np.asarray(w)
    k = w.shape[0]
    band = np.asarray(banded_weights(jnp.asarray(w), conn))
    rows, offs = np.arange(k), np.arange(-conn, conn + 1)
    rebuilt = np.zeros_like(w)
    rebuilt[rows[None, :], (rows[None, :] + offs[:, None]) % k] = \
        band[:, None]
    if not np.allclose(w, rebuilt, atol=atol):
        raise ValueError(
            f"W is not circulant-banded with connectivity {conn}: banded "
            f"ppermute mixing would drop {np.abs(w - rebuilt).max():.3g} of "
            "weight mass — use the dense mixing path for this graph")


def ring_mix_ppermute(v_local: jax.Array, axis_name: str, weights: jax.Array,
                      conn: int = 1) -> jax.Array:
    """Gossip step inside shard_map: banded circulant mixing via ppermute.

    Args:
      v_local: this node's state (any shape); the node index is the position
        along ``axis_name``.
      axis_name: mesh axis carrying the K nodes.
      weights: (2*conn+1,) band [w_{-conn}, ..., w_0, ..., w_{+conn}].
      conn: connectivity (1 = ring, 2 = 2-connected cycle, ...).
    """
    # lax.axis_size only exists on newer jax; psum of 1 is the portable spelling
    k = (lax.axis_size(axis_name) if hasattr(lax, "axis_size")
         else lax.psum(1, axis_name))
    out = weights[conn] * v_local
    for off in range(1, conn + 1):
        # receive from left neighbor at distance `off`
        perm_l = [((i + off) % k, i) for i in range(k)]
        from_right = lax.ppermute(v_local, axis_name, [(i, (i + off) % k) for i in range(k)])
        from_left = lax.ppermute(v_local, axis_name, perm_l)
        out = out + weights[conn + off] * from_left + weights[conn - off] * from_right
    return out


def dense_mix_shardmap(v_local: jax.Array, axis_name: str, w: jax.Array) -> jax.Array:
    """Gossip step inside shard_map for arbitrary W: all-gather + weighted sum.

    Fallback for non-circulant graphs; costs an all-gather of v (K*|v| bytes).
    """
    idx = lax.axis_index(axis_name)
    v_all = lax.all_gather(v_local, axis_name)  # (K, ...)
    return dense_mix(w, v_all)[idx]
