"""Gossip mixing operators: v_k <- sum_l W_kl v_l  (Algorithm 1, step 4).

Three executable paths with identical semantics (validated against each
other in tests):

* ``dense_mix`` — a (K, K) x (K, d) matmul on stacked node state. Used by the
  single-host simulator (vmapped over nodes) and as the oracle for arbitrary
  graphs.
* ``ring_mix_ppermute`` — a shard_map body using ``lax.ppermute`` neighbor
  exchanges for banded (c-connected-cycle / ring) mixing matrices. This is the
  TPU-native adaptation: each gossip round costs only deg(k) * |v| bytes per
  ICI link instead of a full all-reduce, which is exactly the paper's
  communication-efficiency argument transcribed to pod hardware. Retained as
  the circulant special case (and for bitwise compatibility of historical
  ring runs).
* the **topology-program path** (``repro.topo``) — the general form:
  ``compile_plan`` edge-colors ANY sparse W's support into matchings, each
  lowered to one ``ppermute`` (``repro.topo.lowering.plan_mix_step``), with
  per-round weight coefficients riding the executor schedule. This is what
  ``repro.dist.runtime`` executes for non-circulant and churn-reweighted
  (time-varying) graphs; ``check_circulant_band`` below is the ring path's
  validity gate, ``repro.topo.check_plan_covers`` its generalization.

``mix_power`` applies B gossip steps (time-varying-graph extension, App. E.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with a fallback to the pre-0.5 experimental API.

    The installed jax (0.4.x) only ships ``jax.experimental.shard_map``;
    newer releases promote it to ``jax.shard_map``. Every shard_map user in
    this repo goes through this shim so the mesh paths work on both.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def dense_mix(w: jax.Array, v_stack: jax.Array) -> jax.Array:
    """v'_k = sum_l W_kl v_l for stacked node state.

    Args:
      w: (K, K) mixing matrix.
      v_stack: (K, ...) per-node state stacked on axis 0.
    """
    flat = v_stack.reshape(v_stack.shape[0], -1)
    out = w.astype(flat.dtype) @ flat
    return out.reshape(v_stack.shape)


def mix_power(w: jax.Array, v_stack: jax.Array, steps: int) -> jax.Array:
    """Apply B consecutive gossip steps (App. E.2 time-varying extension).

    For B >= 2 the B-step mix (W^B) v is computed by folding W first:
    B-1 (K, K) matmuls + one (K, d) mix — O(B K^3 + K^2 d) instead of the
    sequential O(B K^2 d), a win whenever the node state is larger than the
    node count (d > K, the only regime the paper cares about). B is a static
    Python int, so the fold unrolls at trace time.
    """
    if steps <= 0:
        return v_stack
    if steps == 1:
        return dense_mix(w, v_stack)
    w_pow = w
    for _ in range(steps - 1):
        w_pow = w @ w_pow
    return dense_mix(w_pow, v_stack)


def mix_power_wire(w: jax.Array, v_send: jax.Array,
                   v_self: jax.Array | None, steps: int) -> jax.Array:
    """B gossip steps where the FIRST step mixes on-the-wire payloads.

    ``v_send`` is what each node emitted (possibly a Byzantine lie — see
    ``repro.attack``); ``v_self`` is the stacked honest state, or None when
    nothing was corrupted (the fast path is then exactly ``mix_power``).
    A lie only exists on the wire: each receiving node's OWN contribution
    W_kk v_k uses its honest state, so the first step is
    ``W v_send + diag(W) (v_self - v_send)``; the remaining B-1 steps mix
    the already-received values honestly."""
    if v_self is None or steps <= 0:
        return mix_power(w, v_send, steps)
    first = dense_mix(w, v_send)
    diag = jnp.diagonal(w).astype(first.dtype)
    first = first + diag[:, None] * (v_self - v_send)
    return mix_power(w, first, steps - 1)


def qmix_steps(w: jax.Array, v_stack: jax.Array, ef, steps: int, wire: str,
               round_key, node_ids=None, payload=None):
    """B gossip steps over a QUANTIZED wire (simulator / dense oracle).

    Every step, each node encodes its current value once (EF-compensated
    when ``ef`` is not None, stochastic rounding keyed per
    (round, step, node) — ``quant.wire_view``) and the whole mix runs on
    the dequantized stack: ``W @ deq``.  All contributions — including the
    node's own diagonal term — go through the codec, so the function is
    independent of how rows are later sharded; the plan and block
    lowerings (``repro.topo.lowering.plan_qmix_steps`` /
    ``block_qmix_steps``) reproduce it to the same tolerance contracts as
    their fp32 counterparts (allclose / bitwise).

    ``payload``: optional pre-encoded ``(q, scale)`` for the first step
    (the pipelined executor's double buffer).  Returns ``(mixed, ef_new)``.
    """
    from repro.core import quant

    out = v_stack
    for s in range(steps):
        if s == 0 and payload is not None:
            deq = quant.dequantize(*payload)
        else:
            k = None if round_key is None else quant.step_key(round_key, s)
            p = out if ef is None else out + ef
            q, sc = quant.quantize_rows(p, wire, k, node_ids=node_ids)
            deq = quant.dequantize(q, sc)
            if ef is not None:
                ef = p - deq
        out = dense_mix(w, deq)
    return out, ef


def banded_weights(w: jax.Array, conn: int) -> jax.Array:
    """Extract (2*conn+1,) banded weights [w_-c..w_0..w_+c] from a circulant W.

    ASSUMES W is circulant-banded (ring or c-connected cycle with uniform
    Metropolis weights); ``w`` is usually traced here, so no mass check is
    possible — callers with a concrete W validate via
    ``check_circulant_band`` before entering jit.
    """
    k = w.shape[0]
    offs = jnp.arange(-conn, conn + 1)
    rows = jnp.arange(k)
    cols = (rows[None, :] + offs[:, None]) % k
    band = w[rows[None, :], cols]  # (2c+1, K)
    return band[:, 0]


def check_circulant_band(w, conn: int, atol: float = 1e-6) -> None:
    """Raise ValueError unless the CONCRETE matrix ``w`` is circulant with
    bandwidth <= ``conn`` — i.e. the banded ppermute mixing reproduces the
    full W matmul exactly (no weight mass outside the band, no row
    variation the band extraction would silently drop)."""
    import numpy as np

    w = np.asarray(w)
    k = w.shape[0]
    band = np.asarray(banded_weights(jnp.asarray(w), conn))
    rows, offs = np.arange(k), np.arange(-conn, conn + 1)
    rebuilt = np.zeros_like(w)
    rebuilt[rows[None, :], (rows[None, :] + offs[:, None]) % k] = \
        band[:, None]
    if not np.allclose(w, rebuilt, atol=atol):
        raise ValueError(
            f"W is not circulant-banded with connectivity {conn}: banded "
            f"ppermute mixing would drop {np.abs(w - rebuilt).max():.3g} of "
            "weight mass — use the dense mixing path for this graph")


def ring_mix_ppermute(v_local: jax.Array, axis_name: str, weights: jax.Array,
                      conn: int = 1) -> jax.Array:
    """Gossip step inside shard_map: banded circulant mixing via ppermute.

    Args:
      v_local: this node's state (any shape); the node index is the position
        along ``axis_name``.
      axis_name: mesh axis carrying the K nodes.
      weights: (2*conn+1,) band [w_{-conn}, ..., w_0, ..., w_{+conn}].
      conn: connectivity (1 = ring, 2 = 2-connected cycle, ...).
    """
    # lax.axis_size only exists on newer jax; psum of 1 is the portable spelling
    k = (lax.axis_size(axis_name) if hasattr(lax, "axis_size")
         else lax.psum(1, axis_name))
    out = weights[conn] * v_local
    for off in range(1, conn + 1):
        # receive from left neighbor at distance `off`
        perm_l = [((i + off) % k, i) for i in range(k)]
        from_right = lax.ppermute(v_local, axis_name, [(i, (i + off) % k) for i in range(k)])
        from_left = lax.ppermute(v_local, axis_name, perm_l)
        out = out + weights[conn + off] * from_left + weights[conn - off] * from_right
    return out


def dense_mix_shardmap(v_local: jax.Array, axis_name: str, w: jax.Array) -> jax.Array:
    """Gossip step inside shard_map for arbitrary W: all-gather + weighted sum.

    Fallback for non-circulant graphs; costs an all-gather of v (K*|v| bytes).
    """
    idx = lax.axis_index(axis_name)
    v_all = lax.all_gather(v_local, axis_name)  # (K, ...)
    return dense_mix(w, v_all)[idx]


# ---------------------------------------------------------------------------
# robust (Byzantine-resilient) aggregation
# ---------------------------------------------------------------------------

ROBUST_MODES = ("trim", "median", "clip")

# adaptive clip radius = factor x median neighbor deviation norm: > 1 so the
# honest spread passes unclipped (see robust_neighborhood_mix docstring)
_CLIP_TAU_FACTOR = 3.0

# outlier gates for trim/median: a neighbor is distrusted when its payload
# is anti-correlated with the neighborhood's coordinate-median center
# (cosine below _TRIM_COS_GATE — honest estimates of the same dual point
# stay positively correlated once mixing starts, dipping just below 0 only
# on the heterogeneous first rounds, while a sign-flipped payload reads
# ~-0.7 against a healthy center) or when its norm exceeds
# _TRIM_NORM_GATE x the LARGEST other neighbor norm (inflation attacks;
# the leave-one-out max — unlike a median — survives the near-zero payload
# norms lasso-type problems emit while most blocks are still inactive).
# Honest neighbors trip neither, so a clean defended run is the linear mix
# bit-for-bit and the Lemma-1 invariant the certificate audits holds to
# float precision.
_TRIM_COS_GATE = -0.2
_TRIM_NORM_GATE = 3.0
# the norm gate only ARMS when the center is informative (nonzero) and the
# payload is not positively aligned with it: early-round honest spikes are
# 3-11x their neighbors in norm (heterogeneous data blocks activate at
# different times) but always correlate positively with a nonzero center,
# while an inflation lie big enough to matter cannot afford to point along
# the consensus estimate (aligned inflation is bounded-influence: it only
# accelerates the direction the cohort already agreed on)
_TRIM_NORM_ARM_COS = 0.2


def _masked_trimmed_mean(vals, mask, b_counts, counts):
    """Coordinate-wise trimmed mean over the masked slots of ``vals``.

    vals (R, K, d): candidate values; slots with mask == False are ignored.
    b_counts (R,): how many extremes to drop from EACH side per row.
    counts (R,): masked slot count per row. Masked-out slots are replaced by
    the dtype's max sentinel so every row's sort places them past the kept
    window — the result depends only on masked values, which is what makes
    the simulator (true values everywhere) and the block lowering (zeros at
    never-exchanged slots) produce bitwise-identical rows.
    """
    big = jnp.asarray(jnp.finfo(vals.dtype).max, vals.dtype)
    guarded = jnp.where(mask[:, :, None], vals, big)
    srt = jnp.sort(guarded, axis=1)
    idx = jnp.arange(vals.shape[1])[None, :, None]
    lo = b_counts[:, None, None]
    hi = (counts - b_counts)[:, None, None]
    keep = (idx >= lo) & (idx < hi)
    kept = jnp.sum(jnp.where(keep, srt, 0.0), axis=1)
    denom = jnp.maximum(counts - 2 * b_counts, 1).astype(vals.dtype)
    return kept / denom[:, None]


def _neighborhood_setup(w_rows, buf, row_ids, self_override):
    """Shared masking/value setup of the robust aggregation and its gate.

    Returns ``(flat, w_rows, self_hot, mask, counts, self_vals, vals)`` —
    exactly the quantities ``robust_neighborhood_mix`` computes before
    branching on the mode, factored out so :func:`gate_flags` sees the SAME
    operations (XLA CSEs the two when both are traced into one program,
    which is what makes the telemetry gate counter free on defended runs).
    """
    k = buf.shape[0]
    flat = buf.reshape(k, -1)
    w_rows = jnp.asarray(w_rows, dtype=flat.dtype)
    row_ids = jnp.asarray(row_ids)
    r = row_ids.shape[0]
    self_hot = jnp.arange(k)[None, :] == row_ids[:, None]        # (R, K)
    mask = (w_rows != 0) | self_hot
    counts = jnp.sum(mask.astype(jnp.int32), axis=1)             # (R,)

    self_vals = (flat[row_ids] if self_override is None
                 else self_override.reshape(r, -1).astype(flat.dtype))
    vals = jnp.broadcast_to(flat[None, :, :], (r, k, flat.shape[1]))
    if self_override is not None:
        # wire-only attacks: the receiver's own slot carries its honest
        # state, not the payload it emitted to everyone else
        vals = jnp.where(self_hot[:, :, None], self_vals[:, None, :], vals)
    return flat, w_rows, self_hot, mask, counts, self_vals, vals


def _gate_center_flags(vals, mask, self_hot, counts, trim):
    """Robust center + per-neighbor outlier gate for trim/median modes.

    Returns ``(center, flagged)``: the coordinate-median neighborhood
    center (R, d) and the (R, K) flag mask (True = this receiver rejects
    that sender's edge this step; self slots never flag).
    """
    r, k, dflat = vals.shape
    # coordinate-wise neighborhood order statistics: masked-out slots
    # sort past every real value (sentinel), so positions 0..counts-1
    # are exactly the neighborhood — identical in sim (true values at
    # never-exchanged slots) and block (zeros there) buffers, which is
    # what keeps the two paths bitwise equal
    big = jnp.asarray(jnp.finfo(vals.dtype).max, vals.dtype)
    guarded = jnp.where(mask[:, :, None], vals, big)
    target = (counts - 1) // 2
    if k <= 32:
        # rank selection: the (counts-1)//2-th order statistic via an
        # O(K^2) comparison count instead of a sort — XLA's CPU sort
        # custom-call costs ~4x more than these fused elementwise
        # reductions at gossip-neighborhood sizes, and the robust mix
        # runs every round of every defended run. Index tie-breaking
        # gives each slot a unique rank, and tied slots carry equal
        # values, so the selected VALUE is bitwise the sorted one's.
        lt = guarded[:, :, None, :] < guarded[:, None, :, :]
        eq = guarded[:, :, None, :] == guarded[:, None, :, :]
        ilt = (jnp.arange(k)[:, None]
               < jnp.arange(k)[None, :])[None, :, :, None]
        rank = jnp.sum(lt | (eq & ilt), axis=1)              # (R, K, d)
        sel = rank == target[:, None, None]
        center = jnp.sum(jnp.where(sel, guarded, 0.0), axis=1)
    else:
        # large neighborhoods: the (R, K^2, d) comparison tensor stops
        # paying for itself — fall back to the sort
        srt = jnp.sort(guarded, axis=1)
        center = jnp.take_along_axis(
            srt, jnp.broadcast_to(target[:, None, None],
                                  (r, 1, dflat)), axis=1)[:, 0]
    # per-NEIGHBOR outlier gate on whole-vector geometry (see the
    # robust_neighborhood_mix docstring): anti-correlation with the robust
    # center, or norm inflation vs the (trim+1)-th largest neighbor norm —
    # a reference that `trim` colluding inflated payloads cannot raise.
    # Neither statistic fires on honest payloads, so the unflagged path is
    # the linear mix bit-for-bit.
    norms = jnp.sqrt(jnp.sum(vals * vals, axis=-1))          # (R, K)
    cnorm = jnp.sqrt(jnp.sum(center * center, axis=-1))      # (R,)
    dots = jnp.einsum("rkd,rd->rk", vals, center)
    cos = dots / (norms * cnorm[:, None] + 1e-30)
    nb_mask = mask & ~self_hot
    m_nb = jnp.sum(nb_mask.astype(jnp.int32), axis=1)
    nb_norms = jnp.where(nb_mask, norms, -jnp.inf)
    depth = jnp.minimum(trim, jnp.maximum(m_nb - 1, 0))      # (R,)
    # the (k-1-depth)-th order statistic by rank selection (same
    # sort-free trick as the center, one comparison matrix per row)
    n_lt = nb_norms[:, :, None] < nb_norms[:, None, :]
    n_eq = nb_norms[:, :, None] == nb_norms[:, None, :]
    n_ilt = (jnp.arange(k)[:, None] < jnp.arange(k)[None, :])[None]
    n_rank = jnp.sum(n_lt | (n_eq & n_ilt), axis=1)          # (R, K)
    n_sel = n_rank == (k - 1 - depth)[:, None]
    ref = jnp.sum(jnp.where(n_sel, nb_norms, 0.0), axis=1,
                  keepdims=True)
    ref = jnp.where(jnp.isfinite(ref), ref, 0.0)             # (R, 1)
    # the norm gate needs a positive reference (in early sparse rounds a
    # row may see <= trim+1 active neighbors and "3 x 0" would flag the
    # lone honest one) AND a non-aligned payload against a nonzero
    # center (see _TRIM_NORM_ARM_COS) — either false drop would
    # permanently drift the cohort's Lemma-1 invariant
    norm_armed = (ref > 0) & (cnorm[:, None] > 0) \
        & (cos < _TRIM_NORM_ARM_COS)
    flagged = (cos < _TRIM_COS_GATE) | \
              ((norms > _TRIM_NORM_GATE * ref) & norm_armed)  # (R, K)
    flagged = flagged & nb_mask
    return center, flagged


def _clip_scale(vals, mask, self_hot, self_vals, row_ids, clip, dtype):
    """Per-neighbor deviation clipping factors for mode="clip".

    Returns ``(dev, scale, nb_mask)``: the (R, K, d) deviations from self,
    the (R, K) clip factors (``< 1`` exactly where a deviation was actually
    clipped) and the non-self neighborhood mask.
    """
    dev = vals - self_vals[:, None, :]                           # (R, K, d)
    norms = jnp.sqrt(jnp.sum(dev * dev, axis=-1))                # (R, K)
    nb_mask = mask & ~self_hot
    if clip is not None:
        tau = jnp.full(row_ids.shape, clip, dtype)
    else:
        # adaptive threshold: a multiple of the median NEIGHBOR (non-self)
        # deviation norm — same masked-sort machinery on the (R, K) norm
        # rows. The factor leaves typical honest neighbors UNclipped (the
        # aggregation stays exactly linear near consensus, so the Lemma-1
        # invariant drift stops) while a sign-flip payload's ~2||v||
        # deviation still lands far outside it
        m_nb = jnp.sum(nb_mask.astype(jnp.int32), axis=1)
        tau = _masked_trimmed_mean(norms[:, :, None], nb_mask,
                                   (jnp.maximum(m_nb, 1) - 1) // 2,
                                   jnp.maximum(m_nb, 1))[:, 0]
        tau = jnp.where(m_nb > 0, _CLIP_TAU_FACTOR * tau, 0.0)
    scale = jnp.minimum(1.0, tau[:, None] / (norms + 1e-30))     # (R, K)
    return dev, scale, nb_mask


def gate_flags(w_rows: jax.Array, buf: jax.Array, row_ids: jax.Array,
               mode: str, *, trim: int = 1, clip: float | None = None,
               self_override: jax.Array | None = None) -> jax.Array:
    """The (R, K) per-edge rejection mask the robust aggregation applies.

    Same arguments and setup as :func:`robust_neighborhood_mix`; returns
    only the boolean gate decision — True where receiver row r rejects
    sender column k's edge this step (trim/median: the outlier gate fired;
    clip: the deviation was actually clipped). Self slots are never
    flagged. Because every operation mirrors the mix exactly (shared
    helpers), tracing this next to the mix in one jitted program costs
    nothing: XLA CSEs the duplicate subexpressions. This is what the
    ``repro.obs`` telemetry counters sum per sender.
    """
    if mode not in ROBUST_MODES:
        raise ValueError(f"unknown robust mode {mode!r} "
                         f"(want one of {ROBUST_MODES})")
    flat, w_rows, self_hot, mask, counts, self_vals, vals = \
        _neighborhood_setup(w_rows, buf, row_ids, self_override)
    if mode in ("trim", "median"):
        _, flagged = _gate_center_flags(vals, mask, self_hot, counts, trim)
        return flagged
    _, scale, nb_mask = _clip_scale(vals, mask, self_hot, self_vals,
                                    jnp.asarray(row_ids), clip, flat.dtype)
    return (scale < 1.0) & nb_mask


def robust_neighborhood_mix(w_rows: jax.Array, buf: jax.Array,
                            row_ids: jax.Array, mode: str, *,
                            trim: int = 1,
                            clip: float | None = None,
                            self_override: jax.Array | None = None
                            ) -> jax.Array:
    """Robust aggregation of a neighborhood buffer — the Byzantine-resilient
    replacement for ``w_rows @ buf``.

    The mixing-layer defense against participants that lie (PAPERS.md,
    Pasquini et al.): instead of trusting the linear W-contraction, each node
    aggregates its neighborhood with an outlier-suppressing rule. Shared by
    the dense simulator (``robust_mix_dense``: buf is the full stack) and the
    block-plan lowering (``repro.topo.lowering.block_robust_mix_step``: buf
    is the ppermute-assembled zero-filled neighborhood buffer) — every
    computed quantity depends only on slots inside the neighborhood support,
    so the two paths are bitwise identical.

    Args:
      w_rows: (R, K) these nodes' rows of the round's W; the support
        (w != 0, self always included) defines each neighborhood. Under
        churn reweighting a frozen node's row degenerates to e_k and the
        aggregation returns its own value unchanged.
      buf: (K, d_flat) value buffer (rows outside the support may be
        anything — typically zeros in block mode, true values in sim mode).
      row_ids: (R,) global node ids of the rows (``arange(K)`` in sim mode,
        ``device*ln + arange(ln)`` in block mode) — selects each node's own
        value for clipping.
      mode: "trim"   — gated trimmed W-mean: each neighbor is tested
                       against the outlier gate (payload anti-correlated
                       with the neighborhood's coordinate-median center, or
                       norm more than ``_TRIM_NORM_GATE`` x the (trim+1)-th
                       largest neighbor norm); a FLAGGED neighbor's edge is
                       dropped for this step and its weight moved onto the
                       self term; everything else passes through untouched;
            "median" — same outlier gate, but a flagged payload is replaced
                       by the coordinate-wise neighborhood (lower) median
                       instead of dropped, keeping the row weights;
            "clip"   — per-neighbor norm clipping: each neighbor's deviation
                       from the node's own value is clipped to ``clip`` (or,
                       when None, to ``_CLIP_TAU_FACTOR`` x the median
                       neighbor deviation norm), then the usual W-weighted
                       sum runs on clipped values.
      trim: collusion depth the norm gate survives — the inflation
        reference is the (trim+1)-th largest neighbor norm, which ``trim``
        coordinated liars cannot raise.
      self_override: optional (R, ...) HONEST self values — under a wire
        attack (``repro.attack``) ``buf`` holds emitted payloads, but each
        receiving node's own slot is its own state, which was never on the
        wire; the override swaps it in (and the self slot is always exempt
        from the outlier gate — a node trusts itself).

    Why gated instead of an always-on trimmed mean / winsorization: any
    unconditional nonlinearity keeps shaving the K-amplified honest update
    spikes Algorithm 1 emits (v += gamma K dv) — per coordinate an honest
    extreme routinely sits tens of trimmed-window-widths out, so per-
    coordinate statistics cannot tell it from a lie — and the resulting
    mean distortion permanently drifts the Lemma-1 invariant the Prop.-1
    certificate audits: a CLEAN defended run would read as tampered. The
    gate instead decides per NEIGHBOR from whole-vector geometry (honest
    payloads estimate the same dual point, so they correlate positively
    with any robust center and agree in norm; sign-flipped payloads
    anti-correlate and inflated ones stand out in norm), and only flagged
    payloads are rejected. Clean runs therefore take the exact linear path,
    while a stealthy lie that slips the gate must hide inside the honest
    geometry — its per-round influence bounded by what an honest neighbor
    could have said anyway. Breakdown point: the coordinate-median center
    tolerates just under half the neighborhood lying, the norm reference
    ``trim`` colluders; placements where one neighborhood contains several
    coordinated liars (e.g. 2 adjacent Byzantine nodes on tiny graphs) can
    evade or scramble the gate. All modes keep a frozen/self-only
    neighborhood fixed.
    """
    if mode not in ROBUST_MODES:
        raise ValueError(f"unknown robust mode {mode!r} "
                         f"(want one of {ROBUST_MODES})")
    flat, w_rows, self_hot, mask, counts, self_vals, vals = \
        _neighborhood_setup(w_rows, buf, row_ids, self_override)
    r = vals.shape[0]

    if mode in ("trim", "median"):
        center, flagged = _gate_center_flags(vals, mask, self_hot, counts,
                                             trim)
        # NOTE: ``vals`` already carries the self_override substitution
        # (_neighborhood_setup) and ``flagged`` already excludes the self
        # slot (& nb_mask), so neither branch needs a second self-slot
        # where()
        if mode == "median":
            # flagged payloads are replaced outright by the robust center
            clamped = jnp.where(flagged[:, :, None],
                                center[:, None, :], vals)
            out = jnp.einsum("rk,rkd->rd", w_rows,
                             jnp.where(mask[:, :, None], clamped, 0.0))
        else:
            # "trim": drop the flagged edges for this step and move their
            # weight onto the self term — a gated trimmed W-mean. Unlike
            # clamping to a window edge this leaves no residual pull
            # toward the lie's side of the window
            w_eff = jnp.where(flagged, 0.0, w_rows)
            w_drop = jnp.sum(jnp.where(flagged, w_rows, 0.0), axis=1)
            out = jnp.einsum("rk,rkd->rd", w_eff,
                             jnp.where(mask[:, :, None], vals, 0.0))
            out = out + w_drop[:, None] * self_vals
        return out.reshape((r,) + buf.shape[1:])

    # mode == "clip": norm-clip each neighbor's deviation from self
    dev, scale, _ = _clip_scale(vals, mask, self_hot, self_vals,
                                jnp.asarray(row_ids), clip, flat.dtype)
    clipped = self_vals[:, None, :] + dev * scale[:, :, None]
    clipped = jnp.where(mask[:, :, None], clipped, 0.0)
    out = jnp.einsum("rk,rkd->rd", w_rows, clipped)
    return out.reshape((r,) + buf.shape[1:])


def robust_mix_dense(w: jax.Array, v_stack: jax.Array, mode: str, *,
                     trim: int = 1, clip: float | None = None,
                     self_stack: jax.Array | None = None) -> jax.Array:
    """ONE robust gossip step on stacked (K, ...) node state — the dense
    (simulator) counterpart of ``dense_mix`` for ``ColaConfig.robust``.
    ``self_stack`` carries the honest states when ``v_stack`` holds
    attacked wire payloads (see ``robust_neighborhood_mix``)."""
    k = v_stack.shape[0]
    flat = v_stack.reshape(k, -1)
    ov = None if self_stack is None else self_stack.reshape(k, -1)
    out = robust_neighborhood_mix(w, flat, jnp.arange(k), mode,
                                  trim=trim, clip=clip, self_override=ov)
    return out.reshape(v_stack.shape).astype(v_stack.dtype)


def robust_mix_steps(w: jax.Array, v_stack: jax.Array, mode: str, *,
                     trim: int = 1, clip: float | None = None,
                     steps: int = 1,
                     self_stack: jax.Array | None = None) -> jax.Array:
    """B consecutive robust gossip steps. Robust aggregation is nonlinear,
    so there is no W^B fold — the steps apply sequentially (matching the
    on-the-wire ``topo.lowering.block_robust_mix_steps`` exactly). A wire
    attack (``self_stack`` not None) only exists on the FIRST step; later
    steps re-mix already-received values, which are honest."""
    out = v_stack
    for i in range(steps):
        out = robust_mix_dense(w, out, mode, trim=trim, clip=clip,
                               self_stack=self_stack if i == 0 else None)
    return out
