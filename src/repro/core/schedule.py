"""Streaming on-device schedules + partial participation (client sampling).

The round-block executor historically consumed only pre-materialized
``(T, ...)`` schedule stacks (mixing matrices, active masks, CD budgets,
attack rows), so host memory scaled as T x K long before compute did. This
module provides the streaming alternative: a ``ScheduleProgram`` bundles
named per-round *generators* — pure jax functions ``t -> {entry: array}``
whose randomness derives from ``jax.random.fold_in(key, t)`` — and the
executor evaluates them INSIDE the ``lax.scan`` round body (see
``executor.run_round_blocks(stream=...)``). Nothing T-shaped is ever
materialized; the same program can also be ``materialize()``-d into the
classical stacks, which is what the streaming-vs-stacked bitwise pins and
the chunked-host fallback (non-generative schedules like eavesdropper
taps) use.

On top of it, ``SampleConfig`` implements FedAvg-style partial
participation (McMahan et al.; the elasticity regime of CoLA Sec. 4):
every round samples K' << K active nodes uniformly via a ``fold_in(t)``
top-k draw. Two execution modes:

* ``dense``  — small K: the generator emits the round's ``active`` mask
  and the reweighted mixing matrix ``w`` (the induced Metropolis weights
  of the complete graph's active subgraph are EXACTLY ones/K' on the
  active block, inactive diagonal 1), plus the dynamic-certificate
  entries, so the standard round body and churn certificate machinery run
  unchanged — bitwise equal to the materialized path for the same draws.
* ``cohort`` — million-node populations: the generator emits the sorted
  active index vector ``cohort_idx`` and the round body gathers/updates
  only the (K', ...) cohort slices (``cola._run_cola_cohort``); the
  certificate stays sound on the sampled subnetwork via the cohort mode
  of ``metrics.CertificateRecorder``.

Participation requires a complete base graph (the sampled subnetwork of a
sparse graph may disconnect, and its contraction factor has no cheap
on-device form); the distributed runtime instead lowers participation to
its existing time-varying-plan churn path (any graph) by evaluating the
same generator host-side (``participation_callable``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

# auto mode switches to the cohort path above this population size: a dense
# (K, K) mixing matrix at 4096 nodes is 64 MB/round of schedule — past that
# the O(K'^2 + K) cohort round is the only sane regime
DENSE_MAX_NODES = 4096


@dataclasses.dataclass(frozen=True)
class SampleConfig:
    """Partial-participation sampler: K' of K nodes active per round.

    ``mode="auto"`` picks ``dense`` (full stacked state, streamed W) up to
    ``DENSE_MAX_NODES`` nodes and ``cohort`` (gather/scatter on the sampled
    index set, no (K, K) array anywhere) beyond. ``stream=False`` is the
    escape hatch for equivalence tests: the SAME jax generator is evaluated
    host-side into classical stacked schedules, so a streamed run and its
    materialized twin are bitwise comparable. ``seed=None`` derives the
    sampling key from the run seed.
    """

    k_active: int
    mode: str = "auto"          # "auto" | "dense" | "cohort"
    stream: bool = True
    seed: int | None = None

    def __post_init__(self):
        if self.k_active < 1:
            raise ValueError(f"need k_active >= 1, got {self.k_active}")
        if self.mode not in ("auto", "dense", "cohort"):
            raise ValueError(f"unknown participation mode {self.mode!r} "
                             "(want 'auto', 'dense' or 'cohort')")

    def resolve_mode(self, k: int) -> str:
        if self.k_active > k:
            raise ValueError(f"k_active={self.k_active} exceeds the "
                             f"population K={k}")
        if self.mode != "auto":
            return self.mode
        return "dense" if k <= DENSE_MAX_NODES else "cohort"

    def resolve_seed(self, run_seed: int) -> int:
        return int(run_seed if self.seed is None else self.seed)


@dataclasses.dataclass(frozen=True)
class ScheduleProgram:
    """Named per-round schedule generators, evaluated inside the scan.

    ``parts`` is a tuple of pure jax functions ``t -> {name: array}`` whose
    outputs merge left to right into the round's schedule slice. The
    program streams (``stream_fn`` — what the executor's ``stream=`` hook
    consumes) or materializes (``materialize`` — the classical stacked
    schedule, for bitwise pins and non-streaming drivers). Functions must
    derive all randomness from ``fold_in``-style keys on ``t`` so the two
    forms see identical draws.
    """

    parts: tuple

    def stream_fn(self) -> Callable[[jax.Array], dict]:
        parts = self.parts

        def stream(t):
            out: dict = {}
            for p in parts:
                out.update(p(t))
            return out

        return stream

    def entry_structs(self) -> dict:
        """{name: ShapeDtypeStruct} of ONE round's streamed entries."""
        return dict(jax.eval_shape(self.stream_fn(), jnp.int32(0)))

    def materialize(self, rounds: int) -> dict:
        """Evaluate the generators host-side into stacked (T, ...) arrays —
        the classical schedule form, bitwise the values the streamed scan
        would derive round by round."""
        fn = jax.jit(self.stream_fn())
        cols: dict = {name: [] for name in self.entry_structs()}
        for t in range(rounds):
            out = fn(jnp.int32(t))
            for name, val in out.items():
                cols[name].append(np.asarray(val))
        return {name: np.stack(vals) if vals else
                np.zeros((0,) + tuple(self.entry_structs()[name].shape),
                         self.entry_structs()[name].dtype)
                for name, vals in cols.items()}

    def footprint(self, rounds: int) -> dict:
        """Streamed vs stacked schedule memory, bytes: what ``dryrun
        --plan --active`` renders. ``streamed`` is one round's entries
        (resident inside the scan); ``stacked`` is the (T, ...) alternative
        this program replaces."""
        per_round = {name: int(np.prod(sd.shape, dtype=np.int64))
                     * np.dtype(sd.dtype).itemsize
                     for name, sd in self.entry_structs().items()}
        streamed = int(sum(per_round.values()))
        return {"entries": per_round, "streamed_bytes": streamed,
                "stacked_bytes": streamed * int(rounds)}


def active_mask(key: jax.Array, t, k: int, k_active: int) -> jax.Array:
    """(K,) bool participation mask for round ``t``: the top-``k_active``
    entries of a ``fold_in(key, t)``-keyed uniform draw — a uniformly random
    K'-subset, re-derivable at any round without carrying sampler state."""
    u = jax.random.uniform(jax.random.fold_in(key, t), (k,))
    _, idx = jax.lax.top_k(u, k_active)
    return jnp.zeros((k,), bool).at[idx].set(True)


def cohort_indices(key: jax.Array, t, k: int, k_active: int) -> jax.Array:
    """(K',) sorted int32 active-node indices for round ``t`` — the SAME
    draw as ``active_mask`` (same fold_in key, same top-k), in gather
    order."""
    u = jax.random.uniform(jax.random.fold_in(key, t), (k,))
    _, idx = jax.lax.top_k(u, k_active)
    return jnp.sort(idx.astype(jnp.int32))


def sampled_complete_weights(mask: jax.Array, k_active: int,
                             dtype) -> jax.Array:
    """Induced Metropolis mixing matrix of the complete graph's active
    subgraph: every active pair (self included) gets weight 1/K' — the
    induced subgraph is itself complete, so the Metropolis construction
    collapses to the exact uniform average — and inactive nodes keep
    W_kk = 1 (frozen, as ``topo.reweight_for_active`` builds host-side)."""
    m = mask.astype(dtype)
    inv = jnp.asarray(1.0 / k_active, dtype)
    return jnp.outer(m, m) * inv + jnp.diag(jnp.asarray(1.0, dtype) - m)


def require_complete(graph) -> None:
    if getattr(graph, "name", None) != "complete":
        raise ValueError(
            "participation= requires a complete base graph (topology "
            f"{getattr(graph, 'name', type(graph).__name__)!r}): the "
            "sampled subnetwork of a sparse graph may disconnect and its "
            "contraction factor has no on-device closed form. The "
            "distributed runtime supports sparse graphs via its host-side "
            "churn plan path.")


def participation_parts(k: int, sample: SampleConfig, *, dtype,
                        run_seed: int, cert=None,
                        leave_reset: bool = False) -> tuple:
    """The dense-mode generator parts for a participation run: the active
    mask + streamed mixing matrix, optionally the dynamic-certificate
    entries (complete graph => beta of the sampled subnetwork is exactly 0,
    so the Eq.-10 threshold is a run constant) and the leaver reset flags.
    """
    key = jax.random.PRNGKey(sample.resolve_seed(run_seed))
    k_active = sample.k_active

    def part_mix(t):
        mask = active_mask(key, t, k, k_active)
        return {"active": mask.astype(dtype),
                "w": sampled_complete_weights(mask, k_active, dtype)}

    parts = [part_mix]
    if cert is not None:
        thresh = cohort_grad_thresh(cert)

        def part_cert(t):
            mask = active_mask(key, t, k, k_active)
            cmask = jnp.outer(mask, mask) | jnp.eye(k, dtype=bool)
            return {"cert_mask": cmask.astype(dtype),
                    "cert_grad_thresh": jnp.asarray(thresh, dtype)}

        parts.append(part_cert)
    if leave_reset:
        ones = jnp.ones((k,), bool)

        def part_reset(t):
            prev = jnp.where(t == 0, ones, active_mask(key, t - 1, k,
                                                       k_active))
            leave = prev & ~active_mask(key, t, k, k_active)
            return {"leavers": leave, "reset_any": jnp.any(leave)}

        parts.append(part_reset)
    return tuple(parts)


def cohort_parts(k: int, sample: SampleConfig, *, dtype,
                 run_seed: int) -> tuple:
    """The cohort-mode generator part: sorted active indices (what the
    gather/scatter round body consumes) plus the (K,) mask the certificate
    uses to split active from frozen nodes."""
    key = jax.random.PRNGKey(sample.resolve_seed(run_seed))
    k_active = sample.k_active

    def part(t):
        idx = cohort_indices(key, t, k, k_active)
        mask = jnp.zeros((k,), bool).at[idx].set(True)
        return {"cohort_idx": idx, "active": mask.astype(dtype)}

    return (part,)


def cohort_grad_thresh(cert) -> float:
    """The Eq.-10 threshold over a sampled COMPLETE subnetwork. The induced
    mixing matrix is the exact uniform average (a rank-one projector), so
    the active subnetwork's contraction factor beta is 0 and the dynamic
    threshold of ``metrics.certificate_round_inputs`` collapses to this run
    constant — the closed form that lets the certificate stream."""
    n_sizes = np.sum(np.asarray(cert.masks), axis=1)
    scale = float(np.sum(n_sizes ** 2 * np.asarray(cert.sigma_k)))
    k = cert.part.num_nodes
    return float((scale ** -0.5) / (2.0 * cert.l_bound * np.sqrt(float(k)))
                 * cert.eps)


def participation_callable(k: int, sample: SampleConfig,
                           run_seed: int) -> Callable:
    """Adapter for the stacked-schedule drivers (the loop reference driver
    and the distributed runtime's churn plan path): an
    ``active_schedule(t, rng)`` callable that replays the SAME fold_in
    draws as the streamed generator, host-side. Ignores the shared
    schedule rng — participation draws come from the sampler key."""
    key = jax.random.PRNGKey(sample.resolve_seed(run_seed))
    k_active = sample.k_active
    fn = jax.jit(lambda t: active_mask(key, t, k, k_active))

    def schedule(t, rng):
        return np.asarray(fn(jnp.int32(t)))

    return schedule


def render_stream_footprint(k: int, k_active: int, rounds: int,
                            d: int, *, seed: int = 0,
                            dtype=np.float32) -> str:
    """Human-readable streamed-schedule footprint (the ``dryrun --plan
    --active`` section): per-entry bytes resident inside the scan vs the
    (T, ...) stacks streaming replaces. Uses the cohort generator above
    ``DENSE_MAX_NODES`` (exactly what ``run_cola`` would execute)."""
    sample = SampleConfig(k_active=k_active)
    mode = sample.resolve_mode(k)
    if mode == "cohort":
        parts = cohort_parts(k, sample, dtype=np.dtype(dtype),
                             run_seed=seed)
    else:
        parts = participation_parts(k, sample, dtype=np.dtype(dtype),
                                    run_seed=seed)
    prog = ScheduleProgram(parts=parts)
    fp = prog.footprint(rounds)
    lines = [f"[streamed schedule] K={k:,} K'={k_active:,} T={rounds:,} "
             f"mode={mode} (schedule bytes resident per round)"]
    for name, b in sorted(fp["entries"].items()):
        lines.append(f"  {name:<12} {b:>14,} B/round")
    lines.append(f"  {'streamed':<12} {fp['streamed_bytes']:>14,} B total "
                 "(scan-resident)")
    lines.append(f"  {'stacked':<12} {fp['stacked_bytes']:>14,} B total "
                 "(the (T, ...) alternative)")
    return "\n".join(lines)
