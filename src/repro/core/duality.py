"""Decentralized duality machinery — the numeric core of both recording paths.

Two families of diagnostics live here:

* the **global** quantities of Lemmas 1/2 — H_A / H_B objectives (Eq. DA/DB),
  the decentralized duality gap (Eq. 6) and the consensus violation — computed
  by ``gap_report`` from the full stacked state (the gather-everything path
  behind ``repro.core.metrics.GapRecorder``);
* the **local** Prop.-1 certificates (Eqs. 9-10) — per-node conditions whose
  conjunction certifies ``G_H <= eps`` from one gossip exchange of neighbor
  gradients only. ``local_certificates`` is built from the reusable pieces
  (``node_subproblem_gaps``, ``neighborhood_mean``, ``certificate_thresholds``)
  that ``repro.core.metrics.CertificateRecorder`` assembles on-device inside
  the round-block scan, and that ``repro.dist.runtime`` re-assembles from a
  ``ppermute``/``psum`` of the local gradient (O(d) per device per record
  round — no (K, d) stack gathers).

The Eq.-10 neighborhood mean uses a masked-neighbor formulation: each node
averages exactly the gradient VALUES a gossip exchange delivers (its own plus
its neighbors'), selected by the 0/1 support of the adjacency — or of the
round's mixing matrix, which under churn reweighting excludes dropped
neighbors the way a real exchange would.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import Partition


class GapReport(NamedTuple):
    primal: jax.Array             # F_A(x)
    hamiltonian: jax.Array        # H_A(x, {v_k})
    dual: jax.Array               # -H_B({w_k}) with w_k = grad f(v_k)
    gap: jax.Array                # G_H (Eq. 6)
    consensus_violation: jax.Array  # sum_k ||v_k - Ax||^2


def hamiltonian(problem, x_global: jax.Array, v_stack: jax.Array) -> jax.Array:
    """H_A(x, {v_k}) = (1/K) sum_k f(v_k) + g(x)   (Eq. DA)."""
    f_vals = jax.vmap(problem.f)(v_stack)
    return jnp.mean(f_vals) + problem.g(x_global)


def gap_report(problem, part: Partition, x_parts: jax.Array,
               v_stack: jax.Array) -> GapReport:
    """All Lemma-1/2 quantities at the optimality choice w_k = grad f(v_k)."""
    x = part.merge_vector(x_parts)
    ax = problem.a @ x
    w_stack = jax.vmap(problem.grad_f)(v_stack)          # (K, d)
    w_bar = jnp.mean(w_stack, axis=0)
    f_vals = jax.vmap(problem.f)(v_stack)
    fc_vals = jax.vmap(problem.f_conj)(w_stack)
    g_val = problem.g(x)
    gc_val = jnp.sum(problem.g_conj_el(-(problem.a.T @ w_bar), problem.g_params()))
    h_a = jnp.mean(f_vals) + g_val
    h_b = jnp.mean(fc_vals) + gc_val
    gap = h_a + h_b
    cv = jnp.sum((v_stack - ax[None, :]) ** 2)
    return GapReport(primal=problem.f(ax) + g_val, hamiltonian=h_a,
                     dual=-h_b, gap=gap, consensus_violation=cv)


def block_spectral_norms(a_parts: jax.Array, iters: int = 50,
                         seed: int = 0,
                         cache: jax.Array | None = None) -> jax.Array:
    """sigma_k = ||A_[k]||_2^2 (Eq. 7) for every node, by power iteration.

    ``cache`` short-circuits the power iteration with a previously computed
    ``(K,)`` result — the sigma_k of a run are round-invariant, so recorders
    compute them ONCE at init and record rounds never re-run the iteration.
    """
    k, d, n_k = a_parts.shape
    if cache is not None:
        cache = jnp.asarray(cache)
        if cache.shape != (k,):
            raise ValueError(f"sigma_k cache has shape {cache.shape}, "
                             f"want ({k},)")
        return cache
    key = jax.random.PRNGKey(seed)
    v0 = jax.random.normal(key, (k, n_k), dtype=a_parts.dtype)

    def body(_, v):
        u = jnp.einsum("kdn,kn->kd", a_parts, v)
        w = jnp.einsum("kdn,kd->kn", a_parts, u)
        return w / (jnp.linalg.norm(w, axis=1, keepdims=True) + 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v0)
    u = jnp.einsum("kdn,kn->kd", a_parts, v)
    num = jnp.einsum("kn,kn->k", jnp.einsum("kdn,kd->kn", a_parts, u), v)
    den = jnp.einsum("kn,kn->k", v, v)
    return num / (den + 1e-30)


class CertificateReport(NamedTuple):
    """Prop. 1: per-node booleans whose conjunction certifies G_H <= eps."""

    local_gap: jax.Array          # (K,) LHS of Eq. 9
    local_gap_ok: jax.Array       # (K,) Eq. 9 holds
    grad_disagreement: jax.Array  # (K,) LHS of Eq. 10
    grad_ok: jax.Array            # (K,) Eq. 10 holds
    certified: jax.Array          # scalar bool: all nodes pass both


def neighbor_mask(neighbors, k: int, dtype=jnp.float32) -> jax.Array:
    """Self-inclusive 0/1 neighborhood mask N_k ∪ {k} from either a boolean
    adjacency (no self loops) or a mixing matrix W (whose support is the
    round's actual exchange pattern — under churn reweighting a dropped
    neighbor has W_kj = 0 and leaves the neighborhood, exactly as the real
    gossip exchange it models)."""
    m = jnp.asarray(np.asarray(neighbors) != 0, dtype=dtype)
    return jnp.maximum(m, jnp.eye(k, dtype=dtype))


def neighborhood_mean(grads: jax.Array, mask: jax.Array) -> jax.Array:
    """Eq.-10 neighborhood mean, masked-neighbor formulation.

    Each node averages the gradient VALUES its gossip exchange delivers:
    ``where(mask)``-selected rows of ``grads``, summed over the neighborhood
    — not a dense (K, K) float matmul that weights every node's gradient
    (non-neighbors by 0.0 and any matrix entry by its magnitude). This is
    the stacked oracle the distributed ``ppermute`` exchange is checked
    against: identical inputs (own + neighbor gradients), identical mean.
    """
    sel = jnp.where(mask[:, :, None] > 0, grads[None, :, :], 0.0)  # (K, K, d)
    counts = jnp.sum(mask, axis=1, keepdims=True)
    return jnp.sum(sel, axis=1) / counts


def consensus_residual(v_sum: jax.Array, ax_sum: jax.Array,
                       k_nodes: int) -> jax.Array:
    """Relative Lemma-1 invariant residual: ||(1/K) sum_k v_k - A x|| scaled
    by (||A x|| + 1).

    Every HONEST CoLA dynamic — any dx, churn freezing, budgets, Fig.-6
    resets — preserves (1/K) sum_k v_k = A x exactly in exact arithmetic
    (the mean-v and Ax updates cancel algebraically), and doubly-stochastic
    linear mixing keeps the mean untouched. A Byzantine payload (the
    effective column-stochasticity of the mix is broken) or per-link
    corruption moves the mean without moving A x, so this residual is the
    certificate layer's tamper detector (``certificate_violated``). Robust
    NONLINEAR aggregation (trim/median/clip) drifts it benignly by the
    neighborhood spread, which vanishes near consensus — hence a tolerance
    band rather than an exact-zero check.

    Args:
      v_sum: (d,) sum over all K nodes of v_k (psum-able partial in dist).
      ax_sum: (d,) sum over all K nodes of A_[k] x_[k] (= A x).
    """
    rho = jnp.linalg.norm(v_sum / k_nodes - ax_sum)
    return rho / (jnp.linalg.norm(ax_sum) + 1.0)


def node_subproblem_gaps(problem, x_parts: jax.Array, v_stack: jax.Array,
                         a_parts: jax.Array, gp_parts: jax.Array,
                         masks: jax.Array, grads: jax.Array) -> jax.Array:
    """(K,) LHS of condition (9): each node's local subproblem duality gap,
    from node-local quantities only (no cross-node data at all)."""
    def node_gap(v_k, g_k, a_k, x_k, gp_k, m_k):
        conj = problem.g_conj_el(-(a_k.T @ g_k), gp_k)
        prim = problem.g_el(x_k, gp_k)
        return jnp.dot(v_k, g_k) + jnp.sum((prim + conj) * m_k)

    return jax.vmap(node_gap)(v_stack, grads, a_parts, x_parts,
                              gp_parts, masks)


def certificate_thresholds(masks, sigma_k, beta_ub: float, l_bound: float,
                           eps: float, k_nodes: int):
    """(gap_thresh, grad_thresh): the Prop.-1 RHS of conditions (9), (10).

    Both are round-invariant — they depend only on the partition sizes, the
    per-block spectral norms sigma_k, the mixing contraction beta and the
    L-bound — so recorders evaluate this once at init and record rounds
    compare against baked scalars.
    """
    gap_thresh = eps / (2.0 * k_nodes)
    n_k_sizes = jnp.sum(jnp.asarray(masks), axis=1)
    scale = jnp.sum(n_k_sizes ** 2 * jnp.asarray(sigma_k))
    grad_thresh = (scale ** -0.5) * (1.0 - beta_ub) / (
        2.0 * l_bound * jnp.sqrt(float(k_nodes))) * eps
    return gap_thresh, grad_thresh


def local_certificates(problem, part: Partition, x_parts: jax.Array,
                       v_stack: jax.Array, a_parts: jax.Array,
                       gp_parts: jax.Array, masks: jax.Array,
                       neighbors, beta_ub: float,
                       sigma_k: jax.Array, eps: float,
                       l_bound: float,
                       grads: jax.Array | None = None,
                       neigh_mean: jax.Array | None = None
                       ) -> CertificateReport:
    """Evaluate the Prop.-1 conditions (9) and (10) from local quantities only.

    The only cross-node data each node uses is its neighbors' gradients
    grad f(v_j), j in N_k — exactly what one gossip exchange provides.

    Args:
      neighbors: (K, K) boolean adjacency OR the round's mixing matrix W;
        only the support is used (self always included, W_kk > 0 for
        Metropolis weights). Passing the churn-reweighted W restricts each
        neighborhood to the nodes that actually exchanged this round.
      grads / neigh_mean: optional precomputed (K, d) gradients and Eq.-10
        neighborhood means (e.g. from the gossip exchange the round already
        performed) — recomputed from ``v_stack`` when omitted.
    """
    k_nodes = v_stack.shape[0]
    if grads is None:
        grads = jax.vmap(problem.grad_f)(v_stack)        # (K, d)

    # -- condition (9): local subproblem duality gap ------------------------
    local_gap = node_subproblem_gaps(problem, x_parts, v_stack, a_parts,
                                     gp_parts, masks, grads)

    # -- condition (10): gradient agreement with the neighborhood -----------
    if neigh_mean is None:
        mask = neighbor_mask(neighbors, k_nodes, dtype=grads.dtype)
        neigh_mean = neighborhood_mean(grads, mask)
    disagree = jnp.linalg.norm(grads - neigh_mean, axis=1)

    gap_thresh, grad_thresh = certificate_thresholds(
        masks, sigma_k, beta_ub, l_bound, eps, k_nodes)
    cond9 = local_gap <= gap_thresh
    cond10 = disagree <= grad_thresh

    return CertificateReport(
        local_gap=local_gap, local_gap_ok=cond9,
        grad_disagreement=disagree, grad_ok=cond10,
        certified=jnp.all(cond9 & cond10))
