"""Decentralized duality machinery: H_A / H_B objectives (Eq. DA/DB), the
decentralized duality gap (Lemma 2, Eq. 6), consensus violation, and the
Prop.-1 local certificates.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import Partition


class GapReport(NamedTuple):
    primal: jax.Array             # F_A(x)
    hamiltonian: jax.Array        # H_A(x, {v_k})
    dual: jax.Array               # -H_B({w_k}) with w_k = grad f(v_k)
    gap: jax.Array                # G_H (Eq. 6)
    consensus_violation: jax.Array  # sum_k ||v_k - Ax||^2


def hamiltonian(problem, x_global: jax.Array, v_stack: jax.Array) -> jax.Array:
    """H_A(x, {v_k}) = (1/K) sum_k f(v_k) + g(x)   (Eq. DA)."""
    f_vals = jax.vmap(problem.f)(v_stack)
    return jnp.mean(f_vals) + problem.g(x_global)


def gap_report(problem, part: Partition, x_parts: jax.Array,
               v_stack: jax.Array) -> GapReport:
    """All Lemma-1/2 quantities at the optimality choice w_k = grad f(v_k)."""
    x = part.merge_vector(x_parts)
    ax = problem.a @ x
    w_stack = jax.vmap(problem.grad_f)(v_stack)          # (K, d)
    w_bar = jnp.mean(w_stack, axis=0)
    f_vals = jax.vmap(problem.f)(v_stack)
    fc_vals = jax.vmap(problem.f_conj)(w_stack)
    g_val = problem.g(x)
    gc_val = jnp.sum(problem.g_conj_el(-(problem.a.T @ w_bar), problem.g_params()))
    h_a = jnp.mean(f_vals) + g_val
    h_b = jnp.mean(fc_vals) + gc_val
    gap = h_a + h_b
    cv = jnp.sum((v_stack - ax[None, :]) ** 2)
    return GapReport(primal=problem.f(ax) + g_val, hamiltonian=h_a,
                     dual=-h_b, gap=gap, consensus_violation=cv)


def block_spectral_norms(a_parts: jax.Array, iters: int = 50,
                         seed: int = 0) -> jax.Array:
    """sigma_k = ||A_[k]||_2^2 (Eq. 7) for every node, by power iteration."""
    k, d, n_k = a_parts.shape
    key = jax.random.PRNGKey(seed)
    v0 = jax.random.normal(key, (k, n_k), dtype=a_parts.dtype)

    def body(_, v):
        u = jnp.einsum("kdn,kn->kd", a_parts, v)
        w = jnp.einsum("kdn,kd->kn", a_parts, u)
        return w / (jnp.linalg.norm(w, axis=1, keepdims=True) + 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v0)
    u = jnp.einsum("kdn,kn->kd", a_parts, v)
    num = jnp.einsum("kn,kn->k", jnp.einsum("kdn,kd->kn", a_parts, u), v)
    den = jnp.einsum("kn,kn->k", v, v)
    return num / (den + 1e-30)


class CertificateReport(NamedTuple):
    """Prop. 1: per-node booleans whose conjunction certifies G_H <= eps."""

    local_gap: jax.Array          # (K,) LHS of Eq. 9
    local_gap_ok: jax.Array       # (K,) Eq. 9 holds
    grad_disagreement: jax.Array  # (K,) LHS of Eq. 10
    grad_ok: jax.Array            # (K,) Eq. 10 holds
    certified: jax.Array          # scalar bool: all nodes pass both


def local_certificates(problem, part: Partition, x_parts: jax.Array,
                       v_stack: jax.Array, a_parts: jax.Array,
                       gp_parts: jax.Array, masks: jax.Array,
                       adjacency: np.ndarray, beta_ub: float,
                       sigma_k: jax.Array, eps: float,
                       l_bound: float) -> CertificateReport:
    """Evaluate the Prop.-1 conditions (9) and (10) from local quantities only.

    The only cross-node data each node uses is its neighbors' gradients
    grad f(v_j), j in N_k — exactly what one gossip exchange provides.
    """
    k_nodes = v_stack.shape[0]
    grads = jax.vmap(problem.grad_f)(v_stack)            # (K, d)

    # -- condition (9): local subproblem duality gap ------------------------
    def node_gap(v_k, g_k, a_k, x_k, gp_k, m_k):
        conj = problem.g_conj_el(-(a_k.T @ g_k), gp_k)
        prim = problem.g_el(x_k, gp_k)
        return jnp.dot(v_k, g_k) + jnp.sum((prim + conj) * m_k)

    local_gap = jax.vmap(node_gap)(v_stack, grads, a_parts, x_parts,
                                   gp_parts, masks)
    cond9 = local_gap <= eps / (2.0 * k_nodes)

    # -- condition (10): gradient agreement with the neighborhood -----------
    # N_k includes k itself (W_kk > 0 for Metropolis weights).
    adj_self = jnp.asarray(adjacency, dtype=grads.dtype) + jnp.eye(
        k_nodes, dtype=grads.dtype)
    deg = jnp.sum(adj_self, axis=1, keepdims=True)
    neigh_mean = (adj_self @ grads) / deg
    disagree = jnp.linalg.norm(grads - neigh_mean, axis=1)
    n_k_sizes = jnp.sum(masks, axis=1)
    scale = jnp.sum(n_k_sizes ** 2 * sigma_k)
    thresh = (scale ** -0.5) * (1.0 - beta_ub) / (2.0 * l_bound *
                                                  jnp.sqrt(k_nodes)) * eps
    cond10 = disagree <= thresh

    return CertificateReport(
        local_gap=local_gap, local_gap_ok=cond9,
        grad_disagreement=disagree, grad_ok=cond10,
        certified=jnp.all(cond9 & cond10))
