"""Decentralized baselines the paper compares against (§4, Fig. 2):

* DGD    — decentralized (sub)gradient descent [Nedic & Ozdaglar 2009],
           prox-variant for composite objectives.
* DIGing — gradient tracking [Nedic et al. 2017]; recovers EXTRA on static
           symmetric W.
* D-ADMM — decentralized consensus ADMM [Shi et al. 2014, Boyd et al. 2011]
           with an inexact local solver (fixed number of prox-gradient steps,
           matching the paper's "same number of coordinates as CoLA" setup).

All of them address the sum-structured form  min_w sum_k F_k(w)  with
F_k(w) = f(X_k w; y_k)/1 + (1/K) g(w): the data is partitioned by SAMPLES
(rows), each node holds a full copy of w — in contrast to CoLA's column
partitioning. This is their natural formulation and what the paper benchmarks.

All three runners execute on the shared round-block engine
(``repro.core.executor``) by default — ``block_size`` rounds per device
dispatch, metrics recorded on device — with ``executor="loop"`` retained as
the per-round reference path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import executor as exec_engine, metrics as metrics_lib, \
    mixing, topology as topo


def _baseline_mixer(w_mix, robust, trim, clip):
    """The consensus contraction the baseline rounds use: the plain
    ``w_mix @`` dot when ``robust`` is None (bitwise the historical path),
    else the same Byzantine-resilient per-neighborhood aggregation CoLA's
    mixing layer applies (``mixing.robust_mix_dense``) so DGD/DIGing can be
    benchmarked under the attack harness on equal footing."""
    if robust is None:
        return lambda ws: w_mix @ ws
    if robust not in mixing.ROBUST_MODES:
        raise ValueError(f"unknown robust mode {robust!r} "
                         f"(want one of {mixing.ROBUST_MODES})")
    return lambda ws: mixing.robust_mix_dense(w_mix, ws, robust,
                                              trim=trim, clip=clip)


@dataclasses.dataclass(frozen=True)
class ConsensusProblem:
    """min_w sum_k [ loss(X_k w, y_k) + (1/K) g(w) ], nodes hold row blocks."""

    x_parts: jax.Array   # (K, m_k, d) row blocks (padded with zero rows)
    y_parts: jax.Array   # (K, m_k)
    row_mask: jax.Array  # (K, m_k)
    loss: str            # "square" | "logistic"
    reg: str             # "l2" | "l1"
    lam: float

    @property
    def num_nodes(self) -> int:
        return self.x_parts.shape[0]

    @property
    def dim(self) -> int:
        return self.x_parts.shape[2]

    # -- smooth part: data fit + (l2 reg if reg == l2) ----------------------
    def local_fit(self, w: jax.Array, k_slice) -> jax.Array:
        xk, yk, mk = k_slice
        z = xk @ w
        if self.loss == "square":
            return 0.5 * jnp.sum(((z - yk) ** 2) * mk)
        return jnp.sum(jnp.logaddexp(0.0, -yk * z) * mk)

    def objective(self, w: jax.Array) -> jax.Array:
        """Global F(w) (uses one shared w)."""
        fit = 0.0
        z = jnp.einsum("kmd,d->km", self.x_parts, w)
        if self.loss == "square":
            fit = 0.5 * jnp.sum(((z - self.y_parts) ** 2) * self.row_mask)
        else:
            fit = jnp.sum(jnp.logaddexp(0.0, -self.y_parts * z) * self.row_mask)
        if self.reg == "l2":
            return fit + 0.5 * self.lam * jnp.sum(w ** 2)
        return fit + self.lam * jnp.sum(jnp.abs(w))

    def smooth_grad(self, w_stack: jax.Array) -> jax.Array:
        """(K, d) gradients of the smooth part of each F_k at each node's w_k."""
        z = jnp.einsum("kmd,kd->km", self.x_parts, w_stack)
        if self.loss == "square":
            resid = (z - self.y_parts) * self.row_mask
        else:
            resid = -self.y_parts * jax.nn.sigmoid(-self.y_parts * z) * self.row_mask
        grad = jnp.einsum("kmd,km->kd", self.x_parts, resid)
        if self.reg == "l2":
            grad = grad + (self.lam / self.num_nodes) * w_stack
        return grad

    def prox_reg(self, w: jax.Array, step: float) -> jax.Array:
        """prox of (step/K) * nonsmooth reg (only l1 is nonsmooth here)."""
        if self.reg == "l1":
            t = step * self.lam / self.num_nodes
            return jnp.sign(w) * jnp.maximum(jnp.abs(w) - t, 0.0)
        return w


def make_consensus_problem(x: np.ndarray, y: np.ndarray, k: int, *, loss: str,
                           reg: str, lam: float) -> ConsensusProblem:
    m = x.shape[0]
    m_k = -(-m // k)
    pad = k * m_k - m
    xp = np.pad(x, ((0, pad), (0, 0))).reshape(k, m_k, x.shape[1])
    yp = np.pad(y, (0, pad)).reshape(k, m_k)
    mask = (np.arange(k * m_k) < m).reshape(k, m_k).astype(x.dtype)
    return ConsensusProblem(jnp.asarray(xp), jnp.asarray(yp),
                            jnp.asarray(mask), loss, reg, lam)


class BaselineResult(NamedTuple):
    w_stack: jax.Array
    history: dict


def _telemetry_info(driver: str, prob: ConsensusProblem, graph, *,
                    mixes_per_round: int, config: dict) -> dict:
    """Static per-round wire model for a baseline's telemetry entry.

    The baselines mix with a dense (K, K) contraction — the all-gather
    oracle's wire: each device receives the full (K, d) replica stack per
    mixing application, so the modeled budget is ``mixes_per_round x K x d``
    payload bytes per device per round (DIGing mixes both the iterate and
    the tracker). Rounds never early-stop here, so the static host product
    is exact — no on-device counter carry is needed.
    """
    k, d = prob.num_nodes, prob.dim
    itemsize = np.dtype(prob.x_parts.dtype).itemsize
    per = mixes_per_round * k * d * itemsize
    return {"driver": driver,
            "graph": {"kind": getattr(graph, "name", type(graph).__name__),
                      "num_nodes": k},
            "config": config,
            "bytes_per_round": per,
            "permutes_per_round": 0,
            "contract": f"dense all-gather x{mixes_per_round}: "
                        f"{per:,}B/device/round"}


def _run(prob: ConsensusProblem, round_fn: Callable, state, rounds: int,
         record_every: int, extract_w: Callable, executor: str = "block",
         block_size: int = 64, telemetry: dict | None = None
         ) -> BaselineResult:
    """Drive ``round_fn`` for ``rounds`` rounds.

    ``executor="block"`` scans ``block_size`` rounds per device dispatch with
    on-device metric recording (see ``repro.core.executor``); "loop" is the
    retained one-dispatch-per-round reference path. ``round_fn`` must be an
    unjitted pure ``carry -> carry`` body. ``telemetry`` (a
    ``_telemetry_info`` dict) surfaces the run's wire counters in
    ``history["telemetry"]`` and emits a ``repro.obs`` RunReport.
    """
    def obj_fn(ws):
        return prob.objective(jnp.mean(ws, axis=0))

    def cons_fn(ws):
        return jnp.sum((ws - jnp.mean(ws, axis=0)) ** 2)

    recorder = metrics_lib.FnRecorder(
        labels=("objective", "consensus"),
        fn=lambda carry: jnp.stack([obj_fn(extract_w(carry)),
                                    cons_fn(extract_w(carry))]))

    if executor == "block":
        def step_fn(carry, _ctx, _sched):
            return round_fn(carry), None

        rec = exec_engine.record_flags(rounds, record_every)
        run_tr = None
        if telemetry is not None:
            from repro.obs import trace as obs_trace
            with obs_trace.use(obs_trace.Tracer()) as run_tr, \
                    run_tr.attach():
                res = exec_engine.run_round_blocks(
                    step_fn, state, {}, recorder=recorder, record_mask=rec,
                    block_size=block_size, num_rounds=rounds)
        else:
            res = exec_engine.run_round_blocks(
                step_fn, state, {}, recorder=recorder, record_mask=rec,
                block_size=block_size, num_rounds=rounds)
        history = metrics_lib.history_from(recorder, res)
        if telemetry is not None:
            from repro.obs import report as obs_report
            history["telemetry"] = {
                "rounds": rounds,
                "wire_bytes": rounds * telemetry["bytes_per_round"],
                "permutes": rounds * telemetry["permutes_per_round"],
                "contract": telemetry["contract"],
                "stop_round": res.stop_round}
            obs_report.auto_emit(obs_report.make_report(
                driver=telemetry["driver"],
                problem_fp=exec_engine.fingerprint(prob),
                config=telemetry["config"], graph=telemetry["graph"],
                rounds=rounds, history=history,
                contract=telemetry["contract"],
                spans=run_tr.summary()))
        return BaselineResult(w_stack=extract_w(res.state), history=history)

    if executor != "loop":
        raise ValueError(f"unknown executor {executor!r} "
                         "(want 'block' or 'loop')")
    if telemetry is not None:
        raise ValueError("telemetry requires executor='block'")
    history: dict = {"round": [], "objective": [], "consensus": [],
                     "stop_round": None}
    step = jax.jit(round_fn)
    report = jax.jit(recorder.record_fn)
    for t in range(rounds):
        state = step(state)
        if t % record_every == 0 or t == rounds - 1:
            row = report(state)
            history["round"].append(t)
            for j, name in enumerate(recorder.labels):
                history[name].append(float(row[j]))
    return BaselineResult(w_stack=extract_w(state), history=history)


# ---------------------------------------------------------------------------
# DGD (prox-variant for composite objectives)
# ---------------------------------------------------------------------------

def run_dgd(prob: ConsensusProblem, graph: topo.Topology, *, step: float,
            rounds: int, record_every: int = 1, diminishing: bool = False,
            robust: str | None = None, robust_trim: int = 1,
            robust_clip: float | None = None,
            executor: str = "block", block_size: int = 64,
            telemetry: bool = False) -> BaselineResult:
    w_mix = jnp.asarray(topo.metropolis_weights(graph), dtype=prob.x_parts.dtype)
    k, d = prob.num_nodes, prob.dim
    mix = _baseline_mixer(w_mix, robust, robust_trim, robust_clip)
    tel = _telemetry_info(
        "dgd", prob, graph, mixes_per_round=1,
        config={"step": step, "diminishing": diminishing, "robust": robust,
                "rounds": rounds}) if telemetry else None

    def one_round(carry):
        ws, t = carry
        alpha = step / jnp.sqrt(t + 1.0) if diminishing else step
        mixed = mix(ws)
        grad = prob.smooth_grad(ws)
        new = prob.prox_reg(mixed - alpha * grad, alpha)
        return (new, t + 1.0)

    state = (jnp.zeros((k, d), dtype=prob.x_parts.dtype), jnp.asarray(0.0))
    return _run(prob, one_round, state, rounds, record_every, lambda s: s[0],
                executor, block_size, tel)


# ---------------------------------------------------------------------------
# DIGing (gradient tracking; == EXTRA on static symmetric W)
# ---------------------------------------------------------------------------

def run_diging(prob: ConsensusProblem, graph: topo.Topology, *, step: float,
               rounds: int, record_every: int = 1,
               robust: str | None = None, robust_trim: int = 1,
               robust_clip: float | None = None, executor: str = "block",
               block_size: int = 64, telemetry: bool = False
               ) -> BaselineResult:
    w_mix = jnp.asarray(topo.metropolis_weights(graph), dtype=prob.x_parts.dtype)
    k, d = prob.num_nodes, prob.dim
    # both contractions (the iterate mix and the tracker mix) go through the
    # robust aggregation — a liar corrupts s exactly like ws on the wire
    mix = _baseline_mixer(w_mix, robust, robust_trim, robust_clip)

    def one_round(carry):
        ws, s, g_prev = carry
        ws_new = mix(ws) - step * s
        # nonsmooth reg handled by subgradient inside the tracked gradient
        g_new = prob.smooth_grad(ws_new)
        if prob.reg == "l1":
            g_new = g_new + (prob.lam / k) * jnp.sign(ws_new)
        s_new = mix(s) + g_new - g_prev
        return (ws_new, s_new, g_new)

    ws0 = jnp.zeros((k, d), dtype=prob.x_parts.dtype)
    g0 = prob.smooth_grad(ws0)
    if prob.reg == "l1":
        g0 = g0 + (prob.lam / k) * jnp.sign(ws0)
    # g0 appears twice in the carry; copy so state donation sees distinct
    # buffers (donating the same buffer twice is an error)
    state = (ws0, g0, jnp.array(g0))
    tel = _telemetry_info(
        "diging", prob, graph, mixes_per_round=2,
        config={"step": step, "robust": robust,
                "rounds": rounds}) if telemetry else None
    return _run(prob, one_round, state, rounds, record_every, lambda s: s[0],
                executor, block_size, tel)


# ---------------------------------------------------------------------------
# Decentralized (consensus) ADMM with inexact local solves
# ---------------------------------------------------------------------------

def run_dadmm(prob: ConsensusProblem, graph: topo.Topology, *, rho: float,
              rounds: int, inner_steps: int = 10, inner_lr: float | None = None,
              record_every: int = 1, executor: str = "block",
              block_size: int = 64, telemetry: bool = False
              ) -> BaselineResult:
    """Consensus ADMM [Shi et al. 2014]:

      x_k^{t+1} = argmin F_k(x) + <a_k^t, x> + rho * d_k ||x - m_k^t||^2
      a_k^{t+1} = a_k^t + rho * (d_k x_k^{t+1} - sum_{j in N_k} x_j^{t+1})

    with m_k^t the average of x_k and its neighbors' midpoints. The argmin is
    solved inexactly with ``inner_steps`` prox-gradient steps (the paper uses a
    CD budget matched to CoLA's).
    """
    adj = jnp.asarray(graph.adjacency, dtype=prob.x_parts.dtype)
    deg = jnp.sum(adj, axis=1)  # (K,)
    k, d = prob.num_nodes, prob.dim
    # Lipschitz-ish constant for the inner prox-gradient steps.
    if inner_lr is None:
        col_norm = float(jnp.max(jnp.sum(prob.x_parts ** 2, axis=(1, 2))))
        inner_lr = 1.0 / (col_norm + rho * float(jnp.max(deg)) * 2.0 + 1e-9)

    def one_round(carry):
        xs, a = carry
        neigh_sum = adj @ xs                         # (K, d)
        mid = 0.5 * (deg[:, None] * xs + neigh_sum)  # rho-term anchor

        def inner(_, x_cur):
            grad = prob.smooth_grad(x_cur) + a + 2.0 * rho * (
                deg[:, None] * x_cur - mid)
            return prob.prox_reg(x_cur - inner_lr * grad, inner_lr)

        xs_new = jax.lax.fori_loop(0, inner_steps, inner, xs)
        a_new = a + rho * (deg[:, None] * xs_new - adj @ xs_new)
        return (xs_new, a_new)

    xs0 = jnp.zeros((k, d), dtype=prob.x_parts.dtype)
    state = (xs0, jnp.zeros_like(xs0))
    # two neighbor-sum contractions per round (the x and dual updates)
    tel = _telemetry_info(
        "dadmm", prob, graph, mixes_per_round=2,
        config={"rho": rho, "inner_steps": inner_steps,
                "rounds": rounds}) if telemetry else None
    return _run(prob, one_round, state, rounds, record_every, lambda s: s[0],
                executor, block_size, tel)
