# CoLA: Decentralized Linear Learning (He, Bian, Jaggi — NeurIPS 2018).
# The paper's primary contribution as a composable JAX module: gossip mixing
# over arbitrary graph topologies, data-local quadratic subproblems with
# Theta-approximate coordinate-descent solvers, decentralized duality gaps and
# local certificates, elasticity/fault tolerance, and the baselines it is
# evaluated against.
from repro.core import (  # noqa: F401
    baselines,
    cola,
    duality,
    mixing,
    partition,
    problems,
    subproblem,
    topology,
)
from repro.core.cola import ColaConfig, ColaState, run_cola  # noqa: F401
from repro.core.problems import PROBLEMS, Problem  # noqa: F401
