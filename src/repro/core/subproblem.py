"""The data-local quadratic subproblem G_k^{sigma'} (paper Eq. 1-2) and its
Theta-approximate block coordinate-descent solver (Assumption 1).

    G_k(dx; v_k, x_k) = (1/K) f(v_k) + <grad_f(v_k), A_k dx>
                        + sigma'/(2 tau) ||A_k dx||^2
                        + sum_{i in P_k} g_i(x_i + dx_i)

The CD solver performs ``kappa`` cyclic passes over the local coordinates; each
single-coordinate update has the closed form

    z      = x_i + dx_i
    grad_i = A_i^T (grad_f(v_k) + (sigma'/tau) r)        with r = A_k dx
    q_i    = (sigma'/tau) ||A_i||^2
    z_new  = prox_{g_i, 1/q_i}(z - grad_i / q_i)
    dx_i  += z_new - z;   r += A_i (z_new - z)

``kappa`` is the paper's knob for the local accuracy Theta (Fig. 1): more
passes => smaller Theta => fewer communication rounds.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


class SubproblemSpec(NamedTuple):
    """Static pieces of G_k shared by all nodes."""

    sigma_over_tau: float  # sigma' / tau
    inv_k: float           # 1 / K


def eval_subproblem(problem, spec: SubproblemSpec, a_k: jax.Array,
                    x_k: jax.Array, dx_k: jax.Array, v_k: jax.Array,
                    grad_k: jax.Array, gp_k: jax.Array,
                    mask_k: jax.Array) -> jax.Array:
    """Evaluate G_k^{sigma'}(dx; v_k, x_k) for one node (used in tests/Theta probes)."""
    r = a_k @ dx_k
    lin = jnp.dot(grad_k, r)
    quad = 0.5 * spec.sigma_over_tau * jnp.sum(r ** 2)
    g_term = jnp.sum(problem.g_el(x_k + dx_k, gp_k) * mask_k)
    return spec.inv_k * problem.f(v_k) + lin + quad + g_term


def cd_solve(problem, spec: SubproblemSpec, a_k: jax.Array, x_k: jax.Array,
             grad_k: jax.Array, gp_k: jax.Array, mask_k: jax.Array,
             num_steps: int, step_budget: jax.Array | None = None
             ) -> jax.Array:
    """Theta-approximate solution of G_k by cyclic CD updates (one node).

    Args:
      problem: the GLM Problem (provides prox_g_el).
      spec: sigma'/tau and 1/K constants.
      a_k: (d, n_k) local columns.
      x_k: (n_k,) local iterate block.
      grad_k: (d,) gradient of f at this node's (mixed) local estimate v_k.
      gp_k: (n_k,) per-coordinate g parameters.
      mask_k: (n_k,) 1 for real coordinates, 0 for padding.
      num_steps: total single-coordinate updates — the paper's kappa knob
        (Fig. 1); may be less than one full pass over the block.
      step_budget: optional TRACED per-call budget <= num_steps — the
        node-specific Theta_k of Definition 5 (stragglers do fewer updates;
        budget 0 == Theta_k = 1, no update). num_steps stays static so all
        nodes share one compiled program.

    Returns:
      dx_k: (n_k,) the local update Delta x_[k].
    """
    n_k = a_k.shape[1]
    col_sq = jnp.sum(a_k * a_k, axis=0)  # (n_k,) ||A_i||^2
    q = spec.sigma_over_tau * col_sq
    q_safe = jnp.where(q > 0, q, 1.0)

    def coord_step(carry, idx):
        step_i, i = idx
        dx, r = carry
        a_i = lax.dynamic_index_in_dim(a_k, i, axis=1, keepdims=False)
        z = x_k[i] + dx[i]
        grad_i = jnp.dot(a_i, grad_k + spec.sigma_over_tau * r)
        step = 1.0 / q_safe[i]
        z_new = problem.prox_g_el(z - grad_i * step, step, gp_k[i])
        ok = (q[i] > 0) & (mask_k[i] > 0)
        if step_budget is not None:
            ok = ok & (step_i < step_budget)
        delta = jnp.where(ok, z_new - z, 0.0)
        return (dx.at[i].add(delta), r + a_i * delta), None

    # derive the zeros from the inputs so they inherit device-varying types
    # under shard_map (vma) — semantically identical to jnp.zeros.
    dx0 = x_k * 0.0
    r0 = a_k[:, 0] * 0.0
    passes = -(-num_steps // n_k)
    order = jnp.tile(jnp.arange(n_k), passes)[:num_steps]
    steps = jnp.arange(num_steps)
    (dx, _), _ = lax.scan(coord_step, (dx0, r0), (steps, order))
    return dx


def cd_solve_all(problem, spec: SubproblemSpec, a_parts: jax.Array,
                 x_parts: jax.Array, grads: jax.Array, gp_parts: jax.Array,
                 masks: jax.Array, num_steps: int,
                 step_budgets: jax.Array | None = None) -> jax.Array:
    """vmap of cd_solve over the node axis (single-host simulator path).

    ``step_budgets``: optional (K,) per-node budgets (heterogeneous Theta_k).
    """
    if step_budgets is None:
        fn = lambda a_k, x_k, g_k, gp_k, m_k: cd_solve(
            problem, spec, a_k, x_k, g_k, gp_k, m_k, num_steps)
        return jax.vmap(fn)(a_parts, x_parts, grads, gp_parts, masks)
    fn = lambda a_k, x_k, g_k, gp_k, m_k, b_k: cd_solve(
        problem, spec, a_k, x_k, g_k, gp_k, m_k, num_steps, b_k)
    return jax.vmap(fn)(a_parts, x_parts, grads, gp_parts, masks,
                        step_budgets)
