"""The data-local quadratic subproblem G_k^{sigma'} (paper Eq. 1-2) and its
Theta-approximate block coordinate-descent solver (Assumption 1).

    G_k(dx; v_k, x_k) = (1/K) f(v_k) + <grad_f(v_k), A_k dx>
                        + sigma'/(2 tau) ||A_k dx||^2
                        + sum_{i in P_k} g_i(x_i + dx_i)

The CD solver performs ``kappa`` cyclic passes over the local coordinates; each
single-coordinate update has the closed form

    z      = x_i + dx_i
    grad_i = A_i^T (grad_f(v_k) + (sigma'/tau) r)        with r = A_k dx
    q_i    = (sigma'/tau) ||A_i||^2
    z_new  = prox_{g_i, 1/q_i}(z - grad_i / q_i)
    dx_i  += z_new - z;   r += A_i (z_new - z)

``kappa`` is the paper's knob for the local accuracy Theta (Fig. 1): more
passes => smaller Theta => fewer communication rounds.

Two formulations of the per-coordinate gradient, identical in exact
arithmetic:

* **residual** (the formula above): carry ``r = A_k dx`` (d,) and take
  ``A_i^T (grad + (sigma'/tau) r)`` — two O(d) ops per coordinate step.
* **Gram-cached**: with the node-local Gram block ``G = A_k^T A_k``
  (computed once per env build) and ``c = A_k^T grad_f(v_k)`` (once per
  round), carry ``h = G dx`` (n_k,) instead:

      grad_i = c_i + (sigma'/tau) h_i;   h += G[:, i] * delta

  — one O(n_k) op per coordinate step. ``gram_pays`` is the cost model:
  the Gram path wins when n_k < d AND the (n_k, n_k) block fits the
  VMEM/cache budget; otherwise the residual path is used.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

# VMEM we allow the cached (n_k, n_k) Gram block to occupy per node. A TPU
# core has ~16 MB of VMEM which the block shares with dx/h/x/scalars; half
# of it keeps headroom for double-buffered loads.
GRAM_VMEM_BUDGET = 8 * 2 ** 20


def gram_pays(d: int, n_k: int, itemsize: int = 4,
              vmem_budget: int = GRAM_VMEM_BUDGET) -> bool:
    """Cost model for the Gram-cached CD path.

    A residual coordinate step moves ~2 * d * itemsize bytes (column dot +
    rank-1 residual update); a Gram step moves ~n_k * itemsize (one Gram
    column axpy). Caching pays iff the per-step saving is real (n_k < d)
    and the (n_k, n_k) block actually fits on chip.
    """
    return n_k < d and n_k * n_k * itemsize <= vmem_budget


def block_gram(a_parts: jax.Array) -> jax.Array:
    """(K, d, n_k) column blocks -> (K, n_k, n_k) node-local Gram blocks."""
    return jnp.einsum("kdn,kdm->knm", a_parts, a_parts)


class SubproblemSpec(NamedTuple):
    """Static pieces of G_k shared by all nodes."""

    sigma_over_tau: float  # sigma' / tau
    inv_k: float           # 1 / K


def eval_subproblem(problem, spec: SubproblemSpec, a_k: jax.Array,
                    x_k: jax.Array, dx_k: jax.Array, v_k: jax.Array,
                    grad_k: jax.Array, gp_k: jax.Array,
                    mask_k: jax.Array) -> jax.Array:
    """Evaluate G_k^{sigma'}(dx; v_k, x_k) for one node (used in tests/Theta probes)."""
    r = a_k @ dx_k
    lin = jnp.dot(grad_k, r)
    quad = 0.5 * spec.sigma_over_tau * jnp.sum(r ** 2)
    g_term = jnp.sum(problem.g_el(x_k + dx_k, gp_k) * mask_k)
    return spec.inv_k * problem.f(v_k) + lin + quad + g_term


def cd_solve(problem, spec: SubproblemSpec, a_k: jax.Array, x_k: jax.Array,
             grad_k: jax.Array, gp_k: jax.Array, mask_k: jax.Array,
             num_steps: int, step_budget: jax.Array | None = None
             ) -> jax.Array:
    """Theta-approximate solution of G_k by cyclic CD updates (one node).

    Args:
      problem: the GLM Problem (provides prox_g_el).
      spec: sigma'/tau and 1/K constants.
      a_k: (d, n_k) local columns.
      x_k: (n_k,) local iterate block.
      grad_k: (d,) gradient of f at this node's (mixed) local estimate v_k.
      gp_k: (n_k,) per-coordinate g parameters.
      mask_k: (n_k,) 1 for real coordinates, 0 for padding.
      num_steps: total single-coordinate updates — the paper's kappa knob
        (Fig. 1); may be less than one full pass over the block.
      step_budget: optional TRACED per-call budget <= num_steps — the
        node-specific Theta_k of Definition 5 (stragglers do fewer updates;
        budget 0 == Theta_k = 1, no update). num_steps stays static so all
        nodes share one compiled program.

    Returns:
      dx_k: (n_k,) the local update Delta x_[k].
    """
    n_k = a_k.shape[1]
    col_sq = jnp.sum(a_k * a_k, axis=0)  # (n_k,) ||A_i||^2
    q = spec.sigma_over_tau * col_sq
    q_safe = jnp.where(q > 0, q, 1.0)

    def coord_step(carry, idx):
        step_i, i = idx
        dx, r = carry
        a_i = lax.dynamic_index_in_dim(a_k, i, axis=1, keepdims=False)
        z = x_k[i] + dx[i]
        grad_i = jnp.dot(a_i, grad_k + spec.sigma_over_tau * r)
        step = 1.0 / q_safe[i]
        z_new = problem.prox_g_el(z - grad_i * step, step, gp_k[i])
        ok = (q[i] > 0) & (mask_k[i] > 0)
        if step_budget is not None:
            ok = ok & (step_i < step_budget)
        delta = jnp.where(ok, z_new - z, 0.0)
        return (dx.at[i].add(delta), r + a_i * delta), None

    # derive the zeros from the inputs so they inherit device-varying types
    # under shard_map (vma) — semantically identical to jnp.zeros.
    dx0 = x_k * 0.0
    r0 = a_k[:, 0] * 0.0
    passes = -(-num_steps // n_k)
    order = jnp.tile(jnp.arange(n_k), passes)[:num_steps]
    steps = jnp.arange(num_steps)
    (dx, _), _ = lax.scan(coord_step, (dx0, r0), (steps, order))
    return dx


def cd_solve_gram(problem, spec: SubproblemSpec, gram_k: jax.Array,
                  atg_k: jax.Array, x_k: jax.Array, gp_k: jax.Array,
                  mask_k: jax.Array, num_steps: int,
                  step_budget: jax.Array | None = None) -> jax.Array:
    """Gram-cached CD solve of G_k for one node (see module docstring).

    Args:
      gram_k: (n_k, n_k) node-local Gram block A_[k]^T A_[k].
      atg_k: (n_k,) A_[k]^T grad_f(v_k), precomputed once per round.
      Remaining args as in ``cd_solve``.
    """
    n_k = gram_k.shape[0]
    col_sq = jnp.diagonal(gram_k)  # ||A_i||^2
    q = spec.sigma_over_tau * col_sq
    q_safe = jnp.where(q > 0, q, 1.0)

    def coord_step(carry, idx):
        step_i, i = idx
        dx, h = carry
        g_col = lax.dynamic_index_in_dim(gram_k, i, axis=1, keepdims=False)
        z = x_k[i] + dx[i]
        grad_i = atg_k[i] + spec.sigma_over_tau * h[i]
        step = 1.0 / q_safe[i]
        z_new = problem.prox_g_el(z - grad_i * step, step, gp_k[i])
        ok = (q[i] > 0) & (mask_k[i] > 0)
        if step_budget is not None:
            ok = ok & (step_i < step_budget)
        delta = jnp.where(ok, z_new - z, 0.0)
        return (dx.at[i].add(delta), h + g_col * delta), None

    dx0 = x_k * 0.0
    h0 = x_k * 0.0
    passes = -(-num_steps // n_k)
    order = jnp.tile(jnp.arange(n_k), passes)[:num_steps]
    steps = jnp.arange(num_steps)
    (dx, _), _ = lax.scan(coord_step, (dx0, h0), (steps, order))
    return dx


def cd_solve_all(problem, spec: SubproblemSpec, a_parts: jax.Array,
                 x_parts: jax.Array, grads: jax.Array, gp_parts: jax.Array,
                 masks: jax.Array, num_steps: int,
                 step_budgets: jax.Array | None = None,
                 gram_parts: jax.Array | None = None) -> jax.Array:
    """vmap of cd_solve over the node axis (single-host simulator path).

    ``step_budgets``: optional (K,) per-node budgets (heterogeneous Theta_k).
    ``gram_parts``: optional (K, n_k, n_k) Gram blocks — when given, the
    O(n_k)-per-step Gram-cached formulation replaces the O(d) residual one
    (numerically equivalent up to float reassociation, see module docstring).
    """
    if gram_parts is not None:
        atg = jnp.einsum("kdn,kd->kn", a_parts, grads)
        if step_budgets is None:
            fn = lambda g_k, c_k, x_k, gp_k, m_k: cd_solve_gram(
                problem, spec, g_k, c_k, x_k, gp_k, m_k, num_steps)
            return jax.vmap(fn)(gram_parts, atg, x_parts, gp_parts, masks)
        fn = lambda g_k, c_k, x_k, gp_k, m_k, b_k: cd_solve_gram(
            problem, spec, g_k, c_k, x_k, gp_k, m_k, num_steps, b_k)
        return jax.vmap(fn)(gram_parts, atg, x_parts, gp_parts, masks,
                            step_budgets)
    if step_budgets is None:
        fn = lambda a_k, x_k, g_k, gp_k, m_k: cd_solve(
            problem, spec, a_k, x_k, g_k, gp_k, m_k, num_steps)
        return jax.vmap(fn)(a_parts, x_parts, grads, gp_parts, masks)
    fn = lambda a_k, x_k, g_k, gp_k, m_k, b_k: cd_solve(
        problem, spec, a_k, x_k, g_k, gp_k, m_k, num_steps, b_k)
    return jax.vmap(fn)(a_parts, x_parts, grads, gp_parts, masks,
                        step_budgets)
