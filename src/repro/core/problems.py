"""GLM problem definitions mapped to the CoLA primal/dual pair (A)/(B).

Problem (A):  min_x  f(A x) + sum_i g_i(x_i),  A in R^{d x n}, columns A_i.

Every problem supplies:
  * ``f``, ``grad_f`` and the smoothness constant ``1/tau`` (f is (1/tau)-smooth),
  * the convex conjugate ``f_conj`` (for duality gaps, Lemma 2),
  * separable ``g`` via elementwise ``g_el(x, p)`` / ``g_conj_el(u, p)`` where
    ``p`` is an optional per-coordinate parameter vector (e.g. the labels in the
    sample-partitioned ridge-dual mapping) that is partitioned across nodes
    together with the columns of A,
  * the proximal operator ``prox_g_el(z, step, p)``,
  * strong convexity ``mu_g`` (Thm 1) and support bound ``l_bound`` (Thm 2).

Mappings follow Duenner et al. 2016 / Smith et al. 2018 (CoCoA), which the
paper builds on. L1 problems use the standard B-bounded-support modification so
Theorem 2's L-bounded-support assumption holds and duality gaps are finite.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Problem:
    """A composite objective f(Ax) + sum_i g_i(x_i) with its dual structure."""

    name: str
    a: jax.Array  # data matrix, (d, n)
    f: Callable[[jax.Array], jax.Array]
    grad_f: Callable[[jax.Array], jax.Array]
    f_conj: Callable[[jax.Array], jax.Array]
    g_el: Callable[[jax.Array, jax.Array], jax.Array]
    g_conj_el: Callable[[jax.Array, jax.Array], jax.Array]
    prox_g_el: Callable[[jax.Array, jax.Array, jax.Array], jax.Array]
    tau: float          # f is (1/tau)-smooth
    mu_g: float         # strong convexity of every g_i
    l_bound: float      # L-bounded support of g_i (inf if not bounded)
    g_param: jax.Array | None = None  # (n,) per-coordinate parameter or None
    # (l1, l2, box) of the generalized elastic-net prox family
    #   prox(z) = clip(soft(z - step*g_param_i, step*l1) / (1 + step*l2), +-box)
    # — consumed by the Pallas CD kernel (repro.kernels.cd_glm).
    prox_spec: tuple = (0.0, 0.0, np.inf)

    @property
    def d(self) -> int:
        return self.a.shape[0]

    @property
    def n(self) -> int:
        return self.a.shape[1]

    def g_params(self) -> jax.Array:
        if self.g_param is None:
            return jnp.zeros((self.n,), dtype=self.a.dtype)
        return self.g_param

    def g(self, x: jax.Array) -> jax.Array:
        return jnp.sum(self.g_el(x, self.g_params()))

    def objective(self, x: jax.Array) -> jax.Array:
        """F_A(x) = f(Ax) + g(x)."""
        return self.f(self.a @ x) + self.g(x)

    def dual_objective(self, w: jax.Array) -> jax.Array:
        """F_B(w) = f*(w) + sum_i g_i*(-A_i^T w)  (problem (B))."""
        return self.f_conj(w) + jnp.sum(self.g_conj_el(-(self.a.T @ w), self.g_params()))


# ---------------------------------------------------------------------------
# f parts (data-fit terms)
# ---------------------------------------------------------------------------

def _quadratic_f(b: jax.Array):
    """f(v) = 0.5 ||v - b||^2  -> 1-smooth (tau = 1); f*(w) = 0.5||w||^2 + <w, b>."""
    def f(v):
        return 0.5 * jnp.sum((v - b) ** 2)

    def grad_f(v):
        return v - b

    def f_conj(w):
        return 0.5 * jnp.sum(w ** 2) + jnp.dot(w, b)

    return f, grad_f, f_conj, 1.0


def _logistic_f(y: jax.Array):
    """f(v) = sum_j log(1 + exp(-y_j v_j)); (1/4)-smooth -> tau = 4.

    f*(w): with u := -w.y constrained to [0,1],
    f*(w) = sum_j u log u + (1-u) log(1-u)  (negative binary entropy).
    """
    def f(v):
        return jnp.sum(jnp.logaddexp(0.0, -y * v))

    def grad_f(v):
        return -y * jax.nn.sigmoid(-y * v)

    def f_conj(w):
        u = jnp.clip(-w * y, 1e-12, 1.0 - 1e-12)
        return jnp.sum(u * jnp.log(u) + (1.0 - u) * jnp.log1p(-u))

    return f, grad_f, f_conj, 4.0


# ---------------------------------------------------------------------------
# g parts (separable terms). All take (x, p) with p an unused-or-used
# per-coordinate parameter so that they vectorize over partitioned blocks.
# ---------------------------------------------------------------------------

def _l2_g(lam: float):
    def g_el(x, p):
        return 0.5 * lam * x ** 2

    def g_conj_el(u, p):
        return u ** 2 / (2.0 * lam)

    def prox(z, step, p):
        return z / (1.0 + step * lam)

    return g_el, g_conj_el, prox, lam, np.inf


def _l1_g(lam: float, box: float):
    """g_i(x) = lam |x| + i{|x| <= box}; g*(u) = box * max(0, |u| - lam)."""
    def g_el(x, p):
        return lam * jnp.abs(x) + jnp.where(jnp.abs(x) <= box, 0.0, jnp.inf)

    def g_conj_el(u, p):
        return box * jnp.maximum(0.0, jnp.abs(u) - lam)

    def prox(z, step, p):
        soft = jnp.sign(z) * jnp.maximum(jnp.abs(z) - step * lam, 0.0)
        return jnp.clip(soft, -box, box)

    return g_el, g_conj_el, prox, 0.0, box


def _elastic_net_g(lam: float, alpha: float, box: float):
    """g_i(x) = lam * (alpha |x| + (1-alpha)/2 x^2)."""
    l1 = lam * alpha
    l2 = lam * (1.0 - alpha)

    def g_el(x, p):
        return l1 * jnp.abs(x) + 0.5 * l2 * x ** 2

    def g_conj_el(u, p):
        if l2 > 0:
            return jnp.maximum(0.0, jnp.abs(u) - l1) ** 2 / (2.0 * l2)
        return box * jnp.maximum(0.0, jnp.abs(u) - l1)

    def prox(z, step, p):
        soft = jnp.sign(z) * jnp.maximum(jnp.abs(z) - step * l1, 0.0)
        return soft / (1.0 + step * l2)

    l_bound = np.inf if l2 > 0 else box
    return g_el, g_conj_el, prox, l2, l_bound


# ---------------------------------------------------------------------------
# Problem constructors
# ---------------------------------------------------------------------------

def ridge_primal(x_data: jax.Array, y: jax.Array, lam: float) -> Problem:
    """Ridge regression, feature-partitioned: min_x 0.5||Xx-y||^2 + lam/2||x||^2."""
    f, grad_f, f_conj, tau = _quadratic_f(y)
    g_el, g_conj_el, prox, mu, l = _l2_g(lam)
    return Problem("ridge_primal", x_data, f, grad_f, f_conj,
                   g_el, g_conj_el, prox, tau, mu, l,
                   prox_spec=(0.0, lam, np.inf))


def ridge_dual(x_data: jax.Array, y: jax.Array, lam: float) -> Problem:
    """Ridge regression mapped through (B): sample-partitioned.

    With f(v)=0.5||v-y||^2 and g=lam/2||.||^2, problem (B) over w (one dual
    variable per sample) is  min_w 0.5||w||^2 + <w,y> + ||X^T w||^2/(2 lam),
    itself of form (A) with A~ = X^T (columns = samples),
    f~(u) = ||u||^2/(2 lam) and g~_j(w_j) = 0.5 w_j^2 + y_j w_j.
    """
    at = x_data.T  # (n_features, n_samples): columns are samples

    def f(u):
        return jnp.sum(u ** 2) / (2.0 * lam)

    def grad_f(u):
        return u / lam

    def f_conj(s):
        return 0.5 * lam * jnp.sum(s ** 2)

    def g_el(w, p):
        return 0.5 * w ** 2 + p * w

    def g_conj_el(u, p):
        return 0.5 * (u - p) ** 2

    def prox(z, step, p):
        return (z - step * p) / (1.0 + step)

    return Problem("ridge_dual", at, f, grad_f, f_conj,
                   g_el, g_conj_el, prox, lam, 1.0, np.inf, g_param=y,
                   prox_spec=(0.0, 1.0, np.inf))


def lasso(x_data: jax.Array, y: jax.Array, lam: float, box: float = 10.0) -> Problem:
    """Lasso, feature-partitioned: min_x 0.5||Xx - y||^2 + lam ||x||_1."""
    f, grad_f, f_conj, tau = _quadratic_f(y)
    g_el, g_conj_el, prox, mu, l = _l1_g(lam, box)
    return Problem("lasso", x_data, f, grad_f, f_conj,
                   g_el, g_conj_el, prox, tau, mu, l,
                   prox_spec=(lam, 0.0, box))


def elastic_net(x_data: jax.Array, y: jax.Array, lam: float, alpha: float = 0.5,
                box: float = 1e3) -> Problem:
    f, grad_f, f_conj, tau = _quadratic_f(y)
    g_el, g_conj_el, prox, mu, l = _elastic_net_g(lam, alpha, box)
    return Problem("elastic_net", x_data, f, grad_f, f_conj,
                   g_el, g_conj_el, prox, tau, mu, l,
                   prox_spec=(lam * alpha, lam * (1.0 - alpha), box))


def logistic_l2(x_data: jax.Array, y: jax.Array, lam: float) -> Problem:
    """L2-regularized logistic regression, feature-partitioned. y in {-1, +1}."""
    f, grad_f, f_conj, tau = _logistic_f(y)
    g_el, g_conj_el, prox, mu, l = _l2_g(lam)
    return Problem("logistic_l2", x_data, f, grad_f, f_conj,
                   g_el, g_conj_el, prox, tau, mu, l,
                   prox_spec=(0.0, lam, np.inf))


def logistic_l1(x_data: jax.Array, y: jax.Array, lam: float,
                box: float = 10.0) -> Problem:
    """Sparse logistic regression (general convex case of Thm 2)."""
    f, grad_f, f_conj, tau = _logistic_f(y)
    g_el, g_conj_el, prox, mu, l = _l1_g(lam, box)
    return Problem("logistic_l1", x_data, f, grad_f, f_conj,
                   g_el, g_conj_el, prox, tau, mu, l,
                   prox_spec=(lam, 0.0, box))


PROBLEMS = {
    "ridge_primal": ridge_primal,
    "ridge_dual": ridge_dual,
    "lasso": lasso,
    "elastic_net": elastic_net,
    "logistic_l2": logistic_l2,
    "logistic_l1": logistic_l1,
}
