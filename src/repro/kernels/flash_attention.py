"""Pallas TPU flash attention with GQA + positional masking.

The kernel is the TPU adaptation of the zoo's attention hot path: the
(Sq, Skv) score matrix never leaves VMEM — a (block_q, head_dim) query tile
and running (m, l, acc) statistics live in VMEM scratch while the kernel
walks KV tiles along the last (sequential) grid axis. All four variants of
``repro.models.attention`` (causal / sliding / chunked_local / cross) are
expressed through the same explicit-position masking, so ring-buffer decode
caches work unchanged.

Grid: (batch, kv_head, q_group, num_q_blocks, num_kv_blocks) — the KV axis is
last, so on TPU the scratch accumulators carry across KV tiles of one query
tile (the sequential-grid idiom). Block shapes are MXU-aligned: block_q x
head_dim and block_kv x head_dim tiles with head_dim a multiple of 128 in the
production configs.

``ops.flash_attention`` is the jit'd wrapper (drop-in for
``chunked_attention``); ``ref.py`` is the pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask_block(mode: str, qp, kp, window: int):
    """(Bq, Bk) boolean mask from position tiles (same math as _mode_mask)."""
    q = qp[:, None]
    k = kp[None, :]
    valid = k >= 0
    if mode == "causal":
        return valid & (k <= q)
    if mode == "sliding":
        return valid & (k <= q) & (k > q - window)
    if mode == "chunked_local":
        return valid & (k <= q) & ((k // window) == (q // window))
    if mode == "cross":
        return valid
    raise ValueError(mode)


def _flash_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, mode: str, window: int,
                  scale: float, num_kv_blocks: int):
    kv_i = pl.program_id(4)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, 0].astype(jnp.float32) * scale       # (Bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (Bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                  # (Bk, hd)
    qp = qp_ref[0]                                       # (Bq,)
    kp = kp_ref[0]                                       # (Bk,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (Bq, Bk)
    mask = _mask_block(mode, qp, kp, window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    # fully-masked rows: keep p at 0 (s - m_new would be NEG_INF - NEG_INF)
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_ref[...] = m_new

    @pl.when(kv_i == num_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0, 0] = (acc_ref[...]
                          / jnp.maximum(l_ref[...], 1e-30)[:, None]
                          ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_pos: jax.Array, kv_pos: jax.Array, *, mode: str,
                    window: int = 0, block_q: int = 128, block_kv: int = 128,
                    interpret: bool = True) -> jax.Array:
    """Flash GQA attention via pl.pallas_call.

    Args mirror ``repro.models.attention.chunked_attention``:
      q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd), H = G * KV.
      q_pos: (B, Sq) int32; kv_pos: (B, Skv) int32, -1 = empty slot.

    ``interpret=True`` executes the kernel body on CPU (this container);
    on TPU pass interpret=False.
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = hd ** -0.5

    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    # pad sequences to block multiples; padded kv slots get position -1
    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_kv)), constant_values=-1)
    sq_p, skv_p = sq + pad_q, skv + pad_kv
    nq, nk = sq_p // block_q, skv_p // block_kv

    # (B, KV, G, Sq, hd) so the head group axes are grid axes
    qt = q.reshape(b, sq_p, kvh, g, hd).transpose(0, 2, 3, 1, 4)
    kt = k.transpose(0, 2, 1, 3)                         # (B, KV, Skv, hd)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, kvh, g, nq, nk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, mode=mode, window=window,
                          scale=scale, num_kv_blocks=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, block_q, hd),
                         lambda bi, ki, gi, qi, kvi: (bi, ki, gi, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda bi, ki, gi, qi, kvi: (bi, ki, kvi, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda bi, ki, gi, qi, kvi: (bi, ki, kvi, 0)),
            pl.BlockSpec((1, block_q),
                         lambda bi, ki, gi, qi, kvi: (bi, qi)),
            pl.BlockSpec((1, block_kv),
                         lambda bi, ki, gi, qi, kvi: (bi, kvi)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, block_q, hd),
                               lambda bi, ki, gi, qi, kvi: (bi, ki, gi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(qt, kt, vt, q_pos, kv_pos)

    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq_p, h, hd)
    return out[:, :sq]
