"""jit'd wrappers connecting the Pallas kernels to the framework APIs.

* ``cd_solve_pallas`` — drop-in replacement for
  ``repro.core.subproblem.cd_solve_all`` (the CoLA local solver), dispatching
  a Problem's generalized prox scalars to the cd_glm kernel.
* ``flash_attention_ops`` — drop-in for
  ``repro.models.attention.chunked_attention``.

Both run the kernel body in interpret mode on CPU (this container) and as a
compiled Mosaic kernel on TPU.
"""
from __future__ import annotations

import jax

from repro.core.partition import Partition
from repro.core.problems import Problem
from repro.core.subproblem import SubproblemSpec
from repro.kernels import cd_glm, flash_attention as fa


def cd_solve_pallas(problem: Problem, spec: SubproblemSpec,
                    a_parts: jax.Array, x_parts: jax.Array,
                    grads: jax.Array, gp_parts: jax.Array,
                    masks: jax.Array, num_steps: int, *,
                    interpret: bool = True) -> jax.Array:
    """Same signature/semantics as ``cd_solve_all`` but on the Pallas kernel."""
    l1, l2, box = problem.prox_spec
    return cd_glm.cd_solve_blocks(
        a_parts, x_parts, grads, gp_parts, masks,
        num_steps=num_steps, sigma_over_tau=float(spec.sigma_over_tau),
        l1=float(l1), l2=float(l2), box=float(box), interpret=interpret)


def flash_attention_ops(q, k, v, q_pos, kv_pos, *, mode: str,
                        window: int = 0, block_q: int = 128,
                        block_kv: int = 128, interpret: bool = True):
    """Drop-in for chunked_attention (same argument convention)."""
    return fa.flash_attention(q, k, v, q_pos, kv_pos, mode=mode,
                              window=window, block_q=block_q,
                              block_kv=block_kv, interpret=interpret)
