"""jit'd wrappers connecting the Pallas kernels to the framework APIs.

* ``cd_solve_pallas`` — drop-in replacement for
  ``repro.core.subproblem.cd_solve_all`` (the CoLA local solver), dispatching
  a Problem's generalized prox scalars to the cd_glm kernel.
* ``flash_attention_ops`` — drop-in for
  ``repro.models.attention.chunked_attention``.

Both run the kernel body in interpret mode on CPU (this container) and as a
compiled Mosaic kernel on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.partition import Partition
from repro.core.problems import Problem
from repro.core.subproblem import SubproblemSpec, gram_pays
from repro.kernels import cd_glm, flash_attention as fa


def cd_solve_pallas(problem: Problem, spec: SubproblemSpec,
                    a_parts: jax.Array, x_parts: jax.Array,
                    grads: jax.Array, gp_parts: jax.Array,
                    masks: jax.Array, num_steps: int, *,
                    interpret: bool = True,
                    gram_parts: jax.Array | None = None,
                    cd_mode: str = "residual") -> jax.Array:
    """Same signature/semantics as ``cd_solve_all`` but on the Pallas kernel.

    ``cd_mode``: "residual" (default, the O(d)-per-step kernel), "gram"
    (force the O(n_k)-per-step Gram-cached kernel) or "auto" (pick by
    ``subproblem.gram_pays``). ``gram_parts`` may pass precomputed Gram
    blocks (e.g. ``ColaEnv.gram_parts``); otherwise they are built on the
    fly when the Gram kernel is selected.
    """
    l1, l2, box = problem.prox_spec
    k, d, n_k = a_parts.shape
    use_gram = (cd_mode == "gram"
                or (cd_mode == "auto"
                    and gram_pays(d, n_k, a_parts.dtype.itemsize)))
    if use_gram:
        if gram_parts is None:
            gram_parts = jnp.einsum("kdn,kdm->knm", a_parts, a_parts)
        atg = jnp.einsum("kdn,kd->kn", a_parts, grads)
        return cd_glm.cd_solve_blocks_gram(
            gram_parts, x_parts, atg, gp_parts, masks,
            num_steps=num_steps, sigma_over_tau=float(spec.sigma_over_tau),
            l1=float(l1), l2=float(l2), box=float(box), interpret=interpret)
    return cd_glm.cd_solve_blocks(
        a_parts, x_parts, grads, gp_parts, masks,
        num_steps=num_steps, sigma_over_tau=float(spec.sigma_over_tau),
        l1=float(l1), l2=float(l2), box=float(box), interpret=interpret)


def flash_attention_ops(q, k, v, q_pos, kv_pos, *, mode: str,
                        window: int = 0, block_q: int = 128,
                        block_kv: int = 128, interpret: bool = True):
    """Drop-in for chunked_attention (same argument convention)."""
    return fa.flash_attention(q, k, v, q_pos, kv_pos, mode=mode,
                              window=window, block_q=block_q,
                              block_kv=block_kv, interpret=interpret)
