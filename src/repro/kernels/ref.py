"""Pure-jnp oracles for the Pallas kernels (the allclose targets in tests).

* CD-GLM subproblem solver: ``repro.core.subproblem.cd_solve_all`` — the
  vmapped cyclic coordinate-descent reference.
* Flash attention: ``repro.models.attention.reference_attention`` — the naive
  O(Sq*Skv) softmax attention with explicit position masking.
"""
from repro.core.subproblem import (  # noqa: F401
    block_gram,
    cd_solve_all as cd_solve_ref,
    cd_solve_gram as cd_solve_gram_ref,
    gram_pays,
)
from repro.models.attention import (  # noqa: F401
    chunked_attention as chunked_attention_ref,
    reference_attention as attention_ref,
)
