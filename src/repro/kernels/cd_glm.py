"""Pallas TPU kernel for the CoLA local subproblem solver (paper Eq. 1-2).

The paper's wall-clock is dominated by the Theta-approximate local solve
(Fig. 1b communication/computation trade-off). On TPU we keep the whole
node-local working set in VMEM for all ``kappa * n_k`` coordinate updates:

  * the node's column block A_[k]  (d x n_k tile),
  * the residual  r = A_[k] dx     (d,),
  * the iterate block dx           (n_k,),

so a full CD pass costs exactly one HBM read of A_[k] (at tile load) and no
HBM traffic inside the loop — the adaptation of the paper's "computation
between communication rounds" model to the TPU memory hierarchy (DESIGN.md
§3.3). Each grid program owns one node k (grid = (K,)); the sequential
coordinate recurrence runs as a ``fori_loop`` whose carries (dx, r) the
compiler keeps in VMEM/VREGs.

The separable prox is the generalized elastic-net family

    prox(z) = clip( soft(z - step*lin_i, step*l1) / (1 + step*l2), +-box )

which covers every ``repro.core.problems`` instance (l2 / l1+box / elastic
net / ridge-dual-with-linear-term); ``ops.py`` maps a Problem to its
(l1, l2, box) scalars + per-coordinate ``lin`` vector, and ``ref.py`` is the
pure-jnp oracle (``cd_solve_all``).

Two kernel variants share the prox (see ``repro.core.subproblem`` for the
cost model):

* ``_cd_kernel`` — residual formulation: VMEM holds the (d, n_k) column
  block; each coordinate step does an O(d) column dot + O(d) rank-1
  residual update.
* ``_cd_kernel_gram`` — Gram-cached: VMEM holds the (n_k, n_k) Gram block
  ``A_[k]^T A_[k]`` and the precomputed ``c = A_[k]^T grad``; each step
  maintains ``h = G dx`` with one O(n_k) column axpy. Preferred by
  ``repro.core.subproblem.gram_pays`` when n_k < d and the Gram block fits
  the VMEM budget; otherwise the residual kernel runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _cd_kernel(a_ref, x_ref, grad_ref, lin_ref, mask_ref, dx_ref, *,
               num_steps: int, sigma_over_tau: float, l1: float, l2: float,
               box: float):
    a = a_ref[0]          # (d, n_k) — the node's column block, in VMEM
    x = x_ref[0]          # (n_k,)
    grad = grad_ref[0]    # (d,)
    lin = lin_ref[0]      # (n_k,) linear term of g_i (ridge-dual labels)
    mask = mask_ref[0]    # (n_k,) 1 = real coordinate, 0 = padding

    n_k = a.shape[1]
    col_sq = jnp.sum(a * a, axis=0)                   # ||A_i||^2
    q = sigma_over_tau * col_sq
    q_safe = jnp.where(q > 0, q, 1.0)

    def coord_step(step_i, carry):
        dx, r = carry
        i = step_i % n_k                              # cyclic pass order
        a_i = lax.dynamic_slice_in_dim(a, i, 1, axis=1)[:, 0]
        z = x[i] + dx[i]
        grad_i = jnp.dot(a_i, grad + sigma_over_tau * r)
        step = 1.0 / q_safe[i]
        u = z - grad_i * step - step * lin[i]
        soft = jnp.sign(u) * jnp.maximum(jnp.abs(u) - step * l1, 0.0)
        z_new = jnp.clip(soft / (1.0 + step * l2), -box, box)
        delta = jnp.where((q[i] > 0) & (mask[i] > 0), z_new - z, 0.0)
        return dx.at[i].add(delta), r + a_i * delta

    dx0 = jnp.zeros_like(x)
    r0 = jnp.zeros_like(grad)
    dx, _ = lax.fori_loop(0, num_steps, coord_step, (dx0, r0))
    dx_ref[0] = dx


def _cd_kernel_gram(gram_ref, x_ref, atg_ref, lin_ref, mask_ref, dx_ref, *,
                    num_steps: int, sigma_over_tau: float, l1: float,
                    l2: float, box: float):
    gram = gram_ref[0]    # (n_k, n_k) — the node's Gram block, in VMEM
    x = x_ref[0]          # (n_k,)
    atg = atg_ref[0]      # (n_k,) A_[k]^T grad_f(v_k), precomputed per round
    lin = lin_ref[0]      # (n_k,) linear term of g_i (ridge-dual labels)
    mask = mask_ref[0]    # (n_k,) 1 = real coordinate, 0 = padding

    n_k = gram.shape[0]
    # diag(G) = ||A_i||^2, via an iota mask (TPU-safe diagonal extraction)
    rows = lax.broadcasted_iota(jnp.int32, (n_k, n_k), 0)
    cols = lax.broadcasted_iota(jnp.int32, (n_k, n_k), 1)
    col_sq = jnp.sum(jnp.where(rows == cols, gram, 0.0), axis=0)
    q = sigma_over_tau * col_sq
    q_safe = jnp.where(q > 0, q, 1.0)

    def coord_step(step_i, carry):
        dx, h = carry                                 # h = G dx
        i = step_i % n_k                              # cyclic pass order
        g_col = lax.dynamic_slice_in_dim(gram, i, 1, axis=1)[:, 0]
        z = x[i] + dx[i]
        grad_i = atg[i] + sigma_over_tau * h[i]
        step = 1.0 / q_safe[i]
        u = z - grad_i * step - step * lin[i]
        soft = jnp.sign(u) * jnp.maximum(jnp.abs(u) - step * l1, 0.0)
        z_new = jnp.clip(soft / (1.0 + step * l2), -box, box)
        delta = jnp.where((q[i] > 0) & (mask[i] > 0), z_new - z, 0.0)
        return dx.at[i].add(delta), h + g_col * delta

    dx0 = jnp.zeros_like(x)
    h0 = jnp.zeros_like(x)
    dx, _ = lax.fori_loop(0, num_steps, coord_step, (dx0, h0))
    dx_ref[0] = dx


@functools.partial(jax.jit, static_argnames=(
    "num_steps", "sigma_over_tau", "l1", "l2", "box", "interpret"))
def cd_solve_blocks_gram(gram_parts: jax.Array, x_parts: jax.Array,
                         atg_parts: jax.Array, lin_parts: jax.Array,
                         masks: jax.Array, *, num_steps: int,
                         sigma_over_tau: float, l1: float, l2: float,
                         box: float, interpret: bool = True) -> jax.Array:
    """Gram-cached variant of ``cd_solve_blocks``; one grid program per node.

    Args:
      gram_parts: (K, n_k, n_k) node-local Gram blocks A_[k]^T A_[k].
      atg_parts: (K, n_k) per-node A_[k]^T grad_f(v_k).
      x_parts/lin_parts/masks: (K, n_k).

    Returns dx_parts: (K, n_k).
    """
    k, n_k, _ = gram_parts.shape
    kernel = functools.partial(
        _cd_kernel_gram, num_steps=num_steps, sigma_over_tau=sigma_over_tau,
        l1=l1, l2=l2, box=box)
    return pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, n_k, n_k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n_k), lambda i: (i, 0)),
            pl.BlockSpec((1, n_k), lambda i: (i, 0)),
            pl.BlockSpec((1, n_k), lambda i: (i, 0)),
            pl.BlockSpec((1, n_k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, n_k), x_parts.dtype),
        interpret=interpret,
    )(gram_parts, x_parts, atg_parts, lin_parts, masks)


@functools.partial(jax.jit, static_argnames=(
    "num_steps", "sigma_over_tau", "l1", "l2", "box", "interpret"))
def cd_solve_blocks(a_parts: jax.Array, x_parts: jax.Array,
                    grads: jax.Array, lin_parts: jax.Array,
                    masks: jax.Array, *, num_steps: int,
                    sigma_over_tau: float, l1: float, l2: float,
                    box: float, interpret: bool = True) -> jax.Array:
    """Solve all K node subproblems; one grid program per node.

    Args:
      a_parts: (K, d, n_k); x_parts/lin_parts/masks: (K, n_k); grads: (K, d).
      num_steps: total coordinate updates per node (kappa * n_k).
      sigma_over_tau / l1 / l2 / box: subproblem + prox scalars.

    Returns dx_parts: (K, n_k).
    """
    k, d, n_k = a_parts.shape
    kernel = functools.partial(
        _cd_kernel, num_steps=num_steps, sigma_over_tau=sigma_over_tau,
        l1=l1, l2=l2, box=box)
    return pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, d, n_k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n_k), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, n_k), lambda i: (i, 0)),
            pl.BlockSpec((1, n_k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, n_k), x_parts.dtype),
        interpret=interpret,
    )(a_parts, x_parts, grads, lin_parts, masks)
