# Pallas TPU kernels for the perf-critical compute layers, each with a jit'd
# wrapper (ops.py) and a pure-jnp oracle (ref.py):
#   * flash_attention — GQA flash attention with positional masking (all
#     four attention variants of the zoo, ring-buffer caches included)
#   * cd_glm — the CoLA local-subproblem block coordinate-descent solver
#     (the paper's compute hotspot), whole node block resident in VMEM
from repro.kernels.cd_glm import cd_solve_blocks  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.ops import cd_solve_pallas, flash_attention_ops  # noqa: F401
