"""Sharded LM token pipeline over the synthetic corpus.

Deterministic, stateless batch addressing: batch ``i`` is a pure function of
(seed, i), so data-parallel shards and gossip nodes can each draw their own
disjoint stream without coordination — and a restarted job resumes exactly
(the CoLA elasticity argument applied to the input pipeline).
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import token_stream


class TokenBatches:
    """Batches of (tokens, labels) windows from a synthetic corpus."""

    def __init__(self, vocab_size: int, batch: int, seq: int, *,
                 corpus_tokens: int = 1 << 18, seed: int = 0):
        self.corpus = token_stream(corpus_tokens, vocab_size, seed=seed)
        self.batch, self.seq = batch, seq
        self.rng_seed = seed

    def __call__(self, step: int, shard: int = 0) -> dict:
        rng = np.random.default_rng(
            (self.rng_seed, step, shard))  # stateless addressing
        starts = rng.integers(0, len(self.corpus) - self.seq - 1,
                              size=self.batch)
        idx = starts[:, None] + np.arange(self.seq + 1)[None, :]
        window = self.corpus[idx]
        return {"tokens": window[:, :-1].astype(np.int32),
                "labels": window[:, 1:].astype(np.int32)}
