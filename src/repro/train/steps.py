"""Training and serving step functions for every architecture in the zoo.

``make_train_step`` builds the canonical data-parallel step: forward (+MoE
aux loss), backward, global-norm clip, AdamW. ``make_prefill_step`` /
``make_decode_step`` build the serving steps the inference shapes lower.

All steps are pure jittable functions of (state/params, batch) so the launch
layer can wrap them in ``jax.jit`` with explicit in/out shardings — both for
real execution and for the multi-pod dry-run (AOT ``.lower().compile()``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.models.blocks import DEFAULT_CTX, ModelCtx
from repro.models.common import softmax_cross_entropy
from repro.optim.optimizers import Optimizer, adamw, clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    lr: float = 3e-4
    grad_clip: float = 1.0
    aux_weight: float = 0.01     # MoE load-balance loss weight
    weight_decay: float = 0.01
    state_dtype: str = "float32"  # optimizer moment dtype
    microbatches: int = 1        # gradient-accumulation chunks per step


def init_train_state(cfg: ModelConfig, key: jax.Array,
                     hp: TrainHParams = TrainHParams()) -> TrainState:
    params = transformer.init_params(cfg, key)
    opt = _optimizer(hp)
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def _optimizer(hp: TrainHParams) -> Optimizer:
    return adamw(weight_decay=hp.weight_decay,
                 state_dtype=jnp.dtype(hp.state_dtype))


def loss_fn(cfg: ModelConfig, params: Any, batch: dict, ctx: ModelCtx,
            aux_weight: float):
    """Next-token cross entropy (text positions only) + MoE aux loss."""
    logits, aux = transformer.forward(cfg, params, batch, ctx)
    tokens = batch["tokens"]
    # VLM prepends patch positions: score only the text suffix.
    logits = logits[:, -tokens.shape[1]:]
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    mask = jnp.ones(labels.shape, dtype=jnp.float32).at[:, -1].set(0.0) \
        if "labels" not in batch else None
    ce = softmax_cross_entropy(logits, labels, mask)
    return ce + aux_weight * aux, (ce, aux)


def make_train_step(cfg: ModelConfig, hp: TrainHParams = TrainHParams(),
                    ctx: ModelCtx = DEFAULT_CTX) -> Callable:
    """(state, batch) -> (state, metrics) — the canonical all-reduce DP step."""
    opt = _optimizer(hp)

    def train_step(state: TrainState, batch: dict):
        grad_fn = jax.value_and_grad(
            partial(loss_fn, cfg), has_aux=True)
        if hp.microbatches > 1:
            # gradient accumulation: scan over microbatch chunks so peak
            # activation memory scales with B / microbatches
            m = hp.microbatches
            micro = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                batch)

            def acc_body(carry, mb):
                g_acc, l_acc, ce_acc, aux_acc = carry
                (l, (ce, aux)), g = grad_fn(state.params, mb, ctx,
                                            hp.aux_weight)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l, ce_acc + ce, aux_acc + aux), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss, ce, aux), _ = jax.lax.scan(
                acc_body, (zeros, 0.0, 0.0, 0.0), micro)
            grads = jax.tree.map(lambda g: (g / m), grads)
            loss, ce, aux = loss / m, ce / m, aux / m
        else:
            (loss, (ce, aux)), grads = grad_fn(state.params, batch, ctx,
                                               hp.aux_weight)
        grads, gnorm = clip_by_global_norm(grads, hp.grad_clip)
        params, opt_state = opt.update(grads, state.opt_state, state.params,
                                       state.step, hp.lr)
        metrics = {"loss": loss, "ce": ce, "aux": aux, "grad_norm": gnorm}
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig,
                      ctx: ModelCtx = DEFAULT_CTX) -> Callable:
    """(params, batch, cache) -> (last_logits, cache)."""
    def prefill_step(params, batch, cache):
        return transformer.prefill(cfg, params, batch, cache, ctx)
    return prefill_step


def make_decode_step(cfg: ModelConfig,
                     ctx: ModelCtx = DEFAULT_CTX) -> Callable:
    """(params, tokens (B,1), t, cache[, enc_kv, enc_pos]) -> (logits, cache).

    This is the step the decode_32k / long_500k shapes lower: ONE new token
    against a cache of seq_len (ring-buffered to the window for SWA/chunked
    variants, O(1) recurrent state for SSM/hybrid).
    """
    def decode_step(params, tokens, t, cache, **kw):
        return transformer.decode_step(cfg, params, tokens, t, cache,
                                       ctx=ctx, **kw)
    return decode_step


def greedy_generate(cfg: ModelConfig, params, prompt: jax.Array,
                    num_steps: int, max_len: int,
                    ctx: ModelCtx = DEFAULT_CTX):
    """Host-side reference generation loop (examples/tests)."""
    b, s = prompt.shape
    cache = transformer.init_cache(cfg, params, b, max_len)
    kw = {}
    logits, cache = transformer.prefill(cfg, params, {"tokens": prompt},
                                        cache, ctx)
    out = [jnp.argmax(logits[:, -1], axis=-1)]
    for i in range(num_steps - 1):
        tok = out[-1][:, None]
        logits, cache = transformer.decode_step(
            cfg, params, tok, jnp.asarray(s + i, jnp.int32), cache, ctx=ctx,
            **kw)
        out.append(jnp.argmax(logits[:, -1], axis=-1))
    return jnp.stack(out, axis=1)
