"""Dependency-free pytree checkpointing (npz, path-keyed).

Leaves are stored under their ``jax.tree_util.keystr`` path, so restore is
order-independent and validates structure against a reference pytree.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in leaves}


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)


def restore(path: str, like: Any) -> Any:
    """Load a checkpoint into the structure (and dtypes) of ``like``."""
    with np.load(path) as data:
        stored = dict(data)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for key_path, leaf in leaves:
        key = jax.tree_util.keystr(key_path)
        if key not in stored:
            raise KeyError(f"checkpoint {path} is missing leaf {key}")
        arr = stored[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
