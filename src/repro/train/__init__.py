from repro.train.steps import (  # noqa: F401
    TrainState,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    init_train_state,
)
