"""Eavesdropper auditing: what does a passive link observer learn?

Pasquini et al. (PAPERS.md) show decentralized gossip leaks MORE than
federated averaging: every payload v_k a node emits is an estimate of the
global consensus A x, so a single tapped link reconstructs the shared state
— and through grad f(v) the data-dependent residual — without compromising
any node. These helpers quantify that leakage from the ``RunResult.taps``
trajectory an ``Eavesdropper`` scenario records.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def payload_cosines(taps, reference) -> np.ndarray:
    """(T, n_tap) cosine similarity of each tapped payload to ``reference``
    (d,) — e.g. the true consensus A x* — per round. 0 rows (the zero
    initial state) map to cosine 0."""
    taps = np.asarray(taps, dtype=np.float64)
    ref = np.asarray(reference, dtype=np.float64)
    num = taps @ ref
    den = np.linalg.norm(taps, axis=-1) * np.linalg.norm(ref) + 1e-30
    return num / den


def gradient_inversion_report(taps, problem, reference) -> dict:
    """Audit a tap trajectory for state/gradient reconstruction leakage.

    Args:
      taps: (T, n_tap, d) recorded payloads (``RunResult.taps``).
      problem: the problem whose ``grad_f`` maps payloads to the
        data-dependent gradient (the inversion target: for quadratic losses
        grad f(v) exposes the residual v - y, i.e. the labels).
      reference: (d,) ground-truth consensus to compare against (A x at the
        solution, or the final honest v).

    Returns a dict:
      ``state_cosine``      (T, n_tap) payload-vs-reference cosine per round;
      ``final_state_cosine``  scalar mean over taps at the last round;
      ``grad_cosine``       (n_tap,) cosine of grad f(tap_T) vs
                            grad f(reference) — gradient-inversion fidelity;
      ``payload_norm``      (T,) mean tapped payload norm (attack visibility).
    """
    taps = np.asarray(taps)
    if taps.ndim != 3:
        raise ValueError(f"taps must be (T, n_tap, d); got {taps.shape}")
    ref = np.asarray(reference)
    state_cos = payload_cosines(taps, ref)
    g_ref = np.asarray(problem.grad_f(jnp.asarray(ref)), dtype=np.float64)
    g_tap = np.asarray(jax.vmap(problem.grad_f)(jnp.asarray(taps[-1])),
                       dtype=np.float64)
    num = g_tap @ g_ref
    den = np.linalg.norm(g_tap, axis=-1) * np.linalg.norm(g_ref) + 1e-30
    return {
        "state_cosine": state_cos,
        "final_state_cosine": float(state_cos[-1].mean()),
        "grad_cosine": num / den,
        "payload_norm": np.linalg.norm(taps, axis=-1).mean(axis=-1),
    }
