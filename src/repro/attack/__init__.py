"""repro.attack — adversarial attack scenarios as schedule transforms.

Attack scenarios are reusable transforms over the executor's pre-materialized
``(T, ...)`` schedules (see ``repro.core.executor``): a scenario rewrites or
adds schedule entries that the round body consumes, so the SAME attack
definition drives the single-host simulator (``run_cola(attacks=...)``) and
the shard_map distributed runtime (``run_dist_cola(attacks=...)``) with
bitwise-identical corruption — and composes freely with churn / budget
schedules, which are materialized first.

Defenses live in the mixing layer (``ColaConfig(robust=...)`` →
``repro.core.mixing.robust_neighborhood_mix``); detection lives in the
certificate layer (``certificate_violated`` via the Lemma-1 consensus
residual, ``repro.core.duality.consensus_residual``). The threat model:
attacks corrupt the DATA PLANE (payloads, links, work); the recorder /
certificate layer is trusted telemetry.
"""
from repro.attack.audit import gradient_inversion_report, payload_cosines
from repro.attack.scenarios import (ATTACK_ENTRY_NAMES, AttackContext,
                                    AttackInfo, Byzantine, Eavesdropper,
                                    FreeRider, LinkCorruption, SCENARIOS,
                                    apply_attacks, register_scenario,
                                    scenario, streamed_attacks)

__all__ = [
    "ATTACK_ENTRY_NAMES", "AttackContext", "AttackInfo", "Byzantine",
    "Eavesdropper", "FreeRider", "LinkCorruption", "SCENARIOS",
    "apply_attacks", "register_scenario", "scenario", "streamed_attacks",
    "gradient_inversion_report", "payload_cosines",
]
