"""Attack scenarios as pre-materialized schedule transforms.

Each scenario is a frozen dataclass with ``apply(sched, ctx)`` mutating the
driver's materialized schedule dict in place:

* ``Byzantine``     — nodes emit sign-flipped / scaled / random ``v_k``
                      payloads on a round window. Writes the per-node payload
                      transform entries ``atk_coef`` (T, K) and — for random
                      payloads — ``atk_bias_coef`` (T, K) + ``atk_bias``
                      (T, K, d) that the round body applies to the OUTGOING
                      ``v`` before the gossip mix. The attacker is
                      two-faced: the lie exists only on the wire — receivers
                      consume it, while the liar's own mixing term and
                      internal state evolve honestly (so a working defense
                      recovers near-clean dynamics and the certificate can
                      stay sound).
* ``FreeRider``     — nodes do no local work (``atk_work`` (T, K) zeroes
                      their dx); with ``stale=True`` they also emit their
                      initial (zero) state instead of fresh progress.
* ``LinkCorruption``— per-(src, dst) directed-edge payload scaling: rewrites
                      the materialized W stack itself, so the corruption
                      flows identically through the dense mix, the per-node
                      ``PlanSchedule`` coefficients and the block
                      ``BlockPlanSchedule`` rows (all derive from ``w``).
* ``Eavesdropper``  — a passive tap: the simulator records the tapped nodes'
                      emitted payloads each round into ``RunResult.taps``
                      (T, n_tap, d) for gradient-inversion auditing
                      (``repro.attack.audit``). Simulator-only.

Scenarios registered in ``SCENARIOS`` can be constructed by name via
``scenario("byzantine", ...)``. ``apply_attacks`` runs a list of scenarios
left to right (later scenarios overwrite overlapping node/round windows of
the same entry) and returns the transform summary the drivers need:
which entries exist, the tap nodes, whether W was touched (the dist plan
scheduler must then materialize per-round coefficients), and a hashable
token for compiled-driver cache keys.

Streaming: ``Byzantine`` and ``FreeRider`` are GENERATIVE — their round-t
transform row is a pure function of ``t`` — so they also expose
``stream_entries(ctx)``, a jax generator evaluated inside the round-block
scan (``streamed_attacks`` composes a scenario list into one generator for
``executor.run_round_blocks(stream=...)``), deriving the same values
``apply`` would have stacked without any (T, K) materialization.
``LinkCorruption`` (rewrites materialized W state) and ``Eavesdropper``
(records trajectories) have no generative form and stay stacked-only.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

# payload-transform schedule entries the round body may consume; prefixed
# "atk_" in the schedule dict. "dishonest" is derived, not consumed by the
# round body: the (T, K) ground-truth mask of nodes whose wire payload
# differs from their true state that round. The certificate recorder reads
# it (``metrics.attackify``) to audit the HONEST COHORT — the harness knows
# what it injected, the defense never sees it.
ATTACK_ENTRY_NAMES = ("coef", "bias_coef", "bias", "work", "dishonest")

SCENARIOS: dict = {}


def register_scenario(name: str):
    """Class decorator: make the scenario constructible by name."""
    def deco(cls):
        SCENARIOS[name] = cls
        return cls
    return deco


def scenario(name: str, **kwargs):
    """Construct a registered scenario by name (the string-keyed API the
    benchmarks/CLI use): ``scenario("byzantine", nodes=(3, 11))``."""
    if name not in SCENARIOS:
        raise ValueError(f"unknown attack scenario {name!r} "
                         f"(registered: {sorted(SCENARIOS)})")
    return SCENARIOS[name](**kwargs)


@dataclasses.dataclass(frozen=True)
class AttackContext:
    """Run facts a scenario may need to materialize its entries."""

    graph: Any          # repro.core.topology.Topology
    rounds: int
    k: int
    d: int
    dtype: Any
    seed: int = 0


@dataclasses.dataclass
class AttackInfo:
    """What ``apply_attacks`` did — consumed by the drivers."""

    token: tuple              # hashable summary for compiled-driver cache keys
    entry_names: tuple        # subset of ATTACK_ENTRY_NAMES present in sched
    tap_nodes: tuple          # eavesdropper node ids (sim-only)
    w_modified: bool          # LinkCorruption rewrote the W stack


def _window(start: int, stop: int | None, rounds: int) -> slice:
    stop = rounds if stop is None else min(stop, rounds)
    if not (0 <= start <= stop):
        raise ValueError(f"bad attack round window [{start}, {stop})")
    return slice(start, stop)


def _ensure_entry(sched: dict, name: str, ctx: AttackContext,
                  fill: float) -> np.ndarray:
    """Materialize a writable (T, K) attack entry, defaulting to ``fill``."""
    key = "atk_" + name
    if key not in sched:
        sched[key] = np.full((ctx.rounds, ctx.k), fill, dtype=ctx.dtype)
    return sched[key]


def _resolve_nodes(nodes, fraction, ctx: AttackContext, seed: int) -> tuple:
    if nodes is not None:
        nodes = tuple(int(n) for n in nodes)
    elif fraction is not None:
        count = max(1, int(round(fraction * ctx.k)))
        rng = np.random.default_rng(seed)
        nodes = tuple(int(n) for n in
                      sorted(rng.choice(ctx.k, size=count, replace=False)))
    else:
        raise ValueError("need nodes= or fraction=")
    if any(n < 0 or n >= ctx.k for n in nodes):
        raise ValueError(f"attack nodes {nodes} out of range for K={ctx.k}")
    return nodes


@register_scenario("byzantine")
@dataclasses.dataclass(frozen=True)
class Byzantine:
    """Nodes emit corrupted v_k payloads: ``v_send = coef * v + bias``.

    mode="sign_flip": coef = -scale (the canonical poisoning attack — the
    emitted estimate points away from the node's actual state);
    mode="scale":     coef = scale (inflate/deflate);
    mode="random":    coef = 0, bias = scale * a run-constant standard-normal
                      direction per node (drawn from ``seed``).

    The lie is wire-only (a two-faced attacker): neighbors receive
    ``v_send`` while the liar's own mixing term and subsequent local solve
    use its honest state — the strongest stealthy variant, since the
    attacker's internal bookkeeping stays self-consistent.
    """

    nodes: tuple | None = None
    fraction: float | None = None
    mode: str = "sign_flip"
    scale: float = 1.0
    start: int = 0
    stop: int | None = None
    seed: int = 0

    def apply(self, sched: dict, ctx: AttackContext) -> None:
        if self.mode not in ("sign_flip", "scale", "random"):
            raise ValueError(f"unknown Byzantine mode {self.mode!r}")
        nodes = list(_resolve_nodes(self.nodes, self.fraction, ctx,
                                    self.seed))
        rows = _window(self.start, self.stop, ctx.rounds)
        coef = _ensure_entry(sched, "coef", ctx, 1.0)
        if self.mode == "sign_flip":
            coef[rows, nodes] = -self.scale
        elif self.mode == "scale":
            coef[rows, nodes] = self.scale
        else:  # random payload: drop the state, emit a fixed random vector
            coef[rows, nodes] = 0.0
            bias_coef = _ensure_entry(sched, "bias_coef", ctx, 0.0)
            bias_coef[rows, nodes] = self.scale
            # run-constant per-node directions; (T, K, d) broadcast view
            # keeps the schedule O(K d) in host memory
            if "atk_bias" in sched:
                base = np.array(sched["atk_bias"][0])
            else:
                base = np.zeros((ctx.k, ctx.d), dtype=ctx.dtype)
            rng = np.random.default_rng(self.seed)
            base[nodes] = rng.standard_normal(
                (len(nodes), ctx.d)).astype(ctx.dtype)
            sched["atk_bias"] = np.broadcast_to(base,
                                                (ctx.rounds,) + base.shape)

    def stream_entry_names(self) -> tuple:
        return ("coef", "bias_coef", "bias") if self.mode == "random" \
            else ("coef",)

    def stream_entries(self, ctx: AttackContext):
        """Generative twin of ``apply``: a pure-jax ``fn(t, entries) ->
        entries`` deriving this round's transform row from ``t`` alone (the
        window test is a traced comparison, the node set and random
        directions are run constants), chaining left to right like the
        stacked path overwrites."""
        import jax.numpy as jnp

        if self.mode not in ("sign_flip", "scale", "random"):
            raise ValueError(f"unknown Byzantine mode {self.mode!r}")
        nodes = list(_resolve_nodes(self.nodes, self.fraction, ctx,
                                    self.seed))
        rows = _window(self.start, self.stop, ctx.rounds)
        lo, hi = rows.start, rows.stop
        hit_nodes = np.zeros((ctx.k,), dtype=bool)
        hit_nodes[nodes] = True
        nm = jnp.asarray(hit_nodes)
        k, dtype, scale = ctx.k, ctx.dtype, self.scale
        if self.mode == "random":
            base = np.zeros((ctx.k, ctx.d), dtype=ctx.dtype)
            rng = np.random.default_rng(self.seed)
            base[nodes] = rng.standard_normal(
                (len(nodes), ctx.d)).astype(ctx.dtype)
            base_j = jnp.asarray(base)

        def gen(t, entries):
            hit = jnp.where((t >= lo) & (t < hi), nm, False)
            coef = entries.get("atk_coef", jnp.ones((k,), dtype))
            if self.mode == "sign_flip":
                coef = jnp.where(hit, -scale, coef)
            elif self.mode == "scale":
                coef = jnp.where(hit, scale, coef)
            else:
                coef = jnp.where(hit, 0.0, coef)
                bc = entries.get("atk_bias_coef", jnp.zeros((k,), dtype))
                entries = {**entries,
                           "atk_bias_coef": jnp.where(hit, scale, bc),
                           # window-independent merge, like the stacked
                           # (T, K, d) broadcast of run-constant directions
                           "atk_bias": jnp.where(
                               nm[:, None], base_j,
                               entries.get("atk_bias",
                                           jnp.zeros_like(base_j)))}
            return {**entries, "atk_coef": coef}

        return gen


@register_scenario("free_rider")
@dataclasses.dataclass(frozen=True)
class FreeRider:
    """Nodes that stop doing local work: their dx is zeroed every attacked
    round (``atk_work``), so they ride their neighbors' progress. With
    ``stale=True`` they also emit their INITIAL (zero) state instead of the
    mixed estimate they carry — the under-churn "stale state" payload."""

    nodes: tuple
    start: int = 0
    stop: int | None = None
    stale: bool = False

    def apply(self, sched: dict, ctx: AttackContext) -> None:
        nodes = list(_resolve_nodes(self.nodes, None, ctx, 0))
        rows = _window(self.start, self.stop, ctx.rounds)
        work = _ensure_entry(sched, "work", ctx, 1.0)
        work[rows, nodes] = 0.0
        if self.stale:
            coef = _ensure_entry(sched, "coef", ctx, 1.0)
            coef[rows, nodes] = 0.0

    def stream_entry_names(self) -> tuple:
        return ("work", "coef") if self.stale else ("work",)

    def stream_entries(self, ctx: AttackContext):
        """Generative twin of ``apply`` (see ``Byzantine.stream_entries``)."""
        import jax.numpy as jnp

        nodes = list(_resolve_nodes(self.nodes, None, ctx, 0))
        rows = _window(self.start, self.stop, ctx.rounds)
        lo, hi = rows.start, rows.stop
        hit_nodes = np.zeros((ctx.k,), dtype=bool)
        hit_nodes[nodes] = True
        nm = jnp.asarray(hit_nodes)
        k, dtype, stale = ctx.k, ctx.dtype, self.stale

        def gen(t, entries):
            hit = jnp.where((t >= lo) & (t < hi), nm, False)
            work = entries.get("atk_work", jnp.ones((k,), dtype))
            entries = {**entries, "atk_work": jnp.where(hit, 0.0, work)}
            if stale:
                coef = entries.get("atk_coef", jnp.ones((k,), dtype))
                entries = {**entries, "atk_coef": jnp.where(hit, 0.0, coef)}
            return entries

        return gen


@register_scenario("link_corruption")
@dataclasses.dataclass(frozen=True)
class LinkCorruption:
    """Scale the payload crossing chosen DIRECTED edges (src -> dst):
    ``W[t, dst, src] *= scale`` in the materialized stack. scale=0 drops the
    link. The corruption flows through every comm path identically because
    the plan schedules derive from the same post-transform W; scaling stays
    inside the compiled plan's support, so coverage checks still pass."""

    edges: tuple                # ((src, dst), ...)
    scale: float = 0.0
    start: int = 0
    stop: int | None = None

    def apply(self, sched: dict, ctx: AttackContext) -> None:
        rows = _window(self.start, self.stop, ctx.rounds)
        # always copy: the no-churn stack is a read-only broadcast view, and
        # a churn stack may be shared — the identity change also tells
        # apply_attacks that W was rewritten
        w = np.array(sched["w"])
        for src, dst in self.edges:
            src, dst = int(src), int(dst)
            if not (0 <= src < ctx.k and 0 <= dst < ctx.k):
                raise ValueError(f"link ({src}, {dst}) out of range "
                                 f"for K={ctx.k}")
            if src == dst:
                raise ValueError("link corruption targets edges, not the "
                                 "self term — use Byzantine for payloads")
            w[rows, dst, src] = w[rows, dst, src] * self.scale
        sched["w"] = w


@register_scenario("eavesdropper")
@dataclasses.dataclass(frozen=True)
class Eavesdropper:
    """Passive link tap: record the tapped nodes' EMITTED payloads (after
    any Byzantine transform — what actually crosses the wire) each round.
    The simulator returns them as ``RunResult.taps`` (T, n_tap, d) for
    ``repro.attack.audit``; the distributed runtime rejects taps (recording
    full payload trajectories per round is a simulator-side analysis)."""

    nodes: tuple

    def apply(self, sched: dict, ctx: AttackContext) -> None:
        _resolve_nodes(self.nodes, None, ctx, 0)  # validate only


def apply_attacks(sched: dict, attacks, ctx: AttackContext
                  ) -> tuple[dict, AttackInfo]:
    """Run scenarios left to right over a materialized schedule.

    Returns the (possibly copied) schedule and an ``AttackInfo``. Drivers
    must fold ``info.token`` into their compiled-driver cache keys (attack
    entries change the traced step function) and — when ``info.w_modified``
    — materialize per-round plan coefficients instead of the static
    broadcast fast path.
    """
    if attacks is None:
        attacks = ()
    if not isinstance(attacks, (list, tuple)):
        attacks = (attacks,)
    sched = dict(sched)
    w_before = sched["w"]
    tap_nodes: list = []
    for atk in attacks:
        if not hasattr(atk, "apply"):
            raise TypeError(f"not an attack scenario: {atk!r} (want an "
                            "object with .apply(sched, ctx), e.g. from "
                            "repro.attack.scenario())")
        atk.apply(sched, ctx)
        if isinstance(atk, Eavesdropper):
            tap_nodes.extend(int(n) for n in atk.nodes)
    # ground truth for the cohort certificate: a node is dishonest on round
    # t iff its wire payload differs from its state (coef != 1 or a bias
    # injection) — the transform the round body will actually apply
    if "atk_coef" in sched or "atk_bias_coef" in sched:
        dis = np.zeros((ctx.rounds, ctx.k), dtype=bool)
        if "atk_coef" in sched:
            dis |= sched["atk_coef"] != 1.0
        if "atk_bias_coef" in sched:
            dis |= sched["atk_bias_coef"] != 0.0
        sched["atk_dishonest"] = dis.astype(ctx.dtype)
    entry_names = tuple(n for n in ATTACK_ENTRY_NAMES
                        if "atk_" + n in sched)
    info = AttackInfo(
        token=tuple(repr(a) for a in attacks),
        entry_names=entry_names,
        tap_nodes=tuple(dict.fromkeys(tap_nodes)),  # dedupe, keep order
        w_modified=sched["w"] is not w_before,
    )
    return sched, info


def streamed_attacks(attacks, ctx: AttackContext):
    """Compose a scenario list into ONE jax generator for the streaming
    executor: ``part(t) -> {"atk_*": row}`` deriving the round's transform
    entries inside the scan, bitwise the values ``apply_attacks`` would
    have stacked. Returns ``(part, info)`` where ``info`` is the same
    ``AttackInfo`` the stacked path yields (``w_modified`` always False —
    W-rewriting scenarios have no generative form and raise here).
    """
    import jax.numpy as jnp

    if attacks is None:
        attacks = ()
    if not isinstance(attacks, (list, tuple)):
        attacks = (attacks,)
    gens, names = [], set()
    for atk in attacks:
        if not hasattr(atk, "stream_entries"):
            raise NotImplementedError(
                f"{type(atk).__name__} has no streamable (generative) form "
                "— it rewrites or records materialized schedule state. Run "
                "it on the stacked-schedule path (no participation "
                "streaming).")
        gens.append(atk.stream_entries(ctx))
        names.update(atk.stream_entry_names())
    if {"coef", "bias_coef"} & names:
        names.add("dishonest")
    entry_names = tuple(n for n in ATTACK_ENTRY_NAMES if n in names)
    k, dtype = ctx.k, ctx.dtype

    def part(t):
        entries: dict = {}
        for g in gens:
            entries = g(t, entries)
        if "atk_coef" in entries or "atk_bias_coef" in entries:
            dis = jnp.zeros((k,), dtype=bool)
            if "atk_coef" in entries:
                dis = dis | (entries["atk_coef"] != 1.0)
            if "atk_bias_coef" in entries:
                dis = dis | (entries["atk_bias_coef"] != 0.0)
            entries = {**entries, "atk_dishonest": dis.astype(dtype)}
        return entries

    info = AttackInfo(
        token=tuple(repr(a) for a in attacks) + ("streamed",),
        entry_names=entry_names,
        tap_nodes=(),
        w_modified=False,
    )
    return part, info
