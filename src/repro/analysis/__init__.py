"""repro.analysis — static contract verification for compiled COLA programs.

The paper's claims are properties of *compiled artifacts*: the plan paths
never gather the (K, d) stack, certificates exchange O(d) bytes, the round
hot path is honest fp32, the block executor never re-traces a warmed
driver. The numeric test suite can't see any of that — a program that
silently all-gathers still converges. This package verifies the lowered
programs themselves, at three levels:

**1. Comm contracts** (``contracts``) — a ``CommContract`` declares the
collective budget a lowered program is allowed (forbidden kinds, ppermute
byte/count caps, psum allowances, gather floors); ``check_comm(program,
contract)`` holds the compiled HLO to it via the trip-count-aware
``launch.hlo_analysis.analyze``. Contracts come from the objects that know
their own budget — ``CommPlan.contract()`` / ``BlockPlan.contract()``
(backed by ``topo.lowering.comm_budget``, the single source of truth for
what the lowerings emit) — or from the helpers ``ring_contract`` /
``certificate_contract`` / ``gather_contract`` for plan-less paths. The
dist test files assert through this layer instead of inline HLO regexes.

**2. Jaxpr lint passes** (``passes``, registry ``PASS_REGISTRY``):

=======================  ==================================================
``dtype-drift``          every floating value in the jaxpr has the declared
                         compute dtype (catches weak-type f64 promotion and
                         lossy half-precision round-trips)
``host-callback-in-scan``  no ``debug_callback``/``pure_callback``/... in a
                         ``scan``/``while`` body (a host sync per round
                         defeats round-block dispatch amortization)
``constant-capture``     no closed-over array constant above a size
                         threshold baked into the executable
``donation``             every ``donate_argnums`` buffer is actually
                         aliased in the lowering (jax drops unusable
                         donations with only a warning)
``retrace``              a warmed-up run resolves every
                         ``executor.cached_driver`` probe as a hit
                         (``RetraceMonitor`` hooks the cache's listener
                         API; any miss = unstable cache key)
=======================  ==================================================

**3. Repo AST lints** (``astlint``, registry ``RULES``): ``frozen-transform``
(schedule transforms / registered attack scenarios must be frozen
dataclasses — they ride compiled-driver cache keys), ``id-in-cache-key``
(no ``id()``/``hash()`` in cache keys — addresses get recycled), and
``prng-reuse`` (a PRNG key consumed twice without a split/fold_in rebind).

**Drivers** (``drivers``, registry ``DRIVER_REGISTRY``) bind the levels to
every registered driver configuration — sim round blocks (plain and
robust), gossip-DP mixing, dist ring/plan/block/block-robust rounds, the
dense oracle, certificate recorders (ring and plan), the gap recorder, and
the block executor's retrace check. ``python -m repro.analysis --all``
runs them all plus the AST lints; ``--selftest`` runs the
seeded-violation fixtures (``selftest``) proving each pass fires.

Registration: ``@register_pass`` / ``@register_rule`` / ``@register_driver``
/ ``@register_selftest`` add entries to the respective registries; the CLI
enumerates registries, so a new pass or driver config is picked up without
touching ``__main__``.

This module imports lazily (``__getattr__``) so ``python -m
repro.analysis`` can pin ``XLA_FLAGS`` before anything touches jax.
"""
from __future__ import annotations

_EXPORTS = {
    # contracts
    "CommContract": "contracts",
    "CommContractViolation": "contracts",
    "check_comm": "contracts",
    "ring_contract": "contracts",
    "certificate_contract": "contracts",
    "gather_contract": "contracts",
    "FORBID_NEIGHBOR_ONLY": "contracts",
    # passes
    "Finding": "passes",
    "PASS_REGISTRY": "passes",
    "register_pass": "passes",
    "dtype_drift": "passes",
    "host_callback_in_scan": "passes",
    "constant_capture": "passes",
    "donation": "passes",
    "RetraceMonitor": "passes",
    "check_retrace": "passes",
    "run_jaxpr_passes": "passes",
    "walk_eqns": "passes",
    # astlint
    "RULES": "astlint",
    "register_rule": "astlint",
    "lint_source": "astlint",
    "lint_paths": "astlint",
    # drivers
    "DRIVER_REGISTRY": "drivers",
    "register_driver": "drivers",
    "SkipDriver": "drivers",
    # selftest
    "SELFTESTS": "selftest",
    "run_selftests": "selftest",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib
        mod = importlib.import_module(f"repro.analysis.{_EXPORTS[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
