"""Jaxpr lint passes over COLA driver programs.

Each pass walks a closed jaxpr (or a lowering) and returns a list of
``Finding``s — empty means the contract holds. Passes register under a name
with ``@register_pass`` so the CLI can enumerate them; they are plain
functions, so tests and drivers can also call them directly with
pass-specific keyword knobs.

The contracts (see ``repro.analysis.__init__`` for the full table):

* ``dtype-drift`` — the round hot path is HONEST fp32: every floating-point
  value in the jaxpr has the declared compute dtype. A weak-type promotion
  to f64 or a silent bf16/f16 round-trip both corrupt the certificate
  arithmetic without failing any numeric test until much later.
* ``host-callback-in-scan`` — no host callback primitive (``debug_callback``,
  ``pure_callback``, ``io_callback``, ...) inside a ``scan``/``while`` body:
  one forgotten ``jax.debug.print`` forces a host sync per round and
  destroys the block executor's dispatch amortization.
* ``constant-capture`` — no closed-over array above a size threshold baked
  into the program as a jaxpr constant: large constants bloat every cached
  executable and make ``executor.fingerprint`` hash the captured bytes on
  every cache probe.
* ``donation`` — every arg declared in ``donate_argnums`` is actually
  marked for aliasing in the lowering (``tf.aliasing_output`` /
  ``jax.buffer_donor``): a donated buffer that silently fails to alias
  doubles the state memory of long runs.
* ``retrace`` (``check_retrace`` / ``RetraceMonitor``) — a warmed-up run
  must resolve every ``executor.cached_driver`` probe as a hit: a miss on
  the second identical run means the cache key is unstable and every run
  pays trace+compile.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, List

import jax
import numpy as np
from jax import core as jcore

from repro.core import executor


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation: which pass, where, and what went wrong."""

    pass_name: str
    message: str
    where: str = ""

    def __str__(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.pass_name}{loc}: {self.message}"


PASS_REGISTRY: dict = {}


def register_pass(name: str) -> Callable:
    """Register a pass under ``name`` (listed by the CLI; see module
    docstring for the contract each built-in pass enforces)."""
    def deco(fn):
        PASS_REGISTRY[name] = fn
        fn.pass_name = name
        return fn
    return deco


# -- jaxpr walking ----------------------------------------------------------

def _sub_jaxprs(eqn) -> Iterator:
    for val in eqn.params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, jcore.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jcore.Jaxpr):
                yield v


def walk_eqns(jaxpr, path: tuple = ()) -> Iterator[tuple]:
    """Yield (eqn, path) over ``jaxpr`` and every nested sub-jaxpr, where
    ``path`` is the tuple of enclosing primitive names (scan, cond, ...)."""
    for eqn in jaxpr.eqns:
        yield eqn, path
        for sub in _sub_jaxprs(eqn):
            yield from walk_eqns(sub, path + (eqn.primitive.name,))


def _closed(jaxpr_or_fn, *args):
    if isinstance(jaxpr_or_fn, jcore.ClosedJaxpr):
        return jaxpr_or_fn
    return jax.make_jaxpr(jaxpr_or_fn)(*args)


# -- passes -----------------------------------------------------------------

@register_pass("dtype-drift")
def dtype_drift(closed: jcore.ClosedJaxpr, *, compute_dtype="float32",
                where: str = "") -> List[Finding]:
    """Flag every floating/complex value whose dtype differs from the
    declared compute dtype — weak-type f64 promotions, silent f32->f64
    upcasts and lossy bf16/f16 round-trips all surface here."""
    import jax.numpy as jnp
    compute = np.dtype(compute_dtype)
    out: List[Finding] = []
    seen: set = set()

    def check(aval, label, path):
        dt = getattr(aval, "dtype", None)
        if dt is None:
            return
        if not (jnp.issubdtype(dt, jnp.floating)
                or jnp.issubdtype(dt, jnp.complexfloating)):
            return
        if np.dtype(dt) == compute:
            return
        key = (label, str(dt), path)
        if key in seen:
            return
        seen.add(key)
        inside = "/".join(path) or "<top>"
        out.append(Finding(
            "dtype-drift",
            f"{label} has dtype {dt} (compute dtype is {compute}) "
            f"inside {inside}", where))

    for var in closed.jaxpr.invars:
        check(var.aval, "input", ())
    for eqn, path in walk_eqns(closed.jaxpr):
        for var in eqn.outvars:
            check(var.aval, f"{eqn.primitive.name} output", path)
    return out


_CALLBACK_PRIMS = frozenset({
    "debug_callback", "pure_callback", "io_callback", "callback",
    "outside_call", "host_callback_call", "debug_print"})
_LOOP_PRIMS = frozenset({"scan", "while"})


@register_pass("host-callback-in-scan")
def host_callback_in_scan(closed: jcore.ClosedJaxpr, *,
                          where: str = "") -> List[Finding]:
    """Flag host-callback primitives inside scan/while bodies — each one is
    a per-round host round-trip in the block executor."""
    out: List[Finding] = []
    for eqn, path in walk_eqns(closed.jaxpr):
        if eqn.primitive.name in _CALLBACK_PRIMS \
                and any(p in _LOOP_PRIMS for p in path):
            out.append(Finding(
                "host-callback-in-scan",
                f"{eqn.primitive.name} inside {'/'.join(path)}: a host "
                "sync every loop iteration defeats the round-block "
                "dispatch amortization", where))
    return out


@register_pass("constant-capture")
def constant_capture(closed: jcore.ClosedJaxpr, *,
                     max_bytes: int = 1 << 20,
                     where: str = "") -> List[Finding]:
    """Flag closed-over array constants above ``max_bytes`` — they belong in
    the executor's ``context`` argument, not baked into the executable."""
    out: List[Finding] = []
    for const in closed.consts:
        try:
            arr = np.asarray(const)
        except Exception:
            continue
        if arr.nbytes > max_bytes:
            out.append(Finding(
                "constant-capture",
                f"captured constant {arr.dtype}{list(arr.shape)} is "
                f"{arr.nbytes:,} bytes (> {max_bytes:,}): pass it as a jit "
                "argument (executor `context`) instead of closing over it",
                where))
    return out


_DONATION_MARKERS = ("tf.aliasing_output", "jax.buffer_donor")


@register_pass("donation")
def donation(fn: Callable, args: tuple, donate_argnums: tuple, *,
             where: str = "") -> List[Finding]:
    """Verify every leaf of the ``donate_argnums`` args is actually marked
    for input/output aliasing in the lowered program. jax drops donations
    it cannot match to an output (shape/dtype mismatch) with only a
    warning — here that is a contract violation."""
    lowered = jax.jit(fn, donate_argnums=donate_argnums).lower(*args)
    text = lowered.as_text()
    expected = sum(len(jax.tree.leaves(args[i])) for i in donate_argnums)
    marked = sum(text.count(m) for m in _DONATION_MARKERS)
    if marked < expected:
        return [Finding(
            "donation",
            f"{expected - marked} of {expected} donated buffers are not "
            "aliased in the lowering (no tf.aliasing_output/"
            "jax.buffer_donor marker): the donation silently fell off and "
            "the state is double-buffered", where)]
    return []


# -- retrace detection ------------------------------------------------------

class RetraceMonitor:
    """Record every ``executor.cached_driver`` resolution in a scope.

    Built on ``executor.cache_listener``, so nesting two monitors (or a
    monitor inside an ``obs.trace`` tracer) registers two independent
    callbacks and each exit removes exactly its own — no double counting,
    no leaked listener after an exception.

    >>> with RetraceMonitor() as mon:
    ...     run()
    >>> mon.misses   # (key, kind) events that re-built a driver
    """

    def __init__(self):
        self.events: list = []
        self._cm = None

    def __enter__(self):
        self._cm = executor.cache_listener(self._on)
        self._cm.__enter__()
        return self

    def __exit__(self, *exc):
        cm, self._cm = self._cm, None
        if cm is not None:
            cm.__exit__(*exc)
        return False

    def _on(self, key, kind: str) -> None:
        self.events.append((key, kind))

    @property
    def misses(self) -> list:
        return [e for e in self.events if e[1] != "hits"]


@register_pass("retrace")
def check_retrace(run_fn: Callable, *, warmups: int = 1,
                  where: str = "") -> List[Finding]:
    """Run ``run_fn`` ``warmups`` times to populate the driver cache, then
    once more under a ``RetraceMonitor``: any miss or bypass on the warmed
    run means the cache key is unstable (or caching is off) and every run
    re-traces."""
    for _ in range(warmups):
        run_fn()
    with RetraceMonitor() as mon:
        run_fn()
    out: List[Finding] = []
    for key, kind in mon.misses:
        what = ("cache bypass (cache_key=None)" if kind == "bypass"
                else f"cache miss on warmed key {key!r}")
        out.append(Finding(
            "retrace",
            f"{what}: the driver re-traced after an identical warm run — "
            "unstable cache key", where))
    return out


@register_pass("telemetry-carry")
def telemetry_carry(closed_off: jcore.ClosedJaxpr,
                    closed_on: jcore.ClosedJaxpr, *,
                    where: str = "") -> List[Finding]:
    """Verify telemetry counters ride the round scan's CARRY.

    Takes the telemetry-off and telemetry-on builds of one round-block
    program. The on-device ``obs.counters`` accumulate per round, so
    enabling telemetry must GROW the carry of (at least) the round scan;
    if no scan in the telemetry-on jaxpr carries more state than the
    largest scan of its off twin, the counters were captured as trace-time
    constants (computed outside the scan, or summed host-side from a baked
    array) and the recorded totals silently freeze at their trace values.
    """
    def max_carry(closed):
        carries = [eqn.params.get("num_carry", 0)
                   for eqn, _ in walk_eqns(closed.jaxpr)
                   if eqn.primitive.name == "scan"]
        return max(carries, default=None)

    off, on = max_carry(closed_off), max_carry(closed_on)
    if on is None:
        return [Finding(
            "telemetry-carry",
            "telemetry-on program contains no scan: counters cannot be "
            "carried per round at all", where)]
    if off is not None and on <= off:
        return [Finding(
            "telemetry-carry",
            f"telemetry-on round scan carries {on} values, no more than "
            f"the telemetry-off twin's {off}: the counters are captured "
            "as constants instead of accumulated in the scan carry",
            where)]
    return []


def run_jaxpr_passes(jaxpr_or_fn, *args, where: str = "",
                     compute_dtype="float32",
                     max_const_bytes: int = 1 << 20) -> List[Finding]:
    """All jaxpr-level passes (dtype-drift, host-callback-in-scan,
    constant-capture) over one program."""
    closed = _closed(jaxpr_or_fn, *args)
    return (dtype_drift(closed, compute_dtype=compute_dtype, where=where)
            + host_callback_in_scan(closed, where=where)
            + constant_capture(closed, max_bytes=max_const_bytes,
                               where=where))
