"""CLI: verify every registered COLA driver configuration.

``python -m repro.analysis --all``      AST lints + every registered driver
``python -m repro.analysis --selftest`` seeded violations must all be caught
``python -m repro.analysis --driver dist-plan``  one driver by name

Exit status 0 = every contract holds (and, under ``--selftest``, every
seeded violation was caught); 1 otherwise. XLA_FLAGS is pinned to an
8-virtual-device CPU mesh before jax loads, so the dist/block drivers
always lower for real meshes regardless of host hardware.
"""
import os

# must precede any jax import: the dist drivers lower for multi-device
# meshes, and xla reads this at backend init
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import pathlib
import sys
import traceback


def _src_root() -> pathlib.Path:
    # repro is a namespace package (no __file__); anchor on this module
    return pathlib.Path(__file__).resolve().parent.parent


def run_ast(paths=None) -> int:
    from repro.analysis import astlint
    paths = paths or [_src_root()]
    findings = astlint.lint_paths(paths)
    for f in findings:
        print(f"FAIL ast: {f}")
    print(f"ast-lint: {len(findings)} finding(s) over {len(paths)} root(s) "
          f"[{len(astlint.RULES)} rule(s)]")
    return len(findings)


def run_drivers(names=None) -> int:
    from repro.analysis import drivers
    names = names or sorted(drivers.DRIVER_REGISTRY)
    failures = 0
    for name in names:
        try:
            check = drivers.DRIVER_REGISTRY[name]
        except KeyError:
            print(f"FAIL {name}: unknown driver (have: "
                  f"{', '.join(sorted(drivers.DRIVER_REGISTRY))})")
            failures += 1
            continue
        try:
            findings = check()
        except drivers.SkipDriver as e:
            print(f"SKIP {name}: {e}")
            continue
        except Exception:
            print(f"FAIL {name}: driver crashed")
            traceback.print_exc()
            failures += 1
            continue
        if findings:
            failures += 1
            print(f"FAIL {name}: {len(findings)} finding(s)")
            for f in findings:
                print(f"  {f}")
        else:
            print(f"PASS {name}")
    return failures


def run_selftest() -> int:
    from repro.analysis import selftest
    missed = 0
    for name, caught, detail in selftest.run_selftests(skip_mesh=True):
        if caught is None:
            print(f"SKIP selftest {name}: {detail}")
        elif caught:
            first = detail.splitlines()[0]
            print(f"CAUGHT {name}: {first}")
        else:
            missed += 1
            print(f"MISSED {name}: {detail}")
    return missed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract verification for compiled COLA "
                    "programs (see repro.analysis.__doc__)")
    ap.add_argument("--all", action="store_true",
                    help="AST lints + every registered driver (default)")
    ap.add_argument("--ast", action="store_true", help="AST lints only")
    ap.add_argument("--driver", action="append", metavar="NAME",
                    help="run one registered driver (repeatable)")
    ap.add_argument("--selftest", action="store_true",
                    help="run the seeded-violation fixtures; fail unless "
                         "every one is caught")
    ap.add_argument("--list", action="store_true",
                    help="list registered drivers, passes and rules")
    args = ap.parse_args(argv)

    if args.list:
        from repro.analysis import astlint, drivers, passes, selftest
        print("drivers: " + ", ".join(sorted(drivers.DRIVER_REGISTRY)))
        print("passes:  " + ", ".join(sorted(passes.PASS_REGISTRY)))
        print("rules:   " + ", ".join(sorted(astlint.RULES)))
        print("selftests: " + ", ".join(sorted(selftest.SELFTESTS)))
        return 0

    failures = 0
    if args.selftest:
        failures += run_selftest()
    if args.ast and not args.all:
        failures += run_ast()
    if args.driver:
        failures += run_drivers(args.driver)
    if args.all or not (args.selftest or args.ast or args.driver):
        failures += run_ast()
        failures += run_drivers()
    print(f"repro.analysis: {'FAIL' if failures else 'OK'} "
          f"({failures} failing check(s))")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
