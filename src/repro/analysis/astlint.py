"""Repo-specific AST lints over the source tree.

Three rules, each encoding a correctness invariant the runtime relies on
but Python cannot enforce:

* ``frozen-transform`` — every attack-scenario / schedule transform (a
  class registered with ``@register_scenario`` or defining
  ``apply(self, sched, ctx)``) must be a ``@dataclasses.dataclass``
  with ``frozen=True``: transforms ride compiled-driver cache keys via
  field hashing, and a mutable transform could change after its key was
  computed.
* ``id-in-cache-key`` — no ``id()`` / ``hash()`` inside a ``cache_key=``
  argument, a ``cached_driver``/``fingerprint`` call, or a
  ``cache_token`` method body: an address-based key silently reuses a
  stale compiled driver when the allocator recycles the address (the
  exact bug PR 2 fixed — this rule keeps it fixed).
* ``prng-reuse`` — a PRNG key consumed by two ``jax.random`` samplers in
  the same straight-line block without an intervening
  ``split``/``fold_in`` rebind produces correlated draws; rebind first.

Rules register in ``RULES`` via ``@register_rule`` and run over parsed
modules — no imports of the linted code, so they also run on files with
unsatisfied dependencies.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Callable, Iterable, List

from repro.analysis.passes import Finding

RULES: dict = {}


def register_rule(name: str) -> Callable:
    def deco(fn):
        RULES[name] = fn
        fn.rule_name = name
        return fn
    return deco


def _call_name(node: ast.AST) -> str:
    """Trailing name of a call target: ``jax.random.normal`` -> normal."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _dotted(node: ast.AST) -> str:
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _is_frozen_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        if isinstance(dec, ast.Call) and _call_name(dec.func) == "dataclass":
            for kw in dec.keywords:
                if kw.arg == "frozen" and isinstance(kw.value, ast.Constant) \
                        and kw.value.value is True:
                    return True
    return False


@register_rule("frozen-transform")
def frozen_transform(tree: ast.Module, path: str) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        registered = any(
            isinstance(dec, ast.Call)
            and _call_name(dec.func) == "register_scenario"
            for dec in node.decorator_list)
        has_apply = any(
            isinstance(st, ast.FunctionDef) and st.name == "apply"
            and [a.arg for a in st.args.args][:3] == ["self", "sched", "ctx"]
            for st in node.body)
        if (registered or has_apply) and not _is_frozen_dataclass(node):
            why = "registered scenario" if registered \
                else "schedule transform (defines apply(self, sched, ctx))"
            out.append(Finding(
                "frozen-transform",
                f"class {node.name} is a {why} but not a frozen dataclass: "
                "transforms are hashed into compiled-driver cache keys and "
                "must be immutable", f"{path}:{node.lineno}"))
    return out


def _id_hash_calls(node: ast.AST) -> Iterable[ast.Call]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                and sub.func.id in ("id", "hash"):
            yield sub


@register_rule("id-in-cache-key")
def id_in_cache_key(tree: ast.Module, path: str) -> List[Finding]:
    out: List[Finding] = []

    def flag(call: ast.Call, ctx: str) -> None:
        out.append(Finding(
            "id-in-cache-key",
            f"{call.func.id}() inside {ctx}: address-based keys alias when "
            "the allocator recycles addresses — use executor.fingerprint() "
            "(content-addressed) instead", f"{path}:{call.lineno}"))

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = _call_name(node.func)
            if name in ("cached_driver", "fingerprint"):
                for arg in list(node.args) + [kw.value for kw in
                                              node.keywords]:
                    for call in _id_hash_calls(arg):
                        flag(call, f"a {name}() argument")
        if isinstance(node, ast.keyword) and node.arg == "cache_key":
            for call in _id_hash_calls(node.value):
                flag(call, "a cache_key= argument")
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == "cache_token":
            for st in node.body:
                for call in _id_hash_calls(st):
                    flag(call, "a cache_token() body")
    return out


# jax.random samplers that CONSUME a key (split/fold_in derive new ones)
_SAMPLERS = frozenset({
    "normal", "uniform", "bernoulli", "randint", "truncated_normal",
    "permutation", "choice", "gamma", "exponential", "laplace", "bits",
    "categorical", "gumbel", "dirichlet", "beta", "poisson", "rademacher"})


def _stmt_calls(stmt: ast.stmt) -> Iterable[ast.Call]:
    """Calls in ``stmt``'s own expressions, NOT descending into nested
    statement lists — an ``if``'s branches, a nested ``def``'s body — which
    are separate straight-line blocks (scanned on their own) rather than
    sequential consumptions."""
    stack = [stmt]
    while stack:
        node = stack.pop()
        if isinstance(node, ast.Call):
            yield node
        for value in ast.iter_child_nodes(node):
            if isinstance(value, ast.stmt) and value is not stmt:
                continue
            stack.append(value)


def _assigned_names(stmt: ast.stmt) -> set:
    names = set()
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign, ast.For)):
        targets = [stmt.target]
    for t in targets:
        for sub in ast.walk(t):
            if isinstance(sub, ast.Name):
                names.add(sub.id)
    return names


@register_rule("prng-reuse")
def prng_reuse(tree: ast.Module, path: str) -> List[Finding]:
    """Same key Name consumed by >= 2 ``jax.random`` samplers in one
    straight-line statement block with no rebind in between."""
    out: List[Finding] = []
    for node in ast.walk(tree):
        for field in ("body", "orelse", "finalbody"):
            block = getattr(node, field, None)
            if not isinstance(block, list):
                continue
            used: dict = {}
            for stmt in block:
                if not isinstance(stmt, ast.stmt):
                    continue
                for name in _assigned_names(stmt):
                    used.pop(name, None)
                for call in _stmt_calls(stmt):
                    if not (_call_name(call.func) in _SAMPLERS
                            and "random" in _dotted(call.func)
                            and call.args
                            and isinstance(call.args[0], ast.Name)):
                        continue
                    key = call.args[0].id
                    if key in used:
                        out.append(Finding(
                            "prng-reuse",
                            f"key `{key}` consumed by "
                            f"{_dotted(call.func)} was already consumed at "
                            f"line {used[key]} without a split/fold_in "
                            "rebind: the draws are identical/correlated",
                            f"{path}:{call.lineno}"))
                    used[key] = call.lineno
    return out


def lint_source(text: str, path: str = "<string>") -> List[Finding]:
    """Run every registered rule over one module's source."""
    tree = ast.parse(text, filename=path)
    out: List[Finding] = []
    for rule in RULES.values():
        out.extend(rule(tree, path))
    return out


def lint_paths(paths: Iterable) -> List[Finding]:
    """Run every rule over all ``.py`` files under ``paths``."""
    out: List[Finding] = []
    for root in paths:
        root = pathlib.Path(root)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            out.extend(lint_source(f.read_text(), str(f)))
    return out
