"""Comm contracts: declared collective budgets checked against lowered HLO.

A ``CommContract`` is the static half of the paper's communication model:
what a compiled COLA program is ALLOWED to move per device. ``check_comm``
holds a lowered program to it using the trip-count-aware
``launch.hlo_analysis.analyze`` pass — the one place the "plan paths never
gather, certificates exchange O(d)" guarantees are enforced, instead of
regex walls copy-pasted into test files.

Contracts are produced by the objects that know their own budget
(``CommPlan.contract()`` / ``BlockPlan.contract()`` in ``repro.topo.plan``)
or by the helpers below for the runtime paths that have no plan object
(ring mixing, certificate recorders, gather oracles).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.launch import hlo_analysis

#: collective kinds a neighbor-only program must not issue at all
FORBID_NEIGHBOR_ONLY: Tuple[str, ...] = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all")


class CommContractViolation(AssertionError):
    """A lowered program exceeded its declared collective budget."""


@dataclasses.dataclass(frozen=True)
class CommContract:
    """Per-device collective budget of one lowered program.

    All byte bounds are per-device totals over the whole program (trip-count
    aware: a collective inside a scan counts once per trip), matching
    ``hlo_analysis.analyze``'s accounting — all-reduce bytes count x2
    (reduce + broadcast), async start/done pairs count once.

    Attributes:
      name: label for failure messages (e.g. ``plan-K4-c3``).
      forbid: collective kinds that must move ZERO bytes.
      max_collective_permute_bytes: per-device ppermute payload cap, or None.
      max_collective_permute_count: executed ppermute op cap, or None.
      require_collective_permute: the program must actually exchange
        (count > 0 and bytes > 0) — guards against vacuously-passing
        programs that lost their collectives to DCE.
      max_all_reduce_bytes: scalar/O(d) psum allowance (certificate
        recorders), or None. Only meaningful when "all-reduce" is not in
        ``forbid``.
      min_all_gather_bytes: floor for paths that MUST gather (the dense
        oracle contrast assertions), or None.
      min_total_bytes: floor on total collective bytes (gather-recorder
        contrast), or None.
    """

    name: str
    forbid: Tuple[str, ...] = FORBID_NEIGHBOR_ONLY
    max_collective_permute_bytes: float | None = None
    max_collective_permute_count: float | None = None
    require_collective_permute: bool = False
    max_all_reduce_bytes: float | None = None
    min_all_gather_bytes: float | None = None
    min_total_bytes: float | None = None

    def describe(self) -> str:
        """One-line budget summary (the ``dryrun --plan`` contract line)."""
        parts = []
        if self.max_collective_permute_count is not None:
            parts.append(
                f"<={int(self.max_collective_permute_count)} "
                "collective-permute(s)")
        if self.max_collective_permute_bytes is not None:
            parts.append(
                f"<={int(self.max_collective_permute_bytes):,} "
                "ppermute bytes/device")
        if self.max_all_reduce_bytes is not None:
            parts.append(
                f"all-reduce<={int(self.max_all_reduce_bytes):,}B")
        if self.forbid:
            parts.append("zero " + "/".join(self.forbid))
        if self.min_all_gather_bytes is not None:
            parts.append(f"all-gather>={int(self.min_all_gather_bytes):,}B")
        if self.min_total_bytes is not None:
            parts.append(f"total>={int(self.min_total_bytes):,}B")
        return f"[contract {self.name}] " + ", ".join(parts)


def _as_hlo_text(program) -> str:
    """Accept HLO text, a jax ``Lowered``, or a compiled executable."""
    if isinstance(program, str):
        return program
    if hasattr(program, "compile"):       # jax.stages.Lowered
        program = program.compile()
    if hasattr(program, "as_text"):       # jax.stages.Compiled
        return program.as_text()
    raise TypeError(
        f"check_comm wants HLO text, a Lowered or a Compiled; got "
        f"{type(program)!r}")


def check_comm(program, contract: CommContract, *,
               pod_size: int | None = None) -> dict:
    """Verify a lowered program against its declared collective budget.

    Returns the full ``hlo_analysis.analyze`` report on success; raises
    ``CommContractViolation`` listing every violated clause (with the
    per-kind byte/count tables) otherwise.
    """
    report = hlo_analysis.analyze(_as_hlo_text(program), pod_size=pod_size)
    coll, counts = report["collectives"], report["collective_counts"]
    bad = []
    for kind in contract.forbid:
        if coll.get(kind, 0) != 0:
            bad.append(f"forbidden {kind}: {coll[kind]:,.0f} bytes "
                       f"(must be 0)")
    cp_bytes = coll["collective-permute"]
    cp_count = counts["collective-permute"]
    if contract.max_collective_permute_bytes is not None \
            and cp_bytes > contract.max_collective_permute_bytes:
        bad.append(
            f"collective-permute moves {cp_bytes:,.0f} bytes/device > "
            f"budget {contract.max_collective_permute_bytes:,.0f}")
    if contract.max_collective_permute_count is not None \
            and cp_count > contract.max_collective_permute_count:
        bad.append(
            f"{cp_count:.0f} collective-permutes executed > budget "
            f"{contract.max_collective_permute_count:.0f}")
    if contract.require_collective_permute and not (
            cp_count > 0 and cp_bytes > 0):
        bad.append("no collective-permute executed: the program lost its "
                   "neighbor exchange (count "
                   f"{cp_count:.0f}, bytes {cp_bytes:,.0f})")
    if contract.max_all_reduce_bytes is not None \
            and coll["all-reduce"] > contract.max_all_reduce_bytes:
        bad.append(
            f"all-reduce moves {coll['all-reduce']:,.0f} bytes > allowance "
            f"{contract.max_all_reduce_bytes:,.0f}")
    if contract.min_all_gather_bytes is not None \
            and coll["all-gather"] < contract.min_all_gather_bytes:
        bad.append(
            f"all-gather moves {coll['all-gather']:,.0f} bytes < required "
            f"{contract.min_all_gather_bytes:,.0f} (this path MUST gather)")
    if contract.min_total_bytes is not None \
            and coll["total"] < contract.min_total_bytes:
        bad.append(
            f"total collective bytes {coll['total']:,.0f} < required "
            f"{contract.min_total_bytes:,.0f}")
    if bad:
        raise CommContractViolation(
            f"{contract.describe()}\n  " + "\n  ".join(bad)
            + f"\n  bytes={ {k: v for k, v in coll.items()} }"
            + f"\n  counts={ {k: v for k, v in counts.items()} }")
    return report


# -- runtime paths without a plan object ------------------------------------

def ring_contract(d: int, conn: int = 1, itemsize: int = 4, *,
                  gossip_steps: int = 1) -> CommContract:
    """Budget of the banded ppermute ring (``comm="ring"``): 2*conn
    shifts of a (d,) payload per gossip step, nothing gathered."""
    return CommContract(
        name=f"ring-conn{conn}-d{d}",
        max_collective_permute_count=gossip_steps * 2 * conn,
        max_collective_permute_bytes=gossip_steps * 2 * conn * d * itemsize,
        require_collective_permute=True)


def certificate_contract(d: int, conn: int = 1,
                         itemsize: int = 4) -> CommContract:
    """The O(d) certificate-record budget (Prop. 1 exchange): neighbor
    payloads over <= 2*conn ppermutes, scalar row reductions plus the
    (2, d) invariant-sum psum (lowered twice by XLA across the early-stop
    branch) — never a K*d gather."""
    return CommContract(
        name=f"certificate-conn{conn}-d{d}",
        forbid=("all-gather", "reduce-scatter", "all-to-all"),
        max_collective_permute_bytes=2 * conn * d * itemsize,
        max_all_reduce_bytes=(4 * d + 64) * itemsize)


def gather_contract(name: str, *, min_all_gather_bytes: float | None = None,
                    min_total_bytes: float | None = None) -> CommContract:
    """Contrast contract for paths that MUST move the stacks (the dense
    oracle, the gather-``GapRecorder``) — proves the analyzer would see the
    collectives a plan path is asserted not to have."""
    return CommContract(name=name, forbid=(),
                        min_all_gather_bytes=min_all_gather_bytes,
                        min_total_bytes=min_total_bytes)
