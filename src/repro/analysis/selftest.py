"""Seeded-violation fixtures: prove every verifier pass actually fires.

A static checker that silently passes on everything is worse than none, so
each pass ships with a deliberately broken program — a forced f64 upcast, a
``jax.debug.print`` inside a scan, an injected all-gather on the plan path,
a donation with no usable output, an oversized captured constant, an
unstable cache key, and source snippets violating each AST rule. The CLI's
``--selftest`` (and ``tests/test_analysis.py``) runs them all and FAILS if
any seeded violation goes undetected.

Each fixture returns the findings its pass produced on the broken program;
"caught" means at least one finding names the seeded defect.
"""
from __future__ import annotations

import itertools
import textwrap
import warnings
from typing import Callable, List

from repro.analysis import astlint, passes
from repro.analysis.passes import Finding

SELFTESTS: dict = {}


def register_selftest(name: str) -> Callable:
    def deco(fn):
        SELFTESTS[name] = fn
        fn.selftest_name = name
        return fn
    return deco


@register_selftest("dtype-drift")
def seeded_dtype_drift() -> List[Finding]:
    """A silent f32 -> f64 -> f32 round-trip inside the program."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    with enable_x64():
        def fn(x):
            acc = x.astype(jnp.float64) * 2.0
            return acc.astype(jnp.float32)
        closed = jax.make_jaxpr(fn)(jnp.zeros((4,), jnp.float32))
    return passes.dtype_drift(closed, where="selftest:f64-upcast")


@register_selftest("host-callback-in-scan")
def seeded_host_callback() -> List[Finding]:
    """A forgotten ``jax.debug.print`` inside the round scan."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    def fn(x):
        def step(carry, _):
            jax.debug.print("round carry {c}", c=carry)
            return carry + 1.0, None
        return lax.scan(step, x, None, length=3)[0]

    closed = jax.make_jaxpr(fn)(jnp.float32(0.0))
    return passes.host_callback_in_scan(closed,
                                        where="selftest:debug-print")


@register_selftest("constant-capture")
def seeded_constant_capture() -> List[Finding]:
    """A 2 MiB array baked into the jaxpr instead of passed as an arg."""
    import jax
    import jax.numpy as jnp

    big = jnp.ones((1 << 19,), jnp.float32)  # 2 MiB

    def fn(x):
        return x + big.sum()

    closed = jax.make_jaxpr(fn)(jnp.float32(0.0))
    return passes.constant_capture(closed, max_bytes=1 << 20,
                                   where="selftest:2MiB-const")


@register_selftest("donation")
def seeded_donation() -> List[Finding]:
    """A donated buffer with no shape-matching output: jax drops the
    donation with only a warning; the pass must treat it as a violation."""
    import jax.numpy as jnp

    def fn(x):
        return x[: x.shape[0] // 2] * 2.0  # no (8,) output to alias into

    args = (jnp.zeros((8,), jnp.float32),)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # jax's "donation not used" warning
        return passes.donation(fn, args, (0,),
                               where="selftest:unusable-donation")


@register_selftest("retrace")
def seeded_retrace() -> List[Finding]:
    """An unstable cache key (fresh every call): the warmed re-run must
    surface as cache misses."""
    from repro.core import executor

    counter = itertools.count()

    def run():
        executor.cached_driver(("selftest-retrace", next(counter)),
                               lambda: (lambda: None))

    findings = passes.check_retrace(run, where="selftest:unstable-key")
    executor.clear_driver_cache()
    return findings


@register_selftest("comm-contract")
def seeded_all_gather() -> List[Finding]:
    """An all-gather injected into the plan-executed round: the compiled
    HLO must violate the plan's neighbor-only contract. Needs a 4-device
    mesh (raises ``drivers.SkipDriver`` otherwise)."""
    from repro.analysis import contracts, drivers
    from repro.core import topology as topo

    prob = drivers._lasso()
    hlo, plan = drivers.plan_round_hlo(prob, topo.torus_2d(2, 2), 4,
                                      inject_all_gather=True)
    try:
        contracts.check_comm(hlo, plan.contract(prob.d))
    except contracts.CommContractViolation as e:
        return [Finding("comm-contract", str(e),
                        where="selftest:injected-all-gather")]
    return []


@register_selftest("comm-contract-wire")
def seeded_fp32_leak() -> List[Finding]:
    """An fp32 payload ppermuted across a claimed-int8 wire: the narrow
    contract's byte cap must catch the wide leak. Needs a 4-device mesh
    (raises ``drivers.SkipDriver`` otherwise)."""
    from repro.analysis import contracts, drivers
    from repro.core import topology as topo

    prob = drivers._lasso()
    hlo, plan = drivers.quant_round_hlo(prob, topo.torus_2d(2, 4), 8, 4,
                                        "int8", inject_fp32_leak=True)
    try:
        contracts.check_comm(hlo, plan.contract(prob.d, wire="int8"))
    except contracts.CommContractViolation as e:
        return [Finding("comm-contract", str(e),
                        where="selftest:fp32-on-int8-wire")]
    return []


@register_selftest("telemetry-carry")
def seeded_telemetry_constant() -> List[Finding]:
    """Telemetry counters captured as a trace-time constant instead of
    extending the round scan's carry: the "on" build's scan carries no more
    state than its off twin, so every counter update is dead code and the
    recorded totals freeze at their trace values."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    def run_off(x):
        def step(carry, _):
            return carry + 1.0, None
        return lax.scan(step, x, None, length=4)[0]

    wire_bytes = jnp.zeros(())  # the seeded bug: counter not in the carry

    def run_on_broken(x):
        def step(carry, _):
            _ = wire_bytes + 64.0  # "update" that never re-enters the scan
            return carry + 1.0, None
        return lax.scan(step, x, None, length=4)[0]

    off = jax.make_jaxpr(run_off)(jnp.float32(0.0))
    on = jax.make_jaxpr(run_on_broken)(jnp.float32(0.0))
    return passes.telemetry_carry(off, on,
                                  where="selftest:constant-counter")


_AST_VIOLATIONS = {
    "frozen-transform": """
        class Mutable:
            def apply(self, sched, ctx):
                sched["w"] = None
        """,
    "id-in-cache-key": """
        def build_driver(prob, build):
            return cached_driver((id(prob), 3), build)
        """,
    "prng-reuse": """
        def sample(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a, b
        """,
}


def _seeded_ast(rule: str) -> Callable[[], List[Finding]]:
    def fixture() -> List[Finding]:
        src = textwrap.dedent(_AST_VIOLATIONS[rule])
        return [f for f in astlint.lint_source(src, f"selftest:{rule}")
                if f.pass_name == rule]
    fixture.__doc__ = f"Source snippet violating the ``{rule}`` AST rule."
    return fixture


for _rule in _AST_VIOLATIONS:
    register_selftest(f"ast-{_rule}")(_seeded_ast(_rule))


def run_selftests(*, skip_mesh: bool = False) -> List[tuple]:
    """Run every seeded violation; returns ``(name, caught, detail)`` rows.

    ``caught`` is True when the pass produced at least one finding on its
    broken program — the CLI exits nonzero on any False. ``skip_mesh``
    marks mesh-dependent fixtures as skipped (``caught=None``) instead of
    erroring on small-device hosts.
    """
    from repro.analysis.drivers import SkipDriver

    rows = []
    for name, fixture in SELFTESTS.items():
        try:
            findings = fixture()
        except SkipDriver as e:
            if skip_mesh:
                rows.append((name, None, str(e)))
                continue
            raise
        caught = len(findings) > 0
        detail = str(findings[0]) if findings else \
            "pass produced NO findings on its seeded violation"
        rows.append((name, caught, detail))
    return rows
