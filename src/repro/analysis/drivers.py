"""Registered driver configurations for the static verifier.

Every entry in ``DRIVER_REGISTRY`` is a zero-argument check that builds one
representative compiled COLA program — simulator round blocks, dist
ring/plan/block rounds, robust mixing, gossip-DP, certificate recorders —
and holds it to its contracts: comm budgets via ``contracts.check_comm``,
jaxpr lints via ``passes.run_jaxpr_passes``, donation via
``passes.donation``. ``python -m repro.analysis --all`` runs them all.

The ``*_round_hlo`` builders are shared with the dist test files (the tests
migrated their inline HLO construction here), so the program the CLI
verifies is byte-identical to the one the test suite asserts on.

Multi-device note: the dist builders lower shard_map programs for real
meshes (up to 4 devices) — callers without enough devices get a
``SkipDriver`` (the CLI entry point forces 8 virtual CPU devices before
importing jax, so ``python -m repro.analysis`` always runs everything).
"""
from __future__ import annotations

from typing import Callable, List

import numpy as np

from repro.analysis import contracts, passes
from repro.analysis.passes import Finding

DRIVER_REGISTRY: dict = {}


class SkipDriver(RuntimeError):
    """Raised by a driver check whose mesh requirements this process
    cannot satisfy (too few devices)."""


def register_driver(name: str) -> Callable:
    def deco(fn):
        DRIVER_REGISTRY[name] = fn
        fn.driver_name = name
        return fn
    return deco


def _require_devices(n: int) -> None:
    import jax
    if jax.device_count() < n:
        raise SkipDriver(
            f"needs {n} devices, have {jax.device_count()} (run via "
            "`python -m repro.analysis`, which forces a virtual mesh)")


def _lasso(n_samples: int = 150, d: int = 48):
    import jax.numpy as jnp
    from repro.core import problems
    from repro.data import synthetic
    x, y, _ = synthetic.regression(n_samples, d, seed=2,
                                   sparsity_solution=0.2)
    return problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)


def _ridge(n_samples: int = 64, d: int = 32):
    import jax.numpy as jnp
    from repro.core import problems
    from repro.data import synthetic
    x, y, _ = synthetic.regression(n_samples, d, seed=0)
    return problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)


# -- shared HLO builders (used by tests/test_dist_plan.py and
# -- tests/test_certificate_dist.py after their migration) ------------------

def plan_round_hlo(prob, graph, k: int, *, inject_all_gather: bool = False):
    """Compiled HLO of the per-node plan-executed round (one node per
    device) plus its ``CommPlan``. ``inject_all_gather`` plants a live
    all-gather in the round body — the seeded violation the CI smoke
    asserts the verifier catches."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import topo as rtopo
    from repro.core import mixing, topology as topo
    from repro.core.cola import ColaConfig, _round_body, build_env, \
        init_state
    from repro.core.partition import make_partition
    from repro.dist import runtime as rt
    from repro.dist.sharding import (cola_env_pspecs, cola_state_pspecs,
                                     plan_payload_pspecs)

    _require_devices(k)
    part = make_partition(prob.n, k)
    env = build_env(prob, part)
    mesh = jax.make_mesh((k,), ("data",))
    plan = rtopo.compile_plan(graph)
    cfg = ColaConfig(kappa=1.0)
    mix_fn, grad_mix_fn = rt._dist_mixers("data", 1, 1, "plan",
                                          cfg.gossip_steps, plan)
    body = _round_body(prob, part, cfg, mix_fn=mix_fn,
                      grad_mix_fn=grad_mix_fn)

    def round_fn(st, e, pay, act):
        new = body(st, e, pay, act)
        if inject_all_gather:
            # a live (gradient-relevant) gather of the stack: exactly the
            # O(K*d) traffic the plan path exists to avoid
            leak = lax.all_gather(new.v_stack, "data").sum() \
                * jnp.float32(1e-30)
            new = jax.tree.map(lambda a: a + leak, new)
        return new

    state_spec, env_spec = cola_state_pspecs("data"), cola_env_pspecs("data")
    shard_step = mixing.shard_map(
        round_fn, mesh,
        in_specs=(state_spec, env_spec, plan_payload_pspecs("data"),
                  P("data")),
        out_specs=state_spec)

    w = topo.metropolis_weights(graph)
    diag, coefs = rtopo.plan_coefficients(plan, w)
    sds = lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)
    args = (jax.tree.map(sds, init_state(prob, part)),
            jax.tree.map(sds, env),
            (sds(diag.astype(np.float32)), sds(coefs.astype(np.float32))),
            sds(np.ones(k, np.float32)))
    sh = lambda spec: NamedSharding(mesh, spec)
    in_sh = (jax.tree.map(lambda _: sh(state_spec), args[0]),
             jax.tree.map(lambda _: sh(env_spec), args[1]),
             (sh(P("data")), sh(P(None, "data"))), sh(P("data")))
    hlo = jax.jit(shard_step, in_shardings=in_sh) \
        .lower(*args).compile().as_text()
    return hlo, plan


def dense_round_hlo(prob, graph, k: int) -> str:
    """Compiled HLO of the dense all-gather oracle round — the contrast
    program that MUST move the (K, d) stack."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import mixing, topology as topo
    from repro.core.cola import ColaConfig, _round_body, build_env, \
        init_state
    from repro.core.partition import make_partition
    from repro.dist import runtime as rt
    from repro.dist.sharding import cola_env_pspecs, cola_state_pspecs

    _require_devices(k)
    part = make_partition(prob.n, k)
    env = build_env(prob, part)
    mesh = jax.make_mesh((k,), ("data",))
    cfg = ColaConfig(kappa=1.0)
    mix_d, grad_d = rt._dist_mixers("data", 1, 1, "dense", cfg.gossip_steps)
    body_d = _round_body(prob, part, cfg, mix_fn=mix_d, grad_mix_fn=grad_d)
    state_spec, env_spec = cola_state_pspecs("data"), cola_env_pspecs("data")
    shard_d = mixing.shard_map(
        lambda st, e, w_, act: body_d(st, e, w_, act), mesh,
        in_specs=(state_spec, env_spec, P(), P("data")),
        out_specs=state_spec)
    w = topo.metropolis_weights(graph)
    sds = lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)
    args = (jax.tree.map(sds, init_state(prob, part)),
            jax.tree.map(sds, env), sds(w.astype(np.float32)),
            sds(np.ones(k, np.float32)))
    sh = lambda spec: NamedSharding(mesh, spec)
    in_sh = (jax.tree.map(lambda _: sh(state_spec), args[0]),
             jax.tree.map(lambda _: sh(env_spec), args[1]),
             sh(P()), sh(P("data")))
    return jax.jit(shard_d, in_shardings=in_sh) \
        .lower(*args).compile().as_text()


def block_round_hlo(prob, graph, k: int, m: int, *,
                    robust: str | None = None):
    """Compiled HLO of the block-mode round (K nodes on M < K devices)
    plus its ``BlockPlan``."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import topo as rtopo
    from repro.core import mixing, topology as topo
    from repro.core.cola import ColaConfig, _round_body, build_env, \
        init_state
    from repro.core.partition import make_partition
    from repro.dist import runtime as rt
    from repro.dist.sharding import (block_payload_pspec, cola_env_pspecs,
                                     cola_state_pspecs)

    _require_devices(m)
    part = make_partition(prob.n, k)
    env = build_env(prob, part)
    mesh = jax.make_mesh((m,), ("data",))
    plan = rtopo.compile_block_plan(graph, m)
    cfg = ColaConfig(kappa=1.0, robust=robust)
    mix_fn, grad_mix_fn = rt._dist_mixers(
        "data", k // m, 1, "plan", cfg.gossip_steps, plan, robust=robust)
    body = _round_body(prob, part, cfg, mix_fn=mix_fn,
                      grad_mix_fn=grad_mix_fn)
    state_spec, env_spec = cola_state_pspecs("data"), cola_env_pspecs("data")
    shard_step = mixing.shard_map(
        lambda st, e, pay, act: body(st, e, pay, act), mesh,
        in_specs=(state_spec, env_spec, block_payload_pspec("data"),
                  P("data")),
        out_specs=state_spec)
    w = topo.metropolis_weights(graph).astype(np.float32)
    sds = lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)
    args = (jax.tree.map(sds, init_state(prob, part)),
            jax.tree.map(sds, env), sds(w), sds(np.ones(k, np.float32)))
    sh = lambda spec: NamedSharding(mesh, spec)
    in_sh = (jax.tree.map(lambda _: sh(state_spec), args[0]),
             jax.tree.map(lambda _: sh(env_spec), args[1]),
             sh(block_payload_pspec("data")), sh(P("data")))
    hlo = jax.jit(shard_step, in_shardings=in_sh) \
        .lower(*args).compile().as_text()
    return hlo, plan


def quant_round_hlo(prob, graph, k: int, m: int, wire: str, *,
                    pipeline: bool = False,
                    inject_fp32_leak: bool = False):
    """Compiled HLO of the quantized-wire round — the block program
    ``run_dist_cola(comm="plan", wire=...)`` executes (quantized wires
    always lower through the BlockPlan, even at one node per device) —
    plus its ``BlockPlan``.

    ``pipeline=True`` lowers the double-buffered body: round t's step-0
    payload was encoded at the end of round t-1 and rides ``ColaState.buf``,
    so the first ppermutes depend only on carried state, not on this
    round's compute. ``inject_fp32_leak`` plants the seeded violation for
    the verifier selftest: the raw fp32 dual block crossing the wire that
    the codec exists to narrow — the claimed-int8 byte cap must catch it.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import topo as rtopo
    from repro.core import mixing, quant, topology as topo
    from repro.core.cola import (ColaConfig, _arm_wire_state, _round_body,
                                 build_env, init_state)
    from repro.core.partition import make_partition
    from repro.dist import runtime as rt
    from repro.dist.sharding import (block_payload_pspec, cola_env_pspecs,
                                     cola_state_pspecs)

    _require_devices(m)
    part = make_partition(prob.n, k)
    env = build_env(prob, part)
    mesh = jax.make_mesh((m,), ("data",))
    plan = rtopo.compile_block_plan(graph, m)
    cfg = ColaConfig(kappa=1.0, wire=wire, pipeline=pipeline)
    mix_fn, grad_mix_fn = rt._dist_mixers("data", k // m, 1, "plan",
                                          cfg.gossip_steps, plan)
    qmix_fn, qencode_fn = rt._dist_qmixers("data", k // m, "plan", cfg,
                                           plan)
    body = _round_body(prob, part, cfg, mix_fn=mix_fn,
                       grad_mix_fn=grad_mix_fn, qmix_fn=qmix_fn,
                       qencode_fn=qencode_fn)

    def round_fn(st, e, pay, act, qk, qk_next):
        new = body(st, e, pay, act, None, None, qk,
                   qk_next if pipeline else None)
        if inject_fp32_leak:
            # the seeded violation: a live fp32 (K/M, d) payload ppermuted
            # around the mesh — exactly the wide wire the codec narrows
            leak = lax.ppermute(st.v_stack, "data",
                                [(i, (i + 1) % m) for i in range(m)])
            new = new._replace(
                v_stack=new.v_stack + leak * jnp.float32(1e-30))
        return new

    state = init_state(prob, part)
    keys = np.asarray(quant.round_keys(0, 2))
    state = _arm_wire_state(state, cfg, keys[0])
    state_spec, env_spec = cola_state_pspecs("data"), cola_env_pspecs("data")
    shard_step = mixing.shard_map(
        round_fn, mesh,
        in_specs=(state_spec, env_spec, block_payload_pspec("data"),
                  P("data"), P(), P()),
        out_specs=state_spec)
    w = topo.metropolis_weights(graph).astype(np.float32)
    sds = lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)
    args = (jax.tree.map(sds, state), jax.tree.map(sds, env), sds(w),
            sds(np.ones(k, np.float32)), sds(keys[0]), sds(keys[1]))
    sh = lambda spec: NamedSharding(mesh, spec)
    in_sh = (jax.tree.map(lambda _: sh(state_spec), args[0]),
             jax.tree.map(lambda _: sh(env_spec), args[1]),
             sh(block_payload_pspec("data")), sh(P("data")),
             sh(P()), sh(P()))
    hlo = jax.jit(shard_step, in_shardings=in_sh) \
        .lower(*args).compile().as_text()
    return hlo, plan


def _param_only_chain(comp, start_ops, allowed=(
        "get-tuple-element", "bitcast", "bitcast-convert", "reshape",
        "copy", "convert", "transpose", "tuple", "constant",
        "broadcast")) -> bool:
    """True iff every transitive operand of ``start_ops`` resolves to a
    computation parameter through shape-plumbing ops only — i.e. the value
    was ready at computation entry, with no compute on the critical path."""
    by_name = {op.name: op for op in comp.ops}
    from repro.launch.hlo_analysis import _operands
    stack = [by_name[sym] for op in start_ops
             for sym in _operands(op) if sym in by_name]
    seen = set()
    while stack:
        op = stack.pop()
        if op.name in seen:
            continue
        seen.add(op.name)
        if op.opcode == "parameter":
            continue
        if op.opcode not in allowed:
            return False
        for sym in _operands(op):
            if sym in by_name:
                stack.append(by_name[sym])
    return True


def pipeline_order_findings(hlo: str, where: str) -> List[Finding]:
    """The pipelined round body must issue its first collective-permute
    from the CARRIED double buffer: the payload's operand chain reaches
    computation parameters without any compute (no quantize reduce, no CD
    dot), which is what lets the exchange overlap this round's solve. The
    unpipelined body fails this — its step-0 payload is quantized from the
    round's own v, so the permute waits on an absmax reduction."""
    from repro.launch import hlo_analysis
    comps, _ = hlo_analysis.parse_module(hlo)
    checked = 0
    for comp in comps.values():
        perms = [op for op in comp.ops
                 if op.opcode.startswith("collective-permute")
                 and not op.opcode.endswith("-done")]
        if not perms:
            continue
        checked += 1
        if _param_only_chain(comp, perms[:1]):
            return []
    if not checked:
        return [Finding("pipeline-order",
                        "no computation issues a collective-permute — the "
                        "round body lost its neighbor exchange", where=where)]
    return [Finding(
        "pipeline-order",
        "first collective-permute depends on this round's compute (its "
        "operand chain does not resolve to carried parameters) — the "
        "double-buffered payload is not overlapping the solve",
        where=where)]


def certificate_record_hlo(prob, graph, k: int, conn: int = 1,
                           comm: str = "ring") -> str:
    """Compiled HLO of the dist certificate record program (``comm`` in
    ring/plan) — the O(d)-budget program."""
    import jax
    from jax.sharding import NamedSharding
    from repro import topo as rtopo
    from repro.core import metrics as metrics_lib, topology as topo
    from repro.core.cola import build_env, init_state
    from repro.core.partition import make_partition
    from repro.dist import runtime as rt
    from repro.dist.sharding import cola_state_pspecs

    _require_devices(k)
    part = make_partition(prob.n, k)
    env = build_env(prob, part)
    mesh = jax.make_mesh((k,), ("data",))
    rec = metrics_lib.make_recorder("certificate", prob, part, env, graph,
                                    topo.metropolis_weights(graph), 0.1)
    rec = rt._place_recorder(rec, mesh, "data")
    plan = rtopo.compile_plan(graph) if comm == "plan" else None
    record = rt._certificate_dist_record(rec, mesh, "data", 1, comm, conn,
                                         plan)
    state = init_state(prob, part)
    sds = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                       state)
    sh = NamedSharding(mesh, cola_state_pspecs("data"))
    shardings = (jax.tree.map(lambda _: sh, sds),)
    return jax.jit(record, in_shardings=shardings) \
        .lower(sds).compile().as_text()


def gap_record_hlo(prob, k: int) -> str:
    """Compiled HLO of the gather-``GapRecorder`` record program — the
    contrast program that must move >= K*d bytes."""
    import jax
    from jax.sharding import NamedSharding
    from repro.core import metrics as metrics_lib
    from repro.core.cola import init_state
    from repro.core.partition import make_partition
    from repro.dist.sharding import cola_state_pspecs

    _require_devices(k)
    part = make_partition(prob.n, k)
    mesh = jax.make_mesh((k,), ("data",))
    gap = metrics_lib.GapRecorder(prob, part)
    state = init_state(prob, part)
    sds = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                       state)
    sh = NamedSharding(mesh, cola_state_pspecs("data"))
    shardings = (jax.tree.map(lambda _: sh, sds),)
    return jax.jit(gap.record_fn, in_shardings=shardings) \
        .lower(sds).compile().as_text()


# -- the simulator round block (jaxpr passes + donation) --------------------

def _sim_block_program(cfg):
    import jax.numpy as jnp
    from jax import lax
    from repro.core import topology as topo
    from repro.core.cola import _round_body, build_env, init_state
    from repro.core.partition import make_partition

    prob = _ridge()
    k, t = 8, 4
    graph = topo.ring(k)
    part = make_partition(prob.n, k)
    env = build_env(prob, part)
    state = init_state(prob, part)
    body = _round_body(prob, part, cfg)
    w = topo.metropolis_weights(graph).astype(np.float32)

    def block(st, ctx, sched):
        def step(s, xs):
            return body(s, ctx, xs["w"], xs["active"]), None
        return lax.scan(step, st, sched)[0]

    sched = {"w": jnp.stack([jnp.asarray(w)] * t),
             "active": jnp.ones((t, k), jnp.float32)}
    return block, (state, env, sched)


def _check_sim(cfg, name: str) -> List[Finding]:
    block, args = _sim_block_program(cfg)
    findings = passes.run_jaxpr_passes(block, *args, where=name)
    findings += passes.donation(block, args, (0,), where=name)
    return findings


def _check_comm_to_findings(check: Callable[[], dict],
                            name: str) -> List[Finding]:
    try:
        check()
    except contracts.CommContractViolation as e:
        return [Finding("comm-contract", str(e), where=name)]
    return []


@register_driver("sim")
def check_sim() -> List[Finding]:
    from repro.core.cola import ColaConfig
    return _check_sim(ColaConfig(kappa=1.0), "sim")


@register_driver("sim-robust")
def check_sim_robust() -> List[Finding]:
    from repro.core.cola import ColaConfig
    return _check_sim(ColaConfig(kappa=1.0, robust="trim"), "sim-robust")


@register_driver("gossip-dp")
def check_gossip_dp() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from jax import lax
    from repro.core import topology as topo
    from repro.optim import gossip as gossip_lib
    from repro.optim.privacy import DPConfig

    k = 8
    gcfg = gossip_lib.GossipConfig(num_nodes=k)
    dp = DPConfig(clip=1.0, sigma=1.0)
    mixer = gossip_lib._param_mixer(gcfg, None, None, None, dp)
    w = jnp.asarray(topo.metropolis_weights(gcfg.graph()),
                    dtype=jnp.float32)
    params = {"w": jnp.zeros((k, 16), jnp.float32),
              "b": jnp.zeros((k,), jnp.float32)}
    key = jax.random.PRNGKey(0)

    def prog(p, w_, key_):
        def step(pp, i):
            return mixer(w_, pp, jax.random.fold_in(key_, i)), None
        return lax.scan(step, p, jnp.arange(4))[0]

    return passes.run_jaxpr_passes(prog, params, w, key, where="gossip-dp")


@register_driver("dist-ring")
def check_dist_ring() -> List[Finding]:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import mixing, topology as topo
    from repro.core.cola import ColaConfig, _round_body, build_env, \
        init_state
    from repro.core.partition import make_partition
    from repro.dist import runtime as rt
    from repro.dist.sharding import cola_env_pspecs, cola_state_pspecs

    k, conn = 4, 1
    _require_devices(k)
    prob = _ridge()
    part = make_partition(prob.n, k)
    env = build_env(prob, part)
    mesh = jax.make_mesh((k,), ("data",))
    cfg = ColaConfig(kappa=1.0)
    mix_fn, grad_mix_fn = rt._dist_mixers("data", 1, conn, "ring",
                                          cfg.gossip_steps)
    body = _round_body(prob, part, cfg, mix_fn=mix_fn,
                      grad_mix_fn=grad_mix_fn)
    state_spec, env_spec = cola_state_pspecs("data"), cola_env_pspecs("data")
    shard_step = mixing.shard_map(
        lambda st, e, w_, act: body(st, e, w_, act), mesh,
        in_specs=(state_spec, env_spec, P(), P("data")),
        out_specs=state_spec)
    w = topo.metropolis_weights(topo.ring(k))
    sds = lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)
    args = (jax.tree.map(sds, init_state(prob, part)),
            jax.tree.map(sds, env), sds(w.astype(np.float32)),
            sds(np.ones(k, np.float32)))
    sh = lambda spec: NamedSharding(mesh, spec)
    in_sh = (jax.tree.map(lambda _: sh(state_spec), args[0]),
             jax.tree.map(lambda _: sh(env_spec), args[1]),
             sh(P()), sh(P("data")))
    hlo = jax.jit(shard_step, in_shardings=in_sh) \
        .lower(*args).compile().as_text()
    return _check_comm_to_findings(
        lambda: contracts.check_comm(
            hlo, contracts.ring_contract(prob.d, conn)), "dist-ring")


@register_driver("dist-plan")
def check_dist_plan() -> List[Finding]:
    from repro.core import topology as topo
    prob = _lasso()
    k = 4
    hlo, plan = plan_round_hlo(prob, topo.torus_2d(2, k // 2), k)
    return _check_comm_to_findings(
        lambda: contracts.check_comm(hlo, plan.contract(prob.d)),
        "dist-plan")


@register_driver("dist-plan-sampled")
def check_dist_plan_sampled() -> List[Finding]:
    """Client sampling on the compiled per-node plan: the complete-graph
    round program honors the full plan's comm contract, and every sampled
    cohort's churn-reweighted W stays executable on that SAME static plan
    — its (diag, coefs) lowering round-trips through
    ``w_from_coefficients`` exactly (sampling only zeroes edges, never
    grows support), which is what keeps one compiled program valid across
    a streamed participation schedule."""
    from repro import topo as rtopo
    from repro.core import schedule as schedule_lib, topology as topo

    prob = _lasso()
    k = 4
    graph = topo.complete(k)
    hlo, plan = plan_round_hlo(prob, graph, k)
    findings = _check_comm_to_findings(
        lambda: contracts.check_comm(hlo, plan.contract(prob.d)),
        "dist-plan-sampled")
    sample = schedule_lib.SampleConfig(k_active=2, mode="dense")
    mask_fn = schedule_lib.participation_callable(k, sample, run_seed=0)
    rng = np.random.default_rng(0)
    for t in range(8):
        mask = mask_fn(t, rng)
        w_t = np.asarray(topo.reweight_for_active(graph, mask))
        try:
            diag, coefs = rtopo.plan_coefficients(plan, w_t, check=True)
        except ValueError as e:
            findings.append(Finding(
                "comm-contract",
                f"round {t} sampled mask {mask.astype(int).tolist()} "
                f"reweights outside the compiled complete-graph plan: {e}",
                where="dist-plan-sampled"))
            continue
        if not (rtopo.w_from_coefficients(plan, diag, coefs) == w_t).all():
            findings.append(Finding(
                "comm-contract",
                f"round {t} (diag, coefs) lowering does not round-trip to "
                "the sampled W — the plan would execute a different matrix "
                "than the certificate accounts for",
                where="dist-plan-sampled"))
    return findings


@register_driver("dist-dense")
def check_dist_dense() -> List[Finding]:
    from repro.core import topology as topo
    prob = _lasso()
    k, itemsize = 4, 4
    hlo = dense_round_hlo(prob, topo.torus_2d(2, k // 2), k)
    return _check_comm_to_findings(
        lambda: contracts.check_comm(hlo, contracts.gather_contract(
            "dense-oracle", min_all_gather_bytes=prob.d * itemsize)),
        "dist-dense")


@register_driver("dist-block")
def check_dist_block() -> List[Finding]:
    from repro.core import topology as topo
    prob = _lasso(153, 48)
    k, m = 9, 3
    hlo, plan = block_round_hlo(prob, topo.complete(k), k, m)
    return _check_comm_to_findings(
        lambda: contracts.check_comm(hlo, plan.contract(prob.d)),
        "dist-block")


@register_driver("dist-block-robust")
def check_dist_block_robust() -> List[Finding]:
    from repro.core import topology as topo
    prob = _lasso()
    k, m = 8, 4
    hlo, plan = block_round_hlo(prob, topo.torus_2d(2, 4), k, m,
                                robust="trim")
    return _check_comm_to_findings(
        lambda: contracts.check_comm(hlo, plan.contract(prob.d)),
        "dist-block-robust")


@register_driver("dist-plan-int8")
def check_dist_plan_int8() -> List[Finding]:
    """The quantized wire's headline contract: the int8 round program
    (what ``run_dist_cola(comm="plan", wire="int8")`` compiles) moves at
    most the narrow-wire ppermute budget — itself required to be <= 0.3x
    the fp32 budget — and gathers nothing."""
    from repro.core import topology as topo
    prob = _lasso()
    k, m = 8, 4
    hlo, plan = quant_round_hlo(prob, topo.torus_2d(2, 4), k, m, "int8")
    contract = plan.contract(prob.d, wire="int8")
    fp32_cap = plan.contract(prob.d).max_collective_permute_bytes
    findings = []
    if contract.max_collective_permute_bytes > 0.3 * fp32_cap:
        findings.append(Finding(
            "comm-contract",
            f"int8 wire budget {contract.max_collective_permute_bytes:,.0f}"
            f" B/device exceeds 0.3x the fp32 budget {fp32_cap:,.0f} — the"
            " codec is not actually narrowing the wire",
            where="dist-plan-int8"))
    return findings + _check_comm_to_findings(
        lambda: contracts.check_comm(hlo, contract), "dist-plan-int8")


@register_driver("dist-plan-fp8-pipelined")
def check_dist_plan_fp8_pipelined() -> List[Finding]:
    """The double-buffered fp8 round: same narrow-wire comm contract, plus
    the pipeline-structure check — the first ppermute must consume the
    CARRIED payload buffer (no compute on its operand chain), which is the
    HLO-visible form of 'comm overlaps the CD solve'."""
    from repro.core import topology as topo
    prob = _lasso()
    k, m = 8, 4
    hlo, plan = quant_round_hlo(prob, topo.torus_2d(2, 4), k, m, "fp8",
                                pipeline=True)
    findings = _check_comm_to_findings(
        lambda: contracts.check_comm(hlo, plan.contract(prob.d, wire="fp8")),
        "dist-plan-fp8-pipelined")
    return findings + pipeline_order_findings(hlo, "dist-plan-fp8-pipelined")


@register_driver("cert-ring")
def check_cert_ring() -> List[Finding]:
    from repro.core import topology as topo
    prob = _lasso()
    k, conn = 4, 1
    hlo = certificate_record_hlo(prob, topo.ring(k), k, conn, comm="ring")
    return _check_comm_to_findings(
        lambda: contracts.check_comm(
            hlo, contracts.certificate_contract(prob.d, conn)), "cert-ring")


@register_driver("cert-plan")
def check_cert_plan() -> List[Finding]:
    from repro.core import topology as topo
    prob = _lasso()
    k, itemsize = 4, 4
    graph = topo.torus_2d(2, k // 2)
    hlo = certificate_record_hlo(prob, graph, k, 1, comm="plan")
    # plan-path certificate: one (d,) ppermute per color + the O(d) psum
    from repro import topo as rtopo
    plan = rtopo.compile_plan(graph)
    contract = contracts.CommContract(
        name=f"certificate-plan-c{plan.num_colors}-d{prob.d}",
        forbid=("all-gather", "reduce-scatter", "all-to-all"),
        max_collective_permute_bytes=plan.num_colors * prob.d * itemsize,
        max_all_reduce_bytes=(4 * prob.d + 64) * itemsize)
    return _check_comm_to_findings(
        lambda: contracts.check_comm(hlo, contract), "cert-plan")


@register_driver("gap-record")
def check_gap_record() -> List[Finding]:
    prob = _lasso()
    k, itemsize = 4, 4
    hlo = gap_record_hlo(prob, k)
    return _check_comm_to_findings(
        lambda: contracts.check_comm(hlo, contracts.gather_contract(
            "gap-recorder", min_total_bytes=k * prob.d * itemsize)),
        "gap-record")


@register_driver("executor-retrace")
def check_executor_retrace() -> List[Finding]:
    """The block executor must resolve a repeated identical run as cache
    hits (content-addressed keys): any miss on the warmed run is a
    retrace."""
    from repro.core import executor
    from repro.core.cola import ColaConfig, run_cola
    from repro.core import topology as topo

    prob = _ridge()
    cfg = ColaConfig(kappa=1.0)
    graph = topo.ring(8)

    def run():
        run_cola(prob, graph, cfg, 4, record_every=2, executor="block",
                 block_size=2)

    return passes.check_retrace(run, where="executor-retrace")
