# repo root on the path too: benchmarks/ imports `benchmarks.common`
PY := PYTHONPATH=src:. python

.PHONY: verify test quick bench bench-smoke analysis obs-smoke

# tier-1 gate: the full suite + the round-executor benchmark in smoke mode,
# checked against the committed BENCH_cola.json trajectory (>20% slowdown
# fails; tune with BENCH_TOLERANCE)
verify: test bench-smoke

# --durations surfaces the slowest tests in CI logs so wall-time
# regressions (e.g. an unmarked multi-device subprocess test) are visible
test:
	$(PY) -m pytest -x -q --durations=15

# quick path: skip the slow subprocess equivalence tests
quick:
	$(PY) -m pytest -x -q -m "not slow"

# full round-executor benchmark; writes BENCH_cola.json at the repo root
bench:
	$(PY) benchmarks/round_bench.py

bench-smoke:
	$(PY) benchmarks/round_bench.py --smoke --check

# static contract verification: AST lints over src/, every registered
# driver config checked against its declared comm contract, and the
# seeded-violation smoke proving each pass still fires
analysis:
	$(PY) -m repro.analysis --all --selftest

# observability smoke: two telemetry runs (clean fp32 + int8/trim under a
# seeded Byzantine pair) land in a throwaway registry, then every
# repro.obs subcommand runs over them — list, show, diff (which must come
# back telemetry-only against the clean twin's config delta), timeline
obs-smoke:
	$(PY) -m repro.obs --dir $$(mktemp -d) smoke
