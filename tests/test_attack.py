"""The adversarial attack harness (``repro.attack``) and the robust mixing
layer it is defended by.

Covers the three layers of the threat model:

* scenarios as schedule transforms — entry materialization, the derived
  ``atk_dishonest`` ground-truth mask, validation, the W-rewrite flag;
* the robust aggregation rule itself — support-only dependence (the
  property that makes sim and block-plan paths bitwise), gate behavior on
  clean vs sign-flipped neighborhoods, self-override wire semantics;
* driver integration — wire-only lies, free-riders frozen, taps, the
  loop-executor and dist-tap rejections, and the end-to-end story:
  an undefended Byzantine run trips the honest-cohort certificate while
  ``robust="trim"`` neutralizes the same attack with the certificate sound.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import attack
from repro.core import mixing, problems, topology as topo
from repro.core.cola import ColaConfig, run_cola
from repro.data import synthetic
from repro.dist.runtime import run_dist_cola


@pytest.fixture(scope="module")
def lasso_prob():
    x, y, _ = synthetic.regression(48, 24, seed=0)
    return problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)


def _ctx(k=8, rounds=10, d=6, seed=0):
    return attack.AttackContext(graph=topo.connected_cycle(k, 2),
                                rounds=rounds, k=k, d=d,
                                dtype=np.float32, seed=seed)


def _sched(ctx):
    w = topo.metropolis_weights(ctx.graph).astype(np.float32)
    return {"w": np.broadcast_to(w, (ctx.rounds,) + w.shape)}


# ---------------------------------------------------------------- scenarios

def test_scenario_registry_constructs_by_name():
    byz = attack.scenario("byzantine", nodes=(1, 3), mode="scale", scale=2.0)
    assert isinstance(byz, attack.Byzantine) and byz.nodes == (1, 3)
    with pytest.raises(ValueError, match="unknown attack scenario"):
        attack.scenario("not_a_scenario")


def test_scenario_validation_errors():
    ctx = _ctx()
    with pytest.raises(ValueError, match="out of range"):
        attack.apply_attacks(_sched(ctx), attack.Byzantine(nodes=(99,)), ctx)
    with pytest.raises(ValueError, match="unknown Byzantine mode"):
        attack.apply_attacks(_sched(ctx),
                             attack.Byzantine(nodes=(0,), mode="nope"), ctx)
    with pytest.raises(ValueError, match="round window"):
        attack.apply_attacks(
            _sched(ctx), attack.Byzantine(nodes=(0,), start=8, stop=2), ctx)
    with pytest.raises(ValueError, match="self term"):
        attack.apply_attacks(
            _sched(ctx), attack.LinkCorruption(edges=((2, 2),)), ctx)
    with pytest.raises(TypeError, match="not an attack scenario"):
        attack.apply_attacks(_sched(ctx), ["byzantine"], ctx)


def test_byzantine_materializes_coef_and_dishonest_mask():
    ctx = _ctx(rounds=10)
    sched, info = attack.apply_attacks(
        _sched(ctx),
        attack.Byzantine(nodes=(2, 5), mode="sign_flip", scale=3.0,
                         start=4, stop=8), ctx)
    coef = sched["atk_coef"]
    assert coef.shape == (10, 8)
    assert np.all(coef[4:8, [2, 5]] == -3.0)
    # everything outside the node/round window is the identity transform
    untouched = np.ones_like(coef)
    untouched[4:8, [2, 5]] = -3.0
    np.testing.assert_array_equal(coef, untouched)
    # the derived ground truth marks exactly the lying (node, round) cells
    dis = sched["atk_dishonest"]
    np.testing.assert_array_equal(dis != 0.0, coef != 1.0)
    assert "coef" in info.entry_names and "dishonest" in info.entry_names
    assert not info.w_modified and info.tap_nodes == ()


def test_byzantine_random_payload_is_run_constant():
    ctx = _ctx(rounds=6, d=5)
    sched, _ = attack.apply_attacks(
        _sched(ctx), attack.Byzantine(nodes=(1,), mode="random", scale=2.0,
                                      seed=7), ctx)
    assert np.all(sched["atk_coef"][:, 1] == 0.0)
    assert np.all(sched["atk_bias_coef"][:, 1] == 2.0)
    bias = sched["atk_bias"]
    assert bias.shape == (6, 8, 5)
    # the injected direction is drawn once and held for the whole run
    np.testing.assert_array_equal(bias[0], bias[-1])
    assert np.any(bias[0, 1] != 0.0) and np.all(bias[0, 0] == 0.0)


def test_free_rider_zeroes_work_and_stale_emits_initial():
    ctx = _ctx()
    sched, info = attack.apply_attacks(
        _sched(ctx), attack.FreeRider(nodes=(0,), stale=True), ctx)
    assert np.all(sched["atk_work"][:, 0] == 0.0)
    assert np.all(sched["atk_coef"][:, 0] == 0.0)
    assert "work" in info.entry_names


def test_link_corruption_rewrites_w_stack():
    ctx = _ctx()
    base = _sched(ctx)
    w0 = np.array(base["w"][0])
    sched, info = attack.apply_attacks(
        base, attack.LinkCorruption(edges=((0, 1),), scale=0.0, start=2), ctx)
    assert info.w_modified
    assert np.all(sched["w"][2:, 1, 0] == 0.0)
    assert sched["w"][0, 1, 0] == w0[1, 0]        # before the window: intact
    # only the targeted directed edge moved
    assert sched["w"][3, 0, 1] == w0[0, 1]


def test_fraction_resolves_deterministic_node_set():
    ctx = _ctx(k=16)
    a = attack.Byzantine(fraction=0.25, seed=3)
    s1, _ = attack.apply_attacks(_sched(ctx), a, ctx)
    s2, _ = attack.apply_attacks(_sched(ctx), a, ctx)
    np.testing.assert_array_equal(s1["atk_coef"], s2["atk_coef"])
    assert (s1["atk_coef"][0] != 1.0).sum() == 4   # 0.25 * 16


# ---------------------------------------------------- robust aggregation

def _neighborhood_case(rng, k=8, d=12):
    graph = topo.connected_cycle(k, 2)
    w = jnp.asarray(topo.metropolis_weights(graph), jnp.float32)
    buf = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    return w, buf


@pytest.mark.parametrize("mode", mixing.ROBUST_MODES)
@pytest.mark.parametrize("override", [False, True])
def test_robust_mix_depends_only_on_neighborhood_support(mode, override):
    """The bitwise sim<->block contract: rows outside a node's W support
    must not influence its aggregate (block mode zero-fills them, sim mode
    carries true values — both paths must agree exactly)."""
    rng = np.random.default_rng(0)
    w, buf = _neighborhood_case(rng)
    k = buf.shape[0]
    ids = jnp.arange(k)
    ov = (jnp.asarray(rng.standard_normal(buf.shape), jnp.float32)
          if override else None)
    full = mixing.robust_neighborhood_mix(w, buf, ids, mode,
                                          self_override=ov)
    # zero out every (row i reads slot j) pair outside the support, one
    # node at a time, exactly like the block path's assembled buffer
    mask = np.asarray(w) != 0.0
    np.fill_diagonal(mask, True)
    for i in range(k):
        zeroed = jnp.where(jnp.asarray(mask[i])[:, None], buf, 0.0)
        row = mixing.robust_neighborhood_mix(
            w[i:i + 1], zeroed, ids[i:i + 1], mode,
            self_override=None if ov is None else ov[i:i + 1])
        np.testing.assert_array_equal(np.asarray(row[0]),
                                      np.asarray(full[i]),
                                      err_msg=f"{mode} row {i} depends on "
                                              "out-of-support slots")


def test_robust_modes_are_linear_on_clean_neighborhoods():
    """Honest payloads (same dual point + noise) must pass the gate: trim
    and median reduce exactly to the linear W-mean on a clean buffer."""
    rng = np.random.default_rng(1)
    w, _ = _neighborhood_case(rng)
    common = rng.standard_normal(12)
    buf = jnp.asarray(common + 0.05 * rng.standard_normal((8, 12)),
                      jnp.float32)
    linear = w @ buf
    for mode in ("trim", "median"):
        out = mixing.robust_mix_dense(w, buf, mode)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(linear),
                                      err_msg=f"{mode} gated a clean run")


def test_trim_neutralizes_sign_flipped_neighbor():
    rng = np.random.default_rng(2)
    w, _ = _neighborhood_case(rng)
    common = rng.standard_normal(12).astype(np.float32)
    buf = np.tile(common, (8, 1)) + 0.05 * rng.standard_normal(
        (8, 12)).astype(np.float32)
    honest = jnp.asarray(buf.copy())
    buf[3] = -10.0 * buf[3]                         # wire lie from node 3
    attacked = jnp.asarray(buf)
    trimmed = mixing.robust_mix_dense(w, attacked, "trim",
                                      self_stack=honest)
    linear = np.asarray(w @ attacked)
    clean = np.asarray(w @ honest)
    out = np.asarray(trimmed)
    # receivers of the lie land far closer to the clean mix than the
    # trusting linear mix does
    for i in (1, 2, 4, 5):                          # neighbors of node 3
        assert np.linalg.norm(out[i] - clean[i]) < \
            0.2 * np.linalg.norm(linear[i] - clean[i])
    # the liar's own aggregate used its honest state (self_override)
    assert np.isfinite(out[3]).all()


def test_robust_mix_rejects_unknown_mode():
    rng = np.random.default_rng(3)
    w, buf = _neighborhood_case(rng)
    with pytest.raises(ValueError, match="unknown robust mode"):
        mixing.robust_mix_dense(w, buf, "winsorize")


def test_robust_mix_steps_applies_wire_attack_once():
    """Multi-step robust gossip: the lie exists on the first exchange only
    — later steps re-mix received (honest) values."""
    rng = np.random.default_rng(4)
    w, buf = _neighborhood_case(rng)
    honest = jnp.asarray(rng.standard_normal(buf.shape), jnp.float32)
    two = mixing.robust_mix_steps(w, buf, "trim", steps=2,
                                  self_stack=honest)
    first = mixing.robust_mix_dense(w, buf, "trim", self_stack=honest)
    second = mixing.robust_mix_dense(w, first, "trim")
    np.testing.assert_array_equal(np.asarray(two), np.asarray(second))


# ---------------------------------------------------- driver integration

def test_attacks_require_block_executor(lasso_prob):
    graph = topo.connected_cycle(8, 2)
    with pytest.raises(ValueError, match="executor='block'"):
        run_cola(lasso_prob, graph, ColaConfig(), rounds=4,
                 executor="loop", attacks=[attack.Byzantine(nodes=(0,))])


def test_identity_link_corruption_is_bitwise_clean(lasso_prob):
    """scale=1.0 rewrites the W stack with the same values: the run (forced
    onto the per-round-coefficient plan path) must match the clean run
    bitwise — the attack plumbing itself is exact."""
    graph = topo.connected_cycle(8, 2)
    cfg = ColaConfig(kappa=2.0)
    clean = run_cola(lasso_prob, graph, cfg, rounds=12, record_every=4)
    noop = run_cola(lasso_prob, graph, cfg, rounds=12, record_every=4,
                    attacks=[attack.LinkCorruption(edges=((0, 1),),
                                                   scale=1.0)])
    np.testing.assert_array_equal(np.asarray(clean.state.x_parts),
                                  np.asarray(noop.state.x_parts))
    np.testing.assert_array_equal(np.asarray(clean.state.v_stack),
                                  np.asarray(noop.state.v_stack))


def test_free_rider_rides_but_run_converges(lasso_prob):
    graph = topo.connected_cycle(8, 2)
    cfg = ColaConfig(kappa=2.0)
    res = run_cola(lasso_prob, graph, cfg, rounds=30, record_every=10,
                   attacks=[attack.FreeRider(nodes=(2,))])
    clean = run_cola(lasso_prob, graph, cfg, rounds=30, record_every=10)
    x = np.asarray(res.state.x_parts)
    assert np.all(x[2] == 0.0)                    # never did local work
    assert np.any(x[1] != 0.0) and np.any(x[3] != 0.0)
    # a single free-rider slows but does not break convergence
    assert res.history["primal"][-1] < 1.5 * clean.history["primal"][-1] + 1.0


def test_eavesdropper_taps_record_wire_payloads(lasso_prob):
    graph = topo.connected_cycle(8, 2)
    cfg = ColaConfig(kappa=2.0)
    tap = attack.Eavesdropper(nodes=(3, 0))
    byz = attack.Byzantine(nodes=(3,), mode="sign_flip", scale=2.0, start=4)
    clean = run_cola(lasso_prob, graph, cfg, rounds=8, record_every=4,
                     attacks=[tap])
    lied = run_cola(lasso_prob, graph, cfg, rounds=8, record_every=4,
                    attacks=[tap, byz])
    assert clean.taps is not None and clean.taps.shape[:2] == (8, 2)
    # before the onset the dynamics are identical; at the first attacked
    # round the states still agree, so the emitted payload is exactly
    # coef * the clean payload — the tap sees what crossed the wire
    np.testing.assert_array_equal(np.asarray(lied.taps[:4]),
                                  np.asarray(clean.taps[:4]))
    np.testing.assert_allclose(np.asarray(lied.taps[4, 0]),
                               -2.0 * np.asarray(clean.taps[4, 0]),
                               rtol=1e-6)
    # the honest tapped node's round-4 payload is untouched
    np.testing.assert_array_equal(np.asarray(lied.taps[4, 1]),
                                  np.asarray(clean.taps[4, 1]))


def test_dist_runtime_rejects_taps(lasso_prob):
    graph = topo.connected_cycle(8, 2)
    mesh = jax.make_mesh((1,), ("nodes",))
    with pytest.raises(ValueError, match="simulator-only"):
        run_dist_cola(lasso_prob, graph, ColaConfig(), mesh, rounds=4,
                      comm="plan", attacks=[attack.Eavesdropper(nodes=(0,))])


def test_undefended_detected_trim_certified(lasso_prob):
    """The end-to-end robustness story on the canonical scenario (small):
    an undefended seeded sign-flip run trips the honest-cohort certificate;
    ``robust="trim"`` neutralizes it and certifies the eps gap within 2x
    the clean round count."""
    graph = topo.torus_2d(4, 4)
    byz = attack.Byzantine(nodes=(0, 10), mode="sign_flip", scale=10.0,
                           start=5, seed=1)

    def go(robust, atk):
        cfg = ColaConfig(kappa=2.0, robust=robust)
        return run_cola(lasso_prob, graph, cfg, rounds=600, record_every=20,
                        recorder="gap+certificate", eps=1.0,
                        attacks=([atk] if atk else None)).history

    clean = go(None, None)
    assert clean["stop_round"] is not None and clean["violated_round"] is None
    undefended = go(None, byz)
    assert undefended["violated_round"] is not None, \
        "undefended sign-flip went undetected"
    trim = go("trim", byz)
    assert trim["violated_round"] is None
    assert trim["stop_round"] is not None
    assert trim["stop_round"] <= 2 * clean["stop_round"]
