"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
same-family variant, one forward + one train step on CPU; output shapes and
finiteness asserted. Decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, get_config, smoke_variant
from repro.models import transformer
from repro.models.blocks import ModelCtx
from repro.models.model import build_model
from repro.train.steps import TrainHParams, init_train_state, make_train_step


def _batch(cfg, key, b=2, s=16):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.num_prefix_tokens, cfg.frontend_dim))
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(key, (b, 12, cfg.frontend_dim))
    return batch


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    cfg = smoke_variant(get_config(request.param))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return request.param, cfg, api, params


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, api, params = arch_setup
    b, s = 2, 16
    batch = _batch(cfg, jax.random.PRNGKey(1), b, s)
    logits, aux = api.forward(params, batch)
    extra = cfg.num_prefix_tokens if cfg.family == "vlm" else 0
    assert logits.shape == (b, s + extra, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert bool(jnp.isfinite(aux))


def test_one_train_step_no_nans(arch_setup):
    arch, cfg, api, params = arch_setup
    hp = TrainHParams(lr=1e-3)
    state = init_train_state(cfg, jax.random.PRNGKey(2), hp)
    step = make_train_step(cfg, hp)
    batch = _batch(cfg, jax.random.PRNGKey(3))
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


def test_decode_matches_forward(arch_setup):
    arch, cfg, api, params = arch_setup
    ctx = ModelCtx(moe_mode="dense")  # exact MoE (no capacity dropping)
    if cfg.num_experts:
        # route to ALL experts: top-k selection among near-tied router probs
        # is shape-dependent at the last ulp, but the all-experts weighted
        # combine is selection-order invariant -> decode comparison is exact.
        import dataclasses
        cfg = dataclasses.replace(cfg, experts_per_token=cfg.num_experts)
        from repro.models.model import build_model as _bm
        api = _bm(cfg)
    b, s = 2, 10
    tokens = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0,
                                cfg.vocab_size)
    batch = _batch(cfg, jax.random.PRNGKey(4), b, s)
    batch["tokens"] = tokens
    full, _ = api.forward(params, batch, ctx)
    cache = api.init_cache(params, b,
                           s + 4 + (cfg.num_prefix_tokens
                                    if cfg.family == "vlm" else 0))
    kw = {}
    pre = dict(batch)
    pre["tokens"] = tokens[:, :-1]
    if cfg.family == "encdec":
        enc_out, enc_pos = api.encode(params, batch["enc_embeds"])
        kw = {"enc_kv": transformer._enc_kv_all_layers(cfg, params, enc_out),
              "enc_pos": enc_pos}
    _, cache = api.prefill(params, pre, cache, ctx)
    pos = s - 1 + (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
    dec, _ = api.decode_step(params, tokens[:, -1:],
                             jnp.asarray(pos, jnp.int32), cache, **kw)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-4,
                               err_msg=arch)


def test_param_counts_match_full_config_order():
    """Full configs instantiate abstractly with plausible parameter counts."""
    import re
    from repro.launch.specs import params_specs, param_bytes
    expect = {  # rough total params in billions (wide tolerance)
        "qwen3_4b": (3, 6), "stablelm_12b": (9, 15), "xlstm_125m": (0.1, 0.3),
        "h2o_danube3_4b": (3, 6), "llama4_maverick_400b": (350, 480),
        "dbrx_132b": (110, 160), "mistral_large_123b": (100, 140),
        "seamless_m4t_medium": (0.5, 2.0), "internvl2_26b": (19, 30),
        # assignment spec (81L, d=3584, expand=2) gives ~4.6B; the marketed
        # 7B includes dual shared blocks + LoRA adapters we don't replicate
        "zamba2_7b": (4, 10),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        n = sum(l.size for l in jax.tree.leaves(params_specs(cfg)))
        assert lo * 1e9 <= n <= hi * 1e9, (arch, n / 1e9)
