"""Prop. 1 local certificates: soundness (certified => gap <= eps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, build_env, init_state, make_round, \
    run_cola
from repro.core.duality import (block_spectral_norms, gap_report,
                                local_certificates, neighbor_mask,
                                neighborhood_mean)
from repro.core.partition import make_partition
from repro.data import synthetic


@pytest.fixture(scope="module")
def setup():
    x, y, _ = synthetic.regression(150, 48, seed=2, sparsity_solution=0.2)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)
    k = 8
    graph = topo.connected_cycle(k, 2)
    part = make_partition(prob.n, k)
    env = build_env(prob, part)
    w = topo.metropolis_weights(graph)
    return prob, graph, part, env, w


def _run(prob, part, env, w, rounds, kappa=4.0):
    state = init_state(prob, part)
    rnd = make_round(prob, part, ColaConfig(kappa=kappa))
    wj = jnp.asarray(w, jnp.float32)
    act = jnp.ones((part.num_nodes,), jnp.float32)
    for _ in range(rounds):
        state = rnd(state, env, wj, act)
    return state


def test_certificate_soundness(setup):
    """Whenever both local conditions hold for every node, the TRUE
    decentralized duality gap is <= eps (Prop. 1 statement)."""
    prob, graph, part, env, w = setup
    sigma_k = block_spectral_norms(env.a_parts)
    beta_ub = topo.beta(w)
    for rounds in (5, 40, 200, 600):
        state = _run(prob, part, env, w, rounds)
        rep = gap_report(prob, part, state.x_parts, state.v_stack)
        for eps in (1e-1, 1e0, 1e1, 1e2):
            cert = local_certificates(
                prob, part, state.x_parts, state.v_stack, env.a_parts,
                env.gp_parts, env.masks, graph.adjacency, beta_ub, sigma_k,
                eps, prob.l_bound)
            if bool(cert.certified):
                assert float(rep.gap) <= eps + 1e-6, (rounds, eps)


def test_certificate_eventually_fires(setup):
    """After enough rounds the certificate certifies a moderate eps."""
    prob, graph, part, env, w = setup
    sigma_k = block_spectral_norms(env.a_parts)
    beta_ub = topo.beta(w)
    state = _run(prob, part, env, w, 1200, kappa=8.0)
    rep = gap_report(prob, part, state.x_parts, state.v_stack)
    eps = max(10.0 * float(rep.gap), 1e-3)
    cert = local_certificates(
        prob, part, state.x_parts, state.v_stack, env.a_parts, env.gp_parts,
        env.masks, graph.adjacency, beta_ub, sigma_k, eps, prob.l_bound)
    # condition (9) needs the *local* gaps small; with enough optimization it
    # must fire for an eps an order of magnitude above the true gap
    assert bool(cert.certified), (float(rep.gap), eps,
                                  np.asarray(cert.local_gap),
                                  np.asarray(cert.grad_disagreement))


def test_certificate_upper_bound_monotone_in_eps(setup):
    """Certifying eps implies certifying any eps' >= eps."""
    prob, graph, part, env, w = setup
    sigma_k = block_spectral_norms(env.a_parts)
    beta_ub = topo.beta(w)
    state = _run(prob, part, env, w, 300)
    fired = []
    for eps in (1e-2, 1e-1, 1e0, 1e1, 1e2, 1e3):
        cert = local_certificates(
            prob, part, state.x_parts, state.v_stack, env.a_parts,
            env.gp_parts, env.masks, graph.adjacency, beta_ub, sigma_k, eps,
            prob.l_bound)
        fired.append(bool(cert.certified))
    # once true, stays true for larger eps
    first = fired.index(True) if True in fired else len(fired)
    assert all(fired[first:])


def test_block_spectral_norms_cache_short_circuits(setup):
    """The sigma_k cache skips the power iteration; bad shapes are rejected."""
    prob, graph, part, env, w = setup
    sigma = block_spectral_norms(env.a_parts)
    cached = block_spectral_norms(env.a_parts, cache=sigma)
    np.testing.assert_array_equal(np.asarray(cached), np.asarray(sigma))
    with pytest.raises(ValueError, match="cache"):
        block_spectral_norms(env.a_parts, cache=sigma[:-1])


def test_masked_neighborhood_mean_matches_neighbor_average(setup):
    """The masked formulation averages exactly the values a gossip exchange
    delivers: own gradient + each adjacency neighbor's."""
    prob, graph, part, env, w = setup
    k = graph.num_nodes
    rng = np.random.default_rng(0)
    grads = jnp.asarray(rng.normal(size=(k, prob.d)), jnp.float32)
    mask = neighbor_mask(graph.adjacency, k)
    mean = np.asarray(neighborhood_mean(grads, mask))
    for node in range(k):
        neigh = sorted(set(graph.neighbors(node)) | {node})
        np.testing.assert_allclose(
            mean[node], np.asarray(grads)[neigh].mean(axis=0),
            rtol=1e-5, atol=1e-6)
    # passing the mixing matrix instead of the adjacency uses its support
    mask_w = neighbor_mask(topo.metropolis_weights(graph), k)
    np.testing.assert_array_equal(np.asarray(mask_w), np.asarray(mask))


# ---------------------------------------------------------------------------
# Prop.-1 soundness as a property: certified == True  =>  gap <= eps
# ---------------------------------------------------------------------------

_PROP_TOPOS = {  # name -> builder valid for every sampled K
    "ring": topo.ring,
    "complete": topo.complete,
    "star": topo.star,
    "cycle2": lambda k: topo.connected_cycle(k, 2) if k >= 5 else topo.ring(k),
}


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 10 ** 6), k=st.sampled_from([2, 4, 8]),
       topo_name=st.sampled_from(sorted(_PROP_TOPOS)),
       lam=st.sampled_from([1e-2, 5e-2]),
       eps_scale=st.sampled_from([0.5, 3.0, 30.0]),
       rounds=st.sampled_from([15, 80, 300]))
def test_certificate_soundness_property(seed, k, topo_name, lam, eps_scale,
                                        rounds):
    """Across random problems/topologies/partitions: every recorded row with
    certified == 1 has the TRUE decentralized duality gap <= eps (the
    recorder runs the composed gap+certificate row, so both sides of the
    implication come from the same round's state)."""
    rng = np.random.default_rng(seed)
    n_samples = int(rng.integers(40, 90))
    n_features = int(rng.integers(24, 48))  # K rarely divides n: padding hit
    x, y, _ = synthetic.regression(n_samples, n_features, seed=seed,
                                   sparsity_solution=0.3)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), lam, box=5.0)
    graph = _PROP_TOPOS[topo_name](k)

    probe = run_cola(prob, graph, ColaConfig(kappa=4.0), rounds,
                     record_every=max(rounds // 3, 1))
    eps = max(eps_scale * probe.history["gap"][-1], 1e-3)
    res = run_cola(prob, graph, ColaConfig(kappa=4.0), rounds,
                   record_every=max(rounds // 6, 1),
                   recorder="gap+certificate", eps=eps)
    h = res.history
    for gap, certified in zip(h["gap"], h["certified"]):
        if certified:
            assert gap <= eps + 1e-6, (topo_name, k, eps, gap)
    if h["stop_round"] is not None:  # stopped == last row certified
        assert h["certified"][-1] == 1.0


def test_certificates_sound_under_churn_round(setup):
    """Regression: after a node leaves and the Metropolis weights rebalance,
    evaluating the certificate against the REWEIGHTED W's support (what the
    surviving nodes' gossip exchange actually provides) stays sound."""
    prob, graph, part, env, w = setup
    k = graph.num_nodes
    rng = np.random.default_rng(5)

    def churn(t, _rng):
        active = np.ones(k, dtype=bool)
        if t % 3 == 2:
            active[int(rng.integers(0, k))] = False
        return active

    res = run_cola(prob, graph, ColaConfig(kappa=6.0), 400,
                   record_every=399, active_schedule=churn,
                   leave_mode="freeze", seed=5)
    state = res.state
    rep = gap_report(prob, part, state.x_parts, state.v_stack)
    sigma_k = block_spectral_norms(env.a_parts)
    # the final round's surviving subnetwork: node 2 dropped, W reweighted
    active = np.ones(k, dtype=bool)
    active[2] = False
    w_churn = topo.reweight_for_active(graph, active)
    for eps in (1e-1, 1e0, 1e1, 1e2, 1e3):
        cert = local_certificates(
            prob, part, state.x_parts, state.v_stack, env.a_parts,
            env.gp_parts, env.masks, w_churn, topo.beta(w_churn), sigma_k,
            eps, prob.l_bound)
        if bool(cert.certified):
            assert float(rep.gap) <= eps + 1e-6, eps
    # the reweighted mask really excludes the leaver from its neighbors
    mask = np.asarray(neighbor_mask(w_churn, k))
    assert mask[2].sum() == 1.0  # leaver: self only
    for j in graph.neighbors(2):
        assert mask[j, 2] == 0.0


def test_recorder_certificates_sound_under_churn(setup):
    """The DRIVER path under churn: run_cola with a certificate recorder and
    an active_schedule judges every record round against the reweighted
    exchange (dynamic mask + active-subnetwork beta), and every certified
    row is sound against the true gap recorded in the same row."""
    from repro.core import metrics as metrics_lib

    prob, graph, part, env, w = setup
    k = graph.num_nodes
    eps = 10.0

    def churn(t, rng):
        return rng.random(k) < 0.75

    for executor in ("block", "loop"):
        res = run_cola(prob, graph, ColaConfig(kappa=8.0), 500,
                       record_every=20, recorder="gap+certificate", eps=eps,
                       active_schedule=churn, seed=11, executor=executor)
        h = res.history
        for gap, certified in zip(h["gap"], h["certified"]):
            if certified:
                assert gap <= eps + 1e-6, (executor, gap)
        assert h["stop_round"] is not None, executor  # still certifies
    # the driver really switched the recorder to the dynamic (churn) mode
    rec = metrics_lib.make_recorder(
        "certificate", prob, part, env, graph,
        topo.metropolis_weights(graph), eps)
    assert not rec.dynamic
    assert metrics_lib.first_certificate(metrics_lib.dynamize(rec)).dynamic
    # per-round inputs: dropped node leaves the mask, threshold tightens
    # with the sparser active subnetwork's beta
    active = np.ones(k, dtype=bool)
    active[2] = False
    w_churn = topo.reweight_for_active(graph, active)
    mask, thr = metrics_lib.certificate_round_inputs(rec, w_churn, active)
    assert mask[2].sum() == 1 and not mask[3, 2]
    _, thr_full = metrics_lib.certificate_round_inputs(
        rec, topo.metropolis_weights(graph), np.ones(k, dtype=bool))
    assert thr <= thr_full + 1e-12


# ---------------------------------------------------------------------------
# The attack dichotomy as a property: a lying participant is never silent
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 10 ** 6),
       n_byz=st.sampled_from([1, 2]),
       scale=st.sampled_from([5.0, 10.0]),
       start=st.sampled_from([0, 5]))
def test_attack_detected_or_neutralized_property(seed, n_byz, scale, start):
    """Across random Byzantine placements (fraction >= 1/K sign-flip):
    EITHER the undefended run visibly breaks AND the honest-cohort
    certificate trips ``certificate_violated`` — no silent poisoning of a
    run that claims a gap guarantee — OR ``robust="trim"`` neutralizes the
    attack (converges within 2x the clean rounds, certificate sound).
    Adversarial placements (e.g. colluders sharing a neighborhood on a
    small torus) may evade the gate, which is exactly when the detection
    arm of the dichotomy must hold instead. The clean defended run must
    never false-alarm."""
    from repro import attack

    x, y, _ = synthetic.regression(48, 24, seed=0)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)
    k = 16
    graph = topo.torus_2d(4, 4)
    rng = np.random.default_rng(seed)
    nodes = tuple(int(n) for n in
                  rng.choice(k, size=n_byz, replace=False))
    byz = attack.Byzantine(nodes=nodes, mode="sign_flip", scale=scale,
                           start=start)

    def go(robust, atk):
        return run_cola(prob, graph, ColaConfig(kappa=2.0, robust=robust),
                        rounds=600, record_every=20,
                        recorder="gap+certificate", eps=1.0,
                        attacks=([atk] if atk else None)).history

    clean = go("trim", None)
    assert clean["violated_round"] is None, \
        "clean trim run false-alarmed the certificate"
    assert clean["stop_round"] is not None

    undefended = go(None, byz)
    broken_and_detected = (
        undefended["violated_round"] is not None
        and (undefended["stop_round"] is None
             or undefended["stop_round"] >= undefended["violated_round"]))

    trim = go("trim", byz)
    neutralized = (trim["violated_round"] is None
                   and trim["stop_round"] is not None
                   and trim["stop_round"] <= 2 * clean["stop_round"])
    assert broken_and_detected or neutralized, (
        nodes, scale, start,
        undefended["violated_round"], undefended["stop_round"],
        trim["violated_round"], trim["stop_round"])
