"""Prop. 1 local certificates: soundness (certified => gap <= eps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, build_env, init_state, make_round
from repro.core.duality import (block_spectral_norms, gap_report,
                                local_certificates)
from repro.core.partition import make_partition
from repro.data import synthetic


@pytest.fixture(scope="module")
def setup():
    x, y, _ = synthetic.regression(150, 48, seed=2, sparsity_solution=0.2)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)
    k = 8
    graph = topo.connected_cycle(k, 2)
    part = make_partition(prob.n, k)
    env = build_env(prob, part)
    w = topo.metropolis_weights(graph)
    return prob, graph, part, env, w


def _run(prob, part, env, w, rounds, kappa=4.0):
    state = init_state(prob, part)
    rnd = make_round(prob, part, ColaConfig(kappa=kappa))
    wj = jnp.asarray(w, jnp.float32)
    act = jnp.ones((part.num_nodes,), jnp.float32)
    for _ in range(rounds):
        state = rnd(state, env, wj, act)
    return state


def test_certificate_soundness(setup):
    """Whenever both local conditions hold for every node, the TRUE
    decentralized duality gap is <= eps (Prop. 1 statement)."""
    prob, graph, part, env, w = setup
    sigma_k = block_spectral_norms(env.a_parts)
    beta_ub = topo.beta(w)
    for rounds in (5, 40, 200, 600):
        state = _run(prob, part, env, w, rounds)
        rep = gap_report(prob, part, state.x_parts, state.v_stack)
        for eps in (1e-1, 1e0, 1e1, 1e2):
            cert = local_certificates(
                prob, part, state.x_parts, state.v_stack, env.a_parts,
                env.gp_parts, env.masks, graph.adjacency, beta_ub, sigma_k,
                eps, prob.l_bound)
            if bool(cert.certified):
                assert float(rep.gap) <= eps + 1e-6, (rounds, eps)


def test_certificate_eventually_fires(setup):
    """After enough rounds the certificate certifies a moderate eps."""
    prob, graph, part, env, w = setup
    sigma_k = block_spectral_norms(env.a_parts)
    beta_ub = topo.beta(w)
    state = _run(prob, part, env, w, 1200, kappa=8.0)
    rep = gap_report(prob, part, state.x_parts, state.v_stack)
    eps = max(10.0 * float(rep.gap), 1e-3)
    cert = local_certificates(
        prob, part, state.x_parts, state.v_stack, env.a_parts, env.gp_parts,
        env.masks, graph.adjacency, beta_ub, sigma_k, eps, prob.l_bound)
    # condition (9) needs the *local* gaps small; with enough optimization it
    # must fire for an eps an order of magnitude above the true gap
    assert bool(cert.certified), (float(rep.gap), eps,
                                  np.asarray(cert.local_gap),
                                  np.asarray(cert.grad_disagreement))


def test_certificate_upper_bound_monotone_in_eps(setup):
    """Certifying eps implies certifying any eps' >= eps."""
    prob, graph, part, env, w = setup
    sigma_k = block_spectral_norms(env.a_parts)
    beta_ub = topo.beta(w)
    state = _run(prob, part, env, w, 300)
    fired = []
    for eps in (1e-2, 1e-1, 1e0, 1e1, 1e2, 1e3):
        cert = local_certificates(
            prob, part, state.x_parts, state.v_stack, env.a_parts,
            env.gp_parts, env.masks, graph.adjacency, beta_ub, sigma_k, eps,
            prob.l_bound)
        fired.append(bool(cert.certified))
    # once true, stays true for larger eps
    first = fired.index(True) if True in fired else len(fired)
    assert all(fired[first:])
