"""Distributed certificate recording: dist-vs-sim parity and the O(d)
collective guarantee.

The in-process tests build the node mesh over ALL visible devices, so the
same file covers the 1-device degenerate case (default suite: every
collective is the identity, parity is bitwise) and a real multi-device mesh
(the CI job runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``). The subprocess test
additionally pins the 4-device ring path — ppermute neighborhood, HLO
lowered to O(d) collectives only — from the default single-device suite.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, run_cola
from repro.data import synthetic
from repro.dist.runtime import run_dist_cola

K = 8
CERT_KEYS = ("local_gap_max", "grad_disagreement_max", "cond9_nodes",
             "cond10_nodes", "certified")


@pytest.fixture(scope="module")
def lasso_prob():
    x, y, _ = synthetic.regression(150, 48, seed=2, sparsity_solution=0.2)
    return problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)


@pytest.fixture(scope="module")
def mesh_all():
    m = jax.device_count()
    assert K % m == 0, f"tests need K={K} divisible by {m} devices"
    return jax.make_mesh((m,), ("data",))


def _bitwise_mesh():
    return jax.device_count() == 1


def test_certificate_dist_matches_sim(lasso_prob, mesh_all):
    """Certificate rows + stop round agree between the simulator and the
    dist runtime — bitwise on a 1-device mesh, to float tolerance on a
    multi-device one (collective reduction order differs)."""
    graph = topo.connected_cycle(K, 2)
    cfg = ColaConfig(kappa=8.0)
    eps = 0.1
    sim = run_cola(lasso_prob, graph, cfg, 600, record_every=25,
                   recorder="certificate", eps=eps)
    dist = run_dist_cola(lasso_prob, graph, cfg, mesh_all, 600, comm="dense",
                         record_every=25, recorder="certificate", eps=eps)
    assert sim.history["stop_round"] == dist.history["stop_round"]
    assert sim.history["round"] == dist.history["round"]
    for name in CERT_KEYS:
        if _bitwise_mesh():
            np.testing.assert_array_equal(sim.history[name],
                                          dist.history[name], err_msg=name)
        else:
            np.testing.assert_allclose(sim.history[name], dist.history[name],
                                       rtol=1e-4, atol=1e-5, err_msg=name)
    if _bitwise_mesh():
        np.testing.assert_array_equal(np.asarray(sim.state.x_parts),
                                      np.asarray(dist.state.x_parts))
        np.testing.assert_array_equal(np.asarray(sim.state.v_stack),
                                      np.asarray(dist.state.v_stack))
    else:
        np.testing.assert_allclose(np.asarray(sim.state.x_parts),
                                   np.asarray(dist.state.x_parts),
                                   rtol=1e-5, atol=1e-6)


def test_certificate_dist_stop_truncates_like_sim(lasso_prob, mesh_all):
    graph = topo.connected_cycle(K, 2)
    cfg = ColaConfig(kappa=8.0)
    dist = run_dist_cola(lasso_prob, graph, cfg, mesh_all, 600, comm="dense",
                         record_every=25, recorder="certificate", eps=0.1)
    t_stop = dist.history["stop_round"]
    assert t_stop is not None and dist.history["round"][-1] == t_stop
    trunc = run_dist_cola(lasso_prob, graph, cfg, mesh_all, t_stop + 1,
                          comm="dense", record_every=25)
    np.testing.assert_array_equal(np.asarray(dist.state.x_parts),
                                  np.asarray(trunc.state.x_parts))
    np.testing.assert_array_equal(np.asarray(dist.state.v_stack),
                                  np.asarray(trunc.state.v_stack))


def test_certificate_dist_under_churn_matches_sim(lasso_prob, mesh_all):
    """Churn flips the certificate into dynamic mode (per-round reweighted
    mask + active-subnetwork threshold) on BOTH drivers; the dist dense
    path consumes the same materialized schedule entries as the sim."""
    graph = topo.connected_cycle(K, 2)
    cfg = ColaConfig(kappa=8.0)

    def churn(t, rng):
        return rng.random(K) < 0.75

    sim = run_cola(lasso_prob, graph, cfg, 500, record_every=20,
                   recorder="certificate", eps=10.0, active_schedule=churn,
                   seed=11)
    dist = run_dist_cola(lasso_prob, graph, cfg, mesh_all, 500, comm="dense",
                         record_every=20, recorder="certificate", eps=10.0,
                         active_schedule=churn, seed=11)
    assert sim.history["stop_round"] == dist.history["stop_round"]
    assert sim.history["stop_round"] is not None
    for name in CERT_KEYS:
        if _bitwise_mesh():
            np.testing.assert_array_equal(sim.history[name],
                                          dist.history[name], err_msg=name)
        else:
            np.testing.assert_allclose(sim.history[name], dist.history[name],
                                       rtol=1e-4, atol=1e-5, err_msg=name)


def test_composed_recorder_dist(lasso_prob, mesh_all):
    """gap+certificate: the gap columns ride the gather path, the
    certificate columns the local path, in ONE recorder."""
    graph = topo.connected_cycle(K, 2)
    sim = run_cola(lasso_prob, graph, ColaConfig(kappa=8.0), 200,
                   record_every=50, recorder="gap+certificate", eps=0.1)
    dist = run_dist_cola(lasso_prob, graph, ColaConfig(kappa=8.0), mesh_all,
                         200, comm="dense", record_every=50,
                         recorder="gap+certificate", eps=0.1)
    assert sim.history["round"] == dist.history["round"]
    for name in ("gap", "certified"):
        np.testing.assert_allclose(sim.history[name], dist.history[name],
                                   rtol=1e-4, atol=1e-5, err_msg=name)


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="ring certificate needs one node per device")
def test_ring_certificate_parity_multidevice(lasso_prob):
    """comm='ring': the ppermute neighborhood mean matches the stacked
    masked-neighbor oracle (CI 4-virtual-device job)."""
    k = jax.device_count()
    mesh = jax.make_mesh((k,), ("data",))
    graph = topo.ring(k)
    cfg = ColaConfig(kappa=8.0)
    sim = run_cola(lasso_prob, graph, cfg, 400, record_every=20,
                   recorder="certificate", eps=0.1)
    dist = run_dist_cola(lasso_prob, graph, cfg, mesh, 400, comm="ring",
                         conn=1, record_every=20, recorder="certificate",
                         eps=0.1)
    assert sim.history["stop_round"] == dist.history["stop_round"]
    for name in CERT_KEYS:
        np.testing.assert_allclose(sim.history[name], dist.history[name],
                                   rtol=1e-4, atol=1e-5, err_msg=name)


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs a real node mesh to lower collectives")
def test_certificate_record_hlo_is_o_d():
    _assert_record_collectives_o_d()


def _assert_record_collectives_o_d():
    """Lower the dist certificate record program for a 4-device ring and
    hold it to ``analysis.contracts.certificate_contract``: O(d) bytes per
    device — no all-gather, collective-permute <= 2*conn*d*itemsize, and an
    all-reduce allowance of (4d + 64)*itemsize covering the scalar row
    reductions plus the (2, d) invariant-sum psum behind the
    consensus_residual / certificate_violated metrics (lowered twice by XLA
    across the early-stop branch) — while the gap recorder's program moves
    >= K*d bytes. Programs come from ``analysis.drivers`` — byte-identical
    to what ``python -m repro.analysis --all`` verifies in CI."""
    from repro.analysis import contracts, drivers

    x, y, _ = synthetic.regression(150, 48, seed=2, sparsity_solution=0.2)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)
    k, conn, itemsize = jax.device_count(), 1, 4
    cert_hlo = drivers.certificate_record_hlo(prob, topo.ring(k), k, conn)
    contracts.check_comm(
        cert_hlo, contracts.certificate_contract(prob.d, conn, itemsize))
    # the gather recorder moves the stacks: >= K*d bytes per device
    gap_hlo = drivers.gap_record_hlo(prob, k)
    contracts.check_comm(gap_hlo, contracts.gather_contract(
        "gap-recorder", min_total_bytes=k * prob.d * itemsize))


# --- subprocess pin: 4-device ring parity + HLO from the 1-device suite ----

RING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import tests.test_certificate_dist as tcd
    import jax, jax.numpy as jnp, numpy as np
    from repro.data import synthetic
    from repro.core import problems, topology as topo
    from repro.core.cola import ColaConfig, run_cola
    from repro.dist.runtime import run_dist_cola

    assert jax.device_count() == 4
    x, y, _ = synthetic.regression(150, 48, seed=2, sparsity_solution=0.2)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)
    mesh = jax.make_mesh((4,), ("data",))
    graph = topo.ring(4)
    cfg = ColaConfig(kappa=8.0)
    sim = run_cola(prob, graph, cfg, 400, record_every=20,
                   recorder="certificate", eps=0.1)
    dist = run_dist_cola(prob, graph, cfg, mesh, 400, comm="ring", conn=1,
                         record_every=20, recorder="certificate", eps=0.1)
    assert sim.history["stop_round"] == dist.history["stop_round"]
    for name in tcd.CERT_KEYS:
        np.testing.assert_allclose(sim.history[name], dist.history[name],
                                   rtol=1e-4, atol=1e-5, err_msg=name)
    tcd._assert_record_collectives_o_d()
    print("CERT_DIST_OK")
""")


@pytest.mark.slow
def test_ring_certificate_4dev_subprocess():
    env = dict(os.environ, PYTHONPATH="src:.")
    out = subprocess.run([sys.executable, "-c", RING_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "CERT_DIST_OK" in out.stdout, out.stdout + "\n" + out.stderr
