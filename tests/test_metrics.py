"""The pluggable recorder layer: GapRecorder bitwise-reproduces the
historical histories, certificate-driven early stopping truncates metrics
and freezes state bitwise, composition and the driver plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics as metrics_lib, problems, topology as topo
from repro.core.cola import ColaConfig, build_env, run_cola
from repro.core.duality import gap_report
from repro.core.partition import make_partition
from repro.data import synthetic

K = 8


@pytest.fixture(scope="module")
def lasso_prob():
    x, y, _ = synthetic.regression(150, 48, seed=2, sparsity_solution=0.2)
    return problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)


@pytest.fixture(scope="module")
def ridge():
    x, y, _ = synthetic.regression(150, 48, seed=4)
    return problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)


@pytest.fixture(scope="module")
def graph():
    return topo.connected_cycle(K, 2)


def test_gap_recorder_row_is_gap_report(ridge, graph):
    """GapRecorder's on-device row == a direct gap_report evaluation — the
    executor refactor is numerics-neutral for the historical metrics."""
    res = run_cola(ridge, graph, ColaConfig(kappa=1.0), 30, record_every=10)
    part = make_partition(ridge.n, K)
    rep = gap_report(ridge, part, res.state.x_parts, res.state.v_stack)
    for name in metrics_lib.GAP_METRICS:
        np.testing.assert_allclose(res.history[name][-1],
                                   float(getattr(rep, name)),
                                   rtol=1e-5, atol=1e-7, err_msg=name)
    assert res.history["stop_round"] is None


def test_gap_recorder_histories_bitwise_stable(ridge, graph):
    """Two identical runs through the recorder layer produce identical
    histories (and the loop driver reproduces the block driver's rounds)."""
    a = run_cola(ridge, graph, ColaConfig(kappa=1.0), 25, record_every=7)
    b = run_cola(ridge, graph, ColaConfig(kappa=1.0), 25, record_every=7)
    assert a.history == b.history
    loop = run_cola(ridge, graph, ColaConfig(kappa=1.0), 25, record_every=7,
                    executor="loop")
    assert loop.history["round"] == a.history["round"]


def _eps_for(prob, graph, rounds=600):
    probe = run_cola(prob, graph, ColaConfig(kappa=8.0), rounds,
                     record_every=rounds - 1)
    return max(10.0 * probe.history["gap"][-1], 1e-1)


def test_certificate_stop_state_bitwise_vs_truncated_run(lasso_prob, graph):
    """The acceptance case: with eps set, the run terminates at first
    certification with final state bitwise identical to the non-stopping
    run truncated at that round, and metrics truncate accordingly."""
    eps = _eps_for(lasso_prob, graph)
    cfg = ColaConfig(kappa=8.0)
    res = run_cola(lasso_prob, graph, cfg, 600, record_every=25,
                   recorder="certificate", eps=eps, block_size=64)
    t_stop = res.history["stop_round"]
    assert t_stop is not None and t_stop < 599
    assert res.history["round"][-1] == t_stop
    assert res.history["certified"][-1] == 1.0
    # every recorded round before the stop is pre-certification
    assert all(c == 0.0 for c in res.history["certified"][:-1])

    trunc = run_cola(lasso_prob, graph, cfg, t_stop + 1, record_every=25)
    np.testing.assert_array_equal(np.asarray(res.state.x_parts),
                                  np.asarray(trunc.state.x_parts))
    np.testing.assert_array_equal(np.asarray(res.state.v_stack),
                                  np.asarray(trunc.state.v_stack))


def test_certificate_stop_loop_matches_block(lasso_prob, graph):
    eps = _eps_for(lasso_prob, graph)
    cfg = ColaConfig(kappa=8.0)
    block = run_cola(lasso_prob, graph, cfg, 600, record_every=25,
                     recorder="certificate", eps=eps, block_size=10)
    loop = run_cola(lasso_prob, graph, cfg, 600, record_every=25,
                    recorder="certificate", eps=eps, executor="loop")
    assert block.history["stop_round"] == loop.history["stop_round"]
    assert block.history["round"] == loop.history["round"]
    np.testing.assert_array_equal(np.asarray(block.state.x_parts),
                                  np.asarray(loop.state.x_parts))


def test_stop_round_invariant_to_block_size(lasso_prob, graph):
    eps = _eps_for(lasso_prob, graph)
    cfg = ColaConfig(kappa=8.0)
    runs = [run_cola(lasso_prob, graph, cfg, 600, record_every=25,
                     recorder="certificate", eps=eps, block_size=bs)
            for bs in (7, 64, 600)]
    stops = {r.history["stop_round"] for r in runs}
    assert len(stops) == 1
    for r in runs[1:]:
        np.testing.assert_array_equal(np.asarray(runs[0].state.x_parts),
                                      np.asarray(r.state.x_parts))


def test_gap_eps_stopping(lasso_prob, graph):
    """The gap recorder's eps stop: terminates once gap <= eps."""
    res = run_cola(lasso_prob, graph, ColaConfig(kappa=8.0), 600,
                   record_every=20, eps=1.0)
    assert res.history["stop_round"] is not None
    assert res.history["gap"][-1] <= 1.0
    assert all(g > 1.0 for g in res.history["gap"][:-1])


def test_composed_recorder_rows_and_stop(lasso_prob, graph):
    eps = _eps_for(lasso_prob, graph)
    res = run_cola(lasso_prob, graph, ColaConfig(kappa=8.0), 600,
                   record_every=25, recorder="gap+certificate", eps=eps)
    labels = metrics_lib.GAP_METRICS + metrics_lib.CERT_METRICS
    for name in labels:
        assert len(res.history[name]) == len(res.history["round"]), name
    # soundness visible in the composed row: gap at certification <= eps
    assert res.history["certified"][-1] == 1.0
    assert res.history["gap"][-1] <= eps


def test_make_recorder_validation(ridge, lasso_prob, graph):
    part = make_partition(ridge.n, K)
    env = build_env(ridge, part)
    w = topo.metropolis_weights(graph)
    with pytest.raises(ValueError, match="eps"):
        metrics_lib.make_recorder("certificate", ridge, part, env, graph, w,
                                  None)
    with pytest.raises(ValueError, match="l_bound"):
        # ridge has unbounded g support: Prop. 1 does not apply
        metrics_lib.make_recorder("certificate", ridge, part, env, graph, w,
                                  1.0)
    with pytest.raises(ValueError, match="unknown recorder"):
        metrics_lib.make_recorder("nope", ridge, part, env, graph, w, None)
    with pytest.raises(ValueError, match="collide"):
        gap = metrics_lib.GapRecorder(ridge, part)
        metrics_lib.ComposedRecorder((gap, gap))


def test_certificate_recorder_reuses_sigma_cache(lasso_prob, graph):
    from repro.core.duality import block_spectral_norms

    part = make_partition(lasso_prob.n, K)
    env = build_env(lasso_prob, part)
    sigma = block_spectral_norms(env.a_parts)
    rec = metrics_lib.certificate_recorder(lasso_prob, part, env, graph,
                                           eps=1.0, sigma_k=sigma)
    assert rec.sigma_k is sigma  # cache short-circuit, no re-iteration
    state = {"sigma_k": rec.sigma_k, "neigh_mask": rec.neigh_mask}
    assert set(rec.init_spec()) == set(state)


def test_collective_footprints(ridge):
    part = make_partition(ridge.n, K)
    gap = metrics_lib.GapRecorder(ridge, part)
    fp = gap.collective_footprint(k=16, d=1000, n_k=100)
    assert fp["all-gather"] == 16 * 1100 * 4
    cert = metrics_lib._FootprintOnly()
    ring = metrics_lib.CertificateRecorder.collective_footprint(
        cert, k=16, d=1000, n_k=100, comm="ring", conn=2)
    assert ring["all-gather"] == 0
    assert ring["collective-permute"] == 2 * 2 * 1000 * 4
    text = metrics_lib.render_footprints(k=16, d=1024, n_k=64)
    assert "certificate" in text and "ring" in text


def test_run_result_history_has_stop_round_key(ridge, graph):
    """Every driver/exec combination exposes stop_round (None w/o eps)."""
    for ex in ("loop", "block"):
        res = run_cola(ridge, graph, ColaConfig(kappa=1.0), 5, executor=ex)
        assert res.history["stop_round"] is None


# ---------------------------------------------------------------------------
# adaptive record cadence (on-device geometric back-off)
# ---------------------------------------------------------------------------

def test_adaptive_cadence_backs_off_geometrically(lasso_prob, graph):
    """Far from eps the record rounds space out geometrically (0, 2, 6, 14,
    ... for base=1/grow=2), capped at max_every; the run still records the
    final round."""
    cad = metrics_lib.AdaptiveCadence(base=1, max_every=16, grow=2, near=0.0)
    # near=0: every ratio is "far", so the cadence is the pure back-off
    res = run_cola(lasso_prob, graph, ColaConfig(kappa=1.0), 80,
                   record_every=cad, recorder="certificate", eps=1e-6)
    rounds = res.history["round"]
    assert rounds[:6] == [0, 2, 6, 14, 30, 46]  # doubling, then capped at 16
    gaps = np.diff(rounds)
    assert gaps.max() <= 16
    assert rounds[-1] == 79  # last round always records
    # far-phase recording is O(log T) + T/max_every, nowhere near T rows
    assert len(rounds) < 80 // 8


def test_adaptive_cadence_tightens_near_certification(lasso_prob, graph):
    """Near the threshold the cadence snaps back to base, so certification
    is detected within base rounds of becoming true."""
    eps = _eps_for(lasso_prob, graph)
    cfg = ColaConfig(kappa=8.0)
    cad = metrics_lib.AdaptiveCadence(base=1, max_every=64, grow=2, near=8.0)
    ada = run_cola(lasso_prob, graph, cfg, 600, record_every=cad,
                   recorder="certificate", eps=eps)
    fix = run_cola(lasso_prob, graph, cfg, 600, record_every=1,
                   recorder="certificate", eps=eps)
    assert ada.history["stop_round"] is not None
    # tightened-to-base tail: certification is at most base + one back-off
    # step late relative to the every-round reference
    assert ada.history["stop_round"] >= fix.history["stop_round"]
    assert ada.history["stop_round"] <= fix.history["stop_round"] + \
        cad.max_every
    # far fewer rows than the fixed-cadence reference
    assert len(ada.history["round"]) < len(fix.history["round"])
    # stopped state is still bitwise the truncated run at ITS stop round
    trunc = run_cola(lasso_prob, graph, cfg, ada.history["stop_round"] + 1,
                     record_every=25)
    np.testing.assert_array_equal(np.asarray(ada.state.x_parts),
                                  np.asarray(trunc.state.x_parts))


def test_adaptive_cadence_loop_matches_block(lasso_prob, graph):
    """The loop driver's host-side controller reproduces the block engine's
    on-device decisions: identical record rounds and stop round."""
    cfg = ColaConfig(kappa=8.0)
    cad = metrics_lib.AdaptiveCadence(base=1, max_every=32, grow=2, near=8.0)
    kw = dict(record_every=cad, recorder="certificate", eps=0.1)
    block = run_cola(lasso_prob, graph, cfg, 600, block_size=64, **kw)
    small = run_cola(lasso_prob, graph, cfg, 600, block_size=10, **kw)
    loop = run_cola(lasso_prob, graph, cfg, 600, executor="loop", **kw)
    assert block.history["round"] == loop.history["round"]
    assert block.history["round"] == small.history["round"]
    assert block.history["stop_round"] == loop.history["stop_round"]
    np.testing.assert_array_equal(np.asarray(block.state.x_parts),
                                  np.asarray(loop.state.x_parts))
    for name in metrics_lib.CERT_METRICS:
        np.testing.assert_allclose(block.history[name], loop.history[name],
                                   rtol=1e-5, atol=1e-6, err_msg=name)


def test_adaptive_cadence_gap_recorder_and_validation(ridge, lasso_prob,
                                                      graph):
    res = run_cola(lasso_prob, graph, ColaConfig(kappa=8.0), 300,
                   record_every="adaptive", recorder="gap", eps=1e-3)
    assert res.history["round"][0] == 0
    assert np.diff(res.history["round"]).max() > 1  # backed off somewhere
    # gap recorder without eps has no ratio: adaptive must refuse
    with pytest.raises(ValueError, match="adaptive record cadence needs"):
        run_cola(lasso_prob, graph, ColaConfig(kappa=8.0), 20,
                 record_every="adaptive", recorder="gap")
    with pytest.raises(ValueError, match="base >= 1"):
        metrics_lib.AdaptiveCadence(base=0)
    assert metrics_lib.as_cadence(5) is None
    assert metrics_lib.as_cadence("adaptive") == metrics_lib.AdaptiveCadence()


def test_adaptive_cadence_under_churn(lasso_prob, graph):
    """Adaptive cadence composes with the dynamic (churn) certificate: any
    round may record, so the certificate schedule materializes every
    round's mask/threshold."""
    def churn(t, rng):
        return rng.random(K) < 0.75

    cfg = ColaConfig(kappa=8.0)
    cad = metrics_lib.AdaptiveCadence(base=1, max_every=16, grow=2, near=8.0)
    kw = dict(record_every=cad, recorder="certificate", eps=10.0,
              active_schedule=churn, seed=11)
    block = run_cola(lasso_prob, graph, cfg, 300, **kw)
    loop = run_cola(lasso_prob, graph, cfg, 300, executor="loop", **kw)
    assert block.history["round"] == loop.history["round"]
    assert block.history["stop_round"] == loop.history["stop_round"]
