"""Decentralized baselines (DGD / DIGing / D-ADMM) and the Fig. 2 claim."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as bl
from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, run_cola, solve_reference
from repro.data import synthetic


@pytest.fixture(scope="module")
def data():
    return synthetic.regression(200, 32, seed=5)


@pytest.fixture(scope="module")
def cons(data):
    x, y, _ = data
    return bl.make_consensus_problem(x, y, 8, loss="square", reg="l2",
                                     lam=1e-2)


def test_dgd_decreases_objective(cons):
    res = bl.run_dgd(cons, topo.ring(8), step=0.3, rounds=150,
                     record_every=30)
    obj = res.history["objective"]
    assert obj[-1] < obj[0]


def test_diging_reaches_higher_accuracy_than_dgd(cons):
    """Gradient tracking beats plain DGD at a fixed constant step."""
    dgd = bl.run_dgd(cons, topo.ring(8), step=0.3, rounds=400,
                     record_every=399)
    dig = bl.run_diging(cons, topo.ring(8), step=0.3, rounds=400,
                        record_every=399)
    assert dig.history["objective"][-1] <= dgd.history["objective"][-1] + 1e-8
    # DIGing drives consensus error down as well
    assert dig.history["consensus"][-1] < 1e-3


def test_dadmm_converges(cons):
    res = bl.run_dadmm(cons, topo.ring(8), rho=1.0, rounds=300,
                       inner_steps=10, record_every=299)
    obj = res.history["objective"]
    assert obj[-1] < obj[0]


def test_cola_outperforms_diging_at_equal_communication():
    """Fig. 2 (qualitative): on an ill-conditioned ridge problem, at equal
    communicated bytes (DIGing sends TWO vectors per round — iterate and
    gradient tracker — so it gets half the rounds), CoLA's suboptimality is
    lower than grid-searched DIGing's; and DIGing diverges for slightly too
    large steps while CoLA is parameter-free."""
    x, y, _ = synthetic.regression(200, 32, seed=5)
    x = (x * np.logspace(-1, 1, 32)).astype(np.float32)  # condition ~1e4
    lam = 1e-2
    prob = problems.ridge_dual(jnp.asarray(x), jnp.asarray(y), lam)
    opt = solve_reference(prob, rounds=2500, kappa=10)
    rounds = 120
    res = run_cola(prob, topo.ring(8), ColaConfig(kappa=8.0), rounds=rounds,
                   record_every=rounds - 1)
    cola_sub = res.history["primal"][-1] - opt

    cons = bl.make_consensus_problem(x, y, 8, loss="square", reg="l2",
                                     lam=lam)
    best = np.inf
    w_opt = np.linalg.solve(x.T @ x + lam * np.eye(x.shape[1]), x.T @ y)
    f_opt = float(cons.objective(jnp.asarray(w_opt)))
    diverged = False
    for step in (0.003, 0.01, 0.02, 0.05, 0.1):
        r = bl.run_diging(cons, topo.ring(8), step=step, rounds=rounds // 2,
                          record_every=rounds // 2 - 1)
        val = r.history["objective"][-1] - f_opt
        if np.isfinite(val) and val < 1e3:
            best = min(best, val)
        else:
            diverged = True
    assert cola_sub <= best * 1.05, (cola_sub, best)
    assert diverged  # the step-size fragility CoLA avoids (paper §4)
