"""The Pallas flash-attention kernel as a model backend: full-forward
equivalence against the jnp chunked-scan backend, per attention variant."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, smoke_variant
from repro.models.model import build_model

ARCHS = ["qwen3_4b",        # full attention + qk-norm
         "h2o_danube3_4b",  # sliding window
         "llama4_maverick_400b",  # chunked-local (+ interleaved MoE)
         "seamless_m4t_medium"]   # cross attention


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_matches_jnp_backend(arch):
    cfg = smoke_variant(get_config(arch))
    api_jnp = build_model(cfg)
    api_pl = build_model(dataclasses.replace(cfg, attn_backend="pallas"))
    params = api_jnp.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                          cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, 12, cfg.frontend_dim))
    a, _ = api_jnp.forward(params, batch)
    b, _ = api_pl.forward(params, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                               rtol=1e-4)


def test_decode_with_pallas_backend():
    cfg = dataclasses.replace(smoke_variant(get_config("h2o_danube3_4b")),
                              attn_backend="pallas")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    full, _ = api.forward(params, {"tokens": tokens})
    cache = api.init_cache(params, 2, 16)
    _, cache = api.prefill(params, {"tokens": tokens[:, :-1]}, cache)
    dec, _ = api.decode_step(params, tokens[:, -1:],
                             jnp.asarray(11, jnp.int32), cache)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               atol=3e-4)
