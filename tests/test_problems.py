"""GLM problem definitions: Fenchel duality + prox properties (Lemma 2/3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import problems
from repro.data import synthetic


def _mk(name, seed=0, lam=1e-2):
    x, y, _ = synthetic.regression(40, 16, seed=seed)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    if name.startswith("logistic"):
        yj = jnp.sign(yj) + (jnp.sign(yj) == 0)
    return problems.PROBLEMS[name](xj, yj, lam)


ALL = sorted(problems.PROBLEMS)


@pytest.mark.parametrize("name", ALL)
def test_fenchel_young_inequality_and_equality(name):
    """f(v) + f*(w) >= <v, w>, equality at w = grad f(v)."""
    prob = _mk(name)
    key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, (prob.d,))
    w_opt = prob.grad_f(v)
    lhs = prob.f(v) + prob.f_conj(w_opt)
    rhs = jnp.dot(v, w_opt)
    np.testing.assert_allclose(float(lhs), float(rhs), rtol=2e-4, atol=2e-4)
    # inequality for a perturbed w (scaled, so it stays in dom f* for the
    # logistic conjugate whose domain is u = -w.y in [0, 1])
    w = w_opt * 0.7
    assert float(prob.f(v) + prob.f_conj(w)) >= float(jnp.dot(v, w)) - 1e-4


@pytest.mark.parametrize("name", ALL)
def test_smoothness_constant(name):
    """grad f is (1/tau)-Lipschitz along random directions."""
    prob = _mk(name)
    key = jax.random.PRNGKey(1)
    v1 = jax.random.normal(key, (prob.d,))
    v2 = v1 + 0.1 * jax.random.normal(jax.random.PRNGKey(2), (prob.d,))
    lhs = float(jnp.linalg.norm(prob.grad_f(v1) - prob.grad_f(v2)))
    rhs = float(jnp.linalg.norm(v1 - v2)) / prob.tau
    assert lhs <= rhs * (1 + 1e-5)


@settings(max_examples=40, deadline=None)
@given(name=st.sampled_from(ALL), z=st.floats(-5, 5), step=st.floats(0.05, 5),
       p=st.floats(-2, 2))
def test_prox_is_argmin(name, z, step, p):
    """prox_{g_i, step}(z) minimizes 0.5/step (u - z)^2 + g_i(u) on a grid."""
    prob = _mk(name)
    zj, stepj, pj = map(jnp.float32, (z, step, p))
    if prob.g_param is None:
        pj = jnp.float32(0.0)
    u_star = prob.prox_g_el(zj, stepj, pj)
    obj = lambda u: 0.5 / stepj * (u - zj) ** 2 + prob.g_el(u, pj)
    grid = jnp.linspace(-12.0, 12.0, 4001)
    vals = jax.vmap(obj)(grid)
    best = jnp.nanmin(jnp.where(jnp.isfinite(vals), vals, jnp.nan))
    assert float(obj(u_star)) <= float(best) + 1e-3


@settings(max_examples=30, deadline=None)
@given(name=st.sampled_from(ALL), u=st.floats(-3, 3), x=st.floats(-3, 3),
       p=st.floats(-1, 1))
def test_g_fenchel_young(name, u, x, p):
    """g(x) + g*(u) >= x*u for the separable terms."""
    prob = _mk(name)
    pj = jnp.float32(0.0) if prob.g_param is None else jnp.float32(p)
    g = float(prob.g_el(jnp.float32(x), pj))
    gc = float(prob.g_conj_el(jnp.float32(u), pj))
    if np.isfinite(g) and np.isfinite(gc):
        assert g + gc >= x * u - 1e-4


@pytest.mark.parametrize("name", ["ridge_primal", "ridge_dual"])
def test_ridge_primal_dual_same_optimum(name):
    """The two CoLA mappings of ridge reach the same training objective."""
    x, y, _ = synthetic.regression(60, 20, seed=3)
    lam = 1e-2
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    # closed-form ridge: w = (X^T X + lam I)^-1 X^T y
    w = np.linalg.solve(x.T @ x + lam * np.eye(20), x.T @ y)
    primal_opt = 0.5 * np.sum((x @ w - y) ** 2) + 0.5 * lam * np.sum(w ** 2)
    prob = _mk(name, seed=3)
    prob = problems.PROBLEMS[name](xj, yj, lam)
    # solve with plain (sub)gradient descent on F_A to moderate accuracy
    from repro.core.cola import solve_reference
    val = solve_reference(prob, rounds=400, kappa=8)
    if name == "ridge_primal":
        np.testing.assert_allclose(val, primal_opt, rtol=1e-3)
    else:
        # dual optimum value relates by strong duality:
        # min F_B = -min F_A ... here F_B(w*) = -primal_opt (up to sign conv)
        np.testing.assert_allclose(-val, primal_opt, rtol=1e-3)
