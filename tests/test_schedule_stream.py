"""Streaming on-device schedules + client sampling (partial participation).

The tentpole pins ride here: a streamed participation run (`stream=True`,
the generator evaluated INSIDE the round-block scan) must be bitwise equal
to its materialized twin (`stream=False`, the SAME jax generator evaluated
host-side into classical (T, ...) stacks), and the sampled-subnetwork
certificate must match the churn-oracle run that replays the identical
fold_in draws through the pre-existing `active_schedule=` machinery
(`participation_callable`). The cohort driver (million-node regime, no
(K, K) array anywhere) is pinned against the dense path at small K, and a
K=10^6 / K'=10^3 smoke proves nothing (T, K)-shaped materializes.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import attack
from repro.core import problems, schedule as schedule_lib, topology as topo
from repro.core.cola import ColaConfig, run_cola
from repro.data import synthetic

K = 16
ROUNDS = 24


@pytest.fixture(autouse=True)
def _registry_off(monkeypatch):
    monkeypatch.setenv("REPRO_RUNS_DIR", "off")


@pytest.fixture(scope="module")
def prob():
    x, y, _ = synthetic.regression(48, 16, seed=2, sparsity_solution=0.2)
    return problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)


@pytest.fixture(scope="module")
def graph():
    return topo.complete(K)


def _cfg(k_active=4, *, stream=True, mode="dense", **kw):
    return ColaConfig(kappa=1.0,
                      participation=schedule_lib.SampleConfig(
                          k_active=k_active, mode=mode, stream=stream),
                      **kw)


def _assert_runs_equal(ra, rb, *, what):
    assert np.array_equal(np.asarray(ra.state.x_parts), np.asarray(rb.state.x_parts)), \
        f"{what}: x diverged"
    assert np.array_equal(np.asarray(ra.state.v_stack),
                          np.asarray(rb.state.v_stack)), \
        f"{what}: v_stack diverged"
    assert set(ra.history) == set(rb.history), what
    for key, val in ra.history.items():
        got = rb.history[key]
        if isinstance(val, dict):
            continue  # telemetry sub-dict, covered elsewhere
        assert np.array_equal(np.asarray(val), np.asarray(got)), \
            f"{what}: history[{key!r}] diverged"


# ---------------------------------------------------------------------------
# streamed vs materialized: the bitwise pin
# ---------------------------------------------------------------------------

def test_streamed_vs_stacked_bitwise(prob, graph):
    """`stream=True` (generator inside the scan) and `stream=False` (same
    generator materialized host-side into (T, ...) stacks) are bitwise
    identical — state AND recorded history."""
    runs = {s: run_cola(prob, graph, _cfg(stream=s), ROUNDS,
                        record_every=4, seed=7)
            for s in (True, False)}
    _assert_runs_equal(runs[True], runs[False], what="stream twin")


def test_streamed_certificate_vs_stacked(prob, graph):
    runs = {s: run_cola(prob, graph, _cfg(stream=s), ROUNDS,
                        record_every=4, recorder="gap+certificate",
                        eps=1.0, seed=3)
            for s in (True, False)}
    _assert_runs_equal(runs[True], runs[False], what="certificate twin")


def _oracle_problem():
    # the hypothesis fallback's @given cannot thread pytest fixtures, so the
    # property builds (and caches) its own problem/graph pair
    if not hasattr(_oracle_problem, "cached"):
        x, y, _ = synthetic.regression(48, 16, seed=2,
                                       sparsity_solution=0.2)
        _oracle_problem.cached = (
            problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0),
            topo.complete(K))
    return _oracle_problem.cached


@given(seed=st.integers(0, 10 ** 6), k_active=st.sampled_from([2, 4, 6]))
@settings(max_examples=8, deadline=None)
def test_sampled_certificate_matches_churn_oracle(seed, k_active):
    """Certificate soundness on the sampled subnetwork: a streamed
    participation run must reproduce — exactly — the run the pre-existing
    churn machinery produces when fed the SAME fold_in draws host-side
    (`participation_callable`). Both reweight over the active subgraph,
    both dynamize the certificate; participation is streamed churn."""
    prob, graph = _oracle_problem()
    sample = schedule_lib.SampleConfig(k_active=k_active, mode="dense")
    streamed = run_cola(prob, graph,
                        ColaConfig(kappa=1.0, participation=sample),
                        12, record_every=4, recorder="gap+certificate",
                        eps=1.0, seed=seed)
    oracle = run_cola(prob, graph, ColaConfig(kappa=1.0), 12,
                      record_every=4, recorder="gap+certificate", eps=1.0,
                      seed=seed,
                      active_schedule=schedule_lib.participation_callable(
                          K, sample, seed))
    _assert_runs_equal(streamed, oracle, what="churn oracle")


def test_participation_draws_are_uniform_ksubsets():
    key_runs = schedule_lib.participation_callable(
        K, schedule_lib.SampleConfig(k_active=3), run_seed=0)
    rng = np.random.default_rng(0)
    masks = np.stack([key_runs(t, rng) for t in range(50)])
    assert masks.dtype == bool and masks.shape == (50, K)
    assert (masks.sum(axis=1) == 3).all()
    assert len({tuple(m) for m in map(tuple, masks)}) > 1  # not a constant
    # every node participates eventually (uniform sampling, 50 draws)
    assert masks.any(axis=0).all()


def test_sample_seed_decouples_from_run_seed(prob, graph):
    """`SampleConfig(seed=...)` pins the participation draws independently
    of the run seed: two different run seeds with the same sampler seed
    visit the same active sets."""
    sample = schedule_lib.SampleConfig(k_active=4, seed=11)
    fn_a = schedule_lib.participation_callable(K, sample, run_seed=0)
    fn_b = schedule_lib.participation_callable(K, sample, run_seed=99)
    rng = np.random.default_rng(0)
    for t in range(8):
        assert (fn_a(t, rng) == fn_b(t, rng)).all()


# ---------------------------------------------------------------------------
# cohort mode: the million-node regime
# ---------------------------------------------------------------------------

def test_cohort_matches_dense_small_k(prob, graph):
    """The gather/scatter cohort round is the same Algorithm-1 round the
    dense participation path runs — pinned at small K where both exist."""
    dense = run_cola(prob, graph, _cfg(mode="dense"), ROUNDS,
                     record_every=4, seed=5)
    cohort = run_cola(prob, graph, _cfg(mode="cohort"), ROUNDS,
                      record_every=4, seed=5)
    np.testing.assert_allclose(np.asarray(cohort.state.x_parts),
                               np.asarray(dense.state.x_parts),
                               rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cohort.history["gap"]),
                               np.asarray(dense.history["gap"]),
                               rtol=1e-4, atol=1e-6)


def test_cohort_certificate_small_k(prob, graph):
    """Cohort certificate rows certify the sampled subnetwork: the
    recorded keys exist and the run still converges monotonically-ish."""
    res = run_cola(prob, graph, _cfg(mode="cohort"), ROUNDS,
                   record_every=4, recorder="gap+certificate", eps=1.0,
                   seed=5)
    assert "certified" in res.history
    gaps = np.asarray(res.history["gap"], dtype=np.float64)
    assert np.isfinite(gaps).all()
    assert gaps[-1] < gaps[0]


def test_auto_mode_switches_on_population():
    s = schedule_lib.SampleConfig(k_active=8)
    assert s.resolve_mode(schedule_lib.DENSE_MAX_NODES) == "dense"
    assert s.resolve_mode(schedule_lib.DENSE_MAX_NODES + 1) == "cohort"
    assert schedule_lib.SampleConfig(k_active=2, mode="cohort") \
        .resolve_mode(16) == "cohort"


@pytest.mark.slow
def test_million_node_cohort_smoke():
    """K=10^6, K'=10^3: the population only ever appears as (K,)-shaped
    state — no (T, K) or (K, K) array exists anywhere. A handful of rounds
    must run and record finite metrics."""
    k, k_active, n = 1_000_000, 1_000, 2_000_000
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((8, n)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((8,)).astype(np.float32))
    prob = problems.lasso(a, y, 1e-3)
    cfg = ColaConfig(kappa=1.0, participation=schedule_lib.SampleConfig(
        k_active=k_active))
    assert cfg.participation.resolve_mode(k) == "cohort"
    res = run_cola(prob, topo.implicit_complete(k), cfg, 2,
                   record_every=1, seed=0)
    gaps = np.asarray(res.history["gap"], dtype=np.float64)
    assert gaps.shape[0] >= 1 and np.isfinite(gaps).all()


# ---------------------------------------------------------------------------
# streamed attacks ride the same stream
# ---------------------------------------------------------------------------

def test_streamed_attacks_bitwise(prob, graph):
    """Generative attack transforms (Byzantine random payload, windowed;
    stale FreeRider) composed onto the participation stream are bitwise
    the stacked `apply_attacks` rows — pinned via the stream=False twin."""
    atks = [attack.Byzantine(nodes=(1, 5), mode="random", scale=4.0,
                             start=2, stop=18, seed=13),
            attack.FreeRider(nodes=(9,), stale=True, start=4)]
    runs = {s: run_cola(prob, graph, _cfg(stream=s), ROUNDS,
                        record_every=4, seed=2, attacks=atks)
            for s in (True, False)}
    _assert_runs_equal(runs[True], runs[False], what="streamed attacks")


def test_non_generative_attack_rejected(prob, graph):
    """W-rewriting scenarios have no generative form: composing them with
    a (streaming) participation run must fail loudly, not silently skip."""
    atk = attack.LinkCorruption(edges=((0, 1),), scale=0.0)
    with pytest.raises(NotImplementedError, match="generative"):
        run_cola(prob, graph, _cfg(), ROUNDS, attacks=[atk])


# ---------------------------------------------------------------------------
# configuration validation
# ---------------------------------------------------------------------------

def test_participation_requires_block_executor(prob, graph):
    with pytest.raises(ValueError, match="executor='block'"):
        run_cola(prob, graph, _cfg(), ROUNDS, executor="loop")


def test_participation_requires_complete_graph(prob):
    with pytest.raises(ValueError, match="complete"):
        run_cola(prob, topo.ring(K), _cfg(), ROUNDS)


def test_participation_excludes_active_schedule(prob, graph):
    with pytest.raises(ValueError, match="active_schedule"):
        run_cola(prob, graph, _cfg(), ROUNDS,
                 active_schedule=np.ones((ROUNDS, K), dtype=bool))


def test_participation_type_checked(prob, graph):
    with pytest.raises(TypeError, match="SampleConfig"):
        run_cola(prob, graph,
                 ColaConfig(kappa=1.0, participation={"k_active": 4}),
                 ROUNDS)


def test_sample_config_validation():
    with pytest.raises(ValueError, match="k_active"):
        schedule_lib.SampleConfig(k_active=0)
    with pytest.raises(ValueError, match="mode"):
        schedule_lib.SampleConfig(k_active=2, mode="sparse")
    with pytest.raises(ValueError, match="exceeds"):
        schedule_lib.SampleConfig(k_active=32).resolve_mode(K)


# ---------------------------------------------------------------------------
# footprint accounting (what `dryrun --plan --active` renders)
# ---------------------------------------------------------------------------

def test_schedule_program_footprint_matches_entries():
    parts = schedule_lib.cohort_parts(
        1000, schedule_lib.SampleConfig(k_active=10, mode="cohort"),
        dtype=np.dtype(np.float32), run_seed=0)
    prog = schedule_lib.ScheduleProgram(parts=parts)
    fp = prog.footprint(100)
    assert fp["streamed_bytes"] == sum(fp["entries"].values())
    assert fp["stacked_bytes"] == fp["streamed_bytes"] * 100
    # cohort entries: (K',) int32 indices + (K,) mask — never (K, K)
    assert fp["entries"]["cohort_idx"] == 10 * 4
    assert fp["entries"]["active"] == 1000 * 4


def test_render_stream_footprint_million_nodes():
    text = schedule_lib.render_stream_footprint(
        1_000_000, 1_000, 1_000, 8)
    assert "mode=cohort" in text
    assert "4,004,000 B total" in text            # streamed: one round
    assert "4,004,000,000 B total" in text        # stacked alternative
    small = schedule_lib.render_stream_footprint(16, 4, 100, 8)
    assert "mode=dense" in small and "w" in small


def test_materialize_matches_stream_fn():
    """`materialize` is the host-side evaluation of the same generators the
    scan consumes — entry by entry, round by round, bitwise."""
    parts = schedule_lib.participation_parts(
        8, schedule_lib.SampleConfig(k_active=3, mode="dense"),
        dtype=np.dtype(np.float32), run_seed=4)
    prog = schedule_lib.ScheduleProgram(parts=parts)
    stacked = prog.materialize(6)
    fn = prog.stream_fn()
    for t in range(6):
        row = fn(jnp.int32(t))
        for name, stack in stacked.items():
            assert np.array_equal(stack[t], np.asarray(row[name])), (name, t)
    # masks really hold K' active nodes and W rows renormalize over them
    act = stacked["active"]
    assert (act.sum(axis=1) == 3).all()
    w = stacked["w"]
    np.testing.assert_allclose(w.sum(axis=2), 1.0, rtol=0, atol=1e-6)
