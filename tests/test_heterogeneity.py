"""Heterogeneous per-node solver quality Theta_k (Definition 5) and the
spectral contraction of gossip mixing."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mixing, problems, topology as topo
from repro.core.cola import ColaConfig, run_cola, solve_reference
from repro.core.partition import make_partition
from repro.core.cola import build_env
from repro.core.subproblem import SubproblemSpec, cd_solve_all
from repro.data import synthetic


@pytest.fixture(scope="module")
def ridge():
    x, y, _ = synthetic.regression(200, 64, seed=0)
    return problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)


@pytest.fixture(scope="module")
def opt(ridge):
    return solve_reference(ridge, rounds=800, kappa=10)


def test_budget_zero_equals_no_update(ridge):
    """Theta_k = 1 (budget 0) must leave dx = 0 for that node."""
    k = 4
    part = make_partition(ridge.n, k)
    env = build_env(ridge, part)
    import jax
    grads = jax.vmap(ridge.grad_f)(
        0.1 * jax.random.normal(jax.random.PRNGKey(0), (k, ridge.d)))
    spec = SubproblemSpec(sigma_over_tau=k / ridge.tau, inv_k=1.0 / k)
    budgets = jnp.asarray([part.block, 0, part.block, 0], jnp.int32)
    dx = cd_solve_all(ridge, spec, env.a_parts,
                      jnp.zeros((k, part.block)), grads, env.gp_parts,
                      env.masks, part.block, step_budgets=budgets)
    assert float(jnp.abs(dx[1]).max()) == 0.0
    assert float(jnp.abs(dx[3]).max()) == 0.0
    assert float(jnp.abs(dx[0]).max()) > 0.0


def test_full_budget_matches_homogeneous(ridge):
    """step_budgets = num_steps reproduces the budget-free path exactly."""
    k = 4
    part = make_partition(ridge.n, k)
    env = build_env(ridge, part)
    import jax
    grads = jax.vmap(ridge.grad_f)(
        0.1 * jax.random.normal(jax.random.PRNGKey(1), (k, ridge.d)))
    spec = SubproblemSpec(sigma_over_tau=k / ridge.tau, inv_k=1.0 / k)
    steps = 2 * part.block
    a = cd_solve_all(ridge, spec, env.a_parts, jnp.zeros((k, part.block)),
                     grads, env.gp_parts, env.masks, steps)
    b = cd_solve_all(ridge, spec, env.a_parts, jnp.zeros((k, part.block)),
                     grads, env.gp_parts, env.masks, steps,
                     step_budgets=jnp.full((k,), steps, jnp.int32))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_stragglers_converge_but_slower(ridge, opt):
    """Half the nodes on 1/4 budget: still converges, a bit slower."""
    full = 2 * 8

    def budgets(t, rng):
        b = np.full(8, full)
        b[rng.random(8) < 0.5] = full // 4
        return b

    het = run_cola(ridge, topo.ring(8), ColaConfig(kappa=2.0), rounds=120,
                   record_every=119, budget_schedule=budgets)
    hom = run_cola(ridge, topo.ring(8), ColaConfig(kappa=2.0), rounds=120,
                   record_every=119)
    sub_het = het.history["primal"][-1] - opt
    sub_hom = hom.history["primal"][-1] - opt
    assert sub_het < 0.05          # converged
    assert sub_het >= sub_hom - 1e-6  # but no faster than homogeneous


def test_gossip_contraction_matches_beta():
    """||W v - v_bar|| <= beta ||v - v_bar|| with equality direction possible
    (the spectral quantity the Thm 1/2 rates depend on)."""
    for builder in (topo.ring, lambda k: topo.connected_cycle(k, 2),
                    topo.complete):
        k = 12
        w = topo.metropolis_weights(builder(k))
        beta = topo.beta(w)
        rng = np.random.default_rng(0)
        v = rng.normal(size=(k, 33)).astype(np.float32)
        vbar = v.mean(axis=0, keepdims=True)
        before = np.linalg.norm(v - vbar)
        after = np.linalg.norm(
            np.asarray(mixing.dense_mix(jnp.asarray(w), jnp.asarray(v)))
            - vbar)
        assert after <= beta * before + 1e-4
