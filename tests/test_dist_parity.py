"""shard_map runtime == single-host simulator, bit for bit.

A 1-device mesh runs the real ``repro.dist.runtime`` code — shard_map,
collectives, schedule plumbing — with every collective degenerating to the
identity, so the distributed driver must reproduce ``run_cola`` EXACTLY
(state bitwise; metric rows to fusion rounding, same contract as the
loop-vs-block executor tests). Covers the full elasticity surface: churn
(freeze + reset-on-leave) and heterogeneous CD budgets, over 200+ rounds.

The block-mode suite extends the bitwise contract to REAL multi-device
meshes: ``comm="plan"`` with K=8 paper-nodes on M in {1, 2, 4} devices
(K/M node blocks, block-level colors) must also match the simulator bit
for bit, static AND under churn, including certificate-driven ``eps=``
stopping — because each device's assembled-buffer dot runs the simulator's
own dense contraction (``repro.topo.lowering.block_mix_step``). The
in-process tests skip the M's the suite's device count cannot carry and
run fully in the CI dist-4dev job; a slow subprocess test pins the 2- and
4-device acceptance scenario from the default 1-device suite.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, run_cola
from repro.data import synthetic
from repro.dist.runtime import run_dist_cola

K = 8


@pytest.fixture(scope="module")
def ridge():
    x, y, _ = synthetic.regression(150, 48, seed=4)
    return problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


def _drop(t, rng):
    return rng.random(K) < 0.7


def _budgets(t, rng):
    b = np.full(K, 16)
    b[rng.random(K) < 0.5] = 4
    return b


# the elasticity surface: same schedule features the executor suite pins
CASES = {
    "plain": {},
    "churn_freeze": dict(active_schedule=_drop),
    "churn_reset": dict(active_schedule=_drop, leave_mode="reset"),
    "budgets": dict(budget_schedule=_budgets),
    "churn_budgets_reset": dict(active_schedule=_drop,
                                budget_schedule=_budgets, leave_mode="reset"),
}


def _assert_parity(sim, dist, case):
    np.testing.assert_array_equal(np.asarray(sim.state.x_parts),
                                  np.asarray(dist.state.x_parts),
                                  err_msg=case)
    np.testing.assert_array_equal(np.asarray(sim.state.v_stack),
                                  np.asarray(dist.state.v_stack),
                                  err_msg=case)
    assert sim.history["round"] == dist.history["round"]
    for name in ("primal", "hamiltonian", "dual", "gap",
                 "consensus_violation"):
        np.testing.assert_allclose(sim.history[name], dist.history[name],
                                   rtol=1e-5, atol=1e-6, err_msg=f"{case}:{name}")


@pytest.mark.parametrize("case", sorted(CASES))
def test_dist_bitwise_matches_sim_1host(ridge, mesh1, case):
    kwargs = CASES[case]
    cfg = ColaConfig(kappa=1.0)
    sim = run_cola(ridge, topo.connected_cycle(K, 2), cfg, 41,
                   record_every=10, seed=3, **kwargs)
    dist = run_dist_cola(ridge, topo.connected_cycle(K, 2), cfg, mesh1, 41,
                         comm="dense", record_every=10, seed=3,
                         block_size=16, **kwargs)
    _assert_parity(sim, dist, case)


def test_dist_bitwise_200_rounds_with_churn(ridge, mesh1):
    """The acceptance case: >= 200 rounds under churn + reset + budgets."""
    kwargs = dict(active_schedule=_drop, budget_schedule=_budgets,
                  leave_mode="reset")
    cfg = ColaConfig(kappa=1.0)
    sim = run_cola(ridge, topo.connected_cycle(K, 2), cfg, 200,
                   record_every=40, seed=7, **kwargs)
    dist = run_dist_cola(ridge, topo.connected_cycle(K, 2), cfg, mesh1, 200,
                         comm="dense", record_every=40, seed=7, **kwargs)
    _assert_parity(sim, dist, "200-round churn")


def test_dist_block_boundaries_invisible(ridge, mesh1):
    cfg = ColaConfig(kappa=1.0)
    a = run_dist_cola(ridge, topo.ring(K), cfg, mesh1, 24, comm="dense",
                      block_size=24)
    b = run_dist_cola(ridge, topo.ring(K), cfg, mesh1, 24, comm="dense",
                      block_size=5)
    np.testing.assert_array_equal(np.asarray(a.state.x_parts),
                                  np.asarray(b.state.x_parts))


def test_dist_gossip_steps_and_gram_modes(ridge, mesh1):
    """B>1 gossip and both CD formulations ride through the dist driver."""
    for cfg in (ColaConfig(kappa=0.5, gossip_steps=2),
                ColaConfig(kappa=1.0, cd_mode="residual")):
        sim = run_cola(ridge, topo.ring(K), cfg, 30, record_every=29)
        dist = run_dist_cola(ridge, topo.ring(K), cfg, mesh1, 30,
                             comm="dense", record_every=29)
        _assert_parity(sim, dist, repr(cfg))


def test_ring_and_plan_dispatch_to_block_on_small_mesh(ridge, mesh1):
    """The historical 'one node per device' ValueErrors are retired: on a
    mesh smaller than K, comm='ring' and comm='plan' (with or without
    churn) dispatch into the BLOCK plan path and reproduce the simulator
    bitwise."""
    cfg = ColaConfig(kappa=1.0)
    for kwargs in ({}, dict(active_schedule=_drop)):
        sim = run_cola(ridge, topo.ring(K), cfg, 8, record_every=4, seed=3,
                       **kwargs)
        for comm in ("ring", "plan"):
            dist = run_dist_cola(ridge, topo.ring(K), cfg, mesh1, 8,
                                 comm=comm, record_every=4, seed=3, **kwargs)
            _assert_parity(sim, dist, f"{comm}:{sorted(kwargs)}")


def test_dist_zero_rounds(ridge, mesh1):
    res = run_dist_cola(ridge, topo.ring(K), ColaConfig(), mesh1, 0,
                        comm="dense")
    assert res.history["round"] == []
    assert float(jnp.abs(res.state.x_parts).max()) == 0.0


# ---------------------------------------------------------------------------
# block-mode parity: K=8 paper-nodes on M in {1, 2, 4} devices, bitwise
# ---------------------------------------------------------------------------

def _block_mesh(m: int):
    if jax.device_count() < m:
        pytest.skip(f"block-mode mesh needs {m} devices "
                    f"(suite has {jax.device_count()}; CI dist-4dev runs it)")
    return jax.make_mesh((m,), ("data",))


@pytest.fixture(scope="module")
def lasso():
    x, y, _ = synthetic.regression(150, 48, seed=2, sparsity_solution=0.2)
    return problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)


@pytest.mark.parametrize("m", [1, 2, 4])
@pytest.mark.parametrize("case", ["static", "churn", "budgets"])
def test_block_plan_bitwise_matches_sim(ridge, m, case):
    """run_dist_cola(comm='plan') with K=8 on M devices: the torus (a
    genuinely non-circulant graph) quotients into K/M node blocks and the
    run matches run_cola bit for bit — static, under churn, and with
    heterogeneous CD budgets."""
    mesh = _block_mesh(m)
    kwargs = {"static": {}, "churn": dict(active_schedule=_drop),
              "budgets": dict(budget_schedule=_budgets)}[case]
    graph = topo.torus_2d(2, K // 2)
    cfg = ColaConfig(kappa=1.0)
    sim = run_cola(ridge, graph, cfg, 25, record_every=6, seed=3, **kwargs)
    dist = run_dist_cola(ridge, graph, cfg, mesh, 25, comm="plan",
                         record_every=6, seed=3, block_size=16, **kwargs)
    _assert_parity(sim, dist, f"block m={m} {case}")


@pytest.mark.parametrize("m", [2, 4])
def test_block_plan_certificate_stop_bitwise(lasso, m):
    """Certificate-driven eps= stopping through the BLOCK plan path: stop
    round and certificate rows equal the simulator's, stopped state bitwise
    equal to the truncated non-stopping run."""
    mesh = _block_mesh(m)
    graph = topo.torus_2d(2, K // 2)
    cfg = ColaConfig(kappa=8.0)
    kw = dict(record_every=20, recorder="certificate", eps=0.1)
    sim = run_cola(lasso, graph, cfg, 400, **kw)
    dist = run_dist_cola(lasso, graph, cfg, mesh, 400, comm="plan", **kw)
    assert dist.history["stop_round"] == sim.history["stop_round"]
    assert dist.history["stop_round"] is not None
    for name in ("local_gap_max", "grad_disagreement_max", "cond9_nodes",
                 "cond10_nodes", "certified"):
        np.testing.assert_allclose(sim.history[name], dist.history[name],
                                   rtol=1e-5, atol=1e-6, err_msg=name)
    t_stop = dist.history["stop_round"]
    trunc = run_dist_cola(lasso, graph, cfg, mesh, t_stop + 1, comm="plan",
                          record_every=20)
    np.testing.assert_array_equal(np.asarray(dist.state.x_parts),
                                  np.asarray(trunc.state.x_parts))
    np.testing.assert_array_equal(np.asarray(dist.state.v_stack),
                                  np.asarray(trunc.state.v_stack))


# --- subprocess pin: the 2-/4-device acceptance scenario from the default
# 1-device suite (the CI dist-4dev job runs the in-process suite above) ----

BLOCK_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.data import synthetic
    from repro.core import problems, topology as topo
    from repro.core.cola import ColaConfig, run_cola
    from repro.dist.runtime import run_dist_cola

    assert jax.device_count() == 4
    K = 8
    graph = topo.torus_2d(2, 4)
    x, y, _ = synthetic.regression(150, 48, seed=4)
    prob = problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)
    cfg = ColaConfig(kappa=1.0)

    def churn(t, rng):
        return rng.random(K) < 0.7

    for kwargs in ({}, dict(active_schedule=churn)):
        sim = run_cola(prob, graph, cfg, 25, record_every=6, seed=3,
                       **kwargs)
        for m in (2, 4):
            mesh = jax.make_mesh((m,), ("data",))
            dist = run_dist_cola(prob, graph, cfg, mesh, 25, comm="plan",
                                 record_every=6, seed=3, **kwargs)
            np.testing.assert_array_equal(np.asarray(sim.state.x_parts),
                                          np.asarray(dist.state.x_parts))
            np.testing.assert_array_equal(np.asarray(sim.state.v_stack),
                                          np.asarray(dist.state.v_stack))

    xl, yl, _ = synthetic.regression(150, 48, seed=2, sparsity_solution=0.2)
    lasso = problems.lasso(jnp.asarray(xl), jnp.asarray(yl), 5e-2, box=5.0)
    mesh = jax.make_mesh((4,), ("data",))
    stop = run_dist_cola(lasso, graph, ColaConfig(kappa=8.0), mesh, 400,
                         comm="plan", record_every=20,
                         recorder="certificate", eps=0.1)
    sim = run_cola(lasso, graph, ColaConfig(kappa=8.0), 400,
                   record_every=20, recorder="certificate", eps=0.1)
    assert stop.history["stop_round"] == sim.history["stop_round"]
    assert stop.history["stop_round"] is not None
    print("BLOCK_PARITY_OK")
""")


@pytest.mark.slow
def test_block_plan_4dev_subprocess():
    env = dict(os.environ, PYTHONPATH="src:.")
    out = subprocess.run([sys.executable, "-c", BLOCK_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "BLOCK_PARITY_OK" in out.stdout, out.stdout + "\n" + out.stderr
