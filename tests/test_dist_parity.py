"""shard_map runtime == single-host simulator on a 1-device mesh, bit for bit.

A 1-device mesh runs the real ``repro.dist.runtime`` code — shard_map,
collectives, schedule plumbing — with every collective degenerating to the
identity, so the distributed driver must reproduce ``run_cola`` EXACTLY
(state bitwise; metric rows to fusion rounding, same contract as the
loop-vs-block executor tests). Covers the full elasticity surface: churn
(freeze + reset-on-leave) and heterogeneous CD budgets, over 200+ rounds.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, run_cola
from repro.data import synthetic
from repro.dist.runtime import run_dist_cola

K = 8


@pytest.fixture(scope="module")
def ridge():
    x, y, _ = synthetic.regression(150, 48, seed=4)
    return problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


def _drop(t, rng):
    return rng.random(K) < 0.7


def _budgets(t, rng):
    b = np.full(K, 16)
    b[rng.random(K) < 0.5] = 4
    return b


# the elasticity surface: same schedule features the executor suite pins
CASES = {
    "plain": {},
    "churn_freeze": dict(active_schedule=_drop),
    "churn_reset": dict(active_schedule=_drop, leave_mode="reset"),
    "budgets": dict(budget_schedule=_budgets),
    "churn_budgets_reset": dict(active_schedule=_drop,
                                budget_schedule=_budgets, leave_mode="reset"),
}


def _assert_parity(sim, dist, case):
    np.testing.assert_array_equal(np.asarray(sim.state.x_parts),
                                  np.asarray(dist.state.x_parts),
                                  err_msg=case)
    np.testing.assert_array_equal(np.asarray(sim.state.v_stack),
                                  np.asarray(dist.state.v_stack),
                                  err_msg=case)
    assert sim.history["round"] == dist.history["round"]
    for name in ("primal", "hamiltonian", "dual", "gap",
                 "consensus_violation"):
        np.testing.assert_allclose(sim.history[name], dist.history[name],
                                   rtol=1e-5, atol=1e-6, err_msg=f"{case}:{name}")


@pytest.mark.parametrize("case", sorted(CASES))
def test_dist_bitwise_matches_sim_1host(ridge, mesh1, case):
    kwargs = CASES[case]
    cfg = ColaConfig(kappa=1.0)
    sim = run_cola(ridge, topo.connected_cycle(K, 2), cfg, 41,
                   record_every=10, seed=3, **kwargs)
    dist = run_dist_cola(ridge, topo.connected_cycle(K, 2), cfg, mesh1, 41,
                         comm="dense", record_every=10, seed=3,
                         block_size=16, **kwargs)
    _assert_parity(sim, dist, case)


def test_dist_bitwise_200_rounds_with_churn(ridge, mesh1):
    """The acceptance case: >= 200 rounds under churn + reset + budgets."""
    kwargs = dict(active_schedule=_drop, budget_schedule=_budgets,
                  leave_mode="reset")
    cfg = ColaConfig(kappa=1.0)
    sim = run_cola(ridge, topo.connected_cycle(K, 2), cfg, 200,
                   record_every=40, seed=7, **kwargs)
    dist = run_dist_cola(ridge, topo.connected_cycle(K, 2), cfg, mesh1, 200,
                         comm="dense", record_every=40, seed=7, **kwargs)
    _assert_parity(sim, dist, "200-round churn")


def test_dist_block_boundaries_invisible(ridge, mesh1):
    cfg = ColaConfig(kappa=1.0)
    a = run_dist_cola(ridge, topo.ring(K), cfg, mesh1, 24, comm="dense",
                      block_size=24)
    b = run_dist_cola(ridge, topo.ring(K), cfg, mesh1, 24, comm="dense",
                      block_size=5)
    np.testing.assert_array_equal(np.asarray(a.state.x_parts),
                                  np.asarray(b.state.x_parts))


def test_dist_gossip_steps_and_gram_modes(ridge, mesh1):
    """B>1 gossip and both CD formulations ride through the dist driver."""
    for cfg in (ColaConfig(kappa=0.5, gossip_steps=2),
                ColaConfig(kappa=1.0, cd_mode="residual")):
        sim = run_cola(ridge, topo.ring(K), cfg, 30, record_every=29)
        dist = run_dist_cola(ridge, topo.ring(K), cfg, mesh1, 30,
                             comm="dense", record_every=29)
        _assert_parity(sim, dist, repr(cfg))


def test_ring_comm_layout_and_churn_dispatch(ridge, mesh1):
    """comm='ring' under churn no longer raises 'needs a circulant W' — it
    dispatches into the compiled topology-program path (repro.topo), which
    still requires one node per device; a too-small mesh is the only
    remaining error."""
    cfg = ColaConfig(kappa=1.0)
    with pytest.raises(ValueError, match="one node per device"):
        # churn -> plan path; 8 nodes on 1 device cannot ppermute
        run_dist_cola(ridge, topo.ring(K), cfg, mesh1, 4, comm="ring",
                      active_schedule=_drop)
    with pytest.raises(ValueError, match="one node per device"):
        # 8 nodes on 1 device: ring comm needs K == mesh axis size
        run_dist_cola(ridge, topo.ring(K), cfg, mesh1, 4, comm="ring")
    with pytest.raises(ValueError, match="one node per device"):
        run_dist_cola(ridge, topo.ring(K), cfg, mesh1, 4, comm="plan")


def test_dist_zero_rounds(ridge, mesh1):
    res = run_dist_cola(ridge, topo.ring(K), ColaConfig(), mesh1, 0,
                        comm="dense")
    assert res.history["round"] == []
    assert float(jnp.abs(res.state.x_parts).max()) == 0.0
