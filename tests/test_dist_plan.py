"""Plan-executed gossip in the dist runtime: the non-circulant acceptance
suite.

``run_dist_cola(comm="plan")`` (and the ``comm="ring"`` requests that now
dispatch into it) must, on a real node mesh:

* match the ``comm="dense"`` all-gather oracle (and the simulator) on a
  non-circulant topology, static AND on a churn schedule;
* lower to neighbor-only HLO — zero all-gathers, collective-permute
  bounded by ``num_colors * d * itemsize`` per device per gossip step
  (asserted via ``launch.hlo_analysis``);
* keep certificate-driven ``eps=`` stopping bitwise-consistent with the
  truncated run.

Block mode extends the HLO contract to meshes smaller than the graph:
K paper-nodes on M < K devices lower to at most Delta_block + 1
collective-permutes of (K/M, d) block payloads per gossip step — asserted
on a complete graph with ODD K (the regime where greedy coloring exceeds
the Vizing bound at the node level) — and still zero all-gathers.

The in-process tests skip on a single-device suite (they need a real
multi-device mesh) and run in the CI 4-virtual-device job; the subprocess
test pins the same coverage from the default 1-device suite.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, run_cola
from repro.data import synthetic
from repro.dist.runtime import run_dist_cola

CERT_KEYS = ("local_gap_max", "grad_disagreement_max", "cond9_nodes",
             "cond10_nodes", "certified")


def _torus(k: int) -> topo.Topology:
    """A genuinely non-circulant graph on K nodes (row-major torus indexing
    mixes +-1 and +-cols offsets, so check_circulant_band rejects it)."""
    return topo.torus_2d(2, k // 2)


@pytest.fixture(scope="module")
def ridge_prob():
    x, y, _ = synthetic.regression(120, 48, seed=0)
    return problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)


@pytest.fixture(scope="module")
def lasso_prob():
    x, y, _ = synthetic.regression(150, 48, seed=2, sparsity_solution=0.2)
    return problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)


needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="per-node plan assertions want a K-device mesh (K == 4 here); "
           "smaller meshes exercise the block path instead")


@needs_mesh
def test_plan_matches_dense_oracle_static(ridge_prob):
    k = jax.device_count()
    mesh = jax.make_mesh((k,), ("data",))
    graph = _torus(k)
    cfg = ColaConfig(kappa=1.0)
    dense = run_dist_cola(ridge_prob, graph, cfg, mesh, 10, comm="dense")
    plan = run_dist_cola(ridge_prob, graph, cfg, mesh, 10, comm="plan")
    np.testing.assert_allclose(plan.history["primal"], dense.history["primal"],
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(plan.state.x_parts),
                               np.asarray(dense.state.x_parts),
                               rtol=1e-5, atol=1e-6)
    sim = run_cola(ridge_prob, graph, cfg, 10)
    np.testing.assert_allclose(plan.history["gap"], sim.history["gap"],
                               rtol=1e-4, atol=1e-5)


@needs_mesh
def test_ring_request_dispatches_to_plan_on_non_circulant(ridge_prob):
    """The stale 'comm=ring needs a circulant W' failure modes are now
    dispatches: a non-circulant graph and a churn schedule both run with
    neighbor-only communication."""
    k = jax.device_count()
    mesh = jax.make_mesh((k,), ("data",))
    graph = _torus(k)
    cfg = ColaConfig(kappa=1.0)
    ring = run_dist_cola(ridge_prob, graph, cfg, mesh, 8, comm="ring")
    dense = run_dist_cola(ridge_prob, graph, cfg, mesh, 8, comm="dense")
    np.testing.assert_allclose(ring.history["primal"], dense.history["primal"],
                               rtol=1e-5)


@needs_mesh
def test_plan_matches_dense_oracle_under_churn(ridge_prob):
    """The acceptance scenario: churn schedule + non-circulant topology,
    neighbor-only comm, same results as the dense all-gather oracle on the
    SAME schedule (identical rng consumption)."""
    k = jax.device_count()
    mesh = jax.make_mesh((k,), ("data",))
    graph = _torus(k)
    cfg = ColaConfig(kappa=1.0)

    def churn(t, rng):
        return rng.random(k) < 0.75

    kw = dict(active_schedule=churn, seed=5, record_every=3)
    dense = run_dist_cola(ridge_prob, graph, cfg, mesh, 15, comm="dense", **kw)
    plan = run_dist_cola(ridge_prob, graph, cfg, mesh, 15, comm="plan", **kw)
    ring = run_dist_cola(ridge_prob, graph, cfg, mesh, 15, comm="ring", **kw)
    np.testing.assert_allclose(plan.history["primal"], dense.history["primal"],
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(plan.state.v_stack),
                               np.asarray(dense.state.v_stack),
                               rtol=1e-5, atol=1e-6)
    # ring request under churn IS the plan path
    np.testing.assert_array_equal(np.asarray(ring.state.v_stack),
                                  np.asarray(plan.state.v_stack))


@needs_mesh
def test_plan_certificate_stop_bitwise_truncation(lasso_prob):
    """eps= stopping through the plan path: the stopped state equals the
    truncated non-stopping run bitwise, and the certificate history matches
    the simulator."""
    k = jax.device_count()
    mesh = jax.make_mesh((k,), ("data",))
    graph = _torus(k)
    cfg = ColaConfig(kappa=8.0)
    dist = run_dist_cola(lasso_prob, graph, cfg, mesh, 400, comm="plan",
                         record_every=20, recorder="certificate", eps=0.1)
    sim = run_cola(lasso_prob, graph, cfg, 400, record_every=20,
                   recorder="certificate", eps=0.1)
    assert dist.history["stop_round"] == sim.history["stop_round"]
    assert dist.history["stop_round"] is not None
    for name in CERT_KEYS:
        np.testing.assert_allclose(sim.history[name], dist.history[name],
                                   rtol=1e-4, atol=1e-5, err_msg=name)
    t_stop = dist.history["stop_round"]
    trunc = run_dist_cola(lasso_prob, graph, cfg, mesh, t_stop + 1,
                          comm="plan", record_every=20)
    np.testing.assert_array_equal(np.asarray(dist.state.x_parts),
                                  np.asarray(trunc.state.x_parts))
    np.testing.assert_array_equal(np.asarray(dist.state.v_stack),
                                  np.asarray(trunc.state.v_stack))


@needs_mesh
def test_plan_certificate_under_churn_matches_sim(lasso_prob):
    """Dynamic certificate mode through the plan path: the ppermute
    neighborhood follows the churn round's reweighted support."""
    k = jax.device_count()
    mesh = jax.make_mesh((k,), ("data",))
    graph = _torus(k)
    cfg = ColaConfig(kappa=8.0)

    def churn(t, rng):
        return rng.random(k) < 0.75

    kw = dict(record_every=20, recorder="certificate", eps=10.0,
              active_schedule=churn, seed=11)
    sim = run_cola(lasso_prob, graph, cfg, 300, **kw)
    dist = run_dist_cola(lasso_prob, graph, cfg, mesh, 300, comm="plan", **kw)
    assert sim.history["stop_round"] == dist.history["stop_round"]
    for name in CERT_KEYS:
        np.testing.assert_allclose(sim.history[name], dist.history[name],
                                   rtol=1e-4, atol=1e-5, err_msg=name)


@needs_mesh
def test_plan_round_hlo_is_neighbor_only():
    _assert_plan_round_neighbor_only()


def _assert_plan_round_neighbor_only():
    """Lower the plan-executed round program for the device mesh and hold
    it to the plan's declared ``CommContract`` (via ``analysis.check_comm``):
    zero all-gather/all-reduce bytes, at most ``num_colors``
    collective-permutes moving at most ``num_colors * d * itemsize`` per
    gossip step — the paper's O(deg * d) communication model in the actual
    HLO. The program is built by ``analysis.drivers`` — byte-identical to
    what ``python -m repro.analysis --all`` verifies in CI."""
    from repro.analysis import contracts, drivers

    x, y, _ = synthetic.regression(150, 48, seed=2, sparsity_solution=0.2)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)
    k, itemsize = jax.device_count(), 4
    hlo, plan = drivers.plan_round_hlo(prob, _torus(k), k)
    contracts.check_comm(hlo, plan.contract(prob.d, itemsize))
    # the dense oracle on the same graph DOES gather the (K, d) stack
    hlo_d = drivers.dense_round_hlo(prob, _torus(k), k)
    contracts.check_comm(hlo_d, contracts.gather_contract(
        "dense-oracle", min_all_gather_bytes=prob.d * itemsize))


@pytest.mark.skipif(jax.device_count() < 3,
                    reason="block HLO assertion lowers for a 3-device mesh")
def test_block_plan_round_hlo_is_neighbor_only():
    _assert_block_round_neighbor_only()


def _assert_block_round_neighbor_only():
    """The block-mode HLO budget, on the acceptance scenario: a complete
    graph with ODD K (K=9 — where greedy node-level coloring exceeds the
    Vizing bound) quotiented onto M=3 devices. One gossip step must issue
    at most Delta_block + 1 collective-permutes (the block-level color
    count — NOT the 9 the per-node coloring would take), move at most
    colors * (K/M) * d * itemsize payload bytes per device, and contain
    zero all-gathers/all-reduces — the ``BlockPlan.contract()`` budget,
    checked via ``analysis.check_comm`` on the shared driver program."""
    from repro.analysis import contracts, drivers

    k, m, itemsize = 9, 3, 4
    x, y, _ = synthetic.regression(153, 48, seed=2, sparsity_solution=0.2)
    prob = problems.lasso(jnp.asarray(x), jnp.asarray(y), 5e-2, box=5.0)
    hlo, plan = drivers.block_round_hlo(prob, topo.complete(k), k, m)
    delta_block = int(np.asarray(
        [row.sum() for row in plan.block.support()]).max())
    # Vizing bound on the quotient: the contract's <= num_colors permute
    # cap is therefore at least as strict as the <= Delta_block + 1
    # acceptance budget (3 on K_9-over-3-devices, not the 9+ the
    # node-level coloring would cost)
    assert plan.num_colors <= delta_block + 1
    contracts.check_comm(hlo, plan.contract(prob.d, itemsize))


# --- subprocess pin: the full acceptance scenario from the 1-device suite --

PLAN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import tests.test_dist_plan as tdp
    import jax, jax.numpy as jnp, numpy as np
    from repro.data import synthetic
    from repro.core import problems, topology as topo
    from repro.core.cola import ColaConfig, run_cola
    from repro.dist.runtime import run_dist_cola

    assert jax.device_count() == 4
    mesh = jax.make_mesh((4,), ("data",))
    graph = topo.torus_2d(2, 2)
    x, y, _ = synthetic.regression(120, 48, seed=0)
    prob = problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)
    cfg = ColaConfig(kappa=1.0)

    def churn(t, rng):
        return rng.random(4) < 0.75

    kw = dict(active_schedule=churn, seed=5, record_every=3)
    dense = run_dist_cola(prob, graph, cfg, mesh, 15, comm="dense", **kw)
    plan = run_dist_cola(prob, graph, cfg, mesh, 15, comm="plan", **kw)
    np.testing.assert_allclose(plan.history["primal"],
                               dense.history["primal"], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(plan.state.v_stack),
                               np.asarray(dense.state.v_stack),
                               rtol=1e-5, atol=1e-6)
    tdp._assert_plan_round_neighbor_only()

    xl, yl, _ = synthetic.regression(150, 48, seed=2, sparsity_solution=0.2)
    lasso = problems.lasso(jnp.asarray(xl), jnp.asarray(yl), 5e-2, box=5.0)
    cfg8 = ColaConfig(kappa=8.0)
    stop = run_dist_cola(lasso, graph, cfg8, mesh, 400, comm="plan",
                         record_every=20, recorder="certificate", eps=0.1)
    t_stop = stop.history["stop_round"]
    assert t_stop is not None
    trunc = run_dist_cola(lasso, graph, cfg8, mesh, t_stop + 1, comm="plan",
                          record_every=20)
    np.testing.assert_array_equal(np.asarray(stop.state.x_parts),
                                  np.asarray(trunc.state.x_parts))
    np.testing.assert_array_equal(np.asarray(stop.state.v_stack),
                                  np.asarray(trunc.state.v_stack))
    tdp._assert_block_round_neighbor_only()
    print("DIST_PLAN_OK")
""")


@pytest.mark.slow
def test_plan_4dev_subprocess():
    env = dict(os.environ, PYTHONPATH="src:.")
    out = subprocess.run([sys.executable, "-c", PLAN_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "DIST_PLAN_OK" in out.stdout, out.stdout + "\n" + out.stderr


@needs_mesh
@pytest.mark.parametrize("robust", ["trim", "median", "clip"])
def test_plan_attacked_defended_matches_sim(lasso_prob, robust):
    """Attack + robust mixing through the block-plan executor (K=8 nodes on
    the 4-device mesh): state matches the simulator BITWISE for trim/median;
    clip is allclose end to end (its sqrt/divide chain fuses differently by
    shard shape inside the scanned program — see
    ``topo.lowering.block_robust_mix_step``)."""
    from repro import attack

    k = 8
    graph = topo.torus_2d(2, 4)
    mesh = jax.make_mesh((jax.device_count(),), ("nodes",))
    byz = attack.Byzantine(nodes=(1, 6), mode="sign_flip", scale=10.0,
                           start=5, seed=1)
    cfg = ColaConfig(kappa=2.0, robust=robust)
    kw = dict(record_every=10, recorder="gap+certificate", eps=1.0,
              attacks=[byz])
    # clip does not neutralize this attack (it bounds per-step influence
    # but the run still grows): compare before the growth amplifies the
    # expected ~1 ulp/step drift past the tolerance
    rounds = 20 if robust == "clip" else 60
    sim = run_cola(lasso_prob, graph, cfg, rounds, **kw)
    dist = run_dist_cola(lasso_prob, graph, cfg, mesh, rounds, comm="plan",
                         **kw)
    if robust == "clip":
        # ~1 ulp/step of fusion drift compounds along the growing attacked
        # trajectory: observed ~2e-4 relative by round 20
        np.testing.assert_allclose(np.asarray(dist.state.x_parts),
                                   np.asarray(sim.state.x_parts),
                                   rtol=1e-3, atol=1e-5)
        np.testing.assert_allclose(np.asarray(dist.state.v_stack),
                                   np.asarray(sim.state.v_stack),
                                   rtol=1e-3, atol=1e-5)
    else:
        np.testing.assert_array_equal(np.asarray(dist.state.x_parts),
                                      np.asarray(sim.state.x_parts))
        np.testing.assert_array_equal(np.asarray(dist.state.v_stack),
                                      np.asarray(sim.state.v_stack))
    np.testing.assert_allclose(dist.history["consensus_residual"],
                               sim.history["consensus_residual"],
                               rtol=1e-5, atol=1e-6)
    assert dist.history["violated_round"] == sim.history["violated_round"]
