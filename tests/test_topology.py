"""Topology / mixing-matrix properties (paper App. B) — property-based."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import topology as topo

BUILDERS = {
    "ring": topo.ring,
    "cycle2": lambda k: topo.connected_cycle(k, 2),
    "complete": topo.complete,
    "star": topo.star,
    "grid": lambda k: topo.grid_2d(*topo._square_factors(k)),
    "torus": lambda k: topo.torus_2d(*topo._square_factors(k)),
}


@settings(max_examples=40, deadline=None)
@given(k=st.integers(5, 24), name=st.sampled_from(sorted(BUILDERS)))
def test_metropolis_doubly_stochastic_symmetric(k, name):
    w = topo.metropolis_weights(BUILDERS[name](k))
    np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-12)
    np.testing.assert_allclose(w, w.T, atol=1e-12)
    assert (w >= -1e-15).all()


@settings(max_examples=30, deadline=None)
@given(k=st.integers(5, 24), name=st.sampled_from(sorted(BUILDERS)))
def test_connected_graphs_have_positive_spectral_gap(k, name):
    w = topo.metropolis_weights(BUILDERS[name](k))
    assert topo.spectral_gap(w) > 1e-6


def test_disconnected_gap_zero():
    w = topo.metropolis_weights(topo.disconnected(6))
    np.testing.assert_allclose(w, np.eye(6))
    assert topo.spectral_gap(w) == pytest.approx(0.0, abs=1e-12)


def test_edge_utilization():
    g = topo.ring(8)
    w = topo.metropolis_weights(g)
    assert ((w > 0) == (g.adjacency | np.eye(8, dtype=bool))).all()


def test_beta_ordering_matches_connectivity():
    """Better-connected graphs have smaller beta (paper Fig. 3 intuition)."""
    k = 16
    betas = {n: topo.beta(topo.metropolis_weights(b(k)))
             for n, b in BUILDERS.items()}
    assert betas["complete"] < betas["cycle2"] < betas["ring"]
    assert betas["torus"] < betas["ring"]


@settings(max_examples=25, deadline=None)
@given(k=st.integers(6, 20), drop=st.integers(1, 3), seed=st.integers(0, 99))
def test_reweight_for_active_stays_doubly_stochastic(k, drop, seed):
    rng = np.random.default_rng(seed)
    active = np.ones(k, dtype=bool)
    active[rng.choice(k, size=drop, replace=False)] = False
    w = topo.reweight_for_active(topo.connected_cycle(k, 2), active)
    np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-12)
    # inactive nodes are isolated: W_kk = 1
    for i in np.nonzero(~active)[0]:
        assert w[i, i] == pytest.approx(1.0)
        assert w[i].sum() == pytest.approx(1.0)
