"""End-to-end behaviour: training reduces loss; serving generates; the CoLA
linear-probe workflow (paper core on deep-model features) runs end to end."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, smoke_variant
from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, run_cola
from repro.models.model import build_model
from repro.train import checkpoint
from repro.train.data import TokenBatches
from repro.train.steps import (TrainHParams, greedy_generate,
                               init_train_state, make_train_step)


def test_training_reduces_loss():
    cfg = smoke_variant(get_config("xlstm_125m"))
    hp = TrainHParams(lr=3e-3)
    state = init_train_state(cfg, jax.random.PRNGKey(0), hp)
    step = jax.jit(make_train_step(cfg, hp))
    pipe = TokenBatches(cfg.vocab_size, 4, 32, corpus_tokens=1 << 13)
    losses = []
    for i in range(30):
        state, m = step(state, jax.tree.map(jnp.asarray, pipe(i)))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[:3] + \
        losses[-3:]


def test_greedy_generation_deterministic_shapes():
    cfg = smoke_variant(get_config("qwen3_4b"))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    out1 = greedy_generate(cfg, params, prompt, num_steps=6, max_len=16)
    out2 = greedy_generate(cfg, params, prompt, num_steps=6, max_len=16)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert (np.asarray(out1) < cfg.vocab_size).all()


def test_checkpoint_roundtrip():
    cfg = smoke_variant(get_config("h2o_danube3_4b"))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        checkpoint.save(path, params)
        restored = checkpoint.restore(path, jax.tree.map(
            lambda p: jnp.zeros_like(p), params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cola_linear_probe_on_model_features():
    """The paper's convex core training a readout on deep-model features:
    extract features from a smoke model, fit a ridge probe decentralized over
    4 nodes, verify it beats the zero predictor."""
    cfg = smoke_variant(get_config("qwen3_4b"))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    pipe = TokenBatches(cfg.vocab_size, 8, 16, corpus_tokens=1 << 12)
    batch = jax.tree.map(jnp.asarray, pipe(0))
    logits, _ = api.forward(params, batch)
    feats = np.asarray(logits.reshape(-1, cfg.vocab_size))[:, :64]
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=64)
    y = feats @ w_true + 0.01 * rng.normal(size=feats.shape[0])
    prob = problems.ridge_primal(jnp.asarray(feats, jnp.float32),
                                 jnp.asarray(y, jnp.float32), 1e-3)
    res = run_cola(prob, topo.ring(4), ColaConfig(kappa=4.0), rounds=100,
                   record_every=99)
    zero_obj = float(prob.objective(jnp.zeros(64)))
    assert res.history["primal"][-1] < 0.2 * zero_obj
