"""Fault tolerance / elasticity (paper §2, Fig. 4, Fig. 6)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import problems, topology as topo
from repro.core.cola import ColaConfig, run_cola, solve_reference
from repro.data import synthetic


@pytest.fixture(scope="module")
def ridge():
    x, y, _ = synthetic.regression(150, 48, seed=4)
    return problems.ridge_primal(jnp.asarray(x), jnp.asarray(y), 1e-2)


@pytest.fixture(scope="module")
def opt(ridge):
    return solve_reference(ridge, rounds=1200, kappa=10)


def _drop_schedule(p, seed=0):
    def schedule(t, rng):
        return rng.random(8) < p
    return schedule


def test_converges_under_node_dropout(ridge, opt):
    """Fig. 4: suboptimality decreases monotonically-ish for p > 0."""
    res = run_cola(ridge, topo.connected_cycle(8, 2), ColaConfig(kappa=2.0),
                   rounds=200, record_every=40,
                   active_schedule=_drop_schedule(0.3))
    sub = np.array(res.history["primal"]) - opt
    assert sub[-1] < sub[0] * 0.2
    assert sub[-1] < 0.5


def test_higher_stay_probability_faster(ridge, opt):
    """Fig. 4: larger p (stay) converges faster."""
    subs = {}
    for stay in (0.5, 1.0):
        res = run_cola(ridge, topo.connected_cycle(8, 2),
                       ColaConfig(kappa=2.0), rounds=120, record_every=119,
                       active_schedule=_drop_schedule(1.0 - stay), seed=7)
        subs[stay] = res.history["primal"][-1] - opt
    assert subs[1.0] <= subs[0.5] + 1e-6


def test_freeze_mode_preserves_mean_invariant(ridge):
    """Lemma 1 invariant holds under churn with frozen leavers."""
    res = run_cola(ridge, topo.connected_cycle(8, 2), ColaConfig(kappa=1.0),
                   rounds=50, record_every=49,
                   active_schedule=_drop_schedule(0.4), leave_mode="freeze")
    from repro.core.partition import make_partition
    part = make_partition(ridge.n, 8)
    x = part.merge_vector(res.state.x_parts)
    np.testing.assert_allclose(
        np.asarray(jnp.mean(res.state.v_stack, axis=0)),
        np.asarray(ridge.a @ x), rtol=3e-4, atol=3e-5)


def test_reset_mode_oscillates_but_stays_bounded(ridge, opt):
    """Fig. 6: the reset-on-leave failure model 'oscillates and does not
    converge fast' (paper App. D) — we assert exactly that: bounded iterates,
    some progress, but clearly slower than the freeze model."""
    reset = run_cola(ridge, topo.connected_cycle(8, 2), ColaConfig(kappa=2.0),
                     rounds=200, record_every=40,
                     active_schedule=_drop_schedule(0.15), leave_mode="reset")
    traj = np.array(reset.history["primal"])
    assert np.isfinite(traj).all()
    assert traj[-1] <= traj[0] + 1e-6          # no divergence
    freeze = run_cola(ridge, topo.connected_cycle(8, 2),
                      ColaConfig(kappa=2.0), rounds=200, record_every=199,
                      active_schedule=_drop_schedule(0.15),
                      leave_mode="freeze")
    assert freeze.history["primal"][-1] - opt <= traj[-1] - opt + 1e-6
