"""Gossip data-parallel optimizer (the paper's communication pattern applied
to deep-net training) — semantics + elasticity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, smoke_variant
from repro.core import topology as topo
from repro.optim import gossip as gsp
from repro.train.data import TokenBatches
from repro.train.steps import TrainHParams, init_train_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("xlstm_125m"))
    hp = TrainHParams(lr=1e-3)
    state0 = init_train_state(cfg, jax.random.PRNGKey(0), hp)
    local = make_train_step(cfg, hp)
    pipe = TokenBatches(cfg.vocab_size, 2, 16, corpus_tokens=1 << 12)
    return cfg, hp, state0, local, pipe


def _stack_batches(pipe, step, k):
    return jax.tree.map(jnp.asarray,
                        jax.tree.map(lambda *xs: np.stack(xs),
                                     *[pipe(step, shard=j) for j in range(k)]))


def test_mixing_preserves_parameter_mean(setup):
    """W doubly stochastic => the node-average of every leaf is invariant."""
    cfg, hp, state0, local, pipe = setup
    k = 4
    gcfg = gsp.GossipConfig(num_nodes=k)
    states = gsp.replicate_state(state0, k)
    step = gsp.make_gossip_step(local, gcfg)
    w = jnp.asarray(gcfg.weights(), jnp.float32)
    act = jnp.ones((k,), jnp.float32)
    states, _ = step(states, _stack_batches(pipe, 0, k), w, act)
    before = gsp.average_params(states.params)
    mixed = gsp.mix_pytree(w, states.params)
    after = gsp.average_params(mixed)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_complete_graph_one_mix_reaches_consensus(setup):
    cfg, hp, state0, local, pipe = setup
    k = 4
    gcfg = gsp.GossipConfig(num_nodes=k, topology="complete")
    states = gsp.replicate_state(state0, k)
    step = gsp.make_gossip_step(local, gcfg)
    w = jnp.full((k, k), 1.0 / k, jnp.float32)  # CoCoA-style full averaging
    act = jnp.ones((k,), jnp.float32)
    states, _ = step(states, _stack_batches(pipe, 1, k), w, act)
    assert float(gsp.consensus_distance(states.params)) < 1e-8


def test_consensus_distance_decreases_over_rounds(setup):
    cfg, hp, state0, local, pipe = setup
    k = 4
    gcfg = gsp.GossipConfig(num_nodes=k, topology="ring")
    states = gsp.replicate_state(state0, k)
    step = gsp.make_gossip_step(local, gcfg)
    w = jnp.asarray(gcfg.weights(), jnp.float32)
    act = jnp.ones((k,), jnp.float32)
    dists, losses = [], []
    for i in range(12):
        states, metrics = step(states, _stack_batches(pipe, i, k), w, act)
        dists.append(float(gsp.consensus_distance(states.params)))
        losses.append(float(jnp.mean(metrics["loss"])))
    # gossip keeps replicas within a bounded neighborhood (no divergence)
    assert dists[-1] < 10 * (min(dists) + 1e-12) + 1e-6
    assert losses[-1] < losses[0]  # and training still makes progress


def test_frozen_nodes_keep_state(setup):
    """Theta_k = 1 elasticity: an inactive node's state is not updated by the
    local step (its params still move by mixing — by design)."""
    cfg, hp, state0, local, pipe = setup
    k = 4
    gcfg = gsp.GossipConfig(num_nodes=k, gossip_steps=0)  # isolate local step
    states = gsp.replicate_state(state0, k)
    step = gsp.make_gossip_step(local, gcfg)
    w = jnp.eye(k, dtype=jnp.float32)
    act = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    new_states, _ = step(states, _stack_batches(pipe, 2, k), w, act)
    p_old = jax.tree.leaves(states.params)
    p_new = jax.tree.leaves(new_states.params)
    for a, b in zip(p_old, p_new):
        np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]))
        np.testing.assert_allclose(np.asarray(a[3]), np.asarray(b[3]))
        assert not np.allclose(np.asarray(a[0]), np.asarray(b[0]))


def test_gossip_b_steps_contracts_faster(setup):
    cfg, hp, state0, local, pipe = setup
    k = 8
    w = jnp.asarray(topo.metropolis_weights(topo.ring(k)), jnp.float32)
    # perturb replicas, then measure contraction of consensus distance
    states = gsp.replicate_state(state0, k)
    noise = jax.tree.map(
        lambda p: p + 0.01 * jax.random.normal(
            jax.random.PRNGKey(5), p.shape, jnp.float32).astype(p.dtype),
        states.params)
    d0 = float(gsp.consensus_distance(noise))
    d1 = float(gsp.consensus_distance(gsp.mix_pytree(w, noise, steps=1)))
    d3 = float(gsp.consensus_distance(gsp.mix_pytree(w, noise, steps=3)))
    assert d3 < d1 < d0


def test_gossip_block_runner_consensus_recorder(setup):
    """The block runner threads a Recorder: on-device consensus rows come
    back as a history, and a consensus stop condition short-circuits the
    remaining rounds (the CoLA early-exit machinery on the gossip path)."""
    cfg, hp, state0, local, pipe = setup
    k, rounds = 4, 6
    gcfg = gsp.GossipConfig(num_nodes=k, topology="complete")
    w = jnp.full((k, k), 1.0 / k, jnp.float32)  # full averaging: consensus
    act = jnp.ones((k,), jnp.float32)
    batches = [_stack_batches(pipe, t, k) for t in range(rounds)]
    bat_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)

    runner = gsp.make_gossip_block_runner(
        local, gcfg, recorder=gsp.ConsensusRecorder())
    states, metrics, hist = runner(
        gsp.replicate_state(state0, k), bat_stack,
        jnp.broadcast_to(w, (rounds, k, k)),
        jnp.broadcast_to(act, (rounds, k)), gsp.mix_schedule(rounds, 1),
        block_size=3)
    assert hist["round"] == list(range(rounds))
    assert hist["stop_round"] is None
    assert all(d < 1e-6 for d in hist["consensus_distance"])  # full mix
    assert np.asarray(metrics["loss"]).shape[0] == rounds

    # armed stop: full averaging certifies consensus on the first record
    runner2 = gsp.make_gossip_block_runner(
        local, gcfg, recorder=gsp.ConsensusRecorder(eps=1e-6))
    _, _, hist2 = runner2(
        gsp.replicate_state(state0, k), bat_stack,
        jnp.broadcast_to(w, (rounds, k, k)),
        jnp.broadcast_to(act, (rounds, k)), gsp.mix_schedule(rounds, 1),
        block_size=3)
    assert hist2["stop_round"] == 0
    assert hist2["round"] == [0]


# ---------------------------------------------------------------------------
# differential privacy on the gossip wire (repro.optim.privacy)
# ---------------------------------------------------------------------------

from repro.optim import privacy  # noqa: E402


def test_dp_config_validation():
    with pytest.raises(ValueError, match="clip > 0"):
        privacy.DPConfig(clip=0.0, sigma=1.0)
    with pytest.raises(ValueError, match="clip > 0"):
        privacy.DPConfig(clip=1.0, sigma=-1.0)
    with pytest.raises(ValueError, match="delta"):
        privacy.DPConfig(clip=1.0, sigma=1.0, delta=2.0)
    dp = privacy.DPConfig(clip=0.5, sigma=2.0)
    assert dp.sensitivity == 1.0          # replace-one: 2 * clip
    assert dp.noise_std == 2.0            # sigma * sensitivity


def test_accountant_zcdp_composition():
    acct = privacy.GaussianAccountant(sigma=2.0, delta=1e-5)
    assert acct.epsilon() == 0.0
    acct.add(16)
    rho = 16 / (2.0 * 4.0)
    assert acct.rho == pytest.approx(rho)
    assert acct.epsilon() == pytest.approx(
        rho + 2.0 * np.sqrt(rho * np.log(1e5)))
    # additive composition: two batches == one combined batch
    acct2 = privacy.GaussianAccountant(sigma=2.0).add(10).add(6)
    assert acct2.rho == pytest.approx(acct.rho)
    with pytest.raises(ValueError, match="un-release"):
        acct.add(-1)


def test_release_count_per_link_vs_broadcast():
    graph = topo.TOPOLOGIES["ring"](8)          # degree 2
    dp_link = privacy.DPConfig(clip=1.0, sigma=1.0, per_link=True)
    dp_bcast = privacy.DPConfig(clip=1.0, sigma=1.0, per_link=False)
    assert privacy.max_degree(graph) == 2
    assert dp_link.releases_per_mix_round(graph, gossip_steps=3) == 6
    assert dp_bcast.releases_per_mix_round(graph, gossip_steps=3) == 3
    eps = privacy.epsilon_schedule(dp_link, graph, 3,
                                   np.array([0, 1, 4, 10]))
    assert eps[0] == 0.0
    assert np.all(np.diff(eps) > 0)             # strictly accumulating


def test_clip_params_bounds_global_pytree_norm():
    rng = np.random.default_rng(0)
    stack = {"a": jnp.asarray(rng.standard_normal((4, 10)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal((4, 3, 2)), jnp.float32)}
    clipped = privacy.clip_params(stack, clip=1.0)
    flat = np.concatenate(
        [np.asarray(p).reshape(4, -1) for p in jax.tree.leaves(clipped)],
        axis=1)
    norms = np.linalg.norm(flat, axis=1)
    assert np.all(norms <= 1.0 + 1e-6)
    # a stack already inside the ball passes through untouched
    small = jax.tree.map(lambda p: p * 1e-3, stack)
    same = privacy.clip_params(small, clip=1.0)
    for a, b in zip(jax.tree.leaves(small), jax.tree.leaves(same)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_noisy_mix_centers_on_clipped_mix_and_is_reproducible():
    rng = np.random.default_rng(1)
    k = 6
    w = jnp.asarray(topo.metropolis_weights(topo.TOPOLOGIES["ring"](k)),
                    jnp.float32)
    stack = {"p": jnp.asarray(rng.standard_normal((k, 12)), jnp.float32)}
    key = jax.random.PRNGKey(0)
    tiny = privacy.DPConfig(clip=10.0, sigma=1e-7)
    out = privacy.noisy_dense_mix(w, stack, tiny, key)
    clean = jnp.einsum("kl,ld->kd", w, privacy.clip_params(
        stack, 10.0)["p"])
    np.testing.assert_allclose(np.asarray(out["p"]), np.asarray(clean),
                               rtol=1e-4, atol=1e-5)
    # the noise stream is a pure function of (key, step, leaf index)
    loud = privacy.DPConfig(clip=10.0, sigma=0.5)
    a = privacy.noisy_dense_mix(w, stack, loud, key)
    b = privacy.noisy_dense_mix(w, stack, loud, key)
    np.testing.assert_array_equal(np.asarray(a["p"]), np.asarray(b["p"]))
    c = privacy.noisy_dense_mix(w, stack, loud, jax.random.PRNGKey(1))
    assert np.any(np.asarray(a["p"]) != np.asarray(c["p"]))
    # per-link and broadcast noise are genuinely different mechanisms
    d = privacy.noisy_dense_mix(
        w, stack, privacy.DPConfig(clip=10.0, sigma=0.5, per_link=False),
        key)
    assert np.any(np.asarray(a["p"]) != np.asarray(d["p"]))


def test_dp_rejects_mesh_and_robust_combos(setup):
    cfg, hp, state0, local, pipe = setup
    gcfg = gsp.GossipConfig(num_nodes=4, robust="trim")
    with pytest.raises(ValueError, match="per-link noise"):
        gsp.make_gossip_step(local, gcfg,
                             dp=privacy.DPConfig(clip=1.0, sigma=1.0))
    mesh = jax.make_mesh((1,), ("nodes",))
    with pytest.raises(ValueError, match="dense"):
        gsp.make_gossip_step(local, gsp.GossipConfig(num_nodes=4),
                             mesh=mesh, axis="nodes",
                             dp=privacy.DPConfig(clip=1.0, sigma=1.0))


def test_dp_block_runner_history_carries_epsilon(setup):
    cfg, hp, state0, local, pipe = setup
    k = 4
    gcfg = gsp.GossipConfig(num_nodes=k, gossip_steps=2, mix_every=2)
    dp = privacy.DPConfig(clip=5.0, sigma=1.0)
    runner = gsp.make_gossip_block_runner(
        local, gcfg, dp=dp, recorder=gsp.ConsensusRecorder())
    rounds = 8
    states = gsp.replicate_state(state0, k)
    batches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_stack_batches(pipe, t, k) for t in range(rounds)])
    w = jnp.broadcast_to(jnp.asarray(gcfg.weights(), jnp.float32),
                         (rounds, k, k))
    act = jnp.ones((rounds, k), jnp.float32)
    mix = np.asarray([(t + 1) % gcfg.mix_every == 0 for t in range(rounds)],
                     np.float32)
    states, _, history = runner(states, batches, w, act, mix, block_size=4)
    eps = np.asarray(history["dp_epsilon"])
    assert eps.shape[0] == len(history["round"])
    assert np.all(np.diff(eps) >= 0) and eps[-1] > 0
    info = history["dp"]
    # 4 mix rounds x B=2 steps x deg_max=2 links = 16 releases
    assert info["releases"] == 16
    assert info["epsilon"] == pytest.approx(
        privacy.GaussianAccountant(1.0, dp.delta).add(16).epsilon())
    assert info["per_link"] is True
