"""Gossip data-parallel optimizer (the paper's communication pattern applied
to deep-net training) — semantics + elasticity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, smoke_variant
from repro.core import topology as topo
from repro.optim import gossip as gsp
from repro.train.data import TokenBatches
from repro.train.steps import TrainHParams, init_train_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("xlstm_125m"))
    hp = TrainHParams(lr=1e-3)
    state0 = init_train_state(cfg, jax.random.PRNGKey(0), hp)
    local = make_train_step(cfg, hp)
    pipe = TokenBatches(cfg.vocab_size, 2, 16, corpus_tokens=1 << 12)
    return cfg, hp, state0, local, pipe


def _stack_batches(pipe, step, k):
    return jax.tree.map(jnp.asarray,
                        jax.tree.map(lambda *xs: np.stack(xs),
                                     *[pipe(step, shard=j) for j in range(k)]))


def test_mixing_preserves_parameter_mean(setup):
    """W doubly stochastic => the node-average of every leaf is invariant."""
    cfg, hp, state0, local, pipe = setup
    k = 4
    gcfg = gsp.GossipConfig(num_nodes=k)
    states = gsp.replicate_state(state0, k)
    step = gsp.make_gossip_step(local, gcfg)
    w = jnp.asarray(gcfg.weights(), jnp.float32)
    act = jnp.ones((k,), jnp.float32)
    states, _ = step(states, _stack_batches(pipe, 0, k), w, act)
    before = gsp.average_params(states.params)
    mixed = gsp.mix_pytree(w, states.params)
    after = gsp.average_params(mixed)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_complete_graph_one_mix_reaches_consensus(setup):
    cfg, hp, state0, local, pipe = setup
    k = 4
    gcfg = gsp.GossipConfig(num_nodes=k, topology="complete")
    states = gsp.replicate_state(state0, k)
    step = gsp.make_gossip_step(local, gcfg)
    w = jnp.full((k, k), 1.0 / k, jnp.float32)  # CoCoA-style full averaging
    act = jnp.ones((k,), jnp.float32)
    states, _ = step(states, _stack_batches(pipe, 1, k), w, act)
    assert float(gsp.consensus_distance(states.params)) < 1e-8


def test_consensus_distance_decreases_over_rounds(setup):
    cfg, hp, state0, local, pipe = setup
    k = 4
    gcfg = gsp.GossipConfig(num_nodes=k, topology="ring")
    states = gsp.replicate_state(state0, k)
    step = gsp.make_gossip_step(local, gcfg)
    w = jnp.asarray(gcfg.weights(), jnp.float32)
    act = jnp.ones((k,), jnp.float32)
    dists, losses = [], []
    for i in range(12):
        states, metrics = step(states, _stack_batches(pipe, i, k), w, act)
        dists.append(float(gsp.consensus_distance(states.params)))
        losses.append(float(jnp.mean(metrics["loss"])))
    # gossip keeps replicas within a bounded neighborhood (no divergence)
    assert dists[-1] < 10 * (min(dists) + 1e-12) + 1e-6
    assert losses[-1] < losses[0]  # and training still makes progress


def test_frozen_nodes_keep_state(setup):
    """Theta_k = 1 elasticity: an inactive node's state is not updated by the
    local step (its params still move by mixing — by design)."""
    cfg, hp, state0, local, pipe = setup
    k = 4
    gcfg = gsp.GossipConfig(num_nodes=k, gossip_steps=0)  # isolate local step
    states = gsp.replicate_state(state0, k)
    step = gsp.make_gossip_step(local, gcfg)
    w = jnp.eye(k, dtype=jnp.float32)
    act = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    new_states, _ = step(states, _stack_batches(pipe, 2, k), w, act)
    p_old = jax.tree.leaves(states.params)
    p_new = jax.tree.leaves(new_states.params)
    for a, b in zip(p_old, p_new):
        np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]))
        np.testing.assert_allclose(np.asarray(a[3]), np.asarray(b[3]))
        assert not np.allclose(np.asarray(a[0]), np.asarray(b[0]))


def test_gossip_b_steps_contracts_faster(setup):
    cfg, hp, state0, local, pipe = setup
    k = 8
    w = jnp.asarray(topo.metropolis_weights(topo.ring(k)), jnp.float32)
    # perturb replicas, then measure contraction of consensus distance
    states = gsp.replicate_state(state0, k)
    noise = jax.tree.map(
        lambda p: p + 0.01 * jax.random.normal(
            jax.random.PRNGKey(5), p.shape, jnp.float32).astype(p.dtype),
        states.params)
    d0 = float(gsp.consensus_distance(noise))
    d1 = float(gsp.consensus_distance(gsp.mix_pytree(w, noise, steps=1)))
    d3 = float(gsp.consensus_distance(gsp.mix_pytree(w, noise, steps=3)))
    assert d3 < d1 < d0


def test_gossip_block_runner_consensus_recorder(setup):
    """The block runner threads a Recorder: on-device consensus rows come
    back as a history, and a consensus stop condition short-circuits the
    remaining rounds (the CoLA early-exit machinery on the gossip path)."""
    cfg, hp, state0, local, pipe = setup
    k, rounds = 4, 6
    gcfg = gsp.GossipConfig(num_nodes=k, topology="complete")
    w = jnp.full((k, k), 1.0 / k, jnp.float32)  # full averaging: consensus
    act = jnp.ones((k,), jnp.float32)
    batches = [_stack_batches(pipe, t, k) for t in range(rounds)]
    bat_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)

    runner = gsp.make_gossip_block_runner(
        local, gcfg, recorder=gsp.ConsensusRecorder())
    states, metrics, hist = runner(
        gsp.replicate_state(state0, k), bat_stack,
        jnp.broadcast_to(w, (rounds, k, k)),
        jnp.broadcast_to(act, (rounds, k)), gsp.mix_schedule(rounds, 1),
        block_size=3)
    assert hist["round"] == list(range(rounds))
    assert hist["stop_round"] is None
    assert all(d < 1e-6 for d in hist["consensus_distance"])  # full mix
    assert np.asarray(metrics["loss"]).shape[0] == rounds

    # armed stop: full averaging certifies consensus on the first record
    runner2 = gsp.make_gossip_block_runner(
        local, gcfg, recorder=gsp.ConsensusRecorder(eps=1e-6))
    _, _, hist2 = runner2(
        gsp.replicate_state(state0, k), bat_stack,
        jnp.broadcast_to(w, (rounds, k, k)),
        jnp.broadcast_to(act, (rounds, k)), gsp.mix_schedule(rounds, 1),
        block_size=3)
    assert hist2["stop_round"] == 0
    assert hist2["round"] == [0]
