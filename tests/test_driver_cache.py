"""Content-addressed driver-cache keys (the id(problem) aliasing bugfix).

The old keys included ``id(problem)``: a rebuilt Problem at a recycled
address silently reused the wrong compiled driver (whose closure baked in
the OLD problem's data), and a live entry pinned the whole Problem via the
closure. The content key must (a) differ whenever anything a jitted closure
captures differs — array data, hyperparameters — regardless of addresses,
and (b) coincide for separately-built identical Problems, which is exactly
the property an id()-based key can never have (two live equal-content
objects always have distinct ids, so these tests fail on the old scheme).
"""
import gc

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executor as exec_engine, problems, topology as topo
from repro.core.cola import ColaConfig, run_cola
from repro.data import synthetic


def _ridge(seed=0, lam=1e-2, y_shift=0.0):
    x, y, _ = synthetic.regression(60, 24, seed=seed)
    return problems.ridge_primal(jnp.asarray(x), jnp.asarray(y) + y_shift,
                                 lam)


def test_fingerprint_is_content_addressed():
    p1, p2 = _ridge(), _ridge()
    assert p1 is not p2
    # identical content, different addresses -> same key (cache HIT; the
    # id()-keyed scheme returns distinct keys here and fails)
    assert exec_engine.fingerprint(p1) == exec_engine.fingerprint(p2)
    # anything a closure captures must change the key: the label vector is
    # captured only inside Problem.f/grad_f closures, not a dataclass field
    assert exec_engine.fingerprint(p1) != exec_engine.fingerprint(
        _ridge(y_shift=1.0))
    assert exec_engine.fingerprint(p1) != exec_engine.fingerprint(
        _ridge(lam=2e-2))
    assert exec_engine.fingerprint(p1) != exec_engine.fingerprint(
        _ridge(seed=1))


def test_recycled_address_different_content_misses():
    """The aliasing scenario itself: rebuild a different-content Problem
    that may land on the recycled address; its run must use ITS data."""
    exec_engine.clear_driver_cache()
    graph, cfg = topo.ring(4), ColaConfig(kappa=1.0)
    p1 = _ridge()
    res1 = run_cola(p1, graph, cfg, 10, record_every=9)
    fp1 = exec_engine.fingerprint(p1)
    del p1
    gc.collect()  # frees p1's address for possible reuse by p2
    p2 = _ridge(y_shift=1.0)  # same shapes/dtypes, different labels
    fp2 = exec_engine.fingerprint(p2)
    assert fp1 != fp2  # even if id(p2) == addr1, the key differs
    res2 = run_cola(p2, graph, cfg, 10, record_every=9)
    # fresh-cache reference run for p2: results must match it exactly
    exec_engine.clear_driver_cache()
    ref2 = run_cola(p2, graph, cfg, 10, record_every=9)
    np.testing.assert_array_equal(np.asarray(res2.state.x_parts),
                                  np.asarray(ref2.state.x_parts))
    assert res2.history["primal"][-1] != pytest.approx(
        res1.history["primal"][-1])


def test_identical_rebuild_hits_cache():
    """Rebuilding an identical Problem per call reuses the compiled driver
    (the workload pattern the ROADMAP item called out)."""
    exec_engine.clear_driver_cache()
    graph, cfg = topo.ring(4), ColaConfig(kappa=1.0)
    run_cola(_ridge(), graph, cfg, 5)
    n_entries = len(exec_engine._DRIVER_CACHE)
    res = run_cola(_ridge(), graph, cfg, 5)  # fresh object, same content
    assert len(exec_engine._DRIVER_CACHE) == n_entries
    exec_engine.clear_driver_cache()
    ref = run_cola(_ridge(), graph, cfg, 5)
    np.testing.assert_array_equal(np.asarray(res.state.x_parts),
                                  np.asarray(ref.state.x_parts))


def test_fingerprint_hashes_arrays_schedules_and_functions():
    a = np.arange(6, dtype=np.float32)
    assert exec_engine.fingerprint(a) == exec_engine.fingerprint(a.copy())
    assert exec_engine.fingerprint(a) != exec_engine.fingerprint(a + 1)
    assert exec_engine.fingerprint(a) != exec_engine.fingerprint(
        a.astype(np.float64))
    assert exec_engine.fingerprint(a) != exec_engine.fingerprint(
        a.reshape(2, 3))

    def make(c):
        def f(x):
            return x + c
        return f

    # same bytecode, different captured constant
    assert exec_engine.fingerprint(make(1.0)) != exec_engine.fingerprint(
        make(2.0))
    assert exec_engine.fingerprint(make(1.0)) == exec_engine.fingerprint(
        make(1.0))


def test_fingerprint_sees_names_globals_and_kwdefaults():
    """Same-bytecode bodies that differ only in the attribute they call, a
    referenced module-level constant, or a keyword-only default must not
    collide (they bake different constants into the compiled driver)."""
    f_exp = lambda v: jnp.exp(v)   # noqa: E731 — identical bytecode,
    f_log = lambda v: jnp.log(v)   # noqa: E731 — co_names differ
    assert exec_engine.fingerprint(f_exp) != exec_engine.fingerprint(f_log)
    assert exec_engine.fingerprint(f_exp) == exec_engine.fingerprint(
        lambda v: jnp.exp(v))

    # literals inside nested code: same outer bytecode, nested const differs
    assert exec_engine.fingerprint(
        lambda x: (lambda y: y * 2.0)(x)) != exec_engine.fingerprint(
        lambda x: (lambda y: y * 3.0)(x))

    for code in ("def g(v):\n    return v * SCALE\n",
                 # global read only inside a nested lambda
                 "def g(v):\n    return (lambda y: y * SCALE)(v)\n"):
        ns_a = {"SCALE": 2.0}
        ns_b = {"SCALE": 3.0}
        exec(compile(code, "<fp>", "exec"), ns_a)
        exec(compile(code, "<fp>", "exec"), ns_b)
        assert exec_engine.fingerprint(ns_a["g"]) != exec_engine.fingerprint(
            ns_b["g"]), code

    def mk(default):
        def f(x, *, step=default):
            return x * step
        return f

    assert exec_engine.fingerprint(mk(1.0)) != exec_engine.fingerprint(
        mk(2.0))


def test_fingerprint_refuses_address_based_reprs():
    """Objects whose only identity is their address must hash by contents
    (via __dict__) or raise — never silently fall back to address-keying."""
    class Plain:
        def __init__(self, v):
            self.v = v

    assert exec_engine.fingerprint(Plain(1)) == exec_engine.fingerprint(
        Plain(1))
    assert exec_engine.fingerprint(Plain(1)) != exec_engine.fingerprint(
        Plain(2))

    class Opaque:
        __slots__ = ()

    with pytest.raises(TypeError, match="content-hash"):
        exec_engine.fingerprint(Opaque())


# --- fingerprint memoization (the digest cached on frozen dataclasses) -----

def test_fingerprint_memoizes_on_frozen_dataclasses_only():
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class Frozen:
        v: float

    @dataclasses.dataclass
    class Mutable:
        v: float

    fz = Frozen(1.0)
    fp = exec_engine.fingerprint(fz)
    assert getattr(fz, exec_engine._FP_MEMO_ATTR) == fp
    assert exec_engine.fingerprint(fz) == fp  # memo path, same digest

    mu = Mutable(1.0)
    exec_engine.fingerprint(mu)
    assert not hasattr(mu, exec_engine._FP_MEMO_ATTR)
    # and the mutable object correctly rehashes after mutation
    before = exec_engine.fingerprint(mu)
    mu.v = 2.0
    assert exec_engine.fingerprint(mu) != before


def test_fingerprint_memo_staleness_on_inplace_array_mutation():
    """The documented soundness boundary: a frozen dataclass wrapping a
    MUTABLE np array mutated in place returns the memoized (now stale)
    digest — clearing the memo rehashes the real content. This pins the
    contract so a future memo change can't silently widen it."""
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class Holder:
        a: np.ndarray

    h = Holder(np.arange(4, dtype=np.float32))
    fp0 = exec_engine.fingerprint(h)
    h.a[0] = 99.0  # in-place: the frozen wrapper can't see it
    assert exec_engine.fingerprint(h) == fp0  # stale memo, by design
    object.__delattr__(h, exec_engine._FP_MEMO_ATTR)
    fp1 = exec_engine.fingerprint(h)
    assert fp1 != fp0  # rehash sees the mutation
    assert fp1 == exec_engine.fingerprint(
        Holder(np.asarray([99.0, 1.0, 2.0, 3.0], np.float32)))


def test_fingerprint_memo_agrees_across_equal_problems():
    """Memoized and fresh digests of distinct-but-equal Problems coincide
    (the memo is an optimization, never a key change)."""
    p1, p2 = _ridge(), _ridge()
    fp1 = exec_engine.fingerprint(p1)   # memoizes on p1
    assert exec_engine.fingerprint(p1) == fp1
    assert exec_engine.fingerprint(p2) == fp1  # p2 hashed from scratch
    assert getattr(p2, exec_engine._FP_MEMO_ATTR) == fp1
    # multi-object calls never read or write memos
    assert exec_engine.fingerprint(p1, p1) == exec_engine.fingerprint(p2, p2)


def test_clear_driver_cache_releases_pinned_closures():
    """A cached driver's closure pins its Problem; clear_driver_cache must
    actually release it (the liveness half of the id()-key bugfix)."""
    import weakref

    exec_engine.clear_driver_cache()
    graph, cfg = topo.ring(4), ColaConfig(kappa=1.0)
    p = _ridge(y_shift=3.0)  # content unique to this test
    run_cola(p, graph, cfg, 5)
    assert len(exec_engine._DRIVER_CACHE) > 0
    ref = weakref.ref(p)
    del p
    gc.collect()
    assert ref() is not None, "cached driver should pin the Problem"
    exec_engine.clear_driver_cache()
    gc.collect()
    assert ref() is None, "clear_driver_cache left the Problem pinned"


def test_driver_cache_stats_and_listeners():
    """The retrace-accounting API: stats count hits/misses/bypasses and
    listeners observe every resolution (what analysis.RetraceMonitor and
    round_bench --check consume)."""
    exec_engine.clear_driver_cache()
    exec_engine.driver_cache_stats(reset=True)
    events = []
    exec_engine._CACHE_LISTENERS.append(lambda k, kind: events.append(kind))
    try:
        exec_engine.cached_driver("stats-key", lambda: (lambda: 1))
        exec_engine.cached_driver("stats-key", lambda: (lambda: 2))
        exec_engine.cached_driver(None, lambda: (lambda: 3))
    finally:
        exec_engine._CACHE_LISTENERS.pop()
    stats = exec_engine.driver_cache_stats()
    assert stats["misses"] >= 1 and stats["hits"] >= 1 \
        and stats["bypass"] >= 1
    assert events == ["misses", "hits", "bypass"]
    # the warmed key resolved to the SAME driver object
    assert exec_engine.cached_driver("stats-key", lambda: (lambda: 4))() == 1
    exec_engine.clear_driver_cache()
