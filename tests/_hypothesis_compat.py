"""Deterministic fallback for the optional ``hypothesis`` dependency.

``hypothesis`` is listed in requirements-dev.txt but is not required to run
the suite: when it is installed, this module re-exports the real
``given``/``settings``/``strategies``; when it is missing, the property
tests degrade to a fixed number of seeded pseudo-random draws per strategy
(same coverage shape, fully deterministic, no shrinking).

Usage in test modules:

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import numpy as _np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(
                lambda rng: options[int(rng.integers(0, len(options)))])

    def settings(**_kwargs):
        return lambda fn: fn

    def given(**strategies):
        def decorate(fn):
            def run_examples():
                rng = _np.random.default_rng(0)
                for _ in range(_FALLBACK_EXAMPLES):
                    fn(**{name: strat.draw(rng)
                          for name, strat in sorted(strategies.items())})

            run_examples.__name__ = fn.__name__
            run_examples.__doc__ = fn.__doc__
            return run_examples

        return decorate
